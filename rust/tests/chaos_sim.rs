//! Deterministic chaos suite over the hermetic sim backend — the ISSUE 9
//! acceptance tests. Zero artifacts, zero skips, every CI invocation.
//!
//! Every scenario scripts faults through `SimOptions` (context death,
//! hangs, transient execute errors) and drives them through the REAL
//! stack — `Runtime::run`'s supervised dispatch loop, the worker pool,
//! the tenant trainer, the serving front-end — then asserts the two
//! properties the supervision plane promises (DESIGN.md §14):
//!
//!   1. **Byte-identity under recovery.** Jobs are seeded by job id, not
//!      by context identity, so requeue-on-context-loss re-executes on a
//!      survivor and produces the same bytes as the fault-free run:
//!      decode fingerprints AND trained GRPO theta bit patterns are
//!      compared against clean references at D ∈ {2, 4}.
//!   2. **Typed, counted degradation.** Deaths quarantine, hangs strike,
//!      transients retry with backoff, exhaustion surfaces a typed
//!      `SupervisionError` — and every event lands in the supervisor
//!      counters, checked here all the way through the logged JSONL row.

use std::collections::{BTreeMap, HashSet};

use tinylora_rl::adapters::packing::Precision;
use tinylora_rl::coordinator::grpo::GrpoConfig;
use tinylora_rl::engine::pool::{GenJob, WorkerPool};
use tinylora_rl::engine::InferenceEngine;
use tinylora_rl::metrics::RunLog;
use tinylora_rl::runtime::{
    Health, SimOptions, SupervisionError, SupervisorPolicy, SIM_SCHEME, SIM_TIER,
};
use tinylora_rl::serving::{AdapterStore, ArrivalTrace, Frontend, FrontendConfig, SchedPolicy, TraceConfig};
use tinylora_rl::tasks::generator::SUITES;
use tinylora_rl::tokenizer::Tokenizer;
use tinylora_rl::trainer::pipeline::train_async;
use tinylora_rl::trainer::{PipelineConfig, TenantSpec, TenantTrainer};
use tinylora_rl::util::json::Value;
use tinylora_rl::util::Pcg64;
use tinylora_rl::weights::WeightSet;
use tinylora_rl::Runtime;

fn scratch(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("tlrl_chaos_sim_{name}"));
    std::fs::create_dir_all(&dir).ok();
    dir
}

fn base_weights(rt: &Runtime, seed: u64) -> WeightSet {
    WeightSet::init(&rt.manifest.tier(SIM_TIER).unwrap().clone(), seed).unwrap()
}

/// Same mixed decode workload as `tests/e2e_sim.rs`: padded single-row
/// jobs and grouped GRPO-style jobs, two adapters, per-job RNG streams.
fn mixed_jobs(rt: &Runtime) -> Vec<GenJob> {
    let weights = base_weights(rt, 0);
    let adapters = [weights, base_weights(rt, 3)];
    (0..6u64)
        .map(|id| {
            let mut rng = Pcg64::with_stream(500 + id, 0x6a6f6273);
            let grouped = id % 3 == 2;
            GenJob {
                id,
                weights: adapters[(id % 2) as usize].clone(),
                problems: (0..if grouped { 2 } else { 3 })
                    .map(|_| SUITES[(id % 2) as usize].generate(&mut rng))
                    .collect(),
                group: if grouped { 2 } else { 1 },
                pb: None,
                temperature: 1.0,
                seed: 70 + id,
                policy_version: 0,
            }
        })
        .collect()
}

/// Token streams + behavior log-prob bit patterns per job — the
/// byte-identity currency of the determinism matrix.
fn fingerprint(
    results: &[tinylora_rl::engine::pool::GenJobResult],
) -> Vec<(u64, Vec<i32>, Vec<u32>)> {
    results
        .iter()
        .map(|r| {
            let mut toks = Vec::new();
            let mut bits = Vec::new();
            for row in &r.rows {
                toks.extend_from_slice(&row.response);
                bits.extend(row.behavior.iter().map(|x| x.to_bits()));
            }
            (r.id, toks, bits)
        })
        .collect()
}

/// Tentpole acceptance, decode leg: kill a context mid-wave at D ∈ {2, 4}
/// — the lost slots requeue onto survivors and the pooled results stay
/// byte-identical to the fault-free serial reference, while the requeue /
/// quarantine / death counters fire and survive the trip through the
/// logged metrics JSONL row.
#[test]
fn context_death_mid_wave_is_byte_identical_at_d_2_4() {
    let rt_ref = Runtime::sim(1).unwrap();
    let engine_ref = InferenceEngine::new(&rt_ref, SIM_TIER, rt_ref.manifest.batch.test).unwrap();
    let reference =
        fingerprint(&WorkerPool::serve_serial(&rt_ref, &engine_ref, &mixed_jobs(&rt_ref)).unwrap());
    assert_eq!(reference.len(), 6);

    for d in [2usize, 4] {
        // ctx 1 serves exactly one execute, then every later dispatch to
        // it observes an injected ContextLost
        let opts = SimOptions {
            die_after_execs: BTreeMap::from([(1usize, 1u64)]),
            ..Default::default()
        };
        let rt = Runtime::sim_with(d, opts).unwrap();
        let engine = InferenceEngine::new(&rt, SIM_TIER, rt.manifest.batch.test).unwrap();
        let survived =
            fingerprint(&WorkerPool::new(4).serve(&rt, &engine, mixed_jobs(&rt)).unwrap());
        assert_eq!(
            survived, reference,
            "D={d}: decode under context death diverged from the fault-free reference"
        );
        assert_eq!(rt.supervisor().health(1), Health::Quarantined, "D={d}: dead ctx not quarantined");
        let sv = rt.supervisor().stats();
        assert!(sv.deaths >= 1, "D={d}: no death counted: {sv:?}");
        assert!(sv.quarantines >= 1, "D={d}: no quarantine counted: {sv:?}");
        assert!(sv.requeues >= 1, "D={d}: no requeue counted — loss never re-pinned: {sv:?}");
        assert_eq!(rt.supervisor().live_count(), d - 1);

        // acceptance: the counters are visible in LOGGED metrics, not
        // just in-process — write the supervisor row and parse it back
        let path = scratch("counters").join(format!("supervisor_d{d}.jsonl"));
        std::fs::remove_file(&path).ok();
        {
            let mut log = RunLog::new(Some(&path), false);
            log.log_supervisor(SIM_TIER, &sv, rt.devices(), rt.supervisor().live_count());
        }
        let row = Value::parse(std::fs::read_to_string(&path).unwrap().trim()).unwrap();
        assert_eq!(row.get("kind").unwrap().str().unwrap(), "supervisor");
        assert!(row.get("requeues").unwrap().usize().unwrap() >= 1);
        assert!(row.get("quarantines").unwrap().usize().unwrap() >= 1);
        assert!(row.get("deaths").unwrap().usize().unwrap() >= 1);
        assert_eq!(row.get("live").unwrap().usize().unwrap(), d - 1);
    }
}

/// Tentpole acceptance, training leg: GRPO tenant waves trained across a
/// context pool where every non-zero context dies after one execute land
/// on bit-identical adapter theta vs the fault-free single-context run,
/// at D ∈ {2, 4}.
#[test]
fn grpo_theta_is_bit_identical_under_context_death_at_d_2_4() {
    let specs = || -> Vec<TenantSpec> {
        (0..3u64)
            .map(|i| TenantSpec {
                name: format!("tenant-{i}"),
                scheme_tag: SIM_SCHEME.into(),
                cfg: GrpoConfig {
                    group: 2,
                    steps: 3,
                    lr: 2e-3 + i as f32 * 1e-3,
                    warmup: 2,
                    seed: 40 + i,
                    ..Default::default()
                },
                precision: Precision::Bf16,
            })
            .collect()
    };
    let thetas = |rt: &Runtime| -> Vec<Vec<u32>> {
        let b = rt.manifest.batch.test;
        let base = base_weights(rt, 3);
        let mut tt =
            TenantTrainer::with_batch(rt, &base, specs(), 2, &scratch("grpo"), b).unwrap();
        tt.train(rt, &mut RunLog::null(), true).unwrap();
        tt.sessions
            .iter()
            .map(|s| s.lp.policy.theta.iter().map(|x| x.to_bits()).collect())
            .collect()
    };

    let clean = thetas(&Runtime::sim(1).unwrap());
    for d in [2usize, 4] {
        let opts = SimOptions {
            die_after_execs: (1..d).map(|c| (c, 1u64)).collect(),
            ..Default::default()
        };
        let rt = Runtime::sim_with(d, opts).unwrap();
        let faulty = thetas(&rt);
        assert_eq!(
            faulty, clean,
            "D={d}: GRPO theta diverged when training survived context death"
        );
        let sv = rt.supervisor().stats();
        assert!(sv.deaths >= 1, "D={d}: faults never fired: {sv:?}");
        assert!(sv.requeues >= 1, "D={d}: no training work was re-pinned: {sv:?}");
    }
}

/// ISSUE 10 acceptance, chaos leg: the async pipeline's staleness-0
/// identity survives mid-pipeline context death. Every non-zero context
/// dies after one execute while `train_async` streams rollout waves at
/// D ∈ {2, 4} — the supervised dispatch requeues the lost decodes onto
/// survivors, so the pipeline still lands on adapter theta bit-identical
/// to the fault-free synchronous run, with exact staleness accounting
/// (nothing produced is lost to the fault, nothing is dropped as stale)
/// and the death/requeue counters proving the chaos actually fired.
#[test]
fn pipeline_staleness_zero_identity_survives_context_death_at_d_2_4() {
    let specs = || -> Vec<TenantSpec> {
        (0..3u64)
            .map(|i| TenantSpec {
                name: format!("tenant-{i}"),
                scheme_tag: SIM_SCHEME.into(),
                cfg: GrpoConfig {
                    group: 2,
                    steps: 3,
                    lr: 2e-3 + i as f32 * 1e-3,
                    warmup: 2,
                    seed: 40 + i,
                    ..Default::default()
                },
                precision: Precision::Bf16,
            })
            .collect()
    };
    let theta_bits = |tt: &TenantTrainer| -> Vec<Vec<u32>> {
        tt.sessions
            .iter()
            .map(|s| s.lp.policy.theta.iter().map(|x| x.to_bits()).collect())
            .collect()
    };

    // fault-free synchronous reference
    let rt_ref = Runtime::sim(1).unwrap();
    let mut tt_ref = TenantTrainer::with_batch(
        &rt_ref,
        &base_weights(&rt_ref, 3),
        specs(),
        2,
        &scratch("pipe_chaos"),
        rt_ref.manifest.batch.test,
    )
    .unwrap();
    tt_ref.train(&rt_ref, &mut RunLog::null(), true).unwrap();
    let clean = theta_bits(&tt_ref);

    for d in [2usize, 4] {
        let opts = SimOptions {
            die_after_execs: (1..d).map(|c| (c, 1u64)).collect(),
            ..Default::default()
        };
        let rt = Runtime::sim_with(d, opts).unwrap();
        let mut tt = TenantTrainer::with_batch(
            &rt,
            &base_weights(&rt, 3),
            specs(),
            2,
            &scratch("pipe_chaos"),
            rt.manifest.batch.test,
        )
        .unwrap();
        let pcfg = PipelineConfig { max_staleness: 0, optimizer_threads: 2, queue_cap: 0 };
        let (_, stats) = train_async(&rt, &mut tt, &pcfg, &mut RunLog::null(), true).unwrap();
        assert_eq!(
            theta_bits(&tt),
            clean,
            "D={d}: pipeline theta diverged when training survived context death"
        );
        // the staleness ledger is untouched by the fault: a requeued decode
        // re-executes at the SAME policy version, so nothing ages out
        assert_eq!(
            (stats.produced, stats.consumed, stats.dropped_stale, stats.max_version_gap),
            (9, 9, 0, 0),
            "D={d}: context death leaked into the staleness accounting"
        );
        let sv = rt.supervisor().stats();
        assert!(sv.deaths >= 1, "D={d}: faults never fired: {sv:?}");
        assert!(sv.requeues >= 1, "D={d}: no pipeline work was re-pinned: {sv:?}");
    }
}

/// Tentpole acceptance, serving leg: a context quarantined by the health
/// check degrades the front-end to the surviving capacity — horizon
/// stretches and goodput drops, but NOTHING extra is shed at a generous
/// deadline (the exact request set is served, byte-identical), and under
/// a tight deadline the served/shed sets still partition the trace
/// exactly once.
#[test]
fn quarantined_context_degrades_goodput_but_sheds_nothing_extra() {
    let tcfg = TraceConfig {
        seed: 5,
        n: 48,
        rate: 400.0,
        burst: 1,
        tenants: 4,
        zipf_s: 0.0,
        ..Default::default()
    };
    let trace = ArrivalTrace::generate(&tcfg).unwrap();
    let cfg_a = FrontendConfig {
        batch: 4,
        slots: 2,
        deadline: 30.0,
        max_wait: 0.02,
        service_base: 0.05,
        service_per_row: 0.0,
        policy: SchedPolicy::DeadlineFlush,
        continuous: true,
    };

    type Served = (tinylora_rl::serving::SloStats, Vec<(u64, String)>, Vec<u64>);
    let run = |faulty: bool, cfg: &FrontendConfig| -> Served {
        let opts = if faulty {
            SimOptions { die_after_execs: BTreeMap::from([(1usize, 0u64)]), ..Default::default() }
        } else {
            SimOptions::default()
        };
        let rt = Runtime::sim_with(2, opts).unwrap();
        // the health check is what converts a scripted death into a
        // quarantine BEFORE the serve plans its capacity
        let healths = rt.health_check().unwrap();
        if faulty {
            assert_eq!(healths[1], Health::Quarantined, "probe must catch the dead context");
            assert_eq!(rt.supervisor().live_count(), 1);
        } else {
            assert!(healths.iter().all(|h| *h == Health::Live));
        }
        let mut store = AdapterStore::with_tiers(SIM_TIER, 4, 32);
        let mut rng = Pcg64::new(11);
        for name in &trace.tenant_names() {
            let theta: Vec<f32> = (0..13).map(|_| rng.normal() * 0.01).collect();
            store.register(name, SIM_SCHEME, &theta, Precision::Bf16).unwrap();
        }
        let mut fe =
            Frontend::new(&rt, store, base_weights(&rt, 3), cfg.clone(), scratch("frontend"))
                .unwrap();
        let plan = fe.serve_trace(&rt, &trace).unwrap();
        let slo = fe.slo(&plan);
        let mut texts: Vec<(u64, String)> =
            fe.responses.iter().map(|r| (r.id, r.text.clone())).collect();
        texts.sort();
        let shed_ids: Vec<u64> = plan.sheds.iter().map(|x| x.id).collect();
        let sv = rt.supervisor().stats();
        if faulty {
            assert!(sv.deaths >= 1 && sv.quarantines >= 1, "faulty run recorded nothing: {sv:?}");
        }
        (slo, texts, shed_ids)
    };

    // generous deadline: degraded capacity stretches the horizon and
    // drops goodput but serves the EXACT same set, byte-identical
    let (slo_h, texts_h, sheds_h) = run(false, &cfg_a);
    let (slo_d, texts_d, sheds_d) = run(true, &cfg_a);
    assert_eq!((slo_h.served, slo_h.shed), (48, 0));
    assert_eq!((slo_d.served, slo_d.shed), (48, 0), "degradation must not shed at a generous deadline");
    assert!(sheds_h.is_empty() && sheds_d.is_empty());
    assert_eq!(texts_d, texts_h, "degraded serving changed decoded bytes");
    assert!(
        slo_d.horizon > slo_h.horizon,
        "lost slot must stretch the horizon: {} vs {}",
        slo_d.horizon,
        slo_h.horizon
    );
    assert!(
        slo_d.goodput < slo_h.goodput,
        "lost slot must cost goodput: {} vs {}",
        slo_d.goodput,
        slo_h.goodput
    );

    // tight deadline on the degraded plane: 12 batches × 50ms on one
    // surviving slot cannot all dispatch within 150ms — shedding must
    // trigger, and served ∪ shed must still partition the trace exactly
    let cfg_b = FrontendConfig { deadline: 0.15, ..cfg_a };
    let (slo_t, texts_t, sheds_t) = run(true, &cfg_b);
    assert!(slo_t.shed > 0, "tight deadline on degraded capacity must shed");
    let served: HashSet<u64> = texts_t.iter().map(|(id, _)| *id).collect();
    let shed: HashSet<u64> = sheds_t.iter().copied().collect();
    assert_eq!(served.len() + shed.len(), 48, "request lost or double-resolved");
    assert!(served.is_disjoint(&shed), "a request was both served and shed");
    let all: HashSet<u64> = trace.events.iter().map(|e| e.id).collect();
    let mut union = served.clone();
    union.extend(&shed);
    assert_eq!(union, all, "served ∪ shed must be exactly the trace");
}

/// Transient execute errors retry in place with backoff and then succeed
/// — consumed faults leave the decoded rows byte-equal to a clean run,
/// with exactly the scripted number of retries counted.
#[test]
fn transient_exec_errors_retry_then_match_clean_run() {
    let tok = Tokenizer::new();
    let run = |opts: SimOptions| -> (Vec<(Vec<i32>, Vec<u32>)>, u64) {
        let rt = Runtime::sim_with(1, opts).unwrap();
        let engine = InferenceEngine::new(&rt, SIM_TIER, rt.manifest.batch.test).unwrap();
        let weights = base_weights(&rt, 0);
        let mut prng = Pcg64::new(17);
        let problems: Vec<_> = (0..3).map(|_| SUITES[0].generate(&mut prng)).collect();
        let mut rng = Pcg64::with_stream(9, 0x72657472);
        let rows = engine
            .generate_problems_on(&rt, 0, &weights, &problems, &tok, 0.0, &mut rng)
            .unwrap();
        let fp = rows
            .iter()
            .map(|r| (r.response.clone(), r.behavior.iter().map(|x| x.to_bits()).collect()))
            .collect();
        (fp, rt.supervisor().stats().retries)
    };

    let (clean, clean_retries) = run(SimOptions::default());
    assert_eq!(clean_retries, 0);
    let faulty_opts = SimOptions {
        exec_failures: BTreeMap::from([(0usize, 2u32)]),
        ..Default::default()
    };
    let (healed, retries) = run(faulty_opts);
    assert_eq!(retries, 2, "two injected failures must cost exactly two retries");
    assert_eq!(healed, clean, "retried decode diverged from the clean run");
}

/// A transient error that outlives the retry budget surfaces as a clean,
/// typed `SupervisionError::RetriesExhausted` — not a hang, not a panic.
#[test]
fn exhausted_retries_surface_a_typed_error() {
    let opts = SimOptions {
        exec_failures: BTreeMap::from([(0usize, 100u32)]),
        ..Default::default()
    };
    let rt = Runtime::sim_with(1, opts).unwrap().with_supervisor_policy(SupervisorPolicy {
        max_retries: 1,
        backoff_base_ms: 0,
        ..Default::default()
    });
    let engine = InferenceEngine::new(&rt, SIM_TIER, rt.manifest.batch.test).unwrap();
    let weights = base_weights(&rt, 0);
    let mut prng = Pcg64::new(17);
    let problems: Vec<_> = (0..2).map(|_| SUITES[0].generate(&mut prng)).collect();
    let tok = Tokenizer::new();
    let mut rng = Pcg64::with_stream(9, 0x72657472);
    let err = engine
        .generate_problems_on(&rt, 0, &weights, &problems, &tok, 0.0, &mut rng)
        .unwrap_err();
    let exhausted = err.chain().any(|c| {
        matches!(
            c.downcast_ref::<SupervisionError>(),
            Some(SupervisionError::RetriesExhausted { attempts: 2, .. })
        )
    });
    assert!(exhausted, "expected RetriesExhausted in the chain, got: {err:#}");
    assert_eq!(rt.supervisor().stats().retries, 1, "exactly the budgeted retry was taken");
}

/// Hang detection: a context stalling far past the execute deadline
/// collects strikes, goes Suspect → Quarantined, and the pool's results
/// remain byte-identical (the hang model returns correct bytes late; the
/// deadline policy is what converts lateness into quarantine).
#[test]
fn hung_context_strikes_out_and_is_quarantined_without_changing_bytes() {
    let rt_ref = Runtime::sim(1).unwrap();
    let engine_ref = InferenceEngine::new(&rt_ref, SIM_TIER, rt_ref.manifest.batch.test).unwrap();
    let reference =
        fingerprint(&WorkerPool::serve_serial(&rt_ref, &engine_ref, &mixed_jobs(&rt_ref)).unwrap());

    let opts = SimOptions {
        hang_execs_us: BTreeMap::from([(1usize, 200_000u64)]),
        ..Default::default()
    };
    // 200ms injected stall vs a 50ms deadline: every ctx-1 execute is a
    // strike; ctx 0 computes in well under 50ms, so no spurious strikes
    let rt = Runtime::sim_with(2, opts).unwrap().with_supervisor_policy(SupervisorPolicy {
        exec_deadline_ms: 50,
        ..Default::default()
    });
    let engine = InferenceEngine::new(&rt, SIM_TIER, rt.manifest.batch.test).unwrap();
    let survived =
        fingerprint(&WorkerPool::new(4).serve(&rt, &engine, mixed_jobs(&rt)).unwrap());
    assert_eq!(survived, reference, "hang recovery changed decoded bytes");
    assert_eq!(rt.supervisor().health(1), Health::Quarantined, "hung context must strike out");
    let sv = rt.supervisor().stats();
    assert!(sv.hangs >= 2, "quarantine needs at least suspect_strikes hang strikes: {sv:?}");
    assert!(sv.quarantines >= 1, "{sv:?}");
    assert_eq!(rt.supervisor().health(0), Health::Live, "healthy context struck spuriously");
}

/// Losing every context is not recoverable — the caller gets the typed
/// `NoLiveContexts` error, with one counted death per context.
#[test]
fn all_contexts_dead_is_a_clean_typed_error() {
    let opts = SimOptions {
        die_after_execs: BTreeMap::from([(0usize, 0u64), (1usize, 0u64)]),
        ..Default::default()
    };
    let rt = Runtime::sim_with(2, opts).unwrap();
    let engine = InferenceEngine::new(&rt, SIM_TIER, rt.manifest.batch.test).unwrap();
    let weights = base_weights(&rt, 0);
    let mut prng = Pcg64::new(17);
    let problems: Vec<_> = (0..2).map(|_| SUITES[0].generate(&mut prng)).collect();
    let tok = Tokenizer::new();
    let mut rng = Pcg64::with_stream(9, 0x72657472);
    let err = engine
        .generate_problems_on(&rt, 0, &weights, &problems, &tok, 0.0, &mut rng)
        .unwrap_err();
    let no_live = err.chain().any(|c| {
        matches!(
            c.downcast_ref::<SupervisionError>(),
            Some(SupervisionError::NoLiveContexts { quarantined: 2 })
        )
    });
    assert!(no_live, "expected NoLiveContexts in the chain, got: {err:#}");
    let sv = rt.supervisor().stats();
    assert_eq!(sv.deaths, 2, "one death per context: {sv:?}");
    assert_eq!(rt.supervisor().live_count(), 0);
}
