//! Integration tests over the full runtime → engine → trainer → bench
//! stack, parameterised over the backend.
//!
//! Every scenario is written as a body taking `(&Runtime, tier)` and runs
//! twice:
//!   * `<name>_sim` — against the hermetic [`Runtime::sim`] backend,
//!     UNCONDITIONALLY: these run in every CI invocation with zero
//!     artifacts on disk (the former `require_artifacts!` skip-fleet is
//!     gone — see ISSUE 5 / DESIGN.md §10);
//!   * `<name>_pjrt` — against the real AOT artifacts + PJRT CPU runtime
//!     on the nano tier, gated on `make artifacts` having run. These are
//!     kept where backend-specific behaviour (HLO lowering, PJRT literal
//!     layout, python↔rust numerical parity) is part of what the scenario
//!     validates.
//!
//! `tests/e2e_sim.rs` holds the sim-only scenarios (multi-device
//! determinism matrices, fault injection, scheduler-through-pool).

use std::path::Path;
use std::sync::{Arc, OnceLock};

use tinylora_rl::adapters::{count, packing::Precision, Theta};
use tinylora_rl::coordinator::grpo::{grpo_session_cfg, GrpoConfig, GrpoLoop};
use tinylora_rl::coordinator::policy::{GrpoHp, Policy, TrainBatch};
use tinylora_rl::coordinator::rollout::RolloutEngine;
use tinylora_rl::coordinator::sweep::{sweep_scheme, SweepConfig};
use tinylora_rl::engine::pool::{GenJob, WorkerPool};
use tinylora_rl::engine::InferenceEngine;
use tinylora_rl::eval::bench::{run_ladder_with, BenchConfig, LADDER};
use tinylora_rl::eval::evaluate_with;
use tinylora_rl::eval::report::RecoveryReport;
use tinylora_rl::metrics::RunLog;
use tinylora_rl::serving::AdapterStore;
use tinylora_rl::tasks::corpus::{pretrain_batch, prompt_batch, sft_batch};
use tinylora_rl::tasks::generator::SUITES;
use tinylora_rl::tensor::{Arg, TensorF32, TensorI32};
use tinylora_rl::tokenizer::{Tokenizer, CHARS, EOS};
use tinylora_rl::trainer::{TenantSpec, TenantTrainer, TrainSession, TrainState};
use tinylora_rl::util::Pcg64;
use tinylora_rl::weights::WeightSet;
use tinylora_rl::Runtime;

fn art_dir() -> &'static Path {
    Path::new("artifacts")
}

fn have_artifacts() -> bool {
    art_dir().join("manifest.json").exists()
}

// Runtime is Send + Sync (Arc'd executable cache, atomic counters): one
// shared instance per backend serves every test thread, including the
// pool tests.
static PJRT_RT: OnceLock<Runtime> = OnceLock::new();
static SIM_RT: OnceLock<Runtime> = OnceLock::new();

fn pjrt_runtime() -> &'static Runtime {
    PJRT_RT.get_or_init(|| Runtime::new(art_dir()).expect("runtime"))
}

fn sim_runtime() -> &'static Runtime {
    SIM_RT.get_or_init(|| Runtime::sim(1).expect("sim runtime"))
}

/// Backend-keyed scratch dir (factor caches, train states) so the sim and
/// pjrt variants of one test never clobber each other.
fn scratch(rt: &Runtime) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("tlrl_itest_{}", rt.backend_name()))
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
    };
}

/// ISSUE 1 acceptance: the runtime must be shareable across engine pool
/// workers. Pure compile-time check — no backend needed.
#[test]
fn runtime_is_send_sync() {
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Runtime>();
    assert_send_sync::<InferenceEngine>();
    assert_send_sync::<WorkerPool>();
}

// ---------------------------------------------------------------------------
// ISSUE 1: engine subsystem
// ---------------------------------------------------------------------------

/// ISSUE 1 acceptance: ≥2 adapter batches served from concurrent threads
/// produce results identical to the single-threaded path. Two weight sets
/// stand in for two activated adapters; jobs of 3 problems on a batch-4
/// executable also exercise the sentinel padding path, and temperature 1.0
/// makes the per-job RNG streams load-bearing (not just greedy argmax).
fn worker_pool_parallel_matches_serial(rt: &Runtime, tier_name: &str) {
    let tier = rt.manifest.tier(tier_name).unwrap().clone();
    let engine = InferenceEngine::new(rt, tier_name, rt.manifest.batch.test).unwrap();
    let adapters =
        [WeightSet::init(&tier, 0).unwrap(), WeightSet::init(&tier, 3).unwrap()];

    let make_jobs = || -> Vec<GenJob> {
        (0..4u64)
            .map(|id| {
                let mut rng = Pcg64::with_stream(100 + id, 0x6a6f6273);
                GenJob {
                    id,
                    weights: adapters[(id % 2) as usize].clone(),
                    problems: (0..3).map(|_| SUITES[0].generate(&mut rng)).collect(),
                    group: 1,
                    pb: None,
                    temperature: 1.0,
                    seed: 40 + id,
                    policy_version: 0,
                }
            })
            .collect()
    };

    let serial = WorkerPool::serve_serial(rt, &engine, &make_jobs()).unwrap();
    let parallel = WorkerPool::new(2).serve(rt, &engine, make_jobs()).unwrap();
    assert_eq!(serial.len(), 4);
    assert_eq!(parallel.len(), 4);
    for (s, p) in serial.iter().zip(&parallel) {
        assert_eq!(s.id, p.id);
        assert_eq!(s.rows.len(), 3, "padding rows must be dropped");
        assert_eq!(p.rows.len(), 3);
        for (a, b) in s.rows.iter().zip(&p.rows) {
            assert_eq!(a.response, b.response, "job {} diverged across threads", s.id);
            assert_eq!(a.text, b.text);
            assert_eq!(a.behavior, b.behavior);
        }
    }
}

#[test]
fn worker_pool_parallel_matches_serial_sim() {
    worker_pool_parallel_matches_serial(sim_runtime(), "sim");
}

#[test]
fn worker_pool_parallel_matches_serial_pjrt() {
    require_artifacts!();
    worker_pool_parallel_matches_serial(pjrt_runtime(), "nano");
}

/// The manifest (parsed from artifacts, or built in-memory by the sim
/// backend) must agree with the rust-side mirrors: tokenizer charset,
/// Table 1 theta-size formulas, tying-plan group assignments.
fn manifest_matches_rust_mirrors(rt: &Runtime) {
    let m = &rt.manifest;
    // tokenizer charset must be identical on both sides
    assert_eq!(m.vocab.chars, CHARS);
    assert_eq!(m.vocab.size, tinylora_rl::tokenizer::VOCAB_SIZE);
    // Table 1 formulas must reproduce every entry point's theta_size
    for exe in m.executables.values() {
        let Some(scheme) = &exe.scheme else { continue };
        let Some(ts) = exe.theta_size else { continue };
        let tier = m.tier(&exe.tier).unwrap();
        let want = match scheme.kind.as_str() {
            "tinylora" => count::tinylora(tier, scheme.u, &scheme.tie, scheme.n_tie).unwrap(),
            "lora_xs" => count::lora_xs(tier, scheme.r),
            "lora" => count::lora(tier, scheme.r),
            "full" => continue,
            other => panic!("unknown scheme kind {other}"),
        };
        assert_eq!(ts, want, "theta size mismatch for {}", exe.name);
        if scheme.kind == "tinylora" {
            let groups = count::group_assignment(tier, &scheme.tie, scheme.n_tie).unwrap();
            assert_eq!(exe.groups, groups, "group assignment mismatch for {}", exe.name);
        }
    }
}

#[test]
fn manifest_matches_rust_mirrors_sim() {
    manifest_matches_rust_mirrors(sim_runtime());
}

#[test]
fn manifest_matches_rust_mirrors_pjrt() {
    require_artifacts!();
    manifest_matches_rust_mirrors(pjrt_runtime());
}

fn generate_runs_and_greedy_is_deterministic(rt: &Runtime, tier_name: &str) {
    let tier = rt.manifest.tier(tier_name).unwrap().clone();
    let weights = WeightSet::init(&tier, 0).unwrap();
    let engine = RolloutEngine::new(rt, tier_name, rt.manifest.batch.test).unwrap();
    let tok = Tokenizer::new();
    let mut rng = Pcg64::new(1);
    let problems: Vec<_> = (0..4).map(|_| SUITES[0].generate(&mut rng)).collect();
    let pb = prompt_batch(&problems, &tok, 1, engine.t_prefill);

    let r1 = engine.rollout(rt, &weights, &pb, &tok, 0.0, &mut Pcg64::new(7)).unwrap();
    let r2 = engine.rollout(rt, &weights, &pb, &tok, 0.0, &mut Pcg64::new(8)).unwrap();
    // greedy decode ignores the uniforms: identical outputs
    for (a, b) in r1.rows.iter().zip(&r2.rows) {
        assert_eq!(a.response, b.response);
    }
    // sampled decode differs from greedy with overwhelming probability
    let r3 = engine.rollout(rt, &weights, &pb, &tok, 1.0, &mut Pcg64::new(9)).unwrap();
    assert!(r3.rows.iter().zip(&r1.rows).any(|(a, b)| a.response != b.response));
    // behavior logps are <= 0 and finite at temp 1
    for row in &r3.rows {
        assert!(row.behavior.iter().all(|&l| l <= 1e-4 && l.is_finite()));
    }
}

#[test]
fn generate_runs_and_greedy_is_deterministic_sim() {
    generate_runs_and_greedy_is_deterministic(sim_runtime(), "sim");
}

#[test]
fn generate_runs_and_greedy_is_deterministic_pjrt() {
    require_artifacts!();
    generate_runs_and_greedy_is_deterministic(pjrt_runtime(), "nano");
}

// ---------------------------------------------------------------------------
// Adapter algebra: merge identity, gradient flow, logprob equivalence
// ---------------------------------------------------------------------------

fn theta_zero_merge_is_identity_and_adapter_grad_flows(rt: &Runtime, tier_name: &str) {
    let tier = rt.manifest.tier(tier_name).unwrap().clone();
    let base = WeightSet::init(&tier, 3).unwrap();
    let ckpt = scratch(rt);
    let policy =
        Policy::new(rt, tier_name, "tinylora_r2_u13_all", "grpo", base.clone(), 0, &ckpt).unwrap();
    assert_eq!(policy.trainable_params(), 13);
    // theta starts at zero -> merged == base exactly
    for name in tinylora_rl::coordinator::policy::ADAPTED {
        let b = base.get(name).unwrap();
        let m = policy.merged.get(name).unwrap();
        for (x, y) in b.data.iter().zip(&m.data) {
            assert!((x - y).abs() < 1e-5, "{name} changed at theta=0");
        }
    }
    // gradient flows into all 13 params
    let batch = synthetic_grpo_batch(&tier, rt.manifest.batch.test);
    let (grad, stats) = policy.grad(rt, &batch, GrpoHp { clip_c: 4.0, kl_coef: 0.001 }).unwrap();
    assert_eq!(grad.len(), 13);
    assert!(grad.iter().all(|g| g.is_finite()));
    assert!(grad.iter().any(|&g| g != 0.0));
    assert!(stats.loss.is_finite());
    // at theta=0 the adapter equals the base model; rollout logps came from
    // elsewhere here, so just sanity-check ratio stat is finite
    assert!(stats.mean_ratio.is_finite());
}

#[test]
fn theta_zero_merge_is_identity_and_adapter_grad_flows_sim() {
    theta_zero_merge_is_identity_and_adapter_grad_flows(sim_runtime(), "sim");
}

#[test]
fn theta_zero_merge_is_identity_and_adapter_grad_flows_pjrt() {
    require_artifacts!();
    theta_zero_merge_is_identity_and_adapter_grad_flows(pjrt_runtime(), "nano");
}

fn synthetic_grpo_batch(tier: &tinylora_rl::manifest::TierInfo, b: usize) -> TrainBatch {
    let t = tier.t_train;
    let mut rng = Pcg64::new(5);
    let mut tokens = vec![0i32; b * t];
    let mut mask = vec![0.0f32; b * (t - 1)];
    let mut behavior = vec![0.0f32; b * (t - 1)];
    for i in 0..b {
        tokens[i * t] = 1; // BOS
        for j in 1..40 {
            tokens[i * t + j] = rng.range_i64(3, 55) as i32;
        }
        for j in 20..39 {
            mask[i * (t - 1) + j] = 1.0;
            behavior[i * (t - 1) + j] = -2.0;
        }
    }
    let adv: Vec<f32> = (0..b).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
    TrainBatch {
        tokens: TensorI32::from_vec(&[b, t], tokens),
        mask: TensorF32::from_vec(&[b, t - 1], mask),
        behavior: TensorF32::from_vec(&[b, t - 1], behavior),
        advantages: TensorF32::from_vec(&[b], adv),
    }
}

/// The paper's Fig-5 claim: training under the adapter parameterisation
/// and sampling from merged weights are numerically equivalent. Push a
/// random theta into the policy; logprobs(merged tokens) must match
/// logprobs recomputed after folding theta a second time (idempotence)
/// and differ from the base model.
fn merged_weights_match_live_adapter_logprobs(rt: &Runtime, tier_name: &str) {
    let tier = rt.manifest.tier(tier_name).unwrap().clone();
    let base = WeightSet::init(&tier, 3).unwrap();
    let ckpt = scratch(rt);
    let mut policy =
        Policy::new(rt, tier_name, "tinylora_r2_u13_all", "grpo", base.clone(), 0, &ckpt).unwrap();
    let mut rng = Pcg64::new(9);
    let theta: Vec<f32> = (0..13).map(|_| rng.normal() * 0.2).collect();
    policy.set_params(rt, &theta).unwrap();

    let b = rt.manifest.batch.test;
    let exe = rt
        .load(
            &rt.manifest
                .find("logprobs", |e| {
                    e.fn_kind == "logprobs" && e.tier == tier_name && e.batch == b
                })
                .unwrap()
                .name,
        )
        .unwrap();
    let t = tier.t_train;
    let mut tokens = vec![0i32; b * t];
    for i in 0..b {
        tokens[i * t] = 1;
        for j in 1..30 {
            tokens[i * t + j] = rng.range_i64(3, 55) as i32;
        }
    }
    let toks = TensorI32::from_vec(&[b, t], tokens);

    let run_logp = |w: &WeightSet| -> Vec<f32> {
        let mut args: Vec<Arg> = w.args();
        args.push(Arg::I32(toks.clone()));
        rt.run(&exe, &args).unwrap().f32(0).unwrap().data
    };
    let lp_merged = run_logp(&policy.merged);
    let lp_base = run_logp(&base);
    // non-trivial theta must move the distribution
    let diff: f32 =
        lp_merged.iter().zip(&lp_base).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max);
    assert!(diff > 1e-3, "theta had no effect ({diff})");
    // remerging is idempotent
    policy.remerge(rt).unwrap();
    let lp_again = run_logp(&policy.merged);
    for (a, b) in lp_merged.iter().zip(&lp_again) {
        assert!((a - b).abs() < 1e-5);
    }
}

#[test]
fn merged_weights_match_live_adapter_logprobs_sim() {
    merged_weights_match_live_adapter_logprobs(sim_runtime(), "sim");
}

#[test]
fn merged_weights_match_live_adapter_logprobs_pjrt() {
    require_artifacts!();
    merged_weights_match_live_adapter_logprobs(pjrt_runtime(), "nano");
}

/// The pretrain entry point's gradients actually descend: 30 Adam steps
/// on one fixed batch must cut the loss by ≥30%. On the sim backend this
/// validates the hand-derived backprop end-to-end.
fn pretrain_step_reduces_loss(rt: &Runtime, tier_name: &str) {
    let tier = rt.manifest.tier(tier_name).unwrap().clone();
    let b = rt.manifest.batch.test;
    let exe = rt
        .load(
            &rt.manifest
                .find("pretrain", |e| {
                    e.fn_kind == "pretrain" && e.tier == tier_name && e.batch == b
                })
                .unwrap()
                .name,
        )
        .unwrap();
    let mut weights = WeightSet::init(&tier, 0).unwrap();
    let tok = Tokenizer::new();
    let mut rng = Pcg64::new(2);
    let mut opt = tinylora_rl::coordinator::optimizer::Adam::new(
        weights.n_params(),
        tinylora_rl::coordinator::optimizer::AdamConfig { lr: 3e-3, ..Default::default() },
    );
    // fixed batch: loss on it must drop markedly over 30 steps
    let (tokens, mask) = pretrain_batch(&SUITES[0], &tok, &mut rng, b, tier.t_train);
    let mut first = 0.0;
    let mut last = 0.0;
    for step in 0..30 {
        let mut args: Vec<Arg> = weights.args();
        args.push(Arg::I32(tokens.clone()));
        args.push(Arg::F32(mask.clone()));
        let out = rt.run(&exe, &args).unwrap();
        let loss = out.f32(out.len() - 1).unwrap().data[0];
        if step == 0 {
            first = loss;
        }
        last = loss;
        let mut grad = Vec::with_capacity(weights.n_params());
        for i in 0..out.len() - 1 {
            grad.extend_from_slice(&out.f32(i).unwrap().data);
        }
        let mut flat = weights.flat();
        opt.step(&mut flat, &grad);
        weights.set_flat(&flat).unwrap();
    }
    assert!(last < first * 0.7, "loss {first} -> {last} did not drop");
}

#[test]
fn pretrain_step_reduces_loss_sim() {
    pretrain_step_reduces_loss(sim_runtime(), "sim");
}

#[test]
fn pretrain_step_reduces_loss_pjrt() {
    require_artifacts!();
    pretrain_step_reduces_loss(pjrt_runtime(), "nano");
}

fn sft_grad_runs_for_adapter_scheme(rt: &Runtime, tier_name: &str) {
    let tier = rt.manifest.tier(tier_name).unwrap().clone();
    let base = WeightSet::init(&tier, 3).unwrap();
    let ckpt = scratch(rt);
    let policy =
        Policy::new(rt, tier_name, "tinylora_r2_u13_all", "sft", base, 0, &ckpt).unwrap();
    let tok = Tokenizer::new();
    let mut rng = Pcg64::new(4);
    let b = rt.manifest.batch.test;
    let (tokens, mask) = sft_batch(&SUITES[0], &tok, &mut rng, b, tier.t_train);
    let batch = TrainBatch {
        tokens,
        mask,
        behavior: TensorF32::zeros(&[b, tier.t_train - 1]),
        advantages: TensorF32::zeros(&[b]),
    };
    let (grad, stats) = policy.grad(rt, &batch, GrpoHp::default()).unwrap();
    assert_eq!(grad.len(), 13);
    assert!(stats.loss > 0.0 && stats.loss.is_finite());
    assert!((0.0..=1.0).contains(&stats.aux1), "token acc {}", stats.aux1);
}

#[test]
fn sft_grad_runs_for_adapter_scheme_sim() {
    sft_grad_runs_for_adapter_scheme(sim_runtime(), "sim");
}

#[test]
fn sft_grad_runs_for_adapter_scheme_pjrt() {
    require_artifacts!();
    sft_grad_runs_for_adapter_scheme(pjrt_runtime(), "nano");
}

/// Tiny end-to-end smoke: untrained weights, full GRPO path at the test
/// batch size, then the TIS diagnostic — at theta ~ 0 the train/inference
/// KL should be tiny (the merged-rollout trick is numerically sound,
/// Fig. 5 bottom panel).
fn end_to_end_grpo_steps_run(rt: &Runtime, tier_name: &str) {
    let tier = rt.manifest.tier(tier_name).unwrap().clone();
    let base = WeightSet::init(&tier, 0).unwrap();
    let ckpt = scratch(rt);
    let mut policy =
        Policy::new(rt, tier_name, "tinylora_r2_u13_all", "grpo", base, 0, &ckpt).unwrap();
    let engine = RolloutEngine::new(rt, tier_name, rt.manifest.batch.test).unwrap();
    let tok = Tokenizer::new();
    let mut rng = Pcg64::new(11);
    let mut opt = tinylora_rl::coordinator::optimizer::Adam::new(
        13,
        tinylora_rl::coordinator::optimizer::AdamConfig::default(),
    );
    for _ in 0..2 {
        let problems: Vec<_> = (0..2).map(|_| SUITES[0].generate(&mut rng)).collect();
        let pb = prompt_batch(&problems, &tok, 2, engine.t_prefill);
        let roll = engine.rollout(rt, &policy.merged, &pb, &tok, 1.0, &mut rng).unwrap();
        let batch = engine.train_batch(&pb, &roll, tier.t_train);
        let (grad, stats) = policy.grad(rt, &batch, GrpoHp { clip_c: 4.0, kl_coef: 0.0 }).unwrap();
        assert!(stats.loss.is_finite());
        let mut params = policy.params();
        opt.step(&mut params, &grad);
        policy.set_params(rt, &params).unwrap();
    }
    let problems: Vec<_> = (0..2).map(|_| SUITES[0].generate(&mut rng)).collect();
    let pb = prompt_batch(&problems, &tok, 2, engine.t_prefill);
    let roll = engine.rollout(rt, &policy.merged, &pb, &tok, 1.0, &mut rng).unwrap();
    let batch = engine.train_batch(&pb, &roll, tier.t_train);
    let (_, stats) = policy.grad(rt, &batch, GrpoHp { clip_c: 4.0, kl_coef: 0.0 }).unwrap();
    assert!(
        stats.kl_k1.abs() < 0.05,
        "train/inference KL too large: {} (merged-weights equivalence broken?)",
        stats.kl_k1
    );
    assert!((stats.mean_ratio - 1.0).abs() < 0.2, "mean ratio {}", stats.mean_ratio);
}

#[test]
fn end_to_end_grpo_steps_run_sim() {
    end_to_end_grpo_steps_run(sim_runtime(), "sim");
}

#[test]
fn end_to_end_grpo_steps_run_pjrt() {
    require_artifacts!();
    end_to_end_grpo_steps_run(pjrt_runtime(), "nano");
}

fn packed_theta_roundtrip_preserves_precision_semantics(rt: &Runtime, tier_name: &str) {
    let info = rt.manifest.grad_exe(tier_name, "grpo", "tinylora_r2_u13_all").unwrap();
    let theta = Theta::init(info, 0).unwrap();
    assert_eq!(theta.len(), 13);
    assert_eq!(theta.update_bytes(Precision::Bf16), 26); // the paper's headline
    assert_eq!(theta.update_bytes(Precision::F32), 52);
}

#[test]
fn packed_theta_roundtrip_preserves_precision_semantics_sim() {
    packed_theta_roundtrip_preserves_precision_semantics(sim_runtime(), "sim");
}

#[test]
fn packed_theta_roundtrip_preserves_precision_semantics_pjrt() {
    require_artifacts!();
    packed_theta_roundtrip_preserves_precision_semantics(pjrt_runtime(), "nano");
}

// ---------------------------------------------------------------------------
// ISSUE 2: trainer subsystem — checkpoint/resume, multi-tenant training and
// sweep determinism.
// ---------------------------------------------------------------------------

fn test_grpo_cfg(steps: usize, lr: f32, seed: u64) -> GrpoConfig {
    GrpoConfig { group: 2, steps, lr, warmup: 2, seed, ..Default::default() }
}

/// f32 fields of a step record as bit patterns (wall-time fields excluded —
/// everything else must be bit-identical across resume/parallelism).
fn rec_bits(r: &tinylora_rl::coordinator::StepRecord) -> Vec<u32> {
    vec![
        r.step as u32,
        r.reward.to_bits(),
        r.response_len.to_bits(),
        r.format_rate.to_bits(),
        r.eos_rate.to_bits(),
        r.lr.to_bits(),
        r.stats.loss.to_bits(),
        r.stats.kl_k1.to_bits(),
        r.stats.mean_ratio.to_bits(),
        r.stats.entropy.to_bits(),
        r.stats.grad_norm.to_bits(),
    ]
}

/// ISSUE 2 acceptance: a killed-and-resumed GRPO run is bit-identical to an
/// uninterrupted one, step-for-step and in the final adapter.
fn resumed_grpo_run_matches_uninterrupted(rt: &Runtime, tier_name: &str) {
    let b = rt.manifest.batch.test;
    let base = WeightSet::init(&rt.manifest.tier(tier_name).unwrap().clone(), 3).unwrap();
    let ckpt = scratch(rt);
    let mk_session = |steps: usize| -> TrainSession<GrpoLoop> {
        let policy =
            Policy::new(rt, tier_name, "tinylora_r2_u13_all", "grpo", base.clone(), 9, &ckpt)
                .unwrap();
        let cfg = test_grpo_cfg(steps, 5e-3, 9);
        let mut scfg = grpo_session_cfg(&cfg);
        scfg.steps = steps;
        TrainSession::new(GrpoLoop::with_batch(rt, policy, cfg, b).unwrap(), scfg)
    };

    // uninterrupted: 4 steps straight through
    let mut full = mk_session(4);
    let full_recs = full.run(rt, &mut RunLog::null()).unwrap();
    let full_theta = full.lp.policy.theta.clone();

    // interrupted: 2 steps, save, "kill", reload, 2 more steps
    let mut first_half = mk_session(2);
    let half_recs = first_half.run(rt, &mut RunLog::null()).unwrap();
    let state_path = scratch(rt).join("resume.trainstate");
    first_half.state().save(&state_path).unwrap();
    drop(first_half);

    let st = TrainState::load(&state_path).unwrap();
    assert_eq!(st.step, 2);
    assert_eq!(st.scheme_tag, "tinylora_r2_u13_all");
    let policy =
        Policy::new(rt, tier_name, "tinylora_r2_u13_all", "grpo", base.clone(), 9, &ckpt).unwrap();
    let cfg = test_grpo_cfg(4, 5e-3, 9);
    let scfg = grpo_session_cfg(&cfg);
    let lp = GrpoLoop::with_batch(rt, policy, cfg, b).unwrap();
    let mut resumed = TrainSession::resume(rt, lp, scfg, &st).unwrap();
    assert_eq!(resumed.completed_steps(), 2);
    let resumed_recs = resumed.run(rt, &mut RunLog::null()).unwrap();
    assert_eq!(resumed_recs.len(), 2);

    for (a, x) in full_recs[..2].iter().zip(&half_recs) {
        assert_eq!(rec_bits(a), rec_bits(x), "pre-kill step {} diverged", a.step);
    }
    for (a, x) in full_recs[2..].iter().zip(&resumed_recs) {
        assert_eq!(rec_bits(a), rec_bits(x), "post-resume step {} diverged", a.step);
    }
    assert_eq!(
        full_theta.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        resumed.lp.policy.theta.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        "final adapter diverged after resume"
    );
    std::fs::remove_file(&state_path).ok();
}

#[test]
fn resumed_grpo_run_matches_uninterrupted_sim() {
    resumed_grpo_run_matches_uninterrupted(sim_runtime(), "sim");
}

#[test]
fn resumed_grpo_run_matches_uninterrupted_pjrt() {
    require_artifacts!();
    resumed_grpo_run_matches_uninterrupted(pjrt_runtime(), "nano");
}

/// ISSUE 2 acceptance: `TenantTrainer` with G=4 produces per-tenant results
/// identical to 4 serial runs (and its pooled waves identical to its serial
/// reference path), and registers all 4 adapters into the `AdapterStore`.
fn tenant_trainer_matches_serial_runs_and_registers(rt: &Runtime, tier_name: &str) {
    let b = rt.manifest.batch.test;
    let base = WeightSet::init(&rt.manifest.tier(tier_name).unwrap().clone(), 3).unwrap();
    let ckpt = scratch(rt);
    let specs: Vec<TenantSpec> = (0..4u64)
        .map(|i| TenantSpec {
            name: format!("tenant-{i}"),
            scheme_tag: "tinylora_r2_u13_all".into(),
            cfg: test_grpo_cfg(3, 2e-3 + i as f32 * 1e-3, 20 + i),
            precision: Precision::Bf16,
        })
        .collect();

    // pooled (2 workers) vs the trainer's serial reference path
    let mut tt_par = TenantTrainer::with_batch(rt, &base, specs.clone(), 2, &ckpt, b).unwrap();
    let out_par = tt_par.train(rt, &mut RunLog::null(), true).unwrap();
    let mut tt_ser = TenantTrainer::with_batch(rt, &base, specs.clone(), 1, &ckpt, b).unwrap();
    let out_ser = tt_ser.train(rt, &mut RunLog::null(), false).unwrap();
    assert_eq!(out_par.len(), 4);
    assert_eq!(out_ser.len(), 4);
    for ((p, s), (sp, ss)) in out_par
        .iter()
        .zip(&out_ser)
        .zip(tt_par.sessions.iter().zip(&tt_ser.sessions))
    {
        assert_eq!(p.name, s.name);
        for (a, c) in p.steps.iter().zip(&s.steps) {
            assert_eq!(rec_bits(a), rec_bits(c), "{}: pooled != serial", p.name);
        }
        assert_eq!(
            sp.lp.policy.theta.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            ss.lp.policy.theta.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "{}: theta diverged across pooling",
            p.name
        );
    }

    // ... and identical to 4 completely independent serial runs
    for (i, spec) in specs.iter().enumerate() {
        let mut policy = Policy::new(
            rt,
            tier_name,
            &spec.scheme_tag,
            "grpo",
            base.clone(),
            spec.cfg.seed,
            &ckpt,
        )
        .unwrap();
        // match the tenant plane's storage precision (updates roundtrip
        // through bf16 there)
        policy.precision = spec.precision;
        let mut sess = TrainSession::new(
            GrpoLoop::with_batch(rt, policy, spec.cfg.clone(), b).unwrap(),
            grpo_session_cfg(&spec.cfg),
        );
        let recs = sess.run(rt, &mut RunLog::null()).unwrap();
        for (a, c) in recs.iter().zip(&out_ser[i].steps) {
            assert_eq!(rec_bits(a), rec_bits(c), "tenant {i}: independent run != tenant run");
        }
        assert_eq!(
            sess.lp.policy.theta.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            tt_ser.sessions[i].lp.policy.theta.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "tenant {i}: theta != independent run"
        );
    }

    // train→serve registration closes the loop: 4 adapters, 26 bytes each
    let mut store = AdapterStore::new(tier_name, 2);
    tt_ser.register_into(&mut store).unwrap();
    assert_eq!(store.len(), 4);
    assert_eq!(store.names(), vec!["tenant-0", "tenant-1", "tenant-2", "tenant-3"]);
    assert_eq!(store.stored_bytes(), 4 * 26, "13 bf16 params = 26 bytes per tenant");
}

#[test]
fn tenant_trainer_matches_serial_runs_and_registers_sim() {
    tenant_trainer_matches_serial_runs_and_registers(sim_runtime(), "sim");
}

#[test]
fn tenant_trainer_matches_serial_runs_and_registers_pjrt() {
    require_artifacts!();
    tenant_trainer_matches_serial_runs_and_registers(pjrt_runtime(), "nano");
}

/// ISSUE 2 acceptance: two sweeps with the same config produce byte-identical
/// outcome JSON — including when the rollout waves run on pool threads.
fn sweep_is_deterministic_across_runs_and_workers(rt: &Runtime, tier_name: &str) {
    let base = WeightSet::init(&rt.manifest.tier(tier_name).unwrap().clone(), 3).unwrap();
    let ckpt = scratch(rt);
    let cfg = |workers: usize| SweepConfig {
        tier: tier_name.into(),
        scheme_tag: "tinylora_r2_u13_all".into(),
        algo: "grpo".into(),
        suite: "gsm8k-syn".into(),
        steps: 2,
        lrs: vec![1e-3, 5e-3],
        seeds: vec![0],
        eval_suite: "gsm8k-syn".into(),
        eval_n: 8,
        workers,
        batch: rt.manifest.batch.test,
    };
    let a = sweep_scheme(rt, &base, &cfg(1), &ckpt, &mut RunLog::null()).unwrap();
    let b = sweep_scheme(rt, &base, &cfg(1), &ckpt, &mut RunLog::null()).unwrap();
    let c = sweep_scheme(rt, &base, &cfg(2), &ckpt, &mut RunLog::null()).unwrap();
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    assert_eq!(a.to_json().to_string(), c.to_json().to_string(), "worker count changed results");
    assert_eq!(a.per_lr.len(), 2);
}

#[test]
fn sweep_is_deterministic_across_runs_and_workers_sim() {
    sweep_is_deterministic_across_runs_and_workers(sim_runtime(), "sim");
}

#[test]
fn sweep_is_deterministic_across_runs_and_workers_pjrt() {
    require_artifacts!();
    sweep_is_deterministic_across_runs_and_workers(pjrt_runtime(), "nano");
}

// ---------------------------------------------------------------------------
// ISSUE 3: benchmark subsystem — pooled pass@k/maj@k ladder runs and the
// recovery-fraction report.
// ---------------------------------------------------------------------------

fn bench_cfg(k: usize, n: usize, workers: usize, batch: usize) -> BenchConfig {
    BenchConfig {
        tier: String::new(), // run_ladder_with takes the engine's tier
        suites: Vec::new(),  // the full 4-suite ladder
        k,
        n,
        temperature: 1.0,
        seed: 3,
        workers,
        batch,
    }
}

/// ISSUE 3 acceptance: the full 4-suite ladder at k=4 pooled across
/// workers is byte-identical (canonical JSON) to the serial reference,
/// and bench runs survive a save/load roundtrip.
fn bench_ladder_pooled_matches_serial_and_roundtrips(rt: &Runtime, tier_name: &str) {
    let b = rt.manifest.batch.test;
    let base = WeightSet::init(&rt.manifest.tier(tier_name).unwrap().clone(), 3).unwrap();
    let engine = InferenceEngine::new(rt, tier_name, b).unwrap();

    let serial = run_ladder_with(rt, &engine, &base, "base", 0, &bench_cfg(4, 4, 1, b)).unwrap();
    let pooled = run_ladder_with(rt, &engine, &base, "base", 0, &bench_cfg(4, 4, 3, b)).unwrap();
    assert_eq!(
        serial.to_json().to_string(),
        pooled.to_json().to_string(),
        "pooled ladder != serial ladder"
    );
    assert_eq!(serial.scores.len(), LADDER.len());
    for sc in &serial.scores {
        assert_eq!(sc.k, 4);
        assert_eq!(sc.n, 4, "padding rows must not be scored");
        for v in [sc.pass1, sc.pass_k, sc.maj_k, sc.format_rate] {
            assert!((0.0..=1.0).contains(&v), "{}: {v} out of range", sc.suite);
        }
        assert!(sc.pass1 <= sc.pass_k + 1e-6, "{}: pass@1 > pass@k", sc.suite);
    }

    let path = scratch(rt).join("bench.json");
    serial.save(&path).unwrap();
    let back = tinylora_rl::eval::bench::BenchRun::load(&path).unwrap();
    assert_eq!(back.to_json().to_string(), serial.to_json().to_string());
    std::fs::remove_file(&path).ok();

    // k that does not divide the baked batch is an error, not a mis-scored run
    let err = run_ladder_with(rt, &engine, &base, "base", 0, &bench_cfg(3, 4, 1, b));
    assert!(err.is_err(), "k=3 must not divide batch {b}");
}

#[test]
fn bench_ladder_pooled_matches_serial_and_roundtrips_sim() {
    bench_ladder_pooled_matches_serial_and_roundtrips(sim_runtime(), "sim");
}

#[test]
fn bench_ladder_pooled_matches_serial_and_roundtrips_pjrt() {
    require_artifacts!();
    bench_ladder_pooled_matches_serial_and_roundtrips(pjrt_runtime(), "nano");
}

/// k=1 greedy benching reduces to the original eval protocol exactly —
/// the bench subsystem strictly generalises `evaluate`.
fn bench_k1_greedy_matches_eval_accuracy(rt: &Runtime, tier_name: &str) {
    let b = rt.manifest.batch.test;
    let base = WeightSet::init(&rt.manifest.tier(tier_name).unwrap().clone(), 3).unwrap();
    let engine = InferenceEngine::new(rt, tier_name, b).unwrap();
    let mut cfg = bench_cfg(1, 8, 1, b);
    cfg.suites = vec!["gsm8k-syn".into()];
    cfg.temperature = 0.0;
    let run = run_ladder_with(rt, &engine, &base, "base", 0, &cfg).unwrap();
    let ev = evaluate_with(rt, &engine, &base, "gsm8k-syn", 8, 3).unwrap();
    assert!((run.scores[0].pass1 - ev.accuracy).abs() < 1e-6, "bench k=1 != greedy eval");
    assert!((run.scores[0].pass_k - ev.accuracy).abs() < 1e-6);
    assert!((run.scores[0].format_rate - ev.format_rate).abs() < 1e-6);
}

#[test]
fn bench_k1_greedy_matches_eval_accuracy_sim() {
    bench_k1_greedy_matches_eval_accuracy(sim_runtime(), "sim");
}

#[test]
fn bench_k1_greedy_matches_eval_accuracy_pjrt() {
    require_artifacts!();
    bench_k1_greedy_matches_eval_accuracy(pjrt_runtime(), "nano");
}

/// Recovery-fraction plumbing over real bench runs: two weight sets stand
/// in for base and full-FT; the reference recovers 100% of itself on
/// every suite, and the report JSON is deterministic.
fn recovery_report_over_real_bench_runs(rt: &Runtime, tier_name: &str) {
    let b = rt.manifest.batch.test;
    let tier = rt.manifest.tier(tier_name).unwrap().clone();
    let engine = InferenceEngine::new(rt, tier_name, b).unwrap();
    let baseline = run_ladder_with(
        rt,
        &engine,
        &WeightSet::init(&tier, 3).unwrap(),
        "base",
        0,
        &bench_cfg(2, 4, 2, b),
    )
    .unwrap();
    let full_ft = WeightSet::init(&tier, 5).unwrap();
    let reference =
        run_ladder_with(rt, &engine, &full_ft, "full", 1000, &bench_cfg(2, 4, 2, b)).unwrap();
    let report = RecoveryReport::new(baseline, reference, Vec::new()).unwrap();
    for si in 0..report.reference.scores.len() {
        assert!(
            (report.recovery(&report.reference, si) - 1.0).abs() < 1e-6,
            "reference must recover itself on suite {si}"
        );
    }
    assert_eq!(report.to_json().to_string(), report.to_json().to_string());
    let md = report.to_markdown();
    assert!(md.contains("| full | 1000 |"), "{md}");
    assert!(md.contains("100%"), "{md}");
}

#[test]
fn recovery_report_over_real_bench_runs_sim() {
    recovery_report_over_real_bench_runs(sim_runtime(), "sim");
}

#[test]
fn recovery_report_over_real_bench_runs_pjrt() {
    require_artifacts!();
    recovery_report_over_real_bench_runs(pjrt_runtime(), "nano");
}

// ---------------------------------------------------------------------------
// ISSUE 4: device-parallel runtime — single-flight compiles, context
// routing, occupancy-aware batch geometry.
// ---------------------------------------------------------------------------

/// ISSUE 4 satellite: concurrent loads of one executable compile it
/// exactly once (single-flight coalescing) and hand every caller the
/// same `Arc` — the seed's check-then-insert double-compile race is gone.
fn concurrent_load_compiles_once(rt: &Runtime, tier_name: &str) {
    let name = rt.manifest.generate_exe(tier_name, rt.manifest.batch.test).unwrap().name.clone();
    let loaded: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..6).map(|_| s.spawn(|| rt.load(&name).unwrap())).collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(rt.stats().compiles, 1, "concurrent loads must coalesce to one compile");
    for e in &loaded {
        assert!(Arc::ptr_eq(e, &loaded[0]), "all callers must share one executable");
    }
}

#[test]
fn concurrent_load_compiles_once_sim() {
    // fresh runtime: the shared SIM_RT may already have this exe cached
    let rt = Runtime::sim(1).unwrap();
    concurrent_load_compiles_once(&rt, "sim");
}

#[test]
fn concurrent_load_compiles_once_pjrt() {
    require_artifacts!();
    let rt = Runtime::new(art_dir()).unwrap();
    concurrent_load_compiles_once(&rt, "nano");
}

/// ISSUE 4 tentpole: a D=2 context pool serves pooled jobs byte-identical
/// to the D=1 serial reference (job→context pinning is a pure function of
/// the job id), and aggregates per-context counters.
fn multi_context_pool_matches_single_context_serial(rt1: &Runtime, rt2: &Runtime, tier_name: &str) {
    assert_eq!(rt1.devices(), 1);
    assert_eq!(rt2.devices(), 2);
    let tier = rt2.manifest.tier(tier_name).unwrap().clone();
    let b = rt2.manifest.batch.test;
    let weights = WeightSet::init(&tier, 0).unwrap();
    let make_jobs = || -> Vec<GenJob> {
        (0..4u64)
            .map(|id| {
                let mut rng = Pcg64::with_stream(300 + id, 0x6a6f6273);
                GenJob {
                    id,
                    weights: weights.clone(),
                    problems: (0..3).map(|_| SUITES[0].generate(&mut rng)).collect(),
                    group: 1,
                    pb: None,
                    temperature: 1.0,
                    seed: 90 + id,
                    policy_version: 0,
                }
            })
            .collect()
    };
    let e1 = InferenceEngine::new(rt1, tier_name, b).unwrap();
    let e2 = InferenceEngine::new(rt2, tier_name, b).unwrap();
    let reference = WorkerPool::serve_serial(rt1, &e1, &make_jobs()).unwrap();
    let pooled = WorkerPool::new(3).serve(rt2, &e2, make_jobs()).unwrap();
    assert_eq!(reference.len(), pooled.len());
    for (a, p) in reference.iter().zip(&pooled) {
        assert_eq!(a.id, p.id);
        for (x, y) in a.rows.iter().zip(&p.rows) {
            assert_eq!(x.response, y.response, "job {} diverged across contexts", a.id);
            assert_eq!(x.behavior, y.behavior);
        }
    }
    // both contexts did real work and the aggregate matches the parts
    let per = rt2.per_context_stats();
    assert_eq!(per.len(), 2);
    assert!(per.iter().all(|s| s.runs > 0), "jobs must spread across both contexts");
    assert_eq!(per.iter().map(|s| s.runs).sum::<u64>(), rt2.stats().runs);
}

#[test]
fn multi_context_pool_matches_single_context_serial_sim() {
    let rt1 = Runtime::sim(1).unwrap();
    let rt2 = Runtime::sim(2).unwrap();
    multi_context_pool_matches_single_context_serial(&rt1, &rt2, "sim");
}

#[test]
fn multi_context_pool_matches_single_context_serial_pjrt() {
    require_artifacts!();
    let rt1 = Runtime::new(art_dir()).unwrap();
    let rt2 = Runtime::with_devices(art_dir(), 2).unwrap();
    multi_context_pool_matches_single_context_serial(&rt1, &rt2, "nano");
}

/// ISSUE 4 tentpole: occupancy-aware geometry never pads more than the
/// fixed-geometry baseline would, and returns exactly one row per real
/// problem regardless of the geometry chosen for the tail flush.
fn occupancy_aware_flush_padding_never_worse(rt: &Runtime, tier_name: &str) {
    let b = rt.manifest.batch.test;
    let tier = rt.manifest.tier(tier_name).unwrap().clone();
    let weights = WeightSet::init(&tier, 0).unwrap();
    let engine = InferenceEngine::new(rt, tier_name, b).unwrap();
    assert!(engine.geometries().contains(&b), "canonical geometry must be held");
    let tok = Tokenizer::new();
    let mut gen_rng = Pcg64::new(31);
    for n in [1usize, b - 1, b, b + 1, 2 * b - 1] {
        let mut rng = Pcg64::new(17);
        let problems: Vec<_> = (0..n).map(|_| SUITES[0].generate(&mut rng)).collect();
        let before = engine.stats();
        let rows =
            engine.generate_problems(rt, &weights, &problems, &tok, 0.0, &mut gen_rng).unwrap();
        let after = engine.stats();
        assert_eq!(rows.len(), n, "one row per real problem at n={n}");
        assert_eq!(after.rows - before.rows, n as u64);
        // fixed baseline pads the tail all the way to the canonical batch
        let fixed = (n.div_ceil(b) * b - n) as u64;
        assert!(
            after.padded_rows - before.padded_rows <= fixed,
            "n={n}: occupancy-aware padding exceeded the fixed baseline"
        );
    }
}

#[test]
fn occupancy_aware_flush_padding_never_worse_sim() {
    occupancy_aware_flush_padding_never_worse(sim_runtime(), "sim");
}

#[test]
fn occupancy_aware_flush_padding_never_worse_pjrt() {
    require_artifacts!();
    occupancy_aware_flush_padding_never_worse(pjrt_runtime(), "nano");
}

fn eos_cut_matches_tokenizer_semantics(rt: &Runtime, tier_name: &str) {
    let tier = rt.manifest.tier(tier_name).unwrap().clone();
    let weights = WeightSet::init(&tier, 0).unwrap();
    let engine = RolloutEngine::new(rt, tier_name, rt.manifest.batch.test).unwrap();
    let tok = Tokenizer::new();
    let mut rng = Pcg64::new(20);
    let problems: Vec<_> = (0..4).map(|_| SUITES[0].generate(&mut rng)).collect();
    let pb = prompt_batch(&problems, &tok, 1, engine.t_prefill);
    let roll = engine.rollout(rt, &weights, &pb, &tok, 1.0, &mut rng).unwrap();
    for row in &roll.rows {
        if row.hit_eos {
            assert_eq!(*row.response.last().unwrap(), EOS);
            assert!(!row.text.contains('\u{0}'));
        } else {
            assert_eq!(row.response.len(), engine.n_gen);
        }
        assert_eq!(row.behavior.len(), row.response.len());
    }
}

#[test]
fn eos_cut_matches_tokenizer_semantics_sim() {
    eos_cut_matches_tokenizer_semantics(sim_runtime(), "sim");
}

#[test]
fn eos_cut_matches_tokenizer_semantics_pjrt() {
    require_artifacts!();
    eos_cut_matches_tokenizer_semantics(pjrt_runtime(), "nano");
}
