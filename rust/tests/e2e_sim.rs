//! End-to-end suite over the hermetic sim backend — ZERO artifacts, zero
//! skips, every CI invocation (ISSUE 5 acceptance).
//!
//! Where `tests/integration.rs` runs each subsystem scenario per backend,
//! this suite holds what only a hermetic backend can test on every run:
//!
//!   * the determinism matrix the paper's systems claims rest on —
//!     pooled == serial byte-identity at D ∈ {1, 2, 4}, tenant-wave ==
//!     independent-runs, bench-ladder canonical-JSON identity, trainer
//!     checkpoint/resume bit-identity;
//!   * fault injection — transient compile failures retried through
//!     `SingleFlight`, slow-context skew that must not change results;
//!   * scheduler policies driven through a live `WorkerPool` (not just
//!     unit-level property tests), including an adapter-starvation
//!     regression;
//!   * the whole CLI-shaped flow (pretrain → GRPO → eval → bench →
//!     serve) in one process with nothing on disk but temp scratch.
//!
//! Nothing here reads `artifacts/`; the suite must pass in a tree where
//! that directory does not exist.

use std::collections::HashSet;

use tinylora_rl::adapters::packing::Precision;
use tinylora_rl::coordinator::grpo::{grpo_session, grpo_session_cfg, GrpoConfig, GrpoLoop};
use tinylora_rl::coordinator::optimizer::lr_at;
use tinylora_rl::coordinator::policy::Policy;
use tinylora_rl::coordinator::pretrain::{pretrain, PretrainConfig};
use tinylora_rl::coordinator::{sweep_population, HalvingConfig, SweepConfig};
use tinylora_rl::experiments::{rl_vs_sft_budget, BudgetConfig};
use tinylora_rl::engine::pool::{GenJob, WorkerPool};
use tinylora_rl::engine::scheduler::{QueuedRequest, SchedPolicy, Scheduler};
use tinylora_rl::engine::InferenceEngine;
use tinylora_rl::eval::bench::{run_ladder_with, BenchConfig};
use tinylora_rl::eval::evaluate;
use tinylora_rl::metrics::RunLog;
use tinylora_rl::runtime::{SimOptions, SIM_SCHEME, SIM_TIER};
use tinylora_rl::serving::{
    AdapterStore, ArrivalTrace, Frontend, FrontendConfig, Router, SloStats, StoreStats,
    TraceConfig,
};
use tinylora_rl::util::json::Value;
use tinylora_rl::tasks::generator::{Problem, SUITES};
use tinylora_rl::trainer::pipeline::train_async;
use tinylora_rl::trainer::{PipelineConfig, TenantSpec, TenantTrainer, TrainSession, TrainState};
use tinylora_rl::util::Pcg64;
use tinylora_rl::weights::WeightSet;
use tinylora_rl::Runtime;

fn scratch(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("tlrl_e2e_sim_{name}"));
    std::fs::create_dir_all(&dir).ok();
    dir
}

fn base_weights(rt: &Runtime, seed: u64) -> WeightSet {
    WeightSet::init(&rt.manifest.tier(SIM_TIER).unwrap().clone(), seed).unwrap()
}

/// A mixed job list covering every pool decode path: padded single-row
/// jobs (sentinel path) and grouped GRPO-style jobs (exact-geometry path).
fn mixed_jobs(rt: &Runtime) -> Vec<GenJob> {
    let weights = base_weights(rt, 0);
    let adapters = [weights, base_weights(rt, 3)];
    (0..6u64)
        .map(|id| {
            let mut rng = Pcg64::with_stream(500 + id, 0x6a6f6273);
            let grouped = id % 3 == 2;
            GenJob {
                id,
                weights: adapters[(id % 2) as usize].clone(),
                problems: (0..if grouped { 2 } else { 3 })
                    .map(|_| SUITES[(id % 2) as usize].generate(&mut rng))
                    .collect(),
                group: if grouped { 2 } else { 1 },
                pb: None,
                temperature: 1.0,
                seed: 70 + id,
                policy_version: 0,
            }
        })
        .collect()
}

/// Byte-level fingerprint of pool results: token streams plus behavior
/// log-prob BIT PATTERNS (f32 equality is not enough for a byte-identity
/// claim).
fn fingerprint(results: &[tinylora_rl::engine::pool::GenJobResult]) -> Vec<(u64, Vec<i32>, Vec<u32>)> {
    results
        .iter()
        .map(|r| {
            let mut toks = Vec::new();
            let mut bits = Vec::new();
            for row in &r.rows {
                toks.extend_from_slice(&row.response);
                bits.extend(row.behavior.iter().map(|x| x.to_bits()));
            }
            (r.id, toks, bits)
        })
        .collect()
}

/// ISSUE 5 acceptance: pooled results are byte-identical to the D=1
/// serial reference at every device count D ∈ {1, 2, 4}, under worker
/// counts that exceed, match and undershoot the job count.
#[test]
fn pooled_equals_serial_byte_identical_at_d_1_2_4() {
    let rt_ref = Runtime::sim(1).unwrap();
    let engine_ref = InferenceEngine::new(&rt_ref, SIM_TIER, rt_ref.manifest.batch.test).unwrap();
    let reference =
        fingerprint(&WorkerPool::serve_serial(&rt_ref, &engine_ref, &mixed_jobs(&rt_ref)).unwrap());
    assert_eq!(reference.len(), 6);

    for d in [1usize, 2, 4] {
        let rt = Runtime::sim(d).unwrap();
        assert_eq!(rt.devices(), d);
        let engine = InferenceEngine::new(&rt, SIM_TIER, rt.manifest.batch.test).unwrap();
        for workers in [2usize, 6, 8] {
            let pooled = fingerprint(
                &WorkerPool::new(workers).serve(&rt, &engine, mixed_jobs(&rt)).unwrap(),
            );
            assert_eq!(
                pooled, reference,
                "D={d} workers={workers}: pooled diverged from the serial reference"
            );
        }
        if d > 1 {
            // the pool genuinely spread work across contexts
            let per = rt.per_context_stats();
            assert!(per.iter().filter(|s| s.runs > 0).count() > 1, "D={d}: one context did it all");
        }
    }
}

/// Fault injection: a transient compile failure surfaces as an error,
/// does NOT poison the single-flight cache, and the retry compiles
/// exactly once — through the full `Runtime::load` path.
#[test]
fn compile_failure_is_transient_and_retried_via_single_flight() {
    let rt = Runtime::sim_with(1, SimOptions { fail_compiles: 2, ..Default::default() }).unwrap();
    let name = rt.manifest.generate_exe(SIM_TIER, rt.manifest.batch.test).unwrap().name.clone();

    // two injected failures: two loads fail, each with a named error
    // (Executable is deliberately not Debug, so take the error by hand)
    for attempt in 0..2 {
        let err = rt.load(&name).err().expect("injected failure must surface");
        let msg = format!("{err:#}");
        assert!(msg.contains("injected sim compile failure"), "attempt {attempt}: {msg}");
        assert_eq!(rt.stats().compiles, 0, "failed compiles must not count as compiles");
    }
    // third try succeeds and the executable is cached for everyone
    let exe = rt.load(&name).unwrap();
    assert_eq!(rt.stats().compiles, 1);
    let again = rt.load(&name).unwrap();
    assert!(std::sync::Arc::ptr_eq(&exe, &again), "retry result must be cached");

    // ... and a failure mid-concurrency resolves: some waiters see the
    // injected error, a retry wins, everyone converges on one compile
    let rt2 = Runtime::sim_with(1, SimOptions { fail_compiles: 1, ..Default::default() }).unwrap();
    let n2 = rt2.manifest.generate_exe(SIM_TIER, rt2.manifest.batch.test).unwrap().name.clone();
    std::thread::scope(|s| {
        for _ in 0..6 {
            s.spawn(|| {
                // first load may observe the injected failure; the retry
                // must always succeed
                if rt2.load(&n2).is_err() {
                    rt2.load(&n2).unwrap();
                }
            });
        }
    });
    assert_eq!(rt2.stats().compiles, 1, "post-failure retries must still coalesce");
}

/// Fault injection: a context that is 30 ms slower per execute changes
/// wall-clock only — pooled results stay byte-identical to the serial
/// reference, because job→context routing and decode content never
/// consult timing.
#[test]
fn slow_context_skew_does_not_change_results() {
    let rt_ref = Runtime::sim(1).unwrap();
    let engine_ref = InferenceEngine::new(&rt_ref, SIM_TIER, rt_ref.manifest.batch.test).unwrap();
    let reference =
        fingerprint(&WorkerPool::serve_serial(&rt_ref, &engine_ref, &mixed_jobs(&rt_ref)).unwrap());

    let rt = Runtime::sim_with(
        2,
        SimOptions { ctx_delay_us: vec![0, 30_000], ..Default::default() },
    )
    .unwrap();
    let engine = InferenceEngine::new(&rt, SIM_TIER, rt.manifest.batch.test).unwrap();
    let skewed =
        fingerprint(&WorkerPool::new(4).serve(&rt, &engine, mixed_jobs(&rt)).unwrap());
    assert_eq!(skewed, reference, "a slow context changed decoded bytes");
    // the slow context really served jobs (the skew was exercised)
    assert!(rt.per_context_stats()[1].runs > 0, "slow context idle — skew not exercised");
}

/// ISSUE 5 acceptance: trainer checkpoint/resume is bit-identical on the
/// sim backend — kill after 2 of 4 GRPO steps, reload, finish; every
/// step record and the final adapter theta match the uninterrupted run
/// bit for bit.
#[test]
fn trainer_checkpoint_resume_is_bit_identical() {
    let rt = Runtime::sim(1).unwrap();
    let b = rt.manifest.batch.test;
    let base = base_weights(&rt, 3);
    let ckpt = scratch("resume");
    let cfg = || GrpoConfig { group: 2, steps: 4, lr: 5e-3, warmup: 2, seed: 21, ..Default::default() };
    let mk = |steps: usize| -> TrainSession<GrpoLoop> {
        let policy = Policy::new(&rt, SIM_TIER, SIM_SCHEME, "grpo", base.clone(), 21, &ckpt).unwrap();
        let mut c = cfg();
        c.steps = steps;
        let scfg = grpo_session_cfg(&c);
        TrainSession::new(GrpoLoop::with_batch(&rt, policy, c, b).unwrap(), scfg)
    };

    let mut full = mk(4);
    let full_recs = full.run(&rt, &mut RunLog::null()).unwrap();
    let full_theta: Vec<u32> = full.lp.policy.theta.iter().map(|x| x.to_bits()).collect();

    let mut half = mk(2);
    let half_recs = half.run(&rt, &mut RunLog::null()).unwrap();
    let state_path = ckpt.join("grpo.trainstate");
    half.state().save(&state_path).unwrap();
    drop(half);

    let st = TrainState::load(&state_path).unwrap();
    assert_eq!(st.step, 2);
    let policy = Policy::new(&rt, SIM_TIER, SIM_SCHEME, "grpo", base.clone(), 21, &ckpt).unwrap();
    let lp = GrpoLoop::with_batch(&rt, policy, cfg(), b).unwrap();
    let mut resumed = TrainSession::resume(&rt, lp, grpo_session_cfg(&cfg()), &st).unwrap();
    let resumed_recs = resumed.run(&rt, &mut RunLog::null()).unwrap();
    assert_eq!(resumed_recs.len(), 2);

    let bits = |r: &tinylora_rl::coordinator::StepRecord| -> Vec<u32> {
        vec![
            r.step as u32,
            r.reward.to_bits(),
            r.response_len.to_bits(),
            r.format_rate.to_bits(),
            r.lr.to_bits(),
            r.stats.loss.to_bits(),
            r.stats.kl_k1.to_bits(),
            r.stats.grad_norm.to_bits(),
        ]
    };
    for (a, x) in full_recs[..2].iter().zip(&half_recs) {
        assert_eq!(bits(a), bits(x), "pre-kill step {} diverged", a.step);
    }
    for (a, x) in full_recs[2..].iter().zip(&resumed_recs) {
        assert_eq!(bits(a), bits(x), "post-resume step {} diverged", a.step);
    }
    let resumed_theta: Vec<u32> = resumed.lp.policy.theta.iter().map(|x| x.to_bits()).collect();
    assert_eq!(full_theta, resumed_theta, "final adapter diverged after resume");
}

/// ISSUE 5 acceptance: a pooled tenant wave equals G independent runs —
/// per-step records and final adapters bit-identical — and the wave runs
/// across a D=2 context pool.
#[test]
fn tenant_wave_matches_independent_runs_across_devices() {
    let rt = Runtime::sim(2).unwrap();
    let b = rt.manifest.batch.test;
    let base = base_weights(&rt, 3);
    let ckpt = scratch("tenants");
    let specs: Vec<TenantSpec> = (0..3u64)
        .map(|i| TenantSpec {
            name: format!("tenant-{i}"),
            scheme_tag: SIM_SCHEME.into(),
            cfg: GrpoConfig {
                group: 2,
                steps: 3,
                lr: 2e-3 + i as f32 * 1e-3,
                warmup: 2,
                seed: 40 + i,
                ..Default::default()
            },
            precision: Precision::Bf16,
        })
        .collect();

    let mut tt = TenantTrainer::with_batch(&rt, &base, specs.clone(), 2, &ckpt, b).unwrap();
    tt.train(&rt, &mut RunLog::null(), true).unwrap();

    for (i, spec) in specs.iter().enumerate() {
        let mut policy =
            Policy::new(&rt, SIM_TIER, &spec.scheme_tag, "grpo", base.clone(), spec.cfg.seed, &ckpt)
                .unwrap();
        policy.precision = spec.precision;
        let mut sess = TrainSession::new(
            GrpoLoop::with_batch(&rt, policy, spec.cfg.clone(), b).unwrap(),
            grpo_session_cfg(&spec.cfg),
        );
        sess.run(&rt, &mut RunLog::null()).unwrap();
        assert_eq!(
            tt.sessions[i].lp.policy.theta.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            sess.lp.policy.theta.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "tenant {i}: pooled wave != independent run"
        );
    }
    // the wave really used both contexts
    assert!(rt.per_context_stats().iter().all(|s| s.runs > 0));
}

/// ISSUE 5 acceptance: the bench ladder's canonical JSON is byte-identical
/// between the serial reference and a pooled run on a multi-context
/// runtime.
#[test]
fn bench_ladder_pooled_equals_serial_canonical_json() {
    let cfg = |workers: usize| BenchConfig {
        tier: SIM_TIER.into(),
        suites: Vec::new(),
        k: 2,
        n: 4,
        temperature: 1.0,
        seed: 7,
        workers,
        batch: 0,
    };
    let rt1 = Runtime::sim(1).unwrap();
    let e1 = InferenceEngine::new(&rt1, SIM_TIER, rt1.manifest.batch.test).unwrap();
    let base1 = base_weights(&rt1, 3);
    let serial = run_ladder_with(&rt1, &e1, &base1, "base", 0, &cfg(1)).unwrap();

    let rt2 = Runtime::sim(2).unwrap();
    let e2 = InferenceEngine::new(&rt2, SIM_TIER, rt2.manifest.batch.test).unwrap();
    let pooled = run_ladder_with(&rt2, &e2, &base1, "base", 0, &cfg(3)).unwrap();
    assert_eq!(
        serial.to_json().to_string(),
        pooled.to_json().to_string(),
        "bench ladder JSON diverged across pooling/devices"
    );
}

/// Occupancy-aware flushes under adversarial row sequences: padding never
/// exceeds the fixed-geometry baseline, exactly one row per problem, and
/// (greedy) a problem's decoded row does not depend on how the queue
/// around it was chunked or padded.
#[test]
fn occupancy_flush_is_packing_invariant_under_adversarial_sequences() {
    let rt = Runtime::sim(1).unwrap();
    let b = rt.manifest.batch.test;
    let engine = InferenceEngine::new(&rt, SIM_TIER, b).unwrap();
    let weights = base_weights(&rt, 0);
    let tok = tinylora_rl::tokenizer::Tokenizer::new();

    let mut rng = Pcg64::new(99);
    let problems: Vec<Problem> = (0..2 * b + 3).map(|_| SUITES[0].generate(&mut rng)).collect();

    // reference: decode the full list once, remember each prompt's row
    let mut gen_rng = Pcg64::new(1);
    let full_rows =
        engine.generate_problems(&rt, &weights, &problems, &tok, 0.0, &mut gen_rng).unwrap();
    assert_eq!(full_rows.len(), problems.len());

    // adversarial prefixes/suffixes: every packing must reproduce the
    // same per-problem greedy rows and never pad worse than fixed-geometry
    for n in [1usize, 2, b - 1, b, b + 1, 2 * b - 1, 2 * b + 3] {
        let chunk = &problems[..n];
        let before = engine.stats();
        let mut r = Pcg64::new(2);
        let rows = engine.generate_problems(&rt, &weights, chunk, &tok, 0.0, &mut r).unwrap();
        let after = engine.stats();
        assert_eq!(rows.len(), n);
        let fixed = (n.div_ceil(b) * b - n) as u64;
        assert!(after.padded_rows - before.padded_rows <= fixed, "n={n}: padded worse than fixed");
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(
                row.response, full_rows[i].response,
                "problem {i} decoded differently when packed in a batch of {n}"
            );
        }
    }
}

/// Scheduler policies driven through a LIVE worker pool on the sim
/// backend: every submitted request is decoded exactly once per policy,
/// wave after wave.
#[test]
fn scheduler_policies_drive_live_worker_pool() {
    for policy in [SchedPolicy::OccupancyFirst, SchedPolicy::DeadlineFlush, SchedPolicy::RoundRobin] {
        let rt = Runtime::sim(2).unwrap();
        let b = rt.manifest.batch.test;
        let engine = InferenceEngine::new(&rt, SIM_TIER, b).unwrap();
        let weights = base_weights(&rt, 0);
        let pool = WorkerPool::new(3);

        let mut sched = Scheduler::new(b, 0.05, policy);
        let mut rng = Pcg64::new(7);
        let n_requests = 17u64; // not a multiple of b: partial flushes happen
        for id in 0..n_requests {
            let p = SUITES[0].generate(&mut rng);
            sched.push(QueuedRequest {
                id,
                adapter: format!("t{}", id % 3),
                prompt: p.prompt,
                arrival: id as f64 * 0.01,
            });
        }

        let mut served: HashSet<u64> = HashSet::new();
        let mut now = 0.0f64;
        let mut waves = 0;
        while sched.pending() > 0 {
            let wave = sched.flush_wave(now);
            if wave.is_empty() {
                now += 0.06;
                continue;
            }
            waves += 1;
            let jobs: Vec<GenJob> = wave
                .iter()
                .enumerate()
                .map(|(k, batch)| GenJob {
                    id: k as u64,
                    weights: weights.clone(),
                    problems: batch
                        .requests
                        .iter()
                        .map(|r| Problem {
                            prompt: r.prompt.clone(),
                            gold: String::new(),
                            answer: 0,
                            suite: "serving",
                        })
                        .collect(),
                    group: 1,
                    pb: None,
                    temperature: 0.0,
                    seed: batch.requests[0].id,
                    policy_version: 0,
                })
                .collect();
            let results = pool.serve(&rt, &engine, jobs).unwrap();
            for (batch, res) in wave.iter().zip(&results) {
                assert_eq!(batch.requests.len(), res.rows.len(), "{policy:?}: row count");
                for req in &batch.requests {
                    assert!(served.insert(req.id), "{policy:?}: request {} served twice", req.id);
                }
            }
            now += 0.05;
        }
        assert_eq!(served.len(), n_requests as usize, "{policy:?}: drops");
        assert!(waves >= 2, "{policy:?}: everything flushed in one wave — scenario too weak");
    }
}

/// Adapter-starvation regression, live: a hot adapter keeps a full batch
/// queued forever; under DeadlineFlush and RoundRobin the lone cold
/// request still reaches the device within a bounded number of decoded
/// waves (OccupancyFirst is the documented-starvable control and is
/// deliberately not asserted here).
#[test]
fn starved_adapter_is_served_through_live_pool_under_fair_policies() {
    for policy in [SchedPolicy::DeadlineFlush, SchedPolicy::RoundRobin] {
        let rt = Runtime::sim(1).unwrap();
        let b = rt.manifest.batch.test;
        let engine = InferenceEngine::new(&rt, SIM_TIER, b).unwrap();
        let weights = base_weights(&rt, 0);
        let pool = WorkerPool::new(2);

        let mut sched = Scheduler::new(b, 0.1, policy);
        let mut rng = Pcg64::new(5);
        let mut next_id = 1000u64;
        let victim = SUITES[0].generate(&mut rng);
        sched.push(QueuedRequest { id: 0, adapter: "lone".into(), prompt: victim.prompt, arrival: 0.0 });

        let mut now = 0.0f64;
        let mut lone_served = false;
        for _round in 0..12 {
            // adversary: refill the hot adapter to a full batch every round
            while sched.waiting_adapters().iter().filter(|a| a.as_str() == "hot").count() == 0
                || sched.pending() < b + 1
            {
                let p = SUITES[0].generate(&mut rng);
                sched.push(QueuedRequest {
                    id: next_id,
                    adapter: "hot".into(),
                    prompt: p.prompt,
                    arrival: now,
                });
                next_id += 1;
                if next_id > 1200 {
                    break;
                }
            }
            let wave = sched.flush_wave(now);
            if !wave.is_empty() {
                let jobs: Vec<GenJob> = wave
                    .iter()
                    .enumerate()
                    .map(|(k, batch)| GenJob {
                        id: k as u64,
                        weights: weights.clone(),
                        problems: batch
                            .requests
                            .iter()
                            .map(|r| Problem {
                                prompt: r.prompt.clone(),
                                gold: String::new(),
                                answer: 0,
                                suite: "serving",
                            })
                            .collect(),
                        group: 1,
                        pb: None,
                        temperature: 0.0,
                        seed: k as u64,
                        policy_version: 0,
                    })
                    .collect();
                pool.serve(&rt, &engine, jobs).unwrap();
                if wave.iter().any(|batch| batch.requests.iter().any(|r| r.id == 0)) {
                    lone_served = true;
                    break;
                }
            }
            now += 0.06;
        }
        assert!(lone_served, "{policy:?}: lone adapter starved behind the hot adapter");
    }
}

/// Row-parallel execution inside a context is a pure throughput knob:
/// pooled decode at row-worker counts {1, 2, 4} is byte-identical to
/// the serial, worker-less reference, and a full GRPO training session
/// lands on bit-identical adapter theta at 1 vs 4 row workers. This is
/// the per-context leg of the determinism matrix (the device-pool leg
/// is `pooled_equals_serial_byte_identical_at_d_1_2_4`).
#[test]
fn sim_row_parallel_workers_preserve_byte_identity() {
    let rt_ref = Runtime::sim(1).unwrap();
    let engine_ref = InferenceEngine::new(&rt_ref, SIM_TIER, rt_ref.manifest.batch.test).unwrap();
    let reference =
        fingerprint(&WorkerPool::serve_serial(&rt_ref, &engine_ref, &mixed_jobs(&rt_ref)).unwrap());

    for row_workers in [1usize, 2, 4] {
        let rt = Runtime::sim_with(2, SimOptions { row_workers, ..Default::default() }).unwrap();
        let engine = InferenceEngine::new(&rt, SIM_TIER, rt.manifest.batch.test).unwrap();
        let pooled =
            fingerprint(&WorkerPool::new(4).serve(&rt, &engine, mixed_jobs(&rt)).unwrap());
        assert_eq!(
            pooled, reference,
            "row_workers={row_workers}: row-parallel decode diverged from serial"
        );
    }

    // training leg: the whole rollout -> grad -> update loop, end to end
    let theta_at = |row_workers: usize| -> Vec<u32> {
        let rt =
            Runtime::sim_with(1, SimOptions { row_workers, ..Default::default() }).unwrap();
        let b = rt.manifest.batch.test;
        let base = base_weights(&rt, 3);
        let ckpt = scratch("row_workers");
        let cfg =
            GrpoConfig { group: 2, steps: 3, lr: 5e-3, warmup: 2, seed: 21, ..Default::default() };
        let policy = Policy::new(&rt, SIM_TIER, SIM_SCHEME, "grpo", base, 21, &ckpt).unwrap();
        let mut sess = TrainSession::new(
            GrpoLoop::with_batch(&rt, policy, cfg.clone(), b).unwrap(),
            grpo_session_cfg(&cfg),
        );
        sess.run(&rt, &mut RunLog::null()).unwrap();
        sess.lp.policy.theta.iter().map(|x| x.to_bits()).collect()
    };
    assert_eq!(theta_at(1), theta_at(4), "GRPO theta diverged across row-worker counts");
}

/// The per-row execute-time budget knob stalls the backend (so latency
/// shaping is real) without changing a single decoded byte.
#[test]
fn sim_row_budget_stalls_execute_without_changing_results() {
    let rt_ref = Runtime::sim(1).unwrap();
    let engine_ref = InferenceEngine::new(&rt_ref, SIM_TIER, rt_ref.manifest.batch.test).unwrap();
    let reference =
        fingerprint(&WorkerPool::serve_serial(&rt_ref, &engine_ref, &mixed_jobs(&rt_ref)).unwrap());

    let rt =
        Runtime::sim_with(1, SimOptions { row_budget_us: 2000, ..Default::default() }).unwrap();
    let engine = InferenceEngine::new(&rt, SIM_TIER, rt.manifest.batch.test).unwrap();
    let t = std::time::Instant::now();
    let budgeted =
        fingerprint(&WorkerPool::serve_serial(&rt, &engine, &mixed_jobs(&rt)).unwrap());
    let elapsed = t.elapsed();
    assert_eq!(budgeted, reference, "row budget changed decoded bytes");
    // 6 jobs x >= 2 rows x 2 ms/row of injected budget: the stall is real
    assert!(
        elapsed >= std::time::Duration::from_millis(20),
        "row budget not applied: drained in {elapsed:?}"
    );
}

/// Multi-tenant serving drains identically with and without pool
/// parallelism (greedy decode: texts must match request for request).
#[test]
fn router_parallel_drain_matches_sequential_on_sim() {
    let build = |rt: &Runtime| -> Router {
        let base = base_weights(rt, 3);
        let mut store = AdapterStore::new(SIM_TIER, 2);
        let mut rng = Pcg64::new(11);
        for i in 0..5 {
            let theta: Vec<f32> = (0..13).map(|_| rng.normal() * 0.1).collect();
            store.register(&format!("tenant-{i}"), SIM_SCHEME, &theta, Precision::Bf16).unwrap();
        }
        let mut router = Router::new(
            rt,
            store,
            base,
            rt.manifest.batch.serve,
            0.2,
            scratch("router"),
        )
        .unwrap();
        let mut traffic_rng = Pcg64::new(23);
        for id in 0..22u64 {
            let tenant = traffic_rng.below(5);
            let p = SUITES[0].generate(&mut traffic_rng);
            router.submit(id, &format!("tenant-{tenant}"), &p);
            router.now += 0.01;
        }
        router
    };

    let rt1 = Runtime::sim(1).unwrap();
    let mut sequential = build(&rt1);
    sequential.drain(&rt1).unwrap();

    let rt2 = Runtime::sim(2).unwrap();
    let mut parallel = build(&rt2);
    parallel.drain_parallel(&rt2, 3).unwrap();

    let texts = |r: &Router| -> Vec<(u64, String, String)> {
        let mut v: Vec<_> =
            r.responses.iter().map(|x| (x.id, x.adapter.clone(), x.text.clone())).collect();
        v.sort();
        v
    };
    assert_eq!(texts(&sequential), texts(&parallel), "parallel drain changed served texts");
    assert_eq!(sequential.stats().served, 22);
    assert_eq!(parallel.stats().served, 22);
}

/// Register the same 26-byte tenants with the same thetas — serving
/// byte-identity claims only hold when every run sees identical adapters.
fn serving_tenants(store: &mut AdapterStore, n: usize) {
    let mut rng = Pcg64::new(11);
    for i in 0..n {
        let theta: Vec<f32> = (0..13).map(|_| rng.normal() * 0.1).collect();
        store.register(&format!("tenant-{i}"), SIM_SCHEME, &theta, Precision::Bf16).unwrap();
    }
}

/// ISSUE 8 acceptance: the continuous-batching front-end is proven
/// byte-identical to wave draining on the full open-loop path. One
/// seeded arrival trace at zero overload is served by (a) the PR 6
/// `Router::drain_parallel` reference, (b) the continuous refill
/// front-end and (c) its wave-drain mode, across devices {1,2} ×
/// row-workers {1,4} — every run must produce the same per-request
/// texts. Replaying the trace must also reproduce the SLO metrics
/// exactly, all the way through the JSONL row.
#[test]
fn continuous_frontend_matches_wave_drain_byte_identical_at_zero_overload() {
    let tcfg = TraceConfig {
        seed: 41,
        n: 26,
        rate: 30.0,
        burst: 2,
        tenants: 5,
        zipf_s: 1.1,
        ..Default::default()
    };
    let trace = ArrivalTrace::generate(&tcfg).unwrap();
    let cfg = FrontendConfig {
        batch: 4,
        slots: 2,
        // effectively infinite budget: zero overload must shed nothing
        deadline: 1e6,
        max_wait: 0.2,
        service_base: 0.05,
        service_per_row: 0.0,
        policy: SchedPolicy::DeadlineFlush,
        continuous: true,
    };

    // (a) reference: the wave-drain router on the identical trace
    let rt = Runtime::sim(1).unwrap();
    let mut store = AdapterStore::new(SIM_TIER, 2);
    serving_tenants(&mut store, 5);
    let mut router = Router::new(
        &rt,
        store,
        base_weights(&rt, 3),
        rt.manifest.batch.serve,
        0.2,
        scratch("fe_ref"),
    )
    .unwrap();
    for e in &trace.events {
        router.now = e.at;
        let p = Problem {
            prompt: e.prompt.clone(),
            gold: String::new(),
            answer: 0,
            suite: "serving",
        };
        router.submit(e.id, &e.tenant, &p);
    }
    router.drain_parallel(&rt, 4).unwrap();
    let mut reference: Vec<(u64, String, String)> =
        router.responses.iter().map(|r| (r.id, r.adapter.clone(), r.text.clone())).collect();
    reference.sort();
    assert_eq!(reference.len(), 26);

    // (b)+(c): both front-end modes across the device/worker matrix
    for (devices, row_workers) in [(1, 1), (2, 1), (1, 4), (2, 4)] {
        let rt =
            Runtime::sim_with(devices, SimOptions { row_workers, ..Default::default() }).unwrap();
        for continuous in [true, false] {
            let mut store = AdapterStore::new(SIM_TIER, 2);
            serving_tenants(&mut store, 5);
            let mut fe = Frontend::new(
                &rt,
                store,
                base_weights(&rt, 3),
                FrontendConfig { continuous, ..cfg.clone() },
                scratch("fe_run"),
            )
            .unwrap();
            let plan = fe.serve_trace(&rt, &trace).unwrap();
            assert!(plan.sheds.is_empty(), "zero overload must not shed");
            let mut triples: Vec<(u64, String, String)> =
                fe.responses.iter().map(|r| (r.id, r.adapter.clone(), r.text.clone())).collect();
            triples.sort();
            assert_eq!(
                triples, reference,
                "front-end texts diverged from drain_parallel \
                 (devices={devices} row_workers={row_workers} continuous={continuous})"
            );
        }
    }

    // trace replay: two fresh runs reproduce the SLO metrics exactly,
    // including the serialized JSONL row (wall time pinned — it is the
    // one field measuring this machine rather than the schedule)
    let run_slo = |tag: &str| -> (SloStats, Value) {
        let rt = Runtime::sim(1).unwrap();
        let mut store = AdapterStore::new(SIM_TIER, 2);
        serving_tenants(&mut store, 5);
        let mut fe =
            Frontend::new(&rt, store, base_weights(&rt, 3), cfg.clone(), scratch("fe_slo"))
                .unwrap();
        let plan = fe.serve_trace(&rt, &trace).unwrap();
        let slo = fe.slo(&plan);
        let path = scratch("fe_slo").join(format!("slo_{tag}.jsonl"));
        std::fs::remove_file(&path).ok();
        {
            let mut log = RunLog::new(Some(&path), false);
            log.log_serve(SIM_TIER, "continuous", trace.config.rate, &slo, 1.0);
        }
        let text = std::fs::read_to_string(&path).unwrap();
        (slo, Value::parse(text.trim()).unwrap())
    };
    let (slo_a, row_a) = run_slo("a");
    let (slo_b, row_b) = run_slo("b");
    assert_eq!(slo_a, slo_b, "trace replay changed the SLO stats");
    assert_eq!(row_a, row_b, "trace replay changed the serialized JSONL row");
    assert_eq!((slo_a.served, slo_a.shed, slo_a.violations), (26, 0, 0));
}

/// ISSUE 8 acceptance: deterministic shedding under injected delays.
/// The sim backend's `row_budget_us` fault knob stalls every execute
/// call on the real wall clock while the front-end's virtual service
/// model (`service_per_row` = the same 20ms/row) pushes the plane past
/// capacity — p99, goodput and shed counts must reflect the stalls, land
/// identically in the JSONL stream on every run, and leave decoded
/// content untouched.
#[test]
fn frontend_injected_stalls_shape_tail_latency_and_shedding_deterministically() {
    let tcfg = TraceConfig {
        seed: 97,
        n: 60,
        rate: 300.0,
        burst: 1,
        tenants: 6,
        zipf_s: 1.1,
        ..Default::default()
    };
    let trace = ArrivalTrace::generate(&tcfg).unwrap();
    // calm capacity: 2 slots × 4 rows / 5ms = 1600 rows/s — even an
    // all-at-once burst of 60 drains in ~40ms, far inside the 200ms
    // budget, so zero shed is guaranteed. Stalled capacity: service(4) =
    // 5ms + 4 × 20ms = 85ms → ≈ 94 rows/s, and 60 arrivals in 200ms
    // cannot all dispatch within deadline → shedding is guaranteed.
    let cfg = |per_row: f64| FrontendConfig {
        batch: 4,
        slots: 2,
        deadline: 0.2,
        max_wait: 0.02,
        service_base: 0.005,
        service_per_row: per_row,
        policy: SchedPolicy::DeadlineFlush,
        continuous: true,
    };
    type Run = (SloStats, Vec<(u64, u64)>, Vec<(u64, String)>, Value, f64);
    let run = |row_budget_us: u64, per_row: f64, tag: &str| -> Run {
        let rt =
            Runtime::sim_with(1, SimOptions { row_budget_us, ..Default::default() }).unwrap();
        let mut store = AdapterStore::new(SIM_TIER, 2);
        serving_tenants(&mut store, 6);
        let mut fe =
            Frontend::new(&rt, store, base_weights(&rt, 3), cfg(per_row), scratch("fe_stall"))
                .unwrap();
        let t = std::time::Instant::now();
        let plan = fe.serve_trace(&rt, &trace).unwrap();
        let elapsed_ms = t.elapsed().as_secs_f64() * 1e3;
        let slo = fe.slo(&plan);
        // shed decisions down to the bit pattern of their timestamps
        let sheds: Vec<(u64, u64)> = plan.sheds.iter().map(|x| (x.id, x.at.to_bits())).collect();
        let mut texts: Vec<(u64, String)> =
            fe.responses.iter().map(|r| (r.id, r.text.clone())).collect();
        texts.sort();
        let path = scratch("fe_stall").join(format!("slo_{tag}.jsonl"));
        std::fs::remove_file(&path).ok();
        {
            let mut log = RunLog::new(Some(&path), false);
            log.log_serve(SIM_TIER, "continuous", trace.config.rate, &slo, 1.0);
        }
        let row = Value::parse(std::fs::read_to_string(&path).unwrap().trim()).unwrap();
        (slo, sheds, texts, row, elapsed_ms)
    };

    let (calm, calm_sheds, calm_texts, _, _) = run(0, 0.0, "calm");
    let (stalled, sheds1, texts1, row1, elapsed_ms) = run(20_000, 0.02, "stall_a");
    let (stalled2, sheds2, texts2, row2, _) = run(20_000, 0.02, "stall_b");

    // injected stalls are exactly reproducible: same SLO stats, same shed
    // decisions (ids AND timestamps), same texts, same JSONL row
    assert_eq!(stalled, stalled2, "stalled SLO stats not deterministic");
    assert_eq!(sheds1, sheds2, "shed decisions not deterministic");
    assert_eq!(texts1, texts2, "stalled decode texts not deterministic");
    assert_eq!(row1, row2, "stalled JSONL serve row not deterministic");

    // the stall profile: calm serves everything, stalled sheds and pays
    // tail latency, goodput collapses accordingly
    assert!(calm_sheds.is_empty());
    assert_eq!((calm.served, calm.shed), (60, 0));
    assert!(stalled.shed > 0, "overloaded stalled run must shed");
    assert_eq!(stalled.served + stalled.shed, 60);
    assert!(
        stalled.p99_latency > calm.p99_latency,
        "injected stalls must surface in p99: stalled {} vs calm {}",
        stalled.p99_latency,
        calm.p99_latency
    );
    assert!(stalled.goodput < calm.goodput);

    // the fault knob stalls the REAL clock: ≥ 10 batches × ≥ 20ms each
    assert!(
        elapsed_ms >= 100.0,
        "row_budget_us stalls must hit the wall clock (elapsed {elapsed_ms:.0}ms)"
    );

    // stalls shape timing only — any request served in both runs decoded
    // byte-identical content
    let calm_map: std::collections::HashMap<u64, &String> =
        calm_texts.iter().map(|(id, t)| (*id, t)).collect();
    let mut common = 0;
    for (id, text) in &texts1 {
        if let Some(t) = calm_map.get(id) {
            assert_eq!(*t, text, "request {id} decoded differently under stalls");
            common += 1;
        }
    }
    assert!(common > 0, "no overlap between calm and stalled served sets");
}

/// The whole CLI-shaped lifecycle in one process, zero artifacts:
/// pretrain a sim backbone (loss must genuinely fall), GRPO-elicit a
/// 13-param adapter from the saved checkpoint, evaluate it, bench it on
/// the ladder, and serve it — the "aha" flow `--backend sim` gives a
/// fresh clone with no toolchain.
#[test]
fn full_stack_pretrain_train_bench_serve_with_zero_artifacts() {
    let rt = Runtime::sim(1).unwrap();
    assert_eq!(rt.backend_name(), "sim");
    let dirs = scratch("full_stack");
    let mut log = RunLog::null();

    // 1. pretrain from scratch; the sim gradients must actually descend
    let pcfg = PretrainConfig { steps: 60, lr: 3e-3, warmup: 10, seed: 0, ..Default::default() };
    let res = pretrain(&rt, SIM_TIER, &pcfg, &dirs, &mut log).unwrap();
    assert!(res.final_loss.is_finite());
    let first_loss = res.losses.first().unwrap().1;
    assert!(
        res.final_loss < first_loss,
        "pretraining did not descend: {first_loss} -> {}",
        res.final_loss
    );

    // 2. load the checkpoint the way every driver does and GRPO-elicit
    let base = Policy::load_base(&rt, SIM_TIER, &dirs).unwrap();
    let policy = Policy::new(&rt, SIM_TIER, SIM_SCHEME, "grpo", base.clone(), 0, &dirs).unwrap();
    assert_eq!(policy.trainable_params(), 13);
    let gcfg = GrpoConfig { steps: 2, group: 4, seed: 0, ..Default::default() };
    let mut sess = grpo_session(&rt, policy, gcfg).unwrap();
    let recs = sess.run(&rt, &mut log).unwrap();
    assert_eq!(recs.len(), 2);
    let trained = sess.into_loop().policy;

    // 3. greedy eval + the pass@k ladder on the trained adapter
    let ev = evaluate(&rt, SIM_TIER, &trained.merged, "gsm8k-syn", 8, 777).unwrap();
    assert!((0.0..=1.0).contains(&ev.accuracy));
    let engine = InferenceEngine::new(&rt, SIM_TIER, rt.manifest.batch.test).unwrap();
    let bcfg = BenchConfig {
        tier: SIM_TIER.into(),
        suites: Vec::new(),
        k: 2,
        n: 2,
        temperature: 1.0,
        seed: 7,
        workers: 2,
        batch: 0,
    };
    let run = run_ladder_with(&rt, &engine, &trained.merged, SIM_SCHEME, 13, &bcfg).unwrap();
    assert_eq!(run.scores.len(), 4);
    assert!(run.to_markdown().contains(SIM_SCHEME));

    // 4. register into the serving plane and serve real traffic
    let mut store = AdapterStore::new(SIM_TIER, 2);
    store.register("prod", SIM_SCHEME, &trained.theta, Precision::Bf16).unwrap();
    assert_eq!(store.stored_bytes(), 26, "the paper's 26-byte headline update");
    let mut router =
        Router::new(&rt, store, base, rt.manifest.batch.serve, 0.2, dirs.clone()).unwrap();
    let mut rng = Pcg64::new(3);
    for id in 0..9u64 {
        let p = SUITES[0].generate(&mut rng);
        router.submit(id, "prod", &p);
        router.now += 0.01;
    }
    router.drain(&rt).unwrap();
    let stats = router.stats();
    assert_eq!(stats.served, 9);
    assert!(stats.batches >= 3, "b=4 serving of 9 requests needs >= 3 batches");
    std::fs::remove_dir_all(&dirs).ok();
}

/// Tiered-store acceptance: a large tenant population served through the
/// full three-tier plane (cold-miss unpack, warm-hit re-merge, hot-hit
/// clone, wave pinning, eviction-with-demotion) produces responses
/// byte-identical to an oracle store big enough to keep every merged
/// tenant hot — at every device / row-worker / drain-parallelism
/// combination — while the stats prove each transition really fired.
#[test]
fn tiered_store_serves_large_population_byte_identical_to_oracle() {
    const TENANTS: usize = 2000;

    let run_plane = |rt: &Runtime,
                     base: &WeightSet,
                     max_resident: usize,
                     max_warm: usize,
                     par_workers: usize|
     -> (Vec<(u64, String, String)>, StoreStats) {
        let mut store = AdapterStore::with_tiers(SIM_TIER, max_resident, max_warm);
        let mut rng = Pcg64::new(212);
        for i in 0..TENANTS {
            let theta: Vec<f32> = (0..13).map(|_| rng.normal() * 0.05).collect();
            store.register(&format!("tenant-{i}"), SIM_SCHEME, &theta, Precision::Bf16).unwrap();
        }
        assert_eq!(store.stored_bytes(), TENANTS * 26, "26-byte records at population scale");
        assert_eq!(store.stored_bytes(), store.recompute_stored_bytes());

        let mut router = Router::new(
            rt,
            store,
            base.clone(),
            rt.manifest.batch.serve,
            0.2,
            scratch("tenant_plane"),
        )
        .unwrap();
        // segment trace: revisits under eviction pressure walk every tier
        // transition; each segment drains fully before the next submits,
        // so the adapter access order is deterministic regardless of
        // batching and parallelism
        let segments: Vec<Vec<usize>> =
            vec![vec![0, 1], vec![2, 3], vec![0, 1], (10..26).collect(), vec![0], vec![0]];
        for (si, seg) in segments.iter().enumerate() {
            let mut prng = Pcg64::with_stream(si as u64, 0x7e4a);
            for (j, &tenant) in seg.iter().enumerate() {
                let p = SUITES[0].generate(&mut prng);
                router.submit((si * 100 + j) as u64, &format!("tenant-{tenant}"), &p);
            }
            router.now += 1.0;
            if par_workers == 0 {
                router.drain(rt).unwrap();
            } else {
                router.drain_parallel(rt, par_workers).unwrap();
            }
        }
        let mut texts: Vec<(u64, String, String)> =
            router.responses.iter().map(|x| (x.id, x.adapter.clone(), x.text.clone())).collect();
        texts.sort();
        (texts, router.store.stats())
    };

    let mut tiered_runs = Vec::new();
    for (devices, row_workers, par_workers) in [(1usize, 0usize, 0usize), (2, 0, 3), (1, 4, 2)] {
        let rt =
            Runtime::sim_with(devices, SimOptions { row_workers, ..Default::default() }).unwrap();
        let base = base_weights(&rt, 7);

        // oracle: everything fits hot — merges happen, evictions never do
        let (oracle, ost) = run_plane(&rt, &base, TENANTS, TENANTS, par_workers);
        assert_eq!((ost.evictions_hot, ost.demotions), (0, 0), "oracle must never evict");

        // tiered: 2 hot slots + 8 warm thetas in front of 2000 cold records
        let (tiered, st) = run_plane(&rt, &base, 2, 8, par_workers);
        assert_eq!(
            tiered, oracle,
            "D={devices} rw={row_workers} par={par_workers}: tiered serving changed bytes"
        );
        assert!(
            st.cold_misses > 0 && st.warm_hits > 0 && st.hot_hits > 0,
            "trace must traverse all three tiers: {st:?}"
        );
        assert!(
            st.evictions_hot > 0 && st.demotions > 0 && st.evictions_warm > 0,
            "eviction/demotion machinery not exercised: {st:?}"
        );
        assert_eq!(st.hot_hits + st.warm_hits + st.cold_misses, st.activations);
        tiered_runs.push(tiered);
    }
    assert!(
        tiered_runs.windows(2).all(|w| w[0] == w[1]),
        "tiered serving diverged across device/row-worker/parallelism configs"
    );
}

/// Shared tenant grid for the async-pipeline determinism tests.
fn pipeline_specs(n: u64) -> Vec<TenantSpec> {
    (0..n)
        .map(|i| TenantSpec {
            name: format!("pipe-{i}"),
            scheme_tag: SIM_SCHEME.into(),
            cfg: GrpoConfig {
                group: 2,
                steps: 3,
                lr: 2e-3 + i as f32 * 5e-4,
                warmup: 2,
                seed: 60 + i,
                ..Default::default()
            },
            precision: Precision::Bf16,
        })
        .collect()
}

/// Every StepRecord field except the two wall-clock ones, as bit patterns.
fn record_bits(r: &tinylora_rl::coordinator::grpo::StepRecord) -> Vec<u32> {
    vec![
        r.step as u32,
        r.reward.to_bits(),
        r.response_len.to_bits(),
        r.format_rate.to_bits(),
        r.eos_rate.to_bits(),
        r.lr.to_bits(),
        r.stats.loss.to_bits(),
        r.stats.aux1.to_bits(),
        r.stats.kl_k1.to_bits(),
        r.stats.kl_k3.to_bits(),
        r.stats.mean_ratio.to_bits(),
        r.stats.frac_clipped.to_bits(),
        r.stats.entropy.to_bits(),
        r.stats.mean_logp.to_bits(),
        r.stats.grad_norm.to_bits(),
    ]
}

/// JSONL rows with the wall-time fields stripped and the pipeline summary
/// row removed — "RunLog modulo wall times", the byte-identity currency
/// of the pipeline determinism contract.
fn rows_modulo_wall(rows: Vec<Value>) -> Vec<Value> {
    rows.into_iter()
        .filter(|r| r.get("kind").unwrap().str().unwrap() != "pipeline")
        .map(|mut r| {
            if let Value::Obj(m) = &mut r {
                for key in ["rollout_ms", "grad_ms", "wall_ms", "steps_per_s"] {
                    m.remove(key);
                }
            }
            r
        })
        .collect()
}

/// ISSUE 10 acceptance, determinism leg: at `max_staleness = 0` the async
/// pipeline is byte-identical to the synchronous `TenantTrainer` — final
/// theta bits, every StepRecord field, and the RunLog rows modulo wall
/// times — at every (devices, workers, optimizer_threads) combination.
/// Along the way every importance ratio is exactly 1.0 and nothing is
/// ever clipped: at staleness 0 the behavior policy IS the current
/// policy, and the sim guarantees rollout log-probs equal trainer
/// log-probs bit for bit.
#[test]
fn pipeline_staleness_zero_is_byte_identical_to_sync_trainer() {
    const TENANTS: u64 = 4;
    const STEPS: u64 = 3;
    let rt_ref = Runtime::sim(1).unwrap();
    let b = rt_ref.manifest.batch.test;
    let ckpt = scratch("pipeline_sync");
    let mut tt_ref =
        TenantTrainer::with_batch(&rt_ref, &base_weights(&rt_ref, 3), pipeline_specs(TENANTS), 2, &ckpt, b)
            .unwrap();
    let mut log_ref = RunLog::null();
    let ref_out = tt_ref.train(&rt_ref, &mut log_ref, true).unwrap();
    let ref_theta: Vec<Vec<u32>> = tt_ref
        .sessions
        .iter()
        .map(|s| s.lp.policy.theta.iter().map(|x| x.to_bits()).collect())
        .collect();
    let ref_rows = rows_modulo_wall(log_ref.rows);
    assert_eq!(ref_rows.len(), (TENANTS * STEPS) as usize);

    for (devices, workers, opt_threads) in [(1usize, 1usize, 1usize), (2, 4, 2), (2, 3, 8), (1, 2, 3)] {
        let rt = Runtime::sim(devices).unwrap();
        let mut tt =
            TenantTrainer::with_batch(&rt, &base_weights(&rt, 3), pipeline_specs(TENANTS), workers, &ckpt, b)
                .unwrap();
        let mut log = RunLog::null();
        let pcfg =
            PipelineConfig { max_staleness: 0, optimizer_threads: opt_threads, queue_cap: 0 };
        let (outcomes, stats) = train_async(&rt, &mut tt, &pcfg, &mut log, true).unwrap();
        let tag = format!("D={devices} workers={workers} opt={opt_threads}");

        // exact accounting: window 1 means on-policy everywhere
        assert_eq!(
            (stats.produced, stats.consumed, stats.dropped_stale, stats.max_version_gap),
            (TENANTS * STEPS, TENANTS * STEPS, 0, 0),
            "{tag}: staleness-0 accounting broken"
        );

        // theta bits
        for (i, sess) in tt.sessions.iter().enumerate() {
            let theta: Vec<u32> = sess.lp.policy.theta.iter().map(|x| x.to_bits()).collect();
            assert_eq!(theta, ref_theta[i], "{tag}: tenant {i} theta diverged from sync");
        }

        // StepRecord bits (minus wall times) + the exact-1.0 ratio claim
        for (i, (sync_o, async_o)) in ref_out.iter().zip(&outcomes).enumerate() {
            assert_eq!(sync_o.steps.len(), async_o.steps.len(), "{tag}: tenant {i} step count");
            for (a, x) in sync_o.steps.iter().zip(&async_o.steps) {
                assert_eq!(
                    record_bits(a),
                    record_bits(x),
                    "{tag}: tenant {i} step {} diverged from sync",
                    a.step
                );
                assert_eq!(
                    x.stats.mean_ratio.to_bits(),
                    1.0f32.to_bits(),
                    "{tag}: tenant {i} step {}: importance ratio not exactly 1.0",
                    x.step
                );
                assert_eq!(
                    x.stats.frac_clipped, 0.0,
                    "{tag}: tenant {i} step {}: on-policy step clipped tokens",
                    x.step
                );
            }
        }
        assert_eq!(stats.mean_ratio, 1.0, "{tag}: pooled mean ratio not exactly 1.0");

        // RunLog rows modulo wall times
        assert_eq!(rows_modulo_wall(log.rows), ref_rows, "{tag}: RunLog rows diverged from sync");
    }
}

/// ISSUE 10 acceptance, staleness leg: `queue_cap > max_staleness + 1`
/// deliberately overproduces — every group beyond the staleness window is
/// dropped at consume time, exactly accounted (`produced == consumed +
/// dropped_stale`), every tenant still lands precisely on its step
/// target with contiguous step numbers, and the whole drop pattern is
/// deterministic (two runs bit-identical).
#[test]
fn pipeline_overproduce_drops_are_exactly_accounted() {
    const TENANTS: u64 = 3;
    const STEPS: u64 = 4;
    let run = || {
        let rt = Runtime::sim(2).unwrap();
        let b = rt.manifest.batch.test;
        let mut specs = pipeline_specs(TENANTS);
        for s in &mut specs {
            s.cfg.steps = STEPS as usize;
        }
        let mut tt =
            TenantTrainer::with_batch(&rt, &base_weights(&rt, 3), specs, 2, &scratch("pipeline_drop"), b)
                .unwrap();
        let pcfg = PipelineConfig { max_staleness: 0, optimizer_threads: 2, queue_cap: 3 };
        let (outcomes, stats) =
            train_async(&rt, &mut tt, &pcfg, &mut RunLog::null(), true).unwrap();
        let theta: Vec<Vec<u32>> = tt
            .sessions
            .iter()
            .map(|s| s.lp.policy.theta.iter().map(|x| x.to_bits()).collect())
            .collect();
        (outcomes, stats, theta)
    };

    let (outcomes, stats, theta_a) = run();
    assert_eq!(stats.consumed, TENANTS * STEPS, "every tenant must reach its target");
    assert!(stats.dropped_stale > 0, "queue_cap 3 at staleness 0 must overproduce and drop");
    assert_eq!(
        stats.produced,
        stats.consumed + stats.dropped_stale,
        "a produced group is either trained on or counted as dropped — never lost"
    );
    for (i, o) in outcomes.iter().enumerate() {
        assert_eq!(o.steps.len(), STEPS as usize, "tenant {i} missed steps under drops");
        for (k, r) in o.steps.iter().enumerate() {
            assert_eq!(r.step, k, "tenant {i}: non-contiguous step numbers under drops");
        }
    }

    let (_, stats_b, theta_b) = run();
    assert_eq!(theta_a, theta_b, "overproduce drop pattern is not deterministic");
    assert_eq!(stats.dropped_stale, stats_b.dropped_stale, "drop counts differ across runs");
}

/// ISSUE 10 satellite: killing a session strictly MID-warmup and resuming
/// must replay the warmup LR ramp from the restored step counter, not
/// restart it — every post-resume record (LR included) and the final
/// theta are bit-identical to the uninterrupted run, and the resumed LRs
/// match `lr_at` evaluated at the true global step.
#[test]
fn resume_mid_warmup_replays_lr_schedule_bit_identical() {
    let rt = Runtime::sim(1).unwrap();
    let b = rt.manifest.batch.test;
    let base = base_weights(&rt, 3);
    let ckpt = scratch("resume_warmup");
    const WARMUP: u64 = 4;
    const KILL_AT: usize = 2; // strictly inside the ramp: 2 < 4
    let cfg = || GrpoConfig {
        group: 2,
        steps: 6,
        lr: 5e-3,
        warmup: WARMUP,
        seed: 33,
        ..Default::default()
    };
    let mk = |steps: usize| -> TrainSession<GrpoLoop> {
        let policy = Policy::new(&rt, SIM_TIER, SIM_SCHEME, "grpo", base.clone(), 33, &ckpt).unwrap();
        let mut c = cfg();
        c.steps = steps;
        let scfg = grpo_session_cfg(&c);
        TrainSession::new(GrpoLoop::with_batch(&rt, policy, c, b).unwrap(), scfg)
    };

    let mut full = mk(6);
    let full_recs = full.run(&rt, &mut RunLog::null()).unwrap();
    let full_theta: Vec<u32> = full.lp.policy.theta.iter().map(|x| x.to_bits()).collect();
    // the scenario is real: the kill point sits strictly inside the ramp
    assert!(full_recs[KILL_AT].lr < cfg().lr, "step {KILL_AT} must still be warming up");

    let mut half = mk(KILL_AT);
    half.run(&rt, &mut RunLog::null()).unwrap();
    let state_path = ckpt.join("grpo_warmup.trainstate");
    half.state().save(&state_path).unwrap();
    drop(half);

    let st = TrainState::load(&state_path).unwrap();
    assert_eq!(st.step, KILL_AT as u64);
    let policy = Policy::new(&rt, SIM_TIER, SIM_SCHEME, "grpo", base.clone(), 33, &ckpt).unwrap();
    let lp = GrpoLoop::with_batch(&rt, policy, cfg(), b).unwrap();
    let mut resumed = TrainSession::resume(&rt, lp, grpo_session_cfg(&cfg()), &st).unwrap();
    let resumed_recs = resumed.run(&rt, &mut RunLog::null()).unwrap();
    assert_eq!(resumed_recs.len(), 6 - KILL_AT);

    for (a, x) in full_recs[KILL_AT..].iter().zip(&resumed_recs) {
        assert_eq!(record_bits(a), record_bits(x), "post-resume step {} diverged", a.step);
        // the regression this test pins: the replayed LR is the schedule
        // at the GLOBAL step, not a ramp restarted from zero
        assert_eq!(
            x.lr.to_bits(),
            lr_at(cfg().lr, WARMUP, x.step as u64).to_bits(),
            "resumed step {} did not replay the warmup schedule",
            x.step
        );
    }
    let resumed_theta: Vec<u32> = resumed.lp.policy.theta.iter().map(|x| x.to_bits()).collect();
    assert_eq!(full_theta, resumed_theta, "final theta diverged after mid-warmup resume");
}

/// ISSUE 10 satellite: `experiments::rl_vs_sft_budget` is a first-class,
/// deterministic experiment — two fresh runs serialize byte-identical
/// JSON, rows come back in (scheme × [grpo, sft]) order, and recovery is
/// anchored on one shared reference accuracy.
#[test]
fn rl_vs_sft_budget_experiment_is_deterministic() {
    let run = || -> (String, f32) {
        let rt = Runtime::sim(1).unwrap();
        let base = base_weights(&rt, 3);
        let cfg = BudgetConfig {
            tier: SIM_TIER.into(),
            schemes: vec![SIM_SCHEME.into()],
            suite: "gsm8k-syn".into(),
            eval_suite: "gsm8k-syn".into(),
            steps: 2,
            eval_n: 4,
            seed: 5,
            reference_acc: 0.0,
        };
        let out =
            rl_vs_sft_budget(&rt, &base, &cfg, &scratch("budget"), &mut RunLog::null()).unwrap();
        assert_eq!(out.rows.len(), 2, "one grpo row + one sft row per scheme");
        assert_eq!(out.rows[0].algo, "grpo");
        assert_eq!(out.rows[1].algo, "sft");
        for row in &out.rows {
            assert!((0.0..=1.0).contains(&row.final_acc), "accuracy out of range: {row:?}");
            assert!(row.recovery.is_finite(), "recovery must be finite: {row:?}");
            assert_eq!(row.trainable_params, 13, "the paper's 13-parameter scheme");
            assert_eq!(row.update_bytes, 52, "13 f32 params at the experiment default precision");
        }
        (out.to_json().to_string(), out.reference_acc)
    };
    let (a, ref_a) = run();
    let (b, ref_b) = run();
    assert_eq!(a, b, "rl_vs_sft_budget JSON not byte-identical across runs");
    assert_eq!(ref_a.to_bits(), ref_b.to_bits());
    assert!(a.contains("\"kind\":\"rl_vs_sft_budget\""));
}

/// ISSUE 10 tentpole, population leg: successive halving over an
/// lr × seed grid runs THROUGH the async pipeline — rung populations
/// shrink by the keep fraction, frozen losers stop exactly at their cut
/// step (the pipeline's per-tenant targets freeze them), the winner
/// finishes every rung, and the whole outcome is deterministic.
#[test]
fn population_sweep_halves_and_freezes_losers_deterministically() {
    const RUNGS: usize = 3;
    const STEPS_PER_RUNG: usize = 2;
    let run = || {
        let rt = Runtime::sim(2).unwrap();
        let base = base_weights(&rt, 3);
        let cfg = SweepConfig {
            tier: SIM_TIER.into(),
            scheme_tag: SIM_SCHEME.into(),
            algo: "grpo".into(),
            suite: "gsm8k-syn".into(),
            steps: RUNGS * STEPS_PER_RUNG,
            lrs: vec![1e-3, 3e-3],
            seeds: vec![0, 1, 2],
            eval_suite: "gsm8k-syn".into(),
            eval_n: 0,
            workers: 2,
            batch: rt.manifest.batch.test,
        };
        let hcfg = HalvingConfig {
            rungs: RUNGS,
            steps_per_rung: STEPS_PER_RUNG,
            keep: 0.5,
            pipeline: PipelineConfig { max_staleness: 0, optimizer_threads: 2, queue_cap: 0 },
        };
        sweep_population(&rt, &base, &cfg, &hcfg, &scratch("population"), &mut RunLog::null())
            .unwrap()
    };

    let out = run();
    assert_eq!(out.population, 6);
    assert_eq!(out.rungs.len(), RUNGS);
    let actives: Vec<usize> = out.rungs.iter().map(|r| r.active).collect();
    let survivors: Vec<usize> = out.rungs.iter().map(|r| r.survivors).collect();
    assert_eq!(actives, vec![6, 3, 2], "keep=0.5 halving trajectory (ceil, min 1)");
    // the final rung never cuts — everyone who reached it finishes
    assert_eq!(survivors, vec![3, 2, 2]);
    // frozen losers stopped exactly at their cut; the winner ran them all
    let winner = &out.members[out.best];
    assert_eq!(winner.steps, RUNGS * STEPS_PER_RUNG, "winner must finish every rung");
    assert_eq!(winner.rungs_survived, RUNGS);
    for m in &out.members {
        assert_eq!(
            m.steps,
            (m.rungs_survived + usize::from(m.rungs_survived < RUNGS)) * STEPS_PER_RUNG,
            "member {} trained past its freeze point",
            m.name
        );
    }
    assert!(out.stats.consumed > 0 && out.stats.dropped_stale == 0);

    let again = run();
    assert_eq!(
        out.to_json().to_string(),
        again.to_json().to_string(),
        "population sweep JSON not byte-identical across runs"
    );
}
