//! Byte-precision packing of adapter updates (paper §6.5, Fig. 4).
//!
//! The experiment: when the constraint is the update size in *bytes* (e.g.
//! communicating deltas in distributed training), which precision wins?
//! We simulate storage/communication by quantize→dequantize round-trips:
//! the optimizer state stays f32, but the *applied/communicated* update
//! passes through the chosen precision.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    F32,
    Bf16,
    F16,
}

impl Precision {
    pub fn bytes(self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::Bf16 | Precision::F16 => 2,
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "f32" | "fp32" => Some(Precision::F32),
            "bf16" => Some(Precision::Bf16),
            "f16" | "fp16" => Some(Precision::F16),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "fp32",
            Precision::Bf16 => "bf16",
            Precision::F16 => "fp16",
        }
    }
}

/// f32 -> bf16 bits (round-to-nearest-even on the dropped mantissa).
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // never round a NaN: carry out of the mantissa would turn a
        // max-payload NaN into ±inf (or flip its sign bit). Truncate the
        // payload and force a quiet bit so the mantissa stays non-zero.
        return ((bits >> 16) as u16) | 0x0040;
    }
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x7fff + lsb);
    (rounded >> 16) as u16
}

pub fn bf16_bits_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// f32 -> IEEE binary16 bits (round-to-nearest-even, with denormal and
/// overflow handling).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x7f_ffff;

    if exp == 255 {
        // inf / nan
        return sign | 0x7c00 | if mant != 0 { 0x200 } else { 0 };
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7c00; // overflow -> inf
    }
    if unbiased >= -14 {
        // normal range
        let half_exp = ((unbiased + 15) as u16) << 10;
        let half_mant = (mant >> 13) as u16;
        let rem = mant & 0x1fff;
        let mut h = sign | half_exp | half_mant;
        if rem > 0x1000 || (rem == 0x1000 && (half_mant & 1) == 1) {
            h = h.wrapping_add(1); // may carry into the exponent — correct
        }
        h
    } else if unbiased >= -24 {
        // denormal: value = mant_full * 2^(unbiased-23); half ulp = 2^-24,
        // so half_mant = mant_full >> (-unbiased - 1)
        let shift = (-unbiased - 1) as u32; // 14..23
        let mant_full = mant | 0x80_0000;
        let half_mant = (mant_full >> shift) as u16;
        let rem = mant_full & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut h = sign | half_mant;
        if rem > half || (rem == half && (half_mant & 1) == 1) {
            h = h.wrapping_add(1);
        }
        h
    } else {
        sign // underflow -> 0
    }
}

pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // denormal: normalize (value = mant * 2^-24; after k left-shifts
            // the leading bit sits at 0x400 and the exponent is -14 - k)
            let mut e = 0i32;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x3ff;
            sign | (((112 + e + 1) as u32) << 23) | (m << 13)
        }
    } else if exp == 31 {
        sign | 0x7f80_0000 | (mant << 13)
    } else {
        sign | ((exp + 112) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Quantize a vector through `precision` and back (identity for f32).
pub fn roundtrip(xs: &[f32], precision: Precision) -> Vec<f32> {
    match precision {
        Precision::F32 => xs.to_vec(),
        Precision::Bf16 => xs.iter().map(|&x| bf16_bits_to_f32(f32_to_bf16_bits(x))).collect(),
        Precision::F16 => xs.iter().map(|&x| f16_bits_to_f32(f32_to_f16_bits(x))).collect(),
    }
}

/// Serialize to the wire format (what the paper counts as "update bytes").
pub fn pack(xs: &[f32], precision: Precision) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * precision.bytes());
    pack_into(xs, precision, &mut out);
    out
}

/// Append the wire format of `xs` to `out` — the allocation-free core of
/// [`pack`]; the serving cold tier packs records straight into its
/// contiguous arena through this.
pub fn pack_into(xs: &[f32], precision: Precision, out: &mut Vec<u8>) {
    out.reserve(xs.len() * precision.bytes());
    match precision {
        Precision::F32 => {
            for &x in xs {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        Precision::Bf16 => {
            for &x in xs {
                out.extend_from_slice(&f32_to_bf16_bits(x).to_le_bytes());
            }
        }
        Precision::F16 => {
            for &x in xs {
                out.extend_from_slice(&f32_to_f16_bits(x).to_le_bytes());
            }
        }
    }
}

pub fn unpack(bytes: &[u8], precision: Precision) -> Vec<f32> {
    match precision {
        Precision::F32 => bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect(),
        Precision::Bf16 => bytes
            .chunks_exact(2)
            .map(|c| bf16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])))
            .collect(),
        Precision::F16 => bytes
            .chunks_exact(2)
            .map(|c| f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;

    #[test]
    fn f32_roundtrip_is_identity() {
        let xs = [1.5, -2.25, 1e-8, 3e8];
        assert_eq!(roundtrip(&xs, Precision::F32), xs.to_vec());
    }

    #[test]
    fn bf16_known_values() {
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(1.0)), 1.0);
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(-2.0)), -2.0);
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(0.0)), 0.0);
        // bf16 keeps f32 range
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(3e38)).is_finite());
    }

    #[test]
    fn f16_known_values() {
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1.0)), 1.0);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(-0.5)), -0.5);
        assert_eq!(f16_bits_to_f32(0x3c00), 1.0);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff); // f16 max
        assert_eq!(f32_to_f16_bits(1e6), 0x7c00); // overflow -> inf
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn relative_error_bounds() {
        check("quantization error bounds", 300, |rng| {
            let x = rng.normal() * 10f32.powi(rng.range_i64(-3, 3) as i32);
            let bf = bf16_bits_to_f32(f32_to_bf16_bits(x));
            let fh = f16_bits_to_f32(f32_to_f16_bits(x));
            // bf16: 8 mantissa bits -> rel err <= 2^-8; f16: 11 bits, but
            // denormals below ~6e-5 lose precision gradually.
            if x.abs() > 1e-30 && (bf - x).abs() / x.abs() > 1.0 / 256.0 {
                return Err(format!("bf16 err too big for {x}"));
            }
            if x.abs() > 1e-3 && (fh - x).abs() / x.abs() > 1.0 / 1024.0 {
                return Err(format!("f16 err too big for {x}"));
            }
            Ok(())
        });
    }

    #[test]
    fn pack_unpack_roundtrip() {
        check("pack/unpack", 100, |rng| {
            let n = rng.below(50) as usize + 1;
            let xs: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            for p in [Precision::F32, Precision::Bf16, Precision::F16] {
                let bytes = pack(&xs, p);
                if bytes.len() != n * p.bytes() {
                    return Err("wrong byte count".into());
                }
                let back = unpack(&bytes, p);
                let direct = roundtrip(&xs, p);
                if back != direct {
                    return Err(format!("{p:?} mismatch"));
                }
            }
            Ok(())
        });
    }

    /// Pack→unpack must agree with the in-memory quantize roundtrip for
    /// arbitrary bit patterns — including NaNs (any payload), infinities,
    /// denormals and signed zeros — across every `Precision` variant.
    /// Comparison is on bits, which is NaN-safe.
    #[test]
    fn pack_unpack_roundtrip_all_bit_patterns() {
        check("pack/unpack arbitrary bits", 500, |rng| {
            let n = rng.below(20) as usize + 1;
            let xs: Vec<f32> = (0..n).map(|_| f32::from_bits(rng.next_u64() as u32)).collect();
            for p in [Precision::F32, Precision::Bf16, Precision::F16] {
                let bytes = pack(&xs, p);
                if bytes.len() != n * p.bytes() {
                    return Err(format!("{p:?}: wrong byte count"));
                }
                let back = unpack(&bytes, p);
                let direct = roundtrip(&xs, p);
                for (i, (&b, &d)) in back.iter().zip(&direct).enumerate() {
                    if b.to_bits() != d.to_bits() {
                        return Err(format!(
                            "{p:?} idx {i}: wire {b:?} != roundtrip {d:?} (src bits {:#010x})",
                            xs[i].to_bits()
                        ));
                    }
                }
                // specials must survive quantization classwise
                for (&x, &b) in xs.iter().zip(&back) {
                    if x.is_nan() && !b.is_nan() {
                        return Err(format!("{p:?}: NaN {:#010x} became {b}", x.to_bits()));
                    }
                    if x.is_infinite() && (!b.is_infinite() || b.signum() != x.signum()) {
                        return Err(format!("{p:?}: {x} became {b}"));
                    }
                }
            }
            Ok(())
        });
    }

    /// `pack_into` appends to existing bytes and matches `pack` exactly.
    #[test]
    fn pack_into_appends_and_matches_pack() {
        let xs = [1.0f32, -2.5, f32::NAN, 0.0];
        for p in [Precision::F32, Precision::Bf16, Precision::F16] {
            let mut out = vec![0xAB, 0xCD];
            pack_into(&xs, p, &mut out);
            assert_eq!(&out[..2], &[0xAB, 0xCD]);
            assert_eq!(&out[2..], pack(&xs, p).as_slice());
        }
    }

    /// Regression: max-payload NaNs used to round into ±inf / -0.0 in bf16.
    #[test]
    fn bf16_adversarial_nan_payloads_stay_nan() {
        for bits in [0x7fff_ffffu32, 0xffff_ffff, 0x7f80_0001, 0xff80_ffff, 0x7fc0_0000] {
            let x = f32::from_bits(bits);
            assert!(x.is_nan());
            let y = bf16_bits_to_f32(f32_to_bf16_bits(x));
            assert!(y.is_nan(), "NaN {bits:#010x} became {y}");
        }
        // infinities are exact in bf16
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(f32::INFINITY)), f32::INFINITY);
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NEG_INFINITY)), f32::NEG_INFINITY);
    }

    /// The paper's headline config: 13 params pack to exactly 26 bytes at
    /// bf16, and NaN/inf theta values survive the wire format.
    #[test]
    fn headline_13_param_update_is_26_bytes_even_with_specials() {
        let mut theta = [0.1f32; 13];
        theta[3] = f32::NAN;
        theta[7] = f32::INFINITY;
        theta[11] = f32::NEG_INFINITY;
        for p in [Precision::Bf16, Precision::F16] {
            let bytes = pack(&theta, p);
            assert_eq!(bytes.len(), 26, "{p:?}");
            let back = unpack(&bytes, p);
            assert_eq!(back.len(), 13);
            assert!(back[3].is_nan());
            assert_eq!(back[7], f32::INFINITY);
            assert_eq!(back[11], f32::NEG_INFINITY);
        }
        assert_eq!(pack(&theta, Precision::F32).len(), 52);
    }

    #[test]
    fn f16_denormals_roundtrip() {
        for x in [6e-5f32, 1e-5, 6e-8, -3e-6] {
            let y = f16_bits_to_f32(f32_to_f16_bits(x));
            assert!((y - x).abs() <= 6e-8 + x.abs() * 0.01, "{x} -> {y}");
        }
    }
}
