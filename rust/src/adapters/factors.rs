//! Frozen SVD factor sets: per adapted module, the truncated-SVD factors
//! (Us = U·Σ, Vf = V) of the *pretrained* weight, stacked over layers in the
//! manifest's order (us_q, vf_q, us_k, vf_k, ...).  Computed once per
//! (checkpoint, rank) and cached on disk next to the checkpoint.

use std::path::Path;

use anyhow::{bail, Result};

use crate::adapters::svd::truncated_svd;
use crate::manifest::TierInfo;
use crate::tensor::{Arg, TensorF32};
use crate::util::fnv1a;
use crate::weights::WeightSet;

/// The seven adapted modules, in manifest order, with their weight-tensor names.
pub const MODULES: [(&str, &str); 7] = [
    ("q", "attn_q"),
    ("k", "attn_k"),
    ("v", "attn_v"),
    ("o", "attn_o"),
    ("up", "mlp_up"),
    ("gate", "mlp_gate"),
    ("down", "mlp_down"),
];

/// Stable fingerprint of the adapted weight tensors — the same hash
/// [`FactorSet::cached`] keys its disk cache with, exposed so callers
/// (the serving store) can memoize factor sets in memory per base model
/// without recomputing or re-reading them.
pub fn weights_fingerprint(weights: &WeightSet) -> Result<u64> {
    let mut h = 0u64;
    for (_, wname) in MODULES {
        let t = weights.get(wname)?;
        let bytes =
            unsafe { std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4) };
        h ^= fnv1a(bytes);
    }
    Ok(h)
}

#[derive(Clone)]
pub struct FactorSet {
    pub r: usize,
    /// interleaved per module: [us_q, vf_q, us_k, vf_k, ...]
    pub tensors: Vec<TensorF32>,
}

impl FactorSet {
    /// Compute factors from pretrained weights at rank r.
    pub fn compute(tier: &TierInfo, weights: &WeightSet, r: usize) -> Result<Self> {
        let mut tensors = Vec::with_capacity(14);
        for (mname, wname) in MODULES {
            let w = weights.get(wname)?;
            let &(d_in, d_out) = tier
                .module_dims
                .get(mname)
                .ok_or_else(|| anyhow::anyhow!("no module dims for {mname}"))?;
            if w.shape != vec![tier.n_layers, d_in, d_out] {
                bail!("{wname}: unexpected shape {:?}", w.shape);
            }
            let mut us = TensorF32::zeros(&[tier.n_layers, d_in, r]);
            let mut vf = TensorF32::zeros(&[tier.n_layers, d_out, r]);
            for l in 0..tier.n_layers {
                let mat = &w.data[l * d_in * d_out..(l + 1) * d_in * d_out];
                let seed = fnv1a(format!("{}/{}/{}/{}", tier.name, mname, l, r).as_bytes());
                let f = truncated_svd(mat, d_in, d_out, r, seed);
                us.data[l * d_in * r..(l + 1) * d_in * r].copy_from_slice(&f.us);
                vf.data[l * d_out * r..(l + 1) * d_out * r].copy_from_slice(&f.vf);
            }
            tensors.push(us);
            tensors.push(vf);
        }
        Ok(Self { r, tensors })
    }

    /// Load from cache or compute + cache. Cache key includes a hash of the
    /// adapted weights so stale factors are never reused.
    pub fn cached(
        tier: &TierInfo,
        weights: &WeightSet,
        r: usize,
        cache_dir: &Path,
    ) -> Result<Self> {
        let h = weights_fingerprint(weights)?;
        let path = cache_dir.join(format!("{}_r{}_{:016x}.factors", tier.name, r, h));
        if path.exists() {
            if let Ok(f) = Self::load(&path, tier, r) {
                return Ok(f);
            }
        }
        let f = Self::compute(tier, weights, r)?;
        f.save(&path).ok(); // cache failure is not fatal
        Ok(f)
    }

    fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = Vec::new();
        for t in &self.tensors {
            let bytes = unsafe {
                std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
            };
            out.extend_from_slice(bytes);
        }
        std::fs::write(path, out)?;
        Ok(())
    }

    fn load(path: &Path, tier: &TierInfo, r: usize) -> Result<Self> {
        let bytes = std::fs::read(path)?;
        let mut tensors = Vec::with_capacity(14);
        let mut off = 0usize;
        for (mname, _) in MODULES {
            let &(d_in, d_out) = tier.module_dims.get(mname).unwrap();
            for dim in [d_in, d_out] {
                let shape = vec![tier.n_layers, dim, r];
                let numel: usize = shape.iter().product();
                let end = off + numel * 4;
                if end > bytes.len() {
                    bail!("factor cache truncated");
                }
                let mut data = vec![0f32; numel];
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        bytes[off..end].as_ptr(),
                        data.as_mut_ptr() as *mut u8,
                        numel * 4,
                    );
                }
                tensors.push(TensorF32::from_vec(&shape, data));
                off = end;
            }
        }
        if off != bytes.len() {
            bail!("factor cache has trailing bytes");
        }
        Ok(Self { r, tensors })
    }

    /// Factor tensors as runtime args (manifest order).
    pub fn args(&self) -> Vec<Arg> {
        self.tensors.iter().map(|t| Arg::F32(t.clone())).collect()
    }
}
