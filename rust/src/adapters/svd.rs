//! Truncated SVD via randomized subspace iteration — the substrate that
//! produces TinyLoRA / LoRA-XS's frozen factors (Us = U·Σ, Vf = V) from the
//! pretrained weights.  No LAPACK in the image, so this is built from
//! scratch: power iteration for the range, then a Jacobi eigensolver on the
//! small projected Gram matrix.
//!
//! Matrices are row-major flat `Vec<f32>`.

use crate::util::Pcg64;

/// Result of `truncated_svd`: w ≈ us · vf^T with us = U·Σ [m,r], vf = V [n,r].
pub struct SvdFactors {
    pub us: Vec<f32>, // [m, r]
    pub vf: Vec<f32>, // [n, r]
    pub singular_values: Vec<f32>,
}

/// y[m,k] = a[m,n] * b[n,k]
fn matmul(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; m * k];
    for i in 0..m {
        for l in 0..n {
            let av = a[i * n + l];
            if av == 0.0 {
                continue;
            }
            let brow = &b[l * k..(l + 1) * k];
            let yrow = &mut y[i * k..(i + 1) * k];
            for j in 0..k {
                yrow[j] += av * brow[j];
            }
        }
    }
    y
}

/// y[n,k] = a^T[n,m] * b[m,k] where a is [m,n]
fn matmul_tn(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; n * k];
    for l in 0..m {
        let arow = &a[l * n..(l + 1) * n];
        let brow = &b[l * k..(l + 1) * k];
        for i in 0..n {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let yrow = &mut y[i * k..(i + 1) * k];
            for j in 0..k {
                yrow[j] += av * brow[j];
            }
        }
    }
    y
}

/// Orthonormalize the columns of y [m, q] in place: modified Gram-Schmidt
/// with re-orthogonalization ("twice is enough", Kahan) — a single pass in
/// f32 loses orthogonality catastrophically when the sketch hits a
/// rank-deficient W and later columns become near-dependent.
fn orthonormalize(y: &mut [f32], m: usize, q: usize) {
    for j in 0..q {
        for _pass in 0..2 {
            for i in 0..j {
                let mut dot = 0.0f32;
                for row in 0..m {
                    dot += y[row * q + i] * y[row * q + j];
                }
                for row in 0..m {
                    y[row * q + j] -= dot * y[row * q + i];
                }
            }
        }
        let mut norm = 0.0f32;
        for row in 0..m {
            norm += y[row * q + j] * y[row * q + j];
        }
        let norm = norm.sqrt().max(1e-12);
        for row in 0..m {
            y[row * q + j] /= norm;
        }
    }
}

/// Cyclic Jacobi eigendecomposition of a small symmetric matrix s [q, q].
/// Returns (eigenvalues desc, eigenvectors as columns of v [q, q]).
pub fn jacobi_eigh(s: &[f32], q: usize) -> (Vec<f32>, Vec<f32>) {
    let mut a: Vec<f64> = s.iter().map(|&x| x as f64).collect();
    let mut v = vec![0.0f64; q * q];
    for i in 0..q {
        v[i * q + i] = 1.0;
    }
    for _sweep in 0..60 {
        let mut off = 0.0f64;
        for p in 0..q {
            for r in (p + 1)..q {
                off += a[p * q + r] * a[p * q + r];
            }
        }
        if off < 1e-22 {
            break;
        }
        for p in 0..q {
            for r in (p + 1)..q {
                let apq = a[p * q + r];
                if apq.abs() < 1e-30 {
                    continue;
                }
                // classic symmetric Jacobi rotation zeroing a[p][r]
                let app = a[p * q + p];
                let aqq = a[r * q + r];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let sn = t * c;
                a[p * q + p] = app - t * apq;
                a[r * q + r] = aqq + t * apq;
                a[p * q + r] = 0.0;
                a[r * q + p] = 0.0;
                for k in 0..q {
                    if k == p || k == r {
                        continue;
                    }
                    let akp = a[k * q + p];
                    let akq = a[k * q + r];
                    a[k * q + p] = c * akp - sn * akq;
                    a[p * q + k] = a[k * q + p];
                    a[k * q + r] = sn * akp + c * akq;
                    a[r * q + k] = a[k * q + r];
                }
                for k in 0..q {
                    let vkp = v[k * q + p];
                    let vkq = v[k * q + r];
                    v[k * q + p] = c * vkp - sn * vkq;
                    v[k * q + r] = sn * vkp + c * vkq;
                }
            }
        }
    }
    let mut idx: Vec<usize> = (0..q).collect();
    idx.sort_by(|&i, &j| a[j * q + j].partial_cmp(&a[i * q + i]).unwrap());
    let evals: Vec<f32> = idx.iter().map(|&i| a[i * q + i].max(0.0) as f32).collect();
    let mut evecs = vec![0.0f32; q * q];
    for (new, &old) in idx.iter().enumerate() {
        for k in 0..q {
            evecs[k * q + new] = v[k * q + old] as f32;
        }
    }
    (evals, evecs)
}

/// Randomized truncated SVD of w [m, n] to rank r.
pub fn truncated_svd(w: &[f32], m: usize, n: usize, r: usize, seed: u64) -> SvdFactors {
    assert_eq!(w.len(), m * n);
    let r = r.min(m).min(n);
    let oversample = 4.min(m.min(n) - r);
    let q = r + oversample;
    let iters = 6;

    let mut rng = Pcg64::with_stream(seed, 0x737664);
    // range finder: Y = W * G, then power iterations
    let g = rng.normal_vec(n * q, 1.0);
    let mut y = matmul(w, &g, m, n, q);
    orthonormalize(&mut y, m, q);
    for _ in 0..iters {
        let mut z = matmul_tn(w, &y, m, n, q); // [n, q]
        orthonormalize(&mut z, n, q);
        y = matmul(w, &z, m, n, q); // [m, q]
        orthonormalize(&mut y, m, q);
    }
    // b = Y^T W  [q, n]
    let b = matmul_tn(&y, w, m, q, n);
    // eigendecomposition of b b^T [q, q]
    let mut bbt = vec![0.0f32; q * q];
    for i in 0..q {
        for j in 0..q {
            let mut dot = 0.0f32;
            for k in 0..n {
                dot += b[i * n + k] * b[j * n + k];
            }
            bbt[i * q + j] = dot;
        }
    }
    let (evals, u_small) = jacobi_eigh(&bbt, q);
    let sv: Vec<f32> = evals.iter().take(r).map(|&e| e.sqrt()).collect();

    // U = Y * U_small  [m, q] -> take r cols; us = U * diag(sv)
    let u_full = matmul(&y, &u_small, m, q, q);
    let mut us = vec![0.0f32; m * r];
    for i in 0..m {
        for j in 0..r {
            us[i * r + j] = u_full[i * q + j] * sv[j];
        }
    }
    // V^T = diag(1/sv) U_small^T B -> vf[n, r] = B^T U_small diag(1/sv)
    let mut vf = vec![0.0f32; n * r];
    for j in 0..r {
        let inv = if sv[j] > 1e-8 { 1.0 / sv[j] } else { 0.0 };
        for k in 0..n {
            let mut dot = 0.0f32;
            for i in 0..q {
                dot += b[i * n + k] * u_small[i * q + j];
            }
            vf[k * r + j] = dot * inv;
        }
    }
    SvdFactors { us, vf, singular_values: sv }
}

/// Frobenius norm of w - us vf^T (for tests / diagnostics).
pub fn residual_fro(w: &[f32], us: &[f32], vf: &[f32], m: usize, n: usize, r: usize) -> f32 {
    let mut acc = 0.0f64;
    for i in 0..m {
        for j in 0..n {
            let mut rec = 0.0f32;
            for k in 0..r {
                rec += us[i * r + k] * vf[j * r + k];
            }
            let d = (w[i * n + j] - rec) as f64;
            acc += d * d;
        }
    }
    acc.sqrt() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;

    fn fro(w: &[f32]) -> f32 {
        w.iter().map(|x| (x * x) as f64).sum::<f64>().sqrt() as f32
    }

    #[test]
    fn exact_recovery_of_low_rank() {
        check("svd recovers low-rank exactly", 20, |rng| {
            let (m, n) = (rng.range_i64(6, 40) as usize, rng.range_i64(6, 40) as usize);
            let true_r = rng.range_i64(1, 3) as usize;
            // w = sum of true_r outer products
            let mut w = vec![0.0f32; m * n];
            for _ in 0..true_r {
                let a = rng.normal_vec(m, 1.0);
                let b = rng.normal_vec(n, 1.0);
                for i in 0..m {
                    for j in 0..n {
                        w[i * n + j] += a[i] * b[j];
                    }
                }
            }
            let r = true_r + 1;
            let f = truncated_svd(&w, m, n, r, 42);
            let res = residual_fro(&w, &f.us, &f.vf, m, n, r.min(m).min(n));
            if res > 1e-2 * fro(&w).max(1.0) {
                return Err(format!("residual {res} vs |w| {}", fro(&w)));
            }
            Ok(())
        });
    }

    #[test]
    fn singular_values_sorted_and_nonneg() {
        let mut rng = Pcg64::new(1);
        let w = rng.normal_vec(30 * 20, 1.0);
        let f = truncated_svd(&w, 30, 20, 5, 7);
        for pair in f.singular_values.windows(2) {
            assert!(pair[0] >= pair[1] - 1e-4);
        }
        assert!(f.singular_values.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn residual_decreases_with_rank() {
        let mut rng = Pcg64::new(2);
        let (m, n) = (24, 16);
        let w = rng.normal_vec(m * n, 1.0);
        let mut prev = f32::INFINITY;
        for r in [1, 2, 4, 8] {
            let f = truncated_svd(&w, m, n, r, 3);
            let res = residual_fro(&w, &f.us, &f.vf, m, n, r);
            assert!(res <= prev + 1e-3, "rank {r}: {res} > {prev}");
            prev = res;
        }
    }

    #[test]
    fn vf_columns_orthonormal() {
        let mut rng = Pcg64::new(3);
        let (m, n, r) = (20, 14, 4);
        let w = rng.normal_vec(m * n, 1.0);
        let f = truncated_svd(&w, m, n, r, 5);
        for i in 0..r {
            for j in 0..r {
                let mut dot = 0.0f32;
                for k in 0..n {
                    dot += f.vf[k * r + i] * f.vf[k * r + j];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 2e-2, "v^T v [{i},{j}] = {dot}");
            }
        }
    }

    #[test]
    fn near_optimal_on_random_matrix() {
        // For an i.i.d. gaussian matrix, compare against the residual from
        // re-running with a different sketch seed — both should agree to a
        // few percent (randomized SVD with power iterations is near-exact).
        let mut rng = Pcg64::new(4);
        let (m, n, r) = (32, 24, 6);
        let w = rng.normal_vec(m * n, 1.0);
        let f1 = truncated_svd(&w, m, n, r, 1);
        let f2 = truncated_svd(&w, m, n, r, 999);
        let r1 = residual_fro(&w, &f1.us, &f1.vf, m, n, r);
        let r2 = residual_fro(&w, &f2.us, &f2.vf, m, n, r);
        assert!((r1 - r2).abs() / r1.max(1e-6) < 0.05, "{r1} vs {r2}");
    }
}
