//! Table 1 — trainable-parameter formulas per method.
//!
//! These are the paper's closed forms, computed from a tier's geometry.
//! An integration test asserts they agree with the manifest's `theta_size`
//! for every lowered artifact (python computes sizes independently).

use anyhow::{bail, Result};

use crate::manifest::TierInfo;

pub const N_MODULES: usize = 7; // q,k,v,o,up,gate,down

/// Full finetuning: every parameter.
pub fn full(tier: &TierInfo) -> usize {
    tier.n_params
}

/// LoRA at rank r over all adapted modules: sum of r*(d_in + d_out).
pub fn lora(tier: &TierInfo, r: usize) -> usize {
    tier.module_dims
        .values()
        .map(|&(di, dd)| tier.n_layers * r * (di + dd))
        .sum()
}

/// LoRA-XS: one r x r code per module -> n * m * r^2.
pub fn lora_xs(tier: &TierInfo, r: usize) -> usize {
    tier.n_layers * N_MODULES * r * r
}

/// TinyLoRA: u per *group*; groups determined by the tying plan. An
/// unknown plan name (these arrive from the manifest / CLI flags) is an
/// error, not a panic.
pub fn tinylora(tier: &TierInfo, u: usize, tie: &str, n_tie: usize) -> Result<usize> {
    Ok(n_groups(tier, tie, n_tie)? * u)
}

/// Number of distinct trainable vectors under a tying plan (mirrors
/// `Scheme.groups` in python/compile/configs.py).
pub fn n_groups(tier: &TierInfo, tie: &str, n_tie: usize) -> Result<usize> {
    let n = tier.n_layers * N_MODULES;
    Ok(match tie {
        "all" => 1,
        "none" => n,
        "tiled" => n.div_ceil(n_tie),
        "structured" => {
            let per_type = tier.n_layers.div_ceil(n_tie);
            N_MODULES * per_type
        }
        other => bail!("unknown tie plan {other:?} (all|none|tiled|structured)"),
    })
}

/// Flat module index (l * 7 + m) -> group id; mirror of python's
/// `Scheme.groups` (cross-checked against manifest `groups` in tests).
pub fn group_assignment(tier: &TierInfo, tie: &str, n_tie: usize) -> Result<Vec<usize>> {
    let n = tier.n_layers * N_MODULES;
    Ok(match tie {
        "all" => vec![0; n],
        "none" => (0..n).collect(),
        "tiled" => (0..n).map(|i| i / n_tie).collect(),
        "structured" => {
            let per_type = tier.n_layers.div_ceil(n_tie);
            let mut out = Vec::with_capacity(n);
            for l in 0..tier.n_layers {
                for m in 0..N_MODULES {
                    out.push(m * per_type + l / n_tie);
                }
            }
            out
        }
        other => bail!("unknown tie plan {other:?} (all|none|tiled|structured)"),
    })
}

/// Render the paper's Table 1 for a tier (used by the `info` CLI command).
pub fn table1(tier: &TierInfo) -> Result<String> {
    let mut s = String::new();
    s.push_str(&format!(
        "Table 1 — trainable parameters ({}: d={}, L={}, m={})\n",
        tier.name, tier.d, tier.n_layers, N_MODULES
    ));
    s.push_str(&format!("  {:<22} {:>12}\n", "method", "params"));
    s.push_str(&format!("  {:<22} {:>12}\n", "full FT", full(tier)));
    for r in [1, 8, 64] {
        s.push_str(&format!("  {:<22} {:>12}\n", format!("LoRA r={r}"), lora(tier, r)));
    }
    for r in [1, 2, 8] {
        s.push_str(&format!("  {:<22} {:>12}\n", format!("LoRA-XS r={r}"), lora_xs(tier, r)));
    }
    for (u, tie, n_tie, label) in [
        (1usize, "none", 1usize, "TinyLoRA u=1 untied"),
        (13, "all", 1, "TinyLoRA u=13 tied"),
        (1, "all", 1, "TinyLoRA u=1 tied"),
    ] {
        s.push_str(&format!(
            "  {:<22} {:>12}\n",
            label,
            tinylora(tier, u, tie, n_tie)?
        ));
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::TierInfo;
    use crate::testing::check;

    fn tier(l: usize, d: usize, f: usize) -> TierInfo {
        let mut module_dims = std::collections::BTreeMap::new();
        for m in ["q", "k", "v", "o"] {
            module_dims.insert(m.to_string(), (d, d));
        }
        module_dims.insert("up".into(), (d, f));
        module_dims.insert("gate".into(), (d, f));
        module_dims.insert("down".into(), (f, d));
        TierInfo {
            name: "t".into(),
            d,
            n_layers: l,
            n_heads: 2,
            f,
            t_max: 8,
            t_prefill: 4,
            t_train: 8,
            head_dim: d / 2,
            n_params: 12345,
            weights: vec![],
            module_dims,
        }
    }

    #[test]
    fn minimums_match_paper_table1() {
        let t = tier(3, 64, 128);
        // TinyLoRA minimum is ONE parameter (full tying, u=1)
        assert_eq!(tinylora(&t, 1, "all", 1).unwrap(), 1);
        // LoRA-XS minimum is one per module: n*m
        assert_eq!(lora_xs(&t, 1), 3 * 7);
        // LoRA r=1 is sum over modules of (d_in + d_out)
        assert_eq!(lora(&t, 1), 3 * (4 * 128 + 2 * 192 + 192));
    }

    #[test]
    fn the_13_param_config() {
        let t = tier(3, 64, 128);
        assert_eq!(tinylora(&t, 13, "all", 1).unwrap(), 13);
    }

    /// ISSUE 5 satellite: an unknown tie plan (manifest / CLI input) is a
    /// named error through every entry point, never a panic.
    #[test]
    fn unknown_tie_plan_is_an_error() {
        let t = tier(2, 32, 64);
        for res in [
            n_groups(&t, "diagonal", 1).map(|_| ()),
            group_assignment(&t, "diagonal", 1).map(|_| ()),
            tinylora(&t, 13, "diagonal", 1).map(|_| ()),
        ] {
            let msg = format!("{:#}", res.unwrap_err());
            assert!(msg.contains("unknown tie plan"), "{msg}");
            assert!(msg.contains("diagonal"), "{msg}");
        }
        // the valid plans still resolve, and table1 renders
        for tie in ["all", "none", "tiled", "structured"] {
            n_groups(&t, tie, 2).unwrap();
        }
        assert!(table1(&t).unwrap().contains("TinyLoRA u=13 tied"));
    }

    #[test]
    fn group_assignment_properties() {
        check("groups partition modules", 200, |rng| {
            let l = rng.range_i64(1, 8) as usize;
            let t = tier(l, 32, 64);
            let tie = *rng.choice(&["all", "none", "tiled", "structured"]);
            let n_tie = rng.range_i64(1, 9) as usize;
            let gs = group_assignment(&t, tie, n_tie).unwrap();
            if gs.len() != l * N_MODULES {
                return Err("wrong length".into());
            }
            let max = *gs.iter().max().unwrap();
            if max + 1 != n_groups(&t, tie, n_tie).unwrap() {
                return Err(format!(
                    "max {} vs n_groups {}",
                    max,
                    n_groups(&t, tie, n_tie).unwrap()
                ));
            }
            // group ids must be contiguous 0..=max
            let mut seen = vec![false; max + 1];
            for &g in &gs {
                seen[g] = true;
            }
            if !seen.iter().all(|&b| b) {
                return Err("non-contiguous group ids".into());
            }
            // tying monotonicity: larger n_tie never increases group count
            if tie == "tiled" || tie == "structured" {
                let g2 = n_groups(&t, tie, n_tie + 1).unwrap();
                if g2 > n_groups(&t, tie, n_tie).unwrap() {
                    return Err("n_tie+1 increased groups".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn structured_shares_within_type_only() {
        let t = tier(4, 32, 64);
        let gs = group_assignment(&t, "structured", 2).unwrap();
        // modules of different types never share a group
        for l1 in 0..4 {
            for l2 in 0..4 {
                for m1 in 0..N_MODULES {
                    for m2 in 0..N_MODULES {
                        if m1 != m2 {
                            assert_ne!(gs[l1 * 7 + m1], gs[l2 * 7 + m2]);
                        }
                    }
                }
            }
        }
        // layers 0,1 share; 2,3 share; 0,2 do not
        assert_eq!(gs[0], gs[7]);
        assert_ne!(gs[0], gs[14]);
    }

    #[test]
    fn tiled_shares_across_types() {
        let t = tier(2, 32, 64);
        let gs = group_assignment(&t, "tiled", 7).unwrap();
        // first 7 modules (layer 0) share one group regardless of type
        assert!(gs[..7].iter().all(|&g| g == gs[0]));
        assert!(gs[7..14].iter().all(|&g| g == gs[7]));
        assert_ne!(gs[0], gs[7]);
    }
}
