//! Adapter algebra: flat trainable vectors (theta), Table-1 parameter
//! counting, byte-precision packing (Fig. 4), and the frozen SVD factors
//! (Us, Vf) that TinyLoRA / LoRA-XS freeze.

pub mod count;
pub mod factors;
pub mod packing;
pub mod svd;

use anyhow::{bail, Result};

use crate::manifest::{ExeInfo, ThetaSegment};
use crate::util::Pcg64;

/// A flat trainable vector plus its segment table (from the manifest).
#[derive(Clone, Debug)]
pub struct Theta {
    pub data: Vec<f32>,
    pub segments: Vec<ThetaSegment>,
}

impl Theta {
    /// Initialize from an executable's theta segment table: zeros / normal
    /// per segment (LoRA A is random, B zero; tinylora/lora-xs start at 0 so
    /// every scheme starts exactly at the base model).
    pub fn init(exe: &ExeInfo, seed: u64) -> Result<Self> {
        let Some(size) = exe.theta_size else {
            bail!("{} has no theta (full-FT scheme?)", exe.name);
        };
        let mut rng = Pcg64::with_stream(seed, 0x7468657461);
        let mut data = vec![0.0f32; size];
        for seg in &exe.theta_segments {
            match seg.init.kind.as_str() {
                "zeros" => {}
                "normal" => {
                    for x in &mut data[seg.offset..seg.offset + seg.len] {
                        *x = rng.normal() * seg.init.std;
                    }
                }
                "from_checkpoint" => bail!("full scheme theta comes from the weight set"),
                other => bail!("unknown init {other}"),
            }
        }
        Ok(Self { data, segments: exe.theta_segments.clone() })
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Bytes of one update at a given storage precision (paper's Fig. 1/4
    /// x-axis: update *size*).
    pub fn update_bytes(&self, precision: packing::Precision) -> usize {
        self.len() * precision.bytes()
    }

    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{ArgSpec, DType, InitSpec};

    fn exe_with_segments(segs: Vec<ThetaSegment>) -> ExeInfo {
        let size = segs.iter().map(|s| s.len).sum();
        ExeInfo {
            name: "test".into(),
            file: String::new(),
            fn_kind: "grpo".into(),
            tier: "nano".into(),
            batch: 1,
            seq: 8,
            use_pallas: false,
            inputs: vec![ArgSpec { name: "x".into(), dtype: DType::F32, shape: vec![1] }],
            outputs: vec![],
            scheme: None,
            scheme_tag: None,
            theta_size: Some(size),
            theta_segments: segs,
            groups: vec![],
        }
    }

    #[test]
    fn init_zeros_and_normal() {
        let exe = exe_with_segments(vec![
            ThetaSegment {
                name: "v".into(),
                shape: vec![4],
                offset: 0,
                len: 4,
                init: InitSpec { kind: "zeros".into(), std: 0.0 },
            },
            ThetaSegment {
                name: "a".into(),
                shape: vec![6],
                offset: 4,
                len: 6,
                init: InitSpec { kind: "normal".into(), std: 0.1 },
            },
        ]);
        let th = Theta::init(&exe, 1).unwrap();
        assert_eq!(th.len(), 10);
        assert!(th.data[..4].iter().all(|&x| x == 0.0));
        assert!(th.data[4..].iter().any(|&x| x != 0.0));
        // deterministic
        assert_eq!(th.data, Theta::init(&exe, 1).unwrap().data);
    }
}
