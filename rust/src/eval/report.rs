//! Recovery-fraction reports — the paper's headline table: what fraction
//! of a reference run's improvement over the base model each adapter
//! recovers, keyed by trained-parameter count ("90% of the improvement
//! with 1000x fewer trained parameters").
//!
//! A [`RecoveryReport`] stitches [`BenchRun`]s produced by
//! [`crate::eval::bench`]: one baseline (the untrained base model), one
//! reference anchoring 100% (typically the full-FT run), and any number of
//! adapter runs. Per suite,
//!
//! ```text
//! recovery = (acc_adapter - acc_base) / (acc_reference - acc_base)
//! ```
//!
//! on pass@1, with a degenerate (zero-improvement) reference defined as
//! fully recovered. Output is deterministic JSON plus a rendered markdown
//! table (golden-tested). The `report` CLI builds one from saved bench
//! JSON files; `experiments::recovery_report` builds one straight from
//! in-memory training outcomes.

use anyhow::{bail, Result};

use crate::eval::bench::BenchRun;
use crate::util::json::{num, obj, s, Value};

/// Baseline + reference + adapter runs over one shared suite set.
#[derive(Clone, Debug)]
pub struct RecoveryReport {
    /// the untrained base model (recovery 0% by definition)
    pub baseline: BenchRun,
    /// the run anchoring 100% recovery (full FT / the largest adapter)
    pub reference: BenchRun,
    /// adapter runs, sorted ascending by trained-parameter count
    pub adapters: Vec<BenchRun>,
}

impl RecoveryReport {
    /// Validates that every run shares the baseline's full protocol —
    /// suite set, k, decode seed and per-suite problem counts (mixed
    /// protocols make the fractions meaningless) — then sorts the
    /// adapters by trained-parameter count.
    pub fn new(
        baseline: BenchRun,
        reference: BenchRun,
        mut adapters: Vec<BenchRun>,
    ) -> Result<Self> {
        let want: Vec<(&str, usize)> =
            baseline.scores.iter().map(|x| (x.suite.as_str(), x.n)).collect();
        for run in adapters.iter().chain(std::iter::once(&reference)) {
            if run.tier != baseline.tier {
                bail!(
                    "backbone tier mismatch: {} ran on {}, baseline on {}",
                    run.name,
                    run.tier,
                    baseline.tier
                );
            }
            if run.k != baseline.k {
                bail!("bench k mismatch: {} has k={}, baseline k={}", run.name, run.k, baseline.k);
            }
            if run.seed != baseline.seed {
                bail!(
                    "decode seed mismatch: {} ran seed {}, baseline seed {} (different problem sets)",
                    run.name,
                    run.seed,
                    baseline.seed
                );
            }
            let got: Vec<(&str, usize)> =
                run.scores.iter().map(|x| (x.suite.as_str(), x.n)).collect();
            if got != want {
                bail!(
                    "suite/budget mismatch: {} ran {:?}, baseline ran {:?}",
                    run.name,
                    got,
                    want
                );
            }
        }
        adapters.sort_by_key(|r| r.params);
        Ok(Self { baseline, reference, adapters })
    }

    /// Fraction of the reference improvement recovered on suite `si`
    /// (pass@1). A reference that did not improve counts as recovered.
    pub fn recovery(&self, run: &BenchRun, si: usize) -> f32 {
        let base = self.baseline.scores[si].pass1;
        let full = self.reference.scores[si].pass1 - base;
        if full.abs() < 1e-6 {
            return 1.0;
        }
        (run.scores[si].pass1 - base) / full
    }

    /// Mean recovery across the suite set.
    pub fn mean_recovery(&self, run: &BenchRun) -> f32 {
        let n = self.baseline.scores.len().max(1) as f32;
        (0..self.baseline.scores.len()).map(|si| self.recovery(run, si)).sum::<f32>() / n
    }

    /// Deterministic JSON: the three run groups plus the derived recovery
    /// table, so consumers need no recomputation.
    pub fn to_json(&self) -> Value {
        let table: Vec<Value> = self
            .adapters
            .iter()
            .chain(std::iter::once(&self.reference))
            .map(|run| {
                obj(vec![
                    ("name", s(&run.name)),
                    ("params", num(run.params as f64)),
                    (
                        "per_suite",
                        Value::Arr(
                            (0..run.scores.len())
                                .map(|si| num(self.recovery(run, si) as f64))
                                .collect(),
                        ),
                    ),
                    ("mean", num(self.mean_recovery(run) as f64)),
                ])
            })
            .collect();
        obj(vec![
            ("kind", s("recovery_report")),
            ("baseline", self.baseline.to_json()),
            ("reference", self.reference.to_json()),
            ("adapters", Value::Arr(self.adapters.iter().map(|r| r.to_json()).collect())),
            ("recovery", Value::Arr(table)),
        ])
    }

    /// The paper's table, rendered (golden-tested — keep byte-stable):
    /// rows ordered by trained-parameter count, cells `pass@1 (recovery)`.
    pub fn to_markdown(&self) -> String {
        let mut out = format!(
            "## Recovery vs trained parameters (pass@1, k={}, seed {})\n\n| run | params |",
            self.baseline.k, self.baseline.seed
        );
        for sc in &self.baseline.scores {
            out.push_str(&format!(" {} |", sc.suite));
        }
        out.push_str(" mean recovery |\n|---|---|");
        for _ in &self.baseline.scores {
            out.push_str("---|");
        }
        out.push_str("---|\n");
        out.push_str(&format!("| {} | {} |", self.baseline.name, self.baseline.params));
        for sc in &self.baseline.scores {
            out.push_str(&format!(" {:.3} |", sc.pass1));
        }
        out.push_str(" — |\n");
        for run in self.adapters.iter().chain(std::iter::once(&self.reference)) {
            out.push_str(&format!("| {} | {} |", run.name, run.params));
            for si in 0..run.scores.len() {
                out.push_str(&format!(
                    " {:.3} ({:.0}%) |",
                    run.scores[si].pass1,
                    self.recovery(run, si) * 100.0
                ));
            }
            out.push_str(&format!(" {:.0}% |\n", self.mean_recovery(run) * 100.0));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::bench::SuiteScore;

    fn score(suite: &str, pass1: f32) -> SuiteScore {
        SuiteScore {
            suite: suite.into(),
            n: 16,
            k: 4,
            pass1,
            pass_k: pass1,
            maj_k: pass1,
            format_rate: 1.0,
            mean_response_len: 20.0,
        }
    }

    fn run(name: &str, params: usize, accs: &[(&str, f32)]) -> BenchRun {
        BenchRun {
            tier: "micro".into(),
            name: name.into(),
            params,
            k: 4,
            seed: 777,
            scores: accs.iter().map(|&(sname, a)| score(sname, a)).collect(),
            wall_secs: 0.0,
        }
    }

    #[test]
    fn recovery_fraction_math() {
        let report = RecoveryReport::new(
            run("base", 0, &[("gsm8k-syn", 0.40), ("aime-syn", 0.10)]),
            run("full", 139_000, &[("gsm8k-syn", 0.60), ("aime-syn", 0.10)]),
            vec![run("tinylora_r2_u13_all", 13, &[("gsm8k-syn", 0.58), ("aime-syn", 0.30)])],
        )
        .unwrap();
        let tiny = &report.adapters[0];
        assert!((report.recovery(tiny, 0) - 0.9).abs() < 1e-6);
        // degenerate reference (no improvement) counts as fully recovered
        assert_eq!(report.recovery(tiny, 1), 1.0);
        assert!((report.mean_recovery(tiny) - 0.95).abs() < 1e-6);
        // the reference recovers itself on the improving suite
        assert!((report.recovery(&report.reference, 0) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn adapters_sorted_by_params_and_mismatches_rejected() {
        let report = RecoveryReport::new(
            run("base", 0, &[("gsm8k-syn", 0.4)]),
            run("full", 1000, &[("gsm8k-syn", 0.6)]),
            vec![
                run("b", 13, &[("gsm8k-syn", 0.5)]),
                run("a", 1, &[("gsm8k-syn", 0.45)]),
            ],
        )
        .unwrap();
        assert_eq!(report.adapters[0].params, 1);
        assert_eq!(report.adapters[1].params, 13);

        // different suite set
        assert!(RecoveryReport::new(
            run("base", 0, &[("gsm8k-syn", 0.4)]),
            run("full", 1000, &[("aime-syn", 0.6)]),
            vec![],
        )
        .is_err());
        // different k
        let mut other_k = run("full", 1000, &[("gsm8k-syn", 0.6)]);
        other_k.k = 8;
        assert!(RecoveryReport::new(run("base", 0, &[("gsm8k-syn", 0.4)]), other_k, vec![])
            .is_err());
        // different backbone tier
        let mut other_tier = run("full", 1000, &[("gsm8k-syn", 0.6)]);
        other_tier.tier = "nano".into();
        assert!(RecoveryReport::new(run("base", 0, &[("gsm8k-syn", 0.4)]), other_tier, vec![])
            .is_err());
        // different decode seed (different problem sets)
        let mut other_seed = run("full", 1000, &[("gsm8k-syn", 0.6)]);
        other_seed.seed = 3;
        assert!(RecoveryReport::new(run("base", 0, &[("gsm8k-syn", 0.4)]), other_seed, vec![])
            .is_err());
        // different per-suite budget
        let mut other_n = run("full", 1000, &[("gsm8k-syn", 0.6)]);
        other_n.scores[0].n = 8;
        assert!(RecoveryReport::new(run("base", 0, &[("gsm8k-syn", 0.4)]), other_n, vec![])
            .is_err());
    }

    #[test]
    fn markdown_golden() {
        let report = RecoveryReport::new(
            run("base", 0, &[("gsm8k-syn", 0.40), ("aime-syn", 0.10)]),
            run("full", 139000, &[("gsm8k-syn", 0.60), ("aime-syn", 0.30)]),
            vec![run("tinylora_r2_u13_all", 13, &[("gsm8k-syn", 0.58), ("aime-syn", 0.25)])],
        )
        .unwrap();
        let want = "## Recovery vs trained parameters (pass@1, k=4, seed 777)\n\n\
                    | run | params | gsm8k-syn | aime-syn | mean recovery |\n\
                    |---|---|---|---|---|\n\
                    | base | 0 | 0.400 | 0.100 | — |\n\
                    | tinylora_r2_u13_all | 13 | 0.580 (90%) | 0.250 (75%) | 82% |\n\
                    | full | 139000 | 0.600 (100%) | 0.300 (100%) | 100% |\n";
        assert_eq!(report.to_markdown(), want);
    }

    #[test]
    fn json_contains_derived_table() {
        let report = RecoveryReport::new(
            run("base", 0, &[("gsm8k-syn", 0.4)]),
            run("full", 1000, &[("gsm8k-syn", 0.6)]),
            vec![run("tiny", 13, &[("gsm8k-syn", 0.5)])],
        )
        .unwrap();
        let v = report.to_json();
        assert_eq!(v.get("kind").unwrap().str().unwrap(), "recovery_report");
        let rows = v.get("recovery").unwrap().arr().unwrap();
        assert_eq!(rows.len(), 2); // adapter + reference
        assert!((rows[0].get("mean").unwrap().f64().unwrap() - 0.5).abs() < 1e-6);
        // deterministic: serializing twice is byte-identical
        assert_eq!(v.to_string(), report.to_json().to_string());
    }
}
