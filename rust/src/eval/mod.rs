//! Evaluation subsystem: held-out problem streams, greedy pass@1 scoring,
//! and the full benchmark ladder.
//!
//! Three layers, lowest first:
//!
//!   * this module — deterministic held-out problem streams
//!     ([`eval_problems`]; seed-disjoint from training by construction)
//!     and the paper's simplest protocol: greedy decode, exact-match
//!     pass@1 ([`evaluate`] / [`evaluate_suite_ladder`]);
//!   * [`bench`] — the benchmark subsystem: a registry of suites with
//!     per-suite decode budgets ([`bench::LADDER`]), k-way temperature
//!     sampling pooled across engine workers, and the unbiased
//!     pass@k / maj@k estimators (Tables 1–3);
//!   * [`report`] — recovery-fraction reports over several bench runs
//!     (the "90% of the improvement with 1000x fewer parameters" table).
//!
//! All decoding is a thin client of `engine::InferenceEngine`: chunking,
//! sentinel padding and EOS-cut/decode happen there; this subsystem owns
//! problem streams and score aggregation only.

pub mod bench;
pub mod report;

use anyhow::{anyhow, Result};

use crate::engine::InferenceEngine;
use crate::runtime::Runtime;
use crate::tasks::generator::{suite, Problem, SUITES};
use crate::tokenizer::Tokenizer;
use crate::util::Pcg64;
use crate::weights::WeightSet;

#[derive(Clone, Copy, Debug, Default)]
pub struct EvalResult {
    pub accuracy: f32,
    pub format_rate: f32,
    pub mean_response_len: f32,
    pub n: usize,
}

/// Deterministic held-out problem set for a suite (seed stream disjoint
/// from training by construction: trainers use stream 0x6772706f).
/// Unknown suite names are an error — never a silent fallback to the
/// first suite.
pub fn eval_problems(suite_name: &str, n: usize, seed: u64) -> Result<Vec<Problem>> {
    let s = suite(suite_name).ok_or_else(|| {
        anyhow!(
            "unknown eval suite {suite_name:?}; available: {:?}",
            SUITES.iter().map(|s| s.name).collect::<Vec<_>>()
        )
    })?;
    let mut rng = Pcg64::with_stream(seed, 0x6576616c);
    Ok((0..n).map(|_| s.generate(&mut rng)).collect())
}

/// Greedy-decode `n` held-out problems; exact-match accuracy.
pub fn evaluate(
    rt: &Runtime,
    tier: &str,
    weights: &WeightSet,
    suite_name: &str,
    n: usize,
    seed: u64,
) -> Result<EvalResult> {
    let engine = InferenceEngine::new(rt, tier, rt.manifest.batch.roll)?;
    evaluate_with(rt, &engine, weights, suite_name, n, seed)
}

/// Same as [`evaluate`] but reusing a caller-owned engine (drivers that
/// eval repeatedly avoid re-resolving the executable each call).
pub fn evaluate_with(
    rt: &Runtime,
    engine: &InferenceEngine,
    weights: &WeightSet,
    suite_name: &str,
    n: usize,
    seed: u64,
) -> Result<EvalResult> {
    let tok = Tokenizer::new();
    let problems = eval_problems(suite_name, n, seed)?;
    let mut rng = Pcg64::with_stream(seed, 0x65767231);
    let rows = engine.generate_problems(rt, weights, &problems, &tok, 0.0, &mut rng)?;

    let mut correct = 0usize;
    let mut fmt = 0usize;
    let mut len_sum = 0f32;
    for row in &rows {
        if row.reward > 0.5 {
            correct += 1;
        }
        if row.has_format {
            fmt += 1;
        }
        len_sum += row.response.len() as f32;
    }
    Ok(EvalResult {
        accuracy: correct as f32 / problems.len() as f32,
        format_rate: fmt as f32 / problems.len() as f32,
        mean_response_len: len_sum / problems.len() as f32,
        n: problems.len(),
    })
}

/// Evaluate across the full benchmark ladder (Table 2's columns).
pub fn evaluate_suite_ladder(
    rt: &Runtime,
    tier: &str,
    weights: &WeightSet,
    n_per_suite: usize,
    seed: u64,
) -> Result<Vec<(String, EvalResult)>> {
    let engine = InferenceEngine::new(rt, tier, rt.manifest.batch.roll)?;
    SUITES
        .iter()
        .map(|s| {
            Ok((
                s.name.to_string(),
                evaluate_with(rt, &engine, weights, s.name, n_per_suite, seed)?,
            ))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_problems_deterministic_and_distinct_from_training() {
        let a = eval_problems("gsm8k-syn", 10, 1).unwrap();
        let b = eval_problems("gsm8k-syn", 10, 1).unwrap();
        assert_eq!(a, b);
        let c = eval_problems("gsm8k-syn", 10, 2).unwrap();
        assert_ne!(a, c);
        // training stream (grpo::draw_problems) must not collide
        let mut rng = crate::util::Pcg64::with_stream(1, 0x6772706f);
        let t = crate::coordinator::grpo::draw_problems("gsm8k-syn", 10, &mut rng);
        assert_ne!(a, t);
    }

    #[test]
    fn unknown_suite_is_an_error_not_a_fallback() {
        let err = eval_problems("gsm8k", 4, 1).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown eval suite"), "{msg}");
        assert!(msg.contains("gsm8k-syn"), "should list available suites: {msg}");
    }
}
