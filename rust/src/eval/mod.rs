//! Evaluation harness: greedy decoding over held-out problem sets, exact-
//! match accuracy per suite (the paper's pass@1 protocol).

use anyhow::Result;

use crate::coordinator::rollout::RolloutEngine;
use crate::runtime::Runtime;
use crate::tasks::corpus::prompt_batch;
use crate::tasks::generator::{suite, Problem, SUITES};
use crate::tokenizer::Tokenizer;
use crate::util::Pcg64;
use crate::weights::WeightSet;

#[derive(Clone, Copy, Debug, Default)]
pub struct EvalResult {
    pub accuracy: f32,
    pub format_rate: f32,
    pub mean_response_len: f32,
    pub n: usize,
}

/// Deterministic held-out problem set for a suite (seed stream disjoint
/// from training by construction: trainers use stream 0x6772706f).
pub fn eval_problems(suite_name: &str, n: usize, seed: u64) -> Vec<Problem> {
    let s = suite(suite_name).unwrap_or(&SUITES[0]);
    let mut rng = Pcg64::with_stream(seed, 0x6576616c);
    (0..n).map(|_| s.generate(&mut rng)).collect()
}

/// Greedy-decode `n` held-out problems; exact-match accuracy.
pub fn evaluate(
    rt: &Runtime,
    tier: &str,
    weights: &WeightSet,
    suite_name: &str,
    n: usize,
    seed: u64,
) -> Result<EvalResult> {
    let engine = RolloutEngine::new(rt, tier, rt.manifest.batch.roll)?;
    let tok = Tokenizer::new();
    let problems = eval_problems(suite_name, n, seed);
    let mut rng = Pcg64::with_stream(seed, 0x65767231);

    let b = engine.batch;
    let mut correct = 0usize;
    let mut fmt = 0usize;
    let mut len_sum = 0f32;
    let mut done = 0usize;
    while done < problems.len() {
        let take = (problems.len() - done).min(b);
        let mut chunk: Vec<Problem> = problems[done..done + take].to_vec();
        // pad the final batch to the executable's baked size
        while chunk.len() < b {
            chunk.push(chunk[chunk.len() - 1].clone());
        }
        let pb = prompt_batch(&chunk, &tok, 1, engine.t_prefill);
        let roll = engine.rollout(rt, weights, &pb, &tok, 0.0, &mut rng)?;
        for row in roll.rows.iter().take(take) {
            if row.reward > 0.5 {
                correct += 1;
            }
            if row.has_format {
                fmt += 1;
            }
            len_sum += row.response.len() as f32;
        }
        done += take;
    }
    Ok(EvalResult {
        accuracy: correct as f32 / problems.len() as f32,
        format_rate: fmt as f32 / problems.len() as f32,
        mean_response_len: len_sum / problems.len() as f32,
        n: problems.len(),
    })
}

/// Evaluate across the full benchmark ladder (Table 2's columns).
pub fn evaluate_suite_ladder(
    rt: &Runtime,
    tier: &str,
    weights: &WeightSet,
    n_per_suite: usize,
    seed: u64,
) -> Result<Vec<(String, EvalResult)>> {
    SUITES
        .iter()
        .map(|s| Ok((s.name.to_string(), evaluate(rt, tier, weights, s.name, n_per_suite, seed)?)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_problems_deterministic_and_distinct_from_training() {
        let a = eval_problems("gsm8k-syn", 10, 1);
        let b = eval_problems("gsm8k-syn", 10, 1);
        assert_eq!(a, b);
        let c = eval_problems("gsm8k-syn", 10, 2);
        assert_ne!(a, c);
        // training stream (grpo::draw_problems) must not collide
        let mut rng = crate::util::Pcg64::with_stream(1, 0x6772706f);
        let t = crate::coordinator::grpo::draw_problems("gsm8k-syn", 10, &mut rng);
        assert_ne!(a, t);
    }
}
