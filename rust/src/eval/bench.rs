//! The benchmark subsystem: sampled k-way decoding over the difficulty
//! ladder, with pass@k / maj@k scoring (the paper's Tables 1–3 protocol).
//!
//! Where the base `eval` module answers "greedy pass@1 on one suite", this
//! module reproduces the paper's *evidence*: a [`LADDER`] of benchmark
//! suites (GSM8K-like → AIME-like analogues, each with its own decode
//! budget), k temperature-sampled completions per problem, and the
//! unbiased [`pass_at_k`] / majority-vote [`maj@k`](majority_answer)
//! estimators over them.
//!
//! Throughput comes from the engine subsystem: each problem's k samples
//! are one *group* in a [`GenJob`] (the same grouped-row layout GRPO
//! rollout waves use), and the whole ladder is built as one job list
//! served across an [`engine::WorkerPool`](crate::engine::pool::WorkerPool)
//! — workers stay saturated across suite boundaries instead of draining
//! per suite. Per-job RNG seeds are derived from stable request data
//! (suite name + chunk index), so a pooled ladder run is bit-identical to
//! a serial one (asserted in `tests/integration.rs`).
//!
//! Results land in a [`BenchRun`]: deterministic JSON (via `util::json`;
//! wall-clock time is deliberately excluded) plus a rendered markdown
//! table (golden-tested). [`crate::eval::report::RecoveryReport`] stitches
//! several runs into the paper's recovery-fraction table.
//!
//! The ladder is backend-blind; `tests/e2e_sim.rs` asserts pooled==serial
//! canonical-JSON identity on the sim backend in every CI run, so the
//! determinism claim no longer depends on artifacts existing.

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::engine::pool::{GenJob, WorkerPool};
use crate::engine::{is_padding, padding_problem, GenRow, InferenceEngine};
use crate::eval::eval_problems;
use crate::runtime::Runtime;
use crate::tasks::generator::Problem;
use crate::tasks::verifier;
use crate::util::json::{num, obj, s, Value};
use crate::util::{fnv1a, Timer};
use crate::weights::WeightSet;

/// One rung of the benchmark ladder: a task-generator suite plus its
/// decode budget (held-out problems per run) and sampling temperature.
/// Harder suites get smaller budgets — the paper's suites shrink the same
/// way (GSM8K's 1319 problems vs AIME's 30).
#[derive(Clone, Copy, Debug)]
pub struct BenchSuite {
    /// Suite name in [`crate::tasks::generator::SUITES`] (which also
    /// records the paper benchmark each suite stands in for — single
    /// source of truth for that mapping).
    pub suite: &'static str,
    /// Held-out problems decoded per run (0 < budget).
    pub budget: usize,
    /// Sampling temperature for the k-way decode.
    pub temperature: f32,
}

/// The 4-suite difficulty ladder (Table 2's columns, easiest first):
/// GSM8K → MATH500 → AMC23 → AIME24 analogues.
pub const LADDER: &[BenchSuite] = &[
    BenchSuite { suite: "gsm8k-syn", budget: 64, temperature: 1.0 },
    BenchSuite { suite: "math500-syn", budget: 48, temperature: 1.0 },
    BenchSuite { suite: "amc-syn", budget: 32, temperature: 1.0 },
    BenchSuite { suite: "aime-syn", budget: 16, temperature: 1.0 },
];

/// Look up a ladder rung by suite name; unknown names are an error (never
/// a silent fallback).
pub fn bench_suite(name: &str) -> Result<&'static BenchSuite> {
    LADDER.iter().find(|b| b.suite == name).ok_or_else(|| {
        anyhow!(
            "unknown bench suite {name:?}; ladder: {:?}",
            LADDER.iter().map(|b| b.suite).collect::<Vec<_>>()
        )
    })
}

/// Unbiased pass@k estimator (Chen et al., "Evaluating Large Language
/// Models Trained on Code"): given `n` samples of which `c` are correct,
///
/// ```text
/// pass@k = 1 - C(n-c, k) / C(n, k)
/// ```
///
/// computed as a stable running product. Requires `1 <= k <= n`.
///
/// ```
/// use tinylora_rl::eval::bench::pass_at_k;
/// assert_eq!(pass_at_k(1, 1, 1), 1.0); // k=1 on one sample = exact match
/// assert!((pass_at_k(4, 2, 1) - 0.5).abs() < 1e-12); // pass@1 = c/n
/// assert_eq!(pass_at_k(4, 0, 4), 0.0);
/// assert_eq!(pass_at_k(4, 1, 4), 1.0); // any correct sample ⇒ pass@n = 1
/// ```
pub fn pass_at_k(n: usize, c: usize, k: usize) -> f64 {
    assert!((1..=n).contains(&k), "pass@k needs 1 <= k ({k}) <= n ({n})");
    if c == 0 {
        return 0.0;
    }
    if n - c < k {
        return 1.0;
    }
    let mut prod = 1.0f64;
    for i in 0..k {
        prod *= (n - c - i) as f64 / (n - i) as f64;
    }
    1.0 - prod
}

/// Majority vote over extracted answers. `None` entries (no parseable
/// answer) never vote; ties break to the answer seen *earliest* in sample
/// order, so maj@k is deterministic under a fixed decode seed.
///
/// ```
/// use tinylora_rl::eval::bench::majority_answer;
/// assert_eq!(majority_answer(&[Some(3), Some(5), Some(5)]), Some(5));
/// assert_eq!(majority_answer(&[Some(3), Some(5)]), Some(3)); // tie → first seen
/// assert_eq!(majority_answer(&[None, None]), None);
/// ```
pub fn majority_answer(answers: &[Option<i64>]) -> Option<i64> {
    let mut tally: Vec<(i64, usize)> = Vec::new();
    for a in answers.iter().flatten() {
        match tally.iter_mut().find(|(v, _)| v == a) {
            Some((_, c)) => *c += 1,
            None => tally.push((*a, 1)),
        }
    }
    // strictly-greater keeps the first-seen answer on ties
    let mut best: Option<(i64, usize)> = None;
    for (v, c) in tally {
        if best.map(|(_, bc)| c > bc).unwrap_or(true) {
            best = Some((v, c));
        }
    }
    best.map(|(v, _)| v)
}

/// Per-suite scores from one k-way sampled run.
#[derive(Clone, Debug, PartialEq)]
pub struct SuiteScore {
    pub suite: String,
    /// real (non-padding) problems scored
    pub n: usize,
    pub k: usize,
    /// unbiased pass@1 over the k samples (= c/k averaged over problems)
    pub pass1: f32,
    /// unbiased pass@k
    pub pass_k: f32,
    /// majority-vote accuracy over the k samples
    pub maj_k: f32,
    /// fraction of samples in the canonical `#### n` format
    pub format_rate: f32,
    pub mean_response_len: f32,
}

impl SuiteScore {
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("suite", s(&self.suite)),
            ("n", num(self.n as f64)),
            ("k", num(self.k as f64)),
            ("pass1", num(self.pass1 as f64)),
            ("pass_k", num(self.pass_k as f64)),
            ("maj_k", num(self.maj_k as f64)),
            ("format_rate", num(self.format_rate as f64)),
            ("mean_response_len", num(self.mean_response_len as f64)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            suite: v.get("suite")?.str()?.to_string(),
            n: v.get("n")?.usize()?,
            k: v.get("k")?.usize()?,
            pass1: v.get("pass1")?.f64()? as f32,
            pass_k: v.get("pass_k")?.f64()? as f32,
            maj_k: v.get("maj_k")?.f64()? as f32,
            format_rate: v.get("format_rate")?.f64()? as f32,
            mean_response_len: v.get("mean_response_len")?.f64()? as f32,
        })
    }
}

/// Score k consecutive samples per problem (the engine's grouped-row
/// layout: rows `[p*k, (p+1)*k)` belong to problem `p`). Padding problems
/// are skipped; `rows.len()` must equal `problems.len() * k`.
pub fn score_rows(
    suite: &str,
    problems: &[Problem],
    rows: &[GenRow],
    k: usize,
) -> Result<SuiteScore> {
    if k == 0 || rows.len() != problems.len() * k {
        bail!("score_rows: {} rows != {} problems x k={k}", rows.len(), problems.len());
    }
    let mut n = 0usize;
    let (mut pass1, mut pass_k, mut maj_k) = (0.0f64, 0.0f64, 0.0f64);
    let (mut fmt, mut len_sum) = (0usize, 0f32);
    for (p, group) in problems.iter().zip(rows.chunks(k)) {
        if is_padding(p) {
            continue;
        }
        n += 1;
        let c = group.iter().filter(|r| r.reward > 0.5).count();
        pass1 += c as f64 / k as f64;
        pass_k += pass_at_k(k, c, k);
        let answers: Vec<Option<i64>> =
            group.iter().map(|r| verifier::extract_answer(&r.text)).collect();
        if majority_answer(&answers) == Some(p.answer) {
            maj_k += 1.0;
        }
        fmt += group.iter().filter(|r| r.has_format).count();
        len_sum += group.iter().map(|r| r.response.len() as f32).sum::<f32>();
    }
    if n == 0 {
        bail!("score_rows: no real problems in suite {suite:?}");
    }
    Ok(SuiteScore {
        suite: suite.to_string(),
        n,
        k,
        pass1: (pass1 / n as f64) as f32,
        pass_k: (pass_k / n as f64) as f32,
        maj_k: (maj_k / n as f64) as f32,
        format_rate: fmt as f32 / (n * k) as f32,
        mean_response_len: len_sum / (n * k) as f32,
    })
}

/// Configuration for one ladder run.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub tier: String,
    /// suite names to run (empty = the full [`LADDER`])
    pub suites: Vec<String>,
    /// samples per problem (must divide the decode batch)
    pub k: usize,
    /// problems per suite (0 = the suite's ladder budget)
    pub n: usize,
    /// sampling temperature (negative = the suite's ladder temperature)
    pub temperature: f32,
    pub seed: u64,
    /// pool threads (1 = the serial reference path, bit-identical)
    pub workers: usize,
    /// decode geometry (0 = `manifest.batch.roll`)
    pub batch: usize,
}

impl BenchConfig {
    pub fn new(tier: &str) -> Self {
        Self {
            tier: tier.to_string(),
            suites: Vec::new(),
            k: 4,
            n: 0,
            temperature: -1.0,
            seed: 777,
            workers: 1,
            batch: 0,
        }
    }
}

/// Everything one ladder run produced for one set of weights.
#[derive(Clone, Debug)]
pub struct BenchRun {
    pub tier: String,
    /// label of the evaluated weights ("base", a scheme tag, ...)
    pub name: String,
    /// trained parameters behind these weights (0 for the base model)
    pub params: usize,
    pub k: usize,
    pub seed: u64,
    pub scores: Vec<SuiteScore>,
    /// wall time; NOT serialized (JSON stays byte-deterministic)
    pub wall_secs: f64,
}

impl BenchRun {
    /// Canonical JSON (byte-identical across reruns and worker counts —
    /// asserted in `tests/integration.rs`).
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("kind", s("bench_run")),
            ("tier", s(&self.tier)),
            ("name", s(&self.name)),
            ("params", num(self.params as f64)),
            ("k", num(self.k as f64)),
            // string, not number: u64 seeds above 2^53 would round in f64
            ("seed", s(&self.seed.to_string())),
            ("suites", Value::Arr(self.scores.iter().map(|x| x.to_json()).collect())),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        if v.get("kind")?.str()? != "bench_run" {
            bail!("not a bench_run JSON object");
        }
        Ok(Self {
            tier: v.get("tier")?.str()?.to_string(),
            name: v.get("name")?.str()?.to_string(),
            params: v.get("params")?.usize()?,
            k: v.get("k")?.usize()?,
            seed: v.get("seed")?.str()?.parse()?,
            scores: v
                .get("suites")?
                .arr()?
                .iter()
                .map(SuiteScore::from_json)
                .collect::<Result<_>>()?,
            wall_secs: 0.0,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string() + "\n")?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Value::parse(text.trim())?)
    }

    /// Rendered markdown table (golden-tested — keep byte-stable).
    pub fn to_markdown(&self) -> String {
        let mut out = format!(
            "### Bench — {}/{} ({} params, k={}, seed {})\n\n",
            self.tier, self.name, self.params, self.k, self.seed
        );
        out.push_str(&format!(
            "| suite | stands in for | n | pass@1 | pass@{k} | maj@{k} | format | len |\n\
             |---|---|---|---|---|---|---|---|\n",
            k = self.k
        ));
        for sc in &self.scores {
            let stands = crate::tasks::generator::suite(&sc.suite)
                .map(|x| x.stands_in_for)
                .unwrap_or("—");
            out.push_str(&format!(
                "| {} | {} | {} | {:.3} | {:.3} | {:.3} | {:.3} | {:.1} |\n",
                sc.suite, stands, sc.n, sc.pass1, sc.pass_k, sc.maj_k, sc.format_rate,
                sc.mean_response_len
            ));
        }
        out
    }
}

/// Stable per-job decode seed — a pure function of request data so that
/// serial and pooled runs draw identical samples no matter which worker
/// picks a job up.
fn job_seed(run_seed: u64, suite: &str, chunk_idx: usize) -> u64 {
    run_seed ^ fnv1a(suite.as_bytes()) ^ (chunk_idx as u64).wrapping_mul(0x9e3779b97f4a7c15)
}

/// Run the ladder with a caller-owned engine (drivers benching several
/// weight sets reuse one executable resolution).
///
/// Known memory bound: `GenJob` owns its weights, so every job clones the
/// merged `WeightSet` (~0.5 MB at current tiers; the full default ladder
/// is ≤ a few dozen jobs). Same bound as tenant rollout waves — moving
/// the backbone behind `Arc` in `GenJob` is the shared fix if tiers grow.
pub fn run_ladder_with(
    rt: &Runtime,
    engine: &InferenceEngine,
    weights: &WeightSet,
    name: &str,
    params: usize,
    cfg: &BenchConfig,
) -> Result<BenchRun> {
    let k = cfg.k;
    if k == 0 {
        bail!("bench: k must be >= 1");
    }
    if engine.batch % k != 0 {
        bail!("bench: k={k} must divide the decode batch {}", engine.batch);
    }
    let per_job = engine.batch / k;
    let suites: Vec<&'static BenchSuite> = if cfg.suites.is_empty() {
        LADDER.iter().collect()
    } else {
        cfg.suites.iter().map(|n| bench_suite(n)).collect::<Result<_>>()?
    };

    let t0 = Timer::start();
    // the whole ladder as ONE job list: workers stay saturated across
    // suite boundaries instead of draining per suite
    let mut jobs: Vec<GenJob> = Vec::new();
    let mut meta: Vec<(usize, Vec<Problem>)> = Vec::new(); // job id -> (suite idx, its problems)
    for (si, bs) in suites.iter().enumerate() {
        let n = if cfg.n > 0 { cfg.n } else { bs.budget };
        let temperature = if cfg.temperature >= 0.0 { cfg.temperature } else { bs.temperature };
        let problems = eval_problems(bs.suite, n, cfg.seed)?;
        for (ci, chunk) in problems.chunks(per_job).enumerate() {
            // k=1 jobs take the engine's arbitrary-length path (it flushes
            // the tail on the smallest baked geometry and drops sentinel
            // rows itself); grouped jobs must fill a baked geometry
            // exactly, so the tail chunk pads only to the smallest
            // geometry (divisible by k) that holds it — occupancy-aware
            // k-grouping instead of always filling the canonical batch
            let job_problems = if k == 1 {
                chunk.to_vec()
            } else {
                let target = engine.grouped_geometry(chunk.len() * k, k) / k;
                let mut padded = chunk.to_vec();
                while padded.len() < target {
                    padded.push(padding_problem());
                }
                padded
            };
            jobs.push(GenJob {
                id: jobs.len() as u64,
                weights: weights.clone(),
                problems: job_problems.clone(),
                group: k,
                pb: None,
                temperature,
                seed: job_seed(cfg.seed, bs.suite, ci),
                policy_version: 0,
            });
            meta.push((si, job_problems));
        }
    }

    let pool = WorkerPool::new(cfg.workers);
    let results = pool.serve_maybe(rt, engine, jobs, cfg.workers > 1)?;

    // demux rows back per suite (results arrive sorted by job id, and jobs
    // were emitted suite-major, so per-suite order is the problem order)
    let mut suite_problems: Vec<Vec<Problem>> = vec![Vec::new(); suites.len()];
    let mut suite_rows: Vec<Vec<GenRow>> = vec![Vec::new(); suites.len()];
    for res in results {
        let (si, problems) = &meta[res.id as usize];
        suite_problems[*si].extend(problems.iter().cloned());
        suite_rows[*si].extend(res.rows);
    }
    let scores = suites
        .iter()
        .enumerate()
        .map(|(si, bs)| score_rows(bs.suite, &suite_problems[si], &suite_rows[si], k))
        .collect::<Result<Vec<_>>>()?;
    Ok(BenchRun {
        tier: engine.tier.clone(),
        name: name.to_string(),
        params,
        k,
        seed: cfg.seed,
        scores,
        wall_secs: t0.secs(),
    })
}

/// Run the full ladder for one weight set (the `bench` CLI entry point).
pub fn run_ladder(
    rt: &Runtime,
    weights: &WeightSet,
    name: &str,
    params: usize,
    cfg: &BenchConfig,
) -> Result<BenchRun> {
    let batch = if cfg.batch > 0 { cfg.batch } else { rt.manifest.batch.roll };
    let engine = InferenceEngine::new(rt, &cfg.tier, batch)?;
    run_ladder_with(rt, &engine, weights, name, params, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// exact C(n,k) reference for the estimator cross-check
    fn binom(n: usize, k: usize) -> f64 {
        if k > n {
            return 0.0;
        }
        let mut out = 1.0f64;
        for i in 0..k {
            out *= (n - i) as f64 / (k - i) as f64;
        }
        out
    }

    #[test]
    fn pass_at_k_matches_combinatorial_formula() {
        for n in 1..=10usize {
            for c in 0..=n {
                for k in 1..=n {
                    let want = 1.0 - binom(n - c, k) / binom(n, k);
                    let got = pass_at_k(n, c, k);
                    assert!((got - want).abs() < 1e-12, "n={n} c={c} k={k}: {got} vs {want}");
                }
            }
        }
    }

    #[test]
    fn pass_at_1_is_exact_match_accuracy() {
        // with n samples, pass@1 is the plain fraction correct — and at
        // n=k=1 it degenerates to 0/1 exact match
        for n in 1..=8usize {
            for c in 0..=n {
                assert!((pass_at_k(n, c, 1) - c as f64 / n as f64).abs() < 1e-12);
            }
        }
        assert_eq!(pass_at_k(1, 0, 1), 0.0);
        assert_eq!(pass_at_k(1, 1, 1), 1.0);
    }

    #[test]
    fn pass_at_k_monotone_in_k() {
        for c in 0..=8usize {
            let mut prev = 0.0;
            for k in 1..=8 {
                let p = pass_at_k(8, c, k);
                assert!(p >= prev - 1e-12, "c={c} k={k}");
                prev = p;
            }
        }
    }

    #[test]
    fn majority_tie_breaks_to_first_seen_deterministically() {
        assert_eq!(majority_answer(&[Some(3), Some(5), Some(5), Some(3)]), Some(3));
        assert_eq!(majority_answer(&[Some(5), Some(3), Some(3), Some(5)]), Some(5));
        // None never votes; a single parseable answer wins
        assert_eq!(majority_answer(&[None, Some(9), None]), Some(9));
        assert_eq!(majority_answer(&[]), None);
    }

    fn row(text: &str, reward: f32) -> GenRow {
        GenRow {
            prompt_len: 4,
            response: vec![1, 2, 3],
            behavior: vec![],
            text: text.to_string(),
            reward,
            hit_eos: true,
            has_format: verifier::has_canonical_format(text),
        }
    }

    fn problem(answer: i64) -> Problem {
        Problem { prompt: "p".into(), gold: format!("#### {answer}"), answer, suite: "gsm8k-syn" }
    }

    #[test]
    fn score_rows_grouped_layout_and_padding() {
        let problems = vec![problem(7), problem(9), padding_problem()];
        // problem 0: one of two samples correct; problem 1: majority wrong
        // answer but one correct sample; padding rows must be ignored
        let rows = vec![
            row("#### 7", 1.0),
            row("#### 8", 0.0),
            row("#### 1", 0.0),
            row("#### 9", 1.0),
            row("", 0.0),
            row("", 0.0),
        ];
        let sc = score_rows("gsm8k-syn", &problems, &rows, 2).unwrap();
        assert_eq!(sc.n, 2);
        assert_eq!(sc.k, 2);
        assert!((sc.pass1 - 0.5).abs() < 1e-6);
        assert!((sc.pass_k - 1.0).abs() < 1e-6, "any-correct at k=n");
        // problem 0 majority tie -> first seen (7, correct); problem 1 tie
        // -> first seen (1, wrong)
        assert!((sc.maj_k - 0.5).abs() < 1e-6);
        assert!((sc.format_rate - 1.0).abs() < 1e-6);
        assert!(score_rows("gsm8k-syn", &problems, &rows[..4], 2).is_err(), "length mismatch");
    }

    #[test]
    fn ladder_names_resolve_and_unknown_is_error() {
        for b in LADDER {
            assert!(crate::tasks::generator::suite(b.suite).is_some(), "{} missing", b.suite);
            assert!(b.budget > 0);
            assert_eq!(bench_suite(b.suite).unwrap().suite, b.suite);
        }
        assert!(bench_suite("nope").is_err());
        // budgets shrink up the ladder, like the paper's suites
        for w in LADDER.windows(2) {
            assert!(w[1].budget <= w[0].budget);
        }
    }

    #[test]
    fn bench_run_json_roundtrip_is_deterministic() {
        let run = BenchRun {
            tier: "micro".into(),
            name: "tinylora_r2_u13_all".into(),
            params: 13,
            k: 4,
            seed: 777,
            scores: vec![SuiteScore {
                suite: "gsm8k-syn".into(),
                n: 64,
                k: 4,
                pass1: 0.91,
                pass_k: 0.984,
                maj_k: 0.953,
                format_rate: 0.998,
                mean_response_len: 18.25,
            }],
            wall_secs: 12.5,
        };
        let j1 = run.to_json().to_string();
        let back = BenchRun::from_json(&Value::parse(&j1).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string(), j1);
        assert_eq!(back.scores, run.scores);
        assert_eq!(back.wall_secs, 0.0, "timing must not survive serialization");
    }

    #[test]
    fn markdown_golden() {
        let run = BenchRun {
            tier: "micro".into(),
            name: "base".into(),
            params: 0,
            k: 4,
            seed: 777,
            scores: vec![
                SuiteScore {
                    suite: "gsm8k-syn".into(),
                    n: 64,
                    k: 4,
                    pass1: 0.91,
                    pass_k: 0.984,
                    maj_k: 0.953,
                    format_rate: 0.998,
                    mean_response_len: 18.25,
                },
                SuiteScore {
                    suite: "aime-syn".into(),
                    n: 16,
                    k: 4,
                    pass1: 0.25,
                    pass_k: 0.5,
                    maj_k: 0.3125,
                    format_rate: 0.75,
                    mean_response_len: 33.5,
                },
            ],
            wall_secs: 0.0,
        };
        let want = "### Bench — micro/base (0 params, k=4, seed 777)\n\n\
                    | suite | stands in for | n | pass@1 | pass@4 | maj@4 | format | len |\n\
                    |---|---|---|---|---|---|---|---|\n\
                    | gsm8k-syn | GSM8K | 64 | 0.910 | 0.984 | 0.953 | 0.998 | 18.2 |\n\
                    | aime-syn | AIME24 | 16 | 0.250 | 0.500 | 0.312 | 0.750 | 33.5 |\n";
        assert_eq!(run.to_markdown(), want);
    }

    #[test]
    fn job_seeds_are_stable_and_distinct() {
        assert_eq!(job_seed(7, "gsm8k-syn", 0), job_seed(7, "gsm8k-syn", 0));
        assert_ne!(job_seed(7, "gsm8k-syn", 0), job_seed(7, "gsm8k-syn", 1));
        assert_ne!(job_seed(7, "gsm8k-syn", 0), job_seed(7, "aime-syn", 0));
        assert_ne!(job_seed(7, "gsm8k-syn", 1), job_seed(8, "gsm8k-syn", 1));
    }
}
