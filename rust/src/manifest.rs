//! Typed view over `artifacts/manifest.json` — the single source of truth
//! for every shape/dtype that crosses the python→rust boundary.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Value;

#[derive(Clone, Debug, PartialEq)]
pub enum DType {
    F32,
    S32,
}

#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl ArgSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct InitSpec {
    pub kind: String, // "normal" | "zeros" | "ones" | "from_checkpoint"
    pub std: f32,
}

#[derive(Clone, Debug)]
pub struct WeightSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: InitSpec,
}

#[derive(Clone, Debug)]
pub struct TierInfo {
    pub name: String,
    pub d: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub f: usize,
    pub t_max: usize,
    pub t_prefill: usize,
    pub t_train: usize,
    pub head_dim: usize,
    pub n_params: usize,
    pub weights: Vec<WeightSpec>,
    /// module name -> (d_in, d_out) for the seven adapted modules
    pub module_dims: BTreeMap<String, (usize, usize)>,
}

#[derive(Clone, Debug)]
pub struct SchemeInfo {
    pub kind: String, // tinylora | lora_xs | lora | full
    pub r: usize,
    pub u: usize,
    pub tie: String,
    pub n_tie: usize,
    pub lora_alpha: f32,
}

#[derive(Clone, Debug)]
pub struct ThetaSegment {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub len: usize,
    pub init: InitSpec,
}

#[derive(Clone, Debug)]
pub struct ExeInfo {
    pub name: String,
    pub file: String,
    pub fn_kind: String, // prefill|decode|generate|grpo|sft|pretrain|logprobs|merge
    pub tier: String,
    pub batch: usize,
    pub seq: usize,
    pub use_pallas: bool,
    pub inputs: Vec<ArgSpec>,
    pub outputs: Vec<ArgSpec>,
    pub scheme: Option<SchemeInfo>,
    pub scheme_tag: Option<String>,
    pub theta_size: Option<usize>,
    pub theta_segments: Vec<ThetaSegment>,
    pub groups: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct Vocab {
    pub size: usize,
    pub chars: String,
    pub pad: i32,
    pub bos: i32,
    pub eos: i32,
}

#[derive(Clone, Debug)]
pub struct BatchGeometry {
    pub roll: usize,
    pub train: usize,
    pub serve: usize,
    pub test: usize,
}

pub struct Manifest {
    pub dir: PathBuf,
    pub vocab: Vocab,
    pub modules: Vec<String>,
    pub weight_names: Vec<String>,
    pub n_stats: usize,
    pub batch: BatchGeometry,
    pub tiers: BTreeMap<String, TierInfo>,
    pub executables: BTreeMap<String, ExeInfo>,
}

fn parse_init(v: &Value) -> Result<InitSpec> {
    Ok(InitSpec {
        kind: v.get("kind")?.str()?.to_string(),
        std: v.opt("std").map(|s| s.f64().unwrap_or(0.0) as f32).unwrap_or(0.0),
    })
}

fn parse_args(v: &Value) -> Result<Vec<ArgSpec>> {
    v.arr()?
        .iter()
        .map(|a| {
            Ok(ArgSpec {
                name: a.get("name")?.str()?.to_string(),
                dtype: match a.get("dtype")?.str()? {
                    "f32" => DType::F32,
                    "s32" => DType::S32,
                    other => bail!("unknown dtype {other}"),
                },
                shape: a.get("shape")?.usize_vec()?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(art_dir: &Path) -> Result<Self> {
        let path = art_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = Value::parse(&text).context("parsing manifest.json")?;

        let vo = v.get("vocab")?;
        let vocab = Vocab {
            size: vo.get("size")?.usize()?,
            chars: vo.get("chars")?.str()?.to_string(),
            pad: vo.get("pad")?.i64()? as i32,
            bos: vo.get("bos")?.i64()? as i32,
            eos: vo.get("eos")?.i64()? as i32,
        };
        let bo = v.get("batch")?;
        let batch = BatchGeometry {
            roll: bo.get("roll")?.usize()?,
            train: bo.get("train")?.usize()?,
            serve: bo.get("serve")?.usize()?,
            test: bo.get("test")?.usize()?,
        };

        let mut tiers = BTreeMap::new();
        for (name, t) in v.get("tiers")?.obj()? {
            let mut weights = Vec::new();
            for w in t.get("weights")?.arr()? {
                weights.push(WeightSpec {
                    name: w.get("name")?.str()?.to_string(),
                    shape: w.get("shape")?.usize_vec()?,
                    init: parse_init(w.get("init")?)?,
                });
            }
            let mut module_dims = BTreeMap::new();
            for (m, dims) in t.get("module_dims")?.obj()? {
                let d = dims.usize_vec()?;
                module_dims.insert(m.clone(), (d[0], d[1]));
            }
            tiers.insert(
                name.clone(),
                TierInfo {
                    name: name.clone(),
                    d: t.get("d")?.usize()?,
                    n_layers: t.get("n_layers")?.usize()?,
                    n_heads: t.get("n_heads")?.usize()?,
                    f: t.get("f")?.usize()?,
                    t_max: t.get("t_max")?.usize()?,
                    t_prefill: t.get("t_prefill")?.usize()?,
                    t_train: t.get("t_train")?.usize()?,
                    head_dim: t.get("head_dim")?.usize()?,
                    n_params: t.get("n_params")?.usize()?,
                    weights,
                    module_dims,
                },
            );
        }

        let mut executables = BTreeMap::new();
        for (name, e) in v.get("executables")?.obj()? {
            let scheme = match e.opt("scheme") {
                Some(sv) => Some(SchemeInfo {
                    kind: sv.get("kind")?.str()?.to_string(),
                    r: sv.get("r")?.usize()?,
                    u: sv.get("u")?.usize()?,
                    tie: sv.get("tie")?.str()?.to_string(),
                    n_tie: sv.get("n_tie")?.usize()?,
                    lora_alpha: sv.get("lora_alpha")?.f64()? as f32,
                }),
                None => None,
            };
            let mut theta_segments = Vec::new();
            if let Some(segs) = e.opt("theta_segments") {
                for s in segs.arr()? {
                    theta_segments.push(ThetaSegment {
                        name: s.get("name")?.str()?.to_string(),
                        shape: s.get("shape")?.usize_vec()?,
                        offset: s.get("offset")?.usize()?,
                        len: s.get("len")?.usize()?,
                        init: parse_init(s.get("init")?)?,
                    });
                }
            }
            executables.insert(
                name.clone(),
                ExeInfo {
                    name: name.clone(),
                    file: e.get("file")?.str()?.to_string(),
                    fn_kind: e.get("fn")?.str()?.to_string(),
                    tier: e.get("tier")?.str()?.to_string(),
                    batch: e.get("batch")?.usize()?,
                    seq: e.get("seq")?.usize()?,
                    use_pallas: e.get("use_pallas")?.boolean()?,
                    inputs: parse_args(e.get("inputs")?)?,
                    outputs: parse_args(e.get("outputs")?)?,
                    scheme,
                    scheme_tag: e.opt("scheme_tag").map(|s| s.str().unwrap().to_string()),
                    theta_size: e.opt("theta_size").map(|s| s.usize().unwrap()),
                    theta_segments,
                    groups: e.opt("groups").map(|g| g.usize_vec().unwrap()).unwrap_or_default(),
                },
            );
        }

        Ok(Self {
            dir: art_dir.to_path_buf(),
            vocab,
            modules: v.get("modules")?.arr()?.iter().map(|m| m.str().unwrap().to_string()).collect(),
            weight_names: v
                .get("weight_names")?
                .arr()?
                .iter()
                .map(|m| m.str().unwrap().to_string())
                .collect(),
            n_stats: v.get("n_stats")?.usize()?,
            batch,
            tiers,
            executables,
        })
    }

    pub fn tier(&self, name: &str) -> Result<&TierInfo> {
        self.tiers.get(name).with_context(|| format!("unknown tier {name:?}"))
    }

    pub fn exe(&self, name: &str) -> Result<&ExeInfo> {
        self.executables
            .get(name)
            .with_context(|| format!("unknown executable {name:?} — re-run `make artifacts`?"))
    }

    /// Find the unique executable matching a predicate (used by trainers to
    /// locate e.g. "the grpo grad for tier X scheme tag Y").
    pub fn find<F: Fn(&ExeInfo) -> bool>(&self, what: &str, pred: F) -> Result<&ExeInfo> {
        let hits: Vec<_> = self.executables.values().filter(|e| pred(e)).collect();
        match hits.len() {
            1 => Ok(hits[0]),
            0 => bail!("no executable matches {what}"),
            n => bail!("{n} executables match {what}"),
        }
    }

    /// Grad executable for a (tier, algo, scheme) at the default train batch.
    pub fn grad_exe(&self, tier: &str, algo: &str, tag: &str) -> Result<&ExeInfo> {
        self.grad_exe_b(tier, algo, tag, self.batch.train)
            .or_else(|_| self.find(&format!("{algo} grad {tier}/{tag} (any batch)"), |e| {
                e.fn_kind == algo && e.tier == tier && e.scheme_tag.as_deref() == Some(tag)
            }))
    }

    /// Grad executable at an explicit batch size.
    pub fn grad_exe_b(&self, tier: &str, algo: &str, tag: &str, batch: usize) -> Result<&ExeInfo> {
        self.find(&format!("{algo} grad {tier}/{tag} b{batch}"), |e| {
            e.fn_kind == algo
                && e.tier == tier
                && e.scheme_tag.as_deref() == Some(tag)
                && e.batch == batch
        })
    }

    pub fn merge_exe(&self, tier: &str, tag: &str) -> Result<&ExeInfo> {
        self.find(&format!("merge {tier}/{tag}"), |e| {
            e.fn_kind == "merge" && e.tier == tier && e.scheme_tag.as_deref() == Some(tag)
        })
    }

    pub fn generate_exe(&self, tier: &str, batch: usize) -> Result<&ExeInfo> {
        self.find(&format!("generate {tier} b{batch}"), |e| {
            e.fn_kind == "generate" && e.tier == tier && e.batch == batch
        })
    }
}
