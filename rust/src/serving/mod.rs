//! Multi-adapter serving plane — the paper's deployment motivation:
//! TinyLoRA adapters are small enough (26 bytes!) to store *millions* of
//! tenants, through a three-tier store (packed cold arena → warm theta
//! LRU → hot merged-model LRU) with lazy merge-on-first-request,
//! batch-aware wave promotion, and per-adapter dynamic batching.
//!
//! Decode and batch formation live in the shared `engine` subsystem
//! (`InferenceEngine`, `Scheduler`, `WorkerPool`); this module owns the
//! serving-specific pieces: the adapter store and the router.

pub mod batcher;
pub mod router;
pub mod store;

pub use batcher::{Batch, DynamicBatcher, Request};
pub use router::{Response, Router, RouterStats};
pub use store::{AdapterStore, ColdTier, Residency, ResidentLru, StoreStats};

// convenience re-exports for serving clients
pub use crate::engine::scheduler::{AdapterBatch, QueuedRequest, SchedPolicy, Scheduler};
