//! Multi-adapter serving plane — the paper's deployment motivation:
//! TinyLoRA adapters are small enough (26 bytes!) to store *millions* of
//! tenants, through a three-tier store (packed cold arena → warm theta
//! LRU → hot merged-model LRU) with lazy merge-on-first-request,
//! batch-aware wave promotion, and per-adapter dynamic batching.
//!
//! Decode and batch formation live in the shared `engine` subsystem
//! (`InferenceEngine`, `Scheduler`, `WorkerPool`); this module owns the
//! serving-specific pieces: the adapter store, the wave-drain router,
//! and the open-loop continuous-batching front-end (`frontend` +
//! `trace`).

pub mod batcher;
pub mod frontend;
pub mod router;
pub mod store;
pub mod trace;

pub use batcher::{Batch, DynamicBatcher, Request};
pub use frontend::{schedule, Frontend, FrontendConfig, Schedule, ShedEvent, SloStats};
pub use router::{Response, Router, RouterStats};
pub use store::{AdapterStore, ColdTier, Residency, ResidentLru, StoreStats};
pub use trace::{ArrivalTrace, TraceConfig, TraceEvent};

// convenience re-exports for serving clients
pub use crate::engine::scheduler::{AdapterBatch, QueuedRequest, SchedPolicy, Scheduler};

/// A formed batch as decode problems. Serving prompts are free-form (no
/// gold/answer), so suite is a fixed marker — shared by the router's wave
/// path and the front-end's refill path so both decode the exact same
/// `Problem` rows for the same batch (part of the byte-identity
/// argument, DESIGN.md §13).
pub(crate) fn serving_problems(batch: &AdapterBatch) -> Vec<crate::tasks::generator::Problem> {
    batch
        .requests
        .iter()
        .map(|r| crate::tasks::generator::Problem {
            prompt: r.prompt.clone(),
            gold: String::new(),
            answer: 0,
            suite: "serving",
        })
        .collect()
}
