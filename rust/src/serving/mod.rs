//! Multi-adapter serving plane — the paper's deployment motivation:
//! TinyLoRA adapters are small enough (26 bytes!) to store thousands of
//! tenants, with an LRU of activated (merged) models and per-adapter
//! dynamic batching.

pub mod batcher;
pub mod router;
pub mod store;

pub use batcher::{Batch, DynamicBatcher, Request};
pub use router::{Router, RouterStats};
pub use store::AdapterStore;
