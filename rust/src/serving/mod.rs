//! Multi-adapter serving plane — the paper's deployment motivation:
//! TinyLoRA adapters are small enough (26 bytes!) to store thousands of
//! tenants, with an LRU of activated (merged) models and per-adapter
//! dynamic batching.
//!
//! Decode and batch formation live in the shared `engine` subsystem
//! (`InferenceEngine`, `Scheduler`, `WorkerPool`); this module owns the
//! serving-specific pieces: the adapter store and the router.

pub mod batcher;
pub mod router;
pub mod store;

pub use batcher::{Batch, DynamicBatcher, Request};
pub use router::{Response, Router, RouterStats};
pub use store::{AdapterStore, ResidentLru};

// convenience re-exports for serving clients
pub use crate::engine::scheduler::{AdapterBatch, QueuedRequest, SchedPolicy, Scheduler};
