//! Million-tenant adapter plane — the paper's serving motivation made
//! concrete: a trained adapter is 13 params / 26 bytes, so one box holds
//! *millions* of per-tenant adapters (paper §1, citing Punica).
//!
//! Three inclusive tiers, promotion is lazy (merge on first request):
//!
//! ```text
//!   cold  — every tenant, packed bytes in one contiguous arena
//!           (26 B/tenant headline + tens of bytes of index)
//!   warm  — LRU of unpacked f32 theta vectors (52 B/tenant at u=13)
//!   hot   — LRU of fully-merged WeightSets (n_params × 4 B each)
//! ```
//!
//! `activate` walks cold → warm → hot; hot evictions *demote* to warm
//! (the unpacked theta survives, only the expensive merge is dropped) so
//! re-promotion skips the cold-tier unpack.  Batch-aware promotion:
//! `begin_wave` pins and promotes every adapter of a formed wave once,
//! up front, off the per-request path, and demotion never evicts an
//! adapter pinned by an in-flight wave (the hot tier may transiently
//! exceed its capacity by the wave's width — see DESIGN.md §12).

mod cold;
mod lru;

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::adapters::factors::{weights_fingerprint, FactorSet};
use crate::adapters::packing::Precision;
use crate::coordinator::policy::Policy;
use crate::runtime::Runtime;
use crate::weights::WeightSet;

pub use cold::ColdTier;
pub use lru::ResidentLru;

/// Which tier currently holds an adapter (highest wins; read-only probe).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Residency {
    Hot,
    Warm,
    Cold,
    Unknown,
}

/// Point-in-time observability snapshot: per-tier hit/transition counts
/// (events since construction or [`AdapterStore::reset_stats`]) and
/// resident-byte gauges.  Logged to the JSONL metrics stream via
/// `metrics::RunLog::log_store`.
#[derive(Clone, Copy, Debug, Default)]
pub struct StoreStats {
    pub tenants: usize,
    pub activations: u64,
    pub hot_hits: u64,
    pub warm_hits: u64,
    pub cold_misses: u64,
    pub promotions_warm: u64,
    pub promotions_hot: u64,
    /// hot evictions whose merged model was demoted to a warm entry
    pub demotions: u64,
    pub evictions_warm: u64,
    pub evictions_hot: u64,
    /// packed cold-tier data bytes (maintained counter, not a scan)
    pub stored_bytes: usize,
    pub cold_index_bytes: usize,
    pub warm_bytes: usize,
    pub hot_bytes: usize,
    pub warm_entries: usize,
    pub hot_entries: usize,
    /// mid-decode row refills served by the continuous-batching
    /// front-end (`begin_refill` calls)
    pub refills: u64,
}

#[derive(Clone, Copy, Default)]
struct Counters {
    activations: u64,
    hot_hits: u64,
    warm_hits: u64,
    cold_misses: u64,
    promotions_warm: u64,
    promotions_hot: u64,
    demotions: u64,
    evictions_warm: u64,
    evictions_hot: u64,
    refills: u64,
}

pub struct AdapterStore {
    pub tier: String,
    cold: ColdTier,
    /// unpacked theta vectors, access-ordered
    warm: ResidentLru<Vec<f32>>,
    /// fully-merged models, access-ordered
    hot: ResidentLru<WeightSet>,
    /// hot-tier capacity (merged models are the expensive resource)
    pub max_resident: usize,
    /// warm-tier capacity; 0 disables the warm tier entirely
    pub max_warm: usize,
    /// adapters pinned by in-flight waves (name -> pin count); pinned
    /// entries are never evicted from hot
    pinned: HashMap<String, usize>,
    /// per-(scheme, base-fingerprint) factor cache shared across tenants
    factors: HashMap<(String, u64), Arc<FactorSet>>,
    stored_bytes: usize,
    warm_bytes: usize,
    hot_bytes: usize,
    c: Counters,
}

impl AdapterStore {
    pub fn new(tier: &str, max_resident: usize) -> Self {
        // default warm tier: one demotion generation per hot slot, ×8
        Self::with_tiers(tier, max_resident, max_resident.max(1) * 8)
    }

    pub fn with_tiers(tier: &str, max_resident: usize, max_warm: usize) -> Self {
        Self {
            tier: tier.to_string(),
            cold: ColdTier::new(),
            warm: ResidentLru::new(),
            hot: ResidentLru::new(),
            max_resident: max_resident.max(1),
            max_warm,
            pinned: HashMap::new(),
            factors: HashMap::new(),
            stored_bytes: 0,
            warm_bytes: 0,
            hot_bytes: 0,
            c: Counters::default(),
        }
    }

    /// Register a trained adapter straight into the cold tier (packs
    /// theta at the given precision). Duplicates are an error.
    pub fn register(
        &mut self,
        name: &str,
        scheme_tag: &str,
        theta: &[f32],
        precision: Precision,
    ) -> Result<()> {
        let id = self.cold.insert(name, scheme_tag, theta, precision)?;
        self.stored_bytes += self.cold.packed(id).len();
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.cold.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cold.is_empty()
    }

    pub fn names(&self) -> Vec<String> {
        self.cold.names_sorted()
    }

    /// Total packed bytes of all stored adapters (the paper's storage
    /// argument).  O(1): a counter maintained on register — the cold
    /// tier is grow-only, so nothing ever subtracts.
    pub fn stored_bytes(&self) -> usize {
        self.stored_bytes
    }

    /// O(n) recomputation of [`Self::stored_bytes`] from the arena
    /// records — test/diagnostic cross-check for the counter.
    pub fn recompute_stored_bytes(&self) -> usize {
        (0..self.cold.len() as u32).map(|id| self.cold.packed(id).len()).sum()
    }

    /// Bytes one resident merged model costs.
    pub fn resident_model_bytes(&self, n_params: usize) -> usize {
        n_params * 4
    }

    /// Resident merged models from LRU to MRU (diagnostics/tests).
    pub fn resident_order(&self) -> Vec<String> {
        self.hot.order()
    }

    /// Which tier holds `name` right now (no promotion, no recency bump).
    pub fn residency(&self, name: &str) -> Residency {
        if self.hot.contains(name) {
            Residency::Hot
        } else if self.warm.contains(name) {
            Residency::Warm
        } else if self.cold.lookup(name).is_some() {
            Residency::Cold
        } else {
            Residency::Unknown
        }
    }

    /// Activate an adapter for one request: return merged weights,
    /// promoting cold → warm → hot as needed.  `base` is the shared
    /// frozen base model.
    pub fn activate(
        &mut self,
        rt: &Runtime,
        base: &WeightSet,
        name: &str,
        ckpt_dir: &Path,
    ) -> Result<WeightSet> {
        self.promote(rt, base, name, ckpt_dir, true)?;
        Ok(self.hot.touch(name).expect("promote left the adapter hot").clone())
    }

    /// Hot-tier checkout without touching hit/activation counters: the
    /// wave path counts one activation per adapter at `begin_wave`, then
    /// checks each batch's (already promoted and pinned) weights out
    /// through this.
    pub fn checkout_hot(&mut self, name: &str) -> Option<WeightSet> {
        self.hot.touch(name).cloned()
    }

    /// Batch-aware promotion: pin every adapter of a formed wave, then
    /// promote/merge each exactly once, up front — per-request serving
    /// then only clones hot entries.  Pins nest (waves may overlap) and
    /// guarantee demotion never evicts an in-flight adapter, at the cost
    /// of letting the hot tier transiently exceed `max_resident` by the
    /// wave width.  On error the wave's pins are released.
    pub fn begin_wave(
        &mut self,
        rt: &Runtime,
        base: &WeightSet,
        adapters: &[String],
        ckpt_dir: &Path,
    ) -> Result<()> {
        for name in adapters {
            *self.pinned.entry(name.clone()).or_insert(0) += 1;
        }
        for name in adapters {
            if let Err(e) = self.promote(rt, base, name, ckpt_dir, true) {
                self.end_wave(adapters);
                return Err(e).with_context(|| format!("promoting wave adapter {name:?}"));
            }
        }
        Ok(())
    }

    /// Release a wave's pins and trim the hot tier back to capacity
    /// (deferred demotions happen here).
    pub fn end_wave(&mut self, adapters: &[String]) {
        for name in adapters {
            if let Some(n) = self.pinned.get_mut(name.as_str()) {
                *n -= 1;
                if *n == 0 {
                    self.pinned.remove(name.as_str());
                }
            }
        }
        self.hot_trim();
    }

    /// One mid-decode row refill of the continuous-batching front-end: a
    /// single-adapter wave (pin → promote/merge → checkout), used each
    /// time a freed decode slot is refilled with a new batch while other
    /// slots are still mid-decode.  Pins nest with any surrounding wave,
    /// so a refill can never evict an adapter another slot is serving.
    /// Balance with [`AdapterStore::end_refill`].
    pub fn begin_refill(
        &mut self,
        rt: &Runtime,
        base: &WeightSet,
        name: &str,
        ckpt_dir: &Path,
    ) -> Result<WeightSet> {
        let wave = [name.to_string()];
        self.begin_wave(rt, base, &wave, ckpt_dir)?;
        self.c.refills += 1;
        Ok(self
            .checkout_hot(name)
            .expect("begin_wave promoted and pinned the refill adapter"))
    }

    /// Release a refill's pin (deferred hot-tier trim happens here).
    pub fn end_refill(&mut self, name: &str) {
        self.end_wave(&[name.to_string()]);
    }

    /// Stage a set of adapters into the warm tier (cold-tier unpack only,
    /// no merge) — e.g. the whole upcoming wave before its chunks pin and
    /// merge their slices.  Counts tier transitions but no activations.
    pub fn prefetch_warm(&mut self, adapters: &[String]) -> Result<()> {
        if self.max_warm == 0 {
            return Ok(());
        }
        for name in adapters {
            if self.hot.contains(name) || self.warm.contains(name) {
                continue;
            }
            let id = self
                .cold
                .lookup(name)
                .with_context(|| format!("unknown adapter {name:?}"))?;
            let theta = self.cold.unpack_theta(id);
            self.warm_insert(name, theta);
        }
        Ok(())
    }

    /// The tier walk. `request` distinguishes a served activation (counts
    /// toward activations + per-tier hits) from internal staging.
    fn promote(
        &mut self,
        rt: &Runtime,
        base: &WeightSet,
        name: &str,
        ckpt_dir: &Path,
        request: bool,
    ) -> Result<()> {
        if request {
            self.c.activations += 1;
        }
        if self.hot.touch(name).is_some() {
            if request {
                self.c.hot_hits += 1;
            }
            return Ok(());
        }
        let id = self.cold.lookup(name).with_context(|| format!("unknown adapter {name:?}"))?;
        let theta = match self.warm.touch(name) {
            Some(t) => {
                if request {
                    self.c.warm_hits += 1;
                }
                t.clone()
            }
            None => {
                if request {
                    self.c.cold_misses += 1;
                }
                let t = self.cold.unpack_theta(id);
                self.warm_insert(name, t.clone());
                t
            }
        };
        let scheme_tag = self.cold.scheme_tag(id).to_string();
        let factors = self.factors_for(rt, &scheme_tag, base, ckpt_dir)?;
        let merged =
            Policy::merge_theta(rt, &self.tier, &scheme_tag, base, &theta, ckpt_dir, factors.as_deref())?;
        self.c.promotions_hot += 1;
        self.hot_bytes += self.resident_model_bytes(merged.n_params());
        self.hot.insert_unbounded(name, merged);
        self.hot_trim();
        Ok(())
    }

    /// Frozen SVD factors for (scheme, base), shared across every tenant
    /// of that scheme — memoized in memory by the base fingerprint so a
    /// million cold activations compute them once.
    fn factors_for(
        &mut self,
        rt: &Runtime,
        scheme_tag: &str,
        base: &WeightSet,
        ckpt_dir: &Path,
    ) -> Result<Option<Arc<FactorSet>>> {
        let scheme = rt.manifest.grad_exe(&self.tier, "grpo", scheme_tag)?.scheme.clone();
        let Some(scheme) = scheme else { return Ok(None) };
        if scheme.kind != "tinylora" && scheme.kind != "lora_xs" {
            return Ok(None);
        }
        let key = (scheme_tag.to_string(), weights_fingerprint(base)?);
        if let Some(f) = self.factors.get(&key) {
            return Ok(Some(f.clone()));
        }
        let tier = rt.manifest.tier(&self.tier)?.clone();
        let f = Arc::new(FactorSet::cached(&tier, base, scheme.r, ckpt_dir)?);
        self.factors.insert(key, f.clone());
        Ok(Some(f))
    }

    fn warm_insert(&mut self, name: &str, theta: Vec<f32>) {
        if self.max_warm == 0 {
            return;
        }
        debug_assert!(!self.warm.contains(name), "warm_insert would double-count {name:?}");
        self.c.promotions_warm += 1;
        self.warm_bytes += theta.len() * 4;
        self.warm.insert_unbounded(name, theta);
        // warm eviction ignores pins: a pinned adapter is hot, and losing
        // its warm copy only costs a cold-tier re-unpack on demotion
        for (_, t) in self.warm.trim(self.max_warm, |_| true) {
            self.warm_bytes -= t.len() * 4;
            self.c.evictions_warm += 1;
        }
    }

    /// Trim hot back to capacity, skipping pinned entries; evicted merged
    /// models are *demoted* — their unpacked theta is re-staged warm (via
    /// the cold record if the warm copy was evicted meanwhile) so the
    /// next activation skips the unpack, only redoing the merge.
    fn hot_trim(&mut self) {
        let pinned = &self.pinned;
        let evicted = self.hot.trim(self.max_resident, |n| !pinned.contains_key(n));
        for (name, w) in evicted {
            self.hot_bytes -= self.resident_model_bytes(w.n_params());
            self.c.evictions_hot += 1;
            self.c.demotions += 1;
            if self.max_warm > 0 && !self.warm.contains(&name) {
                if let Some(id) = self.cold.lookup(&name) {
                    let theta = self.cold.unpack_theta(id);
                    self.warm_insert(&name, theta);
                }
            }
        }
    }

    /// Fraction of served activations answered straight from the hot
    /// tier (no merge) — the router's `merge_hit_rate`.
    pub fn hit_rate(&self) -> f32 {
        if self.c.activations == 0 {
            0.0
        } else {
            self.c.hot_hits as f32 / self.c.activations as f32
        }
    }

    /// Observability snapshot (counts + byte gauges).
    pub fn stats(&self) -> StoreStats {
        StoreStats {
            tenants: self.cold.len(),
            activations: self.c.activations,
            hot_hits: self.c.hot_hits,
            warm_hits: self.c.warm_hits,
            cold_misses: self.c.cold_misses,
            promotions_warm: self.c.promotions_warm,
            promotions_hot: self.c.promotions_hot,
            demotions: self.c.demotions,
            evictions_warm: self.c.evictions_warm,
            evictions_hot: self.c.evictions_hot,
            stored_bytes: self.stored_bytes,
            cold_index_bytes: self.cold.index_bytes(),
            warm_bytes: self.warm_bytes,
            hot_bytes: self.hot_bytes,
            warm_entries: self.warm.len(),
            hot_entries: self.hot.len(),
            refills: self.c.refills,
        }
    }

    /// Zero the event counters (activations, hits, transitions).  Byte
    /// gauges and residency are untouched — this separates a measurement
    /// window from its warmup.
    pub fn reset_stats(&mut self) {
        self.c = Counters::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{SIM_SCHEME, SIM_TIER};

    fn scratch(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("tlrl_store_{name}"));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sim_store(max_resident: usize, max_warm: usize, n: usize) -> AdapterStore {
        let mut store = AdapterStore::with_tiers(SIM_TIER, max_resident, max_warm);
        for i in 0..n {
            store
                .register(&format!("t{i}"), SIM_SCHEME, &[0.01 * (i + 1) as f32; 13], Precision::Bf16)
                .unwrap();
        }
        store
    }

    #[test]
    fn register_and_account_bytes() {
        let mut store = AdapterStore::new("micro", 2);
        store.register("a", "tinylora_r2_u13_all", &[0.0; 13], Precision::Bf16).unwrap();
        store.register("b", "tinylora_r2_u13_all", &[0.0; 13], Precision::F32).unwrap();
        assert_eq!(store.len(), 2);
        // the paper's headline: 13 bf16 params = 26 bytes
        assert_eq!(store.stored_bytes(), 26 + 52);
        assert!(store.register("a", "x", &[0.0], Precision::F32).is_err());
        // a failed register must not move the counter
        assert_eq!(store.stored_bytes(), 78);
        assert_eq!(store.names(), vec!["a", "b"]);
    }

    #[test]
    fn thousands_of_adapters_fit_in_one_model_budget() {
        // storage argument: micro tier model = 139k params * 4B ≈ 557KB;
        // a 26-byte adapter fits > 20_000 times in that budget.
        let mut store = AdapterStore::new("micro", 1);
        for i in 0..1000 {
            store
                .register(&format!("tenant-{i}"), "tinylora_r2_u13_all", &[0.1; 13], Precision::Bf16)
                .unwrap();
        }
        assert_eq!(store.stored_bytes(), 26_000);
        assert!(store.stored_bytes() < store.resident_model_bytes(139_000) / 20);
    }

    /// Satellite: the maintained `stored_bytes` counter must equal the
    /// O(n) arena recomputation at every point of a mixed-precision
    /// registration sequence.
    #[test]
    fn stored_bytes_counter_matches_recomputed_scan() {
        let mut store = AdapterStore::new("micro", 2);
        assert_eq!(store.stored_bytes(), store.recompute_stored_bytes());
        for i in 0..50 {
            let precision = match i % 3 {
                0 => Precision::Bf16,
                1 => Precision::F16,
                _ => Precision::F32,
            };
            let n = 1 + i % 17;
            store.register(&format!("t{i}"), "s", &vec![0.5; n], precision).unwrap();
            assert_eq!(store.stored_bytes(), store.recompute_stored_bytes(), "after insert {i}");
        }
        // duplicate failure leaves both in agreement
        assert!(store.register("t0", "s", &[0.0; 13], Precision::Bf16).is_err());
        assert_eq!(store.stored_bytes(), store.recompute_stored_bytes());
    }

    /// The tier state machine end-to-end on the sim backend: cold miss →
    /// warm+hot promotion; hot eviction → demotion (warm survives); warm
    /// hit skips the cold tier; stats track every transition.
    #[test]
    fn tier_state_machine_promotes_demotes_and_counts() {
        let rt = Runtime::sim(1).unwrap();
        let base = WeightSet::init(&rt.manifest.tier(SIM_TIER).unwrap().clone(), 3).unwrap();
        let dir = scratch("state_machine");
        let mut store = sim_store(1, 2, 4);
        assert_eq!(store.residency("t0"), Residency::Cold);
        assert_eq!(store.residency("nope"), Residency::Unknown);

        // cold miss: t0 becomes warm + hot
        let w0 = store.activate(&rt, &base, "t0", &dir).unwrap();
        assert_eq!(store.residency("t0"), Residency::Hot);
        let st = store.stats();
        assert_eq!((st.activations, st.cold_misses, st.warm_hits, st.hot_hits), (1, 1, 0, 0));
        assert_eq!((st.promotions_warm, st.promotions_hot), (1, 1));
        assert_eq!(st.hot_bytes, store.resident_model_bytes(w0.n_params()));
        assert_eq!(st.warm_bytes, 13 * 4);

        // hot hit: same weights, no new promotion
        let w0b = store.activate(&rt, &base, "t0", &dir).unwrap();
        assert_eq!(w0b.flat(), w0.flat());
        assert_eq!(store.stats().hot_hits, 1);

        // t1 evicts t0 from hot (capacity 1) — t0 demotes to warm
        store.activate(&rt, &base, "t1", &dir).unwrap();
        assert_eq!(store.residency("t1"), Residency::Hot);
        assert_eq!(store.residency("t0"), Residency::Warm);
        let st = store.stats();
        assert_eq!((st.evictions_hot, st.demotions), (1, 1));
        assert_eq!(st.hot_entries, 1);

        // warm hit: t0 re-merges from its warm theta, no cold miss
        let w0c = store.activate(&rt, &base, "t0", &dir).unwrap();
        assert_eq!(w0c.flat(), w0.flat());
        let st = store.stats();
        assert_eq!((st.warm_hits, st.cold_misses), (1, 2));

        // flooding warm (capacity 2) evicts the LRU theta
        store.activate(&rt, &base, "t2", &dir).unwrap();
        store.activate(&rt, &base, "t3", &dir).unwrap();
        let st = store.stats();
        assert!(st.evictions_warm > 0);
        assert_eq!(st.warm_entries, 2);
        assert_eq!(st.warm_bytes, 2 * 13 * 4);
        assert_eq!(st.hot_entries, 1);
        assert_eq!(store.resident_order(), vec!["t3"]);

        // gauges survive a stats reset, counters do not
        store.reset_stats();
        let st = store.stats();
        assert_eq!(st.activations, 0);
        assert_eq!(st.warm_entries, 2);
        assert!(st.hot_bytes > 0 && st.stored_bytes == 4 * 26);
    }

    /// Pinning: a wave wider than the hot tier keeps every wave adapter
    /// resident until `end_wave`, then trims with demotion.
    #[test]
    fn wave_pins_override_hot_capacity_until_end_wave() {
        let rt = Runtime::sim(1).unwrap();
        let base = WeightSet::init(&rt.manifest.tier(SIM_TIER).unwrap().clone(), 3).unwrap();
        let dir = scratch("wave_pins");
        let mut store = sim_store(1, 4, 3);
        let wave: Vec<String> = vec!["t0".into(), "t1".into(), "t2".into()];
        store.begin_wave(&rt, &base, &wave, &dir).unwrap();
        // capacity is 1, but all three pinned adapters are hot
        assert_eq!(store.stats().hot_entries, 3);
        for name in &wave {
            assert_eq!(store.residency(name), Residency::Hot, "{name}");
            assert!(store.checkout_hot(name).is_some(), "{name}");
        }
        // wave checkout counts one activation per adapter, not per request
        assert_eq!(store.stats().activations, 3);
        store.end_wave(&wave);
        let st = store.stats();
        assert_eq!(st.hot_entries, 1);
        assert_eq!((st.evictions_hot, st.demotions), (2, 2));
        // demoted adapters stayed warm
        assert_eq!(store.residency("t0"), Residency::Warm);
        assert_eq!(store.residency("t1"), Residency::Warm);
        assert_eq!(store.residency("t2"), Residency::Hot);
        assert!(store.begin_wave(&rt, &base, &["ghost".to_string()], &dir).is_err());
        // the failed wave released its pin
        store.end_wave(&[]); // no-op
        assert_eq!(store.stats().hot_entries, 1);
    }

    /// A row refill is a one-adapter wave: it pins across the checkout
    /// (so concurrent slots can't evict it), counts one refill + one
    /// activation, and nests with a surrounding wave's pins.
    #[test]
    fn refill_pins_nest_and_count() {
        let rt = Runtime::sim(1).unwrap();
        let base = WeightSet::init(&rt.manifest.tier(SIM_TIER).unwrap().clone(), 3).unwrap();
        let dir = scratch("refill");
        let mut store = sim_store(1, 4, 3);
        // an in-flight wave holds t0 hot; refills of t1/t2 must not evict it
        let wave: Vec<String> = vec!["t0".into()];
        store.begin_wave(&rt, &base, &wave, &dir).unwrap();
        let w1 = store.begin_refill(&rt, &base, "t1", &dir).unwrap();
        let w2 = store.begin_refill(&rt, &base, "t2", &dir).unwrap();
        assert!(w1.n_params() > 0 && w2.n_params() > 0);
        assert_eq!(store.residency("t0"), Residency::Hot);
        assert_eq!(store.stats().hot_entries, 3);
        store.end_refill("t1");
        store.end_refill("t2");
        // refill pins released: hot trims back around the still-pinned wave
        assert_eq!(store.residency("t0"), Residency::Hot);
        assert_eq!(store.stats().hot_entries, 1);
        store.end_wave(&wave);
        let st = store.stats();
        assert_eq!(st.refills, 2);
        assert_eq!(st.activations, 3);
        // a nested refill of the SAME adapter keeps it pinned until both ends
        store.begin_refill(&rt, &base, "t0", &dir).unwrap();
        store.begin_refill(&rt, &base, "t0", &dir).unwrap();
        store.end_refill("t0");
        assert_eq!(store.residency("t0"), Residency::Hot);
        store.end_refill("t0");
        assert!(store.begin_refill(&rt, &base, "ghost", &dir).is_err());
        assert_eq!(store.stats().refills, 4, "failed refill does not count");
    }

    /// `prefetch_warm` stages cold records without activations; a
    /// following wave then counts warm hits, not cold misses.
    #[test]
    fn prefetch_stages_warm_without_counting_activations() {
        let rt = Runtime::sim(1).unwrap();
        let base = WeightSet::init(&rt.manifest.tier(SIM_TIER).unwrap().clone(), 3).unwrap();
        let dir = scratch("prefetch");
        let mut store = sim_store(2, 4, 3);
        store.prefetch_warm(&["t0".into(), "t1".into()]).unwrap();
        let st = store.stats();
        assert_eq!(st.activations, 0);
        assert_eq!(st.promotions_warm, 2);
        assert_eq!(store.residency("t0"), Residency::Warm);
        store.activate(&rt, &base, "t0", &dir).unwrap();
        let st = store.stats();
        assert_eq!((st.warm_hits, st.cold_misses), (1, 0));
        assert!(store.prefetch_warm(&["ghost".to_string()]).is_err());
    }
}
