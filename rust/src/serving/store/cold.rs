//! Cold tier: every registered adapter as a fixed-width packed record in
//! one contiguous grow-only byte arena — 26 bytes for the headline
//! 13-param bf16 config — plus a compact id-interned index (name bytes in
//! a second arena, scheme tags interned to a u16, open-addressing table
//! of u32 record ids).  No per-tenant heap allocations: a million tenants
//! cost `record_width × 1M` data bytes (~26 MB) plus tens of bytes of
//! index per tenant, instead of a `String` + `Vec<u8>` + hash-map entry
//! each.

use anyhow::{bail, Result};

use crate::adapters::packing::{pack_into, unpack, Precision};
use crate::util::fnv1a;

/// Empty slot marker in the open-addressing table.
const EMPTY: u32 = u32::MAX;

/// One adapter's metadata: 20 bytes, offsets into the shared arenas.
#[derive(Clone, Copy)]
struct ColdRecord {
    name_off: u32,
    name_len: u32,
    data_off: u32,
    n_params: u32,
    scheme: u16,
    precision: u8,
}

fn precision_code(p: Precision) -> u8 {
    match p {
        Precision::F32 => 0,
        Precision::Bf16 => 1,
        Precision::F16 => 2,
    }
}

fn code_precision(c: u8) -> Precision {
    match c {
        0 => Precision::F32,
        1 => Precision::Bf16,
        2 => Precision::F16,
        _ => unreachable!("invalid precision code {c}"),
    }
}

/// The arena store itself.  Ids are dense `u32`s in registration order;
/// lookup by name goes through a power-of-two open-addressing table kept
/// under 0.5 load factor (linear probing, fnv1a of the name bytes).
/// Arena offsets are `u32`, capping each arena at 4 GB — 165 M tenants
/// of 26-byte records, far past the 1M design point.
pub struct ColdTier {
    /// packed theta bytes, records laid end to end
    data: Vec<u8>,
    /// adapter name bytes, laid end to end (no per-name String)
    names: Vec<u8>,
    records: Vec<ColdRecord>,
    /// interned scheme tags — a handful of distinct values shared by
    /// millions of tenants
    schemes: Vec<String>,
    /// open-addressing index: slot -> record id (EMPTY = vacant)
    table: Vec<u32>,
}

impl Default for ColdTier {
    fn default() -> Self {
        Self::new()
    }
}

impl ColdTier {
    pub fn new() -> Self {
        Self {
            data: Vec::new(),
            names: Vec::new(),
            records: Vec::new(),
            schemes: Vec::new(),
            table: vec![EMPTY; 16],
        }
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    fn name_bytes(&self, id: u32) -> &[u8] {
        let r = &self.records[id as usize];
        &self.names[r.name_off as usize..(r.name_off + r.name_len) as usize]
    }

    /// Probe the table for `name`: returns the slot where it lives or
    /// would go, plus the record id if present.
    fn probe(&self, name: &[u8]) -> (usize, Option<u32>) {
        let mask = self.table.len() - 1;
        let mut i = (fnv1a(name) as usize) & mask;
        loop {
            match self.table[i] {
                EMPTY => return (i, None),
                id if self.name_bytes(id) == name => return (i, Some(id)),
                _ => i = (i + 1) & mask,
            }
        }
    }

    fn grow_table(&mut self) {
        let mask = self.table.len() * 2 - 1;
        let mut table = vec![EMPTY; self.table.len() * 2];
        for id in 0..self.records.len() as u32 {
            let mut i = (fnv1a(self.name_bytes(id)) as usize) & mask;
            while table[i] != EMPTY {
                i = (i + 1) & mask;
            }
            table[i] = id;
        }
        self.table = table;
    }

    fn intern_scheme(&mut self, tag: &str) -> Result<u16> {
        if let Some(i) = self.schemes.iter().position(|s| s == tag) {
            return Ok(i as u16);
        }
        if self.schemes.len() > u16::MAX as usize {
            bail!("too many distinct scheme tags");
        }
        self.schemes.push(tag.to_string());
        Ok((self.schemes.len() - 1) as u16)
    }

    /// Append a packed record. Duplicate names are an error.
    pub fn insert(
        &mut self,
        name: &str,
        scheme_tag: &str,
        theta: &[f32],
        precision: Precision,
    ) -> Result<u32> {
        if self.records.len() >= EMPTY as usize {
            bail!("cold tier record id space exhausted");
        }
        if self.records.len() + 1 > self.table.len() / 2 {
            self.grow_table();
        }
        let (slot, existing) = self.probe(name.as_bytes());
        if existing.is_some() {
            bail!("adapter {name:?} already registered");
        }
        let width = theta.len() * precision.bytes();
        if self.data.len() + width > u32::MAX as usize
            || self.names.len() + name.len() > u32::MAX as usize
        {
            bail!("cold tier arena exceeds u32 offset space");
        }
        let scheme = self.intern_scheme(scheme_tag)?;
        let id = self.records.len() as u32;
        let name_off = self.names.len() as u32;
        self.names.extend_from_slice(name.as_bytes());
        let data_off = self.data.len() as u32;
        pack_into(theta, precision, &mut self.data);
        self.records.push(ColdRecord {
            name_off,
            name_len: name.len() as u32,
            data_off,
            n_params: theta.len() as u32,
            scheme,
            precision: precision_code(precision),
        });
        self.table[slot] = id;
        Ok(id)
    }

    pub fn lookup(&self, name: &str) -> Option<u32> {
        self.probe(name.as_bytes()).1
    }

    pub fn name(&self, id: u32) -> &str {
        std::str::from_utf8(self.name_bytes(id)).expect("names are inserted as valid utf8")
    }

    pub fn scheme_tag(&self, id: u32) -> &str {
        &self.schemes[self.records[id as usize].scheme as usize]
    }

    pub fn precision(&self, id: u32) -> Precision {
        code_precision(self.records[id as usize].precision)
    }

    pub fn n_params(&self, id: u32) -> usize {
        self.records[id as usize].n_params as usize
    }

    /// The record's packed wire bytes (exactly what `packing::pack` of
    /// the original theta produces).
    pub fn packed(&self, id: u32) -> &[u8] {
        let r = &self.records[id as usize];
        let width = r.n_params as usize * code_precision(r.precision).bytes();
        &self.data[r.data_off as usize..r.data_off as usize + width]
    }

    pub fn unpack_theta(&self, id: u32) -> Vec<f32> {
        unpack(self.packed(id), self.precision(id))
    }

    /// Bytes of packed adapter data (the paper's 26 B × tenants figure).
    pub fn data_bytes(&self) -> usize {
        self.data.len()
    }

    /// Bytes the index costs on top of the data arena: records, name
    /// arena, probe table and interned scheme tags, at allocated
    /// capacity (what the process actually holds).
    pub fn index_bytes(&self) -> usize {
        self.records.capacity() * std::mem::size_of::<ColdRecord>()
            + self.names.capacity()
            + self.table.len() * std::mem::size_of::<u32>()
            + self.schemes.capacity() * std::mem::size_of::<String>()
            + self.schemes.iter().map(|s| s.capacity()).sum::<usize>()
    }

    /// All names, sorted (diagnostic/test walk — O(n log n)).
    pub fn names_sorted(&self) -> Vec<String> {
        let mut v: Vec<String> =
            (0..self.records.len() as u32).map(|id| self.name(id).to_string()).collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapters::packing::pack;
    use crate::util::Pcg64;

    /// Arena record round-trip against `packing::{pack,unpack}` over
    /// arbitrary bit patterns at every precision: the stored bytes must
    /// be exactly `pack(theta)`, and `unpack_theta` must be bitwise equal
    /// to unpacking those bytes (specials — NaN, ±inf, denormals —
    /// included by construction).
    #[test]
    fn record_roundtrip_matches_pack_unpack_over_bit_patterns() {
        let mut rng = Pcg64::new(0xC01D);
        for case in 0..200 {
            let precision = match case % 3 {
                0 => Precision::Bf16,
                1 => Precision::F16,
                _ => Precision::F32,
            };
            let n = 1 + (rng.next_u64() % 32) as usize;
            let theta: Vec<f32> =
                (0..n).map(|_| f32::from_bits(rng.next_u64() as u32)).collect();
            let mut tier = ColdTier::new();
            let id = tier.insert("t", "scheme", &theta, precision).unwrap();
            assert_eq!(tier.packed(id), pack(&theta, precision).as_slice());
            let via_arena = tier.unpack_theta(id);
            let via_pack = unpack(&pack(&theta, precision), precision);
            assert_eq!(via_arena.len(), via_pack.len());
            for (a, b) in via_arena.iter().zip(&via_pack) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    /// The headline config: 13 bf16 params pack to exactly 26 bytes in
    /// the arena, metadata intact.
    #[test]
    fn headline_13_param_record_is_26_bytes() {
        let mut tier = ColdTier::new();
        let theta = [0.25f32; 13];
        let id = tier.insert("tenant-0", "tinylora_r2_u13_all", &theta, Precision::Bf16).unwrap();
        assert_eq!(tier.data_bytes(), 26);
        assert_eq!(tier.packed(id).len(), 26);
        assert_eq!(tier.n_params(id), 13);
        assert_eq!(tier.name(id), "tenant-0");
        assert_eq!(tier.scheme_tag(id), "tinylora_r2_u13_all");
        assert_eq!(tier.precision(id), Precision::Bf16);
        assert_eq!(tier.unpack_theta(id), vec![0.25f32; 13]);
    }

    #[test]
    fn duplicate_names_rejected_lookups_survive_rehash() {
        let mut tier = ColdTier::new();
        for i in 0..10_000 {
            tier.insert(&format!("t{i}"), "s", &[i as f32; 13], Precision::Bf16).unwrap();
        }
        assert!(tier.insert("t42", "s", &[0.0; 13], Precision::Bf16).is_err());
        assert_eq!(tier.len(), 10_000);
        // 26 B × tenants, exactly — the bound the bench gate enforces
        assert_eq!(tier.data_bytes(), 26 * 10_000);
        // every name still resolves after many table rehashes
        for i in (0..10_000).step_by(97) {
            let id = tier.lookup(&format!("t{i}")).unwrap();
            assert_eq!(tier.name(id), format!("t{i}"));
        }
        assert_eq!(tier.lookup("t10000"), None);
        assert_eq!(tier.lookup(""), None);
        // one interned scheme string for all 10k tenants: the index stays
        // tens of bytes per tenant
        assert!(tier.index_bytes() < 64 * 10_000, "index {} B", tier.index_bytes());
    }

    #[test]
    fn mixed_precisions_share_one_arena() {
        let mut tier = ColdTier::new();
        let a = tier.insert("a", "s1", &[1.0; 13], Precision::Bf16).unwrap();
        let b = tier.insert("b", "s2", &[2.0; 13], Precision::F32).unwrap();
        let c = tier.insert("c", "s1", &[3.0; 4], Precision::F16).unwrap();
        assert_eq!(tier.data_bytes(), 26 + 52 + 8);
        assert_eq!(tier.unpack_theta(a), vec![1.0f32; 13]);
        assert_eq!(tier.unpack_theta(b), vec![2.0f32; 13]);
        assert_eq!(tier.unpack_theta(c), vec![3.0f32; 4]);
        assert_eq!(tier.scheme_tag(c), "s1");
        assert_eq!(tier.names_sorted(), vec!["a", "b", "c"]);
    }
}
