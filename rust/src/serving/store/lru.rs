//! Access-ordered LRU map with O(1) touch/insert/evict and an
//! eviction-*value* path: `trim` hands evicted entries back to the
//! caller instead of dropping them, so the tiered store can demote a
//! merged model (hot → warm) rather than throw the merge away.

use std::collections::HashMap;

const NIL: usize = usize::MAX;

struct LruSlot<V> {
    name: String,
    /// `None` only while the slot sits on the free list (so an evicted
    /// value is moved out at eviction time, not at slot reuse).
    value: Option<V>,
    prev: usize,
    next: usize,
}

/// Access-ordered map with O(1) touch, insert and LRU evict: a `HashMap`
/// from name to a slot in an index-linked list (LRU at `head`, MRU at
/// `tail`).  Public only so `benches/bench_trainer.rs` can compare it to
/// the seed's `Vec`-scan — serving code goes through `AdapterStore`.
pub struct ResidentLru<V> {
    map: HashMap<String, usize>,
    slots: Vec<LruSlot<V>>,
    free: Vec<usize>,
    head: usize,
    tail: usize,
}

impl<V> Default for ResidentLru<V> {
    fn default() -> Self {
        Self::new()
    }
}

impl<V> ResidentLru<V> {
    pub fn new() -> Self {
        Self { map: HashMap::new(), slots: Vec::new(), free: Vec::new(), head: NIL, tail: NIL }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Membership without promoting to MRU (read-only probes).
    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    fn unlink(&mut self, i: usize) {
        let (prev, next) = (self.slots[i].prev, self.slots[i].next);
        if prev == NIL {
            self.head = next;
        } else {
            self.slots[prev].next = next;
        }
        if next == NIL {
            self.tail = prev;
        } else {
            self.slots[next].prev = prev;
        }
        self.slots[i].prev = NIL;
        self.slots[i].next = NIL;
    }

    fn push_mru(&mut self, i: usize) {
        self.slots[i].prev = self.tail;
        self.slots[i].next = NIL;
        if self.tail == NIL {
            self.head = i;
        } else {
            self.slots[self.tail].next = i;
        }
        self.tail = i;
    }

    /// Look up and mark as most-recently used. O(1).
    pub fn touch(&mut self, name: &str) -> Option<&V> {
        let &i = self.map.get(name)?;
        if self.tail != i {
            self.unlink(i);
            self.push_mru(i);
        }
        self.slots[i].value.as_ref()
    }

    /// Insert as most-recently used with no capacity bound (the caller
    /// trims separately — the tiered store must insert before it knows
    /// which entries are pinned). Overwriting an existing name replaces
    /// its value and promotes it. O(1).
    pub fn insert_unbounded(&mut self, name: &str, value: V) {
        if let Some(&i) = self.map.get(name) {
            // overwrite existing entry and promote to MRU
            self.slots[i].value = Some(value);
            if self.tail != i {
                self.unlink(i);
                self.push_mru(i);
            }
            return;
        }
        let i = match self.free.pop() {
            Some(i) => {
                self.slots[i] =
                    LruSlot { name: name.to_string(), value: Some(value), prev: NIL, next: NIL };
                i
            }
            None => {
                self.slots.push(LruSlot {
                    name: name.to_string(),
                    value: Some(value),
                    prev: NIL,
                    next: NIL,
                });
                self.slots.len() - 1
            }
        };
        self.map.insert(name.to_string(), i);
        self.push_mru(i);
    }

    /// Insert as most-recently used, evicting the LRU entry when above
    /// `capacity` (clamped to ≥ 1, so the just-inserted entry always
    /// survives). Returns the evicted entry, if any. O(1).
    pub fn insert(&mut self, name: &str, value: V, capacity: usize) -> Option<(String, V)> {
        self.insert_unbounded(name, value);
        self.trim(capacity.max(1), |_| true).pop()
    }

    /// Evict least-recently-used entries until `len() <= capacity`
    /// (exact — capacity 0 empties the map), skipping entries for which
    /// `evictable` returns false.  Returns the evicted (name, value)
    /// pairs in eviction (LRU-first) order; this is the demotion path —
    /// the caller decides what the evicted values become.  If every
    /// remaining entry is unevictable the map is left over capacity.
    pub fn trim<F: Fn(&str) -> bool>(&mut self, capacity: usize, evictable: F) -> Vec<(String, V)> {
        let mut out = Vec::new();
        while self.map.len() > capacity {
            // walk LRU→MRU to the first evictable entry
            let mut i = self.head;
            while i != NIL && !evictable(&self.slots[i].name) {
                i = self.slots[i].next;
            }
            if i == NIL {
                break; // everything left is pinned
            }
            self.unlink(i);
            let name = std::mem::take(&mut self.slots[i].name);
            let value = self.slots[i].value.take().expect("live slot has a value");
            self.map.remove(&name);
            self.free.push(i);
            out.push((name, value));
        }
        out
    }

    /// Names from LRU to MRU (test/diagnostic walk — O(n)).
    pub fn order(&self) -> Vec<String> {
        let mut out = Vec::with_capacity(self.map.len());
        let mut i = self.head;
        while i != NIL {
            out.push(self.slots[i].name.clone());
            i = self.slots[i].next;
        }
        out
    }

    /// All live (name, value) pairs in LRU→MRU order (diagnostics/tests).
    pub fn iter(&self) -> impl Iterator<Item = (&str, &V)> {
        let mut i = self.head;
        std::iter::from_fn(move || {
            if i == NIL {
                return None;
            }
            let s = &self.slots[i];
            i = s.next;
            Some((s.name.as_str(), s.value.as_ref().expect("live slot has a value")))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Eviction order must be access order, not insertion order.
    #[test]
    fn lru_evicts_in_access_order() {
        let mut lru: ResidentLru<u32> = ResidentLru::new();
        assert_eq!(lru.insert("a", 1, 3), None);
        assert_eq!(lru.insert("b", 2, 3), None);
        assert_eq!(lru.insert("c", 3, 3), None);
        assert_eq!(lru.order(), vec!["a", "b", "c"]);
        // touching "a" promotes it past "b" and "c"
        assert_eq!(lru.touch("a"), Some(&1));
        assert_eq!(lru.order(), vec!["b", "c", "a"]);
        // inserting above capacity evicts the LRU entry: "b", not "a" —
        // and hands back b's value (the eviction-callback path)
        assert_eq!(lru.insert("d", 4, 3), Some(("b".to_string(), 2)));
        assert_eq!(lru.order(), vec!["c", "a", "d"]);
        assert_eq!(lru.touch("b"), None);
        // slot reuse: a new insert reuses b's freed slot and keeps order
        assert_eq!(lru.insert("e", 5, 3), Some(("c".to_string(), 3)));
        assert_eq!(lru.order(), vec!["a", "d", "e"]);
        assert_eq!(lru.len(), 3);
    }

    #[test]
    fn lru_overwrite_promotes_without_evicting() {
        let mut lru: ResidentLru<u32> = ResidentLru::new();
        lru.insert("a", 1, 2);
        lru.insert("b", 2, 2);
        assert_eq!(lru.insert("a", 10, 2), None);
        assert_eq!(lru.order(), vec!["b", "a"]);
        assert_eq!(lru.touch("a"), Some(&10));
        assert_eq!(lru.len(), 2);
    }

    /// Capacity 0 through the bounded path clamps to 1 (the insert must
    /// survive its own call); through `trim` it is exact and empties.
    #[test]
    fn capacity_zero_keeps_exactly_the_newest_then_trims_to_nothing() {
        let mut lru: ResidentLru<u32> = ResidentLru::new();
        assert_eq!(lru.insert("a", 1, 0), None);
        assert_eq!(lru.insert("b", 2, 0), Some(("a".to_string(), 1)));
        assert_eq!(lru.len(), 1);
        assert_eq!(lru.order(), vec!["b"]);
        assert_eq!(lru.trim(0, |_| true), vec![("b".to_string(), 2)]);
        assert!(lru.is_empty());
        assert_eq!(lru.order(), Vec::<String>::new());
        // the emptied map keeps working
        assert_eq!(lru.insert("c", 3, 0), None);
        assert_eq!(lru.touch("c"), Some(&3));
    }

    #[test]
    fn capacity_one_insert_touch_evict_sequence() {
        let mut lru: ResidentLru<u32> = ResidentLru::new();
        assert_eq!(lru.insert("a", 1, 1), None);
        assert_eq!(lru.touch("a"), Some(&1));
        assert_eq!(lru.insert("b", 2, 1), Some(("a".to_string(), 1)));
        assert_eq!(lru.touch("a"), None);
        assert_eq!(lru.touch("b"), Some(&2));
        // overwrite at capacity 1 must not evict the entry it replaces
        assert_eq!(lru.insert("b", 20, 1), None);
        assert_eq!(lru.touch("b"), Some(&20));
        assert_eq!(lru.len(), 1);
    }

    /// `trim` skips unevictable (pinned) names and may leave the map over
    /// capacity when everything remaining is pinned.
    #[test]
    fn trim_respects_pins_and_returns_values_lru_first() {
        let mut lru: ResidentLru<u32> = ResidentLru::new();
        lru.insert_unbounded("a", 1);
        lru.insert_unbounded("b", 2);
        lru.insert_unbounded("c", 3);
        lru.insert_unbounded("d", 4);
        // "a" (the LRU) is pinned: trim to 2 must evict b then c instead
        let evicted = lru.trim(2, |n| n != "a");
        assert_eq!(evicted, vec![("b".to_string(), 2), ("c".to_string(), 3)]);
        assert_eq!(lru.order(), vec!["a", "d"]);
        // everything pinned: trim gives up, map stays over capacity
        assert_eq!(lru.trim(0, |_| false), vec![]);
        assert_eq!(lru.len(), 2);
        assert!(lru.contains("a") && lru.contains("d"));
    }

    #[test]
    fn iter_walks_lru_to_mru_without_promoting() {
        let mut lru: ResidentLru<u32> = ResidentLru::new();
        lru.insert_unbounded("a", 1);
        lru.insert_unbounded("b", 2);
        lru.touch("a");
        let pairs: Vec<(String, u32)> = lru.iter().map(|(n, &v)| (n.to_string(), v)).collect();
        assert_eq!(pairs, vec![("b".to_string(), 2), ("a".to_string(), 1)]);
        assert_eq!(lru.order(), vec!["b", "a"]); // iter did not reorder
        assert!(lru.contains("a") && !lru.contains("z"));
    }
}
