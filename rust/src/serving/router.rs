//! Request router: ties the tiered adapter store and the per-adapter
//! scheduler to the shared inference engine. One scheduling round = form
//! a wave, promote+pin the wave's adapters once up front (batch-aware
//! promotion — merges happen off the per-request path and an in-flight
//! adapter can never be evicted), decode through
//! `engine::InferenceEngine`, record latency. This is the
//! vllm-router-shaped component of L3.
//!
//! The router owns no decode logic: padding sentinels, EOS cuts,
//! occupancy-aware geometry selection (partial flushes decode on the
//! smallest baked batch that fits, cutting `padded_rows` waste) and the
//! fused-generate call all live in `engine`. It owns the *serving policy*:
//! which batch goes next (`engine::scheduler::Scheduler`), which merged
//! model is resident (`AdapterStore`), and — via `drain_parallel` — how
//! many independent adapter batches run concurrently
//! (`engine::pool::WorkerPool`, jobs pinned to runtime execution
//! contexts by job id). Nothing here names a backend: the same router
//! serves PJRT artifacts and the sim backend (`tests/e2e_sim.rs` drains
//! full multi-tenant traffic on sim in every CI run).

use std::path::PathBuf;

use anyhow::Result;

use crate::engine::pool::{GenJob, WorkerPool};
use crate::engine::scheduler::{wave_adapters, AdapterBatch, QueuedRequest, SchedPolicy, Scheduler};
use crate::engine::{GenRow, InferenceEngine};
use crate::serving::store::{AdapterStore, StoreStats};
use crate::tasks::generator::Problem;
use crate::tokenizer::Tokenizer;
use crate::util::{Pcg64, Timer};
use crate::weights::WeightSet;

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub adapter: String,
    pub text: String,
    /// virtual seconds from arrival to completion
    pub latency: f64,
    pub batch_occupancy: f32,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct RouterStats {
    pub served: u64,
    pub batches: u64,
    pub mean_latency: f64,
    pub p95_latency: f64,
    pub mean_occupancy: f64,
    /// real wall time spent serving batches (merge + decode), ms
    pub wall_ms: f64,
    pub merge_hit_rate: f32,
    /// padding rows the engine spent on partial flushes (occupancy-aware
    /// geometry keeps this below the fixed-geometry baseline)
    pub padded_rows: u64,
    /// tiered-store snapshot (per-tier hits, promotions, resident bytes)
    pub store: StoreStats,
}

pub struct Router {
    pub store: AdapterStore,
    pub scheduler: Scheduler,
    engine: InferenceEngine,
    base: WeightSet,
    tok: Tokenizer,
    ckpt_dir: PathBuf,
    latencies: Vec<f64>,
    occupancies: Vec<f32>,
    pub responses: Vec<Response>,
    rng: Pcg64,
    /// virtual clock (seconds); advanced by the caller and by batch service
    pub now: f64,
    /// virtual service time per batch (models device occupancy)
    pub service_time: f64,
    /// accumulated real wall time across serve calls, ms
    wall_ms: f64,
}

impl Router {
    pub fn new(
        rt: &crate::runtime::Runtime,
        store: AdapterStore,
        base: WeightSet,
        batch_size: usize,
        max_wait: f64,
        ckpt_dir: PathBuf,
    ) -> Result<Self> {
        let engine = InferenceEngine::new(rt, &store.tier, batch_size)?;
        let batch = engine.batch;
        Ok(Self {
            store,
            scheduler: Scheduler::new(batch, max_wait, SchedPolicy::OccupancyFirst),
            engine,
            base,
            tok: Tokenizer::new(),
            ckpt_dir,
            latencies: Vec::new(),
            occupancies: Vec::new(),
            responses: Vec::new(),
            rng: Pcg64::new(0),
            now: 0.0,
            service_time: 0.05,
            wall_ms: 0.0,
        })
    }

    /// Swap the batch-formation policy (occupancy-first by default).
    pub fn set_policy(&mut self, policy: SchedPolicy) {
        self.scheduler.policy = policy;
    }

    pub fn engine(&self) -> &InferenceEngine {
        &self.engine
    }

    pub fn submit(&mut self, id: u64, adapter: &str, problem: &Problem) {
        self.scheduler.push(QueuedRequest {
            id,
            adapter: adapter.to_string(),
            prompt: problem.prompt.clone(),
            arrival: self.now,
        });
    }

    /// Serve at most one batch; returns how many requests completed.
    pub fn tick(&mut self, rt: &crate::runtime::Runtime) -> Result<usize> {
        let Some(batch) = self.scheduler.next_batch(self.now) else {
            return Ok(0);
        };
        // batch-aware promotion: a formed batch is a one-batch wave — its
        // adapter is merged and pinned before serving, so concurrent
        // promotion pressure can never evict it mid-flight
        let wave = wave_adapters(std::slice::from_ref(&batch));
        self.store.begin_wave(rt, &self.base, &wave, &self.ckpt_dir)?;
        let n = self.serve_batch(rt, batch);
        self.store.end_wave(&wave);
        n
    }

    /// Record completions for one served batch (virtual clock already
    /// advanced to the completion time).
    fn record(&mut self, batch: &AdapterBatch, rows: &[GenRow]) {
        debug_assert_eq!(batch.requests.len(), rows.len());
        let occ = rows.len() as f32 / self.engine.batch as f32;
        for (req, row) in batch.requests.iter().zip(rows) {
            let latency = self.now - req.arrival;
            self.latencies.push(latency);
            self.responses.push(Response {
                id: req.id,
                adapter: req.adapter.clone(),
                text: row.text.clone(),
                latency,
                batch_occupancy: occ,
            });
        }
        self.occupancies.push(occ);
    }

    fn serve_batch(&mut self, rt: &crate::runtime::Runtime, batch: AdapterBatch) -> Result<usize> {
        let t = Timer::start();
        // the wave promotion in `tick` already merged + pinned this
        // adapter; checkout is a hot-tier clone. The activate fallback
        // keeps direct callers (no wave) working.
        let weights = match self.store.checkout_hot(&batch.adapter) {
            Some(w) => w,
            None => self.store.activate(rt, &self.base, &batch.adapter, &self.ckpt_dir)?,
        };
        let problems = crate::serving::serving_problems(&batch);
        // the engine pads short batches with the explicit sentinel and
        // returns exactly one row per real request. Serving decode is
        // greedy (temp 0) and per-row, so its *content* is
        // context-invariant — the one caller where the least-loaded
        // checkout is safe: ticks interleaved with training/bench work
        // steer around busy contexts, and stick to the engine's warm
        // context when the pool is idle.
        let ctx = rt.checkout(self.engine.default_ctx());
        let rows = self.engine.generate_problems_on(
            rt,
            ctx,
            &weights,
            &problems,
            &self.tok,
            0.0,
            &mut self.rng,
        )?;
        self.now += self.service_time;
        self.record(&batch, &rows);
        self.wall_ms += t.millis();
        Ok(rows.len())
    }

    /// Drain the queue completely, one batch at a time.
    pub fn drain(&mut self, rt: &crate::runtime::Runtime) -> Result<()> {
        loop {
            if self.scheduler.pending() == 0 {
                return Ok(());
            }
            if self.tick(rt)? == 0 {
                // nothing flushable yet: advance virtual time to force it
                self.now += self.scheduler.max_wait.max(1e-3);
            }
        }
    }

    /// Drain the queue serving up to `workers` independent adapter batches
    /// concurrently. Activation (merging) stays on this thread — it
    /// mutates the LRU — while decode fans out across the pool. Greedy
    /// serving decode plus per-job seeds keep decoded *texts* identical to
    /// the sequential `drain`; virtual latencies reflect the parallelism
    /// (waves complete in ceil(wave/workers) service intervals).
    pub fn drain_parallel(&mut self, rt: &crate::runtime::Runtime, workers: usize) -> Result<()> {
        let pool = WorkerPool::new(workers);
        loop {
            if self.scheduler.pending() == 0 {
                return Ok(());
            }
            // collect one wave: every batch flushable at the current clock
            let wave = self.scheduler.flush_wave(self.now);
            if wave.is_empty() {
                self.now += self.scheduler.max_wait.max(1e-3);
                continue;
            }
            let t = Timer::start();
            // batch-aware promotion, stage 1: unpack the WHOLE wave's
            // adapters into the warm tier now, off the per-chunk path —
            // each chunk then only pays its own merges
            self.store.prefetch_warm(&wave_adapters(&wave))?;
            // dispatch the wave `workers` batches at a time: only that
            // many merged models are materialized at once (the store's
            // max_resident bound stays meaningful — pins can exceed it
            // only by the chunk width), and each chunk costs one virtual
            // service interval — a wave of k batches takes
            // ceil(k/workers) intervals, same as `drain` when workers==1
            for chunk in wave.chunks(pool.workers) {
                // stage 2: merge + pin this chunk's adapters once, up
                // front; per-batch checkout below is a hot-tier clone
                let chunk_adapters = wave_adapters(chunk);
                self.store.begin_wave(rt, &self.base, &chunk_adapters, &self.ckpt_dir)?;
                let mut jobs = Vec::with_capacity(chunk.len());
                for (k, b) in chunk.iter().enumerate() {
                    let weights = self
                        .store
                        .checkout_hot(&b.adapter)
                        .expect("begin_wave pinned every chunk adapter");
                    jobs.push(GenJob {
                        id: k as u64,
                        weights,
                        problems: crate::serving::serving_problems(b),
                        group: 1,
                        pb: None,
                        temperature: 0.0,
                        // stable per-batch seed (greedy decode ignores it,
                        // but keep parallel == serial regardless)
                        seed: b.requests.first().map(|r| r.id).unwrap_or(0),
                        policy_version: 0,
                    });
                }
                let results = pool.serve(rt, &self.engine, jobs);
                self.store.end_wave(&chunk_adapters);
                let results = results?;
                self.now += self.service_time;
                for (b, res) in chunk.iter().zip(&results) {
                    self.record(b, &res.rows);
                }
            }
            self.wall_ms += t.millis();
        }
    }

    pub fn stats(&self) -> RouterStats {
        let mut lat = self.latencies.clone();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p95 = if lat.is_empty() { 0.0 } else { lat[(lat.len() * 95 / 100).min(lat.len() - 1)] };
        RouterStats {
            served: self.responses.len() as u64,
            batches: self.occupancies.len() as u64,
            mean_latency: if lat.is_empty() { 0.0 } else { lat.iter().sum::<f64>() / lat.len() as f64 },
            p95_latency: p95,
            mean_occupancy: if self.occupancies.is_empty() {
                0.0
            } else {
                self.occupancies.iter().map(|&x| x as f64).sum::<f64>() / self.occupancies.len() as f64
            },
            wall_ms: self.wall_ms,
            merge_hit_rate: self.store.hit_rate(),
            padded_rows: self.engine.stats().padded_rows,
            store: self.store.stats(),
        }
    }
}
