//! Request router: ties the adapter store and the dynamic batcher to the
//! rollout engine.  One scheduling round = pick a batch, activate its
//! adapter (LRU-cached merge), run the fused generate executable, verify
//! and record latency.  This is the vllm-router-shaped component of L3.

use std::path::PathBuf;

use anyhow::Result;

use crate::coordinator::rollout::RolloutEngine;
use crate::serving::batcher::{Batch, DynamicBatcher, Request};
use crate::serving::store::AdapterStore;
use crate::tasks::corpus::prompt_batch;
use crate::tasks::generator::Problem;
use crate::tokenizer::Tokenizer;
use crate::util::Pcg64;
use crate::weights::WeightSet;

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub adapter: String,
    pub text: String,
    /// virtual seconds from arrival to completion
    pub latency: f64,
    pub batch_occupancy: f32,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct RouterStats {
    pub served: u64,
    pub batches: u64,
    pub mean_latency: f64,
    pub p95_latency: f64,
    pub mean_occupancy: f64,
    pub wall_ms: f64,
    pub merge_hit_rate: f32,
}

pub struct Router {
    pub store: AdapterStore,
    pub batcher: DynamicBatcher,
    engine: RolloutEngine,
    base: WeightSet,
    tok: Tokenizer,
    ckpt_dir: PathBuf,
    latencies: Vec<f64>,
    occupancies: Vec<f32>,
    pub responses: Vec<Response>,
    rng: Pcg64,
    /// virtual clock (seconds); advanced by the caller and by batch service
    pub now: f64,
    /// virtual service time per batch (models device occupancy)
    pub service_time: f64,
}

impl Router {
    pub fn new(
        rt: &crate::runtime::Runtime,
        store: AdapterStore,
        base: WeightSet,
        batch_size: usize,
        max_wait: f64,
        ckpt_dir: PathBuf,
    ) -> Result<Self> {
        let engine = RolloutEngine::new(rt, &store.tier, batch_size)?;
        Ok(Self {
            store,
            batcher: DynamicBatcher::new(batch_size, max_wait),
            engine,
            base,
            tok: Tokenizer::new(),
            ckpt_dir,
            latencies: Vec::new(),
            occupancies: Vec::new(),
            responses: Vec::new(),
            rng: Pcg64::new(0),
            now: 0.0,
            service_time: 0.05,
        })
    }

    pub fn submit(&mut self, id: u64, adapter: &str, problem: &Problem) {
        self.batcher.push(Request {
            id,
            adapter: adapter.to_string(),
            prompt: problem.prompt.clone(),
            arrival: self.now,
        });
    }

    /// Serve at most one batch; returns how many requests completed.
    pub fn tick(&mut self, rt: &crate::runtime::Runtime) -> Result<usize> {
        let Some(batch) = self.batcher.next_batch(self.now) else {
            return Ok(0);
        };
        let n = self.serve_batch(rt, batch)?;
        Ok(n)
    }

    fn serve_batch(&mut self, rt: &crate::runtime::Runtime, batch: Batch) -> Result<usize> {
        let weights = self.store.activate(rt, &self.base, &batch.adapter, &self.ckpt_dir)?;
        // pad the prompt list to the executable's baked batch size
        let mut problems: Vec<Problem> = batch
            .requests
            .iter()
            .map(|r| Problem { prompt: r.prompt.clone(), gold: String::new(), answer: 0, suite: "serving" })
            .collect();
        let n_real = problems.len();
        while problems.len() < self.engine.batch {
            problems.push(problems[problems.len() - 1].clone());
        }
        let pb = prompt_batch(&problems, &self.tok, 1, self.engine.t_prefill);
        let roll = self.engine.rollout(rt, &weights, &pb, &self.tok, 0.0, &mut self.rng)?;
        self.now += self.service_time;
        let occ = n_real as f32 / self.engine.batch as f32;
        for (req, row) in batch.requests.iter().zip(roll.rows.iter()) {
            let latency = self.now - req.arrival;
            self.latencies.push(latency);
            self.responses.push(Response {
                id: req.id,
                adapter: req.adapter.clone(),
                text: row.text.clone(),
                latency,
                batch_occupancy: occ,
            });
        }
        self.occupancies.push(occ);
        Ok(n_real)
    }

    /// Drain the queue completely.
    pub fn drain(&mut self, rt: &crate::runtime::Runtime) -> Result<()> {
        loop {
            if self.batcher.pending() == 0 {
                return Ok(());
            }
            if self.tick(rt)? == 0 {
                // nothing flushable yet: advance virtual time to force it
                self.now += self.batcher.max_wait.max(1e-3);
            }
        }
    }

    pub fn stats(&self) -> RouterStats {
        let mut lat = self.latencies.clone();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p95 = if lat.is_empty() { 0.0 } else { lat[(lat.len() * 95 / 100).min(lat.len() - 1)] };
        RouterStats {
            served: self.responses.len() as u64,
            batches: self.occupancies.len() as u64,
            mean_latency: if lat.is_empty() { 0.0 } else { lat.iter().sum::<f64>() / lat.len() as f64 },
            p95_latency: p95,
            mean_occupancy: if self.occupancies.is_empty() {
                0.0
            } else {
                self.occupancies.iter().map(|&x| x as f64).sum::<f64>() / self.occupancies.len() as f64
            },
            wall_ms: 0.0,
            merge_hit_rate: self.store.hit_rate(),
        }
    }
}
