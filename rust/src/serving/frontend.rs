//! Open-loop continuous-batching serving front-end.
//!
//! `Router::drain_parallel` drains in synchronous waves: a wave of k
//! batches costs ceil(k/workers) service intervals and a worker that
//! finishes early idles until the wave boundary. This front-end replaces
//! the wave barrier with *row refill*: the event loop keeps `slots`
//! decode slots busy and refills a slot the instant its batch completes,
//! forming the next batch from whatever is queued *at that instant* —
//! mid-decode with respect to the other slots, no barrier.
//!
//! The loop is split in two halves, and the split carries the
//! determinism argument (DESIGN.md §13):
//!
//!   * [`schedule`] — a PURE discrete-event simulation on the virtual
//!     clock. Arrivals, deadline sheds, batch formation and slot
//!     assignment are a function of (trace, config) alone: no RNG, no
//!     wall time, no decode feedback (service time is a config-declared
//!     model, `base + per_row × rows`). Replaying a saved trace
//!     therefore reproduces every admission decision bit for bit, on
//!     any backend, at any device/worker count.
//!   * [`Frontend::serve_trace`] — decodes the scheduled batches through
//!     the shared engine with per-refill store pinning
//!     (`begin_refill`/`end_refill`, the one-adapter wave of PR 7's
//!     batch-aware promotion protocol). Serving decode is greedy
//!     (temperature 0) and strictly per-row, so decoded *content* is
//!     batch-packing-invariant — continuous refill and wave draining
//!     produce byte-identical per-request texts, which
//!     `tests/e2e_sim.rs` proves against `Router::drain_parallel`.
//!
//! Admission/shedding semantics: every request carries one deadline
//! budget (seconds from arrival). At every event instant the loop sheds
//! queued requests whose wait has reached the budget — shedding can
//! *only* trigger past the deadline (property-tested), so a zero-overload
//! trace is served in full. A dispatched request always had wait <
//! deadline at formation time; in continuous mode that bounds dispatch
//! lag by the budget for every tenant (the fairness bound: by
//! `arrival + deadline` each request has either reached a slot or been
//! shed). The wave-drain baseline (`continuous: false`) reproduces
//! `drain_parallel`'s chunked barriers under the same admission control;
//! requests already captured in a wave can dispatch past their deadline
//! there — counted as `violations` and excluded from goodput, which is
//! exactly the tail-latency cost the refill loop removes.

use std::collections::VecDeque;
use std::path::PathBuf;

use anyhow::{ensure, Result};

use crate::engine::scheduler::{AdapterBatch, QueuedRequest, SchedPolicy, Scheduler};
use crate::engine::InferenceEngine;
use crate::runtime::Runtime;
use crate::serving::router::Response;
use crate::serving::store::AdapterStore;
use crate::serving::trace::ArrivalTrace;
use crate::tokenizer::Tokenizer;
use crate::util::{Pcg64, Timer};
use crate::weights::WeightSet;

#[derive(Clone, Debug, PartialEq)]
pub struct FrontendConfig {
    /// rows per formed batch; must be one of the engine's baked
    /// geometries (validated by [`Frontend::new`])
    pub batch: usize,
    /// concurrent decode slots (device capacity on the virtual clock)
    pub slots: usize,
    /// per-request deadline budget, virtual seconds from arrival; a
    /// request not dispatched within it is shed
    pub deadline: f64,
    /// flush a partial batch once its oldest request waited this long
    pub max_wait: f64,
    /// virtual service time per dispatched batch: base + per_row × rows
    pub service_base: f64,
    pub service_per_row: f64,
    pub policy: SchedPolicy,
    /// true = row refill (continuous batching); false = the wave-drain
    /// baseline (`drain_parallel` barrier semantics)
    pub continuous: bool,
}

impl Default for FrontendConfig {
    fn default() -> Self {
        Self {
            batch: 4,
            slots: 2,
            deadline: 0.4,
            max_wait: 0.05,
            service_base: 0.05,
            service_per_row: 0.0,
            policy: SchedPolicy::DeadlineFlush,
            continuous: true,
        }
    }
}

impl FrontendConfig {
    /// Virtual service seconds for a batch of `rows` real rows.
    pub fn service(&self, rows: usize) -> f64 {
        self.service_base + self.service_per_row * rows as f64
    }
}

/// One dispatch decision of the pure event loop.
#[derive(Clone, Debug)]
pub struct ScheduledBatch {
    pub batch: AdapterBatch,
    /// decode slot the batch occupied
    pub slot: usize,
    /// virtual dispatch / completion instants
    pub start: f64,
    pub done: f64,
}

/// A load-shed decision: the request waited out its deadline budget.
#[derive(Clone, Debug, PartialEq)]
pub struct ShedEvent {
    pub id: u64,
    pub tenant: String,
    pub arrival: f64,
    pub at: f64,
}

/// Full outcome of the pure event loop over one trace.
#[derive(Clone, Debug)]
pub struct Schedule {
    /// dispatches in dispatch order
    pub batches: Vec<ScheduledBatch>,
    pub sheds: Vec<ShedEvent>,
    /// virtual end of the run (last completion or shed)
    pub horizon: f64,
}

/// SLO profile of a schedule on the virtual clock. Pure data — two runs
/// of the same (trace, config) compare bit-equal.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SloStats {
    pub offered: u64,
    pub served: u64,
    pub shed: u64,
    /// served requests whose *dispatch* exceeded the deadline budget
    /// (possible only in wave mode; excluded from goodput)
    pub violations: u64,
    pub batches: u64,
    pub p50_latency: f64,
    pub p99_latency: f64,
    pub mean_latency: f64,
    pub max_latency: f64,
    /// in-deadline completions per virtual second
    pub goodput: f64,
    pub mean_occupancy: f64,
    pub horizon: f64,
}

impl Schedule {
    /// SLO stats under the config the schedule was computed with.
    pub fn slo(&self, cfg: &FrontendConfig) -> SloStats {
        let mut lat: Vec<f64> = Vec::new();
        let mut rows = 0usize;
        let mut violations = 0u64;
        for sb in &self.batches {
            rows += sb.batch.requests.len();
            for r in &sb.batch.requests {
                lat.push(sb.done - r.arrival);
                if sb.start - r.arrival >= cfg.deadline {
                    violations += 1;
                }
            }
        }
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: usize| {
            if lat.is_empty() {
                0.0
            } else {
                lat[(lat.len() * p / 100).min(lat.len() - 1)]
            }
        };
        let served = lat.len() as u64;
        let shed = self.sheds.len() as u64;
        SloStats {
            offered: served + shed,
            served,
            shed,
            violations,
            batches: self.batches.len() as u64,
            p50_latency: q(50),
            p99_latency: q(99),
            mean_latency: if lat.is_empty() {
                0.0
            } else {
                lat.iter().sum::<f64>() / lat.len() as f64
            },
            max_latency: lat.last().copied().unwrap_or(0.0),
            goodput: if self.horizon > 0.0 {
                (served - violations) as f64 / self.horizon
            } else {
                0.0
            },
            mean_occupancy: if self.batches.is_empty() {
                0.0
            } else {
                rows as f64 / (self.batches.len() * cfg.batch) as f64
            },
            horizon: self.horizon,
        }
    }
}

/// The pure open-loop event loop: replay `trace` against `cfg` and
/// return every dispatch and shed decision. Deterministic — see the
/// module docs for why this carries the whole determinism argument.
pub fn schedule(trace: &ArrivalTrace, cfg: &FrontendConfig) -> Schedule {
    let mut sched = Scheduler::new(cfg.batch, cfg.max_wait, cfg.policy);
    let n_slots = cfg.slots.max(1);
    // per-slot completion time; None = idle
    let mut slots: Vec<Option<f64>> = vec![None; n_slots];
    let mut wave_queue: VecDeque<AdapterBatch> = VecDeque::new();
    let events = &trace.events;
    let mut i = 0usize;
    let mut now = 0.0f64;
    let mut batches: Vec<ScheduledBatch> = Vec::new();
    let mut sheds: Vec<ShedEvent> = Vec::new();
    loop {
        // 1. retire completions due by `now` (slot-id order)
        for s in slots.iter_mut() {
            if s.map(|done| done <= now).unwrap_or(false) {
                *s = None;
            }
        }
        // 2. admit arrivals due by `now`
        while i < events.len() && events[i].at <= now {
            let e = &events[i];
            sched.push(QueuedRequest {
                id: e.id,
                adapter: e.tenant.clone(),
                prompt: e.prompt.clone(),
                arrival: e.at,
            });
            i += 1;
        }
        // 3. deadline sweep: shed every queued request whose wait has
        //    reached the budget — the ONLY shedding trigger
        for r in sched.shed_expired(now, cfg.deadline) {
            sheds.push(ShedEvent { id: r.id, tenant: r.adapter, arrival: r.arrival, at: now });
        }
        // 4. dispatch
        if cfg.continuous {
            // row refill: every idle slot takes the next formable batch
            // at this instant, regardless of what other slots are doing
            while let Some(k) = slots.iter().position(|s| s.is_none()) {
                let Some(b) = sched.next_batch(now) else { break };
                let done = now + cfg.service(b.requests.len());
                slots[k] = Some(done);
                batches.push(ScheduledBatch { batch: b, slot: k, start: now, done });
            }
        } else if slots.iter().all(|s| s.is_none()) {
            // wave-drain baseline: batches form only at wave boundaries
            // (all slots idle) and a wave dispatches in chunks of
            // `slots`, each chunk a barrier — `drain_parallel` semantics
            if wave_queue.is_empty() {
                wave_queue.extend(sched.flush_wave(now));
            }
            for (k, s) in slots.iter_mut().enumerate() {
                let Some(b) = wave_queue.pop_front() else { break };
                let done = now + cfg.service(b.requests.len());
                *s = Some(done);
                batches.push(ScheduledBatch { batch: b, slot: k, start: now, done });
            }
        }
        // 5. advance to the next actionable instant. Everything at or
        //    before `now` already fired above, so only strictly-future
        //    candidates count; all candidate values live in the finite
        //    set {arrival, arrival+max_wait, arrival+deadline,
        //    completion times}, so the loop terminates.
        let mut next = f64::INFINITY;
        if i < events.len() && events[i].at > now {
            next = next.min(events[i].at);
        }
        for done in slots.iter().flatten() {
            if *done > now {
                next = next.min(*done);
            }
        }
        if let Some(oldest) = sched.oldest_arrival() {
            // partial-batch flush instant and deadline-expiry instant of
            // the oldest queued request
            for t in [oldest + cfg.max_wait, oldest + cfg.deadline] {
                if t > now {
                    next = next.min(t);
                }
            }
        }
        if !next.is_finite() {
            break;
        }
        now = next;
    }
    let mut horizon = 0.0f64;
    for sb in &batches {
        horizon = horizon.max(sb.done);
    }
    for x in &sheds {
        horizon = horizon.max(x.at);
    }
    Schedule { batches, sheds, horizon }
}

/// The decode driver: owns the serving store, the shared engine and the
/// response log; executes pure schedules against a runtime.
pub struct Frontend {
    pub store: AdapterStore,
    engine: InferenceEngine,
    base: WeightSet,
    tok: Tokenizer,
    ckpt_dir: PathBuf,
    pub cfg: FrontendConfig,
    pub responses: Vec<Response>,
    rng: Pcg64,
    wall_ms: f64,
}

impl Frontend {
    pub fn new(
        rt: &Runtime,
        store: AdapterStore,
        base: WeightSet,
        cfg: FrontendConfig,
        ckpt_dir: PathBuf,
    ) -> Result<Self> {
        let engine = InferenceEngine::new(rt, &store.tier, cfg.batch)?;
        let geometries = engine.geometries();
        ensure!(
            geometries.contains(&cfg.batch),
            "frontend batch {} is not a baked geometry {:?} — refill batches must \
             decode without re-chunking",
            cfg.batch,
            geometries
        );
        ensure!(cfg.slots >= 1, "frontend needs at least one decode slot");
        ensure!(
            cfg.deadline > cfg.max_wait,
            "deadline budget {} must exceed the flush wait {} or every partial \
             batch would shed before it could flush",
            cfg.deadline,
            cfg.max_wait
        );
        ensure!(
            cfg.service(cfg.batch) > 0.0,
            "virtual service time must be positive"
        );
        Ok(Self {
            store,
            engine,
            base,
            tok: Tokenizer::new(),
            ckpt_dir,
            cfg,
            responses: Vec::new(),
            rng: Pcg64::new(0),
            wall_ms: 0.0,
        })
    }

    pub fn engine(&self) -> &InferenceEngine {
        &self.engine
    }

    /// Real wall time spent in decode + merge across `serve_trace` calls.
    pub fn wall_ms(&self) -> f64 {
        self.wall_ms
    }

    /// SLO profile of a schedule under this frontend's config.
    pub fn slo(&self, plan: &Schedule) -> SloStats {
        plan.slo(&self.cfg)
    }

    /// Serve one trace end to end: compute the pure schedule, stage the
    /// trace's adapters warm once, then decode each scheduled batch with
    /// a per-refill pin (`begin_refill`/`end_refill`). Responses carry
    /// virtual-clock latencies from the schedule; returns the schedule
    /// so callers can compute SLO stats or inspect sheds.
    ///
    /// Graceful degradation (DESIGN.md §14): a quarantined execution
    /// context is lost decode capacity, so the pure schedule is computed
    /// with correspondingly fewer slots — goodput and horizon degrade,
    /// but admission control is otherwise unchanged: nothing extra is
    /// shed, deadlines keep applying, and the served/shed partition stays
    /// exactly the deadline-driven one. With zero quarantined contexts
    /// the effective config equals `self.cfg` and this path is
    /// byte-identical to the healthy one.
    pub fn serve_trace(&mut self, rt: &Runtime, trace: &ArrivalTrace) -> Result<Schedule> {
        let lost = rt.supervisor().quarantined_count().min(self.cfg.slots.saturating_sub(1));
        let cfg = FrontendConfig { slots: self.cfg.slots - lost, ..self.cfg.clone() };
        let plan = schedule(trace, &cfg);
        let t = Timer::start();
        // stage every adapter the plan will touch into the warm tier up
        // front (cold unpack off the refill path); refills then pay at
        // most one merge each
        let mut plan_adapters: Vec<String> = Vec::new();
        for sb in &plan.batches {
            if !plan_adapters.contains(&sb.batch.adapter) {
                plan_adapters.push(sb.batch.adapter.clone());
            }
        }
        self.store.prefetch_warm(&plan_adapters)?;
        for sb in &plan.batches {
            let weights = self.store.begin_refill(rt, &self.base, &sb.batch.adapter, &self.ckpt_dir)?;
            let problems = crate::serving::serving_problems(&sb.batch);
            // greedy decode is content-invariant to context choice, so
            // the least-loaded checkout is safe (same as Router)
            let ctx = rt.checkout(self.engine.default_ctx());
            let rows = self.engine.generate_problems_on(
                rt,
                ctx,
                &weights,
                &problems,
                &self.tok,
                0.0,
                &mut self.rng,
            );
            self.store.end_refill(&sb.batch.adapter);
            let rows = rows?;
            debug_assert_eq!(rows.len(), sb.batch.requests.len());
            let occ = rows.len() as f32 / self.engine.batch as f32;
            for (req, row) in sb.batch.requests.iter().zip(&rows) {
                self.responses.push(Response {
                    id: req.id,
                    adapter: req.adapter.clone(),
                    text: row.text.clone(),
                    latency: sb.done - req.arrival,
                    batch_occupancy: occ,
                });
            }
        }
        self.wall_ms += t.millis();
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::trace::TraceConfig;
    use crate::testing::check;

    fn random_trace(rng: &mut Pcg64) -> ArrivalTrace {
        let cfg = TraceConfig {
            seed: rng.below(1 << 20),
            n: 1 + rng.below(60) as usize,
            rate: 20.0 + rng.uniform() as f64 * 300.0,
            burst: 1 + rng.below(3) as usize,
            tenants: 1 + rng.below(6) as usize,
            zipf_s: *rng.choice(&[0.0, 1.1]),
            ..Default::default()
        };
        ArrivalTrace::generate(&cfg).unwrap()
    }

    fn random_cfg(rng: &mut Pcg64, continuous: bool) -> FrontendConfig {
        let max_wait = 0.01 + rng.uniform() as f64 * 0.08;
        FrontendConfig {
            batch: *rng.choice(&[1usize, 2, 4, 8]),
            slots: 1 + rng.below(3) as usize,
            deadline: max_wait * (2.0 + rng.uniform() as f64 * 8.0),
            max_wait,
            service_base: 0.005 + rng.uniform() as f64 * 0.05,
            service_per_row: *rng.choice(&[0.0, 0.002]),
            policy: *rng.choice(&[
                SchedPolicy::OccupancyFirst,
                SchedPolicy::DeadlineFlush,
                SchedPolicy::RoundRobin,
            ]),
            continuous,
        }
    }

    /// The admission/fairness invariant of the refill loop: every offered
    /// request is resolved EXACTLY once, a shed can only trigger once the
    /// wait reached the deadline budget, and in continuous mode every
    /// dispatch happened strictly inside the budget — so no tenant with
    /// pending work is starved beyond `deadline` (the fairness bound).
    #[test]
    fn prop_resolved_exactly_once_and_sheds_only_past_deadline() {
        check("resolved exactly once", 150, |rng| {
            let trace = random_trace(rng);
            let continuous = rng.below(2) == 0;
            let cfg = random_cfg(rng, continuous);
            let plan = schedule(&trace, &cfg);
            let mut seen = std::collections::HashMap::new();
            for sb in &plan.batches {
                for r in &sb.batch.requests {
                    *seen.entry(r.id).or_insert(0u32) += 1;
                    if sb.start < r.arrival {
                        return Err(format!("request {} dispatched before arrival", r.id));
                    }
                    if continuous && sb.start - r.arrival >= cfg.deadline {
                        return Err(format!(
                            "continuous dispatch of {} violated the deadline: wait {:.4} >= {:.4}",
                            r.id,
                            sb.start - r.arrival,
                            cfg.deadline
                        ));
                    }
                }
            }
            for x in &plan.sheds {
                *seen.entry(x.id).or_insert(0) += 1;
                if x.at - x.arrival < cfg.deadline {
                    return Err(format!(
                        "request {} shed at wait {:.4} < deadline {:.4}",
                        x.id,
                        x.at - x.arrival,
                        cfg.deadline
                    ));
                }
            }
            for e in &trace.events {
                match seen.get(&e.id) {
                    Some(1) => {}
                    Some(k) => return Err(format!("request {} resolved {k} times", e.id)),
                    None => return Err(format!("request {} dropped", e.id)),
                }
            }
            Ok(())
        });
    }

    /// Row-refill batch formation never emits a batch exceeding the
    /// configured geometry, never mixes adapters, and never oversubscribes
    /// the slots (at most `slots` batches in flight at any instant).
    #[test]
    fn prop_batches_bounded_by_geometry_and_slots() {
        check("batches bounded", 150, |rng| {
            let trace = random_trace(rng);
            let cfg = random_cfg(rng, rng.below(2) == 0);
            let plan = schedule(&trace, &cfg);
            for sb in &plan.batches {
                let n = sb.batch.requests.len();
                if n == 0 || n > cfg.batch {
                    return Err(format!("batch of {n} rows vs geometry {}", cfg.batch));
                }
                if sb.batch.requests.iter().any(|r| r.adapter != sb.batch.adapter) {
                    return Err("mixed-adapter batch".into());
                }
                if sb.slot >= cfg.slots {
                    return Err(format!("slot {} out of range {}", sb.slot, cfg.slots));
                }
                let overlapping = plan
                    .batches
                    .iter()
                    .filter(|o| o.start < sb.done && o.done > sb.start)
                    .count();
                if overlapping > cfg.slots {
                    return Err(format!(
                        "{overlapping} batches in flight with only {} slots",
                        cfg.slots
                    ));
                }
            }
            Ok(())
        });
    }

    /// Zero overload (effectively infinite budget): nothing sheds, every
    /// request is served, and FIFO order within each tenant survives the
    /// refill loop.
    #[test]
    fn prop_zero_overload_serves_everything_in_tenant_order() {
        check("zero overload", 100, |rng| {
            let trace = random_trace(rng);
            let cfg = FrontendConfig { deadline: 1e9, ..random_cfg(rng, rng.below(2) == 0) };
            let plan = schedule(&trace, &cfg);
            if !plan.sheds.is_empty() {
                return Err(format!("{} sheds with an infinite budget", plan.sheds.len()));
            }
            let slo = plan.slo(&cfg);
            if slo.served as usize != trace.events.len() {
                return Err(format!("served {} of {}", slo.served, trace.events.len()));
            }
            if slo.violations != 0 {
                return Err("violations with an infinite budget".into());
            }
            // FIFO within tenant: dispatch instants non-decreasing in id
            let mut last: std::collections::HashMap<&str, (u64, f64)> = Default::default();
            for sb in &plan.batches {
                for r in &sb.batch.requests {
                    if let Some(&(pid, pstart)) = last.get(r.adapter.as_str()) {
                        if pid < r.id && pstart > sb.start {
                            return Err(format!(
                                "tenant {} served {} (t={}) after {} (t={})",
                                r.adapter, pid, pstart, r.id, sb.start
                            ));
                        }
                    }
                    last.insert(r.adapter.as_str(), (r.id, sb.start));
                }
            }
            Ok(())
        });
    }

    /// The refill loop strictly dominates the wave barrier on completion
    /// time: with identical (trace, config), the continuous schedule's
    /// last completion is never later than wave-drain's.
    #[test]
    fn prop_continuous_finishes_no_later_than_wave_drain() {
        check("continuous dominates", 100, |rng| {
            let trace = random_trace(rng);
            // infinite budget isolates the refill-vs-barrier comparison
            // from shedding differences
            let base = FrontendConfig { deadline: 1e9, ..random_cfg(rng, true) };
            let cont = schedule(&trace, &FrontendConfig { continuous: true, ..base.clone() });
            let wave = schedule(&trace, &FrontendConfig { continuous: false, ..base });
            if cont.horizon > wave.horizon + 1e-9 {
                return Err(format!(
                    "continuous finished at {:.4} after wave-drain {:.4}",
                    cont.horizon, wave.horizon
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn config_validation_rejects_broken_geometry_and_budgets() {
        let rt = Runtime::sim(1).unwrap();
        let tier = rt.manifest.tier("sim").unwrap().clone();
        let base = WeightSet::init(&tier, 0).unwrap();
        let dir = std::env::temp_dir().join("tlrl_frontend_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let store = || AdapterStore::new("sim", 2);
        // batch 3 is not in the baked geometry set {1,2,4,8}
        let bad_geo = FrontendConfig { batch: 3, ..Default::default() };
        assert!(Frontend::new(&rt, store(), base.clone(), bad_geo, dir.clone()).is_err());
        let bad_budget =
            FrontendConfig { deadline: 0.01, max_wait: 0.05, ..Default::default() };
        assert!(Frontend::new(&rt, store(), base.clone(), bad_budget, dir.clone()).is_err());
        let ok = Frontend::new(&rt, store(), base, FrontendConfig::default(), dir.clone());
        assert!(ok.is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }
}
