//! Seeded open-loop arrival traces — the load model of the serving
//! front-end (`serving::frontend`).
//!
//! An open-loop generator decouples arrivals from service: requests land
//! at times drawn from the model regardless of whether the plane keeps
//! up, which is what exposes queueing collapse and makes shedding
//! meaningful (a closed loop self-throttles and can never overload).
//!
//! The generator is deterministic: one `TraceConfig` (seed + Poisson
//! rate + burst width + zipf tenant skew + problem suite) always yields
//! the same `ArrivalTrace`, and a trace serializes to *canonical* JSON
//! through `util::json` (BTreeMap-backed objects, shortest-round-trip
//! float formatting), so `save` → `load` → `schedule` replays to
//! identical admission decisions bit for bit. Traces are therefore
//! committable artifacts: a load test is a (trace, config) pair, not a
//! random process.
//!
//! Arrival model: inter-arrival gaps between burst events are
//! exponential with mean `burst / rate` (so the long-run arrival rate is
//! `rate` requests per virtual second independent of burst width), each
//! event drops `burst` requests at the same instant, and every request
//! picks its tenant by an inverse-CDF zipf(`zipf_s`) draw (`zipf_s = 0`
//! is uniform) — the same skew model as `bench_store`'s trace.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tasks::generator::{Suite, SUITES};
use crate::util::json::{num, obj, s, Value};
use crate::util::Pcg64;

/// RNG stream tag for trace generation (decoupled from training/pool
/// streams so trace seeds never collide with job seeds).
const TRACE_STREAM: u64 = 0x74726163;

const SCHEMA_VERSION: usize = 1;

/// Everything that determines a generated trace (echoed into the JSON so
/// a committed trace documents its own provenance).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceConfig {
    pub seed: u64,
    /// total requests
    pub n: usize,
    /// long-run arrival rate, requests per virtual second
    pub rate: f64,
    /// requests per arrival event (1 = pure Poisson)
    pub burst: usize,
    /// tenant population (`tenant-0` .. `tenant-{tenants-1}`)
    pub tenants: usize,
    /// zipf skew of tenant popularity; 0.0 = uniform
    pub zipf_s: f64,
    /// problem suite prompts are drawn from (`tasks::generator::SUITES`)
    pub suite: String,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self {
            seed: 0,
            n: 64,
            rate: 40.0,
            burst: 1,
            tenants: 8,
            zipf_s: 1.1,
            suite: "gsm8k-syn".into(),
        }
    }
}

/// One request arrival: id, virtual arrival time, tenant, prompt.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    pub id: u64,
    pub at: f64,
    pub tenant: String,
    pub prompt: String,
}

#[derive(Clone, Debug, PartialEq)]
pub struct ArrivalTrace {
    pub config: TraceConfig,
    /// arrivals in non-decreasing `at` order, ids contiguous from 0
    pub events: Vec<TraceEvent>,
}

fn suite_by_name(name: &str) -> Result<&'static Suite> {
    SUITES
        .iter()
        .find(|s| s.name == name)
        .with_context(|| format!("unknown problem suite {name:?}"))
}

/// Inverse-CDF sample of a continuous-approximation zipf(s) rank on
/// `1..=n`, mapped to a 0-based tenant index; s = 0 degrades to uniform.
fn zipf_pick(rng: &mut Pcg64, n: usize, zipf_s: f64) -> usize {
    if n <= 1 || zipf_s <= 0.0 {
        return rng.below(n as u64) as usize;
    }
    // the closed form divides by (1 - s); nudge the singular s = 1 case
    let s = if (zipf_s - 1.0).abs() < 1e-9 { 1.0 + 1e-9 } else { zipf_s };
    let u = rng.uniform() as f64;
    let x = (1.0 + u * ((n as f64).powf(1.0 - s) - 1.0)).powf(1.0 / (1.0 - s));
    (x as usize).saturating_sub(1).min(n - 1)
}

impl ArrivalTrace {
    /// Deterministically generate a trace from its config.
    pub fn generate(cfg: &TraceConfig) -> Result<ArrivalTrace> {
        if cfg.rate <= 0.0 || !cfg.rate.is_finite() {
            bail!("trace rate must be positive, got {}", cfg.rate);
        }
        if cfg.burst == 0 {
            bail!("trace burst width must be >= 1");
        }
        if cfg.tenants == 0 {
            bail!("trace needs at least one tenant");
        }
        let suite = suite_by_name(&cfg.suite)?;
        let mut rng = Pcg64::with_stream(cfg.seed, TRACE_STREAM);
        let mut events = Vec::with_capacity(cfg.n);
        let mut t = 0.0f64;
        let mut id = 0u64;
        while (id as usize) < cfg.n {
            // exponential gap between burst events, mean burst/rate
            let u = rng.uniform() as f64;
            t += -(1.0 - u).ln() * cfg.burst as f64 / cfg.rate;
            for _ in 0..cfg.burst {
                if id as usize >= cfg.n {
                    break;
                }
                let tenant = zipf_pick(&mut rng, cfg.tenants, cfg.zipf_s);
                let p = suite.generate(&mut rng);
                events.push(TraceEvent {
                    id,
                    at: t,
                    tenant: format!("tenant-{tenant}"),
                    prompt: p.prompt,
                });
                id += 1;
            }
        }
        Ok(ArrivalTrace { config: cfg.clone(), events })
    }

    /// Distinct tenant names appearing in the trace, sorted — what a
    /// serving plane must register before replaying it.
    pub fn tenant_names(&self) -> Vec<String> {
        let mut set: Vec<String> = Vec::new();
        for e in &self.events {
            if !set.contains(&e.tenant) {
                set.push(e.tenant.clone());
            }
        }
        set.sort();
        set
    }

    /// Canonical JSON form (BTreeMap key order + shortest-round-trip
    /// floats: serialize → parse → serialize is byte-stable).
    pub fn to_json(&self) -> Value {
        let c = &self.config;
        obj(vec![
            ("kind", s("arrival_trace")),
            ("schema_version", num(SCHEMA_VERSION as f64)),
            (
                "config",
                obj(vec![
                    ("seed", num(c.seed as f64)),
                    ("n", num(c.n as f64)),
                    ("rate", num(c.rate)),
                    ("burst", num(c.burst as f64)),
                    ("tenants", num(c.tenants as f64)),
                    ("zipf_s", num(c.zipf_s)),
                    ("suite", s(&c.suite)),
                ]),
            ),
            (
                "events",
                Value::Arr(
                    self.events
                        .iter()
                        .map(|e| {
                            obj(vec![
                                ("id", num(e.id as f64)),
                                ("at", num(e.at)),
                                ("tenant", s(&e.tenant)),
                                ("prompt", s(&e.prompt)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Value) -> Result<ArrivalTrace> {
        if v.get("kind")?.str()? != "arrival_trace" {
            bail!("not an arrival trace (kind mismatch)");
        }
        let version = v.get("schema_version")?.usize()?;
        if version != SCHEMA_VERSION {
            bail!("arrival trace schema {version} != {SCHEMA_VERSION}");
        }
        let c = v.get("config")?;
        let config = TraceConfig {
            seed: c.get("seed")?.f64()? as u64,
            n: c.get("n")?.usize()?,
            rate: c.get("rate")?.f64()?,
            burst: c.get("burst")?.usize()?,
            tenants: c.get("tenants")?.usize()?,
            zipf_s: c.get("zipf_s")?.f64()?,
            suite: c.get("suite")?.str()?.to_string(),
        };
        let mut events = Vec::new();
        let mut last_at = f64::NEG_INFINITY;
        for (k, e) in v.get("events")?.arr()?.iter().enumerate() {
            let ev = TraceEvent {
                id: e.get("id")?.f64()? as u64,
                at: e.get("at")?.f64()?,
                tenant: e.get("tenant")?.str()?.to_string(),
                prompt: e.get("prompt")?.str()?.to_string(),
            };
            if ev.id != k as u64 {
                bail!("trace event {k} has id {} (ids must be contiguous from 0)", ev.id);
            }
            if ev.at < last_at {
                bail!("trace event {k} arrives at {} before its predecessor {last_at}", ev.at);
            }
            last_at = ev.at;
            events.push(ev);
        }
        Ok(ArrivalTrace { config, events })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        std::fs::write(path, self.to_json().to_string() + "\n")
            .with_context(|| format!("writing trace {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<ArrivalTrace> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {}", path.display()))?;
        Self::from_json(&Value::parse(text.trim())?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::scheduler::SchedPolicy;
    use crate::serving::frontend::{schedule, FrontendConfig};

    fn small_cfg() -> TraceConfig {
        TraceConfig { seed: 7, n: 18, rate: 50.0, burst: 3, tenants: 4, zipf_s: 1.1, ..Default::default() }
    }

    /// Golden determinism: the same config always serializes to the same
    /// canonical JSON string, parse → re-serialize is byte-stable, and
    /// the file round-trip preserves every event exactly.
    #[test]
    fn golden_canonical_json_round_trips_byte_identical() {
        let a = ArrivalTrace::generate(&small_cfg()).unwrap();
        let b = ArrivalTrace::generate(&small_cfg()).unwrap();
        let text = a.to_json().to_string();
        assert_eq!(text, b.to_json().to_string(), "generation is not deterministic");
        // canonical: parse → re-serialize must reproduce the exact bytes
        let reparsed = ArrivalTrace::from_json(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(reparsed, a);
        assert_eq!(reparsed.to_json().to_string(), text, "serialization is not canonical");
        // file round-trip
        let dir = std::env::temp_dir().join("tlrl_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("golden.json");
        a.save(&path).unwrap();
        let loaded = ArrivalTrace::load(&path).unwrap();
        assert_eq!(loaded, a, "save/load changed the trace");
        std::fs::remove_dir_all(&dir).ok();
        // a different seed must actually move the trace
        let other =
            ArrivalTrace::generate(&TraceConfig { seed: 8, ..small_cfg() }).unwrap();
        assert_ne!(other.to_json().to_string(), text);
    }

    /// Replay: a loaded trace drives the frontend's pure schedule to the
    /// same admission decisions as the in-memory original — same batches
    /// (ids, slots, times to the bit) and same sheds.
    #[test]
    fn replayed_trace_yields_identical_admission_decisions() {
        let trace = ArrivalTrace::generate(&TraceConfig {
            n: 40,
            rate: 300.0, // overload the tiny config below so sheds occur
            ..small_cfg()
        })
        .unwrap();
        let dir = std::env::temp_dir().join("tlrl_trace_replay_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        trace.save(&path).unwrap();
        let loaded = ArrivalTrace::load(&path).unwrap();
        std::fs::remove_dir_all(&dir).ok();

        let cfg = FrontendConfig {
            batch: 4,
            slots: 1,
            deadline: 0.08,
            max_wait: 0.02,
            service_base: 0.03,
            service_per_row: 0.0,
            policy: SchedPolicy::DeadlineFlush,
            continuous: true,
        };
        let a = schedule(&trace, &cfg);
        let b = schedule(&loaded, &cfg);
        assert!(!a.sheds.is_empty(), "overload config produced no sheds — test is vacuous");
        let key = |s: &crate::serving::frontend::Schedule| {
            let batches: Vec<(Vec<u64>, usize, u64, u64)> = s
                .batches
                .iter()
                .map(|sb| {
                    (
                        sb.batch.requests.iter().map(|r| r.id).collect(),
                        sb.slot,
                        sb.start.to_bits(),
                        sb.done.to_bits(),
                    )
                })
                .collect();
            let sheds: Vec<(u64, u64)> =
                s.sheds.iter().map(|x| (x.id, x.at.to_bits())).collect();
            (batches, sheds)
        };
        assert_eq!(key(&a), key(&b), "replay diverged from the original trace");
    }

    /// Structural invariants: monotone times, contiguous ids, burst
    /// grouping, tenant names in range, and a sane long-run rate.
    #[test]
    fn structure_rate_and_burst_grouping() {
        let cfg = TraceConfig {
            seed: 3,
            n: 600,
            rate: 80.0,
            burst: 3,
            tenants: 6,
            zipf_s: 1.1,
            ..Default::default()
        };
        let tr = ArrivalTrace::generate(&cfg).unwrap();
        assert_eq!(tr.events.len(), 600);
        for (k, e) in tr.events.iter().enumerate() {
            assert_eq!(e.id, k as u64);
            if k > 0 {
                assert!(e.at >= tr.events[k - 1].at, "arrivals not monotone");
            }
            assert!(e.tenant.starts_with("tenant-"));
            assert!(!e.prompt.is_empty());
        }
        // bursts share a timestamp in groups of `burst`
        for chunk in tr.events.chunks(3) {
            assert!(chunk.iter().all(|e| e.at == chunk[0].at), "burst split across instants");
        }
        // long-run rate within 25% of nominal over 600 arrivals
        let span = tr.events.last().unwrap().at;
        let measured = 600.0 / span;
        assert!(
            (measured - 80.0).abs() < 20.0,
            "measured rate {measured:.1}/s too far from nominal 80/s"
        );
        // zipf skew: the head tenant dominates a uniform share
        let head = tr.events.iter().filter(|e| e.tenant == "tenant-0").count();
        assert!(head > 600 / 6, "zipf head tenant not over-represented ({head}/600)");
        assert!(tr.tenant_names().len() <= 6);
    }

    #[test]
    fn invalid_configs_are_rejected() {
        assert!(ArrivalTrace::generate(&TraceConfig { rate: 0.0, ..small_cfg() }).is_err());
        assert!(ArrivalTrace::generate(&TraceConfig { burst: 0, ..small_cfg() }).is_err());
        assert!(ArrivalTrace::generate(&TraceConfig { tenants: 0, ..small_cfg() }).is_err());
        assert!(ArrivalTrace::generate(&TraceConfig { suite: "nope".into(), ..small_cfg() })
            .is_err());
    }
}
