//! Dynamic batcher: groups queued requests by adapter into fixed-size
//! executable batches (the generate executables have baked batch sizes),
//! trading latency for occupancy — the standard continuous-batching
//! dial, scoped per adapter because a batch runs under ONE merged model.
//!
//! LEGACY: the router now batches through `engine::scheduler::Scheduler`
//! (per-adapter queues, O(#adapters) batch formation, pluggable policies).
//! This single-queue implementation — `next_batch` rescans the whole queue
//! per candidate adapter, O(n²) at depth — is kept as the baseline for
//! `bench_main.rs::bench_scheduler` and for its original unit tests.

use std::collections::VecDeque;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub adapter: String,
    pub prompt: String,
    /// virtual arrival time (the simulation clock, seconds)
    pub arrival: f64,
}

#[derive(Clone, Debug)]
pub struct Batch {
    pub adapter: String,
    pub requests: Vec<Request>,
}

pub struct DynamicBatcher {
    queue: VecDeque<Request>,
    pub batch_size: usize,
    /// flush a partial batch once its oldest request waited this long
    pub max_wait: f64,
}

impl DynamicBatcher {
    pub fn new(batch_size: usize, max_wait: f64) -> Self {
        Self { queue: VecDeque::new(), batch_size, max_wait }
    }

    pub fn push(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Form the next batch at virtual time `now`:
    ///   1. prefer the adapter with a full batch waiting (occupancy);
    ///   2. otherwise, if the oldest request exceeded max_wait, flush its
    ///      adapter's partial batch (latency bound);
    ///   3. otherwise return None (caller advances time / adds requests).
    pub fn next_batch(&mut self, now: f64) -> Option<Batch> {
        if self.queue.is_empty() {
            return None;
        }
        // count per adapter, preserving FIFO order of first appearance
        let mut order: Vec<String> = Vec::new();
        for r in &self.queue {
            if !order.contains(&r.adapter) {
                order.push(r.adapter.clone());
            }
        }
        let full = order.iter().find(|a| {
            self.queue.iter().filter(|r| &r.adapter == *a).count() >= self.batch_size
        });
        let pick = match full {
            Some(a) => Some(a.clone()),
            None => {
                let oldest = self.queue.front().unwrap();
                (now - oldest.arrival >= self.max_wait).then(|| oldest.adapter.clone())
            }
        }?;
        let mut requests = Vec::with_capacity(self.batch_size);
        let mut i = 0;
        while i < self.queue.len() && requests.len() < self.batch_size {
            if self.queue[i].adapter == pick {
                requests.push(self.queue.remove(i).unwrap());
            } else {
                i += 1;
            }
        }
        Some(Batch { adapter: pick, requests })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, adapter: &str, arrival: f64) -> Request {
        Request { id, adapter: adapter.into(), prompt: format!("p{id}"), arrival }
    }

    #[test]
    fn full_batch_preferred() {
        let mut b = DynamicBatcher::new(2, 10.0);
        b.push(req(1, "a", 0.0));
        b.push(req(2, "b", 0.1));
        b.push(req(3, "b", 0.2));
        let batch = b.next_batch(0.3).unwrap();
        assert_eq!(batch.adapter, "b");
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn partial_batch_waits_then_flushes() {
        let mut b = DynamicBatcher::new(4, 1.0);
        b.push(req(1, "a", 0.0));
        assert!(b.next_batch(0.5).is_none(), "should wait for more");
        let batch = b.next_batch(1.5).unwrap();
        assert_eq!(batch.requests.len(), 1);
    }

    #[test]
    fn fifo_within_adapter() {
        let mut b = DynamicBatcher::new(2, 0.0);
        b.push(req(1, "a", 0.0));
        b.push(req(2, "a", 0.1));
        b.push(req(3, "a", 0.2));
        let batch = b.next_batch(0.2).unwrap();
        assert_eq!(batch.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
    }

    #[test]
    fn empty_queue_yields_none() {
        let mut b = DynamicBatcher::new(2, 0.0);
        assert!(b.next_batch(100.0).is_none());
    }
}
