//! Multi-adapter store — the paper's serving motivation made concrete:
//! a 10x smaller adapter lets you hold 10x more tenants in memory
//! (paper §1, citing Punica).
//!
//! Adapters are stored *packed* (theta bytes at their storage precision —
//! 26 bytes for the headline 13-param bf16 config).  Activation folds an
//! adapter into full merged weights; merged models are expensive
//! (n_params * 4 bytes), so only an LRU-bounded set stays resident, in an
//! access-ordered map (O(1) touch/evict — the seed scanned a `Vec`, O(n)
//! per touch with whole-`WeightSet` moves).

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::adapters::packing::{pack, unpack, Precision};
use crate::coordinator::policy::Policy;
use crate::runtime::Runtime;
use crate::weights::WeightSet;

#[derive(Clone)]
pub struct AdapterEntry {
    pub name: String,
    pub scheme_tag: String,
    pub precision: Precision,
    pub packed: Vec<u8>,
}

impl AdapterEntry {
    pub fn bytes(&self) -> usize {
        self.packed.len()
    }
}

const NIL: usize = usize::MAX;

struct LruSlot<V> {
    name: String,
    /// `None` only while the slot sits on the free list (so an evicted
    /// merged model is dropped at eviction time, not at slot reuse).
    value: Option<V>,
    prev: usize,
    next: usize,
}

/// Access-ordered map with O(1) touch, insert and LRU evict: a `HashMap`
/// from name to a slot in an index-linked list (LRU at `head`, MRU at
/// `tail`).  Public only so `benches/bench_trainer.rs` can compare it to
/// the seed's `Vec`-scan — serving code goes through `AdapterStore`.
pub struct ResidentLru<V> {
    map: HashMap<String, usize>,
    slots: Vec<LruSlot<V>>,
    free: Vec<usize>,
    head: usize,
    tail: usize,
}

impl<V> Default for ResidentLru<V> {
    fn default() -> Self {
        Self::new()
    }
}

impl<V> ResidentLru<V> {
    pub fn new() -> Self {
        Self { map: HashMap::new(), slots: Vec::new(), free: Vec::new(), head: NIL, tail: NIL }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    fn unlink(&mut self, i: usize) {
        let (prev, next) = (self.slots[i].prev, self.slots[i].next);
        if prev == NIL {
            self.head = next;
        } else {
            self.slots[prev].next = next;
        }
        if next == NIL {
            self.tail = prev;
        } else {
            self.slots[next].prev = prev;
        }
        self.slots[i].prev = NIL;
        self.slots[i].next = NIL;
    }

    fn push_mru(&mut self, i: usize) {
        self.slots[i].prev = self.tail;
        self.slots[i].next = NIL;
        if self.tail == NIL {
            self.head = i;
        } else {
            self.slots[self.tail].next = i;
        }
        self.tail = i;
    }

    /// Look up and mark as most-recently used. O(1).
    pub fn touch(&mut self, name: &str) -> Option<&V> {
        let &i = self.map.get(name)?;
        if self.tail != i {
            self.unlink(i);
            self.push_mru(i);
        }
        self.slots[i].value.as_ref()
    }

    /// Insert as most-recently used, evicting the LRU entry when above
    /// `capacity`. Returns the evicted name, if any. O(1).
    pub fn insert(&mut self, name: &str, value: V, capacity: usize) -> Option<String> {
        if let Some(&i) = self.map.get(name) {
            // overwrite existing entry and promote to MRU
            self.slots[i].value = Some(value);
            if self.tail != i {
                self.unlink(i);
                self.push_mru(i);
            }
            return None;
        }
        let i = match self.free.pop() {
            Some(i) => {
                self.slots[i] =
                    LruSlot { name: name.to_string(), value: Some(value), prev: NIL, next: NIL };
                i
            }
            None => {
                self.slots.push(LruSlot {
                    name: name.to_string(),
                    value: Some(value),
                    prev: NIL,
                    next: NIL,
                });
                self.slots.len() - 1
            }
        };
        self.map.insert(name.to_string(), i);
        self.push_mru(i);
        if self.map.len() > capacity.max(1) {
            return self.evict_lru();
        }
        None
    }

    fn evict_lru(&mut self) -> Option<String> {
        let i = self.head;
        if i == NIL {
            return None;
        }
        self.unlink(i);
        let name = std::mem::take(&mut self.slots[i].name);
        self.slots[i].value = None; // drop the resident model now
        self.map.remove(&name);
        self.free.push(i);
        Some(name)
    }

    /// Names from LRU to MRU (test/diagnostic walk — O(n)).
    pub fn order(&self) -> Vec<String> {
        let mut out = Vec::with_capacity(self.map.len());
        let mut i = self.head;
        while i != NIL {
            out.push(self.slots[i].name.clone());
            i = self.slots[i].next;
        }
        out
    }
}

pub struct AdapterStore {
    pub tier: String,
    entries: HashMap<String, AdapterEntry>,
    /// access-ordered residency of activated (merged) models
    resident: ResidentLru<WeightSet>,
    pub max_resident: usize,
    pub activations: u64,
    pub hits: u64,
}

impl AdapterStore {
    pub fn new(tier: &str, max_resident: usize) -> Self {
        Self {
            tier: tier.to_string(),
            entries: HashMap::new(),
            resident: ResidentLru::new(),
            max_resident: max_resident.max(1),
            activations: 0,
            hits: 0,
        }
    }

    /// Register a trained adapter (packs theta at the given precision).
    pub fn register(
        &mut self,
        name: &str,
        scheme_tag: &str,
        theta: &[f32],
        precision: Precision,
    ) -> Result<()> {
        if self.entries.contains_key(name) {
            bail!("adapter {name:?} already registered");
        }
        self.entries.insert(
            name.to_string(),
            AdapterEntry {
                name: name.to_string(),
                scheme_tag: scheme_tag.to_string(),
                precision,
                packed: pack(theta, precision),
            },
        );
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<_> = self.entries.keys().cloned().collect();
        v.sort();
        v
    }

    /// Total bytes of all stored adapters (the paper's storage argument).
    pub fn stored_bytes(&self) -> usize {
        self.entries.values().map(|e| e.bytes()).sum()
    }

    /// Bytes one resident merged model costs.
    pub fn resident_model_bytes(&self, n_params: usize) -> usize {
        n_params * 4
    }

    /// Resident merged models from LRU to MRU (diagnostics/tests).
    pub fn resident_order(&self) -> Vec<String> {
        self.resident.order()
    }

    /// Activate an adapter: return merged weights, merging on miss.
    /// `base` is the shared frozen base model.
    pub fn activate(
        &mut self,
        rt: &Runtime,
        base: &WeightSet,
        name: &str,
        ckpt_dir: &std::path::Path,
    ) -> Result<WeightSet> {
        self.activations += 1;
        if let Some(w) = self.resident.touch(name) {
            self.hits += 1;
            return Ok(w.clone());
        }
        let e = self
            .entries
            .get(name)
            .with_context(|| format!("unknown adapter {name:?}"))?
            .clone();
        let theta = unpack(&e.packed, e.precision);
        let mut policy =
            Policy::new(rt, &self.tier, &e.scheme_tag, "grpo", base.clone(), 0, ckpt_dir)?;
        policy.theta = theta;
        policy.remerge(rt)?;
        let merged = policy.merged.clone();
        self.resident.insert(name, merged.clone(), self.max_resident);
        Ok(merged)
    }

    pub fn hit_rate(&self) -> f32 {
        if self.activations == 0 {
            0.0
        } else {
            self.hits as f32 / self.activations as f32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_and_account_bytes() {
        let mut store = AdapterStore::new("micro", 2);
        store.register("a", "tinylora_r2_u13_all", &[0.0; 13], Precision::Bf16).unwrap();
        store.register("b", "tinylora_r2_u13_all", &[0.0; 13], Precision::F32).unwrap();
        assert_eq!(store.len(), 2);
        // the paper's headline: 13 bf16 params = 26 bytes
        assert_eq!(store.entries["a"].bytes(), 26);
        assert_eq!(store.entries["b"].bytes(), 52);
        assert_eq!(store.stored_bytes(), 78);
        assert!(store.register("a", "x", &[0.0], Precision::F32).is_err());
    }

    #[test]
    fn thousands_of_adapters_fit_in_one_model_budget() {
        // storage argument: micro tier model = 139k params * 4B ≈ 557KB;
        // a 26-byte adapter fits > 20_000 times in that budget.
        let mut store = AdapterStore::new("micro", 1);
        for i in 0..1000 {
            store
                .register(&format!("tenant-{i}"), "tinylora_r2_u13_all", &[0.1; 13], Precision::Bf16)
                .unwrap();
        }
        assert_eq!(store.stored_bytes(), 26_000);
        assert!(store.stored_bytes() < store.resident_model_bytes(139_000) / 20);
    }

    fn dummy_weights() -> WeightSet {
        WeightSet { tier: "t".into(), names: vec![], tensors: vec![] }
    }

    /// Eviction order must be access order, not insertion order.
    #[test]
    fn lru_evicts_in_access_order() {
        let mut lru: ResidentLru<u32> = ResidentLru::new();
        assert_eq!(lru.insert("a", 1, 3), None);
        assert_eq!(lru.insert("b", 2, 3), None);
        assert_eq!(lru.insert("c", 3, 3), None);
        assert_eq!(lru.order(), vec!["a", "b", "c"]);
        // touching "a" promotes it past "b" and "c"
        assert_eq!(lru.touch("a"), Some(&1));
        assert_eq!(lru.order(), vec!["b", "c", "a"]);
        // inserting above capacity evicts the LRU entry: "b", not "a"
        assert_eq!(lru.insert("d", 4, 3).as_deref(), Some("b"));
        assert_eq!(lru.order(), vec!["c", "a", "d"]);
        assert_eq!(lru.touch("b"), None);
        // slot reuse: a new insert reuses b's freed slot and keeps order
        assert_eq!(lru.insert("e", 5, 3).as_deref(), Some("c"));
        assert_eq!(lru.order(), vec!["a", "d", "e"]);
        assert_eq!(lru.len(), 3);
    }

    #[test]
    fn lru_overwrite_promotes_without_evicting() {
        let mut lru: ResidentLru<u32> = ResidentLru::new();
        lru.insert("a", 1, 2);
        lru.insert("b", 2, 2);
        assert_eq!(lru.insert("a", 10, 2), None);
        assert_eq!(lru.order(), vec!["b", "a"]);
        assert_eq!(lru.touch("a"), Some(&10));
        assert_eq!(lru.len(), 2);
    }

    /// Same behaviour through the store's activate-shaped surface: resident
    /// order reflects touches (exercised without a runtime by driving the
    /// LRU directly with weight sets).
    #[test]
    fn store_resident_order_is_access_ordered() {
        let mut store = AdapterStore::new("t", 2);
        store.resident.insert("x", dummy_weights(), store.max_resident);
        store.resident.insert("y", dummy_weights(), store.max_resident);
        store.resident.touch("x");
        let evicted = store.resident.insert("z", dummy_weights(), store.max_resident);
        assert_eq!(evicted.as_deref(), Some("y"));
        assert_eq!(store.resident_order(), vec!["x", "z"]);
    }
}
