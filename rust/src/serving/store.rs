//! Multi-adapter store — the paper's serving motivation made concrete:
//! a 10x smaller adapter lets you hold 10x more tenants in memory
//! (paper §1, citing Punica).
//!
//! Adapters are stored *packed* (theta bytes at their storage precision —
//! 26 bytes for the headline 13-param bf16 config).  Activation folds an
//! adapter into full merged weights; merged models are expensive
//! (n_params * 4 bytes), so only an LRU-bounded set stays resident.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::adapters::packing::{pack, unpack, Precision};
use crate::coordinator::policy::Policy;
use crate::runtime::Runtime;
use crate::weights::WeightSet;

#[derive(Clone)]
pub struct AdapterEntry {
    pub name: String,
    pub scheme_tag: String,
    pub precision: Precision,
    pub packed: Vec<u8>,
}

impl AdapterEntry {
    pub fn bytes(&self) -> usize {
        self.packed.len()
    }
}

pub struct AdapterStore {
    pub tier: String,
    entries: HashMap<String, AdapterEntry>,
    /// LRU of activated (merged) models: (adapter name, weights)
    resident: Vec<(String, WeightSet)>,
    pub max_resident: usize,
    pub activations: u64,
    pub hits: u64,
}

impl AdapterStore {
    pub fn new(tier: &str, max_resident: usize) -> Self {
        Self {
            tier: tier.to_string(),
            entries: HashMap::new(),
            resident: Vec::new(),
            max_resident: max_resident.max(1),
            activations: 0,
            hits: 0,
        }
    }

    /// Register a trained adapter (packs theta at the given precision).
    pub fn register(
        &mut self,
        name: &str,
        scheme_tag: &str,
        theta: &[f32],
        precision: Precision,
    ) -> Result<()> {
        if self.entries.contains_key(name) {
            bail!("adapter {name:?} already registered");
        }
        self.entries.insert(
            name.to_string(),
            AdapterEntry {
                name: name.to_string(),
                scheme_tag: scheme_tag.to_string(),
                precision,
                packed: pack(theta, precision),
            },
        );
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<_> = self.entries.keys().cloned().collect();
        v.sort();
        v
    }

    /// Total bytes of all stored adapters (the paper's storage argument).
    pub fn stored_bytes(&self) -> usize {
        self.entries.values().map(|e| e.bytes()).sum()
    }

    /// Bytes one resident merged model costs.
    pub fn resident_model_bytes(&self, n_params: usize) -> usize {
        n_params * 4
    }

    /// Activate an adapter: return merged weights, merging on miss.
    /// `base` is the shared frozen base model.
    pub fn activate(
        &mut self,
        rt: &Runtime,
        base: &WeightSet,
        name: &str,
        ckpt_dir: &std::path::Path,
    ) -> Result<WeightSet> {
        self.activations += 1;
        if let Some(pos) = self.resident.iter().position(|(n, _)| n == name) {
            self.hits += 1;
            let entry = self.resident.remove(pos);
            let w = entry.1.clone();
            self.resident.push(entry); // move to MRU position
            return Ok(w);
        }
        let e = self.entries.get(name).with_context(|| format!("unknown adapter {name:?}"))?.clone();
        let theta = unpack(&e.packed, e.precision);
        let mut policy =
            Policy::new(rt, &self.tier, &e.scheme_tag, "grpo", base.clone(), 0, ckpt_dir)?;
        policy.theta = theta;
        policy.remerge(rt)?;
        let merged = policy.merged.clone();
        if self.resident.len() >= self.max_resident {
            self.resident.remove(0); // evict LRU
        }
        self.resident.push((name.to_string(), merged.clone()));
        Ok(merged)
    }

    pub fn hit_rate(&self) -> f32 {
        if self.activations == 0 {
            0.0
        } else {
            self.hits as f32 / self.activations as f32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_and_account_bytes() {
        let mut store = AdapterStore::new("micro", 2);
        store.register("a", "tinylora_r2_u13_all", &[0.0; 13], Precision::Bf16).unwrap();
        store.register("b", "tinylora_r2_u13_all", &[0.0; 13], Precision::F32).unwrap();
        assert_eq!(store.len(), 2);
        // the paper's headline: 13 bf16 params = 26 bytes
        assert_eq!(store.entries["a"].bytes(), 26);
        assert_eq!(store.entries["b"].bytes(), 52);
        assert_eq!(store.stored_bytes(), 78);
        assert!(store.register("a", "x", &[0.0], Precision::F32).is_err());
    }

    #[test]
    fn thousands_of_adapters_fit_in_one_model_budget() {
        // storage argument: micro tier model = 139k params * 4B ≈ 557KB;
        // a 26-byte adapter fits > 20_000 times in that budget.
        let mut store = AdapterStore::new("micro", 1);
        for i in 0..1000 {
            store
                .register(&format!("tenant-{i}"), "tinylora_r2_u13_all", &[0.1; 13], Precision::Bf16)
                .unwrap();
        }
        assert_eq!(store.stored_bytes(), 26_000);
        assert!(store.stored_bytes() < store.resident_model_bytes(139_000) / 20);
    }
}
