//! `tinylora-rl` CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   pretrain   — train a base model tier from scratch, save checkpoint
//!   train      — GRPO or SFT with an adapter scheme on a pretrained tier;
//!                `--ckpt-every N` saves a resumable TrainState, and
//!                `--resume <ckpt>` continues a killed run bit-identically
//!   tenants    — the multi-tenant training plane: `--n G` GRPO tenants
//!                train independent adapters against one shared backbone
//!                (rollout waves pooled over `--workers` threads) and
//!                register into the serving AdapterStore
//!   eval       — greedy pass@1 on a checkpoint (+ optional --ladder)
//!   bench      — the benchmark subsystem: k-way sampled decoding over the
//!                suite ladder, pass@k/maj@k pooled across --workers,
//!                deterministic JSON + markdown per run
//!   report     — stitch saved bench JSONs into the paper's
//!                recovery-fraction table (baseline/reference/adapters)
//!   sweep      — the paper's LR-sweep protocol for one scheme (runs as a
//!                lrs × seeds tenant grid for GRPO); --bench-k K benches
//!                the winning adapter on the ladder afterwards
//!   serve      — open-loop continuous-batching front-end: replay or
//!                generate a seeded arrival trace, serve it with row
//!                refill + deadline shedding (or the wave-drain
//!                baseline), log SLO rows to JSONL
//!   serve-demo — multi-adapter serving simulation
//!   info       — manifest summary + the paper's Table 1 per tier

use std::path::Path;

use anyhow::Result;

use tinylora_rl::adapters::count;
use tinylora_rl::config::{validate_scheme, Args, Dirs};
use tinylora_rl::coordinator::grpo::{grpo_session_cfg, GrpoLoop};
use tinylora_rl::coordinator::sft::{sft_session_cfg, SftLoop};
use tinylora_rl::coordinator::{
    grpo_session, pretrain, sft_session, GrpoConfig, Policy, PretrainConfig, SftConfig,
};
use tinylora_rl::eval::{evaluate, evaluate_suite_ladder};
use tinylora_rl::metrics::RunLog;
use tinylora_rl::trainer::{TrainSession, TrainState};
use tinylora_rl::weights::WeightSet;
use tinylora_rl::Runtime;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "pretrain" => cmd_pretrain(&args),
        "train" => cmd_train(&args),
        "tenants" => cmd_tenants(&args),
        "eval" => cmd_eval(&args),
        "bench" => cmd_bench(&args),
        "report" => cmd_report(&args),
        "sweep" => cmd_sweep(&args),
        "serve" => cmd_serve(&args),
        "serve-demo" => cmd_serve_demo(&args),
        "info" => cmd_info(&args),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "tinylora-rl — Learning to Reason in 13 Parameters (reproduction)

USAGE: tinylora-rl <command> [--flags]

COMMANDS
  pretrain    --tier micro [--steps 1500] [--lr 3e-3] [--seed 0]
  train       --tier micro --scheme tinylora_r2_u13_all [--algo grpo|sft]
              [--steps 60] [--lr 2e-3] [--suite gsm8k-syn|math-mix]
              [--group 4] [--kl-coef 0] [--clip-c 4] [--eval-n 64] [--seed 0]
              [--ckpt-every 10] [--resume ckpts/<state>.trainstate]
  tenants     --tier micro [--n 4] [--scheme tinylora_r2_u13_all]
              [--steps 40] [--lr 2e-3] [--workers 4] [--devices 1]
              [--precision bf16] [--suite gsm8k-syn] [--seed 0]
              [--max-resident 4] [--max-warm 32]
              [--pipeline] [--staleness 0] [--optimizer-threads 1]
              [--queue-cap 0]   (--pipeline = async off-policy trainer:
              rollouts stream through bounded per-tenant replay queues;
              --staleness S drops groups older than S versions; at S=0
              byte-identical to the synchronous path)
  eval        --tier micro [--suite gsm8k-syn | --ladder] [--n 64]
  bench       --tier micro [--suites gsm8k-syn,math500-syn,amc-syn,aime-syn]
              [--k 4] [--n 0] [--workers 4] [--devices 1] [--temperature -1]
              [--seed 777] [--echo]   (benches the base backbone; adapter
              runs come from `sweep --bench-k`)
  report      --baseline results/bench_<..>.json --reference <..>.json
              [--runs a.json,b.json] [--out results/report.md]
  sweep       --tier micro --scheme <tag> [--algo grpo] [--lrs 5e-4,2e-3,8e-3]
              [--seeds 0,1] [--steps 40] [--workers 1] [--devices 1]
              [--bench-k 0]   (--bench-k K benches base + the winning
              adapter on the ladder; shaped by --suites/--bench-n/
              --temperature)
              [--population] [--rungs 3] [--steps-per-rung 4] [--keep 0.5]
              [--staleness 0] [--optimizer-threads 1] [--queue-cap 0]
              (--population = lrs x seeds grid as ONE tenant set through
              the async pipeline with successive-halving early stopping;
              deterministic JSON to results/population_<tier>_<scheme>.json)
  serve       --tier micro [--trace FILE] [--rate 40] [--requests 64]
              [--deadline-ms 400] [--slots 2] [--mode continuous|wave|both]
              [--tenants 16] [--burst 1] [--zipf 1.1] [--max-wait-ms 50]
              [--service-ms 50] [--service-row-us 0] [--policy deadline]
              [--max-resident 4] [--max-warm 32] [--seed 0]
              (open-loop continuous-batching front-end: replays --trace
              if the file exists, else generates a seeded arrival trace —
              and saves it to --trace when given — then serves it with
              row refill and deadline shedding; SLO rows land in
              results/serve_<tier>.jsonl)
  serve-demo  --tier micro [--tenants 16] [--requests 64] [--workers 1]
              [--devices 1] [--max-resident 4] [--max-warm 32]
              (tiered store: --max-resident bounds hot merged models,
              --max-warm bounds warm unpacked thetas; every tenant
              always fits cold at ~26 B packed)
  info        [--tier micro]

Shared: --artifacts DIR --ckpts DIR --results DIR --echo
        --devices D  (execution-context pool: pool jobs pin to contexts,
        up to D device executions overlap; results stay byte-identical)
        --backend pjrt|sim  (sim = hermetic pure-rust backend, zero
        artifacts needed; use --tier sim. Env: TINYLORA_BACKEND)
        --sim-workers W  (sim only: row workers per execute call,
        0 = serial; byte-identical at any W. Env: TINYLORA_SIM_WORKERS)
        --sim-faults SPEC  (sim only: scripted chaos, e.g.
        \"die@ctx1:after=3,slow@ctx0:us=500,compile-fail=2\". Clauses:
        die@ctxN:after=K | slow@ctxN:us=K|ms=K | hang@ctxN:us=K|ms=K |
        exec-fail@ctxN:n=K | compile-fail=K | panic=K. The supervisor
        retries/requeues around the faults; decoded bytes stay identical
        to the fault-free run. Env: TINYLORA_SIM_FAULTS)"
    );
}

/// Build the runtime with `--devices D` execution contexts (default 1,
/// i.e. the classic single-client behaviour). Every subcommand accepts
/// the flag; `serve-demo`/`bench`/`sweep`/`tenants` are where the
/// device-parallel pool actually pays off (pool jobs pin to contexts).
///
/// `--backend sim` (or `TINYLORA_BACKEND=sim`) swaps the PJRT artifact
/// path for the hermetic pure-rust simulator — the whole CLI (pretrain →
/// train → bench → serve-demo, `--tier sim`) then runs with no
/// `artifacts/` directory at all. `--sim-workers W` fans each sim
/// execute call's batch rows across W threads (pure throughput knob:
/// results are byte-identical at any W).
fn runtime(args: &Args, dirs: &Dirs) -> Result<Runtime> {
    let devices = args.usize("devices", 1)?;
    let backend = args.str(
        "backend",
        &std::env::var("TINYLORA_BACKEND").unwrap_or_else(|_| "pjrt".into()),
    );
    match backend.as_str() {
        "pjrt" => Runtime::with_devices(&dirs.artifacts, devices),
        "sim" => {
            let workers = args.usize("sim-workers", 0)?;
            // scripted fault injection for chaos runs: --sim-faults wins,
            // TINYLORA_SIM_FAULTS is the env fallback; a malformed spec
            // fails loudly here instead of silently running fault-free
            let spec = args.str(
                "sim-faults",
                &std::env::var("TINYLORA_SIM_FAULTS").unwrap_or_default(),
            );
            let mut opts = if spec.trim().is_empty() {
                tinylora_rl::runtime::SimOptions::default()
            } else {
                tinylora_rl::runtime::SimOptions::parse_faults(&spec)?
            };
            opts.row_workers = workers;
            Runtime::sim_with(devices, opts)
        }
        other => anyhow::bail!("--backend {other:?} is not a backend (pjrt|sim)"),
    }
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    let dirs = Dirs::from_args(args);
    let rt = runtime(args, &dirs)?;
    let tier = args.str("tier", "micro");
    let cfg = PretrainConfig {
        suite: args.str("suite", "gsm8k-syn"),
        steps: args.usize("steps", 1500)?,
        lr: args.f32("lr", 3e-3)?,
        warmup: args.u64("warmup", 50)?,
        seed: args.u64("seed", 0)?,
        log_every: args.usize("log-every", 50)?,
    };
    let mut log = RunLog::new(Some(&dirs.results.join(format!("pretrain_{tier}.jsonl"))), true);
    let t = tinylora_rl::util::Timer::start();
    let res = pretrain(&rt, &tier, &cfg, &dirs.ckpts, &mut log)?;
    println!(
        "pretrained {tier}: final loss {:.4} in {:.1}s -> {}",
        res.final_loss,
        t.secs(),
        WeightSet::ckpt_path(&dirs.ckpts, &tier).display()
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let dirs = Dirs::from_args(args);
    let rt = runtime(args, &dirs)?;
    let tier = args.str("tier", "micro");
    let scheme = args.str("scheme", "tinylora_r2_u13_all");
    let algo = args.str("algo", "grpo");
    validate_scheme(&rt.manifest, &tier, &scheme, &algo)?;
    let base = Policy::load_base(&rt, &tier, &dirs.ckpts)?;
    let policy = Policy::new(&rt, &tier, &scheme, &algo, base, args.u64("seed", 0)?, &dirs.ckpts)?;
    let mut log = RunLog::new(
        Some(&dirs.results.join(format!("train_{tier}_{scheme}_{algo}.jsonl"))),
        true,
    );

    let suite = args.str("suite", "gsm8k-syn");
    let eval_suite = args.str("eval-suite", if suite == "math-mix" { "math500-syn" } else { &suite });
    let eval_n = args.usize("eval-n", 64)?;
    let before = evaluate(&rt, &tier, &policy.merged, &eval_suite, eval_n, 777)?;
    println!(
        "[{tier}/{scheme}] {} trainable params; baseline {eval_suite} accuracy {:.3}",
        policy.trainable_params(),
        before.accuracy
    );

    // resumable-state plumbing: --ckpt-every N saves a TrainState as the
    // run progresses; --resume <path> continues one bit-identically
    let resume_state = match args.flags.get("resume") {
        Some(p) => {
            let st = TrainState::load(Path::new(p))?;
            println!("resuming {} from step {} ({p})", st.algo, st.step);
            Some(st)
        }
        None => None,
    };
    let ckpt_every = args.usize("ckpt-every", 0)?;
    // seed-keyed so concurrent multi-seed runs don't clobber each other
    let seed = args.u64("seed", 0)?;
    let state_path = dirs.ckpts.join(format!("{tier}_{scheme}_{algo}_s{seed}.trainstate"));

    let policy = match algo.as_str() {
        "grpo" => {
            let cfg = GrpoConfig {
                suite,
                group: args.usize("group", 4)?,
                steps: args.usize("steps", 60)?,
                lr: args.f32("lr", 2e-3)?,
                warmup: args.u64("warmup", 5)?,
                temperature: args.f32("temperature", 1.0)?,
                clip_c: args.f32("clip-c", 4.0)?,
                kl_coef: args.f32("kl-coef", 0.0)?,
                grad_clip: args.f32("grad-clip", 1.0)?,
                seed: args.u64("seed", 0)?,
            };
            let mut sess = match &resume_state {
                Some(st) => {
                    let lp = GrpoLoop::new(&rt, policy, cfg.clone())?;
                    TrainSession::resume(&rt, lp, grpo_session_cfg(&cfg), st)?
                }
                None => grpo_session(&rt, policy, cfg)?,
            };
            if ckpt_every > 0 {
                sess.cfg.ckpt_every = ckpt_every;
                sess.cfg.ckpt_path = Some(state_path.clone());
            }
            sess.run(&rt, &mut log)?;
            sess.into_loop().policy
        }
        "sft" => {
            let cfg = SftConfig {
                suite,
                steps: args.usize("steps", 60)?,
                lr: args.f32("lr", 2e-3)?,
                warmup: args.u64("warmup", 5)?,
                grad_clip: args.f32("grad-clip", 1.0)?,
                seed: args.u64("seed", 0)?,
            };
            let mut sess = match &resume_state {
                Some(st) => {
                    let lp = SftLoop::new(&rt, policy, cfg.clone())?;
                    TrainSession::resume(&rt, lp, sft_session_cfg(&cfg), st)?
                }
                None => sft_session(&rt, policy, cfg)?,
            };
            if ckpt_every > 0 {
                sess.cfg.ckpt_every = ckpt_every;
                sess.cfg.ckpt_path = Some(state_path.clone());
            }
            sess.run(&rt, &mut log)?;
            sess.into_loop().policy
        }
        other => anyhow::bail!("unknown algo {other}"),
    };
    if ckpt_every > 0 {
        println!("train state: {}", state_path.display());
    }

    let after = evaluate(&rt, &tier, &policy.merged, &eval_suite, eval_n, 777)?;
    log.log_eval(&tier, &scheme, policy.trainable_params(), &eval_suite, after.accuracy);
    println!(
        "[{tier}/{scheme}] {eval_suite}: {:.3} -> {:.3} ({} params, {} bytes)",
        before.accuracy,
        after.accuracy,
        policy.trainable_params(),
        policy.update_bytes()
    );
    let rs = rt.stats();
    println!(
        "runtime: {} compiles ({:.0} ms), {} runs ({:.0} ms)",
        rs.compiles, rs.compile_ms, rs.runs, rs.run_ms
    );
    Ok(())
}

/// The multi-tenant training plane: G GRPO tenants train independent
/// adapters against one shared backbone, rollout waves pooled across
/// workers, finished adapters registered into the serving store.
fn cmd_tenants(args: &Args) -> Result<()> {
    use tinylora_rl::adapters::packing::Precision;
    use tinylora_rl::serving::AdapterStore;
    use tinylora_rl::trainer::{TenantSpec, TenantTrainer};

    let dirs = Dirs::from_args(args);
    let rt = runtime(args, &dirs)?;
    let tier = args.str("tier", "micro");
    let scheme = args.str("scheme", "tinylora_r2_u13_all");
    validate_scheme(&rt.manifest, &tier, &scheme, "grpo")?;
    let base = Policy::load_base(&rt, &tier, &dirs.ckpts)?;
    let n = args.usize("n", 4)?.max(1);
    let workers = args.usize("workers", n.min(4))?.max(1);
    let seed0 = args.u64("seed", 0)?;
    let precision = Precision::parse(&args.str("precision", "bf16"))
        .ok_or_else(|| anyhow::anyhow!("bad --precision (f32|bf16|f16)"))?;
    let proto = GrpoConfig {
        suite: args.str("suite", "gsm8k-syn"),
        group: args.usize("group", 4)?,
        steps: args.usize("steps", 40)?,
        lr: args.f32("lr", 2e-3)?,
        kl_coef: args.f32("kl-coef", 0.0)?,
        ..Default::default()
    };
    let specs: Vec<TenantSpec> = (0..n)
        .map(|i| TenantSpec {
            name: format!("tenant-{i}"),
            scheme_tag: scheme.clone(),
            cfg: GrpoConfig { seed: seed0 + i as u64, ..proto.clone() },
            precision,
        })
        .collect();

    let mut log = RunLog::new(
        Some(&dirs.results.join(format!("tenants_{tier}_{scheme}.jsonl"))),
        args.bool("echo"),
    );
    let mut tt = TenantTrainer::new(&rt, &base, specs, workers, &dirs.ckpts)?;
    let t0 = tinylora_rl::util::Timer::start();
    // --pipeline decouples rollout production from optimizer consumption
    // behind bounded per-tenant replay queues (trainer::pipeline); at
    // --staleness 0 it is byte-identical to the synchronous wave path
    let pstats = if args.bool("pipeline") {
        let pcfg = tinylora_rl::trainer::PipelineConfig {
            max_staleness: args.u64("staleness", 0)?,
            optimizer_threads: args.usize("optimizer-threads", 1)?,
            queue_cap: args.usize("queue-cap", 0)?,
        };
        Some(pcfg)
    } else {
        None
    };
    let (outcomes, pipe) = match pstats {
        Some(pcfg) => {
            let (o, st) =
                tinylora_rl::trainer::pipeline::train_async(&rt, &mut tt, &pcfg, &mut log, workers > 1)?;
            (o, Some((pcfg, st)))
        }
        None => (tt.train(&rt, &mut log, workers > 1)?, None),
    };
    let wall = t0.secs();

    let mut store = AdapterStore::with_tiers(
        &tier,
        args.usize("max-resident", 4)?,
        args.usize("max-warm", 32)?,
    );
    tt.register_into(&mut store)?;
    let st = store.stats();
    println!(
        "{n} tenants x {} steps in {wall:.1}s ({} workers) — {} adapters in {} bytes cold (+{} index)",
        proto.steps,
        workers,
        store.len(),
        st.stored_bytes,
        st.cold_index_bytes
    );
    for o in &outcomes {
        println!(
            "  {:<12} seed {:<3} lr {:.1e} | {} params | reward {:.3} fmt {:.3}",
            o.name, o.seed, o.lr, o.trainable_params, o.final_reward, o.final_format_rate
        );
    }
    let es = tt.engine().stats();
    println!(
        "engine: {} generate calls | {} rows (+{} padding) | {:.0} ms decode",
        es.batches, es.rows, es.padded_rows, es.gen_ms
    );
    if let Some((pcfg, st)) = pipe {
        println!(
            "pipeline: S={} q={} opt={} | produced {} consumed {} dropped {} (gap {}) | ratio {:.4} clip {:.4} | {:.1} steps/s",
            pcfg.max_staleness,
            pcfg.window(),
            pcfg.optimizer_threads.max(1),
            st.produced,
            st.consumed,
            st.dropped_stale,
            st.max_version_gap,
            st.mean_ratio,
            st.frac_clipped,
            st.steps_per_s,
        );
    }
    print_context_stats(&rt);
    Ok(())
}

/// Per-context runtime counters — shows how device-parallel work spread
/// across the execution-context pool (one line per `--devices` context),
/// plus the supervision plane's fault counters whenever anything fired.
fn print_context_stats(rt: &Runtime) {
    use tinylora_rl::runtime::Health;
    let sv = rt.supervisor().stats();
    if sv.retries + sv.requeues + sv.quarantines + sv.deaths + sv.hangs > 0 {
        println!(
            "  supervisor: live {}/{} | {} retries | {} requeues | {} quarantines | {} deaths | {} hangs",
            rt.supervisor().live_count(),
            rt.devices(),
            sv.retries,
            sv.requeues,
            sv.quarantines,
            sv.deaths,
            sv.hangs,
        );
    }
    if rt.devices() <= 1 {
        return;
    }
    for (i, cs) in rt.per_context_stats().iter().enumerate() {
        let health = match rt.supervisor().health(i) {
            Health::Live => "",
            Health::Suspect => " | SUSPECT",
            Health::Quarantined => " | QUARANTINED",
        };
        println!(
            "  ctx {i}: {} compiles ({:.0} ms) | {} runs ({:.0} ms){health}",
            cs.compiles, cs.compile_ms, cs.runs, cs.run_ms
        );
    }
}

fn cmd_eval(args: &Args) -> Result<()> {
    let dirs = Dirs::from_args(args);
    let rt = runtime(args, &dirs)?;
    let tier = args.str("tier", "micro");
    let base = Policy::load_base(&rt, &tier, &dirs.ckpts)?;
    let n = args.usize("n", 64)?;
    if args.bool("ladder") {
        println!("{:<16} {:>8} {:>8} {:>8}", "suite", "acc", "fmt", "len");
        for (name, ev) in evaluate_suite_ladder(&rt, &tier, &base, n, 777)? {
            println!(
                "{:<16} {:>8.3} {:>8.3} {:>8.1}",
                name, ev.accuracy, ev.format_rate, ev.mean_response_len
            );
        }
    } else {
        let suite = args.str("suite", "gsm8k-syn");
        let ev = evaluate(&rt, &tier, &base, &suite, n, 777)?;
        println!(
            "{tier} on {suite}: accuracy {:.3} format {:.3} len {:.1} (n={})",
            ev.accuracy, ev.format_rate, ev.mean_response_len, ev.n
        );
    }
    Ok(())
}

/// The benchmark subsystem's CLI face: k-way sampled decoding over the
/// suite ladder, pooled across workers, deterministic JSON + markdown out.
fn cmd_bench(args: &Args) -> Result<()> {
    use tinylora_rl::eval::bench::{run_ladder, BenchConfig};

    let dirs = Dirs::from_args(args);
    let rt = runtime(args, &dirs)?;
    let tier = args.str("tier", "micro");
    let base = Policy::load_base(&rt, &tier, &dirs.ckpts)?;
    let cfg = BenchConfig {
        tier: tier.clone(),
        suites: args.str_list("suites", &[]),
        k: args.usize("k", 4)?,
        n: args.usize("n", 0)?,
        temperature: args.f32("temperature", -1.0)?,
        seed: args.u64("seed", 777)?,
        workers: args.usize("workers", 1)?,
        batch: args.usize("batch", 0)?,
    };
    // this command only ever decodes the base backbone, so the run is
    // labeled "base"/0 params — adapter bench runs come from
    // `sweep --bench-k` (winning merged weights) or
    // `experiments::recovery_report`, never from relabeling base scores
    let run = run_ladder(&rt, &base, "base", 0, &cfg)?;

    let mut log =
        RunLog::new(Some(&dirs.results.join(format!("bench_{tier}.jsonl"))), args.bool("echo"));
    for sc in &run.scores {
        log.log_bench(&format!("{tier}/base"), 0, sc);
    }
    let json_path = dirs.results.join(format!("bench_{tier}_base_k{}.json", cfg.k));
    run.save(&json_path)?;
    println!("{}", run.to_markdown());
    println!(
        "ladder: {} suites x k={} in {:.1}s ({} workers) -> {}",
        run.scores.len(),
        cfg.k,
        run.wall_secs,
        cfg.workers,
        json_path.display()
    );
    Ok(())
}

/// Stitch saved bench JSONs into the recovery-fraction report. Pure file
/// plumbing — needs no artifacts/runtime, so reports can be regenerated
/// anywhere.
fn cmd_report(args: &Args) -> Result<()> {
    use tinylora_rl::eval::bench::BenchRun;
    use tinylora_rl::eval::report::RecoveryReport;

    let dirs = Dirs::from_args(args);
    let baseline = BenchRun::load(Path::new(&args.req("baseline")?))?;
    let reference = BenchRun::load(Path::new(&args.req("reference")?))?;
    let runs: Vec<BenchRun> = args
        .str_list("runs", &[])
        .iter()
        .filter(|p| !p.is_empty())
        .map(|p| BenchRun::load(Path::new(p)))
        .collect::<Result<_>>()?;
    let report = RecoveryReport::new(baseline, reference, runs)?;

    let md = report.to_markdown();
    let out_md = args.str("out", &dirs.results.join("report.md").to_string_lossy());
    let out_md = Path::new(&out_md);
    if let Some(dir) = out_md.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(out_md, &md)?;
    let out_json = out_md.with_extension("json");
    std::fs::write(&out_json, report.to_json().to_string() + "\n")?;
    println!("{md}");
    println!("report: {} + {}", out_md.display(), out_json.display());
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    use tinylora_rl::coordinator::sweep::{sweep_scheme_full, SweepConfig};
    use tinylora_rl::eval::bench::{run_ladder_with, BenchConfig};
    use tinylora_rl::InferenceEngine;
    let dirs = Dirs::from_args(args);
    let rt = runtime(args, &dirs)?;
    let tier = args.str("tier", "micro");
    let scheme = args.str("scheme", "tinylora_r2_u13_all");
    let algo = args.str("algo", "grpo");
    validate_scheme(&rt.manifest, &tier, &scheme, &algo)?;
    let base = Policy::load_base(&rt, &tier, &dirs.ckpts)?;
    let cfg = SweepConfig {
        tier: tier.clone(),
        scheme_tag: scheme.clone(),
        algo,
        suite: args.str("suite", "gsm8k-syn"),
        steps: args.usize("steps", 40)?,
        lrs: args.f32_list("lrs", &[5e-4, 2e-3, 8e-3])?,
        seeds: args
            .str_list("seeds", &["0"])
            .iter()
            .map(|s| s.parse().unwrap())
            .collect(),
        eval_suite: args.str("eval-suite", "gsm8k-syn"),
        eval_n: args.usize("eval-n", 64)?,
        workers: args.usize("workers", 1)?,
        batch: args.usize("batch", 0)?,
    };
    // --population: the whole lrs × seeds grid trains as one tenant set
    // through the async pipeline with successive-halving early stopping —
    // the losers freeze after each rung, so populations of thousands cost
    // ~keep^rungs of the naive grid. Ranks by training reward (no per-rung
    // evals); run a plain sweep on the survivors when accuracy matters.
    if args.bool("population") {
        use tinylora_rl::coordinator::sweep::{sweep_population, HalvingConfig};
        let hcfg = HalvingConfig {
            rungs: args.usize("rungs", 3)?.max(1),
            steps_per_rung: args.usize("steps-per-rung", 4)?.max(1),
            keep: args.f32("keep", 0.5)?,
            pipeline: tinylora_rl::trainer::PipelineConfig {
                max_staleness: args.u64("staleness", 0)?,
                optimizer_threads: args.usize("optimizer-threads", 1)?,
                queue_cap: args.usize("queue-cap", 0)?,
            },
        };
        let mut log = RunLog::new(
            Some(&dirs.results.join(format!("population_{tier}_{scheme}.jsonl"))),
            args.bool("echo"),
        );
        let out = sweep_population(&rt, &base, &cfg, &hcfg, &dirs.ckpts, &mut log)?;
        let best = &out.members[out.best];
        println!(
            "population {} of {} | {} rungs x {} steps | winner {} (lr {:.1e} seed {}) score {:.3}",
            out.scheme_tag,
            out.population,
            hcfg.rungs,
            hcfg.steps_per_rung,
            best.name,
            best.lr,
            best.seed,
            best.score,
        );
        for r in &out.rungs {
            println!(
                "  rung {}: {} active -> {} survivors | mean score {:.3}",
                r.rung, r.active, r.survivors, r.mean_score
            );
        }
        println!(
            "  pipeline: produced {} consumed {} dropped {} | ratio {:.4}",
            out.stats.produced, out.stats.consumed, out.stats.dropped_stale, out.stats.mean_ratio
        );
        let path = dirs.results.join(format!("population_{tier}_{scheme}.json"));
        std::fs::write(&path, out.to_json().to_string() + "\n")?;
        println!("saved {}", path.display());
        print_context_stats(&rt);
        return Ok(());
    }

    // validate the post-training bench config BEFORE spending minutes on
    // the sweep: a k that doesn't divide the decode batch, or a typo'd
    // suite name, fails in ms here instead of after training
    let bench_k = args.usize("bench-k", 0)?;
    let bench_suites = args.str_list("suites", &[]);
    let bench_batch = if cfg.batch > 0 { cfg.batch } else { rt.manifest.batch.roll };
    if bench_k > 0 {
        if bench_batch % bench_k != 0 {
            anyhow::bail!("--bench-k {bench_k} must divide the decode batch {bench_batch}");
        }
        for name in &bench_suites {
            tinylora_rl::eval::bench::bench_suite(name)?;
        }
    }

    let mut log = RunLog::new(
        Some(&dirs.results.join(format!("sweep_{tier}_{scheme}.jsonl"))),
        args.bool("echo"),
    );
    let (out, best_merged) = sweep_scheme_full(&rt, &base, &cfg, &dirs.ckpts, &mut log)?;
    println!(
        "{}: {} params | baseline {:.3} -> best {:.3} @ lr {:.1e}",
        out.scheme_tag, out.trainable_params, out.baseline_accuracy, out.accuracy, out.best_lr
    );

    // post-training eval in the same call: bench the base model and the
    // winning adapter over the pass@k/maj@k ladder; `report` stitches the
    // saved JSONs (plus a full-FT reference) into the recovery table
    if bench_k > 0 {
        let bcfg = BenchConfig {
            tier: tier.clone(),
            suites: bench_suites,
            k: bench_k,
            n: args.usize("bench-n", 0)?,
            temperature: args.f32("temperature", -1.0)?,
            seed: 777,
            workers: cfg.workers,
            batch: cfg.batch,
        };
        // one engine for both runs — same (tier, batch) geometry
        let engine = InferenceEngine::new(&rt, &tier, bench_batch)?;
        let base_run = run_ladder_with(&rt, &engine, &base, "base", 0, &bcfg)?;
        let adapter_run =
            run_ladder_with(&rt, &engine, &best_merged, &scheme, out.trainable_params, &bcfg)?;
        let base_path = dirs.results.join(format!("bench_{tier}_base_k{bench_k}.json"));
        let adapter_path = dirs.results.join(format!("bench_{tier}_{scheme}_k{bench_k}.json"));
        base_run.save(&base_path)?;
        adapter_run.save(&adapter_path)?;
        println!("{}", adapter_run.to_markdown());
        println!("bench: {} + {}", base_path.display(), adapter_path.display());
    }
    Ok(())
}

/// Open-loop serving: generate (or replay) a deterministic arrival trace
/// and push it through the continuous-batching front-end, the wave-drain
/// baseline, or both. All admission/SLO numbers are computed on the
/// virtual clock by the pure schedule, so replaying the same trace file
/// reproduces them exactly — only `wall_ms` measures this machine.
fn cmd_serve(args: &Args) -> Result<()> {
    use tinylora_rl::adapters::packing::Precision;
    use tinylora_rl::serving::{
        AdapterStore, ArrivalTrace, Frontend, FrontendConfig, SchedPolicy, TraceConfig,
    };
    use tinylora_rl::util::Pcg64;

    let dirs = Dirs::from_args(args);
    let rt = runtime(args, &dirs)?;
    let tier = args.str("tier", "micro");
    let base = Policy::load_base(&rt, &tier, &dirs.ckpts)?;

    let trace_path = args.str("trace", "");
    let trace = if !trace_path.is_empty() && Path::new(&trace_path).exists() {
        let t = ArrivalTrace::load(Path::new(&trace_path))?;
        println!("replaying trace {trace_path} ({} requests, rate {}/s)", t.events.len(), t.config.rate);
        t
    } else {
        let tcfg = TraceConfig {
            seed: args.u64("seed", 0)?,
            n: args.usize("requests", 64)?,
            rate: args.f32("rate", 40.0)? as f64,
            burst: args.usize("burst", 1)?,
            tenants: args.usize("tenants", 16)?,
            zipf_s: args.f32("zipf", 1.1)? as f64,
            suite: args.str("suite", "gsm8k-syn"),
        };
        let t = ArrivalTrace::generate(&tcfg)?;
        if !trace_path.is_empty() {
            t.save(Path::new(&trace_path))?;
            println!("saved generated trace -> {trace_path}");
        }
        t
    };
    let rate = trace.config.rate;

    let policy = match args.str("policy", "deadline").as_str() {
        "occupancy" => SchedPolicy::OccupancyFirst,
        "roundrobin" | "rr" => SchedPolicy::RoundRobin,
        _ => SchedPolicy::DeadlineFlush,
    };
    let fcfg = FrontendConfig {
        batch: rt.manifest.batch.serve,
        slots: args.usize("slots", 2)?,
        deadline: args.f32("deadline-ms", 400.0)? as f64 / 1e3,
        max_wait: args.f32("max-wait-ms", 50.0)? as f64 / 1e3,
        service_base: args.f32("service-ms", 50.0)? as f64 / 1e3,
        service_per_row: args.f32("service-row-us", 0.0)? as f64 / 1e6,
        policy,
        continuous: true,
    };

    // one store per mode: each run gets identical tier state, so the
    // continuous-vs-wave comparison is apples to apples
    let tenants = trace.tenant_names();
    let build_store = || -> Result<AdapterStore> {
        let mut store = AdapterStore::with_tiers(
            &tier,
            args.usize("max-resident", 4)?,
            args.usize("max-warm", 32)?,
        );
        let mut rng = Pcg64::new(11);
        for name in &tenants {
            let theta: Vec<f32> = (0..13).map(|_| rng.normal() * 0.01).collect();
            store.register(name, "tinylora_r2_u13_all", &theta, Precision::Bf16)?;
        }
        Ok(store)
    };

    let mut log = RunLog::new(
        Some(&dirs.results.join(format!("serve_{tier}.jsonl"))),
        args.bool("echo"),
    );
    let modes: &[&str] = match args.str("mode", "continuous").as_str() {
        "wave" => &["wave"],
        "both" => &["continuous", "wave"],
        _ => &["continuous"],
    };
    for mode in modes {
        let cfg = FrontendConfig { continuous: *mode == "continuous", ..fcfg.clone() };
        let mut fe = Frontend::new(&rt, build_store()?, base.clone(), cfg, dirs.ckpts.clone())?;
        let plan = fe.serve_trace(&rt, &trace)?;
        let slo = fe.slo(&plan);
        println!(
            "[{mode}] served {}/{} shed {} | p50 {:.3}s p99 {:.3}s | goodput {:.1}/s occ {:.2} | {} batches, {} refills | wall {:.0} ms",
            slo.served,
            slo.offered,
            slo.shed,
            slo.p50_latency,
            slo.p99_latency,
            slo.goodput,
            slo.mean_occupancy,
            slo.batches,
            fe.store.stats().refills,
            fe.wall_ms(),
        );
        log.log_serve(&tier, mode, rate, &slo, fe.wall_ms());
        log.log_store(&tier, &fe.store.stats());
    }
    log.log_supervisor(&tier, &rt.supervisor().stats(), rt.devices(), rt.supervisor().live_count());
    print_context_stats(&rt);
    Ok(())
}

fn cmd_serve_demo(args: &Args) -> Result<()> {
    use tinylora_rl::adapters::packing::Precision;
    use tinylora_rl::serving::{AdapterStore, Router};
    use tinylora_rl::tasks::generator::SUITES;
    use tinylora_rl::util::Pcg64;

    let dirs = Dirs::from_args(args);
    let rt = runtime(args, &dirs)?;
    let tier = args.str("tier", "micro");
    let base = Policy::load_base(&rt, &tier, &dirs.ckpts)?;
    let tenants = args.usize("tenants", 16)?;
    let n_requests = args.usize("requests", 64)?;

    let mut store = AdapterStore::with_tiers(
        &tier,
        args.usize("max-resident", 4)?,
        args.usize("max-warm", 32)?,
    );
    let mut rng = Pcg64::new(11);
    for i in 0..tenants {
        let theta: Vec<f32> = (0..13).map(|_| rng.normal() * 0.01).collect();
        store.register(&format!("tenant-{i}"), "tinylora_r2_u13_all", &theta, Precision::Bf16)?;
    }
    println!(
        "{} adapters stored in {} bytes cold (+{} index; one resident merged model: {} bytes)",
        store.len(),
        store.stored_bytes(),
        store.stats().cold_index_bytes,
        store.resident_model_bytes(rt.manifest.tier(&tier)?.n_params)
    );

    let workers = args.usize("workers", 1)?;
    let mut router = Router::new(&rt, store, base, rt.manifest.batch.serve, 0.2, dirs.ckpts.clone())?;
    for i in 0..n_requests {
        // zipf-ish tenant popularity
        let tenant = (rng.uniform().powf(2.0) * tenants as f32) as usize % tenants;
        let p = SUITES[0].generate(&mut rng);
        router.submit(i as u64, &format!("tenant-{tenant}"), &p);
        router.now += 0.01;
        router.tick(&rt)?;
    }
    if workers > 1 {
        router.drain_parallel(&rt, workers)?;
    } else {
        router.drain(&rt)?;
    }
    let stats = router.stats();
    println!(
        "served {} requests in {} batches | occupancy {:.2} | latency mean {:.3}s p95 {:.3}s | merge hit-rate {:.2} | wall {:.0} ms",
        stats.served, stats.batches, stats.mean_occupancy, stats.mean_latency, stats.p95_latency,
        stats.merge_hit_rate, stats.wall_ms
    );
    let st = stats.store;
    println!(
        "store: hits hot/warm {}/{} cold-misses {} | promos warm/hot {}/{} demotions {} | evictions hot/warm {}/{} | resident warm/hot {}/{} B",
        st.hot_hits, st.warm_hits, st.cold_misses, st.promotions_warm, st.promotions_hot,
        st.demotions, st.evictions_hot, st.evictions_warm, st.warm_bytes, st.hot_bytes
    );
    let mut log = RunLog::new(
        Some(&dirs.results.join(format!("serve_{tier}.jsonl"))),
        args.bool("echo"),
    );
    log.log_store(&tier, &st);
    let es = router.engine().stats();
    println!(
        "engine: {} generate calls | {} rows (+{} padding) | {:.0} ms decode",
        es.batches, es.rows, es.padded_rows, es.gen_ms
    );
    print_context_stats(&rt);
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dirs = Dirs::from_args(args);
    let rt = runtime(args, &dirs)?;
    println!(
        "platform: {} [{} backend] ({} execution contexts)",
        rt.platform(),
        rt.backend_name(),
        rt.devices()
    );
    println!("artifacts: {} executables", rt.manifest.executables.len());
    for (name, t) in &rt.manifest.tiers {
        println!(
            "tier {name}: d={} L={} H={} f={} | {} params",
            t.d, t.n_layers, t.n_heads, t.f, t.n_params
        );
    }
    // the sim backend has exactly one tier; default to it there so
    // `tinylora-rl info --backend sim` works with no flags
    let default_tier = if rt.backend_name() == "sim" { "sim" } else { "micro" };
    let tier = args.str("tier", default_tier);
    let t = rt.manifest.tier(&tier)?;
    println!("\n{}", count::table1(t)?);
    Ok(())
}
