//! Small substrates: deterministic RNG, JSON, timing, stable hashing.

pub mod json;
pub mod rng;
pub mod timer;

pub use rng::Pcg64;
pub use timer::Timer;

/// FNV-1a 64-bit — stable across runs/platforms (used to derive seeds).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Mean of a slice (0.0 when empty).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f32>() / xs.len() as f32
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / xs.len() as f32).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_is_stable() {
        assert_eq!(fnv1a(b"tinylora"), fnv1a(b"tinylora"));
        assert_ne!(fnv1a(b"a"), fnv1a(b"b"));
    }

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((std_dev(&[2.0, 4.0]) - 1.0).abs() < 1e-6);
    }
}
