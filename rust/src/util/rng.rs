//! Deterministic PCG64 RNG (no external crates available offline).
//!
//! PCG-XSL-RR 128/64: state advances by an LCG in u128, output is a
//! xorshift + rotate of the high/low halves. Deterministic across platforms.

#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Self { state: 0, inc };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Raw generator state for checkpointing, as u64 halves of
    /// (state, inc): `[state_hi, state_lo, inc_hi, inc_lo]`.  Restoring via
    /// [`Pcg64::from_state`] continues the stream bit-identically.
    pub fn state(&self) -> [u64; 4] {
        [
            (self.state >> 64) as u64,
            self.state as u64,
            (self.inc >> 64) as u64,
            self.inc as u64,
        ]
    }

    /// Rebuild a generator from a [`Pcg64::state`] snapshot.
    pub fn from_state(s: [u64; 4]) -> Self {
        Self {
            state: ((s[0] as u128) << 64) | s[1] as u128,
            inc: ((s[2] as u128) << 64) | s[3] as u128,
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        // 24 high bits -> exactly representable in f32
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // multiply-shift; bias is negligible for our n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 <= 1e-9 {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * (u1 as f64).ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2 as f64).cos()) as f32;
        }
    }

    /// Fill a vector with N(0, std).
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    pub fn uniform_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.uniform()).collect()
    }

    /// Pick one element uniformly.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_snapshot_resumes_bit_identically() {
        let mut a = Pcg64::with_stream(7, 0x6772706f);
        for _ in 0..17 {
            a.next_u64();
        }
        let snap = a.state();
        let tail: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let mut b = Pcg64::from_state(snap);
        let resumed: Vec<u64> = (0..32).map(|_| b.next_u64()).collect();
        assert_eq!(tail, resumed);
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_range_and_centered() {
        let mut r = Pcg64::new(7);
        let xs: Vec<f32> = (0..20000).map(|_| r.uniform()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let m = xs.iter().sum::<f32>() / xs.len() as f32;
        assert!((m - 0.5).abs() < 0.02, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(9);
        let xs: Vec<f32> = (0..20000).map(|_| r.normal()).collect();
        let m = xs.iter().sum::<f32>() / xs.len() as f32;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / xs.len() as f32;
        assert!(m.abs() < 0.05, "mean {m}");
        assert!((v - 1.0).abs() < 0.08, "var {v}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg64::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
