//! Minimal JSON parser + writer (serde is unavailable in the offline image).
//!
//! Supports the full JSON grammar we produce/consume: objects, arrays,
//! strings (with escapes), numbers, booleans, null.  The parser is
//! recursive-descent over bytes; numbers are kept as f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing junk at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (key {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key).filter(|v| !matches!(v, Value::Null)),
            _ => None,
        }
    }

    pub fn obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn usize(&self) -> Result<usize> {
        Ok(self.f64()? as usize)
    }

    pub fn i64(&self) -> Result<i64> {
        Ok(self.f64()? as i64)
    }

    pub fn boolean(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.arr()?.iter().map(|v| v.usize()).collect()
    }

    // -- writer ---------------------------------------------------------------

    // inherent by design: `Display` would invite `{}` formatting of huge
    // nested values in hot logging paths; serialization is explicit here
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for building JSON output.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(x: &str) -> Value {
    Value::Str(x.to_string())
}

pub fn arr_f32(xs: &[f32]) -> Value {
    Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected eof"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, got {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected , or }} got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(v));
                }
                c => bail!("expected , or ] got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape"),
                    }
                }
                c => {
                    // re-assemble multi-byte utf-8
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(txt.parse()?))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\nthere", "d": true}, "e": null}"#;
        let v = Value::parse(src).unwrap();
        let v2 = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn accessors() {
        let v = Value::parse(r#"{"x": {"y": [10, 20]}}"#).unwrap();
        assert_eq!(v.get("x").unwrap().get("y").unwrap().usize_vec().unwrap(), vec![10, 20]);
        assert!(v.get("z").is_err());
    }

    #[test]
    fn string_escapes() {
        let v = Value::parse(r#""a\"b\\cA\n""#).unwrap();
        assert_eq!(v.str().unwrap(), "a\"b\\cA\n");
    }

    #[test]
    fn unicode_passthrough() {
        let v = Value::parse("\"héllo→\"").unwrap();
        assert_eq!(v.str().unwrap(), "héllo→");
    }

    #[test]
    fn rejects_junk() {
        assert!(Value::parse("{\"a\": 1} x").is_err());
        assert!(Value::parse("[1, ]").is_err());
    }

    #[test]
    fn integers_written_without_decimal() {
        assert_eq!(Value::Num(3.0).to_string(), "3");
        assert_eq!(Value::Num(3.5).to_string(), "3.5");
    }
}
