//! Timing helpers shared by the trainers, metrics and the bench harness.

use std::time::Instant;

pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// Run `f` `iters` times and return (mean_ms, min_ms, max_ms).
pub fn time_iters<F: FnMut()>(iters: usize, mut f: F) -> (f64, f64, f64) {
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(0.0, f64::max);
    (mean, min, max)
}

#[cfg(test)]
mod tests {
    #[test]
    fn time_iters_counts() {
        let mut n = 0;
        let (mean, min, max) = super::time_iters(5, || n += 1);
        assert_eq!(n, 5);
        assert!(min <= mean && mean <= max);
    }
}
