//! Metrics: JSONL run logs + console progress.  Every trainer step, sweep
//! point, eval and bench score lands in one append-only file so figures
//! and reports can be regenerated from logged data.
//!
//! Row schema is one JSON object per line with a `kind` discriminator
//! (`step` / `pretrain` / `sweep_point` / `eval` / `bench`); all rows are
//! written through `util::json`, so they parse back losslessly (tested).

use std::fs::File;
use std::io::Write;
use std::path::Path;

use crate::coordinator::grpo::StepRecord;
use crate::coordinator::policy::Policy;
use crate::coordinator::sft::SftRecord;
use crate::util::json::{num, obj, s, Value};

pub struct RunLog {
    file: Option<File>,
    pub echo: bool,
    pub rows: Vec<Value>,
}

impl RunLog {
    pub fn new(path: Option<&Path>, echo: bool) -> Self {
        let file = path.map(|p| {
            if let Some(dir) = p.parent() {
                std::fs::create_dir_all(dir).ok();
            }
            File::options().create(true).append(true).open(p).expect("open run log")
        });
        Self { file, echo, rows: Vec::new() }
    }

    pub fn null() -> Self {
        Self { file: None, echo: false, rows: Vec::new() }
    }

    pub fn log(&mut self, row: Value) {
        if let Some(f) = &mut self.file {
            let _ = writeln!(f, "{}", row.to_string());
        }
        self.rows.push(row);
    }

    pub fn log_step(&mut self, algo: &str, policy: &Policy, rec: &StepRecord) {
        if self.echo {
            println!(
                "[{algo} {}/{} p={}] step {:>4} reward {:.3} len {:>5.1} fmt {:.2} kl {:+.4} loss {:+.4} ({:.0}+{:.0} ms)",
                policy.tier.name,
                policy.scheme_tag,
                policy.trainable_params(),
                rec.step,
                rec.reward,
                rec.response_len,
                rec.format_rate,
                rec.stats.kl_k1,
                rec.stats.loss,
                rec.rollout_ms,
                rec.grad_ms,
            );
        }
        self.log(obj(vec![
            ("kind", s("step")),
            ("algo", s(algo)),
            ("tier", s(&policy.tier.name)),
            ("scheme", s(&policy.scheme_tag)),
            ("params", num(policy.trainable_params() as f64)),
            ("step", num(rec.step as f64)),
            ("reward", num(rec.reward as f64)),
            ("response_len", num(rec.response_len as f64)),
            ("format_rate", num(rec.format_rate as f64)),
            ("eos_rate", num(rec.eos_rate as f64)),
            ("lr", num(rec.lr as f64)),
            ("loss", num(rec.stats.loss as f64)),
            ("kl_k1", num(rec.stats.kl_k1 as f64)),
            ("kl_k3", num(rec.stats.kl_k3 as f64)),
            ("mean_ratio", num(rec.stats.mean_ratio as f64)),
            ("frac_clipped", num(rec.stats.frac_clipped as f64)),
            ("entropy", num(rec.stats.entropy as f64)),
            ("grad_norm", num(rec.stats.grad_norm as f64)),
            ("rollout_ms", num(rec.rollout_ms)),
            ("grad_ms", num(rec.grad_ms)),
        ]));
    }

    pub fn log_sft_step(&mut self, policy: &Policy, rec: &SftRecord) {
        if self.echo && rec.step % 10 == 0 {
            println!(
                "[sft {}/{} p={}] step {:>4} loss {:.4} tok-acc {:.3}",
                policy.tier.name,
                policy.scheme_tag,
                policy.trainable_params(),
                rec.step,
                rec.loss,
                rec.token_acc
            );
        }
        self.log(obj(vec![
            ("kind", s("step")),
            ("algo", s("sft")),
            ("tier", s(&policy.tier.name)),
            ("scheme", s(&policy.scheme_tag)),
            ("params", num(policy.trainable_params() as f64)),
            ("step", num(rec.step as f64)),
            ("loss", num(rec.loss as f64)),
            ("token_acc", num(rec.token_acc as f64)),
            ("lr", num(rec.lr as f64)),
            ("grad_norm", num(rec.stats.grad_norm as f64)),
        ]));
    }

    pub fn log_pretrain(&mut self, tier: &str, step: usize, loss: f32, acc: f32) {
        if self.echo {
            println!("[pretrain {tier}] step {step:>5} loss {loss:.4} tok-acc {acc:.3}");
        }
        self.log(obj(vec![
            ("kind", s("pretrain")),
            ("tier", s(tier)),
            ("step", num(step as f64)),
            ("loss", num(loss as f64)),
            ("token_acc", num(acc as f64)),
        ]));
    }

    pub fn log_sweep_point(&mut self, scheme: &str, lr: f32, acc: f32) {
        if self.echo {
            println!("[sweep {scheme}] lr {lr:.1e} -> accuracy {acc:.3}");
        }
        self.log(obj(vec![
            ("kind", s("sweep_point")),
            ("scheme", s(scheme)),
            ("lr", num(lr as f64)),
            ("accuracy", num(acc as f64)),
        ]));
    }

    /// One benchmark-ladder suite score (`eval::bench`).
    pub fn log_bench(&mut self, name: &str, params: usize, sc: &crate::eval::bench::SuiteScore) {
        if self.echo {
            println!(
                "[bench {name} p={params}] {}: pass@1 {:.3} pass@{} {:.3} maj@{} {:.3} (n={})",
                sc.suite, sc.pass1, sc.k, sc.pass_k, sc.k, sc.maj_k, sc.n
            );
        }
        self.log(obj(vec![
            ("kind", s("bench")),
            ("name", s(name)),
            ("params", num(params as f64)),
            ("suite", s(&sc.suite)),
            ("n", num(sc.n as f64)),
            ("k", num(sc.k as f64)),
            ("pass1", num(sc.pass1 as f64)),
            ("pass_k", num(sc.pass_k as f64)),
            ("maj_k", num(sc.maj_k as f64)),
            ("format_rate", num(sc.format_rate as f64)),
        ]));
    }

    /// Tiered adapter-store observability snapshot (`serving::StoreStats`):
    /// per-tier hit/miss counts, promotions/demotions/evictions, and
    /// resident-byte gauges, one row per snapshot.
    pub fn log_store(&mut self, tier: &str, st: &crate::serving::store::StoreStats) {
        if self.echo {
            println!(
                "[store {tier}] tenants {} acts {} hits hot/warm {}/{} cold {} evict hot/warm {}/{} bytes cold/warm/hot {}/{}/{}",
                st.tenants,
                st.activations,
                st.hot_hits,
                st.warm_hits,
                st.cold_misses,
                st.evictions_hot,
                st.evictions_warm,
                st.stored_bytes,
                st.warm_bytes,
                st.hot_bytes,
            );
        }
        self.log(obj(vec![
            ("kind", s("store")),
            ("tier", s(tier)),
            ("tenants", num(st.tenants as f64)),
            ("activations", num(st.activations as f64)),
            ("hot_hits", num(st.hot_hits as f64)),
            ("warm_hits", num(st.warm_hits as f64)),
            ("cold_misses", num(st.cold_misses as f64)),
            ("promotions_warm", num(st.promotions_warm as f64)),
            ("promotions_hot", num(st.promotions_hot as f64)),
            ("demotions", num(st.demotions as f64)),
            ("evictions_warm", num(st.evictions_warm as f64)),
            ("evictions_hot", num(st.evictions_hot as f64)),
            ("stored_bytes", num(st.stored_bytes as f64)),
            ("cold_index_bytes", num(st.cold_index_bytes as f64)),
            ("warm_bytes", num(st.warm_bytes as f64)),
            ("hot_bytes", num(st.hot_bytes as f64)),
            ("warm_entries", num(st.warm_entries as f64)),
            ("hot_entries", num(st.hot_entries as f64)),
            ("refills", num(st.refills as f64)),
        ]));
    }

    /// One SLO row of the open-loop serving front-end: the full
    /// latency/goodput/shedding profile of a (trace, mode, rate) run.
    /// Every field except `wall_ms` is computed on the virtual clock and
    /// is bit-reproducible across replays of the same trace.
    pub fn log_serve(
        &mut self,
        tier: &str,
        mode: &str,
        rate: f64,
        slo: &crate::serving::SloStats,
        wall_ms: f64,
    ) {
        if self.echo {
            println!(
                "[serve {tier}/{mode} rate {rate:.0}/s] served {}/{} shed {} p50 {:.3}s p99 {:.3}s goodput {:.1}/s occ {:.2}",
                slo.served,
                slo.offered,
                slo.shed,
                slo.p50_latency,
                slo.p99_latency,
                slo.goodput,
                slo.mean_occupancy,
            );
        }
        self.log(obj(vec![
            ("kind", s("serve")),
            ("tier", s(tier)),
            ("mode", s(mode)),
            ("rate", num(rate)),
            ("offered", num(slo.offered as f64)),
            ("served", num(slo.served as f64)),
            ("shed", num(slo.shed as f64)),
            ("violations", num(slo.violations as f64)),
            ("batches", num(slo.batches as f64)),
            ("p50_latency", num(slo.p50_latency)),
            ("p99_latency", num(slo.p99_latency)),
            ("mean_latency", num(slo.mean_latency)),
            ("max_latency", num(slo.max_latency)),
            ("goodput", num(slo.goodput)),
            ("mean_occupancy", num(slo.mean_occupancy)),
            ("horizon", num(slo.horizon)),
            ("wall_ms", num(wall_ms)),
        ]));
    }

    /// Supervision-plane counters (`runtime::SupervisorStats`): retries,
    /// requeues, quarantines, deaths and hang strikes observed by the
    /// fault-tolerant dispatch loop, plus the live/total context split.
    pub fn log_supervisor(
        &mut self,
        tier: &str,
        st: &crate::runtime::SupervisorStats,
        contexts: usize,
        live: usize,
    ) {
        if self.echo {
            println!(
                "[supervisor {tier}] live {live}/{contexts} retries {} requeues {} quarantines {} deaths {} hangs {}",
                st.retries, st.requeues, st.quarantines, st.deaths, st.hangs,
            );
        }
        self.log(obj(vec![
            ("kind", s("supervisor")),
            ("tier", s(tier)),
            ("contexts", num(contexts as f64)),
            ("live", num(live as f64)),
            ("retries", num(st.retries as f64)),
            ("requeues", num(st.requeues as f64)),
            ("quarantines", num(st.quarantines as f64)),
            ("deaths", num(st.deaths as f64)),
            ("hangs", num(st.hangs as f64)),
        ]));
    }

    /// Async-pipeline run summary (`trainer::pipeline`): queue/staleness
    /// accounting plus the aggregate importance-ratio health of the run.
    /// Everything except `steps_per_s`/`wall_ms` is deterministic.
    pub fn log_pipeline(
        &mut self,
        tier: &str,
        tenants: usize,
        staleness: u64,
        queue_cap: usize,
        optimizer_threads: usize,
        st: &crate::trainer::PipelineStats,
        wall_ms: f64,
    ) {
        if self.echo {
            println!(
                "[pipeline {tier} g={tenants} S={staleness} q={queue_cap} opt={optimizer_threads}] produced {} consumed {} dropped {} gap {} waves {} ratio {:.4} ({:.1} steps/s)",
                st.produced,
                st.consumed,
                st.dropped_stale,
                st.max_version_gap,
                st.waves,
                st.mean_ratio,
                st.steps_per_s,
            );
        }
        self.log(obj(vec![
            ("kind", s("pipeline")),
            ("tier", s(tier)),
            ("tenants", num(tenants as f64)),
            ("staleness", num(staleness as f64)),
            ("queue_cap", num(queue_cap as f64)),
            ("optimizer_threads", num(optimizer_threads as f64)),
            ("produced", num(st.produced as f64)),
            ("consumed", num(st.consumed as f64)),
            ("dropped_stale", num(st.dropped_stale as f64)),
            ("max_version_gap", num(st.max_version_gap as f64)),
            ("waves", num(st.waves as f64)),
            ("mean_ratio", num(st.mean_ratio)),
            ("frac_clipped", num(st.frac_clipped)),
            ("steps_per_s", num(st.steps_per_s)),
            ("wall_ms", num(wall_ms)),
        ]));
    }

    pub fn log_eval(&mut self, tier: &str, scheme: &str, params: usize, suite: &str, acc: f32) {
        if self.echo {
            println!("[eval {tier}/{scheme} p={params}] {suite}: {acc:.3}");
        }
        self.log(obj(vec![
            ("kind", s("eval")),
            ("tier", s(tier)),
            ("scheme", s(scheme)),
            ("params", num(params as f64)),
            ("suite", s(suite)),
            ("accuracy", num(acc as f64)),
        ]));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsonl_rows_parse_back() {
        let dir = std::env::temp_dir().join("tlrl_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("log.jsonl");
        std::fs::remove_file(&path).ok();
        {
            let mut log = RunLog::new(Some(&path), false);
            log.log_pretrain("nano", 0, 3.5, 0.1);
            log.log_sweep_point("tinylora_r2_u13_all", 1e-3, 0.7);
            let st = crate::serving::store::StoreStats {
                tenants: 1000,
                activations: 40,
                hot_hits: 25,
                warm_hits: 5,
                cold_misses: 10,
                stored_bytes: 26_000,
                ..Default::default()
            };
            log.log_store("sim", &st);
            let slo = crate::serving::SloStats {
                offered: 100,
                served: 90,
                shed: 10,
                batches: 30,
                p50_latency: 0.08,
                p99_latency: 0.35,
                goodput: 45.0,
                horizon: 2.0,
                ..Default::default()
            };
            log.log_serve("sim", "continuous", 50.0, &slo, 12.5);
            let sv = crate::runtime::SupervisorStats {
                retries: 3,
                requeues: 2,
                quarantines: 1,
                deaths: 1,
                hangs: 4,
            };
            log.log_supervisor("sim", &sv, 4, 3);
            let ps = crate::trainer::PipelineStats {
                produced: 120,
                consumed: 100,
                dropped_stale: 20,
                max_version_gap: 2,
                waves: 25,
                mean_ratio: 1.0,
                frac_clipped: 0.0,
                steps_per_s: 80.0,
            };
            log.log_pipeline("sim", 10, 2, 4, 2, &ps, 1250.0);
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<_> = text.lines().collect();
        assert_eq!(lines.len(), 6);
        for l in &lines {
            let v = Value::parse(l).unwrap();
            assert!(v.get("kind").is_ok());
        }
        let store_row = Value::parse(lines[2]).unwrap();
        assert_eq!(store_row.get("kind").unwrap().str().unwrap(), "store");
        assert_eq!(store_row.get("stored_bytes").unwrap().usize().unwrap(), 26_000);
        assert_eq!(store_row.get("hot_hits").unwrap().usize().unwrap(), 25);
        let serve_row = Value::parse(lines[3]).unwrap();
        assert_eq!(serve_row.get("kind").unwrap().str().unwrap(), "serve");
        assert_eq!(serve_row.get("mode").unwrap().str().unwrap(), "continuous");
        assert_eq!(serve_row.get("served").unwrap().usize().unwrap(), 90);
        assert_eq!(serve_row.get("goodput").unwrap().f64().unwrap(), 45.0);
        let sv_row = Value::parse(lines[4]).unwrap();
        assert_eq!(sv_row.get("kind").unwrap().str().unwrap(), "supervisor");
        assert_eq!(sv_row.get("live").unwrap().usize().unwrap(), 3);
        assert_eq!(sv_row.get("contexts").unwrap().usize().unwrap(), 4);
        assert_eq!(sv_row.get("requeues").unwrap().usize().unwrap(), 2);
        assert_eq!(sv_row.get("quarantines").unwrap().usize().unwrap(), 1);
        assert_eq!(sv_row.get("deaths").unwrap().usize().unwrap(), 1);
        assert_eq!(sv_row.get("hangs").unwrap().usize().unwrap(), 4);
        let pipe_row = Value::parse(lines[5]).unwrap();
        assert_eq!(pipe_row.get("kind").unwrap().str().unwrap(), "pipeline");
        assert_eq!(pipe_row.get("produced").unwrap().usize().unwrap(), 120);
        assert_eq!(pipe_row.get("consumed").unwrap().usize().unwrap(), 100);
        assert_eq!(pipe_row.get("dropped_stale").unwrap().usize().unwrap(), 20);
        assert_eq!(pipe_row.get("max_version_gap").unwrap().usize().unwrap(), 2);
        assert_eq!(pipe_row.get("mean_ratio").unwrap().f64().unwrap(), 1.0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
