//! `tinylora-rl` — reproduction of *Learning to Reason in 13 Parameters*.
//!
//! Three-layer architecture (see DESIGN.md):
//!   * L1/L2 live in `python/compile/` and are AOT-lowered to HLO text
//!     (`make artifacts`); python never runs at request time.
//!   * L3 (this crate) owns everything with a lifecycle: the
//!     device-parallel runtime (a pool of execution contexts over a
//!     pluggable `Backend` — the PJRT client path or the hermetic
//!     deterministic sim backend, DESIGN.md §9–10 — each with its own
//!     backend/cache/FFI-lock), the shared
//!     thread-safe inference `engine` (the one canonical decode path:
//!     occupancy-aware `InferenceEngine` + per-adapter `Scheduler` +
//!     context-affine `WorkerPool`),
//!     the `trainer` subsystem (the one canonical training-step skeleton:
//!     `TrainSession` + resumable `TrainState` + the multi-tenant
//!     `TenantTrainer`), the pretrain/GRPO/SFT loss loops, rollouts,
//!     the `eval` subsystem (greedy pass@1 plus the `eval::bench`
//!     pass@k/maj@k suite ladder and `eval::report` recovery-fraction
//!     reports), the multi-adapter serving plane, metrics and the CLI.
//!     Rollout, eval and serving are thin clients of `engine`; the three
//!     loss loops are thin `TrainLoop` impls driven by `trainer`.
//!
//! The build environment is fully offline, so small substrates that would
//! normally be crates (JSON, RNG, CLI parsing, bench harness, property
//! testing) are implemented in `util`/`testing`.

pub mod adapters;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod eval;
pub mod experiments;
pub mod manifest;
pub mod metrics;
pub mod runtime;
pub mod sampler;
pub mod serving;
pub mod tasks;
pub mod tensor;
pub mod testing;
pub mod tokenizer;
pub mod trainer;
pub mod util;
pub mod weights;

pub use engine::InferenceEngine;
pub use manifest::Manifest;
pub use runtime::Runtime;
