//! Minimal property-based testing harness (proptest is unavailable in the
//! offline image). `check` runs a predicate over many seeded random cases
//! and reports the first failing seed so failures are reproducible.

use crate::util::Pcg64;

/// Run `prop` over `cases` seeded RNGs; panic with the failing seed.
pub fn check<F: FnMut(&mut Pcg64) -> Result<(), String>>(name: &str, cases: u64, mut prop: F) {
    for seed in 0..cases {
        let mut rng = Pcg64::with_stream(seed, 0x70726f70);
        if let Err(msg) = prop(&mut rng) {
            panic!("property {name:?} failed at seed {seed}: {msg}");
        }
    }
}

/// Assert |a - b| <= atol + rtol * |b| elementwise.
pub fn assert_allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32, ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length mismatch");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol,
            "{ctx}: idx {i}: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("uniform in [0,1)", 50, |rng| {
            let x = rng.uniform();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "failed at seed")]
    fn check_reports_failure() {
        check("always fails", 3, |_| Err("nope".into()));
    }
}
