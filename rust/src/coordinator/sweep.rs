//! LR sweep orchestration — the paper's protocol (§5.1): sweep learning
//! rates per update size, keep the best by final eval accuracy, average
//! over seeds.  Drives the pareto figures (1, 2, 3, 6).

use std::path::Path;

use anyhow::Result;

use crate::coordinator::grpo::{GrpoConfig, GrpoTrainer};
use crate::coordinator::policy::Policy;
use crate::coordinator::sft::{SftConfig, SftTrainer};
use crate::eval::{evaluate, EvalResult};
use crate::metrics::RunLog;
use crate::runtime::Runtime;
use crate::weights::WeightSet;

#[derive(Clone, Debug)]
pub struct SweepConfig {
    pub tier: String,
    pub scheme_tag: String,
    pub algo: String, // "grpo" | "sft"
    pub suite: String,
    pub steps: usize,
    pub lrs: Vec<f32>,
    pub seeds: Vec<u64>,
    pub eval_suite: String,
    pub eval_n: usize,
}

#[derive(Clone, Debug)]
pub struct SweepOutcome {
    pub scheme_tag: String,
    pub trainable_params: usize,
    pub best_lr: f32,
    /// accuracy at the best LR, averaged over seeds
    pub accuracy: f32,
    pub per_lr: Vec<(f32, f32)>,
    pub baseline_accuracy: f32,
    pub final_reward: f32,
    pub format_rate: f32,
}

/// Train one (scheme, lr, seed) run and return final eval accuracy.
pub fn run_once(
    rt: &Runtime,
    base: &WeightSet,
    cfg: &SweepConfig,
    lr: f32,
    seed: u64,
    ckpt_dir: &Path,
    log: &mut RunLog,
) -> Result<(EvalResult, f32, f32)> {
    let mut policy = Policy::new(rt, &cfg.tier, &cfg.scheme_tag, &cfg.algo, base.clone(), seed, ckpt_dir)?;
    let (reward, fmt) = match cfg.algo.as_str() {
        "grpo" => {
            let gcfg = GrpoConfig { suite: cfg.suite.clone(), steps: cfg.steps, lr, seed, ..Default::default() };
            let mut tr = GrpoTrainer::new(rt, &policy, gcfg)?;
            let recs = tr.train(rt, &mut policy, log)?;
            let last = recs.iter().rev().take(5.min(recs.len())).collect::<Vec<_>>();
            (
                last.iter().map(|r| r.reward).sum::<f32>() / last.len() as f32,
                last.iter().map(|r| r.format_rate).sum::<f32>() / last.len() as f32,
            )
        }
        "sft" => {
            let scfg = SftConfig { suite: cfg.suite.clone(), steps: cfg.steps, lr, seed, ..Default::default() };
            let mut tr = SftTrainer::new(rt, &policy, scfg)?;
            tr.train(rt, &mut policy, log)?;
            (0.0, 0.0)
        }
        other => anyhow::bail!("unknown algo {other}"),
    };
    let ev = evaluate(rt, &policy.tier.name, &policy.merged, &cfg.eval_suite, cfg.eval_n, 777)?;
    Ok((ev, reward, fmt))
}

/// Full sweep for one scheme: all LRs x seeds, best-LR selection.
pub fn sweep_scheme(
    rt: &Runtime,
    base: &WeightSet,
    cfg: &SweepConfig,
    ckpt_dir: &Path,
    log: &mut RunLog,
) -> Result<SweepOutcome> {
    let baseline = evaluate(rt, &cfg.tier, base, &cfg.eval_suite, cfg.eval_n, 777)?;
    let mut per_lr = Vec::new();
    let mut best = (0.0f32, f32::NEG_INFINITY, 0.0, 0.0); // (lr, acc, reward, fmt)
    for &lr in &cfg.lrs {
        let mut accs = Vec::new();
        let mut rews = Vec::new();
        let mut fmts = Vec::new();
        for &seed in &cfg.seeds {
            let (ev, rew, fmt) = run_once(rt, base, cfg, lr, seed, ckpt_dir, log)?;
            accs.push(ev.accuracy);
            rews.push(rew);
            fmts.push(fmt);
        }
        let acc = crate::util::mean(&accs);
        per_lr.push((lr, acc));
        log.log_sweep_point(&cfg.scheme_tag, lr, acc);
        if acc > best.1 {
            best = (lr, acc, crate::util::mean(&rews), crate::util::mean(&fmts));
        }
    }
    // trainable size from a probe policy
    let probe = Policy::new(rt, &cfg.tier, &cfg.scheme_tag, &cfg.algo, base.clone(), 0, ckpt_dir)?;
    Ok(SweepOutcome {
        scheme_tag: cfg.scheme_tag.clone(),
        trainable_params: probe.trainable_params(),
        best_lr: best.0,
        accuracy: best.1,
        per_lr,
        baseline_accuracy: baseline.accuracy,
        final_reward: best.2,
        format_rate: best.3,
    })
}
