//! LR sweep orchestration — the paper's protocol (§5.1): sweep learning
//! rates per update size, keep the best by final eval accuracy, average
//! over seeds.  Drives the pareto figures (1, 2, 3, 6).
//!
//! A GRPO sweep is just N tenants with different hyperparameters: the
//! whole lrs × seeds grid trains as one `trainer::TenantTrainer` against
//! the shared backbone, rollout waves interleaved on the same
//! fused-generate executables (and across `--workers` pool threads).
//! SFT has no rollout wave to pool, so it sweeps serially per run.
//!
//! [`sweep_scheme_full`] additionally hands back the winning run's merged
//! weights, so post-training eval is one call: the `sweep --bench-k` CLI
//! path feeds them straight into `eval::bench::run_ladder` for the
//! pass@k/maj@k ladder and recovery-fraction reporting.

use std::path::Path;

use anyhow::Result;

use crate::adapters::packing::Precision;
use crate::coordinator::grpo::{grpo_session, GrpoConfig};
use crate::coordinator::policy::Policy;
use crate::coordinator::sft::{sft_session, SftConfig};
use crate::engine::InferenceEngine;
use crate::eval::{evaluate_with, EvalResult};
use crate::metrics::RunLog;
use crate::runtime::Runtime;
use crate::trainer::pipeline::run_async;
use crate::trainer::{PipelineConfig, PipelineStats, TenantSpec, TenantTrainer};
use crate::util::json::{num, obj, s, Value};
use crate::weights::WeightSet;

#[derive(Clone, Debug)]
pub struct SweepConfig {
    pub tier: String,
    pub scheme_tag: String,
    pub algo: String, // "grpo" | "sft"
    pub suite: String,
    pub steps: usize,
    pub lrs: Vec<f32>,
    pub seeds: Vec<u64>,
    pub eval_suite: String,
    pub eval_n: usize,
    /// pool threads for the tenant rollout waves (grpo only; 1 = serial)
    pub workers: usize,
    /// decode-geometry override for the grpo tenant grid and its evals
    /// (0 = `manifest.batch.roll`; integration tests use `batch.test`)
    pub batch: usize,
}

#[derive(Clone, Debug)]
pub struct SweepOutcome {
    pub scheme_tag: String,
    pub trainable_params: usize,
    pub best_lr: f32,
    /// accuracy at the best LR, averaged over seeds
    pub accuracy: f32,
    pub per_lr: Vec<(f32, f32)>,
    pub baseline_accuracy: f32,
    pub final_reward: f32,
    pub format_rate: f32,
}

impl SweepOutcome {
    /// Canonical JSON row (byte-identical across same-seed runs — asserted
    /// in `tests/integration.rs`).
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("kind", s("sweep_outcome")),
            ("scheme", s(&self.scheme_tag)),
            ("params", num(self.trainable_params as f64)),
            ("best_lr", num(self.best_lr as f64)),
            ("accuracy", num(self.accuracy as f64)),
            ("baseline_acc", num(self.baseline_accuracy as f64)),
            ("final_reward", num(self.final_reward as f64)),
            ("format_rate", num(self.format_rate as f64)),
            (
                "per_lr",
                Value::Arr(
                    self.per_lr
                        .iter()
                        .map(|&(lr, acc)| {
                            obj(vec![("lr", num(lr as f64)), ("acc", num(acc as f64))])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Train one (scheme, lr, seed) run; returns the final eval, the tail
/// reward/format rates and the trained merged weights (for downstream
/// ladder benches). Evals go through the caller's engine, so a grid of
/// runs resolves (and compiles) the eval executable once instead of once
/// per grid point.
#[allow(clippy::too_many_arguments)]
pub fn run_once(
    rt: &Runtime,
    base: &WeightSet,
    cfg: &SweepConfig,
    eval_engine: &InferenceEngine,
    lr: f32,
    seed: u64,
    ckpt_dir: &Path,
    log: &mut RunLog,
) -> Result<(EvalResult, f32, f32, WeightSet)> {
    let policy =
        Policy::new(rt, &cfg.tier, &cfg.scheme_tag, &cfg.algo, base.clone(), seed, ckpt_dir)?;
    let (policy, reward, fmt) = match cfg.algo.as_str() {
        "grpo" => {
            let gcfg = GrpoConfig {
                suite: cfg.suite.clone(),
                steps: cfg.steps,
                lr,
                seed,
                ..Default::default()
            };
            let mut sess = grpo_session(rt, policy, gcfg)?;
            let recs = sess.run(rt, log)?;
            let last = recs.iter().rev().take(5.min(recs.len())).collect::<Vec<_>>();
            let n = last.len().max(1) as f32;
            (
                sess.into_loop().policy,
                last.iter().map(|r| r.reward).sum::<f32>() / n,
                last.iter().map(|r| r.format_rate).sum::<f32>() / n,
            )
        }
        "sft" => {
            let scfg = SftConfig {
                suite: cfg.suite.clone(),
                steps: cfg.steps,
                lr,
                seed,
                ..Default::default()
            };
            let mut sess = sft_session(rt, policy, scfg)?;
            sess.run(rt, log)?;
            (sess.into_loop().policy, 0.0, 0.0)
        }
        other => anyhow::bail!("unknown algo {other}"),
    };
    let ev = evaluate_with(rt, eval_engine, &policy.merged, &cfg.eval_suite, cfg.eval_n, 777)?;
    Ok((ev, reward, fmt, policy.merged))
}

/// Full sweep for one scheme: all LRs x seeds, best-LR selection.
pub fn sweep_scheme(
    rt: &Runtime,
    base: &WeightSet,
    cfg: &SweepConfig,
    ckpt_dir: &Path,
    log: &mut RunLog,
) -> Result<SweepOutcome> {
    Ok(sweep_scheme_full(rt, base, cfg, ckpt_dir, log)?.0)
}

/// [`sweep_scheme`] plus the merged weights of the winning run (best LR,
/// first seed) — what `bench --k` ladder evals and the `sweep --bench-k`
/// CLI path consume after training.
pub fn sweep_scheme_full(
    rt: &Runtime,
    base: &WeightSet,
    cfg: &SweepConfig,
    ckpt_dir: &Path,
    log: &mut RunLog,
) -> Result<(SweepOutcome, WeightSet)> {
    if cfg.lrs.is_empty() || cfg.seeds.is_empty() {
        anyhow::bail!("sweep needs at least one lr and one seed");
    }
    let batch = if cfg.batch > 0 { cfg.batch } else { rt.manifest.batch.roll };
    let eval_engine = InferenceEngine::new(rt, &cfg.tier, batch)?;
    let baseline = evaluate_with(rt, &eval_engine, base, &cfg.eval_suite, cfg.eval_n, 777)?;
    // (lr, acc, reward, fmt) per grid point, lr-major like the spec grid
    let mut grid: Vec<(f32, f32, f32, f32)> = Vec::with_capacity(cfg.lrs.len() * cfg.seeds.len());
    // merged weights per LR at the FIRST seed only — the returned winner
    // is always (best lr, first seed), so retaining the other seeds'
    // copies would be pure memory waste at full-FT scale
    let mut merged: Vec<WeightSet> = Vec::with_capacity(cfg.lrs.len());
    let trainable_params;

    if cfg.algo == "grpo" {
        // the grid IS a tenant set: one adapter per (lr, seed) against the
        // shared backbone
        let mut specs = Vec::with_capacity(cfg.lrs.len() * cfg.seeds.len());
        for &lr in &cfg.lrs {
            for &seed in &cfg.seeds {
                specs.push(TenantSpec {
                    name: format!("{}_lr{lr:.1e}_s{seed}", cfg.scheme_tag),
                    scheme_tag: cfg.scheme_tag.clone(),
                    cfg: GrpoConfig {
                        suite: cfg.suite.clone(),
                        steps: cfg.steps,
                        lr,
                        seed,
                        ..Default::default()
                    },
                    precision: Precision::F32,
                });
            }
        }
        let workers = cfg.workers.max(1);
        let mut tt = TenantTrainer::with_batch(rt, base, specs, workers, ckpt_dir, batch)?;
        let outcomes = tt.train(rt, log, workers > 1)?;
        let n_seeds = cfg.seeds.len();
        for (p, (sess, out)) in tt.sessions.iter().zip(&outcomes).enumerate() {
            let ev = evaluate_with(
                rt,
                &eval_engine,
                &sess.lp.policy.merged,
                &cfg.eval_suite,
                cfg.eval_n,
                777,
            )?;
            grid.push((out.lr, ev.accuracy, out.final_reward, out.final_format_rate));
            if p % n_seeds == 0 {
                merged.push(sess.lp.policy.merged.clone());
            }
        }
        trainable_params =
            tt.sessions.first().map(|s| s.lp.policy.trainable_params()).unwrap_or(0);
    } else {
        for &lr in &cfg.lrs {
            for (si, &seed) in cfg.seeds.iter().enumerate() {
                let (ev, rew, fmt, w) =
                    run_once(rt, base, cfg, &eval_engine, lr, seed, ckpt_dir, log)?;
                grid.push((lr, ev.accuracy, rew, fmt));
                if si == 0 {
                    merged.push(w);
                }
            }
        }
        let probe =
            Policy::new(rt, &cfg.tier, &cfg.scheme_tag, &cfg.algo, base.clone(), 0, ckpt_dir)?;
        trainable_params = probe.trainable_params();
    }

    // aggregate over seeds per LR, then best-LR selection
    let n_seeds = cfg.seeds.len().max(1);
    let mut per_lr = Vec::with_capacity(cfg.lrs.len());
    let mut best = (0.0f32, f32::NEG_INFINITY, 0.0, 0.0); // (lr, acc, reward, fmt)
    let mut best_i = 0usize;
    for (i, &lr) in cfg.lrs.iter().enumerate() {
        let rows = &grid[i * n_seeds..(i + 1) * n_seeds];
        let acc = crate::util::mean(&rows.iter().map(|r| r.1).collect::<Vec<_>>());
        per_lr.push((lr, acc));
        log.log_sweep_point(&cfg.scheme_tag, lr, acc);
        if acc > best.1 {
            best = (
                lr,
                acc,
                crate::util::mean(&rows.iter().map(|r| r.2).collect::<Vec<_>>()),
                crate::util::mean(&rows.iter().map(|r| r.3).collect::<Vec<_>>()),
            );
            best_i = i;
        }
    }
    let best_merged = merged.swap_remove(best_i);
    Ok((
        SweepOutcome {
            scheme_tag: cfg.scheme_tag.clone(),
            trainable_params,
            best_lr: best.0,
            accuracy: best.1,
            per_lr,
            baseline_accuracy: baseline.accuracy,
            final_reward: best.2,
            format_rate: best.3,
        },
        best_merged,
    ))
}

/// Successive-halving schedule for population-scale sweeps.
#[derive(Clone, Copy, Debug)]
pub struct HalvingConfig {
    /// number of rungs; every member trains `steps_per_rung` more steps
    /// per rung it survives
    pub rungs: usize,
    pub steps_per_rung: usize,
    /// survivor fraction per rung (ceil, never below 1)
    pub keep: f32,
    /// async-pipeline knobs the rungs train through
    pub pipeline: PipelineConfig,
}

impl Default for HalvingConfig {
    fn default() -> Self {
        Self { rungs: 3, steps_per_rung: 4, keep: 0.5, pipeline: PipelineConfig::default() }
    }
}

/// One population member's final standing.
#[derive(Clone, Debug)]
pub struct PopulationMember {
    pub name: String,
    pub lr: f32,
    pub seed: u64,
    /// optimizer steps actually applied before the member was frozen (or
    /// finished)
    pub steps: usize,
    /// rungs survived (rungs trained = survived + 1, capped at `rungs`)
    pub rungs_survived: usize,
    /// tail-5 mean reward of the member's last trained rung
    pub score: f32,
}

/// Per-rung accounting of one population sweep.
#[derive(Clone, Debug)]
pub struct RungSummary {
    pub rung: usize,
    /// members that trained this rung
    pub active: usize,
    /// members promoted to the next rung
    pub survivors: usize,
    /// mean score across the rung's active members
    pub mean_score: f32,
}

/// What [`sweep_population`] produced. `to_json` is deterministic (no
/// wall-clock fields) — asserted in `tests/e2e_sim.rs`.
#[derive(Clone, Debug)]
pub struct PopulationOutcome {
    pub tier: String,
    pub scheme_tag: String,
    pub population: usize,
    pub rungs: Vec<RungSummary>,
    pub members: Vec<PopulationMember>,
    /// index into `members` of the winner (highest final-rung score,
    /// first index on ties)
    pub best: usize,
    /// pipeline counters summed over rungs (`mean_ratio` consumed-weighted;
    /// `steps_per_s` from the last rung, excluded from `to_json`)
    pub stats: PipelineStats,
}

impl PopulationOutcome {
    pub fn to_json(&self) -> Value {
        let b = &self.members[self.best];
        obj(vec![
            ("kind", s("population_sweep")),
            ("tier", s(&self.tier)),
            ("scheme", s(&self.scheme_tag)),
            ("population", num(self.population as f64)),
            (
                "rungs",
                Value::Arr(
                    self.rungs
                        .iter()
                        .map(|r| {
                            obj(vec![
                                ("rung", num(r.rung as f64)),
                                ("active", num(r.active as f64)),
                                ("survivors", num(r.survivors as f64)),
                                ("mean_score", num(r.mean_score as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "best",
                obj(vec![
                    ("name", s(&b.name)),
                    ("lr", num(b.lr as f64)),
                    ("seed", num(b.seed as f64)),
                    ("steps", num(b.steps as f64)),
                    ("score", num(b.score as f64)),
                ]),
            ),
            ("produced", num(self.stats.produced as f64)),
            ("consumed", num(self.stats.consumed as f64)),
            ("dropped_stale", num(self.stats.dropped_stale as f64)),
            ("max_version_gap", num(self.stats.max_version_gap as f64)),
            ("mean_ratio", num(self.stats.mean_ratio)),
        ])
    }
}

/// Population-scale sweep: the whole lrs × seeds grid trains as ONE
/// tenant set through the async pipeline, with successive-halving early
/// stopping — every rung trains the surviving members `steps_per_rung`
/// more optimizer steps, ranks them by tail-5 training reward (ties break
/// toward the earlier grid index, so the ranking is fully deterministic),
/// and freezes the rest. Frozen members simply keep their per-tenant
/// target where it is: the pipeline's produce gate stops planning rollouts
/// for them, so a 10× population costs ~`keep`× per extra rung instead of
/// 10×. Ranking uses training reward, not eval accuracy — at thousands of
/// members an eval per member per rung would dwarf the training itself;
/// run `sweep_scheme_full` on the surviving handful when accuracy-based
/// selection matters.
pub fn sweep_population(
    rt: &Runtime,
    base: &WeightSet,
    cfg: &SweepConfig,
    hcfg: &HalvingConfig,
    ckpt_dir: &Path,
    log: &mut RunLog,
) -> Result<PopulationOutcome> {
    if cfg.lrs.is_empty() || cfg.seeds.is_empty() {
        anyhow::bail!("population sweep needs at least one lr and one seed");
    }
    if hcfg.rungs == 0 || hcfg.steps_per_rung == 0 {
        anyhow::bail!("population sweep needs rungs >= 1 and steps_per_rung >= 1");
    }
    if !(hcfg.keep > 0.0 && hcfg.keep <= 1.0) {
        anyhow::bail!("population keep fraction must be in (0, 1]");
    }
    let batch = if cfg.batch > 0 { cfg.batch } else { rt.manifest.batch.roll };
    let total_steps = hcfg.rungs * hcfg.steps_per_rung;
    let mut specs = Vec::with_capacity(cfg.lrs.len() * cfg.seeds.len());
    for &lr in &cfg.lrs {
        for &seed in &cfg.seeds {
            specs.push(TenantSpec {
                name: format!("{}_lr{lr:.1e}_s{seed}", cfg.scheme_tag),
                scheme_tag: cfg.scheme_tag.clone(),
                cfg: GrpoConfig {
                    suite: cfg.suite.clone(),
                    steps: total_steps,
                    lr,
                    seed,
                    ..Default::default()
                },
                precision: Precision::F32,
            });
        }
    }
    let g = specs.len();
    let workers = cfg.workers.max(1);
    let mut tt = TenantTrainer::with_batch(rt, base, specs, workers, ckpt_dir, batch)?;

    let mut members: Vec<PopulationMember> = tt
        .specs
        .iter()
        .map(|sp| PopulationMember {
            name: sp.name.clone(),
            lr: sp.cfg.lr,
            seed: sp.cfg.seed,
            steps: 0,
            rungs_survived: 0,
            score: f32::NEG_INFINITY,
        })
        .collect();
    let mut active: Vec<usize> = (0..g).collect();
    let mut targets = vec![0usize; g];
    let mut rungs = Vec::with_capacity(hcfg.rungs);
    let mut stats = PipelineStats::default();

    for rung in 0..hcfg.rungs {
        for &i in &active {
            targets[i] += hcfg.steps_per_rung;
        }
        let out = run_async(rt, &mut tt, &hcfg.pipeline, &targets, log, workers > 1)?;
        // deterministic merge of the rung's pipeline counters
        let w_old = stats.consumed as f64;
        let w_new = out.stats.consumed as f64;
        if w_old + w_new > 0.0 {
            stats.mean_ratio =
                (stats.mean_ratio * w_old + out.stats.mean_ratio * w_new) / (w_old + w_new);
            stats.frac_clipped = (stats.frac_clipped * w_old + out.stats.frac_clipped * w_new)
                / (w_old + w_new);
        }
        stats.produced += out.stats.produced;
        stats.consumed += out.stats.consumed;
        stats.dropped_stale += out.stats.dropped_stale;
        stats.max_version_gap = stats.max_version_gap.max(out.stats.max_version_gap);
        stats.waves += out.stats.waves;
        stats.steps_per_s = out.stats.steps_per_s;

        // score active members on THIS rung's records (tail-5 mean reward)
        for &i in &active {
            let recs = &out.records[i];
            let tail: Vec<_> = recs.iter().rev().take(5.min(recs.len())).collect();
            let n = tail.len().max(1) as f32;
            members[i].score = tail.iter().map(|r| r.reward).sum::<f32>() / n;
            members[i].steps += recs.len();
        }
        let mean_score = if active.is_empty() {
            0.0
        } else {
            active.iter().map(|&i| members[i].score).sum::<f32>() / active.len() as f32
        };

        // rank and halve (skip after the final rung — everyone finished)
        let survivors = if rung + 1 < hcfg.rungs {
            let mut ranked = active.clone();
            ranked.sort_by(|&a, &b| {
                members[b]
                    .score
                    .partial_cmp(&members[a].score)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
            let keep = ((active.len() as f32 * hcfg.keep).ceil() as usize).max(1);
            ranked.truncate(keep);
            ranked.sort_unstable();
            ranked
        } else {
            active.clone()
        };
        for &i in &survivors {
            members[i].rungs_survived += 1;
        }
        rungs.push(RungSummary {
            rung,
            active: active.len(),
            survivors: survivors.len(),
            mean_score,
        });
        if log.echo {
            println!(
                "[population {} rung {rung}] active {} -> survivors {} mean score {mean_score:.3}",
                cfg.scheme_tag,
                active.len(),
                survivors.len(),
            );
        }
        active = survivors;
    }

    // winner: best final-rung score among the members that reached the
    // last rung; ties break toward the earlier grid index
    let best = active
        .iter()
        .copied()
        .min_by(|&a, &b| {
            members[b]
                .score
                .partial_cmp(&members[a].score)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        })
        .unwrap_or(0);
    Ok(PopulationOutcome {
        tier: tt.tier.clone(),
        scheme_tag: cfg.scheme_tag.clone(),
        population: g,
        rungs,
        members,
        best,
        stats,
    })
}
