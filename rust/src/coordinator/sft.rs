//! The SFT loop — the paper's comparison baseline (§6.2, Fig. 2), as a
//! thin `trainer::TrainLoop` impl. Next-token CE on gold canonical
//! demonstrations, same adapter schemes and (session-owned) optimizer as
//! GRPO so the *only* difference is the learning signal.

use anyhow::Result;

use crate::coordinator::policy::{GradStats, GrpoHp, Policy, TrainBatch};
use crate::metrics::RunLog;
use crate::runtime::Runtime;
use crate::tasks::corpus::sft_batch;
use crate::tasks::generator::{suite, SUITES};
use crate::tensor::TensorF32;
use crate::tokenizer::Tokenizer;
use crate::trainer::{GradOutput, SessionConfig, TrainLoop, TrainSession};
use crate::util::Pcg64;

/// RNG stream tag for the SFT session ("sft" — historical).
pub const SFT_STREAM: u64 = 0x736674;

#[derive(Clone, Debug)]
pub struct SftConfig {
    pub suite: String,
    pub steps: usize,
    pub lr: f32,
    pub warmup: u64,
    pub grad_clip: f32,
    pub seed: u64,
}

impl Default for SftConfig {
    fn default() -> Self {
        Self { suite: "gsm8k-syn".into(), steps: 60, lr: 2e-3, warmup: 5, grad_clip: 1.0, seed: 0 }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct SftRecord {
    pub step: usize,
    pub loss: f32,
    pub token_acc: f32,
    pub lr: f32,
    pub stats: GradStats,
}

pub struct SftLoop {
    pub cfg: SftConfig,
    pub policy: Policy,
    tok: Tokenizer,
    batch: usize,
}

impl SftLoop {
    pub fn new(rt: &Runtime, policy: Policy, cfg: SftConfig) -> Result<Self> {
        Ok(Self { cfg, policy, tok: Tokenizer::new(), batch: rt.manifest.batch.train })
    }
}

impl TrainLoop for SftLoop {
    type Record = SftRecord;

    fn algo(&self) -> &'static str {
        "sft"
    }

    fn tier(&self) -> &str {
        &self.policy.tier.name
    }

    fn scheme_tag(&self) -> &str {
        &self.policy.scheme_tag
    }

    fn config_tag(&self) -> String {
        let c = &self.cfg;
        format!(
            "suite={} batch={} lr={} warmup={} grad_clip={} seed={}",
            c.suite, self.batch, c.lr, c.warmup, c.grad_clip, c.seed
        )
    }

    fn n_params(&self) -> usize {
        self.policy.trainable_params()
    }

    fn params(&self) -> Vec<f32> {
        self.policy.params()
    }

    fn set_params(&mut self, rt: &Runtime, params: &[f32]) -> Result<()> {
        self.policy.set_params(rt, params)
    }

    fn compute(&mut self, rt: &Runtime, _step: usize, rng: &mut Pcg64) -> Result<GradOutput> {
        let s = if self.cfg.suite == "math-mix" {
            *rng.choice(&[&SUITES[1], &SUITES[2], &SUITES[3], &SUITES[4]])
        } else {
            suite(&self.cfg.suite).unwrap_or(&SUITES[0])
        };
        let t = self.policy.tier.t_train;
        let (tokens, mask) = sft_batch(s, &self.tok, rng, self.batch, t);
        let batch = TrainBatch {
            tokens,
            mask,
            behavior: TensorF32::zeros(&[self.batch, t - 1]),
            advantages: TensorF32::zeros(&[self.batch]),
        };
        let t1 = crate::util::Timer::start();
        let (grad, stats) = self.policy.grad(rt, &batch, GrpoHp::default())?;
        let grad_ms = t1.millis();
        Ok(GradOutput { grad, stats, aux: Default::default(), rollout_ms: 0.0, grad_ms })
    }

    fn record(
        &self,
        step: usize,
        lr: f32,
        out: &GradOutput,
        grad_norm: f32,
        log: &mut RunLog,
    ) -> SftRecord {
        let mut stats = out.stats;
        stats.grad_norm = grad_norm;
        let rec = SftRecord { step, loss: stats.loss, token_acc: stats.aux1, lr, stats };
        log.log_sft_step(&self.policy, &rec);
        rec
    }
}

/// Session hyperparameters for one SFT config.
pub fn sft_session_cfg(cfg: &SftConfig) -> SessionConfig {
    SessionConfig {
        steps: cfg.steps,
        lr: cfg.lr,
        warmup: cfg.warmup,
        grad_clip: cfg.grad_clip,
        seed: cfg.seed,
        stream: SFT_STREAM,
        ckpt_every: 0,
        ckpt_path: None,
    }
}

/// Build a full SFT training session.
pub fn sft_session(rt: &Runtime, policy: Policy, cfg: SftConfig) -> Result<TrainSession<SftLoop>> {
    let scfg = sft_session_cfg(&cfg);
    Ok(TrainSession::new(SftLoop::new(rt, policy, cfg)?, scfg))
}
