//! The SFT trainer — the paper's comparison baseline (§6.2, Fig. 2).
//! Next-token CE on gold canonical demonstrations, same adapter schemes and
//! optimizer as GRPO so the *only* difference is the learning signal.

use anyhow::Result;

use crate::coordinator::optimizer::{lr_at, Adam, AdamConfig};
use crate::coordinator::policy::{GradStats, GrpoHp, Policy, TrainBatch};
use crate::metrics::RunLog;
use crate::runtime::Runtime;
use crate::tasks::corpus::sft_batch;
use crate::tasks::generator::{suite, SUITES};
use crate::tensor::{TensorF32, TensorI32};
use crate::tokenizer::Tokenizer;
use crate::util::Pcg64;

#[derive(Clone, Debug)]
pub struct SftConfig {
    pub suite: String,
    pub steps: usize,
    pub lr: f32,
    pub warmup: u64,
    pub grad_clip: f32,
    pub seed: u64,
}

impl Default for SftConfig {
    fn default() -> Self {
        Self { suite: "gsm8k-syn".into(), steps: 60, lr: 2e-3, warmup: 5, grad_clip: 1.0, seed: 0 }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct SftRecord {
    pub step: usize,
    pub loss: f32,
    pub token_acc: f32,
    pub lr: f32,
    pub stats: GradStats,
}

pub struct SftTrainer {
    pub cfg: SftConfig,
    opt: Adam,
    rng: Pcg64,
    tok: Tokenizer,
    step: usize,
    batch: usize,
}

impl SftTrainer {
    pub fn new(rt: &Runtime, policy: &Policy, cfg: SftConfig) -> Result<Self> {
        let opt = Adam::new(
            policy.params().len(),
            AdamConfig { lr: cfg.lr, grad_clip: cfg.grad_clip, ..Default::default() },
        );
        let rng = Pcg64::with_stream(cfg.seed, 0x736674);
        Ok(Self { cfg, opt, rng, tok: Tokenizer::new(), step: 0, batch: rt.manifest.batch.train })
    }

    pub fn step(&mut self, rt: &Runtime, policy: &mut Policy) -> Result<SftRecord> {
        let s = if self.cfg.suite == "math-mix" {
            *self.rng.choice(&[&SUITES[1], &SUITES[2], &SUITES[3], &SUITES[4]])
        } else {
            suite(&self.cfg.suite).unwrap_or(&SUITES[0])
        };
        let (tokens, mask) =
            sft_batch(s, &self.tok, &mut self.rng, self.batch, policy.tier.t_train);
        let t = policy.tier.t_train;
        let batch = TrainBatch {
            tokens,
            mask,
            behavior: TensorF32::zeros(&[self.batch, t - 1]),
            advantages: TensorF32::zeros(&[self.batch]),
        };
        let (grad, mut stats) = policy.grad(rt, &batch, GrpoHp::default())?;
        self.opt.set_lr(lr_at(self.cfg.lr, self.cfg.warmup, self.step as u64));
        let mut params = policy.params();
        stats.grad_norm = self.opt.step(&mut params, &grad);
        policy.set_params(rt, &params)?;
        let rec = SftRecord {
            step: self.step,
            loss: stats.loss,
            token_acc: stats.aux1,
            lr: self.opt.cfg.lr,
            stats,
        };
        self.step += 1;
        Ok(rec)
    }

    pub fn train(
        &mut self,
        rt: &Runtime,
        policy: &mut Policy,
        log: &mut RunLog,
    ) -> Result<Vec<SftRecord>> {
        let mut records = Vec::with_capacity(self.cfg.steps);
        for _ in 0..self.cfg.steps {
            let rec = self.step(rt, policy)?;
            log.log_sft_step(policy, &rec);
            records.push(rec);
        }
        Ok(records)
    }
}

// Unused import silencer for TensorI32 (used via corpus::sft_batch's types).
#[allow(unused)]
fn _types(_: TensorI32) {}
