//! Optimizers over flat parameter vectors.
//!
//! Gradients come back from the AOT executables; the optimizer lives in
//! rust so LR sweeps, precision ablations and grad-accumulation never
//! require re-lowering.  For TinyLoRA the state is u <= a few KB; for the
//! full-FT baseline it spans the whole weight set.

#[derive(Clone, Copy, Debug)]
pub struct AdamConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    /// max grad norm; <= 0 disables clipping
    pub grad_clip: f32,
}

impl Default for AdamConfig {
    fn default() -> Self {
        Self { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0, grad_clip: 1.0 }
    }
}

pub struct Adam {
    pub cfg: AdamConfig,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

/// The moments + step counter that make an Adam run resumable (part of the
/// `trainer::TrainState` checkpoint).
#[derive(Clone, Debug, PartialEq)]
pub struct AdamState {
    pub t: u64,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

impl Adam {
    pub fn new(n: usize, cfg: AdamConfig) -> Self {
        Self { cfg, m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    /// Snapshot the optimizer state for checkpointing.
    pub fn state(&self) -> AdamState {
        AdamState { t: self.t, m: self.m.clone(), v: self.v.clone() }
    }

    /// Restore a [`Adam::state`] snapshot; the next `step` is bit-identical
    /// to the uninterrupted run. Lengths must match this optimizer's.
    pub fn restore(&mut self, st: &AdamState) {
        assert_eq!(st.m.len(), self.m.len(), "adam state length mismatch");
        assert_eq!(st.v.len(), self.v.len(), "adam state length mismatch");
        self.t = st.t;
        self.m = st.m.clone();
        self.v = st.v.clone();
    }

    /// One update step; returns the pre-clip grad norm.
    ///
    /// Weight decay is *decoupled* (AdamW): it is applied directly to the
    /// parameters, scaled by the LR, and never enters the moments, the
    /// clip scaling, or the returned norm. Semantics change (ISSUE 10
    /// bugfix): decay used to be folded into the gradient AFTER clip
    /// scaling — coupled L2 that silently bypassed `grad_clip`, polluted
    /// `m`/`v`, and moved parameters without showing up in the logged
    /// grad norm. With decay enabled the two formulations differ; all
    /// in-repo trainers default `weight_decay` to 0, where they are
    /// identical.
    pub fn step(&mut self, params: &mut [f32], grad: &[f32]) -> f32 {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grad.len(), params.len());
        self.t += 1;
        let norm = grad.iter().map(|g| (g * g) as f64).sum::<f64>().sqrt() as f32;
        let scale = if self.cfg.grad_clip > 0.0 && norm > self.cfg.grad_clip {
            self.cfg.grad_clip / norm
        } else {
            1.0
        };
        let b1 = self.cfg.beta1;
        let b2 = self.cfg.beta2;
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        for i in 0..params.len() {
            let g = grad[i] * scale;
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g;
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            params[i] -= self.cfg.lr
                * (mhat / (vhat.sqrt() + self.cfg.eps) + self.cfg.weight_decay * params[i]);
        }
        norm
    }

    pub fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }
}

/// Plain SGD (+momentum) — used by ablations and as an optimizer baseline.
pub struct Sgd {
    pub lr: f32,
    pub momentum: f32,
    vel: Vec<f32>,
}

impl Sgd {
    pub fn new(n: usize, lr: f32, momentum: f32) -> Self {
        Self { lr, momentum, vel: vec![0.0; n] }
    }

    pub fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        for i in 0..params.len() {
            self.vel[i] = self.momentum * self.vel[i] + grad[i];
            params[i] -= self.lr * self.vel[i];
        }
    }
}

/// Linear warmup then constant (the schedule used by all trainers).
pub fn lr_at(base: f32, warmup: u64, step: u64) -> f32 {
    if warmup == 0 || step >= warmup {
        base
    } else {
        base * (step + 1) as f32 / warmup as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;

    /// Adam on a quadratic must converge to the minimum.
    #[test]
    fn adam_minimizes_quadratic() {
        let target = [3.0f32, -2.0, 0.5];
        let mut p = vec![0.0f32; 3];
        let mut opt = Adam::new(3, AdamConfig { lr: 0.05, ..Default::default() });
        for _ in 0..800 {
            let g: Vec<f32> = p.iter().zip(&target).map(|(x, t)| 2.0 * (x - t)).collect();
            opt.step(&mut p, &g);
        }
        for (x, t) in p.iter().zip(&target) {
            assert!((x - t).abs() < 1e-2, "{x} vs {t}");
        }
    }

    #[test]
    fn grad_clip_bounds_update() {
        let mut p = vec![0.0f32; 4];
        let mut opt = Adam::new(
            4,
            AdamConfig { lr: 0.1, grad_clip: 1.0, ..Default::default() },
        );
        let huge = vec![1e6f32; 4];
        let norm = opt.step(&mut p, &huge);
        assert!(norm > 1e5);
        // first-step Adam update is bounded by lr regardless of grad scale
        for x in &p {
            assert!(x.abs() <= 0.11, "{x}");
        }
    }

    /// Save mid-run, restore into a fresh optimizer, and the continuation
    /// must match the uninterrupted run exactly (the resume invariant).
    #[test]
    fn adam_state_restore_is_bit_identical() {
        let cfg = AdamConfig { lr: 0.02, ..Default::default() };
        let grad_at = |step: u64| -> Vec<f32> {
            (0..3).map(|i| ((step + i) as f32 * 0.37).sin()).collect()
        };
        let mut p_full = vec![1.0f32, -2.0, 0.5];
        let mut full = Adam::new(3, cfg);
        let mut p_half = p_full.clone();
        let mut half = Adam::new(3, cfg);
        for s in 0..5 {
            full.step(&mut p_full, &grad_at(s));
            half.step(&mut p_half, &grad_at(s));
        }
        let snap = half.state();
        let mut resumed = Adam::new(3, cfg);
        resumed.restore(&snap);
        for s in 5..12 {
            full.step(&mut p_full, &grad_at(s));
            resumed.step(&mut p_half, &grad_at(s));
        }
        assert_eq!(p_full, p_half);
    }

    #[test]
    fn sgd_momentum_accelerates() {
        let mut plain = vec![10.0f32];
        let mut mom = vec![10.0f32];
        let mut s1 = Sgd::new(1, 0.01, 0.0);
        let mut s2 = Sgd::new(1, 0.01, 0.9);
        for _ in 0..50 {
            let g1 = vec![2.0 * plain[0]];
            s1.step(&mut plain, &g1);
            let g2 = vec![2.0 * mom[0]];
            s2.step(&mut mom, &g2);
        }
        assert!(mom[0].abs() < plain[0].abs());
    }

    #[test]
    fn warmup_schedule() {
        assert_eq!(lr_at(1.0, 10, 0), 0.1);
        assert_eq!(lr_at(1.0, 10, 9), 1.0);
        assert_eq!(lr_at(1.0, 10, 100), 1.0);
        assert_eq!(lr_at(1.0, 0, 0), 1.0);
    }

    /// Regression (ISSUE 10 satellite): decay alone must never inflate the
    /// reported grad norm — the returned norm is a pure function of the
    /// incoming gradient, with decay applied to the parameters outside it.
    #[test]
    fn weight_decay_never_inflates_reported_norm() {
        let cfg = AdamConfig { lr: 0.1, weight_decay: 10.0, grad_clip: 1.0, ..Default::default() };
        let grad = [3e-3f32, -4e-3];
        let mut p = vec![100.0f32, -250.0];
        let mut opt = Adam::new(2, cfg);
        let norm = opt.step(&mut p, &grad);
        // exact: norm(grad) only, no decay term (huge params would dwarf it)
        let want =
            (grad.iter().map(|g| (g * g) as f64).sum::<f64>()).sqrt() as f32;
        assert_eq!(norm.to_bits(), want.to_bits());
        // and a pure-decay step (zero grad) reports exactly zero norm
        let mut opt0 = Adam::new(2, cfg);
        let mut p0 = vec![100.0f32, -250.0];
        assert_eq!(opt0.step(&mut p0, &[0.0, 0.0]), 0.0);
    }

    /// With zero gradient the moments stay zero, so k decoupled-decay
    /// steps shrink each parameter by exactly (1 - lr*wd)^k — the moments
    /// never see the decay term (they would otherwise bend this curve).
    #[test]
    fn weight_decay_is_decoupled_from_moments() {
        let cfg = AdamConfig { lr: 0.1, weight_decay: 0.5, ..Default::default() };
        let mut p = vec![8.0f32];
        let mut opt = Adam::new(1, cfg);
        let mut want = 8.0f32;
        for _ in 0..6 {
            opt.step(&mut p, &[0.0]);
            want -= 0.1 * (0.5 * want);
            assert_eq!(p[0].to_bits(), want.to_bits());
        }
    }

    #[test]
    fn adam_is_scale_adaptive() {
        // property: for a 1-d quadratic, Adam's step size is ~lr regardless
        // of curvature on step 1
        check("adam step ~ lr", 50, |rng| {
            let scale = 10f32.powi(rng.range_i64(-3, 3) as i32);
            let mut p = vec![1.0f32];
            let mut opt = Adam::new(1, AdamConfig { lr: 0.01, grad_clip: 0.0, ..Default::default() });
            opt.step(&mut p, &[scale]);
            let delta = (1.0 - p[0]).abs();
            if (delta - 0.01).abs() < 2e-3 {
                Ok(())
            } else {
                Err(format!("delta {delta} for scale {scale}"))
            }
        });
    }
}
