//! Rollout layer: a thin training-side client of `engine::InferenceEngine`
//! (which owns the ONE canonical decode path — executable selection,
//! uniforms, the fused `generate` call, EOS-cut/decode/verify). What stays
//! here is what is *training-specific*: GRPO train-batch assembly
//! (prompt ++ response layout, loss mask, behavior log-probs, group
//! advantages).

use anyhow::Result;

use crate::coordinator::advantage::group_advantages;
use crate::coordinator::policy::TrainBatch;
use crate::engine::InferenceEngine;
use crate::runtime::Runtime;
use crate::tasks::corpus::PromptBatch;
use crate::tensor::{TensorF32, TensorI32};
use crate::tokenizer::{Tokenizer, PAD};
use crate::util::Pcg64;
use crate::weights::WeightSet;

// The decode-path types now live in `engine`; trainers keep their
// historical names.
pub use crate::engine::{GenRow as RolloutRow, Generation as Rollout};

pub struct RolloutEngine {
    engine: InferenceEngine,
    pub batch: usize,
    /// sampled tokens per sequence
    pub n_gen: usize,
    pub t_prefill: usize,
}

impl RolloutEngine {
    pub fn new(rt: &Runtime, tier: &str, batch: usize) -> Result<Self> {
        let engine = InferenceEngine::new(rt, tier, batch)?;
        let (batch, n_gen, t_prefill) = (engine.batch, engine.n_gen, engine.t_prefill);
        Ok(Self { engine, batch, n_gen, t_prefill })
    }

    /// The shared engine (per-batch decode stats etc.).
    pub fn engine(&self) -> &InferenceEngine {
        &self.engine
    }

    /// Sample one batch of rollouts from the merged weights.
    pub fn rollout(
        &self,
        rt: &Runtime,
        weights: &WeightSet,
        pb: &PromptBatch,
        tok: &Tokenizer,
        temperature: f32,
        rng: &mut Pcg64,
    ) -> Result<Rollout> {
        self.engine.generate(rt, weights, pb, tok, temperature, rng)
    }

    /// Assemble the GRPO train batch for this engine's geometry.
    pub fn train_batch(&self, pb: &PromptBatch, roll: &Rollout, t_train: usize) -> TrainBatch {
        build_train_batch(pb, roll, self.t_prefill, t_train)
    }
}

/// Assemble a GRPO train batch: prompt ++ response right-padded to t_train,
/// loss mask + behavior log-probs aligned to response tokens, group-relative
/// advantages per sequence.
pub fn build_train_batch(
    pb: &PromptBatch,
    roll: &Rollout,
    t_prefill: usize,
    t_train: usize,
) -> TrainBatch {
    let b = roll.rows.len();
    let mut tokens = vec![PAD; b * t_train];
    let mut mask = vec![0.0f32; b * (t_train - 1)];
    let mut behavior = vec![0.0f32; b * (t_train - 1)];
    for (i, row) in roll.rows.iter().enumerate() {
        let plen = row.prompt_len;
        let prow = &pb.tokens.data[i * t_prefill..(i + 1) * t_prefill];
        tokens[i * t_train..i * t_train + plen].copy_from_slice(&prow[..plen]);
        let n = row.response.len().min(t_train - plen);
        tokens[i * t_train + plen..i * t_train + plen + n].copy_from_slice(&row.response[..n]);
        for j in 0..n {
            // response token j sits at position plen + j, predicted at plen+j-1
            let pos = plen + j - 1;
            mask[i * (t_train - 1) + pos] = 1.0;
            behavior[i * (t_train - 1) + pos] = row.behavior[j];
        }
    }
    let rewards: Vec<f32> = roll.rows.iter().map(|r| r.reward).collect();
    let adv = group_advantages(&rewards, roll.group);
    TrainBatch {
        tokens: TensorI32::from_vec(&[b, t_train], tokens),
        mask: TensorF32::from_vec(&[b, t_train - 1], mask),
        behavior: TensorF32::from_vec(&[b, t_train - 1], behavior),
        advantages: TensorF32::from_vec(&[b], adv),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::corpus::prompt_batch;
    use crate::tasks::generator::SUITES;
    use crate::tokenizer::EOS;

    /// train_batch alignment without a runtime: hand-build a Rollout.
    #[test]
    fn train_batch_alignment() {
        let tok = Tokenizer::new();
        let mut rng = Pcg64::new(1);
        let probs: Vec<_> = (0..2).map(|_| SUITES[0].generate(&mut rng)).collect();
        let pb = prompt_batch(&probs, &tok, 2, 64);
        let rows: Vec<RolloutRow> = (0..4)
            .map(|i| {
                let mut response = tok.encode("#### 7");
                response.push(EOS);
                RolloutRow {
                    prompt_len: pb.prompt_len.data[i] as usize,
                    behavior: vec![-0.5; response.len()],
                    response,
                    text: "#### 7".into(),
                    reward: if i % 2 == 0 { 1.0 } else { 0.0 },
                    hit_eos: true,
                    has_format: true,
                }
            })
            .collect();
        let roll = Rollout { rows, group: 2, policy_version: 0 };
        let tb = build_train_batch(&pb, &roll, 64, 128);
        for i in 0..4 {
            let plen = pb.prompt_len.data[i] as usize;
            // prompt copied
            assert_eq!(tb.tokens.data[i * 128], crate::tokenizer::BOS);
            // first response position is masked-in and has behavior
            assert_eq!(tb.mask.data[i * 127 + plen - 1], 1.0);
            assert_eq!(tb.behavior.data[i * 127 + plen - 1], -0.5);
            // position before response start is not scored
            assert_eq!(tb.mask.data[i * 127 + plen - 2], 0.0);
            // EOS is scored (model must learn to stop)
            let n = roll.rows[i].response.len();
            assert_eq!(tb.mask.data[i * 127 + plen + n - 2], 1.0);
            assert_eq!(tb.mask.data[i * 127 + plen + n - 1], 0.0);
        }
        // group advantages: (1,0) groups -> +/-; centred
        assert!(tb.advantages.data[0] > 0.0 && tb.advantages.data[1] < 0.0);
    }
}
