//! Rollout engine: batched sampling through the fused `generate`
//! executable, EOS handling, reward computation and train-batch assembly.
//!
//! The entire decode loop runs inside ONE executable call (see runtime
//! docs); rust supplies the uniforms (so the sampling policy stays
//! coordinator-owned and reproducible) and post-processes EOS cuts,
//! verification and advantage estimation.

use std::rc::Rc;

use anyhow::Result;

use crate::coordinator::advantage::group_advantages;
use crate::coordinator::policy::TrainBatch;
use crate::runtime::{Executable, Runtime};
use crate::tasks::corpus::PromptBatch;
use crate::tasks::verifier;
use crate::tensor::{Arg, TensorF32, TensorI32};
use crate::tokenizer::{Tokenizer, EOS, PAD};
use crate::util::Pcg64;
use crate::weights::WeightSet;

pub struct RolloutEngine {
    gen_exe: Rc<Executable>,
    pub batch: usize,
    /// sampled tokens per sequence
    pub n_gen: usize,
    pub t_prefill: usize,
}

/// One sampled sequence, post EOS-cut.
#[derive(Clone, Debug)]
pub struct RolloutRow {
    pub prompt_len: usize,
    /// response tokens, including the terminating EOS when present
    pub response: Vec<i32>,
    /// behavior log-prob per response token (merged weights, sampling temp)
    pub behavior: Vec<f32>,
    pub text: String,
    pub reward: f32,
    pub hit_eos: bool,
    pub has_format: bool,
}

pub struct Rollout {
    pub rows: Vec<RolloutRow>,
    pub group: usize,
}

impl Rollout {
    pub fn mean_reward(&self) -> f32 {
        crate::util::mean(&self.rows.iter().map(|r| r.reward).collect::<Vec<_>>())
    }

    pub fn mean_response_len(&self) -> f32 {
        crate::util::mean(&self.rows.iter().map(|r| r.response.len() as f32).collect::<Vec<_>>())
    }

    pub fn format_rate(&self) -> f32 {
        crate::util::mean(
            &self.rows.iter().map(|r| if r.has_format { 1.0 } else { 0.0 }).collect::<Vec<_>>(),
        )
    }
}

impl RolloutEngine {
    pub fn new(rt: &Runtime, tier: &str, batch: usize) -> Result<Self> {
        let info = rt.manifest.generate_exe(tier, batch)?.clone();
        let gen_exe = rt.load(&info.name)?;
        let t = rt.manifest.tier(tier)?;
        Ok(Self { gen_exe, batch: info.batch, n_gen: info.seq, t_prefill: t.t_prefill })
    }

    /// Sample one batch of rollouts from the merged weights.
    pub fn rollout(
        &self,
        rt: &Runtime,
        weights: &WeightSet,
        pb: &PromptBatch,
        tok: &Tokenizer,
        temperature: f32,
        rng: &mut Pcg64,
    ) -> Result<Rollout> {
        assert_eq!(pb.tokens.shape[0], self.batch, "prompt batch != exe batch");
        let b = self.batch;
        let uniforms = TensorF32::from_vec(&[b, self.n_gen], rng.uniform_vec(b * self.n_gen));
        let mut args: Vec<Arg> = weights.args();
        args.push(Arg::I32(pb.tokens.clone()));
        args.push(Arg::I32(pb.prompt_len.clone()));
        args.push(Arg::F32(uniforms));
        args.push(Arg::Scalar(temperature));
        let out = rt.run(&self.gen_exe, &args)?;
        let tokens = out.i32(0)?;
        let blp = out.f32(1)?;

        let mut rows = Vec::with_capacity(b);
        for i in 0..b {
            let gen = &tokens.data[i * self.n_gen..(i + 1) * self.n_gen];
            let lp = &blp.data[i * self.n_gen..(i + 1) * self.n_gen];
            let cut = gen.iter().position(|&t| t == EOS).map(|p| p + 1);
            let n = cut.unwrap_or(self.n_gen);
            let response = gen[..n].to_vec();
            let behavior = lp[..n].to_vec();
            let text = tok.decode(&response);
            let problem = &pb.problems[i];
            let reward = verifier::reward(&text, problem.answer);
            let has_format = verifier::has_canonical_format(&text);
            rows.push(RolloutRow {
                prompt_len: pb.prompt_len.data[i] as usize,
                response,
                behavior,
                text,
                reward,
                hit_eos: cut.is_some(),
                has_format,
            });
        }
        Ok(Rollout { rows, group: pb.group })
    }

    /// Assemble the GRPO train batch for this engine's geometry.
    pub fn train_batch(&self, pb: &PromptBatch, roll: &Rollout, t_train: usize) -> TrainBatch {
        build_train_batch(pb, roll, self.t_prefill, t_train)
    }
}

/// Assemble a GRPO train batch: prompt ++ response right-padded to t_train,
/// loss mask + behavior log-probs aligned to response tokens, group-relative
/// advantages per sequence.
pub fn build_train_batch(
    pb: &PromptBatch,
    roll: &Rollout,
    t_prefill: usize,
    t_train: usize,
) -> TrainBatch {
    let b = roll.rows.len();
    let mut tokens = vec![PAD; b * t_train];
    let mut mask = vec![0.0f32; b * (t_train - 1)];
    let mut behavior = vec![0.0f32; b * (t_train - 1)];
    for (i, row) in roll.rows.iter().enumerate() {
        let plen = row.prompt_len;
        let prow = &pb.tokens.data[i * t_prefill..(i + 1) * t_prefill];
        tokens[i * t_train..i * t_train + plen].copy_from_slice(&prow[..plen]);
        let n = row.response.len().min(t_train - plen);
        tokens[i * t_train + plen..i * t_train + plen + n].copy_from_slice(&row.response[..n]);
        for j in 0..n {
            // response token j sits at position plen + j, predicted at plen+j-1
            let pos = plen + j - 1;
            mask[i * (t_train - 1) + pos] = 1.0;
            behavior[i * (t_train - 1) + pos] = row.behavior[j];
        }
    }
    let rewards: Vec<f32> = roll.rows.iter().map(|r| r.reward).collect();
    let adv = group_advantages(&rewards, roll.group);
    TrainBatch {
        tokens: TensorI32::from_vec(&[b, t_train], tokens),
        mask: TensorF32::from_vec(&[b, t_train - 1], mask),
        behavior: TensorF32::from_vec(&[b, t_train - 1], behavior),
        advantages: TensorF32::from_vec(&[b], adv),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::corpus::prompt_batch;
    use crate::tasks::generator::SUITES;

    /// train_batch alignment without a runtime: hand-build a Rollout.
    #[test]
    fn train_batch_alignment() {
        let tok = Tokenizer::new();
        let mut rng = Pcg64::new(1);
        let probs: Vec<_> = (0..2).map(|_| SUITES[0].generate(&mut rng)).collect();
        let pb = prompt_batch(&probs, &tok, 2, 64);
        let rows: Vec<RolloutRow> = (0..4)
            .map(|i| {
                let mut response = tok.encode("#### 7");
                response.push(EOS);
                RolloutRow {
                    prompt_len: pb.prompt_len.data[i] as usize,
                    behavior: vec![-0.5; response.len()],
                    response,
                    text: "#### 7".into(),
                    reward: if i % 2 == 0 { 1.0 } else { 0.0 },
                    hit_eos: true,
                    has_format: true,
                }
            })
            .collect();
        let roll = Rollout { rows, group: 2 };
        let tb = build_train_batch(&pb, &roll, 64, 128);
        for i in 0..4 {
            let plen = pb.prompt_len.data[i] as usize;
            // prompt copied
            assert_eq!(tb.tokens.data[i * 128], crate::tokenizer::BOS);
            // first response position is masked-in and has behavior
            assert_eq!(tb.mask.data[i * 127 + plen - 1], 1.0);
            assert_eq!(tb.behavior.data[i * 127 + plen - 1], -0.5);
            // position before response start is not scored
            assert_eq!(tb.mask.data[i * 127 + plen - 2], 0.0);
            // EOS is scored (model must learn to stop)
            let n = roll.rows[i].response.len();
            assert_eq!(tb.mask.data[i * 127 + plen + n - 2], 1.0);
            assert_eq!(tb.mask.data[i * 127 + plen + n - 1], 0.0);
        }
        // group advantages: (1,0) groups -> +/-; centred
        assert!(tb.advantages.data[0] > 0.0 && tb.advantages.data[1] < 0.0);
    }
}
