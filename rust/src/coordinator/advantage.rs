//! Group-relative advantage estimation (the "GR" in GRPO).
//!
//! Rewards for the k samples of one prompt are normalised within the group:
//! A_i = (r_i - mean(r)) / (std(r) + eps).  Degenerate groups (all same
//! reward) get zero advantage — no gradient signal, exactly as in GRPO.

use crate::util::{mean, std_dev};

pub const ADV_EPS: f32 = 1e-4;

/// rewards.len() must be a multiple of `group`; samples of one prompt are
/// contiguous. Returns one advantage per sample.
pub fn group_advantages(rewards: &[f32], group: usize) -> Vec<f32> {
    assert!(group > 0 && rewards.len() % group == 0);
    let mut adv = Vec::with_capacity(rewards.len());
    for chunk in rewards.chunks(group) {
        let m = mean(chunk);
        let s = std_dev(chunk);
        if s < ADV_EPS {
            adv.extend(std::iter::repeat(0.0).take(group));
        } else {
            adv.extend(chunk.iter().map(|r| (r - m) / (s + ADV_EPS)));
        }
    }
    adv
}

/// Fraction of groups that produce any learning signal (non-degenerate).
///
/// Like [`group_advantages`], `rewards.len()` must be a multiple of
/// `group`. (It used to floor the divisor while still counting a trailing
/// short chunk as a live group, silently overstating the fraction on
/// ragged input — now ragged input is rejected up front.)
pub fn frac_informative_groups(rewards: &[f32], group: usize) -> f32 {
    assert!(group > 0 && rewards.len() % group == 0);
    let n = rewards.len() / group;
    if n == 0 {
        return 0.0;
    }
    let live = rewards
        .chunks(group)
        .filter(|c| std_dev(c) >= ADV_EPS)
        .count();
    live as f32 / n as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;

    #[test]
    fn degenerate_groups_get_zero() {
        assert_eq!(group_advantages(&[1.0, 1.0, 1.0, 1.0], 4), vec![0.0; 4]);
        assert_eq!(group_advantages(&[0.0, 0.0], 2), vec![0.0; 2]);
    }

    #[test]
    fn mixed_group_is_centred_and_signed() {
        let adv = group_advantages(&[1.0, 0.0, 0.0, 0.0], 4);
        assert!(adv[0] > 0.0);
        assert!(adv[1] < 0.0);
        let sum: f32 = adv.iter().sum();
        assert!(sum.abs() < 1e-4);
    }

    #[test]
    fn properties_hold_for_random_rewards() {
        check("advantages centred + unit-ish scale", 200, |rng| {
            let group = rng.range_i64(2, 8) as usize;
            let n_groups = rng.range_i64(1, 6) as usize;
            let rewards: Vec<f32> =
                (0..group * n_groups).map(|_| (rng.below(2)) as f32).collect();
            let adv = group_advantages(&rewards, group);
            for (g, chunk) in adv.chunks(group).enumerate() {
                let s: f32 = chunk.iter().sum();
                if s.abs() > 1e-3 {
                    return Err(format!("group {g} not centred: {s}"));
                }
                let rchunk = &rewards[g * group..(g + 1) * group];
                // advantage sign must match reward sign relative to the mean
                let m = crate::util::mean(rchunk);
                for (a, r) in chunk.iter().zip(rchunk) {
                    if (r - m).abs() > 1e-6 && a * (r - m) <= 0.0 {
                        return Err("sign mismatch".into());
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn informative_fraction() {
        let r = [1.0, 0.0, 1.0, 1.0, 0.0, 0.0, 1.0, 0.0];
        assert_eq!(frac_informative_groups(&r, 2), 0.5);
    }

    /// Regression (ISSUE 10 satellite): a trailing short chunk used to be
    /// counted as a live group while the divisor floored — 5 rewards at
    /// group 2 reported 2 live / 2 groups = 1.0 even though the "third
    /// group" was a single sample. Ragged input is now rejected exactly
    /// like `group_advantages` rejects it.
    #[test]
    #[should_panic]
    fn informative_fraction_rejects_ragged_input() {
        frac_informative_groups(&[1.0, 0.0, 1.0, 0.0, 1.0], 2);
    }

    #[test]
    fn informative_fraction_empty_is_zero() {
        assert_eq!(frac_informative_groups(&[], 4), 0.0);
    }
}
