//! A `Policy` bundles everything one trainable configuration needs:
//! frozen base weights, frozen SVD factors, the flat trainable vector
//! (theta), the merged inference-plane weights, and the AOT executables
//! that compute gradients and merges.
//!
//! Invariant maintained by `remerge`: `merged` always equals the base model
//! with the current adapter folded in — the inference plane never sees the
//! adapter parameterisation (the paper's merged-weights trick; the
//! numerical gap is absorbed by TIS in the GRPO loss).

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::adapters::factors::FactorSet;
use crate::adapters::packing::{roundtrip, Precision};
use crate::adapters::Theta;
use crate::manifest::TierInfo;
use crate::runtime::{Executable, Runtime};
use crate::tensor::{Arg, TensorF32, TensorI32};
use crate::weights::WeightSet;

/// One GRPO/SFT training batch in executable layout.
pub struct TrainBatch {
    pub tokens: TensorI32,     // [B, T]
    pub mask: TensorF32,       // [B, T-1]
    pub behavior: TensorF32,   // [B, T-1] (grpo only)
    pub advantages: TensorF32, // [B]      (grpo only)
}

#[derive(Clone, Copy, Debug, Default)]
pub struct GrpoHp {
    pub clip_c: f32,
    pub kl_coef: f32,
}

/// Stats vector layout (mirrors model.py's jnp.stack order).
#[derive(Clone, Copy, Debug, Default)]
pub struct GradStats {
    pub loss: f32,
    pub aux1: f32, // grpo: pg loss | sft: accuracy
    pub kl_k1: f32,
    pub kl_k3: f32,
    pub mean_ratio: f32,
    pub frac_clipped: f32,
    pub entropy: f32,
    pub mean_logp: f32,
    pub grad_norm: f32, // filled by the trainer
}

impl GradStats {
    pub fn from_vec(v: &[f32]) -> Self {
        Self {
            loss: v[0],
            aux1: v[1],
            kl_k1: v[2],
            kl_k3: v[3],
            mean_ratio: v[4],
            frac_clipped: v[5],
            entropy: v[6],
            mean_logp: v[7],
            grad_norm: 0.0,
        }
    }
}

pub struct Policy {
    pub tier: TierInfo,
    pub scheme_tag: String,
    pub algo: String, // "grpo" | "sft"
    /// Frozen pretrained weights (adapter schemes). For "full", the live weights.
    pub base: WeightSet,
    /// Inference-plane weights (base with adapter folded in).
    pub merged: WeightSet,
    pub factors: Option<FactorSet>,
    pub theta: Vec<f32>,
    /// Precision the applied update is stored/communicated at (Fig. 4).
    pub precision: Precision,
    merge_exe: Option<Arc<Executable>>,
    pub is_full: bool,
}

/// The seven adapted weight-tensor names, manifest order.
pub const ADAPTED: [&str; 7] =
    ["attn_q", "attn_k", "attn_v", "attn_o", "mlp_up", "mlp_gate", "mlp_down"];

impl Policy {
    pub fn new(
        rt: &Runtime,
        tier_name: &str,
        scheme_tag: &str,
        algo: &str,
        base: WeightSet,
        seed: u64,
        cache_dir: &Path,
    ) -> Result<Self> {
        let tier = rt.manifest.tier(tier_name)?.clone();
        if base.tier != tier_name {
            bail!("checkpoint tier {} != requested {tier_name}", base.tier);
        }
        // validate the scheme + grab its theta layout (identical across the
        // batch variants of the same scheme)
        let grad_info = rt.manifest.grad_exe(tier_name, algo, scheme_tag)?.clone();
        let is_full = scheme_tag == "full";

        let (factors, theta, merge_exe) = if is_full {
            (None, Vec::new(), None)
        } else {
            let scheme = grad_info
                .scheme
                .as_ref()
                .context("adapter artifact missing scheme info")?;
            let needs_factors = scheme.kind == "tinylora" || scheme.kind == "lora_xs";
            let factors = if needs_factors {
                Some(FactorSet::cached(&tier, &base, scheme.r, cache_dir)?)
            } else {
                None
            };
            let theta = Theta::init(&grad_info, seed)?.data;
            let merge_exe = rt.load(&rt.manifest.merge_exe(tier_name, scheme_tag)?.name)?;
            (factors, theta, Some(merge_exe))
        };

        let merged = base.clone();
        let mut p = Self {
            tier,
            scheme_tag: scheme_tag.to_string(),
            algo: algo.to_string(),
            base,
            merged,
            factors,
            theta,
            precision: Precision::F32,
            merge_exe,
            is_full,
        };
        p.remerge(rt)?; // lora's random-A theta still merges to identity (B=0)
        Ok(p)
    }

    /// Merge a stored adapter's theta into `base` without constructing a
    /// full `Policy`: no base clone for the frozen copy, no `Theta`
    /// re-init, exactly one merge execution.  This is the serving
    /// store's promotion path — the old `activate` built a `Policy`
    /// (base clone + identity merge) and then re-merged with the real
    /// theta, i.e. two merges and two base copies per cold activation.
    ///
    /// `factors`: pass a cached set to skip the per-call disk/SVD path;
    /// `None` falls back to [`FactorSet::cached`].  Schemes that need no
    /// factors ignore the argument.
    pub fn merge_theta(
        rt: &Runtime,
        tier_name: &str,
        scheme_tag: &str,
        base: &WeightSet,
        theta: &[f32],
        cache_dir: &Path,
        factors: Option<&FactorSet>,
    ) -> Result<WeightSet> {
        if scheme_tag == "full" {
            bail!("scheme \"full\" has no adapter theta to merge");
        }
        if base.tier != tier_name {
            bail!("checkpoint tier {} != requested {tier_name}", base.tier);
        }
        let grad_info = rt.manifest.grad_exe(tier_name, "grpo", scheme_tag)?;
        let scheme = grad_info.scheme.as_ref().context("adapter artifact missing scheme info")?;
        if let Some(want) = grad_info.theta_size {
            if theta.len() != want {
                bail!("theta has {} params, scheme {scheme_tag} wants {want}", theta.len());
            }
        }
        let computed;
        let factors = if scheme.kind == "tinylora" || scheme.kind == "lora_xs" {
            Some(match factors {
                Some(f) => f,
                None => {
                    let tier = rt.manifest.tier(tier_name)?.clone();
                    computed = FactorSet::cached(&tier, base, scheme.r, cache_dir)?;
                    &computed
                }
            })
        } else {
            None
        };
        let merge_exe = rt.load(&rt.manifest.merge_exe(tier_name, scheme_tag)?.name)?;
        let mut args: Vec<Arg> = Vec::with_capacity(ADAPTED.len() + 15);
        for name in ADAPTED {
            args.push(Arg::F32(base.get(name)?.clone()));
        }
        if let Some(f) = factors {
            args.extend(f.args());
        }
        args.push(Arg::F32(TensorF32::from_vec(&[theta.len()], theta.to_vec())));
        let out = rt.run(&merge_exe, &args)?;
        let mut merged = base.clone();
        for (i, name) in ADAPTED.iter().enumerate() {
            merged.set(name, out.f32(i)?)?;
        }
        Ok(merged)
    }

    /// Number of trained parameters (the paper's x-axis).
    pub fn trainable_params(&self) -> usize {
        if self.is_full {
            self.base.n_params()
        } else {
            self.theta.len()
        }
    }

    /// Update size in bytes at the configured precision.
    pub fn update_bytes(&self) -> usize {
        self.trainable_params() * self.precision.bytes()
    }

    /// Current flat trainable vector.
    pub fn params(&self) -> Vec<f32> {
        if self.is_full {
            self.merged.flat()
        } else {
            self.theta.clone()
        }
    }

    /// Install updated parameters, applying the storage-precision roundtrip
    /// (f32 optimizer state is the caller's responsibility).
    pub fn set_params(&mut self, rt: &Runtime, params: &[f32]) -> Result<()> {
        let q = roundtrip(params, self.precision);
        if self.is_full {
            self.merged.set_flat(&q)?;
        } else {
            if q.len() != self.theta.len() {
                bail!("param len mismatch");
            }
            self.theta = q;
            self.remerge(rt)?;
        }
        Ok(())
    }

    /// Fold the adapter into `merged` (no-op for full).
    pub fn remerge(&mut self, rt: &Runtime) -> Result<()> {
        let Some(merge_exe) = &self.merge_exe else {
            return Ok(());
        };
        let mut args: Vec<Arg> = Vec::new();
        for name in ADAPTED {
            args.push(Arg::F32(self.base.get(name)?.clone()));
        }
        if let Some(f) = &self.factors {
            args.extend(f.args());
        }
        args.push(Arg::F32(TensorF32::from_vec(&[self.theta.len()], self.theta.clone())));
        let out = rt.run(merge_exe, &args)?;
        for (i, name) in ADAPTED.iter().enumerate() {
            self.merged.set(name, out.f32(i)?)?;
        }
        Ok(())
    }

    /// Compute the gradient of the configured loss on a batch.  The grad
    /// executable is resolved by the batch's leading dimension, so one
    /// Policy serves both the train-batch and test-batch artifacts.
    /// Returns (flat gradient, stats).
    pub fn grad(&self, rt: &Runtime, batch: &TrainBatch, hp: GrpoHp) -> Result<(Vec<f32>, GradStats)> {
        let b = batch.tokens.shape[0];
        let grad_exe = rt.load(
            &rt.manifest
                .grad_exe_b(&self.tier.name, &self.algo, &self.scheme_tag, b)?
                .name,
        )?;
        let mut args: Vec<Arg> = if self.is_full {
            self.merged.args()
        } else {
            let mut a = self.base.args();
            if let Some(f) = &self.factors {
                a.extend(f.args());
            }
            a.push(Arg::F32(TensorF32::from_vec(&[self.theta.len()], self.theta.clone())));
            a
        };
        args.push(Arg::I32(batch.tokens.clone()));
        args.push(Arg::F32(batch.mask.clone()));
        if self.algo == "grpo" {
            args.push(Arg::F32(batch.behavior.clone()));
            args.push(Arg::F32(batch.advantages.clone()));
            args.push(Arg::Scalar(hp.clip_c));
            args.push(Arg::Scalar(hp.kl_coef));
        }
        let out = rt.run(&grad_exe, &args)?;
        let n_out = out.len();
        let stats_t = out.f32(n_out - 1)?;
        let stats = GradStats::from_vec(&stats_t.data);
        let grad = if self.is_full {
            let mut flat = Vec::with_capacity(self.base.n_params());
            for i in 0..n_out - 1 {
                flat.extend_from_slice(&out.f32(i)?.data);
            }
            flat
        } else {
            out.f32(0)?.data
        };
        Ok((grad, stats))
    }

    /// Pretrained-checkpoint convention used by all drivers.
    pub fn load_base(rt: &Runtime, tier: &str, ckpt_dir: &Path) -> Result<WeightSet> {
        let path = WeightSet::ckpt_path(ckpt_dir, tier);
        WeightSet::load(&path).with_context(|| {
            format!("no pretrained checkpoint for tier {tier:?} — run `tinylora-rl pretrain --tier {tier}` first")
        })
    }
}
