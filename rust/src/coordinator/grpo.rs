//! The GRPO loop: the paper's training loss (§5), as a thin
//! `trainer::TrainLoop` impl.
//!
//! Per step: sample a group-structured prompt batch, roll out with the
//! *merged* inference weights, verify (exact-match reward), compute
//! group-relative advantages, run the AOT gradient executable under
//! truncated importance sampling. Optimizer wiring, LR scheduling, grad
//! clipping, logging and checkpointing all live in `trainer::TrainSession`
//! — this module owns only what the GRPO loss *means*.
//!
//! The step is split into `plan` → rollout → `finish` so `TenantTrainer`
//! can batch many tenants' rollout waves through the shared
//! `engine::WorkerPool`: a plan carries the rollout seed, and both the
//! in-loop and the pooled path derive the decode RNG from it on the same
//! stream, so pooled results are bit-identical to serial ones.

use anyhow::Result;

use crate::coordinator::policy::{GradStats, GrpoHp, Policy};
use crate::coordinator::rollout::{Rollout, RolloutEngine};
use crate::engine::pool::POOL_STREAM;
use crate::metrics::RunLog;
use crate::runtime::Runtime;
use crate::tasks::corpus::{prompt_batch, PromptBatch};
use crate::tasks::generator::{suite, Problem, Suite, SUITES};
use crate::tokenizer::Tokenizer;
use crate::trainer::{AuxMetrics, GradOutput, SessionConfig, TrainLoop, TrainSession};
use crate::util::Pcg64;

/// RNG stream tag for the GRPO session ("grpo" — historical).
pub const GRPO_STREAM: u64 = 0x6772706f;

#[derive(Clone, Debug)]
pub struct GrpoConfig {
    /// training suite name, or "math-mix" for the SimpleRL-style mixture
    pub suite: String,
    pub group: usize,
    pub steps: usize,
    pub lr: f32,
    pub warmup: u64,
    pub temperature: f32,
    pub clip_c: f32,
    pub kl_coef: f32,
    pub grad_clip: f32,
    pub seed: u64,
}

impl Default for GrpoConfig {
    fn default() -> Self {
        Self {
            suite: "gsm8k-syn".into(),
            group: 4,
            steps: 60,
            lr: 2e-3,
            warmup: 5,
            temperature: 1.0,
            clip_c: 4.0,
            kl_coef: 0.0,
            grad_clip: 1.0,
            seed: 0,
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    pub step: usize,
    pub reward: f32,
    pub response_len: f32,
    pub format_rate: f32,
    pub eos_rate: f32,
    pub lr: f32,
    pub stats: GradStats,
    pub rollout_ms: f64,
    pub grad_ms: f64,
}

/// Draw training problems, honouring the "math-mix" pseudo-suite.
pub fn draw_problems(suite_name: &str, n: usize, rng: &mut Pcg64) -> Vec<Problem> {
    (0..n)
        .map(|_| {
            let s: &Suite = if suite_name == "math-mix" {
                // the harder tiers, mirroring SimpleRL's hardest-difficulty split
                *rng.choice(&[&SUITES[1], &SUITES[2], &SUITES[3], &SUITES[4]])
            } else {
                suite(suite_name).unwrap_or(&SUITES[0])
            };
            s.generate(rng)
        })
        .collect()
}

/// Phase-1 output of a GRPO step: everything the rollout needs, detached
/// from the loop so it can be shipped to a worker pool. The decode RNG is
/// derived from `seed` on `engine::pool::POOL_STREAM` in both the in-loop
/// and the pooled path.
pub struct RolloutPlan {
    pub problems: Vec<Problem>,
    pub pb: PromptBatch,
    pub seed: u64,
}

pub struct GrpoLoop {
    pub cfg: GrpoConfig,
    pub policy: Policy,
    engine: RolloutEngine,
    tok: Tokenizer,
}

impl GrpoLoop {
    /// Training-plane geometry (`manifest.batch.roll`).
    pub fn new(rt: &Runtime, policy: Policy, cfg: GrpoConfig) -> Result<Self> {
        let batch = rt.manifest.batch.roll;
        Self::with_batch(rt, policy, cfg, batch)
    }

    /// Explicit decode geometry (tests and tiny tiers use `batch.test`).
    pub fn with_batch(rt: &Runtime, policy: Policy, cfg: GrpoConfig, batch: usize) -> Result<Self> {
        let engine = RolloutEngine::new(rt, &policy.tier.name, batch)?;
        // user-reachable via --group: reject bad geometry here as an error
        // (the assert in `plan` is then a pure internal invariant)
        if cfg.group == 0 || engine.batch % cfg.group != 0 {
            anyhow::bail!(
                "group {} must divide the decode batch {}",
                cfg.group,
                engine.batch
            );
        }
        Ok(Self { cfg, policy, engine, tok: Tokenizer::new() })
    }

    /// Decode batch size of this loop's engine.
    pub fn batch(&self) -> usize {
        self.engine.batch
    }

    /// Phase 1 (coordinator thread): draw the group-structured prompt batch
    /// and the rollout seed from the session RNG.
    pub fn plan(&self, rng: &mut Pcg64) -> RolloutPlan {
        let b = self.engine.batch;
        assert!(b % self.cfg.group == 0, "batch {b} not divisible by group {}", self.cfg.group);
        let n_prompts = b / self.cfg.group;
        let problems = draw_problems(&self.cfg.suite, n_prompts, rng);
        let pb = prompt_batch(&problems, &self.tok, self.cfg.group, self.engine.t_prefill);
        RolloutPlan { problems, pb, seed: rng.next_u64() }
    }

    /// Phase 2, in-loop variant: sample the planned batch from the merged
    /// weights. `TenantTrainer` ships the same plan to the `WorkerPool`
    /// instead; both derive the decode RNG identically. Returns the rollout
    /// and its wall time.
    pub fn rollout_planned(&self, rt: &Runtime, plan: &RolloutPlan) -> Result<(Rollout, f64)> {
        let t0 = crate::util::Timer::start();
        let mut rng = Pcg64::with_stream(plan.seed, POOL_STREAM);
        let roll = self.engine.rollout(
            rt,
            &self.policy.merged,
            &plan.pb,
            &self.tok,
            self.cfg.temperature,
            &mut rng,
        )?;
        Ok((roll, t0.millis()))
    }

    /// Phase 3: assemble the train batch and run the gradient executable
    /// under truncated importance sampling.
    pub fn finish(
        &self,
        rt: &Runtime,
        plan: &RolloutPlan,
        roll: &Rollout,
        rollout_ms: f64,
    ) -> Result<GradOutput> {
        let batch = self.engine.train_batch(&plan.pb, roll, self.policy.tier.t_train);
        let hp = GrpoHp { clip_c: self.cfg.clip_c, kl_coef: self.cfg.kl_coef };
        let t1 = crate::util::Timer::start();
        let (grad, stats) = self.policy.grad(rt, &batch, hp)?;
        let grad_ms = t1.millis();
        let eos_rate = crate::util::mean(
            &roll.rows.iter().map(|r| if r.hit_eos { 1.0 } else { 0.0 }).collect::<Vec<_>>(),
        );
        Ok(GradOutput {
            grad,
            stats,
            aux: AuxMetrics {
                reward: roll.mean_reward(),
                response_len: roll.mean_response_len(),
                format_rate: roll.format_rate(),
                eos_rate,
            },
            rollout_ms,
            grad_ms,
        })
    }
}

impl TrainLoop for GrpoLoop {
    type Record = StepRecord;

    fn algo(&self) -> &'static str {
        "grpo"
    }

    fn tier(&self) -> &str {
        &self.policy.tier.name
    }

    fn scheme_tag(&self) -> &str {
        &self.policy.scheme_tag
    }

    fn config_tag(&self) -> String {
        let c = &self.cfg;
        // batch is trajectory-shaping too: plan() draws batch/group prompts
        // per step, so a state saved at batch.test must not resume at
        // batch.roll
        format!(
            "suite={} batch={} group={} lr={} warmup={} temp={} clip_c={} kl={} grad_clip={} seed={}",
            c.suite, self.engine.batch, c.group, c.lr, c.warmup, c.temperature, c.clip_c,
            c.kl_coef, c.grad_clip, c.seed
        )
    }

    fn n_params(&self) -> usize {
        self.policy.trainable_params()
    }

    fn params(&self) -> Vec<f32> {
        self.policy.params()
    }

    fn set_params(&mut self, rt: &Runtime, params: &[f32]) -> Result<()> {
        self.policy.set_params(rt, params)
    }

    fn compute(&mut self, rt: &Runtime, _step: usize, rng: &mut Pcg64) -> Result<GradOutput> {
        let plan = self.plan(rng);
        let (roll, rollout_ms) = self.rollout_planned(rt, &plan)?;
        self.finish(rt, &plan, &roll, rollout_ms)
    }

    fn record(
        &self,
        step: usize,
        lr: f32,
        out: &GradOutput,
        grad_norm: f32,
        log: &mut RunLog,
    ) -> StepRecord {
        let mut stats = out.stats;
        stats.grad_norm = grad_norm;
        let rec = StepRecord {
            step,
            reward: out.aux.reward,
            response_len: out.aux.response_len,
            format_rate: out.aux.format_rate,
            eos_rate: out.aux.eos_rate,
            lr,
            stats,
            rollout_ms: out.rollout_ms,
            grad_ms: out.grad_ms,
        };
        log.log_step("grpo", &self.policy, &rec);
        rec
    }
}

/// Session hyperparameters for one GRPO config (checkpointing off; callers
/// opt in via `session.cfg`).
pub fn grpo_session_cfg(cfg: &GrpoConfig) -> SessionConfig {
    SessionConfig {
        steps: cfg.steps,
        lr: cfg.lr,
        warmup: cfg.warmup,
        grad_clip: cfg.grad_clip,
        seed: cfg.seed,
        stream: GRPO_STREAM,
        ckpt_every: 0,
        ckpt_path: None,
    }
}

/// Build a full GRPO training session (the former `GrpoTrainer::new` plus
/// the optimizer wiring, now session-owned).
pub fn grpo_session(rt: &Runtime, policy: Policy, cfg: GrpoConfig) -> Result<TrainSession<GrpoLoop>> {
    let scfg = grpo_session_cfg(&cfg);
    Ok(TrainSession::new(GrpoLoop::new(rt, policy, cfg)?, scfg))
}
