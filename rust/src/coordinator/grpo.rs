//! The GRPO trainer: the paper's training loop (§5).
//!
//! Per step: sample a group-structured prompt batch, roll out with the
//! *merged* inference weights, verify (exact-match reward), compute
//! group-relative advantages, run the AOT gradient executable under
//! truncated importance sampling, apply Adam in rust, re-merge.

use anyhow::Result;

use crate::coordinator::optimizer::{lr_at, Adam, AdamConfig};
use crate::coordinator::policy::{GradStats, GrpoHp, Policy};
use crate::coordinator::rollout::RolloutEngine;
use crate::metrics::RunLog;
use crate::runtime::Runtime;
use crate::tasks::corpus::prompt_batch;
use crate::tasks::generator::{suite, Problem, Suite, SUITES};
use crate::tokenizer::Tokenizer;
use crate::util::Pcg64;

#[derive(Clone, Debug)]
pub struct GrpoConfig {
    /// training suite name, or "math-mix" for the SimpleRL-style mixture
    pub suite: String,
    pub group: usize,
    pub steps: usize,
    pub lr: f32,
    pub warmup: u64,
    pub temperature: f32,
    pub clip_c: f32,
    pub kl_coef: f32,
    pub grad_clip: f32,
    pub seed: u64,
}

impl Default for GrpoConfig {
    fn default() -> Self {
        Self {
            suite: "gsm8k-syn".into(),
            group: 4,
            steps: 60,
            lr: 2e-3,
            warmup: 5,
            temperature: 1.0,
            clip_c: 4.0,
            kl_coef: 0.0,
            grad_clip: 1.0,
            seed: 0,
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    pub step: usize,
    pub reward: f32,
    pub response_len: f32,
    pub format_rate: f32,
    pub eos_rate: f32,
    pub lr: f32,
    pub stats: GradStats,
    pub rollout_ms: f64,
    pub grad_ms: f64,
}

/// Draw training problems, honouring the "math-mix" pseudo-suite.
pub fn draw_problems(suite_name: &str, n: usize, rng: &mut Pcg64) -> Vec<Problem> {
    (0..n)
        .map(|_| {
            let s: &Suite = if suite_name == "math-mix" {
                // the harder tiers, mirroring SimpleRL's hardest-difficulty split
                *rng.choice(&[&SUITES[1], &SUITES[2], &SUITES[3], &SUITES[4]])
            } else {
                suite(suite_name).unwrap_or(&SUITES[0])
            };
            s.generate(rng)
        })
        .collect()
}

pub struct GrpoTrainer {
    pub cfg: GrpoConfig,
    pub engine: RolloutEngine,
    opt: Adam,
    rng: Pcg64,
    tok: Tokenizer,
    step: usize,
}

impl GrpoTrainer {
    pub fn new(rt: &Runtime, policy: &Policy, cfg: GrpoConfig) -> Result<Self> {
        let engine = RolloutEngine::new(rt, &policy.tier.name, rt.manifest.batch.roll)?;
        let opt = Adam::new(
            policy.params().len(),
            AdamConfig { lr: cfg.lr, grad_clip: cfg.grad_clip, ..Default::default() },
        );
        let rng = Pcg64::with_stream(cfg.seed, 0x6772706f);
        Ok(Self { cfg, engine, opt, rng, tok: Tokenizer::new(), step: 0 })
    }

    /// One full GRPO step; returns the step record.
    pub fn step(&mut self, rt: &Runtime, policy: &mut Policy) -> Result<StepRecord> {
        let b = self.engine.batch;
        assert!(b % self.cfg.group == 0);
        let n_prompts = b / self.cfg.group;
        let problems = draw_problems(&self.cfg.suite, n_prompts, &mut self.rng);
        let pb = prompt_batch(&problems, &self.tok, self.cfg.group, self.engine.t_prefill);

        let t0 = crate::util::Timer::start();
        let roll = self.engine.rollout(
            rt,
            &policy.merged,
            &pb,
            &self.tok,
            self.cfg.temperature,
            &mut self.rng,
        )?;
        let rollout_ms = t0.millis();

        let batch = self.engine.train_batch(&pb, &roll, policy.tier.t_train);
        let hp = GrpoHp { clip_c: self.cfg.clip_c, kl_coef: self.cfg.kl_coef };
        let t1 = crate::util::Timer::start();
        let (grad, mut stats) = policy.grad(rt, &batch, hp)?;
        let grad_ms = t1.millis();

        self.opt.set_lr(lr_at(self.cfg.lr, self.cfg.warmup, self.step as u64));
        let mut params = policy.params();
        stats.grad_norm = self.opt.step(&mut params, &grad);
        policy.set_params(rt, &params)?;

        let rec = StepRecord {
            step: self.step,
            reward: roll.mean_reward(),
            response_len: roll.mean_response_len(),
            format_rate: roll.format_rate(),
            eos_rate: crate::util::mean(
                &roll.rows.iter().map(|r| if r.hit_eos { 1.0 } else { 0.0 }).collect::<Vec<_>>(),
            ),
            lr: self.opt.cfg.lr,
            stats,
            rollout_ms,
            grad_ms,
        };
        self.step += 1;
        Ok(rec)
    }

    /// Run the configured number of steps, logging as we go.
    pub fn train(
        &mut self,
        rt: &Runtime,
        policy: &mut Policy,
        log: &mut RunLog,
    ) -> Result<Vec<StepRecord>> {
        let mut records = Vec::with_capacity(self.cfg.steps);
        for _ in 0..self.cfg.steps {
            let rec = self.step(rt, policy)?;
            log.log_step("grpo", policy, &rec);
            records.push(rec);
        }
        Ok(records)
    }
}
