//! L3 coordinator — the paper's training system: rollout engine, GRPO/SFT
//! trainers, group-relative advantages, optimizers, pretraining and the LR
//! sweep protocol.  Python never appears here: every gradient/merge/sample
//! is an AOT-compiled executable behind `runtime::Runtime`.

pub mod advantage;
pub mod grpo;
pub mod optimizer;
pub mod policy;
pub mod pretrain;
pub mod rollout;
pub mod sft;
pub mod sweep;

pub use grpo::{GrpoConfig, GrpoTrainer};
pub use policy::{GradStats, GrpoHp, Policy, TrainBatch};
pub use pretrain::{pretrain, PretrainConfig};
pub use rollout::{Rollout, RolloutEngine};
pub use sft::{SftConfig, SftTrainer};
