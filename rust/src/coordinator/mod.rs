//! L3 coordinator — the paper's training losses and protocols: rollout
//! engine, the GRPO/SFT/pretrain `TrainLoop` impls, group-relative
//! advantages, optimizers and the LR sweep protocol.  The shared step
//! skeleton (optimizer wiring, LR schedule, logging, checkpoint/resume)
//! lives in `crate::trainer`; this module owns what each loss *means*.
//! Python never appears here: every gradient/merge/sample is an
//! AOT-compiled executable behind `runtime::Runtime`.

pub mod advantage;
pub mod grpo;
pub mod optimizer;
pub mod policy;
pub mod pretrain;
pub mod rollout;
pub mod sft;
pub mod sweep;

pub use grpo::{grpo_session, GrpoConfig, GrpoLoop, StepRecord};
pub use policy::{GradStats, GrpoHp, Policy, TrainBatch};
pub use pretrain::{pretrain, pretrain_session, PretrainConfig, PretrainLoop};
pub use rollout::{Rollout, RolloutEngine};
pub use sft::{sft_session, SftConfig, SftLoop};
pub use sweep::{
    sweep_population, sweep_scheme, sweep_scheme_full, HalvingConfig, PopulationOutcome,
    SweepConfig, SweepOutcome,
};
