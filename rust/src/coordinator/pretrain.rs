//! From-scratch pretraining of the base models (the substitution for the
//! paper's Qwen/Llama checkpoints — DESIGN.md §2).
//!
//! LM loss over the synthetic corpus: word problems solved in a *mixture*
//! of answer formats (only one of which the verifier rewards) plus
//! arithmetic drills.  The result is a base model that owns the arithmetic
//! capability but splits its probability mass across styles — the precise
//! precondition for the paper's "RL elicits style" finding.

use std::path::Path;

use anyhow::Result;

use crate::coordinator::optimizer::{lr_at, Adam, AdamConfig};
use crate::metrics::RunLog;
use crate::runtime::Runtime;
use crate::tasks::corpus::pretrain_batch;
use crate::tasks::generator::{suite, SUITES};
use crate::tensor::Arg;
use crate::tokenizer::Tokenizer;
use crate::util::Pcg64;
use crate::weights::WeightSet;

#[derive(Clone, Debug)]
pub struct PretrainConfig {
    pub suite: String,
    pub steps: usize,
    pub lr: f32,
    pub warmup: u64,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for PretrainConfig {
    fn default() -> Self {
        Self { suite: "gsm8k-syn".into(), steps: 1500, lr: 3e-3, warmup: 50, seed: 0, log_every: 50 }
    }
}

pub struct PretrainResult {
    pub final_loss: f32,
    pub losses: Vec<(usize, f32)>,
}

/// Pretrain a tier from scratch and save the checkpoint.
pub fn pretrain(
    rt: &Runtime,
    tier_name: &str,
    cfg: &PretrainConfig,
    ckpt_dir: &Path,
    log: &mut RunLog,
) -> Result<PretrainResult> {
    let tier = rt.manifest.tier(tier_name)?.clone();
    let b = rt.manifest.batch.train;
    let t = tier.t_train;
    let exe = rt.load(
        &rt.manifest
            .find(&format!("pretrain {tier_name}"), |e| {
                e.fn_kind == "pretrain" && e.tier == tier_name && e.batch == b
            })?
            .name,
    )?;

    let mut weights = WeightSet::init(&tier, cfg.seed);
    let mut opt = Adam::new(weights.n_params(), AdamConfig { lr: cfg.lr, ..Default::default() });
    let mut rng = Pcg64::with_stream(cfg.seed, 0x70726574);
    let tok = Tokenizer::new();
    let s = suite(&cfg.suite).unwrap_or(&SUITES[0]);

    let mut losses = Vec::new();
    let mut final_loss = f32::NAN;
    for step in 0..cfg.steps {
        // corpus mixes the training suite with the harder tiers so every
        // eval suite's problem family appears in pretraining
        let s_step = if rng.uniform() < 0.5 { s } else { *rng.choice(&SUITES.iter().collect::<Vec<_>>()) };
        let (tokens, mask) = pretrain_batch(s_step, &tok, &mut rng, b, t);
        let mut args: Vec<Arg> = weights.args();
        args.push(Arg::I32(tokens));
        args.push(Arg::F32(mask));
        let out = rt.run(&exe, &args)?;
        let stats = out.f32(out.len() - 1)?;
        let loss = stats.data[0];
        final_loss = loss;

        let mut grad = Vec::with_capacity(weights.n_params());
        for i in 0..out.len() - 1 {
            grad.extend_from_slice(&out.f32(i)?.data);
        }
        opt.set_lr(lr_at(cfg.lr, cfg.warmup, step as u64));
        let mut flat = weights.flat();
        opt.step(&mut flat, &grad);
        weights.set_flat(&flat)?;

        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            losses.push((step, loss));
            log.log_pretrain(tier_name, step, loss, stats.data[1]);
        }
    }
    weights.save(&WeightSet::ckpt_path(ckpt_dir, tier_name))?;
    Ok(PretrainResult { final_loss, losses })
}
