//! From-scratch pretraining of the base models (the substitution for the
//! paper's Qwen/Llama checkpoints — DESIGN.md §2), as a thin
//! `trainer::TrainLoop` impl over the raw `WeightSet`.
//!
//! LM loss over the synthetic corpus: word problems solved in a *mixture*
//! of answer formats (only one of which the verifier rewards) plus
//! arithmetic drills.  The result is a base model that owns the arithmetic
//! capability but splits its probability mass across styles — the precise
//! precondition for the paper's "RL elicits style" finding.

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::policy::GradStats;
use crate::manifest::TierInfo;
use crate::metrics::RunLog;
use crate::runtime::{Executable, Runtime};
use crate::tasks::corpus::pretrain_batch;
use crate::tasks::generator::{suite, SUITES};
use crate::tensor::Arg;
use crate::tokenizer::Tokenizer;
use crate::trainer::{GradOutput, SessionConfig, TrainLoop, TrainSession};
use crate::util::Pcg64;
use crate::weights::WeightSet;

/// RNG stream tag for the pretraining session ("pret" — historical).
pub const PRETRAIN_STREAM: u64 = 0x70726574;

#[derive(Clone, Debug)]
pub struct PretrainConfig {
    pub suite: String,
    pub steps: usize,
    pub lr: f32,
    pub warmup: u64,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for PretrainConfig {
    fn default() -> Self {
        Self { suite: "gsm8k-syn".into(), steps: 1500, lr: 3e-3, warmup: 50, seed: 0, log_every: 50 }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct PretrainRecord {
    pub step: usize,
    pub loss: f32,
    pub token_acc: f32,
    pub lr: f32,
    pub grad_norm: f32,
}

pub struct PretrainResult {
    pub final_loss: f32,
    pub losses: Vec<(usize, f32)>,
}

pub struct PretrainLoop {
    pub cfg: PretrainConfig,
    pub weights: WeightSet,
    tier: TierInfo,
    exe: Arc<Executable>,
    tok: Tokenizer,
    batch: usize,
}

impl PretrainLoop {
    pub fn new(rt: &Runtime, tier_name: &str, cfg: PretrainConfig) -> Result<Self> {
        let tier = rt.manifest.tier(tier_name)?.clone();
        let b = rt.manifest.batch.train;
        let exe = rt.load(
            &rt.manifest
                .find(&format!("pretrain {tier_name}"), |e| {
                    e.fn_kind == "pretrain" && e.tier == tier_name && e.batch == b
                })?
                .name,
        )?;
        let weights = WeightSet::init(&tier, cfg.seed)?;
        Ok(Self { cfg, weights, tier, exe, tok: Tokenizer::new(), batch: b })
    }
}

impl TrainLoop for PretrainLoop {
    type Record = PretrainRecord;

    fn algo(&self) -> &'static str {
        "pretrain"
    }

    fn tier(&self) -> &str {
        &self.tier.name
    }

    fn config_tag(&self) -> String {
        let c = &self.cfg;
        format!(
            "suite={} batch={} lr={} warmup={} seed={}",
            c.suite, self.batch, c.lr, c.warmup, c.seed
        )
    }

    fn n_params(&self) -> usize {
        self.weights.n_params()
    }

    fn params(&self) -> Vec<f32> {
        self.weights.flat()
    }

    fn set_params(&mut self, _rt: &Runtime, params: &[f32]) -> Result<()> {
        self.weights.set_flat(params)
    }

    fn compute(&mut self, rt: &Runtime, _step: usize, rng: &mut Pcg64) -> Result<GradOutput> {
        // corpus mixes the training suite with the harder tiers so every
        // eval suite's problem family appears in pretraining
        let s = suite(&self.cfg.suite).unwrap_or(&SUITES[0]);
        let s_step =
            if rng.uniform() < 0.5 { s } else { *rng.choice(&SUITES.iter().collect::<Vec<_>>()) };
        let (tokens, mask) = pretrain_batch(s_step, &self.tok, rng, self.batch, self.tier.t_train);
        let mut args: Vec<Arg> = self.weights.args();
        args.push(Arg::I32(tokens));
        args.push(Arg::F32(mask));
        let t1 = crate::util::Timer::start();
        let out = rt.run(&self.exe, &args)?;
        let grad_ms = t1.millis();
        let stats_t = out.f32(out.len() - 1)?;
        let mut grad = Vec::with_capacity(self.weights.n_params());
        for i in 0..out.len() - 1 {
            grad.extend_from_slice(&out.f32(i)?.data);
        }
        // the pretrain executable reports [loss, token_acc]
        let stats = GradStats {
            loss: stats_t.data[0],
            aux1: stats_t.data[1],
            ..Default::default()
        };
        Ok(GradOutput { grad, stats, aux: Default::default(), rollout_ms: 0.0, grad_ms })
    }

    fn record(
        &self,
        step: usize,
        lr: f32,
        out: &GradOutput,
        grad_norm: f32,
        log: &mut RunLog,
    ) -> PretrainRecord {
        if step % self.cfg.log_every == 0 || step + 1 == self.cfg.steps {
            log.log_pretrain(&self.tier.name, step, out.stats.loss, out.stats.aux1);
        }
        PretrainRecord { step, loss: out.stats.loss, token_acc: out.stats.aux1, lr, grad_norm }
    }
}

/// Session hyperparameters for one pretraining config.
pub fn pretrain_session_cfg(cfg: &PretrainConfig) -> SessionConfig {
    SessionConfig {
        steps: cfg.steps,
        lr: cfg.lr,
        warmup: cfg.warmup,
        // the seed wired pretraining through Adam's default clip (1.0)
        grad_clip: 1.0,
        seed: cfg.seed,
        stream: PRETRAIN_STREAM,
        ckpt_every: 0,
        ckpt_path: None,
    }
}

/// Build a full pretraining session.
pub fn pretrain_session(
    rt: &Runtime,
    tier_name: &str,
    cfg: PretrainConfig,
) -> Result<TrainSession<PretrainLoop>> {
    let scfg = pretrain_session_cfg(&cfg);
    Ok(TrainSession::new(PretrainLoop::new(rt, tier_name, cfg)?, scfg))
}

/// Pretrain a tier from scratch and save the checkpoint (the historical
/// driver entry point; drivers that want resume build the session
/// themselves and set `cfg.ckpt_every`).
pub fn pretrain(
    rt: &Runtime,
    tier_name: &str,
    cfg: &PretrainConfig,
    ckpt_dir: &Path,
    log: &mut RunLog,
) -> Result<PretrainResult> {
    let mut session = pretrain_session(rt, tier_name, cfg.clone())?;
    let records = session.run(rt, log)?;
    let lp = session.into_loop();
    lp.weights.save(&WeightSet::ckpt_path(ckpt_dir, tier_name))?;
    let losses = records
        .iter()
        .filter(|r| r.step % cfg.log_every == 0 || r.step + 1 == cfg.steps)
        .map(|r| (r.step, r.loss))
        .collect();
    let final_loss = records.last().map(|r| r.loss).unwrap_or(f32::NAN);
    Ok(PretrainResult { final_loss, losses })
}
