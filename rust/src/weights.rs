//! Model weight sets: ordered host tensors matching the manifest's weight
//! table, with deterministic init (mirroring `model.weight_init_spec`) and a
//! simple binary checkpoint format.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::manifest::TierInfo;
use crate::tensor::{Arg, TensorF32};
use crate::util::Pcg64;

const MAGIC: &[u8; 8] = b"TLRLCKP1";

#[derive(Clone)]
pub struct WeightSet {
    pub tier: String,
    pub names: Vec<String>,
    pub tensors: Vec<TensorF32>,
}

impl WeightSet {
    /// Initialize from the manifest's init spec (same family as python's
    /// `init_weights`; exact values differ — rust owns pretraining).
    /// An unknown init kind is a malformed manifest — an error, not a
    /// panic (the manifest is external input).
    pub fn init(tier: &TierInfo, seed: u64) -> Result<Self> {
        let mut rng = Pcg64::with_stream(seed, 0x77656967687473);
        let mut names = Vec::new();
        let mut tensors = Vec::new();
        for w in &tier.weights {
            let n: usize = w.shape.iter().product();
            let data = match w.init.kind.as_str() {
                "ones" => vec![1.0; n],
                "zeros" => vec![0.0; n],
                "normal" => rng.normal_vec(n, w.init.std),
                other => bail!("weight {}: unknown init kind {other:?}", w.name),
            };
            names.push(w.name.clone());
            tensors.push(TensorF32::from_vec(&w.shape, data));
        }
        Ok(Self { tier: tier.name.clone(), names, tensors })
    }

    pub fn index_of(&self, name: &str) -> Result<usize> {
        self.names
            .iter()
            .position(|n| n == name)
            .with_context(|| format!("no weight named {name:?}"))
    }

    pub fn get(&self, name: &str) -> Result<&TensorF32> {
        Ok(&self.tensors[self.index_of(name)?])
    }

    pub fn set(&mut self, name: &str, t: TensorF32) -> Result<()> {
        let i = self.index_of(name)?;
        if self.tensors[i].shape != t.shape {
            bail!("shape mismatch for {name}: {:?} vs {:?}", self.tensors[i].shape, t.shape);
        }
        self.tensors[i] = t;
        Ok(())
    }

    /// All weights as runtime args, in manifest order.
    pub fn args(&self) -> Vec<Arg> {
        self.tensors.iter().map(|t| Arg::F32(t.clone())).collect()
    }

    pub fn n_params(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    /// Flatten all weights into one vector (full-FT theta view).
    pub fn flat(&self) -> Vec<f32> {
        let mut v = Vec::with_capacity(self.n_params());
        for t in &self.tensors {
            v.extend_from_slice(&t.data);
        }
        v
    }

    /// Overwrite all weights from a flat vector (full-FT optimizer step).
    pub fn set_flat(&mut self, flat: &[f32]) -> Result<()> {
        if flat.len() != self.n_params() {
            bail!("flat len {} != n_params {}", flat.len(), self.n_params());
        }
        let mut off = 0;
        for t in &mut self.tensors {
            let n = t.numel();
            t.data.copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        Ok(())
    }

    // -- checkpoints ---------------------------------------------------------

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        write_str(&mut f, &self.tier)?;
        write_u32(&mut f, self.tensors.len() as u32)?;
        for (name, t) in self.names.iter().zip(&self.tensors) {
            write_str(&mut f, name)?;
            write_u32(&mut f, t.shape.len() as u32)?;
            for &d in &t.shape {
                write_u32(&mut f, d as u32)?;
            }
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
            };
            f.write_all(bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening checkpoint {path:?}"))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("bad checkpoint magic in {path:?}");
        }
        let tier = read_str(&mut f)?;
        let n = read_u32(&mut f)? as usize;
        let mut names = Vec::with_capacity(n);
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            let name = read_str(&mut f)?;
            let ndim = read_u32(&mut f)? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u32(&mut f)? as usize);
            }
            let numel: usize = shape.iter().product();
            let mut data = vec![0f32; numel];
            let bytes: &mut [u8] = unsafe {
                std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, numel * 4)
            };
            f.read_exact(bytes)?;
            names.push(name);
            tensors.push(TensorF32::from_vec(&shape, data));
        }
        Ok(Self { tier, names, tensors })
    }

    /// Conventional checkpoint path for a tier.
    pub fn ckpt_path(dir: &Path, tier: &str) -> std::path::PathBuf {
        dir.join(format!("{tier}.ckpt"))
    }
}

// Binary-format primitives, shared with `trainer::state` (the TrainState
// checkpoint extends this format with optimizer/RNG/step sections).

pub(crate) fn write_u32<W: Write>(w: &mut W, x: u32) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

pub(crate) fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub(crate) fn write_u64<W: Write>(w: &mut W, x: u64) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

pub(crate) fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

pub(crate) fn write_str<W: Write>(w: &mut W, s: &str) -> Result<()> {
    write_u32(w, s.len() as u32)?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

pub(crate) fn read_str<R: Read>(r: &mut R) -> Result<String> {
    let n = read_u32(r)? as usize;
    if n > 1 << 20 {
        bail!("implausible string length {n}");
    }
    let mut b = vec![0u8; n];
    r.read_exact(&mut b)?;
    Ok(String::from_utf8(b)?)
}

/// Write a f32 slice as raw little-endian bytes (length written by caller).
pub(crate) fn write_f32_slice<W: Write>(w: &mut W, xs: &[f32]) -> Result<()> {
    // NOTE: written per-element (not via a raw-pointer cast) so the format
    // is little-endian on every host, matching `read_f32_vec`.
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

pub(crate) fn read_f32_vec<R: Read>(r: &mut R, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{InitSpec, WeightSpec};

    fn tiny_tier() -> TierInfo {
        TierInfo {
            name: "t".into(),
            d: 4,
            n_layers: 1,
            n_heads: 1,
            f: 8,
            t_max: 8,
            t_prefill: 4,
            t_train: 8,
            head_dim: 4,
            n_params: 0,
            weights: vec![
                WeightSpec {
                    name: "a".into(),
                    shape: vec![2, 3],
                    init: InitSpec { kind: "normal".into(), std: 0.5 },
                },
                WeightSpec {
                    name: "g".into(),
                    shape: vec![3],
                    init: InitSpec { kind: "ones".into(), std: 0.0 },
                },
            ],
            module_dims: Default::default(),
        }
    }

    #[test]
    fn init_is_deterministic_and_respects_spec() {
        let t = tiny_tier();
        let w1 = WeightSet::init(&t, 7).unwrap();
        let w2 = WeightSet::init(&t, 7).unwrap();
        assert_eq!(w1.tensors, w2.tensors);
        assert_eq!(w1.get("g").unwrap().data, vec![1.0; 3]);
        let w3 = WeightSet::init(&t, 8).unwrap();
        assert_ne!(w1.get("a").unwrap().data, w3.get("a").unwrap().data);
    }

    /// ISSUE 5 satellite: a malformed manifest init kind is an error
    /// naming the weight and the kind, never a panic.
    #[test]
    fn unknown_init_kind_is_an_error() {
        let mut t = tiny_tier();
        t.weights[1].init.kind = "xavier".into();
        // WeightSet is not Debug, so take the error by hand
        let err = WeightSet::init(&t, 0).err().expect("bad init kind must error");
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown init kind"), "{msg}");
        assert!(msg.contains("xavier"), "{msg}");
        assert!(msg.contains("weight g:"), "should name the weight: {msg}");
    }

    #[test]
    fn checkpoint_roundtrip() {
        let t = tiny_tier();
        let w = WeightSet::init(&t, 3).unwrap();
        let dir = std::env::temp_dir().join("tlrl_test_ckpt");
        let path = dir.join("t.ckpt");
        w.save(&path).unwrap();
        let r = WeightSet::load(&path).unwrap();
        assert_eq!(w.names, r.names);
        assert_eq!(w.tensors, r.tensors);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn flat_roundtrip() {
        let t = tiny_tier();
        let mut w = WeightSet::init(&t, 3).unwrap();
        let mut flat = w.flat();
        flat[0] = 42.0;
        w.set_flat(&flat).unwrap();
        assert_eq!(w.get("a").unwrap().data[0], 42.0);
        assert!(w.set_flat(&flat[1..]).is_err());
    }
}
