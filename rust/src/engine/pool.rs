//! Worker pool: serves independent adapter batches on N threads.
//!
//! `Runtime` is `Send + Sync` (a pool of execution contexts, each with
//! its own Arc'd executable cache, atomic counters and FFI lock), so
//! workers share ONE runtime and ONE `InferenceEngine` by reference via
//! scoped threads — no cloning, no channels. Every job is pinned to the
//! execution context `job.id % rt.devices()` — a pure function of the
//! job, NOT of the worker that dequeues it — so with D contexts up to D
//! device executions overlap, and pooled results stay byte-identical to
//! the serial reference no matter which worker (or how many) ran a job:
//! `serve` and `serve_serial` route every job to the same context. What
//! always overlaps across workers regardless of D is the host side:
//! literal conversion, tuple decomposition, EOS-cut/decode/verify. Each
//! job carries its own merged weights (activation/merging stays on the
//! coordinating thread, where the `AdapterStore` LRU lives) and its own
//! RNG stream seeded from the job id, so results are bit-identical to the
//! single-threaded path regardless of which worker picks a job up or in
//! what order (asserted in `tests/integration.rs`, and unconditionally on
//! the sim backend at D∈{1,2,4} — including under injected per-context
//! delays — in `tests/e2e_sim.rs`).

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::engine::{GenRow, InferenceEngine};
use crate::runtime::Runtime;
use crate::tasks::corpus::PromptBatch;
use crate::tasks::generator::Problem;
use crate::tokenizer::Tokenizer;
use crate::util::Pcg64;
use crate::weights::WeightSet;

/// RNG stream tag for per-job uniform draws ("pool"). Public because the
/// GRPO loop derives its in-loop rollout RNG on the same stream, so a
/// pooled tenant rollout is bit-identical to a serial one.
pub const POOL_STREAM: u64 = 0x706f6f6c;

/// One unit of pool work: a batch of problems to decode under one
/// adapter's merged weights.
pub struct GenJob {
    pub id: u64,
    pub weights: WeightSet,
    pub problems: Vec<Problem>,
    /// rows per problem: 1 for serving/eval traffic; the GRPO group size
    /// for training rollout waves (the batch must then fill the executable
    /// geometry exactly)
    pub group: usize,
    /// prebuilt prompt batch (training waves ship the one the planner
    /// already tokenized, so the worker skips re-assembly); must match
    /// `problems`/`group` and the engine's exact geometry
    pub pb: Option<PromptBatch>,
    pub temperature: f32,
    /// per-job RNG seed (derive it from stable request data, NOT from a
    /// shared mutable counter, to keep parallel == serial)
    pub seed: u64,
    /// Policy version of `weights` (number of optimizer steps applied to
    /// the owning adapter when this job was planned). The async pipeline
    /// reads it back at consume time to enforce its staleness bound;
    /// serving/eval traffic leaves it at 0.
    pub policy_version: u64,
}

pub struct GenJobResult {
    pub id: u64,
    pub rows: Vec<GenRow>,
}

/// Human-readable payload of a caught worker panic.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

pub struct WorkerPool {
    pub workers: usize,
}

impl WorkerPool {
    pub fn new(workers: usize) -> Self {
        Self { workers: workers.max(1) }
    }

    fn run_job(rt: &Runtime, engine: &InferenceEngine, job: &GenJob) -> Result<Vec<GenRow>> {
        let tok = Tokenizer::new();
        let mut rng = Pcg64::with_stream(job.seed, POOL_STREAM);
        // deterministic context affinity: the job id — not the worker —
        // picks the execution context, so results can never depend on
        // which thread dequeued the job or how many threads exist
        let ctx = rt.ctx_for(job.id);
        if let Some(pb) = &job.pb {
            Ok(engine.generate_on(rt, ctx, &job.weights, pb, &tok, job.temperature, &mut rng)?.rows)
        } else if job.group > 1 {
            Ok(engine
                .generate_grouped_on(
                    rt,
                    ctx,
                    &job.weights,
                    &job.problems,
                    job.group,
                    &tok,
                    job.temperature,
                    &mut rng,
                )?
                .rows)
        } else {
            engine.generate_problems_on(
                rt,
                ctx,
                &job.weights,
                &job.problems,
                &tok,
                job.temperature,
                &mut rng,
            )
        }
    }

    /// Serve all jobs across the pool's threads; results come back sorted
    /// by job id. Fails if any job failed (all errors reported).
    pub fn serve(
        &self,
        rt: &Runtime,
        engine: &InferenceEngine,
        jobs: Vec<GenJob>,
    ) -> Result<Vec<GenJobResult>> {
        let n_jobs = jobs.len();
        if n_jobs == 0 {
            return Ok(Vec::new());
        }
        let queue: Mutex<VecDeque<GenJob>> = Mutex::new(jobs.into());
        let results: Mutex<Vec<GenJobResult>> = Mutex::new(Vec::with_capacity(n_jobs));
        let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for _ in 0..self.workers.min(n_jobs) {
                s.spawn(|| loop {
                    let job = queue.lock().unwrap().pop_front();
                    let Some(job) = job else { break };
                    // a panicking job must surface as THAT JOB's error —
                    // an uncaught panic would propagate through the scope
                    // and tear down every caller waiting on results
                    // (nothing pool-shared is held across this call, so
                    // no lock can be poisoned by the unwind)
                    match catch_unwind(AssertUnwindSafe(|| Self::run_job(rt, engine, &job))) {
                        Ok(Ok(rows)) => {
                            results.lock().unwrap().push(GenJobResult { id: job.id, rows })
                        }
                        Ok(Err(e)) => {
                            errors.lock().unwrap().push(format!("job {}: {e:#}", job.id))
                        }
                        Err(panic) => errors.lock().unwrap().push(format!(
                            "job {}: worker panicked: {}",
                            job.id,
                            panic_message(panic.as_ref())
                        )),
                    }
                });
            }
        });
        let errs = errors.into_inner().unwrap();
        if !errs.is_empty() {
            bail!("worker pool: {} job(s) failed: {}", errs.len(), errs.join("; "));
        }
        let mut out = results.into_inner().unwrap();
        out.sort_by_key(|r| r.id);
        Ok(out)
    }

    /// Pooled or serial dispatch behind one call: the serial path is the
    /// bit-identical reference, so callers (tenant waves, bench ladders)
    /// toggle on a worker count without duplicating the demux logic.
    pub fn serve_maybe(
        &self,
        rt: &Runtime,
        engine: &InferenceEngine,
        jobs: Vec<GenJob>,
        parallel: bool,
    ) -> Result<Vec<GenJobResult>> {
        if parallel {
            self.serve(rt, engine, jobs)
        } else {
            Self::serve_serial(rt, engine, &jobs)
        }
    }

    /// Reference single-threaded path (identical semantics to `serve`) —
    /// the equivalence baseline for the concurrency tests.
    pub fn serve_serial(
        rt: &Runtime,
        engine: &InferenceEngine,
        jobs: &[GenJob],
    ) -> Result<Vec<GenJobResult>> {
        let mut out = Vec::with_capacity(jobs.len());
        for job in jobs {
            out.push(GenJobResult { id: job.id, rows: Self::run_job(rt, engine, job)? });
        }
        out.sort_by_key(|r| r.id);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{SimOptions, SIM_TIER};
    use crate::tasks::generator::SUITES;

    #[test]
    fn pool_clamps_to_at_least_one_worker() {
        assert_eq!(WorkerPool::new(0).workers, 1);
        assert_eq!(WorkerPool::new(4).workers, 4);
    }

    /// Regression (ISSUE 9 satellite): before the catch_unwind a
    /// panicking worker unwound through `std::thread::scope` and took the
    /// whole calling thread down — the job was silently dropped and every
    /// caller waiting on the batch died with it. Now the panic is THAT
    /// job's error and the pool finishes the rest of the batch.
    #[test]
    fn worker_panic_surfaces_as_job_error_and_pool_survives() {
        let opts = SimOptions { panic_execs: 1, ..Default::default() };
        let rt = Runtime::sim_with(1, opts).unwrap();
        let engine = InferenceEngine::new(&rt, SIM_TIER, rt.manifest.batch.test).unwrap();
        let tier = rt.manifest.tier(SIM_TIER).unwrap().clone();
        let weights = WeightSet::init(&tier, 0).unwrap();
        let jobs = |n: u64| -> Vec<GenJob> {
            (0..n)
                .map(|id| GenJob {
                    id,
                    weights: weights.clone(),
                    problems: vec![SUITES[0].generate(&mut Pcg64::with_stream(90 + id, 7))],
                    group: 1,
                    pb: None,
                    temperature: 0.0,
                    seed: id,
                    policy_version: 0,
                })
                .collect()
        };
        let err = WorkerPool::new(2).serve(&rt, &engine, jobs(2)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("1 job(s) failed"), "exactly the panicked job fails: {msg}");
        assert!(msg.contains("worker panicked"), "panic must be labelled: {msg}");
        assert!(msg.contains("injected sim execute panic"), "payload must survive: {msg}");
        // the pool is not wedged: the injected panic was consumed, a
        // fresh batch on the same runtime serves clean
        let ok = WorkerPool::new(2).serve(&rt, &engine, jobs(2)).unwrap();
        assert_eq!(ok.len(), 2);
    }
}
