//! Per-adapter request scheduler — replaces `DynamicBatcher`'s single
//! VecDeque, whose `next_batch` rescanned the whole queue per adapter
//! (O(n·adapters), i.e. O(n²) with many tenants) and removed picked
//! requests by index (another O(n) shift each).
//!
//! Here each adapter owns its own FIFO queue, so batch formation is
//! O(#adapters) bookkeeping + O(batch) pops, independent of total queue
//! depth — see `bench_main.rs::bench_scheduler` for the 1k/10k comparison.
//!
//! Policies (pluggable, `SchedPolicy`):
//!   * `OccupancyFirst` — the seed `DynamicBatcher` semantics: prefer any
//!     full batch (first-appearance order), else flush the adapter of the
//!     globally oldest request once it exceeded `max_wait`. Maximises
//!     occupancy but a permanently-full hot adapter can starve others.
//!   * `DeadlineFlush` — expiry takes precedence: the globally oldest
//!     request, once past `max_wait`, is served even if another adapter
//!     has a full batch waiting. Starvation-free.
//!   * `RoundRobin` — rotate a cursor over adapters for full batches
//!     (per-tenant fairness), with the same expiry-first guarantee.
//!
//! Unit-level property tests below cover the policies in isolation;
//! `tests/e2e_sim.rs` additionally drives every policy through a live
//! `WorkerPool` against the sim backend (wave formation → pooled decode →
//! completion accounting), including an adapter-starvation regression.

use std::collections::{HashMap, VecDeque};

#[derive(Clone, Debug)]
pub struct QueuedRequest {
    pub id: u64,
    pub adapter: String,
    pub prompt: String,
    /// virtual arrival time (the simulation clock, seconds)
    pub arrival: f64,
}

#[derive(Clone, Debug)]
pub struct AdapterBatch {
    pub adapter: String,
    pub requests: Vec<QueuedRequest>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedPolicy {
    OccupancyFirst,
    DeadlineFlush,
    RoundRobin,
}

pub struct Scheduler {
    /// adapter -> FIFO of its waiting requests
    queues: HashMap<String, VecDeque<QueuedRequest>>,
    /// adapters with a non-empty queue, in first-appearance order
    order: Vec<String>,
    /// RoundRobin rotation cursor into `order`
    cursor: usize,
    pending: usize,
    pub batch_size: usize,
    /// flush a partial batch once its oldest request waited this long
    pub max_wait: f64,
    pub policy: SchedPolicy,
}

impl Scheduler {
    pub fn new(batch_size: usize, max_wait: f64, policy: SchedPolicy) -> Self {
        Self {
            queues: HashMap::new(),
            order: Vec::new(),
            cursor: 0,
            pending: 0,
            batch_size: batch_size.max(1),
            max_wait,
            policy,
        }
    }

    pub fn push(&mut self, req: QueuedRequest) {
        let q = self.queues.entry(req.adapter.clone()).or_default();
        if q.is_empty() {
            // invariant (maintained by `take`): an adapter is in `order`
            // iff its queue exists and is non-empty — an empty queue here
            // was just created, so no O(#adapters) membership scan needed
            debug_assert!(!self.order.contains(&req.adapter));
            self.order.push(req.adapter.clone());
        }
        q.push_back(req);
        self.pending += 1;
    }

    pub fn pending(&self) -> usize {
        self.pending
    }

    /// Adapters currently waiting (first-appearance order).
    pub fn waiting_adapters(&self) -> &[String] {
        &self.order
    }

    /// Index into `order` of the adapter whose FRONT request is globally
    /// oldest (fronts are per-adapter oldest thanks to FIFO queues).
    fn oldest(&self) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for (i, a) in self.order.iter().enumerate() {
            let front = self.queues[a].front().expect("order lists non-empty queues");
            if best.map(|(_, t)| front.arrival < t).unwrap_or(true) {
                best = Some((i, front.arrival));
            }
        }
        best.map(|(i, _)| i)
    }

    /// First adapter at/after `start` (cyclic) with a full batch waiting.
    fn full_from(&self, start: usize) -> Option<usize> {
        let n = self.order.len();
        (0..n)
            .map(|k| (start + k) % n)
            .find(|&i| self.queues[&self.order[i]].len() >= self.batch_size)
    }

    /// Pop up to `batch_size` requests from the adapter at `order[idx]`.
    fn take(&mut self, idx: usize) -> AdapterBatch {
        let adapter = self.order[idx].clone();
        let q = self.queues.get_mut(&adapter).unwrap();
        let n = q.len().min(self.batch_size);
        let requests: Vec<QueuedRequest> = q.drain(..n).collect();
        self.pending -= requests.len();
        if q.is_empty() {
            self.queues.remove(&adapter);
            self.order.remove(idx);
            if self.cursor > idx {
                self.cursor -= 1;
            }
        } else if self.policy == SchedPolicy::RoundRobin {
            self.cursor = idx + 1;
        }
        if !self.order.is_empty() {
            self.cursor %= self.order.len().max(1);
        } else {
            self.cursor = 0;
        }
        AdapterBatch { adapter, requests }
    }

    /// Form the next batch at virtual time `now`, or None if nothing is
    /// full and nothing has waited past `max_wait` (caller advances time
    /// or adds requests).
    pub fn next_batch(&mut self, now: f64) -> Option<AdapterBatch> {
        if self.order.is_empty() {
            return None;
        }
        let expired = |s: &Self, i: usize| {
            now - s.queues[&s.order[i]].front().unwrap().arrival >= s.max_wait
        };
        let pick = match self.policy {
            SchedPolicy::OccupancyFirst => self
                .full_from(0)
                .or_else(|| self.oldest().filter(|&i| expired(self, i))),
            SchedPolicy::DeadlineFlush => {
                let old = self.oldest()?;
                if expired(self, old) {
                    Some(old)
                } else {
                    self.full_from(0)
                }
            }
            SchedPolicy::RoundRobin => {
                let old = self.oldest()?;
                if expired(self, old) {
                    Some(old)
                } else {
                    self.full_from(self.cursor)
                }
            }
        }?;
        Some(self.take(pick))
    }

    /// Arrival time of the globally oldest queued request — the serving
    /// front-end derives its next flush/expiry event instants from this.
    pub fn oldest_arrival(&self) -> Option<f64> {
        self.oldest().map(|i| self.queues[&self.order[i]].front().unwrap().arrival)
    }

    /// Admission-control hook: remove and return every queued request
    /// whose wait has reached `budget` (`now - arrival >= budget`), in
    /// deterministic order (adapter first-appearance order, FIFO within
    /// an adapter). This is the ONLY path that drops requests — batch
    /// formation never does — so callers own the shedding policy
    /// entirely through when they sweep.
    pub fn shed_expired(&mut self, now: f64, budget: f64) -> Vec<QueuedRequest> {
        let mut shed = Vec::new();
        let mut idx = 0;
        while idx < self.order.len() {
            let adapter = self.order[idx].clone();
            let q = self.queues.get_mut(&adapter).unwrap();
            let mut kept = VecDeque::with_capacity(q.len());
            for r in q.drain(..) {
                if now - r.arrival >= budget {
                    shed.push(r);
                } else {
                    kept.push_back(r);
                }
            }
            *q = kept;
            if q.is_empty() {
                // same invariant maintenance as `take`: adapter leaves
                // `order` with its queue, cursor shifts left past it
                self.queues.remove(&adapter);
                self.order.remove(idx);
                if self.cursor > idx {
                    self.cursor -= 1;
                }
            } else {
                idx += 1;
            }
        }
        self.pending -= shed.len();
        if self.order.is_empty() {
            self.cursor = 0;
        } else {
            self.cursor %= self.order.len();
        }
        shed
    }

    /// Requeue a formed batch whose dispatch was lost (context death
    /// detected before completion — the supervision plane, DESIGN.md
    /// §14). The requests return to the FRONT of their adapter's queue in
    /// their original order, so the next `take` re-forms the same batch
    /// and per-tenant FIFO is preserved: loss detection is synchronous
    /// (the dispatching caller observes the failure before forming more
    /// batches for that adapter), so nothing newer can overtake. Sheds
    /// keep applying — a requeued request that then overstays its budget
    /// is dropped by `shed_expired` like any other.
    pub fn requeue(&mut self, batch: AdapterBatch) {
        if batch.requests.is_empty() {
            return;
        }
        let q = self.queues.entry(batch.adapter.clone()).or_default();
        if q.is_empty() && !self.order.contains(&batch.adapter) {
            self.order.push(batch.adapter.clone());
        }
        let n = batch.requests.len();
        for r in batch.requests.into_iter().rev() {
            q.push_front(r);
        }
        self.pending += n;
    }

    /// Every batch flushable at `now`, in policy order — one serving
    /// "wave". Callers that fan waves across a `WorkerPool` (and, with a
    /// device-parallel runtime, across execution contexts) collect the
    /// whole wave in one call instead of re-running policy selection
    /// interleaved with decode.
    pub fn flush_wave(&mut self, now: f64) -> Vec<AdapterBatch> {
        let mut wave = Vec::new();
        while let Some(b) = self.next_batch(now) {
            wave.push(b);
        }
        wave
    }
}

/// The distinct adapters of a formed wave, in first-appearance order —
/// what the serving store's batch-aware promotion (`begin_wave`) takes:
/// every adapter of the upcoming wave is promoted/merged exactly once,
/// up front, off the per-request path.
pub fn wave_adapters(wave: &[AdapterBatch]) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    for b in wave {
        if !out.iter().any(|a| *a == b.adapter) {
            out.push(b.adapter.clone());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;
    use crate::util::Pcg64;

    fn req(id: u64, adapter: &str, arrival: f64) -> QueuedRequest {
        QueuedRequest { id, adapter: adapter.into(), prompt: format!("p{id}"), arrival }
    }

    fn random_policy(rng: &mut Pcg64) -> SchedPolicy {
        *rng.choice(&[SchedPolicy::OccupancyFirst, SchedPolicy::DeadlineFlush, SchedPolicy::RoundRobin])
    }

    /// Drain everything by advancing virtual time whenever nothing flushes.
    fn drain_all(s: &mut Scheduler, mut now: f64) -> Vec<AdapterBatch> {
        let mut out = Vec::new();
        while s.pending() > 0 {
            match s.next_batch(now) {
                Some(b) => out.push(b),
                None => now += s.max_wait.max(1e-3) + 1e-6,
            }
        }
        out
    }

    #[test]
    fn seed_batcher_semantics_preserved() {
        // the three DynamicBatcher unit cases, against OccupancyFirst
        let mut s = Scheduler::new(2, 10.0, SchedPolicy::OccupancyFirst);
        s.push(req(1, "a", 0.0));
        s.push(req(2, "b", 0.1));
        s.push(req(3, "b", 0.2));
        let b = s.next_batch(0.3).unwrap();
        assert_eq!(b.adapter, "b");
        assert_eq!(b.requests.len(), 2);
        assert_eq!(s.pending(), 1);

        let mut s = Scheduler::new(4, 1.0, SchedPolicy::OccupancyFirst);
        s.push(req(1, "a", 0.0));
        assert!(s.next_batch(0.5).is_none(), "should wait for more");
        assert_eq!(s.next_batch(1.5).unwrap().requests.len(), 1);

        let mut s = Scheduler::new(2, 0.0, SchedPolicy::OccupancyFirst);
        assert!(s.next_batch(100.0).is_none());
    }

    /// Property: within one adapter, requests are served in submission
    /// order, under random interleavings, policies and batch sizes.
    #[test]
    fn prop_fifo_within_adapter() {
        check("fifo within adapter", 200, |rng| {
            let batch = 1 + rng.below(6) as usize;
            let mut s = Scheduler::new(batch, rng.uniform() as f64, random_policy(rng));
            let n = 5 + rng.below(60);
            for id in 0..n {
                let a = format!("t{}", rng.below(5));
                s.push(req(id, &a, id as f64 * 0.01));
            }
            let mut last_seen: std::collections::HashMap<String, u64> = Default::default();
            for b in drain_all(&mut s, 0.0) {
                for r in &b.requests {
                    assert_eq!(r.adapter, b.adapter, "mixed-adapter batch");
                    if let Some(&prev) = last_seen.get(&b.adapter) {
                        if prev >= r.id {
                            return Err(format!("adapter {} served {} after {}", b.adapter, r.id, prev));
                        }
                    }
                    last_seen.insert(b.adapter.clone(), r.id);
                }
            }
            Ok(())
        });
    }

    /// Property: under `drain`, every submitted request is served exactly
    /// once — no drops, no duplicates — for adversarial arrival orders.
    #[test]
    fn prop_exactly_once_under_drain() {
        check("exactly once under drain", 200, |rng| {
            let batch = 1 + rng.below(5) as usize;
            let mut s = Scheduler::new(batch, 0.05, random_policy(rng));
            let n = 1 + rng.below(80);
            // adversarial arrivals: shuffled ids, bursty clustered times
            let mut ids: Vec<u64> = (0..n).collect();
            rng.shuffle(&mut ids);
            for (k, &id) in ids.iter().enumerate() {
                let a = format!("t{}", rng.below(7));
                s.push(req(id, &a, (k / 4) as f64 * 0.02));
            }
            let mut seen = std::collections::HashSet::new();
            let mut served = 0u64;
            for b in drain_all(&mut s, 0.0) {
                if b.requests.len() > batch {
                    return Err(format!("oversized batch {}", b.requests.len()));
                }
                for r in &b.requests {
                    if !seen.insert(r.id) {
                        return Err(format!("request {} served twice", r.id));
                    }
                    served += 1;
                }
            }
            if served != n {
                return Err(format!("served {served} of {n}"));
            }
            if s.pending() != 0 {
                return Err("pending after drain".into());
            }
            Ok(())
        });
    }

    /// Property: with DeadlineFlush/RoundRobin, a lone request on a cold
    /// adapter is served within a bounded number of rounds even while a
    /// hot adapter keeps a full batch queued at all times (the adversarial
    /// schedule that starves OccupancyFirst).
    #[test]
    fn prop_no_starvation_under_flood() {
        check("no starvation", 100, |rng| {
            let policy =
                *rng.choice(&[SchedPolicy::DeadlineFlush, SchedPolicy::RoundRobin]);
            let batch = 2 + rng.below(4) as usize;
            let max_wait = 0.1;
            let mut s = Scheduler::new(batch, max_wait, policy);
            let mut now = 0.0;
            let mut next_id = 1000u64;
            s.push(req(0, "lone", now)); // the victim
            let mut rounds = 0;
            loop {
                // adversary refills the hot adapter to a full batch
                while s.queues.get("hot").map(|q| q.len()).unwrap_or(0) < batch {
                    s.push(req(next_id, "hot", now));
                    next_id += 1;
                }
                if let Some(b) = s.next_batch(now) {
                    if b.requests.iter().any(|r| r.id == 0) {
                        return Ok(()); // victim served
                    }
                }
                now += 0.05; // service/arrival time advances the clock
                rounds += 1;
                if rounds > 50 {
                    return Err(format!("{policy:?}: lone request starved after {rounds} rounds"));
                }
            }
        });
    }

    /// `flush_wave` is exactly "next_batch until None": same batches,
    /// same order, and it leaves the scheduler in the same state.
    #[test]
    fn flush_wave_matches_repeated_next_batch() {
        let build = || {
            let mut s = Scheduler::new(2, 10.0, SchedPolicy::OccupancyFirst);
            for id in 0..9u64 {
                s.push(req(id, if id % 3 == 0 { "a" } else { "b" }, id as f64 * 0.01));
            }
            s
        };
        let mut a = build();
        let mut b = build();
        let wave = a.flush_wave(100.0);
        let mut reference = Vec::new();
        while let Some(batch) = b.next_batch(100.0) {
            reference.push(batch);
        }
        assert_eq!(wave.len(), reference.len());
        for (x, y) in wave.iter().zip(&reference) {
            assert_eq!(x.adapter, y.adapter);
            assert_eq!(
                x.requests.iter().map(|r| r.id).collect::<Vec<_>>(),
                y.requests.iter().map(|r| r.id).collect::<Vec<_>>()
            );
        }
        assert_eq!(a.pending(), b.pending());
    }

    /// `wave_adapters` dedups while keeping first-appearance order (the
    /// same adapter can flush several batches in one wave).
    #[test]
    fn wave_adapters_dedups_in_first_appearance_order() {
        let batch = |a: &str| AdapterBatch { adapter: a.into(), requests: vec![] };
        assert_eq!(wave_adapters(&[]), Vec::<String>::new());
        let wave = [batch("b"), batch("a"), batch("b"), batch("c"), batch("a")];
        assert_eq!(wave_adapters(&wave), vec!["b", "a", "c"]);
    }

    /// Property: `shed_expired` removes exactly the requests whose wait
    /// reached the budget — nothing younger, nothing left behind — and
    /// the scheduler's invariants (pending count, order membership,
    /// exactly-once drain of the survivors) hold afterwards.
    #[test]
    fn prop_shed_expired_removes_exactly_the_expired_set() {
        check("shed expired exact", 200, |rng| {
            let batch = 1 + rng.below(5) as usize;
            let mut s = Scheduler::new(batch, 0.05, random_policy(rng));
            let n = 1 + rng.below(60);
            let mut arrivals = std::collections::HashMap::new();
            for id in 0..n {
                let a = format!("t{}", rng.below(6));
                let at = rng.uniform() as f64;
                arrivals.insert(id, at);
                s.push(req(id, &a, at));
            }
            let now = rng.uniform() as f64 * 1.5;
            let budget = rng.uniform() as f64 * 0.5;
            let shed = s.shed_expired(now, budget);
            let mut shed_ids = std::collections::HashSet::new();
            for r in &shed {
                if now - r.arrival < budget {
                    return Err(format!("shed {} at wait {:.4} < budget {budget:.4}", r.id, now - r.arrival));
                }
                if !shed_ids.insert(r.id) {
                    return Err(format!("request {} shed twice", r.id));
                }
            }
            if s.pending() + shed.len() != n as usize {
                return Err(format!("pending {} + shed {} != {n}", s.pending(), shed.len()));
            }
            // survivors drain exactly once and are exactly the young set
            let mut survivors = std::collections::HashSet::new();
            for b in drain_all(&mut s, now) {
                for r in &b.requests {
                    if !survivors.insert(r.id) {
                        return Err(format!("request {} served twice after shed", r.id));
                    }
                }
            }
            for id in 0..n {
                let expired = now - arrivals[&id] >= budget;
                if expired != shed_ids.contains(&id) {
                    return Err(format!("request {id}: expired={expired} but shed={}", !expired));
                }
                if expired == survivors.contains(&id) {
                    return Err(format!("request {id}: expired={expired} but drained={expired}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn oldest_arrival_tracks_global_front() {
        let mut s = Scheduler::new(4, 1.0, SchedPolicy::DeadlineFlush);
        assert_eq!(s.oldest_arrival(), None);
        s.push(req(0, "a", 0.5));
        s.push(req(1, "b", 0.2));
        s.push(req(2, "a", 0.9));
        assert_eq!(s.oldest_arrival(), Some(0.2));
        let shed = s.shed_expired(1.5, 1.0);
        assert_eq!(shed.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(s.oldest_arrival(), Some(0.9));
        assert_eq!(s.pending(), 1);
    }

    #[test]
    fn requeue_restores_front_order_membership_and_pending() {
        let mut s = Scheduler::new(2, 1e9, SchedPolicy::OccupancyFirst);
        for id in 0..4u64 {
            s.push(req(id, "a", id as f64 * 0.01));
        }
        s.push(req(9, "b", 0.001));
        let b = s.next_batch(0.1).unwrap(); // a: [0, 1]
        assert_eq!(b.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(s.pending(), 3);
        s.requeue(b);
        assert_eq!(s.pending(), 5);
        // the re-formed batch is the same one, in the same order
        let again = s.next_batch(0.1).unwrap();
        assert_eq!(again.adapter, "a");
        assert_eq!(again.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        // requeue of an adapter whose queue fully drained restores its
        // `order` membership so it can flush again
        let rest = s.next_batch(1e18).unwrap();
        assert_eq!(rest.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3]);
        let b9 = s.next_batch(1e18).unwrap();
        assert_eq!(b9.adapter, "b");
        assert_eq!(s.pending(), 0);
        s.requeue(b9);
        assert!(s.waiting_adapters().contains(&"b".to_string()));
        assert_eq!(s.next_batch(1e18).unwrap().requests[0].id, 9);
    }

    /// Property (ISSUE 9 satellite, composing PR 8's exactly-once drain
    /// property with requeue-on-context-loss): when any formed batch can
    /// be lost and requeued — synchronously, before further batches form,
    /// which is how the supervised dispatch loop behaves — per-tenant
    /// FIFO still holds over the SERVED order and every request resolves
    /// exactly once (served or shed, never both, never twice, none lost).
    #[test]
    fn prop_requeue_on_loss_preserves_fifo_and_exactly_once() {
        check("requeue on loss", 200, |rng| {
            let batch = 1 + rng.below(5) as usize;
            let mut s = Scheduler::new(batch, 0.05, random_policy(rng));
            let n = 1 + rng.below(70);
            // ids pushed in order (so per-adapter push order == id order,
            // making FIFO checkable by id), adapters random, arrivals bursty
            for id in 0..n {
                let a = format!("t{}", rng.below(6));
                s.push(req(id, &a, (id / 4) as f64 * 0.02));
            }
            // each request may be lost at most twice (bounded chaos —
            // guarantees termination without weakening the property)
            let mut losses: std::collections::HashMap<u64, u32> = Default::default();
            let mut seen = std::collections::HashSet::new();
            let mut shed_ids = std::collections::HashSet::new();
            let mut last_seen: std::collections::HashMap<String, u64> = Default::default();
            let mut now = 0.0;
            while s.pending() > 0 {
                // occasional shed sweep: requeued requests age like any
                // other, so expiry keeps applying after a loss
                if rng.below(8) == 0 {
                    for r in s.shed_expired(now, 0.5) {
                        if !shed_ids.insert(r.id) {
                            return Err(format!("request {} shed twice", r.id));
                        }
                    }
                    continue;
                }
                let Some(b) = s.next_batch(now) else {
                    now += s.max_wait.max(1e-3) + 1e-6;
                    continue;
                };
                let lossable = b.requests.iter().all(|r| losses.get(&r.id).copied().unwrap_or(0) < 2);
                if lossable && rng.below(3) == 0 {
                    // context died mid-dispatch: the supervised caller
                    // observes the loss and requeues before forming any
                    // further batch for this adapter
                    for r in &b.requests {
                        *losses.entry(r.id).or_insert(0) += 1;
                    }
                    s.requeue(b);
                    continue;
                }
                if b.requests.len() > batch {
                    return Err(format!("oversized batch {}", b.requests.len()));
                }
                for r in &b.requests {
                    if shed_ids.contains(&r.id) {
                        return Err(format!("request {} served after shed", r.id));
                    }
                    if !seen.insert(r.id) {
                        return Err(format!("request {} served twice", r.id));
                    }
                    if let Some(&prev) = last_seen.get(&b.adapter) {
                        if prev >= r.id {
                            return Err(format!(
                                "adapter {} served {} after {} (FIFO broken by requeue)",
                                b.adapter, r.id, prev
                            ));
                        }
                    }
                    last_seen.insert(b.adapter.clone(), r.id);
                }
            }
            if seen.len() + shed_ids.len() != n as usize {
                return Err(format!(
                    "served {} + shed {} != {n} (requests lost)",
                    seen.len(),
                    shed_ids.len()
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn round_robin_rotates_between_full_adapters() {
        let mut s = Scheduler::new(2, 1e9, SchedPolicy::RoundRobin);
        for i in 0..8u64 {
            s.push(req(i, if i % 2 == 0 { "a" } else { "b" }, 0.0));
        }
        let adapters: Vec<String> =
            (0..4).map(|_| s.next_batch(0.0).unwrap().adapter).collect();
        assert_eq!(adapters, vec!["a", "b", "a", "b"], "cursor must rotate");
    }
}
