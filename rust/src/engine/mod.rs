//! The inference engine — the ONE canonical decode path (see DESIGN.md §4).
//!
//! Before this subsystem existed, rollout (`coordinator/rollout.rs`), eval
//! (`eval/`) and serving (`serving/router.rs`) each re-implemented their
//! own drive loop over the fused `generate` executable: executable
//! selection, prompt batching, uniform generation, EOS-cut/decode
//! post-processing and batch padding all lived in three places.
//! `InferenceEngine` owns all of it; those three layers are thin clients.
//!
//! Companion modules:
//!   * `scheduler` — per-adapter request queues with pluggable policies
//!     (replaces the O(n²) single-queue `DynamicBatcher` scan);
//!   * `pool` — a `WorkerPool` that serves independent adapter batches on
//!     N threads (`Runtime` is `Send + Sync`).

pub mod pool;
pub mod scheduler;

use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::runtime::{Executable, Runtime};
use crate::tasks::corpus::{prompt_batch, PromptBatch};
use crate::tasks::generator::Problem;
use crate::tasks::verifier;
use crate::tensor::{Arg, TensorF32};
use crate::tokenizer::{Tokenizer, EOS};
use crate::util::Pcg64;
use crate::weights::WeightSet;

/// Suite tag of the padding sentinel. Padded rows carry this tag (and an
/// unsatisfiable answer) so they can never be confused with real traffic.
pub const PADDING_SUITE: &str = "__padding__";

/// Explicit padding sentinel for short batches (the generate executables
/// have baked batch sizes). Replaces the old "clone the last request"
/// hack, which made padded rows indistinguishable from real ones.
pub fn padding_problem() -> Problem {
    Problem {
        prompt: String::new(),
        gold: String::new(),
        answer: i64::MIN, // no decoded text can ever match
        suite: PADDING_SUITE,
    }
}

pub fn is_padding(p: &Problem) -> bool {
    p.suite == PADDING_SUITE
}

/// One sampled sequence, post EOS-cut.
#[derive(Clone, Debug)]
pub struct GenRow {
    pub prompt_len: usize,
    /// response tokens, including the terminating EOS when present
    pub response: Vec<i32>,
    /// behavior log-prob per response token (merged weights, sampling temp)
    pub behavior: Vec<f32>,
    pub text: String,
    pub reward: f32,
    pub hit_eos: bool,
    pub has_format: bool,
}

/// A generated batch (rollout layers call this `Rollout`).
pub struct Generation {
    pub rows: Vec<GenRow>,
    pub group: usize,
}

impl Generation {
    pub fn mean_reward(&self) -> f32 {
        crate::util::mean(&self.rows.iter().map(|r| r.reward).collect::<Vec<_>>())
    }

    pub fn mean_response_len(&self) -> f32 {
        crate::util::mean(&self.rows.iter().map(|r| r.response.len() as f32).collect::<Vec<_>>())
    }

    pub fn format_rate(&self) -> f32 {
        crate::util::mean(
            &self.rows.iter().map(|r| if r.has_format { 1.0 } else { 0.0 }).collect::<Vec<_>>(),
        )
    }
}

/// Cumulative per-engine counters (thread-safe: pool workers share one
/// engine).
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    /// executable invocations
    pub batches: u64,
    /// real (non-padding) rows decoded
    pub rows: u64,
    /// padding rows wasted on partial batches (occupancy diagnostics)
    pub padded_rows: u64,
    /// wall time inside `generate` calls, ms
    pub gen_ms: f64,
}

/// The shared inference engine: wraps executable selection for one
/// (tier, batch) geometry, uniform generation, the fused-generate call and
/// EOS-cut/decode/verify post-processing.
pub struct InferenceEngine {
    gen_exe: Arc<Executable>,
    pub tier: String,
    /// baked executable batch size
    pub batch: usize,
    /// sampled tokens per sequence
    pub n_gen: usize,
    pub t_prefill: usize,
    stats: Mutex<EngineStats>,
}

impl InferenceEngine {
    pub fn new(rt: &Runtime, tier: &str, batch: usize) -> Result<Self> {
        let info = rt.manifest.generate_exe(tier, batch)?.clone();
        let gen_exe = rt.load(&info.name)?;
        let t = rt.manifest.tier(tier)?;
        Ok(Self {
            gen_exe,
            tier: tier.to_string(),
            batch: info.batch,
            n_gen: info.seq,
            t_prefill: t.t_prefill,
            stats: Mutex::new(EngineStats::default()),
        })
    }

    /// Sample one batch from the merged weights. The prompt batch must
    /// match the executable's baked geometry exactly; use
    /// [`InferenceEngine::generate_problems`] for arbitrary-length inputs.
    pub fn generate(
        &self,
        rt: &Runtime,
        weights: &WeightSet,
        pb: &PromptBatch,
        tok: &Tokenizer,
        temperature: f32,
        rng: &mut Pcg64,
    ) -> Result<Generation> {
        if pb.tokens.shape[0] != self.batch {
            bail!("prompt batch {} != exe batch {}", pb.tokens.shape[0], self.batch);
        }
        let b = self.batch;
        let uniforms = TensorF32::from_vec(&[b, self.n_gen], rng.uniform_vec(b * self.n_gen));
        let mut args: Vec<Arg> = weights.args();
        args.push(Arg::I32(pb.tokens.clone()));
        args.push(Arg::I32(pb.prompt_len.clone()));
        args.push(Arg::F32(uniforms));
        args.push(Arg::Scalar(temperature));
        let t0 = crate::util::Timer::start();
        let out = rt.run(&self.gen_exe, &args)?;
        let gen_ms = t0.millis();
        let tokens = out.i32(0)?;
        let blp = out.f32(1)?;

        let mut rows = Vec::with_capacity(b);
        let mut padded = 0u64;
        for i in 0..b {
            let gen = &tokens.data[i * self.n_gen..(i + 1) * self.n_gen];
            let lp = &blp.data[i * self.n_gen..(i + 1) * self.n_gen];
            let cut = gen.iter().position(|&t| t == EOS).map(|p| p + 1);
            let n = cut.unwrap_or(self.n_gen);
            let response = gen[..n].to_vec();
            let behavior = lp[..n].to_vec();
            let text = tok.decode(&response);
            let problem = &pb.problems[i];
            let pad = is_padding(problem);
            if pad {
                padded += 1;
            }
            // padding rows never earn reward/format credit
            let reward = if pad { 0.0 } else { verifier::reward(&text, problem.answer) };
            let has_format = !pad && verifier::has_canonical_format(&text);
            rows.push(GenRow {
                prompt_len: pb.prompt_len.data[i] as usize,
                response,
                behavior,
                text,
                reward,
                hit_eos: cut.is_some(),
                has_format,
            });
        }
        {
            let mut s = self.stats.lock().unwrap();
            s.batches += 1;
            s.rows += b as u64 - padded;
            s.padded_rows += padded;
            s.gen_ms += gen_ms;
        }
        Ok(Generation { rows, group: pb.group })
    }

    /// Group-structured decode for GRPO-style training: each problem is
    /// expanded into `group` consecutive rows (prompt repeated, independent
    /// samples). Training waves always fill the executable geometry
    /// exactly, so a partial batch is an error, not a padding case.
    pub fn generate_grouped(
        &self,
        rt: &Runtime,
        weights: &WeightSet,
        problems: &[Problem],
        group: usize,
        tok: &Tokenizer,
        temperature: f32,
        rng: &mut Pcg64,
    ) -> Result<Generation> {
        if problems.len() * group != self.batch {
            bail!(
                "grouped batch {}x{} != exe batch {}",
                problems.len(),
                group,
                self.batch
            );
        }
        let pb = prompt_batch(problems, tok, group, self.t_prefill);
        self.generate(rt, weights, &pb, tok, temperature, rng)
    }

    /// Decode an arbitrary problem list: chunks it into executable-sized
    /// batches, pads the final chunk with the explicit sentinel, and
    /// returns exactly one row per real problem (padding rows dropped).
    /// Empty input is an error, not a panic.
    pub fn generate_problems(
        &self,
        rt: &Runtime,
        weights: &WeightSet,
        problems: &[Problem],
        tok: &Tokenizer,
        temperature: f32,
        rng: &mut Pcg64,
    ) -> Result<Vec<GenRow>> {
        if problems.is_empty() {
            bail!("generate_problems: empty problem list");
        }
        let b = self.batch;
        let mut rows = Vec::with_capacity(problems.len());
        for chunk in problems.chunks(b) {
            let mut padded: Vec<Problem> = chunk.to_vec();
            while padded.len() < b {
                padded.push(padding_problem());
            }
            let pb = prompt_batch(&padded, tok, 1, self.t_prefill);
            let gen = self.generate(rt, weights, &pb, tok, temperature, rng)?;
            rows.extend(gen.rows.into_iter().take(chunk.len()));
        }
        Ok(rows)
    }

    pub fn stats(&self) -> EngineStats {
        *self.stats.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padding_sentinel_is_unmistakable() {
        let p = padding_problem();
        assert!(is_padding(&p));
        assert_eq!(p.suite, PADDING_SUITE);
        // the sentinel's answer can never be produced by the verifier on
        // any decodable text (answers are parsed from short digit strings)
        assert_eq!(p.answer, i64::MIN);
        let mut rng = Pcg64::new(1);
        let real = crate::tasks::generator::SUITES[0].generate(&mut rng);
        assert!(!is_padding(&real));
    }

    #[test]
    fn engine_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<InferenceEngine>();
        assert_send_sync::<GenRow>();
        assert_send_sync::<EngineStats>();
    }
}
