//! The inference engine — the ONE canonical decode path (see DESIGN.md §4).
//!
//! Before this subsystem existed, rollout (`coordinator/rollout.rs`), eval
//! (`eval/`) and serving (`serving/router.rs`) each re-implemented their
//! own drive loop over the fused `generate` executable: executable
//! selection, prompt batching, uniform generation, EOS-cut/decode
//! post-processing and batch padding all lived in three places.
//! `InferenceEngine` owns all of it; those three layers are thin clients.
//!
//! Occupancy-aware geometry: the engine holds the manifest's FULL set of
//! baked generate geometries for its tier (every batch size lowered with
//! the same sampled length) and flushes a partial batch on the smallest
//! geometry that fits it ([`pick_geometry`] / [`flush_plan`]) instead of
//! padding all the way to the canonical batch. Geometry choice is a pure
//! function of the pending row count — never of worker timing — so
//! pooled and serial runs pick identical geometries, and row `i` of any
//! batch consumes uniforms `[i·n_gen, (i+1)·n_gen)` regardless of the
//! batch size, so a real row's samples do not depend on how much padding
//! followed it.
//!
//! Companion modules:
//!   * `scheduler` — per-adapter request queues with pluggable policies
//!     (replaces the O(n²) single-queue `DynamicBatcher` scan);
//!   * `pool` — a `WorkerPool` that serves independent adapter batches on
//!     N threads, each job pinned to a runtime execution context by its
//!     job id (`Runtime` is a pool of `Send + Sync` contexts).
//!
//! The engine is backend-blind: it speaks only the manifest contract
//! (baked generate geometries, tuple outputs, the padding sentinel), so
//! the same code decodes through PJRT artifacts and through the hermetic
//! sim backend — `tests/e2e_sim.rs` drives every path below on the sim
//! unconditionally, and the pooled==serial assertions hold per backend
//! because geometry choice and job→context routing never consult the
//! backend at all.

pub mod pool;
pub mod scheduler;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::runtime::context::{add_ms, ms_of};
use crate::runtime::{Executable, Runtime};
use crate::tasks::corpus::{prompt_batch, PromptBatch};
use crate::tasks::generator::Problem;
use crate::tasks::verifier;
use crate::tensor::{Arg, TensorF32};
use crate::tokenizer::{Tokenizer, EOS};
use crate::util::Pcg64;
use crate::weights::WeightSet;

/// Suite tag of the padding sentinel. Padded rows carry this tag (and an
/// unsatisfiable answer) so they can never be confused with real traffic.
pub const PADDING_SUITE: &str = "__padding__";

/// Explicit padding sentinel for short batches (the generate executables
/// have baked batch sizes). Replaces the old "clone the last request"
/// hack, which made padded rows indistinguishable from real ones.
pub fn padding_problem() -> Problem {
    Problem {
        prompt: String::new(),
        gold: String::new(),
        answer: i64::MIN, // no decoded text can ever match
        suite: PADDING_SUITE,
    }
}

pub fn is_padding(p: &Problem) -> bool {
    p.suite == PADDING_SUITE
}

/// Smallest baked geometry that fits `pending` rows (`geometries` must be
/// ascending); falls back to the largest when nothing fits. Pure function
/// of the queue depth — geometry choice can never depend on worker count
/// or timing, which is what keeps pooled flushes identical to serial ones.
pub fn pick_geometry(geometries: &[usize], pending: usize) -> usize {
    debug_assert!(!geometries.is_empty());
    geometries
        .iter()
        .copied()
        .find(|&g| g >= pending)
        .unwrap_or_else(|| *geometries.last().unwrap())
}

/// Chunking plan for decoding `n` arbitrary rows through baked
/// geometries: full `canonical` chunks first, then one tail chunk on the
/// smallest geometry that fits the remainder. Returns
/// `(geometry, real_rows)` per chunk. With `geometries == [canonical]`
/// this degenerates to the fixed-geometry baseline (tail padded all the
/// way up), which is exactly what `bench_runtime` compares against.
pub fn flush_plan(geometries: &[usize], canonical: usize, n: usize) -> Vec<(usize, usize)> {
    let mut plan = Vec::new();
    let mut left = n;
    while left >= canonical {
        plan.push((canonical, canonical));
        left -= canonical;
    }
    if left > 0 {
        plan.push((pick_geometry(geometries, left), left));
    }
    plan
}

/// One sampled sequence, post EOS-cut.
#[derive(Clone, Debug)]
pub struct GenRow {
    pub prompt_len: usize,
    /// response tokens, including the terminating EOS when present
    pub response: Vec<i32>,
    /// behavior log-prob per response token (merged weights, sampling temp)
    pub behavior: Vec<f32>,
    pub text: String,
    pub reward: f32,
    pub hit_eos: bool,
    pub has_format: bool,
}

/// A generated batch (rollout layers call this `Rollout`).
pub struct Generation {
    pub rows: Vec<GenRow>,
    pub group: usize,
    /// Policy version of the weights these rows were sampled from (copied
    /// from the producing `GenJob`; 0 for serving/eval decodes). Lets an
    /// off-policy consumer compute the version gap — and so the staleness
    /// rule and the importance correction — without extra bookkeeping.
    pub policy_version: u64,
}

impl Generation {
    pub fn mean_reward(&self) -> f32 {
        crate::util::mean(&self.rows.iter().map(|r| r.reward).collect::<Vec<_>>())
    }

    pub fn mean_response_len(&self) -> f32 {
        crate::util::mean(&self.rows.iter().map(|r| r.response.len() as f32).collect::<Vec<_>>())
    }

    pub fn format_rate(&self) -> f32 {
        crate::util::mean(
            &self.rows.iter().map(|r| if r.has_format { 1.0 } else { 0.0 }).collect::<Vec<_>>(),
        )
    }
}

/// Cumulative per-engine counters (snapshot of [`EngineCounters`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    /// executable invocations
    pub batches: u64,
    /// real (non-padding) rows decoded
    pub rows: u64,
    /// padding rows wasted on partial batches (occupancy diagnostics)
    pub padded_rows: u64,
    /// wall time inside `generate` calls, ms
    pub gen_ms: f64,
}

/// Lock-free engine counters: pool workers share one engine, and the old
/// `Mutex<EngineStats>` was taken once per decoded batch on every worker.
/// Millisecond totals use the same f64-bits CAS accumulator as the
/// runtime's perf counters.
#[derive(Default)]
pub struct EngineCounters {
    batches: AtomicU64,
    rows: AtomicU64,
    padded_rows: AtomicU64,
    gen_ms_bits: AtomicU64,
}

impl EngineCounters {
    pub fn record(&self, batches: u64, rows: u64, padded_rows: u64, gen_ms: f64) {
        self.batches.fetch_add(batches, Ordering::Relaxed);
        self.rows.fetch_add(rows, Ordering::Relaxed);
        self.padded_rows.fetch_add(padded_rows, Ordering::Relaxed);
        add_ms(&self.gen_ms_bits, gen_ms);
    }

    pub fn snapshot(&self) -> EngineStats {
        EngineStats {
            batches: self.batches.load(Ordering::Relaxed),
            rows: self.rows.load(Ordering::Relaxed),
            padded_rows: self.padded_rows.load(Ordering::Relaxed),
            gen_ms: ms_of(&self.gen_ms_bits),
        }
    }
}

/// The shared inference engine: executable selection over every baked
/// (tier, batch) generate geometry, uniform generation, the fused-generate
/// call and EOS-cut/decode/verify post-processing.
pub struct InferenceEngine {
    pub tier: String,
    /// canonical (largest usable) baked batch size — full chunks use it
    pub batch: usize,
    /// sampled tokens per sequence (identical across all geometries held)
    pub n_gen: usize,
    pub t_prefill: usize,
    /// usable baked generate geometries, ascending: (batch, exe name)
    geometries: Vec<(usize, String)>,
    /// context the canonical executable placed on — public wrappers
    /// without an explicit context decode here; pool workers pass their
    /// job's pinned context instead
    default_ctx: usize,
    stats: EngineCounters,
}

impl InferenceEngine {
    pub fn new(rt: &Runtime, tier: &str, batch: usize) -> Result<Self> {
        let info = rt.manifest.generate_exe(tier, batch)?.clone();
        let default_ctx = rt.placement(&info.name);
        // warm the canonical geometry now: callers fail fast on a missing
        // artifact instead of mid-serve
        rt.load_on(default_ctx, &info.name)?;
        let t = rt.manifest.tier(tier)?;
        // every generate geometry for this tier with the same sampled
        // length, capped at the canonical batch (larger bakes would
        // change the engine's advertised capacity)
        let mut geometries: Vec<(usize, String)> = rt
            .manifest
            .executables
            .values()
            .filter(|e| {
                e.fn_kind == "generate"
                    && e.tier == tier
                    && e.seq == info.seq
                    && e.batch <= info.batch
            })
            .map(|e| (e.batch, e.name.clone()))
            .collect();
        geometries.sort_by_key(|g| g.0);
        geometries.dedup_by_key(|g| g.0);
        Ok(Self {
            tier: tier.to_string(),
            batch: info.batch,
            n_gen: info.seq,
            t_prefill: t.t_prefill,
            geometries,
            default_ctx,
            stats: EngineCounters::default(),
        })
    }

    /// Baked geometry batch sizes held by this engine, ascending.
    pub fn geometries(&self) -> Vec<usize> {
        self.geometries.iter().map(|g| g.0).collect()
    }

    /// Context the canonical executable was placed (and warmed) on — the
    /// preferred context for `Runtime::checkout` callers, so an idle pool
    /// sticks to the warm context instead of compiling on cold ones.
    pub fn default_ctx(&self) -> usize {
        self.default_ctx
    }

    /// Smallest baked geometry that can hold `rows` grouped rows with
    /// group size `group` (the geometry must be divisible by the group so
    /// the k samples of one problem stay consecutive); falls back to the
    /// canonical batch.
    pub fn grouped_geometry(&self, rows: usize, group: usize) -> usize {
        self.geometries
            .iter()
            .map(|g| g.0)
            .find(|&g| group > 0 && g % group == 0 && g >= rows)
            .unwrap_or(self.batch)
    }

    /// The executable for a baked geometry, resident on context `ctx`
    /// (the runtime's per-context cache makes repeat calls a read-lock
    /// lookup; first use per context compiles once, single-flight).
    fn exe_for(&self, rt: &Runtime, ctx: usize, batch: usize) -> Result<Arc<Executable>> {
        let name = self
            .geometries
            .iter()
            .find(|g| g.0 == batch)
            .map(|g| &g.1)
            .ok_or_else(|| {
                anyhow!(
                    "no baked generate geometry b{batch} for tier {} (have {:?})",
                    self.tier,
                    self.geometries()
                )
            })?;
        rt.load_on(ctx, name)
    }

    /// Sample one batch from the merged weights. The prompt batch must
    /// match one of the baked geometries exactly; use
    /// [`InferenceEngine::generate_problems`] for arbitrary-length inputs.
    pub fn generate(
        &self,
        rt: &Runtime,
        weights: &WeightSet,
        pb: &PromptBatch,
        tok: &Tokenizer,
        temperature: f32,
        rng: &mut Pcg64,
    ) -> Result<Generation> {
        self.generate_on(rt, self.default_ctx, weights, pb, tok, temperature, rng)
    }

    /// [`InferenceEngine::generate`] on an explicit execution context
    /// (pool workers pass their job's pinned context so independent
    /// batches execute device-parallel).
    #[allow(clippy::too_many_arguments)]
    pub fn generate_on(
        &self,
        rt: &Runtime,
        ctx: usize,
        weights: &WeightSet,
        pb: &PromptBatch,
        tok: &Tokenizer,
        temperature: f32,
        rng: &mut Pcg64,
    ) -> Result<Generation> {
        let b = pb.tokens.shape[0];
        let exe = self.exe_for(rt, ctx, b)?;
        let uniforms = TensorF32::from_vec(&[b, self.n_gen], rng.uniform_vec(b * self.n_gen));
        let mut args: Vec<Arg> = weights.args();
        args.push(Arg::I32(pb.tokens.clone()));
        args.push(Arg::I32(pb.prompt_len.clone()));
        args.push(Arg::F32(uniforms));
        args.push(Arg::Scalar(temperature));
        let t0 = crate::util::Timer::start();
        let out = rt.run(&exe, &args)?;
        let gen_ms = t0.millis();
        let tokens = out.i32(0)?;
        let blp = out.f32(1)?;

        let mut rows = Vec::with_capacity(b);
        let mut padded = 0u64;
        for i in 0..b {
            let gen = &tokens.data[i * self.n_gen..(i + 1) * self.n_gen];
            let lp = &blp.data[i * self.n_gen..(i + 1) * self.n_gen];
            let cut = gen.iter().position(|&t| t == EOS).map(|p| p + 1);
            let n = cut.unwrap_or(self.n_gen);
            let response = gen[..n].to_vec();
            let behavior = lp[..n].to_vec();
            let text = tok.decode(&response);
            let problem = &pb.problems[i];
            let pad = is_padding(problem);
            if pad {
                padded += 1;
            }
            // padding rows never earn reward/format credit
            let reward = if pad { 0.0 } else { verifier::reward(&text, problem.answer) };
            let has_format = !pad && verifier::has_canonical_format(&text);
            rows.push(GenRow {
                prompt_len: pb.prompt_len.data[i] as usize,
                response,
                behavior,
                text,
                reward,
                hit_eos: cut.is_some(),
                has_format,
            });
        }
        self.stats.record(1, b as u64 - padded, padded, gen_ms);
        Ok(Generation { rows, group: pb.group, policy_version: 0 })
    }

    /// Group-structured decode for GRPO-style training: each problem is
    /// expanded into `group` consecutive rows (prompt repeated, independent
    /// samples). The expanded rows must fill one of the baked geometries
    /// exactly — training waves and grouped bench jobs always do, so a
    /// mismatch is an error, not a padding case.
    #[allow(clippy::too_many_arguments)]
    pub fn generate_grouped(
        &self,
        rt: &Runtime,
        weights: &WeightSet,
        problems: &[Problem],
        group: usize,
        tok: &Tokenizer,
        temperature: f32,
        rng: &mut Pcg64,
    ) -> Result<Generation> {
        let ctx = self.default_ctx;
        self.generate_grouped_on(rt, ctx, weights, problems, group, tok, temperature, rng)
    }

    /// [`InferenceEngine::generate_grouped`] on an explicit context.
    #[allow(clippy::too_many_arguments)]
    pub fn generate_grouped_on(
        &self,
        rt: &Runtime,
        ctx: usize,
        weights: &WeightSet,
        problems: &[Problem],
        group: usize,
        tok: &Tokenizer,
        temperature: f32,
        rng: &mut Pcg64,
    ) -> Result<Generation> {
        let total = problems.len() * group;
        if group == 0 || !self.geometries.iter().any(|g| g.0 == total) {
            bail!(
                "grouped batch {}x{} is not a baked geometry (have {:?})",
                problems.len(),
                group,
                self.geometries()
            );
        }
        let pb = prompt_batch(problems, tok, group, self.t_prefill);
        self.generate_on(rt, ctx, weights, &pb, tok, temperature, rng)
    }

    /// Decode an arbitrary problem list: chunks it into full canonical
    /// batches, flushes the tail on the smallest baked geometry that fits
    /// it (padded with the explicit sentinel), and returns exactly one
    /// row per real problem (padding rows dropped). Empty input is an
    /// error, not a panic.
    pub fn generate_problems(
        &self,
        rt: &Runtime,
        weights: &WeightSet,
        problems: &[Problem],
        tok: &Tokenizer,
        temperature: f32,
        rng: &mut Pcg64,
    ) -> Result<Vec<GenRow>> {
        self.generate_problems_on(rt, self.default_ctx, weights, problems, tok, temperature, rng)
    }

    /// [`InferenceEngine::generate_problems`] on an explicit context.
    #[allow(clippy::too_many_arguments)]
    pub fn generate_problems_on(
        &self,
        rt: &Runtime,
        ctx: usize,
        weights: &WeightSet,
        problems: &[Problem],
        tok: &Tokenizer,
        temperature: f32,
        rng: &mut Pcg64,
    ) -> Result<Vec<GenRow>> {
        if problems.is_empty() {
            bail!("generate_problems: empty problem list");
        }
        let geoms = self.geometries();
        let mut rows = Vec::with_capacity(problems.len());
        let mut offset = 0usize;
        for (geometry, real) in flush_plan(&geoms, self.batch, problems.len()) {
            let chunk = &problems[offset..offset + real];
            offset += real;
            let mut padded: Vec<Problem> = chunk.to_vec();
            while padded.len() < geometry {
                padded.push(padding_problem());
            }
            let pb = prompt_batch(&padded, tok, 1, self.t_prefill);
            let gen = self.generate_on(rt, ctx, weights, &pb, tok, temperature, rng)?;
            rows.extend(gen.rows.into_iter().take(chunk.len()));
        }
        Ok(rows)
    }

    pub fn stats(&self) -> EngineStats {
        self.stats.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;

    #[test]
    fn padding_sentinel_is_unmistakable() {
        let p = padding_problem();
        assert!(is_padding(&p));
        assert_eq!(p.suite, PADDING_SUITE);
        // the sentinel's answer can never be produced by the verifier on
        // any decodable text (answers are parsed from short digit strings)
        assert_eq!(p.answer, i64::MIN);
        let mut rng = Pcg64::new(1);
        let real = crate::tasks::generator::SUITES[0].generate(&mut rng);
        assert!(!is_padding(&real));
    }

    #[test]
    fn engine_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<InferenceEngine>();
        assert_send_sync::<GenRow>();
        assert_send_sync::<EngineStats>();
        assert_send_sync::<EngineCounters>();
    }

    /// ISSUE 4 satellite: the lock-free counters lose no updates under
    /// contention (0.25 ms is exact in binary, so the total is exact).
    #[test]
    fn engine_counters_concurrent_increments_are_lossless() {
        let c = EngineCounters::default();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        c.record(1, 3, 1, 0.25);
                    }
                });
            }
        });
        let snap = c.snapshot();
        assert_eq!(snap.batches, 4000);
        assert_eq!(snap.rows, 12000);
        assert_eq!(snap.padded_rows, 4000);
        assert_eq!(snap.gen_ms, 1000.0);
    }

    #[test]
    fn pick_geometry_smallest_fit_and_fallback() {
        let g = [4, 8, 16, 32];
        assert_eq!(pick_geometry(&g, 1), 4);
        assert_eq!(pick_geometry(&g, 4), 4);
        assert_eq!(pick_geometry(&g, 5), 8);
        assert_eq!(pick_geometry(&g, 17), 32);
        assert_eq!(pick_geometry(&g, 33), 32, "oversized demand falls back to largest");
        assert_eq!(pick_geometry(&[4], 3), 4, "single geometry = fixed baseline");
    }

    /// ISSUE 4 satellite: the occupancy-aware plan never pads more than
    /// the fixed-geometry baseline, for any geometry set and queue depth.
    #[test]
    fn prop_occupancy_never_pads_more_than_fixed() {
        check("occupancy padding", 300, |rng| {
            // random ascending geometry set; canonical = its largest
            let mut geoms: Vec<usize> =
                (0..1 + rng.below(4)).map(|_| 1usize << rng.below(6)).collect();
            geoms.push(1 << (4 + rng.below(3))); // canonical in 16..64
            geoms.sort_unstable();
            geoms.dedup();
            let canonical = *geoms.last().unwrap();
            let depth = 1 + rng.below(500) as usize;

            let fixed = flush_plan(&[canonical], canonical, depth);
            let occ = flush_plan(&geoms, canonical, depth);
            let padded = |plan: &[(usize, usize)]| {
                plan.iter().map(|(g, real)| g - real).sum::<usize>()
            };
            let (pf, po) = (padded(&fixed), padded(&occ));
            if po > pf {
                return Err(format!(
                    "geoms {geoms:?} depth {depth}: occupancy padded {po} > fixed {pf}"
                ));
            }
            // every chunk's geometry actually fits its real rows
            for &(g, real) in fixed.iter().chain(&occ) {
                if g < real {
                    return Err(format!("geometry {g} < real rows {real}"));
                }
            }
            Ok(())
        });
    }

    /// ISSUE 4 satellite: geometry choice changes only padding, never
    /// which rows decode or their order — the real-row sequence of the
    /// occupancy plan equals the fixed-geometry baseline's exactly.
    #[test]
    fn prop_flush_plan_serves_identical_rows_across_geometry() {
        check("flush plan row identity", 200, |rng| {
            let mut geoms: Vec<usize> =
                (0..2 + rng.below(3)).map(|_| 1usize + rng.below(24) as usize).collect();
            geoms.push(24 + rng.below(40) as usize); // canonical
            geoms.sort_unstable();
            geoms.dedup();
            let canonical = *geoms.last().unwrap();
            let depth = 1 + rng.below(300) as usize;

            // expand each plan into the sequence of real row indices it serves
            let rows_of = |plan: &[(usize, usize)]| -> Vec<usize> {
                let mut out = Vec::new();
                for &(_, real) in plan {
                    let start = out.len();
                    out.extend(start..start + real);
                }
                out
            };
            let fixed_rows = rows_of(&flush_plan(&[canonical], canonical, depth));
            let occ_rows = rows_of(&flush_plan(&geoms, canonical, depth));
            if fixed_rows != occ_rows {
                return Err(format!(
                    "geoms {geoms:?} depth {depth}: row sequences diverged"
                ));
            }
            if fixed_rows.len() != depth {
                return Err(format!("plan served {} of {depth} rows", fixed_rows.len()));
            }
            Ok(())
        });
    }

    #[test]
    fn flush_plan_shape() {
        // 2 full canonical chunks + a tail on the smallest fitting geometry
        assert_eq!(flush_plan(&[4, 8, 16], 16, 37), vec![(16, 16), (16, 16), (8, 5)]);
        assert_eq!(flush_plan(&[4, 8, 16], 16, 32), vec![(16, 16), (16, 16)]);
        assert_eq!(flush_plan(&[4, 8, 16], 16, 3), vec![(4, 3)]);
        assert_eq!(flush_plan(&[16], 16, 3), vec![(16, 3)], "fixed baseline pads fully");
        assert!(flush_plan(&[4, 8, 16], 16, 0).is_empty());
    }
}
