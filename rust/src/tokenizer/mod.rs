//! Char-level math tokenizer — the rust mirror of `python/compile/configs.py`.
//!
//! The charset constant is duplicated here (the tokenizer must work before
//! artifacts exist, e.g. for corpus generation in unit tests); an
//! integration test cross-checks it against `manifest.json` so the two
//! sides can never drift.

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const CHARS: &str = "0123456789abcdefghijklmnopqrstuvwxyz .,?+-*/=()#<>:'\n";
pub const VOCAB_SIZE: usize = 64;

#[derive(Clone)]
pub struct Tokenizer {
    to_id: [i32; 256],
    to_char: Vec<char>,
}

impl Default for Tokenizer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tokenizer {
    pub fn new() -> Self {
        let mut to_id = [-1i32; 256];
        let mut to_char = vec!['\0'; 3];
        for (i, c) in CHARS.chars().enumerate() {
            debug_assert!(c.is_ascii());
            to_id[c as usize] = (3 + i) as i32;
            to_char.push(c);
        }
        Self { to_id, to_char }
    }

    /// Encode text; unknown characters are skipped after lowercasing.
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.chars()
            .flat_map(|c| c.to_lowercase())
            .filter_map(|c| {
                if c.is_ascii() {
                    let id = self.to_id[c as usize];
                    (id >= 0).then_some(id)
                } else {
                    None
                }
            })
            .collect()
    }

    /// Decode ids; PAD/BOS vanish, EOS terminates.
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut s = String::new();
        for &id in ids {
            if id == EOS {
                break;
            }
            if id <= BOS {
                continue;
            }
            if let Some(&c) = self.to_char.get(id as usize) {
                s.push(c);
            }
        }
        s
    }

    pub fn vocab_size(&self) -> usize {
        VOCAB_SIZE
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;

    #[test]
    fn roundtrip_ascii_math() {
        let t = Tokenizer::new();
        let s = "what is 23 + 45? #### 68\n";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn lowercases_and_skips_unknown() {
        let t = Tokenizer::new();
        assert_eq!(t.decode(&t.encode("AbC~!@")), "abc");
    }

    #[test]
    fn eos_terminates_decode() {
        let t = Tokenizer::new();
        let mut ids = t.encode("12");
        ids.push(EOS);
        ids.extend(t.encode("34"));
        assert_eq!(t.decode(&ids), "12");
    }

    #[test]
    fn all_ids_in_vocab() {
        let t = Tokenizer::new();
        check("ids < vocab", 100, |rng| {
            let n = rng.below(40) as usize;
            let s: String = (0..n)
                .map(|_| *rng.choice(&CHARS.chars().collect::<Vec<_>>()))
                .collect();
            let ids = t.encode(&s);
            if ids.iter().all(|&i| (i as usize) < VOCAB_SIZE && i >= 3) {
                Ok(())
            } else {
                Err(format!("bad ids for {s:?}"))
            }
        });
    }

    #[test]
    fn charset_has_no_duplicates() {
        let mut seen = std::collections::HashSet::new();
        for c in CHARS.chars() {
            assert!(seen.insert(c), "duplicate char {c:?}");
        }
        assert!(CHARS.len() + 3 <= VOCAB_SIZE);
    }
}
