//! The `Backend` abstraction — what `ExecContext` needs from a device
//! layer, and nothing else.
//!
//! Extracted from the PJRT-only `runtime/context.rs` so the whole stack
//! (engine → trainer → serving → bench) can run against more than one
//! substrate. The contract is exactly the manifest's entry-point surface:
//!
//!   * [`Backend::compile`] turns one manifest [`ExeInfo`] into a resident
//!     [`CompiledExe`] (PJRT: parse + compile the HLO text artifact; sim:
//!     bind the pure-rust implementation of that entry point);
//!   * [`CompiledExe::execute`] runs it over shape-checked [`Arg`]s and
//!     returns one host tensor per manifest output, in manifest order —
//!     the tuple-output convention every caller already assumes.
//!
//! Concurrency contract: a backend instance is owned by exactly one
//! `ExecContext` and is handed that context's `ffi` mutex on every call.
//! Backends guard exactly the sections that touch shared native state
//! (PJRT: compile, execute, device→host transfer) and leave pure host
//! work outside it; the sim backend is pure rust and never locks. Two
//! contexts never share a backend, so cross-context concurrency involves
//! distinct backend instances by construction — the same isolation the
//! PJRT multi-client model provides, now stated at the trait boundary.
//!
//! Implementations: [`super::pjrt::PjrtBackend`] (the production path,
//! requires `make artifacts`) and [`super::sim::SimBackend`] (hermetic,
//! deterministic, zero artifacts — see DESIGN.md §10).

use std::fmt;
use std::path::Path;
use std::sync::Mutex;

use anyhow::Result;

use crate::manifest::ExeInfo;
use crate::tensor::{Arg, TensorF32, TensorI32};

pub use super::sim::SimOptions;

/// Typed execute fault: the executing context is gone for good (device
/// lost, process died, connection severed). The supervisor quarantines
/// the context and requeues the work onto a survivor — never retried in
/// place. Backends signal it by returning an error whose chain contains
/// this value; [`super::supervisor::classify`] walks the chain.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ContextLost {
    pub ctx: usize,
    pub reason: String,
}

impl fmt::Display for ContextLost {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "context {} lost: {}", self.ctx, self.reason)
    }
}

impl std::error::Error for ContextLost {}

/// Typed execute fault: the call failed but the context survives (a
/// flaky transfer, a transient allocator hiccup). Safe to retry in place
/// with backoff — the supervisor does, up to its retry budget.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TransientExecError {
    pub ctx: usize,
    pub reason: String,
}

impl fmt::Display for TransientExecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "transient execute error on context {}: {}", self.ctx, self.reason)
    }
}

impl std::error::Error for TransientExecError {}

/// One output of an execution, already on host. Backends produce these in
/// manifest output order; `Outputs` hands them to callers per dtype.
pub enum HostTensor {
    F32(TensorF32),
    I32(TensorI32),
}

/// A backend-resident compiled entry point. `Send + Sync` because
/// executables are shared across pool workers via `Arc<Executable>`;
/// every native section runs under the owning context's `ffi` lock.
pub trait CompiledExe: Send + Sync {
    /// Run the entry point over `args` (already validated against
    /// `info.inputs`). Returns one host tensor per `info.outputs` entry,
    /// in manifest order. `ffi` is the owning context's lock; guard the
    /// native sections with it and leave host-side work outside.
    fn execute(&self, info: &ExeInfo, args: &[Arg], ffi: &Mutex<()>) -> Result<Vec<HostTensor>>;
}

/// One execution context's device layer.
pub trait Backend: Send + Sync {
    /// Short name for diagnostics ("pjrt" | "sim").
    fn name(&self) -> &'static str;

    /// Platform string for the `info` CLI (PJRT reports the client's
    /// platform; sim reports itself).
    fn platform(&self, ffi: &Mutex<()>) -> String;

    /// Compile/bind one manifest entry point. `art_dir` is where AOT
    /// artifacts live; hermetic backends ignore it. Transient failures
    /// are safe to return: the caller's `SingleFlight` cache does not
    /// poison on error, so a later load retries.
    fn compile(&self, art_dir: &Path, info: &ExeInfo, ffi: &Mutex<()>)
        -> Result<Box<dyn CompiledExe>>;
}

/// Which backend a `Runtime` should construct its contexts with.
#[derive(Clone, Debug)]
pub enum BackendSpec {
    /// One PJRT CPU client per context over AOT artifacts on disk
    /// (requires `make artifacts`). The production path.
    Pjrt,
    /// The hermetic pure-rust simulator: a synthetic manifest, a tiny
    /// deterministic toy model, zero artifacts. `SimOptions` injects
    /// faults (compile failures, per-context execute delays, scripted
    /// context death, hung and transiently-failing executes) for the
    /// e2e and chaos suites.
    Sim(SimOptions),
}

impl BackendSpec {
    pub fn sim() -> Self {
        BackendSpec::Sim(SimOptions::default())
    }
}
