//! The deterministic sim backend — a hermetic, pure-rust implementation
//! of every manifest entry point, so the full stack (engine → trainer →
//! serving → bench) runs end-to-end with ZERO artifacts on disk.
//!
//! What it is: a real (tiny) differentiable language model, not a mock.
//! One shared forward — a char-bigram transformer block over the seven
//! adapted matrices plus a tied embedding — backs `generate`, `logprobs`
//! and all three gradient entry points, hand-derived backprop included.
//! That sharing is load-bearing: rollout behavior log-probs equal the
//! training-side log-probs at the same weights (so GRPO's importance
//! ratios are exactly 1 at theta where the rollout ran, the same
//! invariant the real merged-weights trick provides), pretraining
//! genuinely descends its cross-entropy, and the merge entry point is
//! exactly the linear map the adapter gradients differentiate through.
//!
//! What it deliberately does NOT validate: HLO lowering, PJRT literal
//! layout/FFI, numerical parity with the python model. Those stay
//! artifact-gated (DESIGN.md §10 draws the line in detail).
//!
//! Determinism model: every entry point is a pure function of its
//! manifest-declared inputs — no clocks, no thread ids, no global RNG,
//! fixed f32 summation order. Row `i` of a batch depends only on row `i`'s
//! inputs and the weights, which is what makes sentinel padding inert and
//! pooled execution byte-identical to serial at any device count.
//!
//! Fault injection ([`SimOptions`]): transient compile failures (to
//! exercise `SingleFlight`'s no-poison retry) and per-context execute
//! delays (to prove worker/context timing skew cannot change results).

#![allow(clippy::needless_range_loop)]

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::manifest::{
    ArgSpec, BatchGeometry, DType, ExeInfo, InitSpec, Manifest, SchemeInfo, ThetaSegment,
    TierInfo, Vocab, WeightSpec,
};
use crate::runtime::backend::{Backend, CompiledExe, HostTensor};
use crate::tensor::{Arg, TensorF32, TensorI32};
use crate::tokenizer::{BOS, CHARS, EOS, PAD, VOCAB_SIZE};

/// The sim backbone tier name.
pub const SIM_TIER: &str = "sim";
/// The one adapter scheme the sim manifest bakes (the paper's headline
/// 13-parameter config, same tag as the real artifacts).
pub const SIM_SCHEME: &str = "tinylora_r2_u13_all";

const V: usize = VOCAB_SIZE; // 64
const D: usize = 8;
const F: usize = 16;
const L: usize = 1;
const T_PREFILL: usize = 32;
const T_TRAIN: usize = 64;
const N_GEN: usize = 24;
/// Baked generate geometries (ascending; canonical = batch.roll = 8).
const GEOMETRIES: [usize; 4] = [1, 2, 4, 8];
const BATCH_TRAIN: usize = 4;
const BATCH_ROLL: usize = 8;
const N_THETA: usize = 13;
const N_STATS: usize = 8;

/// Logit gain: the tied-embedding bilinear form `z·E` is O(0.03) at init;
/// the gain lifts logits (and, via the chain rule, gradients) into a range
/// where sampling is non-degenerate and a few dozen Adam steps visibly
/// move the loss. Calibrated against the pretrain-descent test (30 Adam
/// steps at lr 3e-3 on one fixed corpus batch must cut CE ≥30%): on
/// corpus-like text the measured CE ratio is ~0.65 at gain 16 and ~0.60
/// at 24 — 24 keeps real margin without collapsing the initial sampling
/// distribution the way 32 starts to.
const GAIN: f32 = 24.0;
/// Scale of the pseudo-factor directions theta is folded in along.
const MERGE_SCALE: f32 = 0.05;

/// The seven adapted matrices, manifest order, with (d_in, d_out).
const MATS: [(&str, usize, usize); 7] = [
    ("attn_q", D, D),
    ("attn_k", D, D),
    ("attn_v", D, D),
    ("attn_o", D, D),
    ("mlp_up", D, F),
    ("mlp_gate", D, F),
    ("mlp_down", F, D),
];

// ---------------------------------------------------------------------------
// Synthetic manifest
// ---------------------------------------------------------------------------

fn f32_spec(name: &str, shape: &[usize]) -> ArgSpec {
    ArgSpec { name: name.to_string(), dtype: DType::F32, shape: shape.to_vec() }
}

fn i32_spec(name: &str, shape: &[usize]) -> ArgSpec {
    ArgSpec { name: name.to_string(), dtype: DType::S32, shape: shape.to_vec() }
}

fn weight_specs() -> Vec<WeightSpec> {
    let mut w = vec![WeightSpec {
        name: "embed".into(),
        shape: vec![V, D],
        init: InitSpec { kind: "normal".into(), std: 0.1 },
    }];
    for (name, din, dout) in MATS {
        w.push(WeightSpec {
            name: name.into(),
            shape: vec![L, din, dout],
            init: InitSpec { kind: "normal".into(), std: 0.3 },
        });
    }
    w
}

/// Weight argument specs in tier order (what `WeightSet::args` pushes).
fn weight_arg_specs() -> Vec<ArgSpec> {
    weight_specs().iter().map(|w| f32_spec(&w.name, &w.shape)).collect()
}

/// Frozen-factor argument specs (what `FactorSet::args` pushes: us/vf
/// interleaved per module at rank 2). The sim folds theta along its own
/// pseudo-factor directions and ignores these inputs, but the calling
/// convention must match the real adapter artifacts exactly.
fn factor_arg_specs() -> Vec<ArgSpec> {
    let r = 2usize;
    let mut specs = Vec::with_capacity(14);
    for (name, din, dout) in MATS {
        let module = name.rsplit('_').next().unwrap();
        specs.push(f32_spec(&format!("us_{module}"), &[L, din, r]));
        specs.push(f32_spec(&format!("vf_{module}"), &[L, dout, r]));
    }
    specs
}

fn sim_scheme() -> SchemeInfo {
    SchemeInfo {
        kind: "tinylora".into(),
        r: 2,
        u: N_THETA,
        tie: "all".into(),
        n_tie: 1,
        lora_alpha: 0.0,
    }
}

fn theta_segments() -> Vec<ThetaSegment> {
    vec![ThetaSegment {
        name: "theta".into(),
        shape: vec![N_THETA],
        offset: 0,
        len: N_THETA,
        init: InitSpec { kind: "zeros".into(), std: 0.0 },
    }]
}

/// The in-memory manifest the sim backend serves — same schema the PJRT
/// path parses from `artifacts/manifest.json`, so every layer above the
/// runtime is backend-blind. Entry points: fused `generate` at every
/// baked geometry, `grpo`/`sft` adapter grads, full-weight `pretrain`,
/// `logprobs`, and the adapter `merge`.
pub fn sim_manifest() -> Manifest {
    let weights = weight_specs();
    let mut module_dims = BTreeMap::new();
    for (name, din, dout) in MATS {
        module_dims.insert(name.rsplit('_').next().unwrap().to_string(), (din, dout));
    }
    let n_params: usize = weights.iter().map(|w| w.shape.iter().product::<usize>()).sum();
    let tier = TierInfo {
        name: SIM_TIER.into(),
        d: D,
        n_layers: L,
        n_heads: 2,
        f: F,
        t_max: T_TRAIN,
        t_prefill: T_PREFILL,
        t_train: T_TRAIN,
        head_dim: D / 2,
        n_params,
        weights,
        module_dims,
    };

    let mut executables = BTreeMap::new();
    for b in GEOMETRIES {
        let name = format!("sim_generate_b{b}");
        let mut inputs = weight_arg_specs();
        inputs.push(i32_spec("tokens", &[b, T_PREFILL]));
        inputs.push(i32_spec("prompt_len", &[b]));
        inputs.push(f32_spec("uniforms", &[b, N_GEN]));
        inputs.push(f32_spec("temperature", &[]));
        executables.insert(
            name.clone(),
            ExeInfo {
                name,
                file: String::new(),
                fn_kind: "generate".into(),
                tier: SIM_TIER.into(),
                batch: b,
                seq: N_GEN,
                use_pallas: false,
                inputs,
                outputs: vec![
                    i32_spec("tokens", &[b, N_GEN]),
                    f32_spec("behavior_logp", &[b, N_GEN]),
                ],
                scheme: None,
                scheme_tag: None,
                theta_size: None,
                theta_segments: Vec::new(),
                groups: Vec::new(),
            },
        );
    }

    let adapter_grad = |algo: &str, b: usize| -> ExeInfo {
        let mut inputs = weight_arg_specs();
        inputs.extend(factor_arg_specs());
        inputs.push(f32_spec("theta", &[N_THETA]));
        inputs.push(i32_spec("tokens", &[b, T_TRAIN]));
        inputs.push(f32_spec("mask", &[b, T_TRAIN - 1]));
        if algo == "grpo" {
            inputs.push(f32_spec("behavior", &[b, T_TRAIN - 1]));
            inputs.push(f32_spec("advantages", &[b]));
            inputs.push(f32_spec("clip_c", &[]));
            inputs.push(f32_spec("kl_coef", &[]));
        }
        ExeInfo {
            name: format!("sim_{algo}_tinylora_b{b}"),
            file: String::new(),
            fn_kind: algo.into(),
            tier: SIM_TIER.into(),
            batch: b,
            seq: T_TRAIN,
            use_pallas: false,
            inputs,
            outputs: vec![f32_spec("dtheta", &[N_THETA]), f32_spec("stats", &[N_STATS])],
            scheme: Some(sim_scheme()),
            scheme_tag: Some(SIM_SCHEME.into()),
            theta_size: Some(N_THETA),
            theta_segments: theta_segments(),
            groups: vec![0; L * 7],
        }
    };
    for b in [BATCH_TRAIN, BATCH_ROLL] {
        let e = adapter_grad("grpo", b);
        executables.insert(e.name.clone(), e);
    }
    let e = adapter_grad("sft", BATCH_TRAIN);
    executables.insert(e.name.clone(), e);

    {
        let b = BATCH_TRAIN;
        let mut inputs = weight_arg_specs();
        inputs.push(i32_spec("tokens", &[b, T_TRAIN]));
        inputs.push(f32_spec("mask", &[b, T_TRAIN - 1]));
        let mut outputs: Vec<ArgSpec> =
            weight_specs().iter().map(|w| f32_spec(&format!("d_{}", w.name), &w.shape)).collect();
        outputs.push(f32_spec("stats", &[N_STATS]));
        executables.insert(
            format!("sim_pretrain_b{b}"),
            ExeInfo {
                name: format!("sim_pretrain_b{b}"),
                file: String::new(),
                fn_kind: "pretrain".into(),
                tier: SIM_TIER.into(),
                batch: b,
                seq: T_TRAIN,
                use_pallas: false,
                inputs,
                outputs,
                scheme: None,
                scheme_tag: None,
                theta_size: None,
                theta_segments: Vec::new(),
                groups: Vec::new(),
            },
        );
    }

    {
        let b = BATCH_TRAIN;
        let mut inputs = weight_arg_specs();
        inputs.push(i32_spec("tokens", &[b, T_TRAIN]));
        executables.insert(
            format!("sim_logprobs_b{b}"),
            ExeInfo {
                name: format!("sim_logprobs_b{b}"),
                file: String::new(),
                fn_kind: "logprobs".into(),
                tier: SIM_TIER.into(),
                batch: b,
                seq: T_TRAIN,
                use_pallas: false,
                inputs,
                outputs: vec![f32_spec("logp", &[b, T_TRAIN - 1])],
                scheme: None,
                scheme_tag: None,
                theta_size: None,
                theta_segments: Vec::new(),
                groups: Vec::new(),
            },
        );
    }

    {
        let mut inputs: Vec<ArgSpec> =
            MATS.iter().map(|(name, din, dout)| f32_spec(name, &[L, *din, *dout])).collect();
        inputs.extend(factor_arg_specs());
        inputs.push(f32_spec("theta", &[N_THETA]));
        let outputs: Vec<ArgSpec> = MATS
            .iter()
            .map(|(name, din, dout)| f32_spec(&format!("merged_{name}"), &[L, *din, *dout]))
            .collect();
        executables.insert(
            "sim_merge_tinylora".into(),
            ExeInfo {
                name: "sim_merge_tinylora".into(),
                file: String::new(),
                fn_kind: "merge".into(),
                tier: SIM_TIER.into(),
                batch: 1,
                seq: 0,
                use_pallas: false,
                inputs,
                outputs,
                scheme: Some(sim_scheme()),
                scheme_tag: Some(SIM_SCHEME.into()),
                theta_size: Some(N_THETA),
                theta_segments: theta_segments(),
                groups: vec![0; L * 7],
            },
        );
    }

    Manifest {
        dir: PathBuf::from("<sim>"),
        vocab: Vocab { size: V, chars: CHARS.into(), pad: PAD, bos: BOS, eos: EOS },
        modules: MATS.iter().map(|(n, _, _)| n.rsplit('_').next().unwrap().to_string()).collect(),
        weight_names: weight_specs().iter().map(|w| w.name.clone()).collect(),
        n_stats: N_STATS,
        batch: BatchGeometry {
            roll: BATCH_ROLL,
            train: BATCH_TRAIN,
            serve: BATCH_TRAIN,
            test: BATCH_TRAIN,
        },
        tiers: BTreeMap::from([(SIM_TIER.to_string(), tier)]),
        executables,
    }
}

// ---------------------------------------------------------------------------
// Fault injection
// ---------------------------------------------------------------------------

/// Sim-only fault injection, set at runtime construction
/// (`Runtime::sim_with`). All fields default to "no faults".
#[derive(Clone, Debug, Default)]
pub struct SimOptions {
    /// Fail the next N compiles (runtime-wide) with a transient error —
    /// exercises `SingleFlight`'s failure-is-not-cached retry path.
    pub fail_compiles: u32,
    /// Artificial per-execute delay in ms, keyed by context id (contexts
    /// beyond the vec's length get 0) — models a slow device and proves
    /// timing skew cannot change pooled results.
    pub ctx_delay_ms: Vec<u64>,
}

/// Shared mutable fault state (one per runtime, shared by its contexts).
pub struct SimFaults {
    compile_failures: AtomicU32,
}

impl SimFaults {
    pub fn new(opts: &SimOptions) -> Self {
        Self { compile_failures: AtomicU32::new(opts.fail_compiles) }
    }

    /// Consume one injected compile failure, if any remain.
    fn take_compile_failure(&self) -> bool {
        self.compile_failures
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1))
            .is_ok()
    }

    /// Injected compile failures not yet consumed (test introspection).
    pub fn pending_compile_failures(&self) -> u32 {
        self.compile_failures.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------------
// Backend plumbing
// ---------------------------------------------------------------------------

pub struct SimBackend {
    faults: Arc<SimFaults>,
    delay_ms: u64,
}

impl SimBackend {
    pub fn new(faults: Arc<SimFaults>, delay_ms: u64) -> Self {
        Self { faults, delay_ms }
    }
}

impl Backend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn platform(&self, _ffi: &Mutex<()>) -> String {
        "sim".into()
    }

    fn compile(
        &self,
        _art_dir: &Path,
        info: &ExeInfo,
        _ffi: &Mutex<()>,
    ) -> Result<Box<dyn CompiledExe>> {
        if self.faults.take_compile_failure() {
            bail!("injected sim compile failure for {} (transient)", info.name);
        }
        match info.fn_kind.as_str() {
            "generate" | "logprobs" | "pretrain" | "sft" | "grpo" | "merge" => {
                Ok(Box::new(SimExe { delay_ms: self.delay_ms }))
            }
            other => bail!("sim backend has no entry point kind {other:?}"),
        }
    }
}

struct SimExe {
    delay_ms: u64,
}

impl CompiledExe for SimExe {
    fn execute(&self, info: &ExeInfo, args: &[Arg], _ffi: &Mutex<()>) -> Result<Vec<HostTensor>> {
        // fault injection: a slow context (never a different one) — results
        // are a pure function of args, so skew cannot change them
        if self.delay_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(self.delay_ms));
        }
        match info.fn_kind.as_str() {
            "generate" => run_generate(info, args),
            "logprobs" => run_logprobs(info, args),
            "pretrain" => run_pretrain(info, args),
            "sft" => run_adapter_grad(info, args, false),
            "grpo" => run_adapter_grad(info, args, true),
            "merge" => run_merge(info, args),
            other => bail!("sim backend has no entry point kind {other:?}"),
        }
    }
}

fn f32s(args: &[Arg], i: usize) -> Result<&[f32]> {
    match &args[i] {
        Arg::F32(t) => Ok(&t.data),
        other => bail!("sim: arg {i} is not an f32 tensor ({other:?})"),
    }
}

fn i32s(args: &[Arg], i: usize) -> Result<&[i32]> {
    match &args[i] {
        Arg::I32(t) => Ok(&t.data),
        other => bail!("sim: arg {i} is not an s32 tensor ({other:?})"),
    }
}

fn scalar(args: &[Arg], i: usize) -> Result<f32> {
    match &args[i] {
        Arg::Scalar(x) => Ok(*x),
        Arg::F32(t) if t.data.len() == 1 => Ok(t.data[0]),
        other => bail!("sim: arg {i} is not a scalar ({other:?})"),
    }
}

fn out_f32(info: &ExeInfo, idx: usize, data: Vec<f32>) -> HostTensor {
    HostTensor::F32(TensorF32::from_vec(&info.outputs[idx].shape, data))
}

fn out_i32(info: &ExeInfo, idx: usize, data: Vec<i32>) -> HostTensor {
    HostTensor::I32(TensorI32::from_vec(&info.outputs[idx].shape, data))
}

// ---------------------------------------------------------------------------
// The toy model: forward, backward, merge
// ---------------------------------------------------------------------------

/// Borrowed model weights: tied embedding + the seven adapted matrices
/// (owned variants hold merged copies).
struct SimModel<'a> {
    embed: &'a [f32],
    mats: [&'a [f32]; 7],
}

/// Cached activations of one forward position (for backprop).
struct Acts {
    x: usize,
    h: Vec<f32>,
    tnh: Vec<f32>,
    vv: Vec<f32>,
    u: Vec<f32>,
    g: Vec<f32>,
    p: Vec<f32>,
    z: Vec<f32>,
}

/// Accumulated gradients, tier weight order (embed + the seven mats).
struct SimGrads {
    embed: Vec<f32>,
    mats: [Vec<f32>; 7],
}

impl SimGrads {
    fn zeros() -> Self {
        Self {
            embed: vec![0.0; V * D],
            mats: [
                vec![0.0; D * D],
                vec![0.0; D * D],
                vec![0.0; D * D],
                vec![0.0; D * D],
                vec![0.0; D * F],
                vec![0.0; D * F],
                vec![0.0; F * D],
            ],
        }
    }
}

/// y[j] = sum_i x[i] * w[i*d_out + j] for a row-major [d_in, d_out] matrix.
fn mv(w: &[f32], x: &[f32], d_out: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; d_out];
    for (i, &xi) in x.iter().enumerate() {
        let row = &w[i * d_out..(i + 1) * d_out];
        for j in 0..d_out {
            y[j] += xi * row[j];
        }
    }
    y
}

impl SimModel<'_> {
    fn from_args<'a>(args: &'a [Arg], base: usize) -> Result<SimModel<'a>> {
        Ok(SimModel {
            embed: f32s(args, base)?,
            mats: [
                f32s(args, base + 1)?,
                f32s(args, base + 2)?,
                f32s(args, base + 3)?,
                f32s(args, base + 4)?,
                f32s(args, base + 5)?,
                f32s(args, base + 6)?,
                f32s(args, base + 7)?,
            ],
        })
    }

    /// One position's forward: token id -> logits over the vocab (and the
    /// intermediates backprop needs). Bigram by construction: the output
    /// depends only on this token and the weights, which makes rows
    /// independent and the fused generate loop exact.
    fn forward(&self, tok: i32) -> (Acts, Vec<f32>) {
        let x = (tok.max(0) as usize).min(V - 1);
        let h = self.embed[x * D..(x + 1) * D].to_vec();
        let [wq, wk, wv, wo, wup, wgate, wdown] = self.mats;
        let sq = mv(wq, &h, D);
        let sk = mv(wk, &h, D);
        let tnh: Vec<f32> = (0..D).map(|j| (sq[j] + sk[j]).tanh()).collect();
        let vv = mv(wv, &tnh, D);
        let a = mv(wo, &vv, D);
        let u = mv(wup, &h, F);
        let g = mv(wgate, &h, F);
        // smooth gate (tanh, not relu) so the model is differentiable
        // everywhere — the finite-difference gradcheck has no kinks to
        // straddle
        let p: Vec<f32> = (0..F).map(|j| u[j] * g[j].tanh()).collect();
        let m = mv(wdown, &p, D);
        let z: Vec<f32> = (0..D).map(|j| h[j] + a[j] + m[j]).collect();
        let mut logits = vec![0.0f32; V];
        for v in 0..V {
            let ev = &self.embed[v * D..(v + 1) * D];
            let mut dot = 0.0f32;
            for j in 0..D {
                dot += z[j] * ev[j];
            }
            logits[v] = GAIN * dot;
        }
        (Acts { x, h, tnh, vv, u, g, p, z }, logits)
    }

    /// Backprop one position given `dlogits` (dLoss/dlogits), accumulating
    /// into `grads`. Exact adjoint of [`SimModel::forward`].
    fn backward(&self, acts: &Acts, dlogits: &[f32], grads: &mut SimGrads) {
        let [wq, wk, wv, wo, wup, wgate, wdown] = self.mats;
        // tied unembedding: logits[v] = GAIN * z . embed[v]
        let mut dz = vec![0.0f32; D];
        for v in 0..V {
            let dv = GAIN * dlogits[v];
            if dv == 0.0 {
                continue;
            }
            let ev = &self.embed[v * D..(v + 1) * D];
            for j in 0..D {
                dz[j] += dv * ev[j];
                grads.embed[v * D + j] += dv * acts.z[j];
            }
        }
        // z = h + a + m
        let mut dh = dz.clone();
        let dm = &dz;
        let da = &dz;
        // m = Wdown . p
        let mut dp = vec![0.0f32; F];
        for i in 0..F {
            for j in 0..D {
                dp[i] += dm[j] * wdown[i * D + j];
                grads.mats[6][i * D + j] += acts.p[i] * dm[j];
            }
        }
        // p = u * tanh(g)
        let mut du = vec![0.0f32; F];
        let mut dg = vec![0.0f32; F];
        for i in 0..F {
            let r = acts.g[i].tanh();
            du[i] = dp[i] * r;
            dg[i] = dp[i] * acts.u[i] * (1.0 - r * r);
        }
        // u = Wup . h ; g = Wgate . h
        for i in 0..D {
            for j in 0..F {
                grads.mats[4][i * F + j] += acts.h[i] * du[j];
                grads.mats[5][i * F + j] += acts.h[i] * dg[j];
                dh[i] += wup[i * F + j] * du[j] + wgate[i * F + j] * dg[j];
            }
        }
        // a = Wo . vv
        let mut dvv = vec![0.0f32; D];
        for i in 0..D {
            for j in 0..D {
                dvv[i] += da[j] * wo[i * D + j];
                grads.mats[3][i * D + j] += acts.vv[i] * da[j];
            }
        }
        // vv = Wv . tanh(s)
        let mut dt = vec![0.0f32; D];
        for i in 0..D {
            for j in 0..D {
                dt[i] += dvv[j] * wv[i * D + j];
                grads.mats[2][i * D + j] += acts.tnh[i] * dvv[j];
            }
        }
        // s = Wq.h + Wk.h ; t = tanh(s)
        let ds: Vec<f32> = (0..D).map(|j| dt[j] * (1.0 - acts.tnh[j] * acts.tnh[j])).collect();
        for i in 0..D {
            for j in 0..D {
                grads.mats[0][i * D + j] += acts.h[i] * ds[j];
                grads.mats[1][i * D + j] += acts.h[i] * ds[j];
                dh[i] += (wq[i * D + j] + wk[i * D + j]) * ds[j];
            }
        }
        // input embedding
        for j in 0..D {
            grads.embed[acts.x * D + j] += dh[j];
        }
    }
}

/// Max-subtracted softmax (deterministic f32, fixed order).
fn softmax(logits: &[f32]) -> Vec<f32> {
    let mx = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&l| (l - mx).exp()).collect();
    let sum: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / sum).collect()
}

fn entropy_of(probs: &[f32]) -> f32 {
    -probs.iter().map(|&p| if p > 0.0 { p * p.ln() } else { 0.0 }).sum::<f32>()
}

/// Deterministic pseudo-factor direction phi(t, k, j) in [-0.5, 0.5]:
/// the fixed "frozen projection" the sim folds theta along. Mirrored by
/// the adapter gradients (exact chain rule through the merge).
fn pseudo_factor(t: usize, k: usize, j: usize) -> f32 {
    let mut h = 0x9e3779b97f4a7c15u64
        ^ (t as u64).wrapping_mul(0xa076_1d64_78bd_642f)
        ^ ((k as u64 + 1).wrapping_mul(0xe703_7ed1_a0b4_28db))
        ^ ((j as u64 + 1).wrapping_mul(0x8ebc_6af0_9c88_c6e3));
    h ^= h >> 29;
    h = h.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    h ^= h >> 32;
    ((h >> 40) as f32) * (1.0 / (1u64 << 24) as f32) - 0.5
}

/// merged[t][j] = base[t][j] + MERGE_SCALE * sum_k theta[k] * phi(t,k,j).
/// Linear in theta and exactly identity at theta = 0 — every adapter
/// scheme starts at the base model, same as the real artifacts.
fn merge_mats(base: [&[f32]; 7], theta: &[f32]) -> [Vec<f32>; 7] {
    std::array::from_fn(|t| {
        let mut out = base[t].to_vec();
        for (j, w) in out.iter_mut().enumerate() {
            let mut delta = 0.0f32;
            for (k, &th) in theta.iter().enumerate() {
                delta += th * pseudo_factor(t, k, j);
            }
            *w += MERGE_SCALE * delta;
        }
        out
    })
}

/// dL/dtheta[k] = MERGE_SCALE * sum_{t,j} dL/dW[t][j] * phi(t,k,j).
fn project_dtheta(dmats: &[Vec<f32>; 7]) -> Vec<f32> {
    let mut dtheta = vec![0.0f32; N_THETA];
    for (t, dm) in dmats.iter().enumerate() {
        for (j, &dw) in dm.iter().enumerate() {
            if dw == 0.0 {
                continue;
            }
            for (k, dt) in dtheta.iter_mut().enumerate() {
                *dt += MERGE_SCALE * dw * pseudo_factor(t, k, j);
            }
        }
    }
    dtheta
}

// ---------------------------------------------------------------------------
// Entry points
// ---------------------------------------------------------------------------

const N_WEIGHTS: usize = 8; // embed + 7 mats, tier order
const N_FACTORS: usize = 14; // us/vf per module (ignored, contract only)

fn run_generate(info: &ExeInfo, args: &[Arg]) -> Result<Vec<HostTensor>> {
    let model = SimModel::from_args(args, 0)?;
    let tokens = i32s(args, N_WEIGHTS)?;
    let plen = i32s(args, N_WEIGHTS + 1)?;
    let uniforms = f32s(args, N_WEIGHTS + 2)?;
    let temperature = scalar(args, N_WEIGHTS + 3)?;
    let b = info.batch;

    let mut out_tokens = vec![0i32; b * N_GEN];
    let mut out_logp = vec![0.0f32; b * N_GEN];
    for i in 0..b {
        let p = (plen[i].max(1) as usize).min(T_PREFILL);
        let mut last = tokens[i * T_PREFILL + p - 1];
        for t in 0..N_GEN {
            let (_, logits) = model.forward(last);
            let (chosen, lp) = if temperature <= 0.0 {
                // greedy: argmax, ties to the lowest index; behavior is
                // the temperature-1 log-prob of the chosen token
                let mut best = 0usize;
                for v in 1..V {
                    if logits[v] > logits[best] {
                        best = v;
                    }
                }
                let probs = softmax(&logits);
                (best, probs[best].max(1e-30).ln())
            } else {
                let scaled: Vec<f32> = logits.iter().map(|&l| l / temperature).collect();
                let probs = softmax(&scaled);
                let u = uniforms[i * N_GEN + t];
                let mut cum = 0.0f32;
                let mut chosen = V - 1;
                for v in 0..V {
                    cum += probs[v];
                    if u < cum {
                        chosen = v;
                        break;
                    }
                }
                (chosen, probs[chosen].max(1e-30).ln())
            };
            out_tokens[i * N_GEN + t] = chosen as i32;
            out_logp[i * N_GEN + t] = lp;
            last = chosen as i32;
        }
    }
    Ok(vec![out_i32(info, 0, out_tokens), out_f32(info, 1, out_logp)])
}

fn run_logprobs(info: &ExeInfo, args: &[Arg]) -> Result<Vec<HostTensor>> {
    let model = SimModel::from_args(args, 0)?;
    let tokens = i32s(args, N_WEIGHTS)?;
    let b = info.batch;
    let t_len = T_TRAIN;
    let mut out = vec![0.0f32; b * (t_len - 1)];
    for i in 0..b {
        for j in 0..t_len - 1 {
            let (_, logits) = model.forward(tokens[i * t_len + j]);
            let probs = softmax(&logits);
            let y = (tokens[i * t_len + j + 1].max(0) as usize).min(V - 1);
            out[i * (t_len - 1) + j] = probs[y].max(1e-30).ln();
        }
    }
    Ok(vec![out_f32(info, 0, out)])
}

/// Shared masked-CE forward/backward (pretrain and SFT).
/// Returns (grads, [loss, token_acc, entropy, mean_logp]).
fn masked_ce(model: &SimModel, tokens: &[i32], mask: &[f32], b: usize) -> (SimGrads, [f32; 4]) {
    let t_len = T_TRAIN;
    let n: f32 = mask.iter().sum::<f32>().max(1.0);
    let mut grads = SimGrads::zeros();
    let (mut loss, mut acc, mut ent, mut lp_sum) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let mut dlogits = vec![0.0f32; V];
    for i in 0..b {
        for j in 0..t_len - 1 {
            let w = mask[i * (t_len - 1) + j];
            if w == 0.0 {
                continue;
            }
            let (acts, logits) = model.forward(tokens[i * t_len + j]);
            let probs = softmax(&logits);
            let y = (tokens[i * t_len + j + 1].max(0) as usize).min(V - 1);
            let lp = probs[y].max(1e-30).ln();
            loss += -w * lp;
            lp_sum += w * lp;
            ent += w * entropy_of(&probs);
            let mut best = 0usize;
            for v in 1..V {
                if logits[v] > logits[best] {
                    best = v;
                }
            }
            if best == y {
                acc += w;
            }
            // dLoss/dlp = -w/n ; dlp/dlogits[v] = onehot - p
            let dl_dlp = -w / n;
            for v in 0..V {
                let onehot = if v == y { 1.0 } else { 0.0 };
                dlogits[v] = dl_dlp * (onehot - probs[v]);
            }
            model.backward(&acts, &dlogits, &mut grads);
        }
    }
    (grads, [loss / n, acc / n, ent / n, lp_sum / n])
}

fn run_pretrain(info: &ExeInfo, args: &[Arg]) -> Result<Vec<HostTensor>> {
    let model = SimModel::from_args(args, 0)?;
    let tokens = i32s(args, N_WEIGHTS)?;
    let mask = f32s(args, N_WEIGHTS + 1)?;
    let (grads, [loss, acc, ent, mean_lp]) = masked_ce(&model, tokens, mask, info.batch);
    let mut out = vec![out_f32(info, 0, grads.embed)];
    for (t, g) in grads.mats.into_iter().enumerate() {
        out.push(out_f32(info, t + 1, g));
    }
    let stats = vec![loss, acc, 0.0, 0.0, 0.0, 0.0, ent, mean_lp];
    out.push(out_f32(info, N_WEIGHTS, stats));
    Ok(out)
}

/// Adapter gradients (SFT CE or GRPO with truncated importance sampling),
/// differentiated through the same merge the `merge` entry point applies.
fn run_adapter_grad(info: &ExeInfo, args: &[Arg], grpo: bool) -> Result<Vec<HostTensor>> {
    let base = SimModel::from_args(args, 0)?;
    let theta = f32s(args, N_WEIGHTS + N_FACTORS)?;
    let merged = merge_mats(base.mats, theta);
    let model = SimModel {
        embed: base.embed,
        mats: std::array::from_fn(|t| merged[t].as_slice()),
    };
    let idx = N_WEIGHTS + N_FACTORS + 1;
    let tokens = i32s(args, idx)?;
    let mask = f32s(args, idx + 1)?;
    let b = info.batch;

    let (grads, stats) = if grpo {
        let behavior = f32s(args, idx + 2)?;
        let advantages = f32s(args, idx + 3)?;
        let clip_c = scalar(args, idx + 4)?;
        let kl_coef = scalar(args, idx + 5)?;
        let t_len = T_TRAIN;
        let n: f32 = mask.iter().sum::<f32>().max(1.0);
        let mut grads = SimGrads::zeros();
        let (mut pg, mut k1, mut k3, mut rsum, mut clipped) = (0.0f32, 0.0f32, 0.0f32, 0.0f32, 0.0f32);
        let (mut ent, mut lp_sum) = (0.0f32, 0.0f32);
        let mut dlogits = vec![0.0f32; V];
        for i in 0..b {
            let adv = advantages[i];
            for j in 0..t_len - 1 {
                let w = mask[i * (t_len - 1) + j];
                if w == 0.0 {
                    continue;
                }
                let (acts, logits) = model.forward(tokens[i * t_len + j]);
                let probs = softmax(&logits);
                let y = (tokens[i * t_len + j + 1].max(0) as usize).min(V - 1);
                let lp = probs[y].max(1e-30).ln();
                let beh = behavior[i * (t_len - 1) + j];
                let ratio = (lp - beh).exp().min(1e6);
                let wt = if clip_c > 0.0 { ratio.min(clip_c) } else { ratio };
                pg += -w * wt * adv * lp;
                k1 += w * (beh - lp);
                k3 += w * (ratio - 1.0 - (lp - beh));
                rsum += w * ratio;
                if clip_c > 0.0 && ratio > clip_c {
                    clipped += w;
                }
                ent += w * entropy_of(&probs);
                lp_sum += w * lp;
                // loss = pg/n + kl_coef * k3/n, with the importance weight
                // stop-gradded (truncated importance sampling):
                // dLoss/dlp = (-wt*adv + kl_coef*(ratio-1)) * w/n
                let dl_dlp = (-wt * adv + kl_coef * (ratio - 1.0)) * w / n;
                for v in 0..V {
                    let onehot = if v == y { 1.0 } else { 0.0 };
                    dlogits[v] = dl_dlp * (onehot - probs[v]);
                }
                model.backward(&acts, &dlogits, &mut grads);
            }
        }
        let loss = pg / n + kl_coef * k3 / n;
        (
            grads,
            vec![loss, pg / n, k1 / n, k3 / n, rsum / n, clipped / n, ent / n, lp_sum / n],
        )
    } else {
        let (grads, [loss, acc, ent, mean_lp]) = masked_ce(&model, tokens, mask, b);
        (grads, vec![loss, acc, 0.0, 0.0, 1.0, 0.0, ent, mean_lp])
    };

    let dtheta = project_dtheta(&grads.mats);
    Ok(vec![out_f32(info, 0, dtheta), out_f32(info, 1, stats)])
}

fn run_merge(info: &ExeInfo, args: &[Arg]) -> Result<Vec<HostTensor>> {
    let base: [&[f32]; 7] = [
        f32s(args, 0)?,
        f32s(args, 1)?,
        f32s(args, 2)?,
        f32s(args, 3)?,
        f32s(args, 4)?,
        f32s(args, 5)?,
        f32s(args, 6)?,
    ];
    let theta = f32s(args, 7 + N_FACTORS)?;
    let merged = merge_mats(base, theta);
    Ok(merged.into_iter().enumerate().map(|(t, m)| out_f32(info, t, m)).collect())
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn random_model_bufs(seed: u64) -> (Vec<f32>, [Vec<f32>; 7]) {
        let mut rng = Pcg64::new(seed);
        let embed = rng.normal_vec(V * D, 0.1);
        let mats: [Vec<f32>; 7] =
            std::array::from_fn(|t| rng.normal_vec(MATS[t].1 * MATS[t].2, 0.3));
        (embed, mats)
    }

    fn model<'a>(embed: &'a [f32], mats: &'a [Vec<f32>; 7]) -> SimModel<'a> {
        SimModel { embed, mats: std::array::from_fn(|t| mats[t].as_slice()) }
    }

    /// CE loss of one (token -> target) position, for finite differences.
    fn pos_loss(m: &SimModel, x: i32, y: usize) -> f32 {
        let (_, logits) = m.forward(x);
        -softmax(&logits)[y].max(1e-30).ln()
    }

    /// The hand-derived backprop matches central finite differences on
    /// every weight tensor — the one test that keeps the whole sim
    /// gradient stack honest.
    #[test]
    fn backward_matches_finite_differences() {
        let (embed, mats) = random_model_bufs(5);
        let (x, y) = (7i32, 11usize);

        // analytic gradient
        let m = model(&embed, &mats);
        let (acts, logits) = m.forward(x);
        let probs = softmax(&logits);
        let mut dlogits = vec![0.0f32; V];
        for v in 0..V {
            let onehot = if v == y { 1.0 } else { 0.0 };
            dlogits[v] = -(onehot - probs[v]); // dLoss/dlp = -1
        }
        let mut grads = SimGrads::zeros();
        m.backward(&acts, &dlogits, &mut grads);

        let eps = 1e-2f32;
        let mut rng = Pcg64::new(9);
        // spot-check a random sample of coordinates in every tensor
        for t in 0..8 {
            for _ in 0..20 {
                let (numeric, analytic) = if t == 0 {
                    // embed rows that matter: the input token and the target
                    let row = if rng.below(2) == 0 { x as usize } else { y };
                    let j = row * D + rng.below(D as u64) as usize;
                    let mut e2 = embed.clone();
                    e2[j] += eps;
                    let lp = pos_loss(&model(&e2, &mats), x, y);
                    e2[j] -= 2.0 * eps;
                    let lm = pos_loss(&model(&e2, &mats), x, y);
                    ((lp - lm) / (2.0 * eps), grads.embed[j])
                } else {
                    let mi = t - 1;
                    let j = rng.below(mats[mi].len() as u64) as usize;
                    let mut m2 = mats.clone();
                    m2[mi][j] += eps;
                    let lp = pos_loss(&model(&embed, &m2), x, y);
                    m2[mi][j] -= 2.0 * eps;
                    let lm = pos_loss(&model(&embed, &m2), x, y);
                    ((lp - lm) / (2.0 * eps), grads.mats[mi][j])
                };
                assert!(
                    (numeric - analytic).abs() <= 2e-3 + 0.05 * numeric.abs(),
                    "tensor {t}: finite diff {numeric} vs analytic {analytic}"
                );
            }
        }
    }

    #[test]
    fn merge_is_identity_at_zero_and_linear() {
        let (_, mats) = random_model_bufs(3);
        let base: [&[f32]; 7] = std::array::from_fn(|t| mats[t].as_slice());
        let zero = merge_mats(base, &[0.0; N_THETA]);
        for t in 0..7 {
            assert_eq!(zero[t], mats[t], "theta=0 must merge to the base exactly");
        }
        // linearity: merge(a) + merge(b) - base == merge(a + b)
        let mut rng = Pcg64::new(4);
        let ta: Vec<f32> = rng.normal_vec(N_THETA, 0.2);
        let tb: Vec<f32> = rng.normal_vec(N_THETA, 0.2);
        let tab: Vec<f32> = ta.iter().zip(&tb).map(|(a, b)| a + b).collect();
        let ma = merge_mats(base, &ta);
        let mb = merge_mats(base, &tb);
        let mab = merge_mats(base, &tab);
        for t in 0..7 {
            for j in 0..mats[t].len() {
                let sum = ma[t][j] + mb[t][j] - mats[t][j];
                assert!((sum - mab[t][j]).abs() < 1e-4, "merge not linear at ({t},{j})");
            }
        }
        // a non-trivial theta must actually move the weights
        assert!(ma.iter().zip(&mats).any(|(m, b)| m != b));
    }

    #[test]
    fn dtheta_projection_matches_merge_chain_rule() {
        // loss = sum_j W[t][j] * c[t][j] (linear in W) has dL/dW = c, so
        // dL/dtheta via the projection must equal the finite difference of
        // the merged loss — exact to f32 roundoff.
        let (_, mats) = random_model_bufs(6);
        let base: [&[f32]; 7] = std::array::from_fn(|t| mats[t].as_slice());
        let mut rng = Pcg64::new(7);
        let c: [Vec<f32>; 7] = std::array::from_fn(|t| rng.normal_vec(mats[t].len(), 1.0));
        let loss = |theta: &[f32]| -> f64 {
            let m = merge_mats(base, theta);
            (0..7)
                .map(|t| {
                    m[t].iter().zip(&c[t]).map(|(&w, &cc)| w as f64 * cc as f64).sum::<f64>()
                })
                .sum()
        };
        let dtheta = project_dtheta(&c);
        let mut theta = vec![0.0f32; N_THETA];
        for k in 0..N_THETA {
            let eps = 1e-2f32;
            theta[k] = eps;
            let lp = loss(&theta);
            theta[k] = -eps;
            let lm = loss(&theta);
            theta[k] = 0.0;
            let numeric = ((lp - lm) / (2.0 * eps as f64)) as f32;
            assert!(
                (numeric - dtheta[k]).abs() <= 1e-3 + 1e-3 * numeric.abs(),
                "theta[{k}]: finite diff {numeric} vs projected {}",
                dtheta[k]
            );
        }
    }

    #[test]
    fn sim_manifest_is_self_consistent() {
        let m = sim_manifest();
        assert_eq!(m.vocab.chars, CHARS);
        assert_eq!(m.vocab.size, VOCAB_SIZE);
        let tier = m.tier(SIM_TIER).unwrap();
        assert_eq!(tier.weights.len(), N_WEIGHTS);
        // every baked generate geometry resolves
        for b in GEOMETRIES {
            let e = m.generate_exe(SIM_TIER, b).unwrap();
            assert_eq!(e.seq, N_GEN);
            // inputs: weights + tokens + prompt_len + uniforms + temperature
            assert_eq!(e.inputs.len(), N_WEIGHTS + 4);
        }
        // grads + merge + logprobs resolve through the same lookups the
        // trainers use
        assert_eq!(m.grad_exe(SIM_TIER, "grpo", SIM_SCHEME).unwrap().theta_size, Some(N_THETA));
        assert_eq!(m.grad_exe(SIM_TIER, "sft", SIM_SCHEME).unwrap().theta_size, Some(N_THETA));
        m.merge_exe(SIM_TIER, SIM_SCHEME).unwrap();
        m.find("logprobs", |e| e.fn_kind == "logprobs").unwrap();
        m.find("pretrain", |e| e.fn_kind == "pretrain" && e.batch == m.batch.train).unwrap();
        // geometry invariants the engine depends on
        assert!(GEOMETRIES.windows(2).all(|w| w[0] < w[1]));
        assert!(GEOMETRIES.contains(&m.batch.test));
        assert!(GEOMETRIES.contains(&m.batch.roll));
    }

    #[test]
    fn generate_rows_are_independent_and_deterministic() {
        let m = sim_manifest();
        let info = m.generate_exe(SIM_TIER, 2).unwrap().clone();
        let (embed, mats) = random_model_bufs(11);
        let mut args: Vec<Arg> = vec![Arg::F32(TensorF32::from_vec(&[V, D], embed))];
        for (t, (_, din, dout)) in MATS.iter().enumerate() {
            args.push(Arg::F32(TensorF32::from_vec(&[L, *din, *dout], mats[t].clone())));
        }
        let mut toks = vec![PAD; 2 * T_PREFILL];
        toks[0] = BOS;
        toks[1] = 10;
        toks[T_PREFILL] = BOS;
        toks[T_PREFILL + 1] = 20;
        args.push(Arg::I32(TensorI32::from_vec(&[2, T_PREFILL], toks)));
        args.push(Arg::I32(TensorI32::from_vec(&[2], vec![2, 2])));
        let mut rng = Pcg64::new(2);
        let uni = rng.uniform_vec(2 * N_GEN);
        args.push(Arg::F32(TensorF32::from_vec(&[2, N_GEN], uni.clone())));
        args.push(Arg::Scalar(1.0));

        let run = |args: &[Arg]| -> (Vec<i32>, Vec<f32>) {
            let out = run_generate(&info, args).unwrap();
            let toks = match &out[0] {
                HostTensor::I32(t) => t.data.clone(),
                _ => panic!("tokens output must be s32"),
            };
            let lps = match &out[1] {
                HostTensor::F32(t) => t.data.clone(),
                _ => panic!("behavior output must be f32"),
            };
            (toks, lps)
        };
        let (t1, l1) = run(&args);
        let (t2, _) = run(&args);
        assert_eq!(t1, t2, "generate must be deterministic");
        assert!(l1.iter().all(|&x| x <= 1e-6 && x.is_finite()), "log-probs must be <= 0");

        // perturb ONLY row 1's uniforms: row 0 must not change
        let mut uni2 = uni;
        for u in &mut uni2[N_GEN..] {
            *u = (*u + 0.37) % 1.0;
        }
        args[N_WEIGHTS + 2] = Arg::F32(TensorF32::from_vec(&[2, N_GEN], uni2));
        let (t3, _) = run(&args);
        assert_eq!(&t1[..N_GEN], &t3[..N_GEN], "row 0 depends on row 1's uniforms");
        assert_ne!(&t1[N_GEN..], &t3[N_GEN..], "row 1 must see its own uniforms");
    }

    #[test]
    fn fault_injection_consumes_compile_failures() {
        let faults = Arc::new(SimFaults::new(&SimOptions { fail_compiles: 1, ctx_delay_ms: vec![] }));
        let backend = SimBackend::new(faults.clone(), 0);
        let m = sim_manifest();
        let info = m.generate_exe(SIM_TIER, 1).unwrap();
        let ffi = Mutex::new(());
        let err = backend.compile(Path::new("<sim>"), info, &ffi);
        assert!(err.is_err(), "first compile must hit the injected failure");
        assert_eq!(faults.pending_compile_failures(), 0);
        assert!(backend.compile(Path::new("<sim>"), info, &ffi).is_ok(), "retry must succeed");
    }
}
