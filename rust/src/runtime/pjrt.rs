//! The PJRT backend: one `xla::PjRtClient` per execution context, running
//! AOT-lowered HLO text artifacts. This is the production device layer;
//! everything `xla`-specific in the runtime lives in this file.
//!
//! Notes driven by the `xla` 0.1.6 wrapper's semantics (measured, see
//! EXPERIMENTS.md §Perf):
//!   * Results always come back as ONE tuple buffer (the client does not
//!     untuple); `PjrtExe::execute` decomposes the tuple into per-output
//!     host tensors.
//!   * Tuple buffers cannot be re-fed as inputs, so loops that would chain
//!     device state (KV caches) are fused *inside* single executables at
//!     lowering time (`generate`).

use std::path::Path;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::manifest::{DType, ExeInfo};
use crate::runtime::backend::{Backend, CompiledExe, HostTensor};
use crate::tensor::{Arg, TensorF32, TensorI32};

pub struct PjrtBackend {
    client: xla::PjRtClient,
}

// SAFETY: the `xla` 0.1.6 wrapper holds non-Send handles to PJRT objects
// (they may be internally reference-counted without atomics). Two claims
// back these impls:
//
// 1. *Within* a context, no PJRT object is ever touched from two threads
//    at once: every code path that uses one — `compile`, `execute`,
//    `to_literal_sync`, `platform_name` — runs under the owning context's
//    `ffi` lock (threaded into every `Backend`/`CompiledExe` call), and a
//    context's objects (client, loaded executables) never escape it
//    (`Runtime::run` routes on `Executable::ctx`).
// 2. *Across* contexts, concurrency only ever involves DISTINCT PJRT
//    objects owned by distinct `PjRtClient`s. This leans on the PJRT
//    contract that independent clients share no unsynchronised state —
//    the multi-client granularity PJRT is designed for — rather than on
//    any thread-safety of individual wrapper handles. It is the one
//    assumption added over the old process-global lock; `--devices 1`
//    (the default) restores exactly the old single-lock behaviour.
//
// `xla::Literal` values are standalone host buffers with no client
// handle and are only ever owned by one thread. All rust-side mutability
// is behind RwLock/Mutex/atomics. Concurrency is exercised by the
// `engine::pool` tests at D=1 and D=2.
unsafe impl Send for PjrtBackend {}
unsafe impl Sync for PjrtBackend {}

impl PjrtBackend {
    pub fn new() -> Result<Self> {
        Ok(Self { client: xla::PjRtClient::cpu()? })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn platform(&self, ffi: &Mutex<()>) -> String {
        let _ffi = ffi.lock().unwrap();
        self.client.platform_name()
    }

    fn compile(
        &self,
        art_dir: &Path,
        info: &ExeInfo,
        ffi: &Mutex<()>,
    ) -> Result<Box<dyn CompiledExe>> {
        let path = art_dir.join(&info.file);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("loading HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = {
            let _ffi = ffi.lock().unwrap();
            self.client.compile(&comp).with_context(|| format!("compiling {}", info.name))?
        };
        Ok(Box::new(PjrtExe { exe }))
    }
}

/// A compiled executable, pinned to the client that compiled it (PJRT
/// loaded executables are client-owned and cannot run elsewhere — the
/// context-identity check in `ExecContext::run` enforces the routing).
struct PjrtExe {
    exe: xla::PjRtLoadedExecutable,
}

// SAFETY: see `PjrtBackend` — loaded executables are immutable after
// compilation and every FFI section on them runs under the owning
// context's `ffi` lock.
unsafe impl Send for PjrtExe {}
unsafe impl Sync for PjrtExe {}

impl CompiledExe for PjrtExe {
    fn execute(&self, info: &ExeInfo, args: &[Arg], ffi: &Mutex<()>) -> Result<Vec<HostTensor>> {
        // host side, outside the lock: arg → literal conversion
        let lits: Vec<xla::Literal> = args.iter().map(|a| a.to_literal()).collect::<Result<_>>()?;
        let root = {
            // device section: execute + transfer both touch PJRT objects
            let _ffi = ffi.lock().unwrap();
            let out = self.exe.execute::<xla::Literal>(&lits)?;
            out[0][0].to_literal_sync()?
        };
        // aot.py lowers with return_tuple=True: root is always a tuple.
        let mut root = root;
        let lits = root.decompose_tuple()?;
        if lits.len() != info.outputs.len() {
            bail!("{}: got {} outputs, want {}", info.name, lits.len(), info.outputs.len());
        }
        // host side again: literal → tensor per manifest output spec
        lits.iter()
            .zip(&info.outputs)
            .map(|(lit, spec)| {
                Ok(match spec.dtype {
                    DType::F32 => HostTensor::F32(TensorF32::from_literal(lit, &spec.shape)?),
                    DType::S32 => HostTensor::I32(TensorI32::from_literal(lit, &spec.shape)?),
                })
            })
            .collect()
    }
}
