//! PJRT runtime: loads AOT artifacts (HLO text) and executes them on the
//! CPU client. The rust binary is self-contained once `make artifacts` has
//! produced `artifacts/*.hlo.txt` + `manifest.json`.
//!
//! Notes driven by the `xla` 0.1.6 wrapper's semantics (measured, see
//! EXPERIMENTS.md §Perf):
//!   * Results always come back as ONE tuple buffer (the client does not
//!     untuple), so every entry point is invoked through `run`, which
//!     decomposes the tuple into per-output literals on host.
//!   * Tuple buffers cannot be re-fed as inputs, so loops that would chain
//!     device state (KV caches) are fused *inside* single executables at
//!     lowering time (`generate`).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::manifest::{DType, ExeInfo, Manifest};
use crate::tensor::{Arg, TensorF32, TensorI32};

pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    art_dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
    /// cumulative (compile_ms, run_ms, runs) for perf accounting
    stats: RefCell<RuntimeStats>,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct RuntimeStats {
    pub compile_ms: f64,
    pub run_ms: f64,
    pub runs: u64,
    pub compiles: u64,
}

pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub info: ExeInfo,
}

/// Outputs of one execution, keyed by position (manifest order).
pub struct Outputs {
    lits: Vec<xla::Literal>,
    info: ExeInfo,
}

impl Outputs {
    pub fn f32(&self, idx: usize) -> Result<TensorF32> {
        let spec = &self.info.outputs[idx];
        if spec.dtype != DType::F32 {
            bail!("output {idx} ({}) is not f32", spec.name);
        }
        TensorF32::from_literal(&self.lits[idx], &spec.shape)
    }

    pub fn i32(&self, idx: usize) -> Result<TensorI32> {
        let spec = &self.info.outputs[idx];
        if spec.dtype != DType::S32 {
            bail!("output {idx} ({}) is not s32", spec.name);
        }
        TensorI32::from_literal(&self.lits[idx], &spec.shape)
    }

    pub fn len(&self) -> usize {
        self.lits.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lits.is_empty()
    }

    /// Find an output index by manifest name.
    pub fn index_of(&self, name: &str) -> Result<usize> {
        self.info
            .outputs
            .iter()
            .position(|o| o.name == name)
            .with_context(|| format!("no output named {name:?}"))
    }
}

impl Runtime {
    pub fn new(art_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(art_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            client,
            manifest,
            art_dir: art_dir.to_path_buf(),
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    /// Default artifact dir: $TINYLORA_ARTIFACTS or ./artifacts.
    pub fn from_env() -> Result<Self> {
        let dir = std::env::var("TINYLORA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::new(Path::new(&dir))
    }

    /// Load (compile) an executable by manifest name, with caching.
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let info = self.manifest.exe(name)?.clone();
        let path = self.art_dir.join(&info.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("loading HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        {
            let mut s = self.stats.borrow_mut();
            s.compile_ms += t0.elapsed().as_secs_f64() * 1e3;
            s.compiles += 1;
        }
        let rc = Rc::new(Executable { exe, info });
        self.cache.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    /// Execute with shape-checked args; returns per-output literals.
    pub fn run(&self, exe: &Executable, args: &[Arg]) -> Result<Outputs> {
        if args.len() != exe.info.inputs.len() {
            bail!(
                "{}: got {} args, want {}",
                exe.info.name,
                args.len(),
                exe.info.inputs.len()
            );
        }
        for (a, spec) in args.iter().zip(&exe.info.inputs) {
            a.check(spec).with_context(|| exe.info.name.clone())?;
        }
        let lits: Vec<xla::Literal> =
            args.iter().map(|a| a.to_literal()).collect::<Result<_>>()?;
        let t0 = Instant::now();
        let out = exe.exe.execute::<xla::Literal>(&lits)?;
        let root = out[0][0].to_literal_sync()?;
        {
            let mut s = self.stats.borrow_mut();
            s.run_ms += t0.elapsed().as_secs_f64() * 1e3;
            s.runs += 1;
        }
        // aot.py lowers with return_tuple=True: root is always a tuple.
        let mut root = root;
        let lits = root.decompose_tuple()?;
        if lits.len() != exe.info.outputs.len() {
            bail!(
                "{}: got {} outputs, want {}",
                exe.info.name,
                lits.len(),
                exe.info.outputs.len()
            );
        }
        Ok(Outputs { lits, info: exe.info.clone() })
    }

    pub fn stats(&self) -> RuntimeStats {
        *self.stats.borrow()
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
