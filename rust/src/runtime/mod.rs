//! The runtime: loads manifest entry points and executes them on a pool
//! of execution contexts, behind a pluggable [`Backend`].
//!
//! Two backends exist (see `backend.rs` for the trait contract):
//!   * **pjrt** — the production path: AOT artifacts (HLO text) compiled
//!     onto one `xla::PjRtClient` per context. Requires `make artifacts`.
//!   * **sim** — a hermetic, deterministic pure-rust implementation of
//!     every manifest entry point ([`sim::sim_manifest`]), so the full
//!     engine → trainer → serving → bench stack runs end-to-end with no
//!     artifacts on disk (`--backend sim`, `Runtime::sim`, or
//!     `TINYLORA_BACKEND=sim`). CI's `tests/e2e_sim.rs` runs on it
//!     unconditionally.
//!
//! Device parallelism: `Runtime` is a facade over D [`ExecContext`]s
//! (one backend instance + executable cache + FFI lock + atomic counters
//! each — see `context.rs`). The old single global `exec_lock` is gone;
//! executions only serialise per context, so `engine::pool` workers,
//! tenant rollout waves and bench ladders overlap up to D ways. Routing
//! is deterministic everywhere it can affect results: named loads place
//! by a stable hash ([`Runtime::placement`]), pool jobs pin by job id
//! ([`Runtime::ctx_for`]), and only content-invariant callers use the
//! least-loaded, warm-sticky [`Runtime::checkout`]. D defaults to 1
//! (`--devices` / `TINYLORA_DEVICES` opt in), and D contexts run the
//! same entry points through the same backend, so results do not depend
//! on which context served a call. DESIGN.md §9 spells out the lock
//! hierarchy and the determinism argument; §10 the backend contract.

pub mod backend;
pub mod context;
pub mod pjrt;
pub mod sim;
pub mod supervisor;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::Result;

pub use backend::{
    Backend, BackendSpec, CompiledExe, ContextLost, HostTensor, SimOptions, TransientExecError,
};
pub use context::{ExecContext, Executable, Outputs, RuntimeStats, SingleFlight};
pub use sim::{sim_manifest, SIM_SCHEME, SIM_TIER};
pub use supervisor::{
    classify, FaultKind, Health, SupervisionError, Supervisor, SupervisorPolicy, SupervisorStats,
};

use crate::manifest::{DType, Manifest};
use crate::tensor::{Arg, TensorF32, TensorI32};
use crate::util::fnv1a;

pub struct Runtime {
    contexts: Vec<ExecContext>,
    pub manifest: Manifest,
    art_dir: PathBuf,
    backend_name: &'static str,
    supervisor: Supervisor,
}

impl Runtime {
    /// Single-context PJRT runtime — the default, byte-identical to the
    /// pre-pool behaviour (one client, one FFI lock).
    pub fn new(art_dir: &Path) -> Result<Self> {
        Self::with_devices(art_dir, 1)
    }

    /// PJRT runtime with `devices` independent execution contexts
    /// (clamped to at least 1). Contexts share nothing; work routed to
    /// different contexts executes concurrently.
    pub fn with_devices(art_dir: &Path, devices: usize) -> Result<Self> {
        Self::with_backend(BackendSpec::Pjrt, art_dir, devices)
    }

    /// Hermetic sim runtime: synthetic manifest, pure-rust entry points,
    /// zero artifacts on disk. Deterministic at any device count.
    pub fn sim(devices: usize) -> Result<Self> {
        Self::sim_with(devices, SimOptions::default())
    }

    /// [`Runtime::sim`] with fault injection (compile failures, slow
    /// contexts) — the e2e suite's handle on failure-path coverage.
    pub fn sim_with(devices: usize, opts: SimOptions) -> Result<Self> {
        Self::with_backend(BackendSpec::Sim(opts), Path::new("<sim>"), devices)
    }

    /// Runtime over an explicit backend spec. The manifest comes from
    /// `art_dir` for PJRT and from [`sim::sim_manifest`] for sim (which
    /// never touches the filesystem).
    pub fn with_backend(spec: BackendSpec, art_dir: &Path, devices: usize) -> Result<Self> {
        let d = devices.max(1);
        let (manifest, backend_name) = match &spec {
            BackendSpec::Pjrt => (Manifest::load(art_dir)?, "pjrt"),
            BackendSpec::Sim(_) => (sim_manifest(), "sim"),
        };
        let mut contexts = Vec::with_capacity(d);
        match spec {
            BackendSpec::Pjrt => {
                for id in 0..d {
                    contexts.push(ExecContext::new(id, Box::new(pjrt::PjrtBackend::new()?)));
                }
            }
            BackendSpec::Sim(opts) => {
                // fault state is runtime-wide (an injected compile failure
                // hits whichever context compiles next); delays, scripted
                // deaths, hangs and transient failures are per-context by id
                let faults = Arc::new(sim::SimFaults::new(&opts, d));
                for id in 0..d {
                    contexts.push(ExecContext::new(
                        id,
                        Box::new(sim::SimBackend::new(faults.clone(), id, &opts)),
                    ));
                }
            }
        }
        let supervisor = Supervisor::new(d, SupervisorPolicy::default());
        Ok(Self { contexts, manifest, art_dir: art_dir.to_path_buf(), backend_name, supervisor })
    }

    /// Replace the supervision policy (builder-style; resets health state
    /// and counters). Chaos scenarios use this to enable execute
    /// deadlines or shrink retry budgets.
    pub fn with_supervisor_policy(mut self, policy: SupervisorPolicy) -> Self {
        self.supervisor = Supervisor::new(self.contexts.len(), policy);
        self
    }

    /// Backend + artifact dir + context count from the environment:
    /// `TINYLORA_BACKEND` ("pjrt" default | "sim"), `TINYLORA_ARTIFACTS`
    /// (default ./artifacts; ignored by sim), `TINYLORA_DEVICES`
    /// (default 1), `TINYLORA_SIM_WORKERS` (sim only: row workers per
    /// execute call, default 0 = serial), `TINYLORA_SIM_FAULTS` (sim
    /// only: fault-injection spec, see [`SimOptions::parse_faults`]). A
    /// set-but-unparseable value is an error, not a silent fall-back
    /// (the operator asked for something; failing fast beats quietly not
    /// delivering it).
    pub fn from_env() -> Result<Self> {
        let dir = std::env::var("TINYLORA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        let devices = match std::env::var("TINYLORA_DEVICES") {
            Err(_) => 1,
            Ok(v) => v.trim().parse().map_err(|_| {
                anyhow::anyhow!("TINYLORA_DEVICES {v:?} is not a device count")
            })?,
        };
        let sim_workers = match std::env::var("TINYLORA_SIM_WORKERS") {
            Err(_) => 0,
            Ok(v) => v.trim().parse().map_err(|_| {
                anyhow::anyhow!("TINYLORA_SIM_WORKERS {v:?} is not a worker count")
            })?,
        };
        // parsed eagerly so a malformed spec fails fast on any backend
        let sim_faults = match std::env::var("TINYLORA_SIM_FAULTS") {
            Err(_) => None,
            Ok(v) if v.trim().is_empty() => None,
            Ok(v) => Some(SimOptions::parse_faults(&v)?),
        };
        match std::env::var("TINYLORA_BACKEND").as_deref() {
            Err(_) | Ok("pjrt") => Self::with_devices(Path::new(&dir), devices),
            Ok("sim") => {
                let mut opts = sim_faults.unwrap_or_default();
                opts.row_workers = sim_workers;
                Self::sim_with(devices, opts)
            }
            Ok(other) => anyhow::bail!("TINYLORA_BACKEND {other:?} is not a backend (pjrt|sim)"),
        }
    }

    /// Which backend this runtime's contexts run ("pjrt" | "sim").
    pub fn backend_name(&self) -> &'static str {
        self.backend_name
    }

    /// Number of execution contexts in the pool.
    pub fn devices(&self) -> usize {
        self.contexts.len()
    }

    /// A context by id (wrapped modulo the pool size, so callers may pass
    /// any stable index).
    pub fn context(&self, id: usize) -> &ExecContext {
        &self.contexts[id % self.contexts.len()]
    }

    /// Deterministic context for a pool job: a pure function of the job
    /// id, NOT of which worker dequeued it — this is what keeps pooled
    /// results byte-identical to serial ones at any D (`serve` and
    /// `serve_serial` route each job identically).
    pub fn ctx_for(&self, job_id: u64) -> usize {
        (job_id % self.contexts.len() as u64) as usize
    }

    /// Stable placement of a named executable: a hash of the name, so
    /// every caller that loads `name` without an explicit context agrees
    /// on one context (no duplicate compiles) and different executables
    /// spread across the pool.
    pub fn placement(&self, name: &str) -> usize {
        (fnv1a(name.as_bytes()) % self.contexts.len() as u64) as usize
    }

    /// Least-loaded checkout biased to `preferred`: stays on `preferred`
    /// unless some context is strictly less loaded (in-flight backend
    /// sections, compiles included). Sticky on ties, so an otherwise-idle
    /// pool keeps reusing the warm context instead of rotating onto cold
    /// ones and paying their first-use compiles. For callers whose
    /// results cannot depend on the context — greedy serving decode,
    /// occupancy probes — NOT for anything whose bytes must be
    /// reproducible under a pinned schedule.
    /// Quarantined contexts are skipped (graceful degradation: the
    /// surviving pool absorbs the load); if everything is quarantined the
    /// preferred index is returned and the subsequent `run` surfaces the
    /// typed `NoLiveContexts` error.
    pub fn checkout(&self, preferred: usize) -> usize {
        let n = self.contexts.len();
        if n == 1 {
            return 0;
        }
        let mut best = preferred % n;
        let mut best_load = usize::MAX;
        if self.supervisor.health(best) != Health::Quarantined {
            best_load = self.contexts[best].in_flight();
        }
        for (i, c) in self.contexts.iter().enumerate() {
            if self.supervisor.health(i) == Health::Quarantined {
                continue;
            }
            let load = c.in_flight();
            if load < best_load {
                best = i;
                best_load = load;
            }
        }
        best
    }

    /// Load (compile) an executable by manifest name on its stable
    /// placement context, with single-flight caching: concurrent loads of
    /// one name yield exactly one compile.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        self.load_on(self.placement(name), name)
    }

    /// Load on an explicit context (engine decode paths pin per-job
    /// contexts and need the executable resident there). A quarantined
    /// `ctx` resolves to its surviving stand-in (same ascending probe the
    /// dispatch path uses), so callers holding a dead pin keep working.
    /// Compile errors surface unchanged — loads are routed, never
    /// retried here (`SingleFlight` already gives failed compiles a
    /// clean retry on the next load).
    pub fn load_on(&self, ctx: usize, name: &str) -> Result<Arc<Executable>> {
        let target = self.supervisor.resolve(ctx % self.contexts.len())?;
        self.context(target).load(&self.manifest, &self.art_dir, name)
    }

    /// Execute with shape-checked args; routed to the context that owns
    /// the executable (backend-resident executables cannot run on another
    /// context's backend). Routing goes through `context` (wrapping) so
    /// an executable from a differently-sized runtime hits
    /// `ExecContext::run`'s id check — a clean error, not an index panic.
    ///
    /// This is the supervised dispatch loop (DESIGN.md §14): quarantined
    /// owners divert to a survivor (the executable is re-loaded there
    /// through the single-flight cache — a requeue), typed transient
    /// errors retry in place with bounded exponential backoff, and typed
    /// context losses quarantine the context and requeue. Result bytes
    /// cannot change under any of it: every entry point is a pure
    /// function of its args, so the survivor computes exactly what the
    /// owner would have.
    pub fn run(&self, exe: &Executable, args: &[Arg]) -> Result<Outputs> {
        let n = self.contexts.len();
        let owner = exe.ctx % n;
        let mut attempts = 0u32;
        let mut dispatched: Option<usize> = None;
        loop {
            let target = self.supervisor.resolve(owner)?;
            if target != owner && dispatched != Some(target) {
                // the owner is quarantined: this dispatch re-pins the
                // orphaned call onto a survivor
                self.supervisor.note_requeue();
            }
            dispatched = Some(target);
            let reloaded;
            let exe_ref = if target == owner {
                exe
            } else {
                reloaded = self.context(target).load(&self.manifest, &self.art_dir, &exe.info.name)?;
                &*reloaded
            };
            let t0 = std::time::Instant::now();
            match self.context(target).run(exe_ref, args) {
                Ok(out) => {
                    self.supervisor.observe_success(target, t0.elapsed().as_secs_f64() * 1e3);
                    return Ok(out);
                }
                Err(err) => match self.supervisor.observe_error(target, &err) {
                    // the target just got quarantined; loop re-resolves
                    // onto a survivor (or NoLiveContexts when none is left)
                    FaultKind::ContextLost => continue,
                    FaultKind::Transient => {
                        if attempts >= self.supervisor.policy().max_retries {
                            return Err(anyhow::Error::new(SupervisionError::RetriesExhausted {
                                ctx: target,
                                attempts: attempts + 1,
                                last: format!("{err:#}"),
                            }));
                        }
                        attempts += 1;
                        self.supervisor.note_retry();
                        let ms = self.supervisor.policy().backoff_ms(attempts);
                        if ms > 0 {
                            std::thread::sleep(std::time::Duration::from_millis(ms));
                        }
                    }
                    FaultKind::Fatal => return Err(err),
                },
            }
        }
    }

    /// The supervision plane: health state, fault counters, dispatch
    /// resolution (see [`Supervisor`]).
    pub fn supervisor(&self) -> &Supervisor {
        &self.supervisor
    }

    /// Actively probe every non-quarantined context with a minimal
    /// generate execute (zero-filled args — the output is discarded, only
    /// liveness and latency matter) and fold the observations into the
    /// health state: losses quarantine, deadline overruns strike.
    /// Returns the post-probe health vector. Probes hit each context
    /// DIRECTLY (no supervised routing — a probe that silently diverted
    /// to a healthy context would hide the fault it exists to find).
    pub fn health_check(&self) -> Result<Vec<Health>> {
        let info = self
            .manifest
            .executables
            .values()
            .filter(|e| e.fn_kind == "generate")
            .min_by_key(|e| e.batch)
            .ok_or_else(|| anyhow::anyhow!("health check needs a generate entry point"))?
            .clone();
        let args: Vec<Arg> = info
            .inputs
            .iter()
            .map(|spec| {
                let numel: usize = spec.shape.iter().product();
                match spec.dtype {
                    // prompt_len rows are clamped to ≥1 by the entry
                    // points, so all-zeros is a valid minimal input
                    DType::F32 => Arg::F32(TensorF32::from_vec(&spec.shape, vec![0.0; numel])),
                    DType::S32 => Arg::I32(TensorI32::from_vec(&spec.shape, vec![0; numel])),
                }
            })
            .collect();
        for ctx in 0..self.contexts.len() {
            if self.supervisor.health(ctx) == Health::Quarantined {
                continue;
            }
            let probe = || -> Result<()> {
                let exe = self.context(ctx).load(&self.manifest, &self.art_dir, &info.name)?;
                let t0 = std::time::Instant::now();
                self.context(ctx).run(&exe, &args)?;
                self.supervisor.observe_success(ctx, t0.elapsed().as_secs_f64() * 1e3);
                Ok(())
            };
            if let Err(err) = probe() {
                self.supervisor.observe_error(ctx, &err);
            }
        }
        Ok(self.supervisor.healths())
    }

    /// Cumulative counters aggregated over every context, with the
    /// runtime-wide supervision counters overlaid.
    pub fn stats(&self) -> RuntimeStats {
        let mut agg = RuntimeStats::default();
        for c in &self.contexts {
            agg.add(&c.stats());
        }
        let sv = self.supervisor.stats();
        agg.retries = sv.retries;
        agg.requeues = sv.requeues;
        agg.quarantines = sv.quarantines;
        agg.deaths = sv.deaths;
        agg
    }

    /// Per-context counter snapshots (index = context id).
    pub fn per_context_stats(&self) -> Vec<RuntimeStats> {
        self.contexts.iter().map(|c| c.stats()).collect()
    }

    pub fn platform(&self) -> String {
        self.contexts[0].platform()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Compile-time guarantee backing `engine::pool::WorkerPool`: sharing
    /// `&Runtime` / `Arc<Executable>` across worker threads is sound.
    #[test]
    fn runtime_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Runtime>();
        assert_send_sync::<ExecContext>();
        assert_send_sync::<Executable>();
        assert_send_sync::<RuntimeStats>();
    }

    /// The sim runtime constructs with zero artifacts on disk and reports
    /// its backend; PJRT stays the default elsewhere.
    #[test]
    fn sim_runtime_constructs_without_artifacts() {
        let rt = Runtime::sim(2).unwrap();
        assert_eq!(rt.backend_name(), "sim");
        assert_eq!(rt.devices(), 2);
        assert_eq!(rt.platform(), "sim");
        assert!(rt.manifest.tiers.contains_key(SIM_TIER));
        // a named load resolves and executes through the normal path
        let name = rt.manifest.generate_exe(SIM_TIER, rt.manifest.batch.test).unwrap().name.clone();
        rt.load(&name).unwrap();
        assert_eq!(rt.stats().compiles, 1);
    }
}
