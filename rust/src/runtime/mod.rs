//! PJRT runtime: loads AOT artifacts (HLO text) and executes them on a
//! pool of CPU execution contexts. The rust binary is self-contained once
//! `make artifacts` has produced `artifacts/*.hlo.txt` + `manifest.json`.
//!
//! Notes driven by the `xla` 0.1.6 wrapper's semantics (measured, see
//! EXPERIMENTS.md §Perf):
//!   * Results always come back as ONE tuple buffer (the client does not
//!     untuple), so every entry point is invoked through `run`, which
//!     decomposes the tuple into per-output literals on host.
//!   * Tuple buffers cannot be re-fed as inputs, so loops that would chain
//!     device state (KV caches) are fused *inside* single executables at
//!     lowering time (`generate`).
//!
//! Device parallelism: `Runtime` is a facade over D [`ExecContext`]s
//! (one PJRT client + executable cache + FFI lock + atomic counters
//! each — see `context.rs`). The old single global `exec_lock` is gone;
//! executions only serialise per context, so `engine::pool` workers,
//! tenant rollout waves and bench ladders overlap on the device up to D
//! ways. Routing is deterministic everywhere it can affect results:
//! named loads place by a stable hash ([`Runtime::placement`]), pool
//! jobs pin by job id ([`Runtime::ctx_for`]), and only content-invariant
//! callers use the least-loaded, warm-sticky [`Runtime::checkout`]. D
//! defaults to 1
//! (`--devices` / `TINYLORA_DEVICES` opt in), and D contexts run the
//! same HLO through the same backend, so results do not depend on which
//! context served a call. DESIGN.md §9 spells out the lock hierarchy and
//! the determinism argument.

pub mod context;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::Result;

pub use context::{ExecContext, Executable, Outputs, RuntimeStats, SingleFlight};

use crate::manifest::Manifest;
use crate::tensor::Arg;
use crate::util::fnv1a;

pub struct Runtime {
    contexts: Vec<ExecContext>,
    pub manifest: Manifest,
    art_dir: PathBuf,
}

impl Runtime {
    /// Single-context runtime — the default, byte-identical to the
    /// pre-pool behaviour (one client, one FFI lock).
    pub fn new(art_dir: &Path) -> Result<Self> {
        Self::with_devices(art_dir, 1)
    }

    /// Runtime with `devices` independent execution contexts (clamped to
    /// at least 1). Contexts share nothing; work routed to different
    /// contexts executes concurrently.
    pub fn with_devices(art_dir: &Path, devices: usize) -> Result<Self> {
        let manifest = Manifest::load(art_dir)?;
        let d = devices.max(1);
        let mut contexts = Vec::with_capacity(d);
        for id in 0..d {
            contexts.push(ExecContext::new(id)?);
        }
        Ok(Self { contexts, manifest, art_dir: art_dir.to_path_buf() })
    }

    /// Default artifact dir: $TINYLORA_ARTIFACTS or ./artifacts; context
    /// count: $TINYLORA_DEVICES or 1. A set-but-unparseable device count
    /// is an error, not a silent fall-back to 1 (the operator asked for
    /// device parallelism; failing fast beats quietly not delivering it).
    pub fn from_env() -> Result<Self> {
        let dir = std::env::var("TINYLORA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        let devices = match std::env::var("TINYLORA_DEVICES") {
            Err(_) => 1,
            Ok(v) => v.trim().parse().map_err(|_| {
                anyhow::anyhow!("TINYLORA_DEVICES {v:?} is not a device count")
            })?,
        };
        Self::with_devices(Path::new(&dir), devices)
    }

    /// Number of execution contexts in the pool.
    pub fn devices(&self) -> usize {
        self.contexts.len()
    }

    /// A context by id (wrapped modulo the pool size, so callers may pass
    /// any stable index).
    pub fn context(&self, id: usize) -> &ExecContext {
        &self.contexts[id % self.contexts.len()]
    }

    /// Deterministic context for a pool job: a pure function of the job
    /// id, NOT of which worker dequeued it — this is what keeps pooled
    /// results byte-identical to serial ones at any D (`serve` and
    /// `serve_serial` route each job identically).
    pub fn ctx_for(&self, job_id: u64) -> usize {
        (job_id % self.contexts.len() as u64) as usize
    }

    /// Stable placement of a named executable: a hash of the name, so
    /// every caller that loads `name` without an explicit context agrees
    /// on one context (no duplicate compiles) and different executables
    /// spread across the pool.
    pub fn placement(&self, name: &str) -> usize {
        (fnv1a(name.as_bytes()) % self.contexts.len() as u64) as usize
    }

    /// Least-loaded checkout biased to `preferred`: stays on `preferred`
    /// unless some context is strictly less loaded (in-flight FFI
    /// sections, compiles included). Sticky on ties, so an otherwise-idle
    /// pool keeps reusing the warm context instead of rotating onto cold
    /// ones and paying their first-use compiles. For callers whose
    /// results cannot depend on the context — greedy serving decode,
    /// occupancy probes — NOT for anything whose bytes must be
    /// reproducible under a pinned schedule.
    pub fn checkout(&self, preferred: usize) -> usize {
        let n = self.contexts.len();
        if n == 1 {
            return 0;
        }
        let mut best = preferred % n;
        let mut best_load = self.contexts[best].in_flight();
        for (i, c) in self.contexts.iter().enumerate() {
            let load = c.in_flight();
            if load < best_load {
                best = i;
                best_load = load;
            }
        }
        best
    }

    /// Load (compile) an executable by manifest name on its stable
    /// placement context, with single-flight caching: concurrent loads of
    /// one name yield exactly one compile.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        self.load_on(self.placement(name), name)
    }

    /// Load on an explicit context (engine decode paths pin per-job
    /// contexts and need the executable resident there).
    pub fn load_on(&self, ctx: usize, name: &str) -> Result<Arc<Executable>> {
        self.context(ctx).load(&self.manifest, &self.art_dir, name)
    }

    /// Execute with shape-checked args; routed to the context that owns
    /// the executable (PJRT executables cannot run on another client).
    /// Routing goes through `context` (wrapping) so an executable from a
    /// differently-sized runtime hits `ExecContext::run`'s id check — a
    /// clean error, not an index panic.
    pub fn run(&self, exe: &Executable, args: &[Arg]) -> Result<Outputs> {
        self.context(exe.ctx).run(exe, args)
    }

    /// Cumulative counters aggregated over every context.
    pub fn stats(&self) -> RuntimeStats {
        let mut agg = RuntimeStats::default();
        for c in &self.contexts {
            agg.add(&c.stats());
        }
        agg
    }

    /// Per-context counter snapshots (index = context id).
    pub fn per_context_stats(&self) -> Vec<RuntimeStats> {
        self.contexts.iter().map(|c| c.stats()).collect()
    }

    pub fn platform(&self) -> String {
        self.contexts[0].platform()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Compile-time guarantee backing `engine::pool::WorkerPool`: sharing
    /// `&Runtime` / `Arc<Executable>` across worker threads is sound.
    #[test]
    fn runtime_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Runtime>();
        assert_send_sync::<ExecContext>();
        assert_send_sync::<Executable>();
        assert_send_sync::<RuntimeStats>();
    }
}
