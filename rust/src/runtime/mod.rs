//! PJRT runtime: loads AOT artifacts (HLO text) and executes them on the
//! CPU client. The rust binary is self-contained once `make artifacts` has
//! produced `artifacts/*.hlo.txt` + `manifest.json`.
//!
//! Notes driven by the `xla` 0.1.6 wrapper's semantics (measured, see
//! EXPERIMENTS.md §Perf):
//!   * Results always come back as ONE tuple buffer (the client does not
//!     untuple), so every entry point is invoked through `run`, which
//!     decomposes the tuple into per-output literals on host.
//!   * Tuple buffers cannot be re-fed as inputs, so loops that would chain
//!     device state (KV caches) are fused *inside* single executables at
//!     lowering time (`generate`).
//!
//! Thread-safety: `Runtime` is `Send + Sync`. The executable cache is an
//! `RwLock` (reads dominate: one compile per name, then lock-free-ish
//! lookups), perf counters sit behind a `Mutex`, and compiled executables
//! are shared as `Arc<Executable>` so `engine::pool::WorkerPool` threads
//! can run independent adapter batches concurrently against one client.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::manifest::{DType, ExeInfo, Manifest};
use crate::tensor::{Arg, TensorF32, TensorI32};

pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    art_dir: PathBuf,
    cache: RwLock<HashMap<String, Arc<Executable>>>,
    /// Serialises every FFI section that touches PJRT objects (compile,
    /// execute, device→host transfer). See the SAFETY note below: we do
    /// NOT rely on the wrapper being internally thread-safe. Host-side
    /// work (arg→literal conversion, tuple decomposition, decode/verify)
    /// stays outside this lock, so `engine::pool` workers still overlap
    /// usefully.
    exec_lock: Mutex<()>,
    /// cumulative (compile_ms, run_ms, runs) for perf accounting
    stats: Mutex<RuntimeStats>,
}

// SAFETY: `Runtime`/`Executable` lack the auto traits only because the
// `xla` 0.1.6 wrapper holds non-Send handles to PJRT objects (they may be
// internally reference-counted without atomics). We therefore make NO
// assumption about the wrapper's internal thread-safety: every code path
// that touches a PJRT object — `compile`, `execute`, `to_literal_sync` —
// runs under `exec_lock`, so those handles are never accessed from two
// threads at once. `xla::Literal` values are standalone host buffers with
// no client handle and are only ever owned by one thread. All rust-side
// mutability is behind RwLock/Mutex. Concurrency is exercised by the
// `engine::pool` tests.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

#[derive(Clone, Copy, Debug, Default)]
pub struct RuntimeStats {
    pub compile_ms: f64,
    pub run_ms: f64,
    pub runs: u64,
    pub compiles: u64,
}

pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub info: ExeInfo,
}

// SAFETY: see the `Runtime` impls above — loaded executables are immutable
// after compilation and PJRT execution is thread-safe.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

/// Outputs of one execution, keyed by position (manifest order).
pub struct Outputs {
    lits: Vec<xla::Literal>,
    info: ExeInfo,
}

impl Outputs {
    pub fn f32(&self, idx: usize) -> Result<TensorF32> {
        let spec = &self.info.outputs[idx];
        if spec.dtype != DType::F32 {
            bail!("output {idx} ({}) is not f32", spec.name);
        }
        TensorF32::from_literal(&self.lits[idx], &spec.shape)
    }

    pub fn i32(&self, idx: usize) -> Result<TensorI32> {
        let spec = &self.info.outputs[idx];
        if spec.dtype != DType::S32 {
            bail!("output {idx} ({}) is not s32", spec.name);
        }
        TensorI32::from_literal(&self.lits[idx], &spec.shape)
    }

    pub fn len(&self) -> usize {
        self.lits.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lits.is_empty()
    }

    /// Find an output index by manifest name.
    pub fn index_of(&self, name: &str) -> Result<usize> {
        self.info
            .outputs
            .iter()
            .position(|o| o.name == name)
            .with_context(|| format!("no output named {name:?}"))
    }
}

impl Runtime {
    pub fn new(art_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(art_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            client,
            manifest,
            art_dir: art_dir.to_path_buf(),
            cache: RwLock::new(HashMap::new()),
            exec_lock: Mutex::new(()),
            stats: Mutex::new(RuntimeStats::default()),
        })
    }

    /// Default artifact dir: $TINYLORA_ARTIFACTS or ./artifacts.
    pub fn from_env() -> Result<Self> {
        let dir = std::env::var("TINYLORA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::new(Path::new(&dir))
    }

    /// Load (compile) an executable by manifest name, with caching.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.read().unwrap().get(name) {
            return Ok(e.clone());
        }
        let info = self.manifest.exe(name)?.clone();
        let path = self.art_dir.join(&info.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("loading HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = {
            let _ffi = self.exec_lock.lock().unwrap();
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?
        };
        {
            let mut s = self.stats.lock().unwrap();
            s.compile_ms += t0.elapsed().as_secs_f64() * 1e3;
            s.compiles += 1;
        }
        let arc = Arc::new(Executable { exe, info });
        // two threads racing to compile the same exe both succeed; the
        // second insert wins and the first Arc just drops when unreferenced
        self.cache.write().unwrap().insert(name.to_string(), arc.clone());
        Ok(arc)
    }

    /// Execute with shape-checked args; returns per-output literals.
    pub fn run(&self, exe: &Executable, args: &[Arg]) -> Result<Outputs> {
        if args.len() != exe.info.inputs.len() {
            bail!(
                "{}: got {} args, want {}",
                exe.info.name,
                args.len(),
                exe.info.inputs.len()
            );
        }
        for (a, spec) in args.iter().zip(&exe.info.inputs) {
            a.check(spec).with_context(|| exe.info.name.clone())?;
        }
        let lits: Vec<xla::Literal> =
            args.iter().map(|a| a.to_literal()).collect::<Result<_>>()?;
        let t0 = Instant::now();
        let root = {
            // device section: execute + transfer both touch PJRT objects
            let _ffi = self.exec_lock.lock().unwrap();
            let out = exe.exe.execute::<xla::Literal>(&lits)?;
            out[0][0].to_literal_sync()?
        };
        {
            let mut s = self.stats.lock().unwrap();
            s.run_ms += t0.elapsed().as_secs_f64() * 1e3;
            s.runs += 1;
        }
        // aot.py lowers with return_tuple=True: root is always a tuple.
        let mut root = root;
        let lits = root.decompose_tuple()?;
        if lits.len() != exe.info.outputs.len() {
            bail!(
                "{}: got {} outputs, want {}",
                exe.info.name,
                lits.len(),
                exe.info.outputs.len()
            );
        }
        Ok(Outputs { lits, info: exe.info.clone() })
    }

    pub fn stats(&self) -> RuntimeStats {
        *self.stats.lock().unwrap()
    }

    pub fn platform(&self) -> String {
        let _ffi = self.exec_lock.lock().unwrap();
        self.client.platform_name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Compile-time guarantee backing `engine::pool::WorkerPool`: sharing
    /// `&Runtime` / `Arc<Executable>` across worker threads is sound.
    #[test]
    fn runtime_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Runtime>();
        assert_send_sync::<Executable>();
        assert_send_sync::<RuntimeStats>();
    }
}
