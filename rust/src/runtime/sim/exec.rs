//! Data-parallel batch execution: rows of a sim batch dispatched across
//! `std::thread::scope` workers — no new deps, no locks, no channels.
//!
//! Determinism under row-parallelism is preserved *by construction*:
//!
//! 1. Rows are split into contiguous chunks ([`chunk_ranges`]) and every
//!    row writes only its own pre-split output slot (disjoint `&mut`
//!    views — the type system rules out write interleaving).
//! 2. Row `i`'s computation reads only row `i`'s inputs and the shared
//!    read-only weights, so scheduling order cannot reach the data.
//! 3. Cross-row reductions (gradients, loss stats) go through per-row
//!    partials folded on the calling thread in ascending row order —
//!    a fixed f32 reduction tree, independent of worker count.
//!
//! Hence pooled == serial byte-identity at ANY worker count: the same
//! property the e2e suite checks across device contexts, now also held
//! per-context for row workers. A worker count of 0 or 1 (or a batch of
//! one chunk) short-circuits to a plain serial loop on the caller's
//! thread — no spawn cost on the b=1 decode path.

use std::ops::Range;

use super::kernels::softmax_rows;
use super::model::{
    ce_row, clamp_tok, forward_block, grpo_row, sample_one, CeSums, GrpoRowIn, GrpoSums, Prepared,
    Scratch, SimGrads, SimModel,
};
use super::{N_GEN, T_PREFILL, V};

/// Split `rows` into at most `workers` contiguous ascending chunks,
/// sizes differing by at most one (earlier chunks take the remainder).
pub fn chunk_ranges(rows: usize, workers: usize) -> Vec<Range<usize>> {
    if rows == 0 {
        return Vec::new();
    }
    let k = workers.max(1).min(rows);
    let (base, extra) = (rows / k, rows % k);
    let mut out = Vec::with_capacity(k);
    let mut start = 0usize;
    for c in 0..k {
        let len = base + usize::from(c < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Split a flat `[rows * per_row]` buffer into per-chunk `&mut` views
/// matching `ranges` (which must be contiguous ascending from 0).
fn split_rows<'a, T>(
    mut buf: &'a mut [T],
    ranges: &[Range<usize>],
    per_row: usize,
) -> Vec<&'a mut [T]> {
    let mut out = Vec::with_capacity(ranges.len());
    for r in ranges {
        let (head, rest) = buf.split_at_mut((r.end - r.start) * per_row);
        out.push(head);
        buf = rest;
    }
    out
}

/// Run `f` once per chunk, each with its chunk's pre-split output slot
/// and a worker-private [`Scratch`]. One chunk runs inline on the
/// calling thread; more fan out over a `std::thread::scope` (auto-join,
/// panics propagate). Chunk/slot pairing is positional, so outputs land
/// in row order regardless of which worker finishes first.
fn dispatch<Out, F>(ranges: Vec<Range<usize>>, outs: Vec<Out>, f: F)
where
    Out: Send,
    F: Fn(Range<usize>, Out, &mut Scratch) + Sync,
{
    debug_assert_eq!(ranges.len(), outs.len());
    if ranges.len() <= 1 {
        let mut sc = Scratch::new();
        for (r, o) in ranges.into_iter().zip(outs) {
            f(r, o, &mut sc);
        }
        return;
    }
    std::thread::scope(|s| {
        let f = &f;
        for (r, o) in ranges.into_iter().zip(outs) {
            s.spawn(move || {
                let mut sc = Scratch::new();
                f(r, o, &mut sc);
            });
        }
    });
}

/// Inputs of one generate call (weights travel via [`SimModel`]).
pub struct GenInput<'a> {
    /// Prompt tokens, `[b, T_PREFILL]` row-major.
    pub tokens: &'a [i32],
    /// Prompt length per row, `[b]`.
    pub prompt_len: &'a [i32],
    /// Sampling uniforms, `[b, N_GEN]` row-major.
    pub uniforms: &'a [f32],
    /// Sampling temperature (<= 0 is greedy).
    pub temperature: f32,
}

/// Batched ancestral decode: all rows of a chunk advance in lockstep —
/// one [`forward_block`] per step over the chunk's current tokens, then
/// a per-row sample. Row `i` reads uniforms row `i` by GLOBAL index, so
/// chunking is invisible in the outputs.
pub fn generate(
    model: SimModel,
    b: usize,
    inp: &GenInput,
    workers: usize,
    out_tokens: &mut [i32],
    out_logp: &mut [f32],
) {
    debug_assert!(inp.tokens.len() >= b * T_PREFILL && inp.uniforms.len() >= b * N_GEN);
    debug_assert!(out_tokens.len() >= b * N_GEN && out_logp.len() >= b * N_GEN);
    let ranges = chunk_ranges(b, workers);
    let tok_slots = split_rows(out_tokens, &ranges, N_GEN);
    let lp_slots = split_rows(out_logp, &ranges, N_GEN);
    let outs: Vec<_> = tok_slots.into_iter().zip(lp_slots).collect();
    dispatch(ranges, outs, |range, (toks_out, lps_out), sc| {
        let prep = Prepared::new(model, false);
        let n = range.end - range.start;
        sc.ensure(n);
        for (bi, i) in range.clone().enumerate() {
            let p = (inp.prompt_len[i].max(1) as usize).min(T_PREFILL);
            sc.xs[bi] = clamp_tok(inp.tokens[i * T_PREFILL + p - 1]);
        }
        for t in 0..N_GEN {
            forward_block(&prep, sc, n);
            for (bi, i) in range.clone().enumerate() {
                let u = inp.uniforms[i * N_GEN + t];
                let (chosen, lp) = sample_one(
                    &sc.logits[bi * V..(bi + 1) * V],
                    inp.temperature,
                    u,
                    &mut sc.probs[bi * V..(bi + 1) * V],
                );
                toks_out[bi * N_GEN + t] = chosen as i32;
                lps_out[bi * N_GEN + t] = lp;
                sc.xs[bi] = chosen;
            }
        }
    });
}

/// Teacher-forced log-probs of every next-token in `[b, t_len]` rows:
/// each row's `t_len - 1` positions form one block (one forward, one
/// softmax sweep — the old per-position `mv()` path, de-allocated).
pub fn logprobs(
    model: SimModel,
    b: usize,
    t_len: usize,
    tokens: &[i32],
    workers: usize,
    out: &mut [f32],
) {
    debug_assert!(tokens.len() >= b * t_len && out.len() >= b * (t_len - 1));
    let ranges = chunk_ranges(b, workers);
    let outs = split_rows(out, &ranges, t_len - 1);
    dispatch(ranges, outs, |range, lp_out, sc| {
        let prep = Prepared::new(model, false);
        let np = t_len - 1;
        sc.ensure(np);
        for (bi, i) in range.clone().enumerate() {
            let row = &tokens[i * t_len..(i + 1) * t_len];
            for j in 0..np {
                sc.xs[j] = clamp_tok(row[j]);
            }
            forward_block(&prep, sc, np);
            softmax_rows(&sc.logits[..np * V], np, V, &mut sc.probs[..np * V]);
            for j in 0..np {
                let y = clamp_tok(row[j + 1]);
                lp_out[bi * np + j] = sc.probs[j * V + y].max(1e-30).ln();
            }
        }
    });
}

/// Full-weight masked-CE gradients over `[b, t_len]` rows (pretrain).
/// Returns the reduced gradients and `[loss, acc, entropy, mean_logp]`
/// (already `/ n`), reduced over per-row partials in ascending row order.
pub fn pretrain_grads(
    model: SimModel,
    b: usize,
    t_len: usize,
    tokens: &[i32],
    mask: &[f32],
    workers: usize,
) -> (SimGrads, [f32; 4]) {
    debug_assert!(tokens.len() >= b * t_len && mask.len() >= b * (t_len - 1));
    let n_total: f32 = mask.iter().sum::<f32>().max(1.0);
    let mut rows: Vec<(SimGrads, CeSums)> =
        (0..b).map(|_| (SimGrads::zeros(), CeSums::default())).collect();
    let ranges = chunk_ranges(b, workers);
    let slots = split_rows(&mut rows, &ranges, 1);
    dispatch(ranges, slots, |range, slot, sc| {
        let prep = Prepared::new(model, true);
        for (bi, i) in range.clone().enumerate() {
            let (grads, sums) = &mut slot[bi];
            *sums = ce_row(
                &prep,
                &tokens[i * t_len..(i + 1) * t_len],
                &mask[i * (t_len - 1)..(i + 1) * (t_len - 1)],
                n_total,
                sc,
                grads,
                true,
            );
        }
    });
    let mut grads = SimGrads::zeros();
    let mut sums = CeSums::default();
    for (g, s) in &rows {
        grads.add(g);
        sums.add(s);
    }
    let n = n_total;
    (grads, [sums.loss / n, sums.acc / n, sums.ent / n, sums.lp / n])
}

/// GRPO-only inputs of one adapter-gradient call.
pub struct GrpoParams<'a> {
    /// Behavior (rollout-time) log-probs, `[b, t_len - 1]`.
    pub behavior: &'a [f32],
    /// Group-relative advantage per row, `[b]`.
    pub advantages: &'a [f32],
    /// Importance-ratio truncation constant (0 disables clipping).
    pub clip_c: f32,
    /// k3 KL penalty coefficient.
    pub kl_coef: f32,
}

/// Adapter gradients through the merge (SFT masked-CE, or GRPO when
/// `grpo` is given): `model` is the already-merged model. Returns the
/// reduced weight-space gradients (mats only — the embedding sites are
/// skipped since only `project_dtheta(grads.mats)` consumes them) and
/// the 8-slot stats vector, both reduced in ascending row order.
pub fn adapter_grads(
    model: SimModel,
    b: usize,
    t_len: usize,
    tokens: &[i32],
    mask: &[f32],
    grpo: Option<&GrpoParams>,
    workers: usize,
) -> (SimGrads, Vec<f32>) {
    debug_assert!(tokens.len() >= b * t_len && mask.len() >= b * (t_len - 1));
    let n: f32 = mask.iter().sum::<f32>().max(1.0);
    let ranges = chunk_ranges(b, workers);
    match grpo {
        Some(g) => {
            let mut rows: Vec<(SimGrads, GrpoSums)> =
                (0..b).map(|_| (SimGrads::zeros(), GrpoSums::default())).collect();
            let slots = split_rows(&mut rows, &ranges, 1);
            dispatch(ranges, slots, |range, slot, sc| {
                let prep = Prepared::new(model, true);
                for (bi, i) in range.clone().enumerate() {
                    let gin = GrpoRowIn {
                        behavior: &g.behavior[i * (t_len - 1)..(i + 1) * (t_len - 1)],
                        adv: g.advantages[i],
                        clip_c: g.clip_c,
                        kl_coef: g.kl_coef,
                    };
                    let (grads, sums) = &mut slot[bi];
                    *sums = grpo_row(
                        &prep,
                        &tokens[i * t_len..(i + 1) * t_len],
                        &mask[i * (t_len - 1)..(i + 1) * (t_len - 1)],
                        &gin,
                        n,
                        sc,
                        grads,
                    );
                }
            });
            let mut grads = SimGrads::zeros();
            let mut s = GrpoSums::default();
            for (g, p) in &rows {
                grads.add(g);
                s.add(p);
            }
            let loss = s.pg / n + g.kl_coef * s.k3 / n;
            let stats = vec![
                loss,
                s.pg / n,
                s.k1 / n,
                s.k3 / n,
                s.rsum / n,
                s.clipped / n,
                s.ent / n,
                s.lp / n,
            ];
            (grads, stats)
        }
        None => {
            let mut rows: Vec<(SimGrads, CeSums)> =
                (0..b).map(|_| (SimGrads::zeros(), CeSums::default())).collect();
            let slots = split_rows(&mut rows, &ranges, 1);
            dispatch(ranges, slots, |range, slot, sc| {
                let prep = Prepared::new(model, true);
                for (bi, i) in range.clone().enumerate() {
                    let (grads, sums) = &mut slot[bi];
                    *sums = ce_row(
                        &prep,
                        &tokens[i * t_len..(i + 1) * t_len],
                        &mask[i * (t_len - 1)..(i + 1) * (t_len - 1)],
                        n,
                        sc,
                        grads,
                        false,
                    );
                }
            });
            let mut grads = SimGrads::zeros();
            let mut s = CeSums::default();
            for (g, p) in &rows {
                grads.add(g);
                s.add(p);
            }
            let stats =
                vec![s.loss / n, s.acc / n, 0.0, 0.0, 1.0, 0.0, s.ent / n, s.lp / n];
            (grads, stats)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::model::reference;
    use super::super::{merge_mats, project_dtheta, MATS, N_THETA, T_TRAIN};
    use super::*;
    use crate::util::Pcg64;

    /// Worker counts every differential case runs at (the e2e suite's
    /// device counts, reused as row-worker counts).
    const WORKER_COUNTS: [usize; 3] = [1, 2, 4];
    /// All baked generate geometries.
    const GEOMS: [usize; 4] = [1, 2, 4, 8];

    fn random_model_bufs(seed: u64) -> (Vec<f32>, [Vec<f32>; 7]) {
        let mut rng = Pcg64::new(seed);
        let embed = rng.normal_vec(V * 8, 0.1);
        let mats: [Vec<f32>; 7] =
            std::array::from_fn(|t| rng.normal_vec(MATS[t].1 * MATS[t].2, 0.3));
        (embed, mats)
    }

    fn model<'a>(embed: &'a [f32], mats: &'a [Vec<f32>; 7]) -> SimModel<'a> {
        SimModel { embed, mats: std::array::from_fn(|t| mats[t].as_slice()) }
    }

    fn bits_eq(a: &[f32], b: &[f32]) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    fn grads_bits_eq(a: &SimGrads, b: &SimGrads) -> bool {
        bits_eq(&a.embed_unembed, &b.embed_unembed)
            && bits_eq(&a.embed_input, &b.embed_input)
            && (0..7).all(|t| bits_eq(&a.mats[t], &b.mats[t]))
    }

    #[test]
    fn chunk_ranges_partition_rows() {
        assert!(chunk_ranges(0, 4).is_empty());
        for rows in 1..=9usize {
            for workers in 0..=5usize {
                let ranges = chunk_ranges(rows, workers);
                assert!(ranges.len() <= workers.max(1) && ranges.len() <= rows);
                assert_eq!(ranges[0].start, 0);
                assert_eq!(ranges.last().unwrap().end, rows);
                for w in ranges.windows(2) {
                    assert_eq!(w[0].end, w[1].start, "chunks must be contiguous ascending");
                }
                let sizes: Vec<usize> = ranges.iter().map(|r| r.end - r.start).collect();
                let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(mx - mn <= 1, "chunk sizes must differ by at most one");
            }
        }
    }

    /// Generate matches the scalar reference (and therefore itself at
    /// every worker count) bit-for-bit at every geometry × temperature.
    #[test]
    fn generate_matches_reference_at_all_geometries_and_workers() {
        let (embed, mats) = random_model_bufs(31);
        let m = model(&embed, &mats);
        let mut rng = Pcg64::new(32);
        for &b in &GEOMS {
            let tokens: Vec<i32> =
                (0..b * T_PREFILL).map(|_| rng.below(V as u64) as i32).collect();
            let plen: Vec<i32> =
                (0..b).map(|_| 1 + rng.below(T_PREFILL as u64) as i32).collect();
            let uniforms = rng.uniform_vec(b * N_GEN);
            for &temperature in &[1.0f32, 0.7, 0.0] {
                let inp = GenInput {
                    tokens: &tokens,
                    prompt_len: &plen,
                    uniforms: &uniforms,
                    temperature,
                };
                // scalar reference: per row, per step, fresh Vecs
                let mut want_toks = vec![0i32; b * N_GEN];
                let mut want_lps = vec![0.0f32; b * N_GEN];
                let mut probs = vec![0.0f32; V];
                for i in 0..b {
                    let p = (plen[i].max(1) as usize).min(T_PREFILL);
                    let mut last = tokens[i * T_PREFILL + p - 1];
                    for t in 0..N_GEN {
                        let (_, logits) = reference::forward_pos(&m, last);
                        let (chosen, lp) =
                            sample_one(&logits, temperature, uniforms[i * N_GEN + t], &mut probs);
                        want_toks[i * N_GEN + t] = chosen as i32;
                        want_lps[i * N_GEN + t] = lp;
                        last = chosen as i32;
                    }
                }
                for &w in &WORKER_COUNTS {
                    let mut got_toks = vec![0i32; b * N_GEN];
                    let mut got_lps = vec![0.0f32; b * N_GEN];
                    generate(m, b, &inp, w, &mut got_toks, &mut got_lps);
                    assert_eq!(got_toks, want_toks, "b={b} w={w} T={temperature}: tokens");
                    assert!(
                        bits_eq(&got_lps, &want_lps),
                        "b={b} w={w} T={temperature}: behavior log-probs diverge"
                    );
                }
            }
        }
    }

    #[test]
    fn logprobs_match_reference_at_all_geometries_and_workers() {
        let (embed, mats) = random_model_bufs(33);
        let m = model(&embed, &mats);
        let mut rng = Pcg64::new(34);
        for &b in &GEOMS {
            let tokens: Vec<i32> =
                (0..b * T_TRAIN).map(|_| rng.below(V as u64) as i32).collect();
            let mut want = vec![0.0f32; b * (T_TRAIN - 1)];
            for i in 0..b {
                for j in 0..T_TRAIN - 1 {
                    let (_, logits) = reference::forward_pos(&m, tokens[i * T_TRAIN + j]);
                    let probs = reference::softmax(&logits);
                    let y = clamp_tok(tokens[i * T_TRAIN + j + 1]);
                    want[i * (T_TRAIN - 1) + j] = probs[y].max(1e-30).ln();
                }
            }
            for &w in &WORKER_COUNTS {
                let mut got = vec![0.0f32; b * (T_TRAIN - 1)];
                logprobs(m, b, T_TRAIN, &tokens, w, &mut got);
                assert!(bits_eq(&got, &want), "b={b} w={w}: logprobs diverge from reference");
            }
        }
    }

    /// Random tokens AND a random sparse mask: the gather path (mask==0
    /// skip) must agree with the reference's skip exactly.
    #[test]
    fn pretrain_grads_match_reference_at_all_geometries_and_workers() {
        let (embed, mats) = random_model_bufs(35);
        let m = model(&embed, &mats);
        let mut rng = Pcg64::new(36);
        for &b in &GEOMS {
            let tokens: Vec<i32> =
                (0..b * T_TRAIN).map(|_| rng.below(V as u64) as i32).collect();
            let mask: Vec<f32> = (0..b * (T_TRAIN - 1))
                .map(|_| if rng.below(4) == 0 { 0.0 } else { 1.0 })
                .collect();
            let n: f32 = mask.iter().sum::<f32>().max(1.0);
            // reference: per-row partials, reduced ascending — the same
            // tree the engine commits to
            let mut want = SimGrads::zeros();
            let mut sums = CeSums::default();
            for i in 0..b {
                let mut g = SimGrads::zeros();
                let s = reference::ce_row_ref(
                    &m,
                    &tokens[i * T_TRAIN..(i + 1) * T_TRAIN],
                    &mask[i * (T_TRAIN - 1)..(i + 1) * (T_TRAIN - 1)],
                    n,
                    &mut g,
                    true,
                );
                want.add(&g);
                sums.add(&s);
            }
            let want_stats = [sums.loss / n, sums.acc / n, sums.ent / n, sums.lp / n];
            for &w in &WORKER_COUNTS {
                let (got, got_stats) = pretrain_grads(m, b, T_TRAIN, &tokens, &mask, w);
                assert!(grads_bits_eq(&got, &want), "b={b} w={w}: pretrain grads diverge");
                assert!(bits_eq(&got_stats, &want_stats), "b={b} w={w}: pretrain stats diverge");
            }
        }
    }

    /// GRPO adapter path: merged weights, ratio/clip/KL math, and the
    /// dtheta projection all bitwise-stable across geometries × workers.
    #[test]
    fn adapter_grads_match_reference_at_all_geometries_and_workers() {
        let (embed, mats) = random_model_bufs(37);
        let base = model(&embed, &mats);
        let mut rng = Pcg64::new(38);
        let theta = rng.normal_vec(N_THETA, 0.2);
        let merged = merge_mats(base.mats, &theta);
        let m = SimModel { embed: &embed, mats: std::array::from_fn(|t| merged[t].as_slice()) };
        for &b in &GEOMS {
            let tokens: Vec<i32> =
                (0..b * T_TRAIN).map(|_| rng.below(V as u64) as i32).collect();
            let mask: Vec<f32> = (0..b * (T_TRAIN - 1))
                .map(|_| if rng.below(5) == 0 { 0.0 } else { 1.0 })
                .collect();
            let behavior: Vec<f32> =
                (0..b * (T_TRAIN - 1)).map(|_| -rng.uniform() * 3.0).collect();
            let advantages: Vec<f32> = (0..b).map(|_| rng.uniform() - 0.5).collect();
            let (clip_c, kl_coef) = (2.0f32, 0.1f32);
            let n: f32 = mask.iter().sum::<f32>().max(1.0);

            let mut want = SimGrads::zeros();
            let mut s = GrpoSums::default();
            for i in 0..b {
                let gin = GrpoRowIn {
                    behavior: &behavior[i * (T_TRAIN - 1)..(i + 1) * (T_TRAIN - 1)],
                    adv: advantages[i],
                    clip_c,
                    kl_coef,
                };
                let mut g = SimGrads::zeros();
                let p = reference::grpo_row_ref(
                    &m,
                    &tokens[i * T_TRAIN..(i + 1) * T_TRAIN],
                    &mask[i * (T_TRAIN - 1)..(i + 1) * (T_TRAIN - 1)],
                    &gin,
                    n,
                    &mut g,
                );
                want.add(&g);
                s.add(&p);
            }
            let want_dtheta = project_dtheta(&want.mats);
            let want_loss = s.pg / n + kl_coef * s.k3 / n;

            let params = GrpoParams {
                behavior: &behavior,
                advantages: &advantages,
                clip_c,
                kl_coef,
            };
            for &w in &WORKER_COUNTS {
                let (got, stats) =
                    adapter_grads(m, b, T_TRAIN, &tokens, &mask, Some(&params), w);
                assert!(
                    (0..7).all(|t| bits_eq(&got.mats[t], &want.mats[t])),
                    "b={b} w={w}: grpo weight grads diverge"
                );
                let got_dtheta = project_dtheta(&got.mats);
                assert!(bits_eq(&got_dtheta, &want_dtheta), "b={b} w={w}: dtheta diverges");
                assert_eq!(stats[0].to_bits(), want_loss.to_bits(), "b={b} w={w}: loss");
                assert_eq!(stats.len(), 8);
            }
            // SFT path at the same geometry: workers must also be inert
            let base_stats: Vec<Vec<f32>> = WORKER_COUNTS
                .iter()
                .map(|&w| adapter_grads(m, b, T_TRAIN, &tokens, &mask, None, w).1)
                .collect();
            for sv in &base_stats[1..] {
                assert!(bits_eq(sv, &base_stats[0]), "b={b}: sft stats vary with workers");
            }
        }
    }
}
