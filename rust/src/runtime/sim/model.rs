//! The sim's tiny differentiable model, vectorized: forward/backward over
//! *blocks* of independent positions through a reusable [`Scratch`] arena
//! — zero per-position allocation — with logits, softmax and backprop
//! fused per block.
//!
//! The model is a char-bigram transformer block (DESIGN.md §10): each
//! position depends only on its own token and the weights, so a block of
//! positions (all active targets of one training row, or the current
//! token of every decode row in a chunk) is a plain `[n, D]` matrix that
//! flows through the [`kernels`](super::kernels) as batched matmuls.
//!
//! Determinism contract (DESIGN.md §11): every buffer is written by
//! kernels that accumulate in the canonical reduction order, and every
//! gradient tensor has exactly ONE accumulation site, so per-element
//! contributions arrive in ascending position order. The two embedding
//! roles (unembedding vs input lookup) would otherwise interleave at a
//! shared element — [`SimGrads`] therefore keeps them in separate buffers
//! and merges elementwise at the end. `reference` (behind `#[cfg(test)]`)
//! is a naive per-position scalar implementation of the same reduction
//! trees: the differential oracle the engine must match bit-for-bit.

#![allow(clippy::needless_range_loop)]

use std::sync::OnceLock;

use super::kernels::{
    matmul_acc, matmul_at_acc, scale_inplace, softmax_row, softmax_row_temp, softmax_rows,
    tanh_inplace, transpose,
};
use super::{D, F, GAIN, MATS, MERGE_SCALE, N_THETA, V};

/// Borrowed model weights: tied embedding + the seven adapted matrices
/// (owned variants hold merged copies).
#[derive(Clone, Copy)]
pub struct SimModel<'a> {
    /// Tied embedding, `[V, D]` row-major.
    pub embed: &'a [f32],
    /// The seven adapted matrices in manifest order (see `MATS`).
    pub mats: [&'a [f32]; 7],
}

/// Clamp a raw token id into the vocab (same clamp at every entry point).
pub fn clamp_tok(tok: i32) -> usize {
    (tok.max(0) as usize).min(V - 1)
}

// ---------------------------------------------------------------------------
// Prepared weights
// ---------------------------------------------------------------------------

/// A [`SimModel`] plus the derived layouts the kernels want: the
/// transposed embedding for the logit matmul and (when backprop will
/// run) transposed weight copies so every `x · Wᵀ` in backward becomes a
/// unit-stride [`matmul_acc`]. Built once per worker chunk, reused for
/// every block.
pub struct Prepared<'a> {
    /// The borrowed weights this was derived from.
    pub model: SimModel<'a>,
    /// `embedᵀ`, `[D, V]` — logits for a whole block in one matmul.
    embed_t: Vec<f32>,
    bwd: Option<PreparedBwd>,
}

/// Transposes used only by backward (q+k are summed before transposing:
/// backward needs `(Wq + Wk)ᵀ` as one matrix).
struct PreparedBwd {
    wqk_t: Vec<f32>,
    wv_t: Vec<f32>,
    wo_t: Vec<f32>,
    wup_t: Vec<f32>,
    wgate_t: Vec<f32>,
    wdown_t: Vec<f32>,
}

impl<'a> Prepared<'a> {
    /// Derive kernel layouts; `need_backward` controls whether the six
    /// backward transposes are built (decode/scoring paths skip them).
    pub fn new(model: SimModel<'a>, need_backward: bool) -> Self {
        let mut embed_t = vec![0.0f32; D * V];
        transpose(model.embed, V, D, &mut embed_t);
        let bwd = need_backward.then(|| {
            let [wq, wk, wv, wo, wup, wgate, wdown] = model.mats;
            let wqk: Vec<f32> = wq.iter().zip(wk).map(|(a, b)| a + b).collect();
            let mut p = PreparedBwd {
                wqk_t: vec![0.0f32; D * D],
                wv_t: vec![0.0f32; D * D],
                wo_t: vec![0.0f32; D * D],
                wup_t: vec![0.0f32; D * F],
                wgate_t: vec![0.0f32; D * F],
                wdown_t: vec![0.0f32; F * D],
            };
            transpose(&wqk, D, D, &mut p.wqk_t);
            transpose(wv, D, D, &mut p.wv_t);
            transpose(wo, D, D, &mut p.wo_t);
            transpose(wup, D, F, &mut p.wup_t);
            transpose(wgate, D, F, &mut p.wgate_t);
            transpose(wdown, F, D, &mut p.wdown_t);
            p
        });
        Self { model, embed_t, bwd }
    }
}

// ---------------------------------------------------------------------------
// Scratch arena
// ---------------------------------------------------------------------------

/// Grow-on-demand activation arena for one worker: every forward/backward
/// buffer for a block of up to `cap` positions, allocated once and reused
/// for the worker's whole chunk (the allocation-free replacement for the
/// old per-position `Acts::zeros()` / `mv()` Vec churn). Lifetime: one
/// `Scratch` per dispatch worker, rows and blocks stream through it.
#[derive(Default)]
pub struct Scratch {
    cap: usize,
    /// Input token per block row (gathered, already vocab-clamped).
    pub(super) xs: Vec<usize>,
    /// Target token per block row (training paths).
    pub(super) ys: Vec<usize>,
    /// Mask weight per block row (training paths).
    pub(super) ws: Vec<f32>,
    // forward activations, block-major [n, D] / [n, F] / [n, V]
    pub(super) h: Vec<f32>,
    pub(super) tnh: Vec<f32>,
    pub(super) vv: Vec<f32>,
    pub(super) att: Vec<f32>,
    pub(super) u: Vec<f32>,
    pub(super) tg: Vec<f32>,
    pub(super) pact: Vec<f32>,
    pub(super) mlp: Vec<f32>,
    pub(super) z: Vec<f32>,
    pub(super) zs: Vec<f32>,
    pub(super) logits: Vec<f32>,
    pub(super) probs: Vec<f32>,
    // backward adjoints
    pub(super) dlogits: Vec<f32>,
    pub(super) dz: Vec<f32>,
    pub(super) dh: Vec<f32>,
    pub(super) dvv: Vec<f32>,
    pub(super) dt: Vec<f32>,
    pub(super) ds: Vec<f32>,
    pub(super) dp: Vec<f32>,
    pub(super) du: Vec<f32>,
    pub(super) dg: Vec<f32>,
}

impl Scratch {
    /// An empty arena; buffers materialize on first [`Scratch::ensure`].
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow every buffer to hold an `n`-position block (never shrinks).
    pub fn ensure(&mut self, n: usize) {
        if n <= self.cap {
            return;
        }
        self.xs.resize(n, 0);
        self.ys.resize(n, 0);
        self.ws.resize(n, 0.0);
        for buf in [&mut self.h, &mut self.tnh, &mut self.vv, &mut self.att, &mut self.mlp] {
            buf.resize(n * D, 0.0);
        }
        for buf in [&mut self.z, &mut self.zs, &mut self.dz, &mut self.dh] {
            buf.resize(n * D, 0.0);
        }
        for buf in [&mut self.dvv, &mut self.dt, &mut self.ds] {
            buf.resize(n * D, 0.0);
        }
        for buf in [&mut self.u, &mut self.tg, &mut self.pact] {
            buf.resize(n * F, 0.0);
        }
        for buf in [&mut self.dp, &mut self.du, &mut self.dg] {
            buf.resize(n * F, 0.0);
        }
        for buf in [&mut self.logits, &mut self.probs, &mut self.dlogits] {
            buf.resize(n * V, 0.0);
        }
        self.cap = n;
    }
}

// ---------------------------------------------------------------------------
// Gradients
// ---------------------------------------------------------------------------

/// Accumulated gradients. The tied embedding appears in TWO independent
/// accumulation sites (unembedding outer product, input-row scatter);
/// keeping them in separate buffers is what gives every gradient element
/// a single site and therefore a position-ascending accumulation order
/// identical between the blocked engine and the scalar oracle. They are
/// merged elementwise by [`SimGrads::embed`] at output time.
pub struct SimGrads {
    /// d/d embed via the tied unembedding (`dlogitsᵀ · z`), `[V, D]`.
    pub embed_unembed: Vec<f32>,
    /// d/d embed via the input lookup (`dh` scattered to token rows).
    pub embed_input: Vec<f32>,
    /// d/d mats in manifest order.
    pub mats: [Vec<f32>; 7],
}

impl SimGrads {
    /// All-zero gradients at the sim's fixed shapes.
    pub fn zeros() -> Self {
        Self {
            embed_unembed: vec![0.0; V * D],
            embed_input: vec![0.0; V * D],
            mats: std::array::from_fn(|t| vec![0.0; MATS[t].1 * MATS[t].2]),
        }
    }

    /// `self += other`, fixed field order (embed sites, then mats 0..7) —
    /// the one reduction used to fold per-row gradients, always applied
    /// in ascending row order regardless of worker count.
    pub fn add(&mut self, other: &SimGrads) {
        for (a, b) in self.embed_unembed.iter_mut().zip(&other.embed_unembed) {
            *a += b;
        }
        for (a, b) in self.embed_input.iter_mut().zip(&other.embed_input) {
            *a += b;
        }
        for t in 0..7 {
            for (a, b) in self.mats[t].iter_mut().zip(&other.mats[t]) {
                *a += b;
            }
        }
    }

    /// The full tied-embedding gradient: unembedding + input sites,
    /// merged elementwise (the fixed final step of the reduction tree).
    pub fn embed(&self) -> Vec<f32> {
        self.embed_unembed.iter().zip(&self.embed_input).map(|(a, b)| a + b).collect()
    }
}

// ---------------------------------------------------------------------------
// Fused block forward / backward
// ---------------------------------------------------------------------------

/// Forward a block of `n` positions whose (clamped) tokens sit in
/// `sc.xs[..n]`: fills `sc.logits[..n*V]` plus every intermediate
/// backward needs. One kernel call per model stage; no allocation.
pub fn forward_block(prep: &Prepared, sc: &mut Scratch, n: usize) {
    sc.ensure(n);
    let m = &prep.model;
    let [wq, wk, _wv, wo, wup, wgate, wdown] = m.mats;
    for p in 0..n {
        let x = sc.xs[p];
        sc.h[p * D..(p + 1) * D].copy_from_slice(&m.embed[x * D..(x + 1) * D]);
    }
    // s = h·Wq + h·Wk (q-terms then k-terms, contraction ascending), tanh
    sc.tnh[..n * D].fill(0.0);
    matmul_acc(&sc.h[..n * D], wq, n, D, D, &mut sc.tnh[..n * D]);
    matmul_acc(&sc.h[..n * D], wk, n, D, D, &mut sc.tnh[..n * D]);
    tanh_inplace(&mut sc.tnh[..n * D]);
    sc.vv[..n * D].fill(0.0);
    matmul_acc(&sc.tnh[..n * D], m.mats[2], n, D, D, &mut sc.vv[..n * D]);
    sc.att[..n * D].fill(0.0);
    matmul_acc(&sc.vv[..n * D], wo, n, D, D, &mut sc.att[..n * D]);
    sc.u[..n * F].fill(0.0);
    matmul_acc(&sc.h[..n * D], wup, n, D, F, &mut sc.u[..n * F]);
    sc.tg[..n * F].fill(0.0);
    matmul_acc(&sc.h[..n * D], wgate, n, D, F, &mut sc.tg[..n * F]);
    tanh_inplace(&mut sc.tg[..n * F]);
    for i in 0..n * F {
        sc.pact[i] = sc.u[i] * sc.tg[i];
    }
    sc.mlp[..n * D].fill(0.0);
    matmul_acc(&sc.pact[..n * F], wdown, n, F, D, &mut sc.mlp[..n * D]);
    // z = (h + a) + m; logits = (GAIN·z) · embedᵀ with GAIN pre-folded
    for i in 0..n * D {
        sc.z[i] = (sc.h[i] + sc.att[i]) + sc.mlp[i];
        sc.zs[i] = GAIN * sc.z[i];
    }
    sc.logits[..n * V].fill(0.0);
    matmul_acc(&sc.zs[..n * D], &prep.embed_t, n, D, V, &mut sc.logits[..n * V]);
}

/// Backprop a block given `sc.dlogits[..n*V]` (dLoss/dlogits, pre-GAIN),
/// accumulating into `grads`. Exact adjoint of [`forward_block`], one
/// kernel call per stage; `sc.dlogits` is consumed (scaled in place).
/// `need_embed` skips both embedding sites — the adapter paths only ever
/// read `grads.mats` (dtheta projection), so the engine skips ~40% of
/// backward's work there.
pub fn backward_block(
    prep: &Prepared,
    sc: &mut Scratch,
    n: usize,
    grads: &mut SimGrads,
    need_embed: bool,
) {
    let bwd = prep.bwd.as_ref().expect("Prepared::new(_, true) required for backward");
    // tied unembedding: logits = (GAIN·z)·embedᵀ — fold GAIN once
    scale_inplace(&mut sc.dlogits[..n * V], GAIN);
    if need_embed {
        matmul_at_acc(&sc.dlogits[..n * V], &sc.z[..n * D], n, V, D, &mut grads.embed_unembed);
    }
    sc.dz[..n * D].fill(0.0);
    matmul_acc(&sc.dlogits[..n * V], prep.model.embed, n, V, D, &mut sc.dz[..n * D]);
    // z = h + a + m: dh starts as dz; dz doubles as dm and da below
    sc.dh[..n * D].copy_from_slice(&sc.dz[..n * D]);
    // m = p·Wdown
    sc.dp[..n * F].fill(0.0);
    matmul_acc(&sc.dz[..n * D], &bwd.wdown_t, n, D, F, &mut sc.dp[..n * F]);
    matmul_at_acc(&sc.pact[..n * F], &sc.dz[..n * D], n, F, D, &mut grads.mats[6]);
    // p = u ⊙ tanh(g)
    for i in 0..n * F {
        let r = sc.tg[i];
        sc.du[i] = sc.dp[i] * r;
        sc.dg[i] = sc.dp[i] * sc.u[i] * (1.0 - r * r);
    }
    // u = h·Wup ; g = h·Wgate
    matmul_at_acc(&sc.h[..n * D], &sc.du[..n * F], n, D, F, &mut grads.mats[4]);
    matmul_at_acc(&sc.h[..n * D], &sc.dg[..n * F], n, D, F, &mut grads.mats[5]);
    matmul_acc(&sc.du[..n * F], &bwd.wup_t, n, F, D, &mut sc.dh[..n * D]);
    matmul_acc(&sc.dg[..n * F], &bwd.wgate_t, n, F, D, &mut sc.dh[..n * D]);
    // a = vv·Wo
    sc.dvv[..n * D].fill(0.0);
    matmul_acc(&sc.dz[..n * D], &bwd.wo_t, n, D, D, &mut sc.dvv[..n * D]);
    matmul_at_acc(&sc.vv[..n * D], &sc.dz[..n * D], n, D, D, &mut grads.mats[3]);
    // vv = tanh(s)·Wv
    sc.dt[..n * D].fill(0.0);
    matmul_acc(&sc.dvv[..n * D], &bwd.wv_t, n, D, D, &mut sc.dt[..n * D]);
    matmul_at_acc(&sc.tnh[..n * D], &sc.dvv[..n * D], n, D, D, &mut grads.mats[2]);
    // s = h·Wq + h·Wk ; tanh
    for i in 0..n * D {
        let t = sc.tnh[i];
        sc.ds[i] = sc.dt[i] * (1.0 - t * t);
    }
    matmul_at_acc(&sc.h[..n * D], &sc.ds[..n * D], n, D, D, &mut grads.mats[0]);
    matmul_at_acc(&sc.h[..n * D], &sc.ds[..n * D], n, D, D, &mut grads.mats[1]);
    matmul_acc(&sc.ds[..n * D], &bwd.wqk_t, n, D, D, &mut sc.dh[..n * D]);
    // input embedding rows (position-ascending scatter)
    if need_embed {
        for p in 0..n {
            let x = sc.xs[p];
            let dst = &mut grads.embed_input[x * D..(x + 1) * D];
            let src = &sc.dh[p * D..(p + 1) * D];
            for j in 0..D {
                dst[j] += src[j];
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Row-level loss fns (one training row = one fused block)
// ---------------------------------------------------------------------------

/// Per-row masked-CE partial sums, reduced over rows in ascending order.
#[derive(Clone, Copy, Default)]
pub struct CeSums {
    /// Weighted negative log-likelihood sum.
    pub loss: f32,
    /// Weighted argmax-accuracy sum.
    pub acc: f32,
    /// Weighted entropy sum.
    pub ent: f32,
    /// Weighted log-prob sum.
    pub lp: f32,
}

impl CeSums {
    /// `self += other`, fixed field order.
    pub fn add(&mut self, o: &CeSums) {
        self.loss += o.loss;
        self.acc += o.acc;
        self.ent += o.ent;
        self.lp += o.lp;
    }
}

/// Per-row GRPO partial sums (field order is the reduction order).
#[derive(Clone, Copy, Default)]
pub struct GrpoSums {
    /// Truncated-importance policy-gradient sum.
    pub pg: f32,
    /// k1 KL estimator sum.
    pub k1: f32,
    /// k3 KL estimator sum.
    pub k3: f32,
    /// Importance-ratio sum.
    pub rsum: f32,
    /// Clip-event weight sum.
    pub clipped: f32,
    /// Weighted entropy sum.
    pub ent: f32,
    /// Weighted log-prob sum.
    pub lp: f32,
}

impl GrpoSums {
    /// `self += other`, fixed field order.
    pub fn add(&mut self, o: &GrpoSums) {
        self.pg += o.pg;
        self.k1 += o.k1;
        self.k3 += o.k3;
        self.rsum += o.rsum;
        self.clipped += o.clipped;
        self.ent += o.ent;
        self.lp += o.lp;
    }
}

/// Gather the active (mask != 0) positions of one training row into the
/// arena: fills `sc.xs/ys/ws[..na]` and returns `na`. Ascending position
/// order — the order every accumulation below inherits.
fn gather_row(tokens: &[i32], mask: &[f32], sc: &mut Scratch) -> usize {
    let t_len = tokens.len();
    sc.ensure(t_len - 1);
    let mut na = 0usize;
    for j in 0..t_len - 1 {
        let w = mask[j];
        if w == 0.0 {
            continue;
        }
        sc.xs[na] = clamp_tok(tokens[j]);
        sc.ys[na] = clamp_tok(tokens[j + 1]);
        sc.ws[na] = w;
        na += 1;
    }
    na
}

/// Masked-CE forward/backward of one row (pretrain and SFT), fused per
/// block: one forward, one softmax sweep, one backward. `n_total` is the
/// GLOBAL mask sum (normalization is batch-wide, computed by the caller).
pub(super) fn ce_row(
    prep: &Prepared,
    tokens: &[i32],
    mask: &[f32],
    n_total: f32,
    sc: &mut Scratch,
    grads: &mut SimGrads,
    need_embed: bool,
) -> CeSums {
    let na = gather_row(tokens, mask, sc);
    let mut sums = CeSums::default();
    if na == 0 {
        return sums;
    }
    forward_block(prep, sc, na);
    softmax_rows(&sc.logits[..na * V], na, V, &mut sc.probs[..na * V]);
    for p in 0..na {
        let (y, w) = (sc.ys[p], sc.ws[p]);
        let logits = &sc.logits[p * V..(p + 1) * V];
        let probs = &sc.probs[p * V..(p + 1) * V];
        let lp = probs[y].max(1e-30).ln();
        sums.loss += -w * lp;
        sums.lp += w * lp;
        sums.ent += w * entropy_of(probs);
        if argmax(logits) == y {
            sums.acc += w;
        }
        // dLoss/dlp = -w/n ; dlp/dlogits[v] = onehot - p
        let dl_dlp = -w / n_total;
        let dl = &mut sc.dlogits[p * V..(p + 1) * V];
        for v in 0..V {
            let onehot = if v == y { 1.0 } else { 0.0 };
            dl[v] = dl_dlp * (onehot - probs[v]);
        }
    }
    backward_block(prep, sc, na, grads, need_embed);
    sums
}

/// Per-row GRPO inputs (behavior log-probs aligned to the row's mask,
/// the row's advantage, and the step's clip/KL scalars).
pub(super) struct GrpoRowIn<'a> {
    pub behavior: &'a [f32],
    pub adv: f32,
    pub clip_c: f32,
    pub kl_coef: f32,
}

/// GRPO forward/backward of one row (truncated importance sampling),
/// fused per block like [`ce_row`]. Also needs the ORIGINAL position
/// index per active slot to index `behavior` — gather preserves it via
/// the mask scan being identical.
pub(super) fn grpo_row(
    prep: &Prepared,
    tokens: &[i32],
    mask: &[f32],
    gin: &GrpoRowIn,
    n_total: f32,
    sc: &mut Scratch,
    grads: &mut SimGrads,
) -> GrpoSums {
    let t_len = tokens.len();
    let mut sums = GrpoSums::default();
    // gather with original positions preserved in ys-order: reuse the
    // mask scan and stash behavior per active slot in ws-order
    sc.ensure(t_len - 1);
    let mut na = 0usize;
    for j in 0..t_len - 1 {
        if mask[j] == 0.0 {
            continue;
        }
        sc.xs[na] = clamp_tok(tokens[j]);
        sc.ys[na] = clamp_tok(tokens[j + 1]);
        sc.ws[na] = mask[j];
        // dt is free at gather time; borrow it to carry behavior lps
        sc.dt[na] = gin.behavior[j];
        na += 1;
    }
    if na == 0 {
        return sums;
    }
    forward_block(prep, sc, na);
    softmax_rows(&sc.logits[..na * V], na, V, &mut sc.probs[..na * V]);
    for p in 0..na {
        let (y, w) = (sc.ys[p], sc.ws[p]);
        let probs = &sc.probs[p * V..(p + 1) * V];
        let lp = probs[y].max(1e-30).ln();
        let beh = sc.dt[p];
        let ratio = (lp - beh).exp().min(1e6);
        let wt = if gin.clip_c > 0.0 { ratio.min(gin.clip_c) } else { ratio };
        sums.pg += -w * wt * gin.adv * lp;
        sums.k1 += w * (beh - lp);
        sums.k3 += w * (ratio - 1.0 - (lp - beh));
        sums.rsum += w * ratio;
        if gin.clip_c > 0.0 && ratio > gin.clip_c {
            sums.clipped += w;
        }
        sums.ent += w * entropy_of(probs);
        sums.lp += w * lp;
        // loss = pg/n + kl_coef * k3/n, importance weight stop-gradded:
        // dLoss/dlp = (-wt*adv + kl_coef*(ratio-1)) * w/n
        let dl_dlp = (-wt * gin.adv + gin.kl_coef * (ratio - 1.0)) * w / n_total;
        let dl = &mut sc.dlogits[p * V..(p + 1) * V];
        for v in 0..V {
            let onehot = if v == y { 1.0 } else { 0.0 };
            dl[v] = dl_dlp * (onehot - probs[v]);
        }
    }
    // adapter path: dtheta only reads mats grads — skip embedding sites
    backward_block(prep, sc, na, grads, false);
    sums
}

/// Sample one token from a logit row, replicating the pre-split scalar
/// semantics exactly: temperature <= 0 is greedy argmax (ties to the
/// lowest index, behavior lp at temperature 1); otherwise cumulative
/// sampling over the temperature-scaled softmax. Fills `probs`.
pub(super) fn sample_one(
    logits: &[f32],
    temperature: f32,
    u: f32,
    probs: &mut [f32],
) -> (usize, f32) {
    if temperature <= 0.0 {
        let best = argmax(logits);
        softmax_row(logits, probs);
        (best, probs[best].max(1e-30).ln())
    } else {
        softmax_row_temp(logits, temperature, probs);
        let mut cum = 0.0f32;
        let mut chosen = V - 1;
        for v in 0..V {
            cum += probs[v];
            if u < cum {
                chosen = v;
                break;
            }
        }
        (chosen, probs[chosen].max(1e-30).ln())
    }
}

/// Argmax with ties to the lowest index (the sim's greedy rule).
pub(super) fn argmax(logits: &[f32]) -> usize {
    let mut best = 0usize;
    for v in 1..logits.len() {
        if logits[v] > logits[best] {
            best = v;
        }
    }
    best
}

/// Shannon entropy of a probability row (ascending, fixed order).
pub(super) fn entropy_of(probs: &[f32]) -> f32 {
    -probs.iter().map(|&p| if p > 0.0 { p * p.ln() } else { 0.0 }).sum::<f32>()
}

// ---------------------------------------------------------------------------
// Merge + dtheta projection (the adapter's linear map)
// ---------------------------------------------------------------------------

/// Deterministic pseudo-factor direction phi(t, k, j) in [-0.5, 0.5]:
/// the fixed "frozen projection" the sim folds theta along. Mirrored by
/// the adapter gradients (exact chain rule through the merge).
pub fn pseudo_factor(t: usize, k: usize, j: usize) -> f32 {
    let mut h = 0x9e3779b97f4a7c15u64
        ^ (t as u64).wrapping_mul(0xa076_1d64_78bd_642f)
        ^ ((k as u64 + 1).wrapping_mul(0xe703_7ed1_a0b4_28db))
        ^ ((j as u64 + 1).wrapping_mul(0x8ebc_6af0_9c88_c6e3));
    h ^= h >> 29;
    h = h.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    h ^= h >> 32;
    ((h >> 40) as f32) * (1.0 / (1u64 << 24) as f32) - 0.5
}

/// Cached phi table, one `[elems(t) * N_THETA]` strip per matrix with k
/// contiguous (unit stride in both the merge and the projection). The
/// old code re-hashed phi(t,k,j) per element per call — 1152×13 hashes
/// on every merge AND every dtheta projection; now it's a lookup.
fn phi_table() -> &'static [Vec<f32>; 7] {
    static PHI: OnceLock<[Vec<f32>; 7]> = OnceLock::new();
    PHI.get_or_init(|| {
        std::array::from_fn(|t| {
            let n = MATS[t].1 * MATS[t].2;
            let mut v = vec![0.0f32; n * N_THETA];
            for j in 0..n {
                for k in 0..N_THETA {
                    v[j * N_THETA + k] = pseudo_factor(t, k, j);
                }
            }
            v
        })
    })
}

/// merged[t][j] = base[t][j] + MERGE_SCALE * sum_k theta[k] * phi(t,k,j).
/// Linear in theta and exactly identity at theta = 0 — every adapter
/// scheme starts at the base model, same as the real artifacts.
pub fn merge_mats(base: [&[f32]; 7], theta: &[f32]) -> [Vec<f32>; 7] {
    let phi = phi_table();
    std::array::from_fn(|t| {
        let mut out = base[t].to_vec();
        for (j, w) in out.iter_mut().enumerate() {
            let row = &phi[t][j * N_THETA..(j + 1) * N_THETA];
            let mut delta = 0.0f32;
            for (k, &th) in theta.iter().enumerate() {
                delta += th * row[k];
            }
            *w += MERGE_SCALE * delta;
        }
        out
    })
}

/// dL/dtheta[k] = MERGE_SCALE * sum_{t,j} dL/dW[t][j] * phi(t,k,j).
pub fn project_dtheta(dmats: &[Vec<f32>; 7]) -> Vec<f32> {
    let phi = phi_table();
    let mut dtheta = vec![0.0f32; N_THETA];
    for (t, dm) in dmats.iter().enumerate() {
        for (j, &dw) in dm.iter().enumerate() {
            if dw == 0.0 {
                continue;
            }
            let row = &phi[t][j * N_THETA..(j + 1) * N_THETA];
            for (k, dt) in dtheta.iter_mut().enumerate() {
                *dt += MERGE_SCALE * dw * row[k];
            }
        }
    }
    dtheta
}

// ---------------------------------------------------------------------------
// Scalar reference oracle (the differential ground truth)
// ---------------------------------------------------------------------------

/// Naive per-position scalar implementation of the SAME reduction trees
/// the blocked engine fixes — no kernels, no arena, no blocking, fresh
/// Vecs everywhere. Because blocking only groups independent output rows
/// and every gradient element has a single accumulation site, the
/// per-element f32 op sequence here is identical to the engine's, so the
/// differential tests in `exec` assert *bitwise* equality against this.
#[cfg(test)]
pub(super) mod reference {
    use super::*;

    /// Per-position activations (plain Vecs — deliberately naive).
    pub struct RefActs {
        pub x: usize,
        pub h: Vec<f32>,
        pub tnh: Vec<f32>,
        pub vv: Vec<f32>,
        pub u: Vec<f32>,
        pub tg: Vec<f32>,
        pub pact: Vec<f32>,
        pub z: Vec<f32>,
    }

    /// `out[j] += sum_i x[i] * w[i*d_out + j]`, contraction index outer —
    /// the scalar twin of a one-row `matmul_acc`.
    fn mv_acc(w: &[f32], x: &[f32], out: &mut [f32]) {
        let d_out = out.len();
        for (i, &xi) in x.iter().enumerate() {
            let row = &w[i * d_out..(i + 1) * d_out];
            for j in 0..d_out {
                out[j] += xi * row[j];
            }
        }
    }

    /// One position's forward, mirroring `forward_block` stage by stage.
    pub fn forward_pos(m: &SimModel, tok: i32) -> (RefActs, Vec<f32>) {
        let x = clamp_tok(tok);
        let h = m.embed[x * D..(x + 1) * D].to_vec();
        let [wq, wk, wv, wo, wup, wgate, wdown] = m.mats;
        let mut tnh = vec![0.0f32; D];
        mv_acc(wq, &h, &mut tnh);
        mv_acc(wk, &h, &mut tnh);
        for t in tnh.iter_mut() {
            *t = t.tanh();
        }
        let mut vv = vec![0.0f32; D];
        mv_acc(wv, &tnh, &mut vv);
        let mut att = vec![0.0f32; D];
        mv_acc(wo, &vv, &mut att);
        let mut u = vec![0.0f32; F];
        mv_acc(wup, &h, &mut u);
        let mut tg = vec![0.0f32; F];
        mv_acc(wgate, &h, &mut tg);
        for t in tg.iter_mut() {
            *t = t.tanh();
        }
        let pact: Vec<f32> = (0..F).map(|i| u[i] * tg[i]).collect();
        let mut mlp = vec![0.0f32; D];
        mv_acc(wdown, &pact, &mut mlp);
        let z: Vec<f32> = (0..D).map(|j| (h[j] + att[j]) + mlp[j]).collect();
        let zs: Vec<f32> = z.iter().map(|&v| GAIN * v).collect();
        // logits[v] += zs[j] * embed[v*D + j], j (contraction) outer
        let mut logits = vec![0.0f32; V];
        for j in 0..D {
            let zj = zs[j];
            for v in 0..V {
                logits[v] += zj * m.embed[v * D + j];
            }
        }
        (RefActs { x, h, tnh, vv, u, tg, pact, z }, logits)
    }

    /// One position's backward, mirroring `backward_block` stage by
    /// stage (including GAIN folding and the two-site embed split).
    pub fn backward_pos(
        m: &SimModel,
        acts: &RefActs,
        dlogits: &[f32],
        grads: &mut SimGrads,
        need_embed: bool,
    ) {
        let [wq, wk, wv, wo, wup, wgate, wdown] = m.mats;
        let dl: Vec<f32> = dlogits.iter().map(|&d| GAIN * d).collect();
        if need_embed {
            for v in 0..V {
                for j in 0..D {
                    grads.embed_unembed[v * D + j] += dl[v] * acts.z[j];
                }
            }
        }
        // dz[j] += dl[v] * embed[v*D + j], v (contraction) outer
        let mut dz = vec![0.0f32; D];
        for v in 0..V {
            let dv = dl[v];
            for j in 0..D {
                dz[j] += dv * m.embed[v * D + j];
            }
        }
        let mut dh = dz.clone();
        // m = p·Wdown: dp = dz·Wdownᵀ (contraction j outer), dWdown += pᵀ·dz
        let mut dp = vec![0.0f32; F];
        for j in 0..D {
            for i in 0..F {
                dp[i] += dz[j] * wdown[i * D + j];
            }
        }
        for i in 0..F {
            for j in 0..D {
                grads.mats[6][i * D + j] += acts.pact[i] * dz[j];
            }
        }
        // p = u ⊙ tanh(g)
        let mut du = vec![0.0f32; F];
        let mut dg = vec![0.0f32; F];
        for i in 0..F {
            let r = acts.tg[i];
            du[i] = dp[i] * r;
            dg[i] = dp[i] * acts.u[i] * (1.0 - r * r);
        }
        for i in 0..D {
            for j in 0..F {
                grads.mats[4][i * F + j] += acts.h[i] * du[j];
                grads.mats[5][i * F + j] += acts.h[i] * dg[j];
            }
        }
        // dh += du·Wupᵀ then dg·Wgateᵀ (two passes, like the two kernels)
        for j in 0..F {
            for i in 0..D {
                dh[i] += du[j] * wup[i * F + j];
            }
        }
        for j in 0..F {
            for i in 0..D {
                dh[i] += dg[j] * wgate[i * F + j];
            }
        }
        // a = vv·Wo
        let mut dvv = vec![0.0f32; D];
        for j in 0..D {
            for i in 0..D {
                dvv[i] += dz[j] * wo[i * D + j];
            }
        }
        for i in 0..D {
            for j in 0..D {
                grads.mats[3][i * D + j] += acts.vv[i] * dz[j];
            }
        }
        // vv = tanh(s)·Wv
        let mut dt = vec![0.0f32; D];
        for j in 0..D {
            for i in 0..D {
                dt[i] += dvv[j] * wv[i * D + j];
            }
        }
        for i in 0..D {
            for j in 0..D {
                grads.mats[2][i * D + j] += acts.tnh[i] * dvv[j];
            }
        }
        // s = h·Wq + h·Wk ; tanh
        let ds: Vec<f32> = (0..D).map(|j| dt[j] * (1.0 - acts.tnh[j] * acts.tnh[j])).collect();
        for i in 0..D {
            for j in 0..D {
                grads.mats[0][i * D + j] += acts.h[i] * ds[j];
            }
        }
        for i in 0..D {
            for j in 0..D {
                grads.mats[1][i * D + j] += acts.h[i] * ds[j];
            }
        }
        // dh += ds·(Wq+Wk)ᵀ, matching the summed-then-transposed kernel
        for j in 0..D {
            for i in 0..D {
                dh[i] += ds[j] * (wq[i * D + j] + wk[i * D + j]);
            }
        }
        if need_embed {
            for j in 0..D {
                grads.embed_input[acts.x * D + j] += dh[j];
            }
        }
    }

    /// Reference softmax with the kernel's exact op order.
    pub fn softmax(logits: &[f32]) -> Vec<f32> {
        let mut probs = vec![0.0f32; logits.len()];
        super::softmax_row(logits, &mut probs);
        probs
    }

    /// Reference masked-CE row: per-position forward/backward, same
    /// stats and dlogits math as `ce_row`, position-ascending.
    pub fn ce_row_ref(
        m: &SimModel,
        tokens: &[i32],
        mask: &[f32],
        n_total: f32,
        grads: &mut SimGrads,
        need_embed: bool,
    ) -> CeSums {
        let t_len = tokens.len();
        let mut sums = CeSums::default();
        for j in 0..t_len - 1 {
            let w = mask[j];
            if w == 0.0 {
                continue;
            }
            let (acts, logits) = forward_pos(m, tokens[j]);
            let probs = softmax(&logits);
            let y = clamp_tok(tokens[j + 1]);
            let lp = probs[y].max(1e-30).ln();
            sums.loss += -w * lp;
            sums.lp += w * lp;
            sums.ent += w * entropy_of(&probs);
            if argmax(&logits) == y {
                sums.acc += w;
            }
            let dl_dlp = -w / n_total;
            let mut dlogits = vec![0.0f32; V];
            for v in 0..V {
                let onehot = if v == y { 1.0 } else { 0.0 };
                dlogits[v] = dl_dlp * (onehot - probs[v]);
            }
            backward_pos(m, &acts, &dlogits, grads, need_embed);
        }
        sums
    }

    /// Reference GRPO row, mirroring `grpo_row`'s math per position.
    pub fn grpo_row_ref(
        m: &SimModel,
        tokens: &[i32],
        mask: &[f32],
        gin: &GrpoRowIn,
        n_total: f32,
        grads: &mut SimGrads,
    ) -> GrpoSums {
        let t_len = tokens.len();
        let mut sums = GrpoSums::default();
        for j in 0..t_len - 1 {
            let w = mask[j];
            if w == 0.0 {
                continue;
            }
            let (acts, logits) = forward_pos(m, tokens[j]);
            let probs = softmax(&logits);
            let y = clamp_tok(tokens[j + 1]);
            let lp = probs[y].max(1e-30).ln();
            let beh = gin.behavior[j];
            let ratio = (lp - beh).exp().min(1e6);
            let wt = if gin.clip_c > 0.0 { ratio.min(gin.clip_c) } else { ratio };
            sums.pg += -w * wt * gin.adv * lp;
            sums.k1 += w * (beh - lp);
            sums.k3 += w * (ratio - 1.0 - (lp - beh));
            sums.rsum += w * ratio;
            if gin.clip_c > 0.0 && ratio > gin.clip_c {
                sums.clipped += w;
            }
            sums.ent += w * entropy_of(&probs);
            sums.lp += w * lp;
            let dl_dlp = (-wt * gin.adv + gin.kl_coef * (ratio - 1.0)) * w / n_total;
            let mut dlogits = vec![0.0f32; V];
            for v in 0..V {
                let onehot = if v == y { 1.0 } else { 0.0 };
                dlogits[v] = dl_dlp * (onehot - probs[v]);
            }
            backward_pos(m, &acts, &dlogits, grads, false);
        }
        sums
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    pub(super) fn random_model_bufs(seed: u64) -> (Vec<f32>, [Vec<f32>; 7]) {
        let mut rng = Pcg64::new(seed);
        let embed = rng.normal_vec(V * D, 0.1);
        let mats: [Vec<f32>; 7] =
            std::array::from_fn(|t| rng.normal_vec(MATS[t].1 * MATS[t].2, 0.3));
        (embed, mats)
    }

    fn model<'a>(embed: &'a [f32], mats: &'a [Vec<f32>; 7]) -> SimModel<'a> {
        SimModel { embed, mats: std::array::from_fn(|t| mats[t].as_slice()) }
    }

    /// The blocked forward equals the scalar oracle bit-for-bit at every
    /// block size that occurs in practice (1 decode row .. 63 targets).
    #[test]
    fn forward_block_matches_reference_bitwise() {
        let (embed, mats) = random_model_bufs(21);
        let m = model(&embed, &mats);
        let prep = Prepared::new(m, false);
        let mut rng = Pcg64::new(22);
        for &n in &[1usize, 2, 4, 5, 8, 31, 63] {
            let toks: Vec<i32> = (0..n).map(|_| rng.below(V as u64) as i32).collect();
            let mut sc = Scratch::new();
            sc.ensure(n);
            for (p, &t) in toks.iter().enumerate() {
                sc.xs[p] = clamp_tok(t);
            }
            forward_block(&prep, &mut sc, n);
            for (p, &t) in toks.iter().enumerate() {
                let (_, want) = reference::forward_pos(&m, t);
                let got = &sc.logits[p * V..(p + 1) * V];
                let eq = got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(eq, "block n={n} pos {p}: vectorized logits != reference");
            }
        }
    }

    /// The blocked backward equals the scalar oracle bit-for-bit on every
    /// gradient tensor (both embed sites and all seven mats).
    #[test]
    fn backward_block_matches_reference_bitwise() {
        let (embed, mats) = random_model_bufs(23);
        let m = model(&embed, &mats);
        let prep = Prepared::new(m, true);
        let mut rng = Pcg64::new(24);
        let n = 17usize;
        let toks: Vec<i32> = (0..n).map(|_| rng.below(V as u64) as i32).collect();
        let dls: Vec<f32> = rng.normal_vec(n * V, 0.3);

        let mut sc = Scratch::new();
        sc.ensure(n);
        for (p, &t) in toks.iter().enumerate() {
            sc.xs[p] = clamp_tok(t);
        }
        forward_block(&prep, &mut sc, n);
        sc.dlogits[..n * V].copy_from_slice(&dls);
        let mut got = SimGrads::zeros();
        backward_block(&prep, &mut sc, n, &mut got, true);

        let mut want = SimGrads::zeros();
        for (p, &t) in toks.iter().enumerate() {
            let (acts, _) = reference::forward_pos(&m, t);
            reference::backward_pos(&m, &acts, &dls[p * V..(p + 1) * V], &mut want, true);
        }
        let pairs: Vec<(&[f32], &[f32], &str)> = vec![
            (&got.embed_unembed, &want.embed_unembed, "embed_unembed"),
            (&got.embed_input, &want.embed_input, "embed_input"),
        ];
        for (g, w, name) in pairs {
            assert!(
                g.iter().zip(w).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{name} grads diverge from reference"
            );
        }
        for t in 0..7 {
            let eq =
                got.mats[t].iter().zip(&want.mats[t]).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(eq, "mats[{t}] grads diverge from reference");
        }
    }

    /// The VECTORIZED backward matches central finite differences on
    /// every weight tensor — the re-check the rewrite must pass (the one
    /// test that keeps the whole sim gradient stack honest).
    #[test]
    fn backward_matches_finite_differences() {
        let (embed, mats) = random_model_bufs(5);
        let (x, y) = (7i32, 11usize);

        // CE loss of one position through the vectorized path
        let pos_loss = |embed: &[f32], mats: &[Vec<f32>; 7]| -> f32 {
            let m = SimModel {
                embed,
                mats: std::array::from_fn(|t| mats[t].as_slice()),
            };
            let prep = Prepared::new(m, false);
            let mut sc = Scratch::new();
            sc.ensure(1);
            sc.xs[0] = clamp_tok(x);
            forward_block(&prep, &mut sc, 1);
            let mut probs = vec![0.0f32; V];
            softmax_row(&sc.logits[..V], &mut probs);
            -probs[y].max(1e-30).ln()
        };

        // analytic gradient via the vectorized backward
        let m = model(&embed, &mats);
        let prep = Prepared::new(m, true);
        let mut sc = Scratch::new();
        sc.ensure(1);
        sc.xs[0] = clamp_tok(x);
        forward_block(&prep, &mut sc, 1);
        let mut probs = vec![0.0f32; V];
        softmax_row(&sc.logits[..V], &mut probs);
        for v in 0..V {
            let onehot = if v == y { 1.0 } else { 0.0 };
            sc.dlogits[v] = -(onehot - probs[v]); // dLoss/dlp = -1
        }
        let mut grads = SimGrads::zeros();
        backward_block(&prep, &mut sc, 1, &mut grads, true);
        let embed_grad = grads.embed();

        let eps = 1e-2f32;
        let mut rng = Pcg64::new(9);
        // spot-check a random sample of coordinates in every tensor
        for t in 0..8 {
            for _ in 0..20 {
                let (numeric, analytic) = if t == 0 {
                    // embed rows that matter: the input token and the target
                    let row = if rng.below(2) == 0 { x as usize } else { y };
                    let j = row * D + rng.below(D as u64) as usize;
                    let mut e2 = embed.clone();
                    e2[j] += eps;
                    let lp = pos_loss(&e2, &mats);
                    e2[j] -= 2.0 * eps;
                    let lm = pos_loss(&e2, &mats);
                    ((lp - lm) / (2.0 * eps), embed_grad[j])
                } else {
                    let mi = t - 1;
                    let j = rng.below(mats[mi].len() as u64) as usize;
                    let mut m2 = mats.clone();
                    m2[mi][j] += eps;
                    let lp = pos_loss(&embed, &m2);
                    m2[mi][j] -= 2.0 * eps;
                    let lm = pos_loss(&embed, &m2);
                    ((lp - lm) / (2.0 * eps), grads.mats[mi][j])
                };
                assert!(
                    (numeric - analytic).abs() <= 2e-3 + 0.05 * numeric.abs(),
                    "tensor {t}: finite diff {numeric} vs analytic {analytic}"
                );
            }
        }
    }

    #[test]
    fn merge_is_identity_at_zero_and_linear() {
        let (_, mats) = random_model_bufs(3);
        let base: [&[f32]; 7] = std::array::from_fn(|t| mats[t].as_slice());
        let zero = merge_mats(base, &[0.0; N_THETA]);
        for t in 0..7 {
            assert_eq!(zero[t], mats[t], "theta=0 must merge to the base exactly");
        }
        // linearity: merge(a) + merge(b) - base == merge(a + b)
        let mut rng = Pcg64::new(4);
        let ta: Vec<f32> = rng.normal_vec(N_THETA, 0.2);
        let tb: Vec<f32> = rng.normal_vec(N_THETA, 0.2);
        let tab: Vec<f32> = ta.iter().zip(&tb).map(|(a, b)| a + b).collect();
        let ma = merge_mats(base, &ta);
        let mb = merge_mats(base, &tb);
        let mab = merge_mats(base, &tab);
        for t in 0..7 {
            for j in 0..mats[t].len() {
                let sum = ma[t][j] + mb[t][j] - mats[t][j];
                assert!((sum - mab[t][j]).abs() < 1e-4, "merge not linear at ({t},{j})");
            }
        }
        // a non-trivial theta must actually move the weights
        assert!(ma.iter().zip(&mats).any(|(m, b)| m != b));
    }

    /// The cached phi table serves exactly the per-call hash values.
    #[test]
    fn phi_table_matches_pseudo_factor() {
        let phi = phi_table();
        for t in 0..7 {
            let n = MATS[t].1 * MATS[t].2;
            for j in [0, 1, n / 2, n - 1] {
                for k in 0..N_THETA {
                    assert_eq!(
                        phi[t][j * N_THETA + k].to_bits(),
                        pseudo_factor(t, k, j).to_bits(),
                        "phi table drift at ({t},{k},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn dtheta_projection_matches_merge_chain_rule() {
        // loss = sum_j W[t][j] * c[t][j] (linear in W) has dL/dW = c, so
        // dL/dtheta via the projection must equal the finite difference of
        // the merged loss — exact to f32 roundoff.
        let (_, mats) = random_model_bufs(6);
        let base: [&[f32]; 7] = std::array::from_fn(|t| mats[t].as_slice());
        let mut rng = Pcg64::new(7);
        let c: [Vec<f32>; 7] = std::array::from_fn(|t| rng.normal_vec(mats[t].len(), 1.0));
        let loss = |theta: &[f32]| -> f64 {
            let m = merge_mats(base, theta);
            (0..7)
                .map(|t| {
                    m[t].iter().zip(&c[t]).map(|(&w, &cc)| w as f64 * cc as f64).sum::<f64>()
                })
                .sum()
        };
        let dtheta = project_dtheta(&c);
        let mut theta = vec![0.0f32; N_THETA];
        for k in 0..N_THETA {
            let eps = 1e-2f32;
            theta[k] = eps;
            let lp = loss(&theta);
            theta[k] = -eps;
            let lm = loss(&theta);
            theta[k] = 0.0;
            let numeric = ((lp - lm) / (2.0 * eps as f64)) as f32;
            assert!(
                (numeric - dtheta[k]).abs() <= 1e-3 + 1e-3 * numeric.abs(),
                "theta[{k}]: finite diff {numeric} vs projected {}",
                dtheta[k]
            );
        }
    }
}
