//! The deterministic sim backend — a hermetic, pure-rust implementation
//! of every manifest entry point, so the full stack (engine → trainer →
//! serving → bench) runs end-to-end with ZERO artifacts on disk.
//!
//! What it is: a real (tiny) differentiable language model, not a mock.
//! One shared forward — a char-bigram transformer block over the seven
//! adapted matrices plus a tied embedding — backs `generate`, `logprobs`
//! and all three gradient entry points, hand-derived backprop included.
//! That sharing is load-bearing: rollout behavior log-probs equal the
//! training-side log-probs at the same weights (so GRPO's importance
//! ratios are exactly 1 at theta where the rollout ran, the same
//! invariant the real merged-weights trick provides), pretraining
//! genuinely descends its cross-entropy, and the merge entry point is
//! exactly the linear map the adapter gradients differentiate through.
//!
//! Since PR 5 the sim is the substrate every CI scenario runs on, so it
//! is also a measured hot path (`benches/bench_sim.rs` → `BENCH_SIM.json`).
//! The execution core is a vectorized, allocation-free, batch-parallel
//! engine split across three submodules (DESIGN.md §11):
//!
//! - [`kernels`] — blocked row-major matmul microkernels with a fixed,
//!   canonical per-element reduction order (blocked == naive bitwise);
//! - [`model`] — fused block forward/backward over a reusable [`Scratch`]
//!   arena (zero per-position allocation), plus merge/projection with a
//!   cached pseudo-factor table and a `#[cfg(test)]` scalar reference
//!   oracle the engine must match bit-for-bit;
//! - [`exec`] — batch rows dispatched across `std::thread::scope` row
//!   workers with pre-split output slots and ascending-row reduction, so
//!   pooled == serial byte-identity holds at any worker count by
//!   construction.
//!
//! This module keeps the backend plumbing: the synthetic manifest, the
//! `Backend`/`CompiledExe` impls, argument parsing, and fault injection.
//!
//! What it deliberately does NOT validate: HLO lowering, PJRT literal
//! layout/FFI, numerical parity with the python model. Those stay
//! artifact-gated (DESIGN.md §10 draws the line in detail).
//!
//! Determinism model: every entry point is a pure function of its
//! manifest-declared inputs — no clocks, no thread ids, no global RNG,
//! fixed f32 summation order. Row `i` of a batch depends only on row `i`'s
//! inputs and the weights, which is what makes sentinel padding inert and
//! pooled execution byte-identical to serial at any device count (and,
//! since the engine split, at any row-worker count).
//!
//! Fault injection ([`SimOptions`]): transient compile failures (to
//! exercise `SingleFlight`'s no-poison retry), per-context execute
//! delays (to prove worker/context timing skew cannot change results),
//! a per-row execute-time budget (tail-latency scenarios for
//! continuous-batching work, scaling with batch size), and — for the
//! chaos suite (`tests/chaos_sim.rs`, DESIGN.md §14) — scripted context
//! death (`die@ctxN:after=K`), hung executes, transient execute errors
//! and worker panics, all expressible as a compact CLI/env spec via
//! [`SimOptions::parse_faults`].

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::manifest::{
    ArgSpec, BatchGeometry, DType, ExeInfo, InitSpec, Manifest, SchemeInfo, ThetaSegment,
    TierInfo, Vocab, WeightSpec,
};
use crate::runtime::backend::{Backend, CompiledExe, ContextLost, HostTensor, TransientExecError};
use crate::tensor::{Arg, TensorF32, TensorI32};
use crate::tokenizer::{BOS, CHARS, EOS, PAD, VOCAB_SIZE};

pub mod exec;
pub mod kernels;
pub mod model;

pub use model::{merge_mats, project_dtheta, pseudo_factor, Scratch, SimGrads, SimModel};

/// The sim backbone tier name.
pub const SIM_TIER: &str = "sim";
/// The one adapter scheme the sim manifest bakes (the paper's headline
/// 13-parameter config, same tag as the real artifacts).
pub const SIM_SCHEME: &str = "tinylora_r2_u13_all";

/// Vocab size (the tokenizer's, = 64).
pub const V: usize = VOCAB_SIZE;
/// Model width.
pub const D: usize = 8;
/// MLP hidden width.
pub const F: usize = 16;
/// Layer count (the sim has one block).
pub const L: usize = 1;
/// Prompt window of the generate entry points.
pub const T_PREFILL: usize = 32;
/// Training sequence length.
pub const T_TRAIN: usize = 64;
/// Tokens generated per row per generate call.
pub const N_GEN: usize = 24;
/// Baked generate geometries (ascending; canonical = batch.roll = 8).
pub const GEOMETRIES: [usize; 4] = [1, 2, 4, 8];
/// Training/serving batch.
pub const BATCH_TRAIN: usize = 4;
/// Rollout batch.
pub const BATCH_ROLL: usize = 8;
/// Adapter parameter count (the paper's 13).
pub const N_THETA: usize = 13;
/// Stats slots every gradient entry point returns.
pub const N_STATS: usize = 8;

/// Logit gain: the tied-embedding bilinear form `z·E` is O(0.03) at init;
/// the gain lifts logits (and, via the chain rule, gradients) into a range
/// where sampling is non-degenerate and a few dozen Adam steps visibly
/// move the loss. Calibrated against the pretrain-descent test (30 Adam
/// steps at lr 3e-3 on one fixed corpus batch must cut CE ≥30%): on
/// corpus-like text the measured CE ratio is ~0.65 at gain 16 and ~0.60
/// at 24 — 24 keeps real margin without collapsing the initial sampling
/// distribution the way 32 starts to.
pub const GAIN: f32 = 24.0;
/// Scale of the pseudo-factor directions theta is folded in along.
pub const MERGE_SCALE: f32 = 0.05;

/// The seven adapted matrices, manifest order, with (d_in, d_out).
pub const MATS: [(&str, usize, usize); 7] = [
    ("attn_q", D, D),
    ("attn_k", D, D),
    ("attn_v", D, D),
    ("attn_o", D, D),
    ("mlp_up", D, F),
    ("mlp_gate", D, F),
    ("mlp_down", F, D),
];

// ---------------------------------------------------------------------------
// Synthetic manifest
// ---------------------------------------------------------------------------

fn f32_spec(name: &str, shape: &[usize]) -> ArgSpec {
    ArgSpec { name: name.to_string(), dtype: DType::F32, shape: shape.to_vec() }
}

fn i32_spec(name: &str, shape: &[usize]) -> ArgSpec {
    ArgSpec { name: name.to_string(), dtype: DType::S32, shape: shape.to_vec() }
}

fn weight_specs() -> Vec<WeightSpec> {
    let mut w = vec![WeightSpec {
        name: "embed".into(),
        shape: vec![V, D],
        init: InitSpec { kind: "normal".into(), std: 0.1 },
    }];
    for (name, din, dout) in MATS {
        w.push(WeightSpec {
            name: name.into(),
            shape: vec![L, din, dout],
            init: InitSpec { kind: "normal".into(), std: 0.3 },
        });
    }
    w
}

/// Weight argument specs in tier order (what `WeightSet::args` pushes).
fn weight_arg_specs() -> Vec<ArgSpec> {
    weight_specs().iter().map(|w| f32_spec(&w.name, &w.shape)).collect()
}

/// Frozen-factor argument specs (what `FactorSet::args` pushes: us/vf
/// interleaved per module at rank 2). The sim folds theta along its own
/// pseudo-factor directions and ignores these inputs, but the calling
/// convention must match the real adapter artifacts exactly.
fn factor_arg_specs() -> Vec<ArgSpec> {
    let r = 2usize;
    let mut specs = Vec::with_capacity(14);
    for (name, din, dout) in MATS {
        let module = name.rsplit('_').next().unwrap();
        specs.push(f32_spec(&format!("us_{module}"), &[L, din, r]));
        specs.push(f32_spec(&format!("vf_{module}"), &[L, dout, r]));
    }
    specs
}

fn sim_scheme() -> SchemeInfo {
    SchemeInfo {
        kind: "tinylora".into(),
        r: 2,
        u: N_THETA,
        tie: "all".into(),
        n_tie: 1,
        lora_alpha: 0.0,
    }
}

fn theta_segments() -> Vec<ThetaSegment> {
    vec![ThetaSegment {
        name: "theta".into(),
        shape: vec![N_THETA],
        offset: 0,
        len: N_THETA,
        init: InitSpec { kind: "zeros".into(), std: 0.0 },
    }]
}

/// The in-memory manifest the sim backend serves — same schema the PJRT
/// path parses from `artifacts/manifest.json`, so every layer above the
/// runtime is backend-blind. Entry points: fused `generate` at every
/// baked geometry, `grpo`/`sft` adapter grads, full-weight `pretrain`,
/// `logprobs`, and the adapter `merge`.
pub fn sim_manifest() -> Manifest {
    let weights = weight_specs();
    let mut module_dims = BTreeMap::new();
    for (name, din, dout) in MATS {
        module_dims.insert(name.rsplit('_').next().unwrap().to_string(), (din, dout));
    }
    let n_params: usize = weights.iter().map(|w| w.shape.iter().product::<usize>()).sum();
    let tier = TierInfo {
        name: SIM_TIER.into(),
        d: D,
        n_layers: L,
        n_heads: 2,
        f: F,
        t_max: T_TRAIN,
        t_prefill: T_PREFILL,
        t_train: T_TRAIN,
        head_dim: D / 2,
        n_params,
        weights,
        module_dims,
    };

    let mut executables = BTreeMap::new();
    for b in GEOMETRIES {
        let name = format!("sim_generate_b{b}");
        let mut inputs = weight_arg_specs();
        inputs.push(i32_spec("tokens", &[b, T_PREFILL]));
        inputs.push(i32_spec("prompt_len", &[b]));
        inputs.push(f32_spec("uniforms", &[b, N_GEN]));
        inputs.push(f32_spec("temperature", &[]));
        executables.insert(
            name.clone(),
            ExeInfo {
                name,
                file: String::new(),
                fn_kind: "generate".into(),
                tier: SIM_TIER.into(),
                batch: b,
                seq: N_GEN,
                use_pallas: false,
                inputs,
                outputs: vec![
                    i32_spec("tokens", &[b, N_GEN]),
                    f32_spec("behavior_logp", &[b, N_GEN]),
                ],
                scheme: None,
                scheme_tag: None,
                theta_size: None,
                theta_segments: Vec::new(),
                groups: Vec::new(),
            },
        );
    }

    let adapter_grad = |algo: &str, b: usize| -> ExeInfo {
        let mut inputs = weight_arg_specs();
        inputs.extend(factor_arg_specs());
        inputs.push(f32_spec("theta", &[N_THETA]));
        inputs.push(i32_spec("tokens", &[b, T_TRAIN]));
        inputs.push(f32_spec("mask", &[b, T_TRAIN - 1]));
        if algo == "grpo" {
            inputs.push(f32_spec("behavior", &[b, T_TRAIN - 1]));
            inputs.push(f32_spec("advantages", &[b]));
            inputs.push(f32_spec("clip_c", &[]));
            inputs.push(f32_spec("kl_coef", &[]));
        }
        ExeInfo {
            name: format!("sim_{algo}_tinylora_b{b}"),
            file: String::new(),
            fn_kind: algo.into(),
            tier: SIM_TIER.into(),
            batch: b,
            seq: T_TRAIN,
            use_pallas: false,
            inputs,
            outputs: vec![f32_spec("dtheta", &[N_THETA]), f32_spec("stats", &[N_STATS])],
            scheme: Some(sim_scheme()),
            scheme_tag: Some(SIM_SCHEME.into()),
            theta_size: Some(N_THETA),
            theta_segments: theta_segments(),
            groups: vec![0; L * 7],
        }
    };
    for b in [BATCH_TRAIN, BATCH_ROLL] {
        let e = adapter_grad("grpo", b);
        executables.insert(e.name.clone(), e);
    }
    let e = adapter_grad("sft", BATCH_TRAIN);
    executables.insert(e.name.clone(), e);

    {
        let b = BATCH_TRAIN;
        let mut inputs = weight_arg_specs();
        inputs.push(i32_spec("tokens", &[b, T_TRAIN]));
        inputs.push(f32_spec("mask", &[b, T_TRAIN - 1]));
        let mut outputs: Vec<ArgSpec> =
            weight_specs().iter().map(|w| f32_spec(&format!("d_{}", w.name), &w.shape)).collect();
        outputs.push(f32_spec("stats", &[N_STATS]));
        executables.insert(
            format!("sim_pretrain_b{b}"),
            ExeInfo {
                name: format!("sim_pretrain_b{b}"),
                file: String::new(),
                fn_kind: "pretrain".into(),
                tier: SIM_TIER.into(),
                batch: b,
                seq: T_TRAIN,
                use_pallas: false,
                inputs,
                outputs,
                scheme: None,
                scheme_tag: None,
                theta_size: None,
                theta_segments: Vec::new(),
                groups: Vec::new(),
            },
        );
    }

    {
        let b = BATCH_TRAIN;
        let mut inputs = weight_arg_specs();
        inputs.push(i32_spec("tokens", &[b, T_TRAIN]));
        executables.insert(
            format!("sim_logprobs_b{b}"),
            ExeInfo {
                name: format!("sim_logprobs_b{b}"),
                file: String::new(),
                fn_kind: "logprobs".into(),
                tier: SIM_TIER.into(),
                batch: b,
                seq: T_TRAIN,
                use_pallas: false,
                inputs,
                outputs: vec![f32_spec("logp", &[b, T_TRAIN - 1])],
                scheme: None,
                scheme_tag: None,
                theta_size: None,
                theta_segments: Vec::new(),
                groups: Vec::new(),
            },
        );
    }

    {
        let mut inputs: Vec<ArgSpec> =
            MATS.iter().map(|(name, din, dout)| f32_spec(name, &[L, *din, *dout])).collect();
        inputs.extend(factor_arg_specs());
        inputs.push(f32_spec("theta", &[N_THETA]));
        let outputs: Vec<ArgSpec> = MATS
            .iter()
            .map(|(name, din, dout)| f32_spec(&format!("merged_{name}"), &[L, *din, *dout]))
            .collect();
        executables.insert(
            "sim_merge_tinylora".into(),
            ExeInfo {
                name: "sim_merge_tinylora".into(),
                file: String::new(),
                fn_kind: "merge".into(),
                tier: SIM_TIER.into(),
                batch: 1,
                seq: 0,
                use_pallas: false,
                inputs,
                outputs,
                scheme: Some(sim_scheme()),
                scheme_tag: Some(SIM_SCHEME.into()),
                theta_size: Some(N_THETA),
                theta_segments: theta_segments(),
                groups: vec![0; L * 7],
            },
        );
    }

    Manifest {
        dir: PathBuf::from("<sim>"),
        vocab: Vocab { size: V, chars: CHARS.into(), pad: PAD, bos: BOS, eos: EOS },
        modules: MATS.iter().map(|(n, _, _)| n.rsplit('_').next().unwrap().to_string()).collect(),
        weight_names: weight_specs().iter().map(|w| w.name.clone()).collect(),
        n_stats: N_STATS,
        batch: BatchGeometry {
            roll: BATCH_ROLL,
            train: BATCH_TRAIN,
            serve: BATCH_TRAIN,
            test: BATCH_TRAIN,
        },
        tiers: BTreeMap::from([(SIM_TIER.to_string(), tier)]),
        executables,
    }
}

// ---------------------------------------------------------------------------
// Fault injection
// ---------------------------------------------------------------------------

/// Sim-only execution options, set at runtime construction
/// (`Runtime::sim_with`). All fields default to "no faults, serial rows".
/// Every fault field is also expressible as a compact spec string
/// (`--sim-faults` / `TINYLORA_SIM_FAULTS`, see [`SimOptions::parse_faults`])
/// so any chaos scenario is reproducible from the command line.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SimOptions {
    /// Fail the next N compiles (runtime-wide) with a transient error —
    /// exercises `SingleFlight`'s failure-is-not-cached retry path.
    pub fail_compiles: u32,
    /// Artificial per-execute delay in µs, keyed by context id (contexts
    /// beyond the vec's length get 0) — models a slow device and proves
    /// timing skew cannot change pooled results.
    pub ctx_delay_us: Vec<u64>,
    /// Row workers per execute call (0 or 1 = serial). A pure throughput
    /// knob: results are byte-identical at every value (`exec` module
    /// docs give the construction), so it is safe to turn up anywhere.
    pub row_workers: usize,
    /// Artificial per-ROW execute-time budget in microseconds: each call
    /// stalls `batch × budget` before computing, on top of `ctx_delay_us`.
    /// Models per-row tail latency (a slow sample, a long row) so
    /// continuous-batching scenarios can shape realistic latency
    /// distributions against the fast engine. Never changes results.
    pub row_budget_us: u64,
    /// Scripted context death: context `ctx` serves exactly `after`
    /// successful executes, then every later execute fails with the typed
    /// [`ContextLost`] marker forever (`after = 0` = dead on arrival).
    /// The supervisor quarantines the context and requeues the work.
    pub die_after_execs: BTreeMap<usize, u64>,
    /// Hung executes: every execute on context `ctx` stalls an extra
    /// `us` microseconds before returning a CORRECT result — a slow-to-
    /// pathological device the supervisor's exec deadline must catch.
    pub hang_execs_us: BTreeMap<usize, u64>,
    /// Fail the next N executes on context `ctx` with the typed
    /// [`TransientExecError`] marker (consumed per call) — exercises the
    /// supervisor's bounded retry-with-backoff.
    pub exec_failures: BTreeMap<usize, u32>,
    /// Panic the next N executes (runtime-wide) — exercises the worker
    /// pool's catch_unwind path: a panicking job must surface as that
    /// job's error, never stall the pool.
    pub panic_execs: u32,
}

impl SimOptions {
    /// Parse a `--sim-faults` / `TINYLORA_SIM_FAULTS` spec into options
    /// (non-fault fields stay default). Grammar: comma-separated clauses
    ///
    /// - `die@ctxN:after=K` — context N dies after K successful executes
    /// - `slow@ctxN:us=K` (or `ms=K`) — per-execute delay on context N
    /// - `hang@ctxN:us=K` (or `ms=K`) — hung executes on context N
    /// - `exec-fail@ctxN:n=K` — next K executes on context N fail transiently
    /// - `compile-fail=K` — next K compiles fail transiently (runtime-wide)
    /// - `panic=K` — next K executes panic (runtime-wide)
    ///
    /// Example: `die@ctx1:after=3,slow@ctx0:us=500,compile-fail=2`.
    /// Malformed specs are rejected with a clause-level error.
    pub fn parse_faults(spec: &str) -> Result<SimOptions> {
        let mut o = SimOptions::default();
        for raw in spec.split(',') {
            let clause = raw.trim();
            if clause.is_empty() {
                bail!("sim fault spec {spec:?}: empty clause");
            }
            if let Some((kind, rest)) = clause.split_once('@') {
                let Some((ctx_str, kv)) = rest.split_once(':') else {
                    bail!("sim fault clause {clause:?}: want kind@ctxN:key=value");
                };
                let ctx: usize = ctx_str
                    .strip_prefix("ctx")
                    .and_then(|n| n.parse().ok())
                    .ok_or_else(|| {
                        anyhow::anyhow!("sim fault clause {clause:?}: bad context {ctx_str:?} (want ctxN)")
                    })?;
                let Some((key, val)) = kv.split_once('=') else {
                    bail!("sim fault clause {clause:?}: want key=value after the context");
                };
                let v: u64 = val.trim().parse().map_err(|_| {
                    anyhow::anyhow!("sim fault clause {clause:?}: bad value {val:?}")
                })?;
                match (kind, key) {
                    ("die", "after") => {
                        o.die_after_execs.insert(ctx, v);
                    }
                    ("slow", "us") | ("slow", "ms") => {
                        let us = if key == "ms" { v.saturating_mul(1000) } else { v };
                        if o.ctx_delay_us.len() <= ctx {
                            o.ctx_delay_us.resize(ctx + 1, 0);
                        }
                        o.ctx_delay_us[ctx] = us;
                    }
                    ("hang", "us") | ("hang", "ms") => {
                        let us = if key == "ms" { v.saturating_mul(1000) } else { v };
                        o.hang_execs_us.insert(ctx, us);
                    }
                    ("exec-fail", "n") => {
                        let n = u32::try_from(v).map_err(|_| {
                            anyhow::anyhow!("sim fault clause {clause:?}: count too large")
                        })?;
                        o.exec_failures.insert(ctx, n);
                    }
                    _ => bail!("sim fault clause {clause:?}: unknown fault {kind:?} with key {key:?}"),
                }
            } else {
                let Some((key, val)) = clause.split_once('=') else {
                    bail!("sim fault clause {clause:?}: want key=value or kind@ctxN:key=value");
                };
                let v: u64 = val.trim().parse().map_err(|_| {
                    anyhow::anyhow!("sim fault clause {clause:?}: bad value {val:?}")
                })?;
                let n = u32::try_from(v).map_err(|_| {
                    anyhow::anyhow!("sim fault clause {clause:?}: count too large")
                })?;
                match key.trim() {
                    "compile-fail" => o.fail_compiles = n,
                    "panic" => o.panic_execs = n,
                    other => bail!("sim fault clause {clause:?}: unknown fault {other:?}"),
                }
            }
        }
        Ok(o)
    }

    /// Canonical spec string for the fault fields — `parse_faults`
    /// round-trips it exactly (for options with default non-fault
    /// fields). Empty when no faults are set.
    pub fn fault_spec(&self) -> String {
        let mut clauses: Vec<String> = Vec::new();
        for (ctx, after) in &self.die_after_execs {
            clauses.push(format!("die@ctx{ctx}:after={after}"));
        }
        for (ctx, us) in self.ctx_delay_us.iter().enumerate() {
            if *us > 0 {
                clauses.push(format!("slow@ctx{ctx}:us={us}"));
            }
        }
        for (ctx, us) in &self.hang_execs_us {
            clauses.push(format!("hang@ctx{ctx}:us={us}"));
        }
        for (ctx, n) in &self.exec_failures {
            clauses.push(format!("exec-fail@ctx{ctx}:n={n}"));
        }
        if self.fail_compiles > 0 {
            clauses.push(format!("compile-fail={}", self.fail_compiles));
        }
        if self.panic_execs > 0 {
            clauses.push(format!("panic={}", self.panic_execs));
        }
        clauses.join(",")
    }
}

/// Shared mutable fault state (one per runtime, shared by its contexts).
pub struct SimFaults {
    compile_failures: AtomicU32,
    panic_execs: AtomicU32,
    /// Successful executes per context — the clock scripted death reads.
    execs: Vec<AtomicU64>,
    /// Remaining injected transient execute failures per context.
    exec_failures: Vec<AtomicU32>,
}

impl SimFaults {
    /// `devices` sizes the per-context counters (one slot per context in
    /// the owning runtime).
    pub fn new(opts: &SimOptions, devices: usize) -> Self {
        let d = devices.max(1);
        Self {
            compile_failures: AtomicU32::new(opts.fail_compiles),
            panic_execs: AtomicU32::new(opts.panic_execs),
            execs: (0..d).map(|_| AtomicU64::new(0)).collect(),
            exec_failures: (0..d)
                .map(|i| AtomicU32::new(opts.exec_failures.get(&i).copied().unwrap_or(0)))
                .collect(),
        }
    }

    /// Consume one injected compile failure, if any remain.
    fn take_compile_failure(&self) -> bool {
        self.compile_failures
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1))
            .is_ok()
    }

    /// Injected compile failures not yet consumed (test introspection).
    pub fn pending_compile_failures(&self) -> u32 {
        self.compile_failures.load(Ordering::Relaxed)
    }

    /// Consume one injected execute panic, if any remain.
    fn take_panic(&self) -> bool {
        self.panic_execs
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1))
            .is_ok()
    }

    /// Consume one injected transient execute failure on `ctx`, if any.
    fn take_exec_failure(&self, ctx: usize) -> bool {
        self.exec_failures
            .get(ctx)
            .map(|c| c.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1)).is_ok())
            .unwrap_or(false)
    }

    fn record_exec(&self, ctx: usize) {
        if let Some(c) = self.execs.get(ctx) {
            c.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Successful executes served by `ctx` so far (test introspection and
    /// the scripted-death clock).
    pub fn execs_on(&self, ctx: usize) -> u64 {
        self.execs.get(ctx).map(|c| c.load(Ordering::Relaxed)).unwrap_or(0)
    }
}

// ---------------------------------------------------------------------------
// Backend plumbing
// ---------------------------------------------------------------------------

pub struct SimBackend {
    faults: Arc<SimFaults>,
    ctx_id: usize,
    delay_us: u64,
    hang_us: u64,
    die_after: Option<u64>,
    row_budget_us: u64,
    workers: usize,
}

impl SimBackend {
    /// One backend per execution context: `ctx_id` selects this context's
    /// per-ctx faults (delay, hang, scripted death) from `opts`.
    pub fn new(faults: Arc<SimFaults>, ctx_id: usize, opts: &SimOptions) -> Self {
        Self {
            faults,
            ctx_id,
            delay_us: opts.ctx_delay_us.get(ctx_id).copied().unwrap_or(0),
            hang_us: opts.hang_execs_us.get(&ctx_id).copied().unwrap_or(0),
            die_after: opts.die_after_execs.get(&ctx_id).copied(),
            row_budget_us: opts.row_budget_us,
            workers: opts.row_workers,
        }
    }
}

impl Backend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn platform(&self, _ffi: &Mutex<()>) -> String {
        "sim".into()
    }

    fn compile(
        &self,
        _art_dir: &Path,
        info: &ExeInfo,
        _ffi: &Mutex<()>,
    ) -> Result<Box<dyn CompiledExe>> {
        if self.faults.take_compile_failure() {
            bail!("injected sim compile failure for {} (transient)", info.name);
        }
        match info.fn_kind.as_str() {
            "generate" | "logprobs" | "pretrain" | "sft" | "grpo" | "merge" => {
                Ok(Box::new(SimExe {
                    faults: self.faults.clone(),
                    ctx_id: self.ctx_id,
                    delay_us: self.delay_us,
                    hang_us: self.hang_us,
                    die_after: self.die_after,
                    row_budget_us: self.row_budget_us,
                    workers: self.workers,
                }))
            }
            other => bail!("sim backend has no entry point kind {other:?}"),
        }
    }
}

struct SimExe {
    faults: Arc<SimFaults>,
    ctx_id: usize,
    delay_us: u64,
    hang_us: u64,
    die_after: Option<u64>,
    row_budget_us: u64,
    workers: usize,
}

impl CompiledExe for SimExe {
    fn execute(&self, info: &ExeInfo, args: &[Arg], _ffi: &Mutex<()>) -> Result<Vec<HostTensor>> {
        let ctx = self.ctx_id;
        // scripted death: once this context's budget of successful
        // executes is spent the context is gone for good — every later
        // call fails with the typed loss marker the supervisor
        // quarantines on. Checked first so a dead context cannot consume
        // transient-failure or panic budgets.
        if matches!(self.die_after, Some(after) if self.faults.execs_on(ctx) >= after) {
            return Err(anyhow::Error::new(ContextLost {
                ctx,
                reason: format!("injected sim context death before {}", info.name),
            }));
        }
        // injected transient execute failure (consumed per call): the
        // context survives, a bounded retry should succeed
        if self.faults.take_exec_failure(ctx) {
            return Err(anyhow::Error::new(TransientExecError {
                ctx,
                reason: format!("injected sim execute failure for {} (transient)", info.name),
            }));
        }
        // injected worker panic: must surface as the job's error via the
        // pool's catch_unwind, never stall the callers
        if self.faults.take_panic() {
            panic!("injected sim execute panic for {}", info.name);
        }
        // fault injection: a slow context, a hung execute, and/or per-row
        // latency (never a different result) — outputs are a pure
        // function of args, so skew cannot change them
        let stall_us = self.delay_us + self.hang_us + info.batch as u64 * self.row_budget_us;
        if stall_us > 0 {
            std::thread::sleep(std::time::Duration::from_micros(stall_us));
        }
        let w = self.workers;
        let out = match info.fn_kind.as_str() {
            "generate" => run_generate(info, args, w),
            "logprobs" => run_logprobs(info, args, w),
            "pretrain" => run_pretrain(info, args, w),
            "sft" => run_adapter_grad(info, args, false, w),
            "grpo" => run_adapter_grad(info, args, true, w),
            "merge" => run_merge(info, args),
            other => bail!("sim backend has no entry point kind {other:?}"),
        }?;
        self.faults.record_exec(ctx);
        Ok(out)
    }
}

fn f32s(args: &[Arg], i: usize) -> Result<&[f32]> {
    match &args[i] {
        Arg::F32(t) => Ok(&t.data),
        other => bail!("sim: arg {i} is not an f32 tensor ({other:?})"),
    }
}

fn i32s(args: &[Arg], i: usize) -> Result<&[i32]> {
    match &args[i] {
        Arg::I32(t) => Ok(&t.data),
        other => bail!("sim: arg {i} is not an s32 tensor ({other:?})"),
    }
}

fn scalar(args: &[Arg], i: usize) -> Result<f32> {
    match &args[i] {
        Arg::Scalar(x) => Ok(*x),
        Arg::F32(t) if t.data.len() == 1 => Ok(t.data[0]),
        other => bail!("sim: arg {i} is not a scalar ({other:?})"),
    }
}

fn out_f32(info: &ExeInfo, idx: usize, data: Vec<f32>) -> HostTensor {
    HostTensor::F32(TensorF32::from_vec(&info.outputs[idx].shape, data))
}

fn out_i32(info: &ExeInfo, idx: usize, data: Vec<i32>) -> HostTensor {
    HostTensor::I32(TensorI32::from_vec(&info.outputs[idx].shape, data))
}

fn model_from_args<'a>(args: &'a [Arg], base: usize) -> Result<SimModel<'a>> {
    Ok(SimModel {
        embed: f32s(args, base)?,
        mats: [
            f32s(args, base + 1)?,
            f32s(args, base + 2)?,
            f32s(args, base + 3)?,
            f32s(args, base + 4)?,
            f32s(args, base + 5)?,
            f32s(args, base + 6)?,
            f32s(args, base + 7)?,
        ],
    })
}

// ---------------------------------------------------------------------------
// Entry points (arg parsing → `exec` engine calls)
// ---------------------------------------------------------------------------

const N_WEIGHTS: usize = 8; // embed + 7 mats, tier order
const N_FACTORS: usize = 14; // us/vf per module (ignored, contract only)

fn run_generate(info: &ExeInfo, args: &[Arg], workers: usize) -> Result<Vec<HostTensor>> {
    let model = model_from_args(args, 0)?;
    let inp = exec::GenInput {
        tokens: i32s(args, N_WEIGHTS)?,
        prompt_len: i32s(args, N_WEIGHTS + 1)?,
        uniforms: f32s(args, N_WEIGHTS + 2)?,
        temperature: scalar(args, N_WEIGHTS + 3)?,
    };
    let b = info.batch;
    let mut out_tokens = vec![0i32; b * N_GEN];
    let mut out_logp = vec![0.0f32; b * N_GEN];
    exec::generate(model, b, &inp, workers, &mut out_tokens, &mut out_logp);
    Ok(vec![out_i32(info, 0, out_tokens), out_f32(info, 1, out_logp)])
}

fn run_logprobs(info: &ExeInfo, args: &[Arg], workers: usize) -> Result<Vec<HostTensor>> {
    let model = model_from_args(args, 0)?;
    let tokens = i32s(args, N_WEIGHTS)?;
    let b = info.batch;
    let mut out = vec![0.0f32; b * (T_TRAIN - 1)];
    exec::logprobs(model, b, T_TRAIN, tokens, workers, &mut out);
    Ok(vec![out_f32(info, 0, out)])
}

fn run_pretrain(info: &ExeInfo, args: &[Arg], workers: usize) -> Result<Vec<HostTensor>> {
    let model = model_from_args(args, 0)?;
    let tokens = i32s(args, N_WEIGHTS)?;
    let mask = f32s(args, N_WEIGHTS + 1)?;
    let (grads, [loss, acc, ent, mean_lp]) =
        exec::pretrain_grads(model, info.batch, T_TRAIN, tokens, mask, workers);
    let mut out = vec![out_f32(info, 0, grads.embed())];
    for (t, g) in grads.mats.into_iter().enumerate() {
        out.push(out_f32(info, t + 1, g));
    }
    let stats = vec![loss, acc, 0.0, 0.0, 0.0, 0.0, ent, mean_lp];
    out.push(out_f32(info, N_WEIGHTS, stats));
    Ok(out)
}

/// Adapter gradients (SFT CE or GRPO with truncated importance sampling),
/// differentiated through the same merge the `merge` entry point applies.
fn run_adapter_grad(
    info: &ExeInfo,
    args: &[Arg],
    grpo: bool,
    workers: usize,
) -> Result<Vec<HostTensor>> {
    let base = model_from_args(args, 0)?;
    let theta = f32s(args, N_WEIGHTS + N_FACTORS)?;
    let merged = merge_mats(base.mats, theta);
    let model = SimModel {
        embed: base.embed,
        mats: std::array::from_fn(|t| merged[t].as_slice()),
    };
    let idx = N_WEIGHTS + N_FACTORS + 1;
    let tokens = i32s(args, idx)?;
    let mask = f32s(args, idx + 1)?;
    let b = info.batch;

    let params;
    let grpo_params = if grpo {
        params = exec::GrpoParams {
            behavior: f32s(args, idx + 2)?,
            advantages: f32s(args, idx + 3)?,
            clip_c: scalar(args, idx + 4)?,
            kl_coef: scalar(args, idx + 5)?,
        };
        Some(&params)
    } else {
        None
    };
    let (grads, stats) = exec::adapter_grads(model, b, T_TRAIN, tokens, mask, grpo_params, workers);
    let dtheta = project_dtheta(&grads.mats);
    Ok(vec![out_f32(info, 0, dtheta), out_f32(info, 1, stats)])
}

fn run_merge(info: &ExeInfo, args: &[Arg]) -> Result<Vec<HostTensor>> {
    let base: [&[f32]; 7] = [
        f32s(args, 0)?,
        f32s(args, 1)?,
        f32s(args, 2)?,
        f32s(args, 3)?,
        f32s(args, 4)?,
        f32s(args, 5)?,
        f32s(args, 6)?,
    ];
    let theta = f32s(args, 7 + N_FACTORS)?;
    let merged = merge_mats(base, theta);
    Ok(merged.into_iter().enumerate().map(|(t, m)| out_f32(info, t, m)).collect())
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn random_model_bufs(seed: u64) -> (Vec<f32>, [Vec<f32>; 7]) {
        let mut rng = Pcg64::new(seed);
        let embed = rng.normal_vec(V * D, 0.1);
        let mats: [Vec<f32>; 7] =
            std::array::from_fn(|t| rng.normal_vec(MATS[t].1 * MATS[t].2, 0.3));
        (embed, mats)
    }

    /// Weight args + a generate arg tail for batch `b` (random prompts).
    fn gen_args(b: usize, seed: u64) -> Vec<Arg> {
        let (embed, mats) = random_model_bufs(seed);
        let mut args: Vec<Arg> = vec![Arg::F32(TensorF32::from_vec(&[V, D], embed))];
        for (t, (_, din, dout)) in MATS.iter().enumerate() {
            args.push(Arg::F32(TensorF32::from_vec(&[L, *din, *dout], mats[t].clone())));
        }
        let mut rng = Pcg64::new(seed + 1);
        let toks: Vec<i32> = (0..b * T_PREFILL).map(|_| rng.below(V as u64) as i32).collect();
        args.push(Arg::I32(TensorI32::from_vec(&[b, T_PREFILL], toks)));
        args.push(Arg::I32(TensorI32::from_vec(&[b], vec![2; b])));
        args.push(Arg::F32(TensorF32::from_vec(&[b, N_GEN], rng.uniform_vec(b * N_GEN))));
        args.push(Arg::Scalar(1.0));
        args
    }

    fn tensors_bits_eq(a: &[HostTensor], b: &[HostTensor]) -> bool {
        a.len() == b.len()
            && a.iter().zip(b).all(|(x, y)| match (x, y) {
                (HostTensor::F32(x), HostTensor::F32(y)) => {
                    x.data.iter().zip(&y.data).all(|(p, q)| p.to_bits() == q.to_bits())
                }
                (HostTensor::I32(x), HostTensor::I32(y)) => x.data == y.data,
                _ => false,
            })
    }

    #[test]
    fn sim_manifest_is_self_consistent() {
        let m = sim_manifest();
        assert_eq!(m.vocab.chars, CHARS);
        assert_eq!(m.vocab.size, VOCAB_SIZE);
        let tier = m.tier(SIM_TIER).unwrap();
        assert_eq!(tier.weights.len(), N_WEIGHTS);
        // every baked generate geometry resolves
        for b in GEOMETRIES {
            let e = m.generate_exe(SIM_TIER, b).unwrap();
            assert_eq!(e.seq, N_GEN);
            // inputs: weights + tokens + prompt_len + uniforms + temperature
            assert_eq!(e.inputs.len(), N_WEIGHTS + 4);
        }
        // grads + merge + logprobs resolve through the same lookups the
        // trainers use
        assert_eq!(m.grad_exe(SIM_TIER, "grpo", SIM_SCHEME).unwrap().theta_size, Some(N_THETA));
        assert_eq!(m.grad_exe(SIM_TIER, "sft", SIM_SCHEME).unwrap().theta_size, Some(N_THETA));
        m.merge_exe(SIM_TIER, SIM_SCHEME).unwrap();
        m.find("logprobs", |e| e.fn_kind == "logprobs").unwrap();
        m.find("pretrain", |e| e.fn_kind == "pretrain" && e.batch == m.batch.train).unwrap();
        // geometry invariants the engine depends on
        assert!(GEOMETRIES.windows(2).all(|w| w[0] < w[1]));
        assert!(GEOMETRIES.contains(&m.batch.test));
        assert!(GEOMETRIES.contains(&m.batch.roll));
    }

    #[test]
    fn generate_rows_are_independent_and_deterministic() {
        let m = sim_manifest();
        let info = m.generate_exe(SIM_TIER, 2).unwrap().clone();
        let (embed, mats) = random_model_bufs(11);
        let mut args: Vec<Arg> = vec![Arg::F32(TensorF32::from_vec(&[V, D], embed))];
        for (t, (_, din, dout)) in MATS.iter().enumerate() {
            args.push(Arg::F32(TensorF32::from_vec(&[L, *din, *dout], mats[t].clone())));
        }
        let mut toks = vec![PAD; 2 * T_PREFILL];
        toks[0] = BOS;
        toks[1] = 10;
        toks[T_PREFILL] = BOS;
        toks[T_PREFILL + 1] = 20;
        args.push(Arg::I32(TensorI32::from_vec(&[2, T_PREFILL], toks)));
        args.push(Arg::I32(TensorI32::from_vec(&[2], vec![2, 2])));
        let mut rng = Pcg64::new(2);
        let uni = rng.uniform_vec(2 * N_GEN);
        args.push(Arg::F32(TensorF32::from_vec(&[2, N_GEN], uni.clone())));
        args.push(Arg::Scalar(1.0));

        // run with 2 row workers: the wrapper path must be as
        // deterministic as the serial engine
        let run = |args: &[Arg]| -> (Vec<i32>, Vec<f32>) {
            let out = run_generate(&info, args, 2).unwrap();
            let toks = match &out[0] {
                HostTensor::I32(t) => t.data.clone(),
                _ => panic!("tokens output must be s32"),
            };
            let lps = match &out[1] {
                HostTensor::F32(t) => t.data.clone(),
                _ => panic!("behavior output must be f32"),
            };
            (toks, lps)
        };
        let (t1, l1) = run(&args);
        let (t2, _) = run(&args);
        assert_eq!(t1, t2, "generate must be deterministic");
        assert!(l1.iter().all(|&x| x <= 1e-6 && x.is_finite()), "log-probs must be <= 0");

        // perturb ONLY row 1's uniforms: row 0 must not change
        let mut uni2 = uni;
        for u in &mut uni2[N_GEN..] {
            *u = (*u + 0.37) % 1.0;
        }
        args[N_WEIGHTS + 2] = Arg::F32(TensorF32::from_vec(&[2, N_GEN], uni2));
        let (t3, _) = run(&args);
        assert_eq!(&t1[..N_GEN], &t3[..N_GEN], "row 0 depends on row 1's uniforms");
        assert_ne!(&t1[N_GEN..], &t3[N_GEN..], "row 1 must see its own uniforms");
    }

    #[test]
    fn fault_injection_consumes_compile_failures() {
        let opts = SimOptions { fail_compiles: 1, ..Default::default() };
        let faults = Arc::new(SimFaults::new(&opts, 1));
        let backend = SimBackend::new(faults.clone(), 0, &opts);
        let m = sim_manifest();
        let info = m.generate_exe(SIM_TIER, 1).unwrap();
        let ffi = Mutex::new(());
        let err = backend.compile(Path::new("<sim>"), info, &ffi);
        assert!(err.is_err(), "first compile must hit the injected failure");
        assert_eq!(faults.pending_compile_failures(), 0);
        assert!(backend.compile(Path::new("<sim>"), info, &ffi).is_ok(), "retry must succeed");
    }

    /// The per-row budget stalls the call by `batch × budget` (a lower
    /// bound — sleep never undershoots) without touching the outputs.
    #[test]
    fn row_budget_stalls_execute_without_changing_results() {
        let m = sim_manifest();
        let b = 4usize;
        let info = m.generate_exe(SIM_TIER, b).unwrap().clone();
        let args = gen_args(b, 51);
        let run_with = |budget_us: u64| -> (Vec<HostTensor>, f64) {
            let opts = SimOptions { row_budget_us: budget_us, ..Default::default() };
            let faults = Arc::new(SimFaults::new(&opts, 1));
            let backend = SimBackend::new(faults, 0, &opts);
            let ffi = Mutex::new(());
            let exe = backend.compile(Path::new("<sim>"), &info, &ffi).unwrap();
            let t = std::time::Instant::now();
            let out = exe.execute(&info, &args, &ffi).unwrap();
            (out, t.elapsed().as_secs_f64())
        };
        let (fast, _) = run_with(0);
        let (slow, secs) = run_with(2000);
        assert!(secs >= 0.008, "4 rows × 2ms budget must stall ≥ 8ms (got {secs}s)");
        assert!(tensors_bits_eq(&fast, &slow), "row budget must never change results");
    }

    /// Compile + execute on context `ctx` of a backend built from `opts`.
    fn exec_on(opts: &SimOptions, devices: usize, ctx: usize, args: &[Arg]) -> Result<Vec<HostTensor>> {
        let m = sim_manifest();
        let info = m.generate_exe(SIM_TIER, 4).unwrap().clone();
        let faults = Arc::new(SimFaults::new(opts, devices));
        let backend = SimBackend::new(faults, ctx, opts);
        let ffi = Mutex::new(());
        let exe = backend.compile(Path::new("<sim>"), &info, &ffi).unwrap();
        exe.execute(&info, args, &ffi)
    }

    #[test]
    fn scripted_death_kills_context_after_budgeted_execs() {
        let mut die = BTreeMap::new();
        die.insert(1usize, 2u64);
        let opts = SimOptions { die_after_execs: die, ..Default::default() };
        let m = sim_manifest();
        let info = m.generate_exe(SIM_TIER, 4).unwrap().clone();
        let args = gen_args(4, 33);
        let faults = Arc::new(SimFaults::new(&opts, 2));
        let ffi = Mutex::new(());
        // ctx 0 has no death scripted: executes forever
        let b0 = SimBackend::new(faults.clone(), 0, &opts);
        let e0 = b0.compile(Path::new("<sim>"), &info, &ffi).unwrap();
        for _ in 0..4 {
            e0.execute(&info, &args, &ffi).unwrap();
        }
        // ctx 1 serves exactly 2 executes, then is lost — permanently
        let b1 = SimBackend::new(faults.clone(), 1, &opts);
        let e1 = b1.compile(Path::new("<sim>"), &info, &ffi).unwrap();
        e1.execute(&info, &args, &ffi).unwrap();
        e1.execute(&info, &args, &ffi).unwrap();
        for _ in 0..2 {
            let err = e1.execute(&info, &args, &ffi).unwrap_err();
            let lost = err
                .chain()
                .any(|c| matches!(c.downcast_ref::<ContextLost>(), Some(l) if l.ctx == 1));
            assert!(lost, "death must carry the typed ContextLost marker: {err:#}");
        }
        assert_eq!(faults.execs_on(1), 2, "a dead context serves no more executes");
    }

    #[test]
    fn transient_exec_failures_are_consumed_then_results_match_clean_run() {
        let args = gen_args(4, 34);
        let clean = exec_on(&SimOptions::default(), 1, 0, &args).unwrap();
        let mut fail = BTreeMap::new();
        fail.insert(0usize, 1u32);
        let opts = SimOptions { exec_failures: fail, ..Default::default() };
        let m = sim_manifest();
        let info = m.generate_exe(SIM_TIER, 4).unwrap().clone();
        let faults = Arc::new(SimFaults::new(&opts, 1));
        let backend = SimBackend::new(faults, 0, &opts);
        let ffi = Mutex::new(());
        let exe = backend.compile(Path::new("<sim>"), &info, &ffi).unwrap();
        let err = exe.execute(&info, &args, &ffi).unwrap_err();
        assert!(
            err.chain().any(|c| c.downcast_ref::<TransientExecError>().is_some()),
            "injected failure must carry the typed transient marker: {err:#}"
        );
        let retried = exe.execute(&info, &args, &ffi).unwrap();
        assert!(tensors_bits_eq(&clean, &retried), "a retried execute must match the clean run");
    }

    #[test]
    fn fault_spec_round_trips_and_parses_the_documented_example() {
        // the README/ISSUE example spec parses into exactly these fields
        let o = SimOptions::parse_faults("die@ctx1:after=3,slow@ctx0:us=500,compile-fail=2").unwrap();
        assert_eq!(o.die_after_execs.get(&1), Some(&3));
        assert_eq!(o.ctx_delay_us, vec![500]);
        assert_eq!(o.fail_compiles, 2);

        // canonical form round-trips exactly
        let full = SimOptions {
            fail_compiles: 2,
            ctx_delay_us: vec![500, 0, 250],
            die_after_execs: BTreeMap::from([(1, 3), (2, 0)]),
            hang_execs_us: BTreeMap::from([(0, 30_000)]),
            exec_failures: BTreeMap::from([(3, 7)]),
            panic_execs: 1,
            ..Default::default()
        };
        let spec = full.fault_spec();
        assert_eq!(SimOptions::parse_faults(&spec).unwrap(), full, "round trip of {spec:?}");

        // ms sugar scales into µs
        let o = SimOptions::parse_faults("slow@ctx1:ms=2,hang@ctx0:ms=5").unwrap();
        assert_eq!(o.ctx_delay_us, vec![0, 2000]);
        assert_eq!(o.hang_execs_us.get(&0), Some(&5000));

        // no faults → empty spec
        assert_eq!(SimOptions::default().fault_spec(), "");
    }

    #[test]
    fn malformed_fault_specs_are_rejected() {
        for bad in [
            "",
            "die@ctx1",              // no key=value
            "die@one:after=3",       // bad context
            "die@ctx1:after=x",      // bad value
            "die@ctx1:n=3",          // wrong key for die
            "warp@ctx1:n=3",         // unknown per-ctx fault
            "compile-fail",          // no value
            "panics=1",              // unknown global fault
            "die@ctx1:after=3,,",    // empty trailing clause
            "exec-fail@ctx0:n=5000000000", // u32 overflow
        ] {
            assert!(
                SimOptions::parse_faults(bad).is_err(),
                "spec {bad:?} must be rejected"
            );
        }
    }
}
