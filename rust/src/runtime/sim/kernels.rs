//! Blocked, autovectorization-friendly microkernels over flat row-major
//! `&[f32]` buffers — the arithmetic substrate of the sim engine.
//!
//! Every kernel obeys one contract, the **canonical reduction order**:
//! each output element accumulates its contributions *in place*, in
//! ascending order of the contraction index, starting from whatever is
//! already in `out`. Blocking only ever groups *independent output rows*
//! (never the contraction dimension), so the per-element f32 operation
//! sequence is identical to the naive triple loop — blocked == naive
//! bit-for-bit, which is what lets `model::reference` (a plain scalar
//! oracle) pin the vectorized engine down to exact bits.
//!
//! Why this shape vectorizes: rustc will not reassociate floats, so a
//! sequential dot product (`acc += a[i]*b[i]`) compiles to a serial
//! dependency chain. All kernels here are therefore written as rank-1 /
//! axpy updates with unit-stride inner loops over *distinct* output
//! elements (`out[j] += x * b[j]`) — independent lanes the compiler can
//! turn into SIMD without changing any rounding. [`matmul_acc`] adds a
//! fixed-width `MR`-row accumulator block on top: four output rows share
//! one sweep over `b`, quartering traffic on the hot matrix.

/// Output-row block width of [`matmul_acc`]. Rows are independent, so
/// blocking over them cannot reorder any per-element accumulation.
const MR: usize = 4;

/// `out[m,n] += a[m,k] · b[k,n]`, all row-major. Contraction (`k`) runs
/// ascending per output element; `MR` output rows are processed per sweep
/// over `b` with a unit-stride inner loop over `n`.
pub fn matmul_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert!(a.len() >= m * k, "a too short");
    debug_assert!(b.len() >= k * n, "b too short");
    debug_assert!(out.len() >= m * n, "out too short");
    let mut i = 0;
    while i + MR <= m {
        let (o0, rest) = out[i * n..(i + MR) * n].split_at_mut(n);
        let (o1, rest) = rest.split_at_mut(n);
        let (o2, o3) = rest.split_at_mut(n);
        for p in 0..k {
            let br = &b[p * n..(p + 1) * n];
            let x0 = a[i * k + p];
            let x1 = a[(i + 1) * k + p];
            let x2 = a[(i + 2) * k + p];
            let x3 = a[(i + 3) * k + p];
            for j in 0..n {
                let bv = br[j];
                o0[j] += x0 * bv;
                o1[j] += x1 * bv;
                o2[j] += x2 * bv;
                o3[j] += x3 * bv;
            }
        }
        i += MR;
    }
    while i < m {
        let or = &mut out[i * n..(i + 1) * n];
        for p in 0..k {
            let br = &b[p * n..(p + 1) * n];
            let x = a[i * k + p];
            for j in 0..n {
                or[j] += x * br[j];
            }
        }
        i += 1;
    }
}

/// `out[k,n] += aᵀ[k,m] · b[m,n]` for row-major `a[m,k]`, `b[m,n]` — the
/// weight-gradient kernel (`dW += actsᵀ · dOut`). The contraction index
/// is `m` (block positions / batch rows) and runs ascending in the OUTER
/// loop: each position contributes one rank-1 update, so gradient
/// elements accumulate in position order — exactly the order a scalar
/// per-position backward produces.
pub fn matmul_at_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert!(a.len() >= m * k, "a too short");
    debug_assert!(b.len() >= m * n, "b too short");
    debug_assert!(out.len() >= k * n, "out too short");
    for p in 0..m {
        let ar = &a[p * k..(p + 1) * k];
        let br = &b[p * n..(p + 1) * n];
        for (i, &x) in ar.iter().enumerate() {
            let or = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                or[j] += x * br[j];
            }
        }
    }
}

/// `dst[cols,rows] = srcᵀ` for row-major `src[rows,cols]`. Used once per
/// `Prepared` model to turn backward's `x · Wᵀ` products into plain
/// [`matmul_acc`] calls with unit-stride inner loops.
pub fn transpose(src: &[f32], rows: usize, cols: usize, dst: &mut [f32]) {
    debug_assert!(src.len() >= rows * cols && dst.len() >= rows * cols);
    for r in 0..rows {
        let sr = &src[r * cols..(r + 1) * cols];
        for (c, &v) in sr.iter().enumerate() {
            dst[c * rows + r] = v;
        }
    }
}

/// In-place `x *= s` (GAIN folding in the fused logit/backprop path).
pub fn scale_inplace(xs: &mut [f32], s: f32) {
    for x in xs {
        *x *= s;
    }
}

/// In-place elementwise tanh (the smooth attention/gate nonlinearity).
pub fn tanh_inplace(xs: &mut [f32]) {
    for x in xs {
        *x = x.tanh();
    }
}

/// Max-subtracted softmax of one row, deterministic fixed order: max fold
/// ascending, exponentials ascending, sum ascending, then divide. Same
/// operation sequence as the pre-split scalar `softmax`.
pub fn softmax_row(logits: &[f32], probs: &mut [f32]) {
    debug_assert_eq!(logits.len(), probs.len());
    let mx = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    for (p, &l) in probs.iter_mut().zip(logits) {
        *p = (l - mx).exp();
    }
    let mut sum = 0.0f32;
    for &p in probs.iter() {
        sum += p;
    }
    for p in probs.iter_mut() {
        *p /= sum;
    }
}

/// [`softmax_row`] of `logits / temperature` without materializing the
/// scaled row: the division is recomputed in the max pass and the exp
/// pass (same bits both times), preserving the exact op sequence of the
/// scalar `softmax(&scaled)` it replaces.
pub fn softmax_row_temp(logits: &[f32], temperature: f32, probs: &mut [f32]) {
    debug_assert_eq!(logits.len(), probs.len());
    let mut mx = f32::NEG_INFINITY;
    for &l in logits {
        mx = mx.max(l / temperature);
    }
    for (p, &l) in probs.iter_mut().zip(logits) {
        *p = (l / temperature - mx).exp();
    }
    let mut sum = 0.0f32;
    for &p in probs.iter() {
        sum += p;
    }
    for p in probs.iter_mut() {
        *p /= sum;
    }
}

/// Block softmax: [`softmax_row`] applied to each of `rows` rows of width
/// `width` (rows are independent; no cross-row reduction exists).
pub fn softmax_rows(logits: &[f32], rows: usize, width: usize, probs: &mut [f32]) {
    debug_assert!(logits.len() >= rows * width && probs.len() >= rows * width);
    for r in 0..rows {
        softmax_row(&logits[r * width..(r + 1) * width], &mut probs[r * width..(r + 1) * width]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    /// Naive triple loop with the same per-element order (i, p-asc, j).
    fn naive_matmul_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
        for i in 0..m {
            for p in 0..k {
                for j in 0..n {
                    out[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
    }

    fn naive_at_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
        for p in 0..m {
            for i in 0..k {
                for j in 0..n {
                    out[i * n + j] += a[p * k + i] * b[p * n + j];
                }
            }
        }
    }

    /// The row-blocked kernel is bitwise equal to the naive triple loop
    /// at every block-remainder shape — the property the whole
    /// determinism argument leans on.
    #[test]
    fn blocked_matmul_matches_naive_bitwise() {
        let mut rng = Pcg64::new(41);
        for &(m, k, n) in &[(1, 8, 8), (3, 8, 16), (4, 16, 8), (7, 8, 64), (63, 8, 64)] {
            let a = rng.normal_vec(m * k, 1.0);
            let b = rng.normal_vec(k * n, 1.0);
            // non-zero starting accumulators: "+=" semantics must match too
            let init = rng.normal_vec(m * n, 0.1);
            let mut got = init.clone();
            let mut want = init.clone();
            matmul_acc(&a, &b, m, k, n, &mut got);
            naive_matmul_acc(&a, &b, m, k, n, &mut want);
            let eq = got.iter().zip(&want).all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(eq, "matmul_acc diverges from naive at ({m},{k},{n})");

            let bt = rng.normal_vec(m * n, 1.0);
            let mut got = vec![0.0f32; k * n];
            let mut want = vec![0.0f32; k * n];
            matmul_at_acc(&a, &bt, m, k, n, &mut got);
            naive_at_acc(&a, &bt, m, k, n, &mut want);
            let eq = got.iter().zip(&want).all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(eq, "matmul_at_acc diverges from naive at ({m},{k},{n})");
        }
    }

    #[test]
    fn transpose_round_trips() {
        let mut rng = Pcg64::new(42);
        let (r, c) = (7, 13);
        let src = rng.normal_vec(r * c, 1.0);
        let mut t = vec![0.0f32; r * c];
        let mut back = vec![0.0f32; r * c];
        transpose(&src, r, c, &mut t);
        transpose(&t, c, r, &mut back);
        assert_eq!(src, back);
        assert_eq!(t[3 * r + 2], src[2 * c + 3]);
    }

    /// softmax_rows rows are independent and each row matches the single
    /// row kernel bit-for-bit; temperature-1 equals the unscaled kernel.
    #[test]
    fn softmax_blocks_match_rows() {
        let mut rng = Pcg64::new(43);
        let (rows, w) = (5, 64);
        let logits = rng.normal_vec(rows * w, 3.0);
        let mut block = vec![0.0f32; rows * w];
        softmax_rows(&logits, rows, w, &mut block);
        for r in 0..rows {
            let mut one = vec![0.0f32; w];
            softmax_row(&logits[r * w..(r + 1) * w], &mut one);
            assert_eq!(one, block[r * w..(r + 1) * w]);
            let sum: f32 = one.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            let mut temp1 = vec![0.0f32; w];
            softmax_row_temp(&logits[r * w..(r + 1) * w], 1.0, &mut temp1);
            let eq = one.iter().zip(&temp1).all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(eq, "temperature-1 softmax must equal the unscaled kernel");
        }
    }
}
