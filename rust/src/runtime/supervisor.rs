//! The runtime supervision plane (DESIGN.md §14) — fault-tolerant
//! execution over the device-parallel context pool.
//!
//! Every context carries a health state:
//!
//! ```text
//!   Live ──deadline strike──▶ Suspect ──strikes ≥ limit──▶ Quarantined
//!    ▲                           │
//!    └──heal_successes in-deadline successes──┘
//!   any ──ContextLost──────────────────────────▶ Quarantined (final)
//! ```
//!
//! `Runtime::run` consults the supervisor on every dispatch: quarantined
//! contexts are skipped (ascending probe from the owning context),
//! typed [`TransientExecError`]s retry in place with bounded exponential
//! backoff, and typed [`ContextLost`] errors quarantine the context and
//! requeue the call onto a survivor. Requeue preserves byte-identity by
//! construction: every sim entry point is a pure function of its args
//! and jobs are seeded by `job_id`, not context identity, so re-running
//! an orphaned job on any surviving context yields the exact bytes the
//! dead context would have produced (the chaos suite asserts this at
//! D∈{2,4}, decode fingerprints and GRPO theta bits included).
//!
//! Hang detection is deadline-based and post-hoc: a successful execute
//! that overran `exec_deadline_ms` counts as a strike (the sim models a
//! hang as a long-but-finite stall; a true never-returns hang needs the
//! process boundary the ROADMAP's multi-process item adds on top of this
//! contract). Deadlines are off by default (`exec_deadline_ms = 0`) so
//! timing-sensitive policies are always opt-in — CI boxes are noisy.
//!
//! The supervisor never un-quarantines: context recovery means
//! constructing a fresh runtime. This is deliberately conservative — a
//! context that lied once about being alive cannot be trusted by a plane
//! whose whole guarantee is determinism.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::backend::{ContextLost, TransientExecError};

/// Per-context health state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Health {
    /// Serving normally.
    Live,
    /// Overran the execute deadline recently; still dispatched, healed by
    /// consecutive in-deadline successes.
    Suspect,
    /// Dead or struck out. Never dispatched again; work re-pins to
    /// survivors. Terminal.
    Quarantined,
}

/// Supervision policy knobs. `Default` is production-shaped: a couple of
/// in-place retries with millisecond backoff, deadlines off.
#[derive(Clone, Copy, Debug)]
pub struct SupervisorPolicy {
    /// In-place retries per call for transient execute errors (on top of
    /// the initial attempt). Exhaustion surfaces
    /// [`SupervisionError::RetriesExhausted`].
    pub max_retries: u32,
    /// First retry backoff; doubles per attempt (see [`Self::backoff_ms`]).
    pub backoff_base_ms: u64,
    /// Backoff ceiling.
    pub backoff_cap_ms: u64,
    /// Execute wall-clock deadline in ms; an overrun is a hang strike.
    /// 0 disables hang detection (the default — CI wall clocks are noisy,
    /// so deadline policies are opt-in per runtime).
    pub exec_deadline_ms: u64,
    /// Strikes until a Suspect context is quarantined.
    pub suspect_strikes: u32,
    /// Consecutive in-deadline successes that heal Suspect → Live.
    pub heal_successes: u32,
}

impl Default for SupervisorPolicy {
    fn default() -> Self {
        Self {
            max_retries: 2,
            backoff_base_ms: 1,
            backoff_cap_ms: 50,
            exec_deadline_ms: 0,
            suspect_strikes: 2,
            heal_successes: 2,
        }
    }
}

impl SupervisorPolicy {
    /// Backoff before retry `attempt` (1-based): `base × 2^(attempt−1)`,
    /// capped. The policy table in DESIGN.md §14 is this function.
    pub fn backoff_ms(&self, attempt: u32) -> u64 {
        let shift = attempt.saturating_sub(1).min(16);
        self.backoff_base_ms.saturating_mul(1u64 << shift).min(self.backoff_cap_ms)
    }
}

/// Monotonic supervision counters (runtime-wide), snapshotted by
/// [`Supervisor::stats`] and logged via `metrics::RunLog::log_supervisor`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SupervisorStats {
    /// In-place retries taken for transient execute errors.
    pub retries: u64,
    /// Dispatches re-pinned from a quarantined owner to a survivor.
    pub requeues: u64,
    /// Contexts quarantined (by loss or by striking out).
    pub quarantines: u64,
    /// Contexts lost outright (`ContextLost` observed).
    pub deaths: u64,
    /// Execute-deadline overruns observed (hang strikes).
    pub hangs: u64,
}

/// How an observed error should be handled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The context is gone: quarantine it and requeue on a survivor.
    ContextLost,
    /// The context survives: retry in place with backoff.
    Transient,
    /// Neither marker present: a real error — surface it unchanged.
    Fatal,
}

/// Classify an error by walking its chain for the typed fault markers
/// (backends may wrap them in arbitrary context layers).
pub fn classify(err: &anyhow::Error) -> FaultKind {
    for cause in err.chain() {
        if cause.downcast_ref::<ContextLost>().is_some() {
            return FaultKind::ContextLost;
        }
        if cause.downcast_ref::<TransientExecError>().is_some() {
            return FaultKind::Transient;
        }
    }
    FaultKind::Fatal
}

/// Typed terminal supervision errors — what callers see when recovery is
/// impossible, distinguishable from backend errors by downcast.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SupervisionError {
    /// A transient execute error persisted past the retry budget.
    RetriesExhausted { ctx: usize, attempts: u32, last: String },
    /// Every context is quarantined; nothing can serve the call.
    NoLiveContexts { quarantined: usize },
}

impl fmt::Display for SupervisionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SupervisionError::RetriesExhausted { ctx, attempts, last } => write!(
                f,
                "context {ctx}: transient execute error persisted after {attempts} attempts: {last}"
            ),
            SupervisionError::NoLiveContexts { quarantined } => {
                write!(f, "no live execution contexts ({quarantined} quarantined)")
            }
        }
    }
}

impl std::error::Error for SupervisionError {}

struct CtxHealth {
    health: Health,
    /// Deadline strikes since the last heal.
    strikes: u32,
    /// Consecutive in-deadline successes (heals Suspect).
    streak: u32,
}

/// Health state + counters for one runtime's context pool. All methods
/// take `&self` (per-context mutexes + atomics), matching the runtime's
/// share-everywhere concurrency model.
pub struct Supervisor {
    policy: SupervisorPolicy,
    states: Vec<Mutex<CtxHealth>>,
    retries: AtomicU64,
    requeues: AtomicU64,
    quarantines: AtomicU64,
    deaths: AtomicU64,
    hangs: AtomicU64,
}

impl Supervisor {
    pub fn new(contexts: usize, policy: SupervisorPolicy) -> Self {
        let n = contexts.max(1);
        Self {
            policy,
            states: (0..n)
                .map(|_| Mutex::new(CtxHealth { health: Health::Live, strikes: 0, streak: 0 }))
                .collect(),
            retries: AtomicU64::new(0),
            requeues: AtomicU64::new(0),
            quarantines: AtomicU64::new(0),
            deaths: AtomicU64::new(0),
            hangs: AtomicU64::new(0),
        }
    }

    pub fn policy(&self) -> &SupervisorPolicy {
        &self.policy
    }

    /// Dispatch target for work owned by `preferred`: the owner when it
    /// is not quarantined, else the first non-quarantined context probing
    /// upward (wrapping) — deterministic, so re-pinned work lands
    /// identically across reruns with the same quarantine set.
    pub fn resolve(&self, preferred: usize) -> anyhow::Result<usize> {
        let n = self.states.len();
        let start = preferred % n;
        for k in 0..n {
            let i = (start + k) % n;
            if self.health(i) != Health::Quarantined {
                return Ok(i);
            }
        }
        Err(anyhow::Error::new(SupervisionError::NoLiveContexts { quarantined: n }))
    }

    /// Record a successful execute on `ctx` that took `elapsed_ms`.
    /// With deadlines enabled, an overrun is a hang strike (Suspect, then
    /// Quarantined at `suspect_strikes`); in-deadline successes heal a
    /// Suspect context after `heal_successes` in a row.
    pub fn observe_success(&self, ctx: usize, elapsed_ms: f64) {
        if self.policy.exec_deadline_ms == 0 {
            return;
        }
        let mut st = self.states[ctx % self.states.len()].lock().unwrap();
        if st.health == Health::Quarantined {
            return; // a pre-quarantine straggler finishing late
        }
        if elapsed_ms > self.policy.exec_deadline_ms as f64 {
            self.hangs.fetch_add(1, Ordering::Relaxed);
            st.streak = 0;
            st.strikes += 1;
            if st.strikes >= self.policy.suspect_strikes {
                st.health = Health::Quarantined;
                self.quarantines.fetch_add(1, Ordering::Relaxed);
            } else {
                st.health = Health::Suspect;
            }
        } else {
            st.streak += 1;
            if st.health == Health::Suspect && st.streak >= self.policy.heal_successes {
                st.health = Health::Live;
                st.strikes = 0;
            }
        }
    }

    /// Record a failed execute on `ctx` and classify it. A loss
    /// quarantines the context (once — concurrent observers race benignly
    /// under the state lock).
    pub fn observe_error(&self, ctx: usize, err: &anyhow::Error) -> FaultKind {
        let kind = classify(err);
        if kind == FaultKind::ContextLost {
            let mut st = self.states[ctx % self.states.len()].lock().unwrap();
            if st.health != Health::Quarantined {
                st.health = Health::Quarantined;
                self.deaths.fetch_add(1, Ordering::Relaxed);
                self.quarantines.fetch_add(1, Ordering::Relaxed);
            }
        }
        kind
    }

    /// Manually quarantine `ctx` (operator action / tests).
    pub fn quarantine(&self, ctx: usize) {
        let mut st = self.states[ctx % self.states.len()].lock().unwrap();
        if st.health != Health::Quarantined {
            st.health = Health::Quarantined;
            self.quarantines.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Count one in-place transient retry.
    pub fn note_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one dispatch re-pinned off a quarantined owner.
    pub fn note_requeue(&self) {
        self.requeues.fetch_add(1, Ordering::Relaxed);
    }

    pub fn health(&self, ctx: usize) -> Health {
        self.states[ctx % self.states.len()].lock().unwrap().health
    }

    pub fn healths(&self) -> Vec<Health> {
        (0..self.states.len()).map(|i| self.health(i)).collect()
    }

    pub fn quarantined_count(&self) -> usize {
        self.healths().iter().filter(|h| **h == Health::Quarantined).count()
    }

    pub fn live_count(&self) -> usize {
        self.states.len() - self.quarantined_count()
    }

    pub fn stats(&self) -> SupervisorStats {
        SupervisorStats {
            retries: self.retries.load(Ordering::Relaxed),
            requeues: self.requeues.load(Ordering::Relaxed),
            quarantines: self.quarantines.load(Ordering::Relaxed),
            deaths: self.deaths.load(Ordering::Relaxed),
            hangs: self.hangs.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn deadline_policy() -> SupervisorPolicy {
        SupervisorPolicy { exec_deadline_ms: 10, ..Default::default() }
    }

    #[test]
    fn health_state_machine_strikes_suspects_heals_and_quarantines() {
        let s = Supervisor::new(2, deadline_policy());
        assert_eq!(s.health(0), Health::Live);
        // one overrun: Suspect, one hang counted
        s.observe_success(0, 25.0);
        assert_eq!(s.health(0), Health::Suspect);
        assert_eq!(s.stats().hangs, 1);
        // two in-deadline successes heal it
        s.observe_success(0, 1.0);
        s.observe_success(0, 1.0);
        assert_eq!(s.health(0), Health::Live);
        // strikes reset on heal: two fresh overruns quarantine
        s.observe_success(0, 25.0);
        s.observe_success(0, 25.0);
        assert_eq!(s.health(0), Health::Quarantined);
        let st = s.stats();
        assert_eq!(st.hangs, 3);
        assert_eq!(st.quarantines, 1);
        assert_eq!(st.deaths, 0, "striking out is not a death");
        // quarantine is terminal: later successes do not resurrect
        s.observe_success(0, 1.0);
        assert_eq!(s.health(0), Health::Quarantined);
        // the other context is untouched
        assert_eq!(s.health(1), Health::Live);
        assert_eq!(s.live_count(), 1);
    }

    #[test]
    fn deadline_off_means_no_strikes() {
        let s = Supervisor::new(1, SupervisorPolicy::default());
        s.observe_success(0, 1e9);
        assert_eq!(s.health(0), Health::Live);
        assert_eq!(s.stats().hangs, 0);
    }

    #[test]
    fn context_loss_quarantines_once_and_counts_a_death() {
        let s = Supervisor::new(4, SupervisorPolicy::default());
        let err = anyhow::Error::new(super::ContextLost { ctx: 2, reason: "gone".into() })
            .context("wrapped by a caller");
        assert_eq!(s.observe_error(2, &err), FaultKind::ContextLost);
        assert_eq!(s.observe_error(2, &err), FaultKind::ContextLost);
        assert_eq!(s.health(2), Health::Quarantined);
        let st = s.stats();
        assert_eq!((st.deaths, st.quarantines), (1, 1), "double observation counts once");
    }

    #[test]
    fn resolve_probes_ascending_and_errors_when_all_dead() {
        let s = Supervisor::new(3, SupervisorPolicy::default());
        assert_eq!(s.resolve(1).unwrap(), 1);
        s.quarantine(1);
        assert_eq!(s.resolve(1).unwrap(), 2, "probe ascends from the owner");
        s.quarantine(2);
        assert_eq!(s.resolve(1).unwrap(), 0, "probe wraps");
        s.quarantine(0);
        let err = s.resolve(1).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<SupervisionError>(),
                Some(SupervisionError::NoLiveContexts { quarantined: 3 })
            ),
            "{err:#}"
        );
    }

    #[test]
    fn classify_walks_wrapped_chains() {
        let lost = anyhow::Error::new(super::ContextLost { ctx: 0, reason: "x".into() })
            .context("layer 1")
            .context("layer 2");
        assert_eq!(classify(&lost), FaultKind::ContextLost);
        let transient =
            anyhow::Error::new(super::TransientExecError { ctx: 0, reason: "y".into() })
                .context("wrapped");
        assert_eq!(classify(&transient), FaultKind::Transient);
        assert_eq!(classify(&anyhow::anyhow!("plain")), FaultKind::Fatal);
    }

    #[test]
    fn backoff_doubles_from_base_and_caps() {
        let p = SupervisorPolicy {
            backoff_base_ms: 2,
            backoff_cap_ms: 12,
            ..Default::default()
        };
        assert_eq!(p.backoff_ms(1), 2);
        assert_eq!(p.backoff_ms(2), 4);
        assert_eq!(p.backoff_ms(3), 8);
        assert_eq!(p.backoff_ms(4), 12, "capped");
        assert_eq!(p.backoff_ms(60), 12, "shift is clamped, no overflow");
        let zero = SupervisorPolicy { backoff_base_ms: 0, ..Default::default() };
        assert_eq!(zero.backoff_ms(1), 0, "base 0 disables sleeping");
    }
}
