//! One execution context = one backend instance ([`Backend`]: a PJRT
//! client or the pure-rust sim) + its own executable cache + its own FFI
//! lock + atomic perf counters.
//!
//! The pre-pool `Runtime` held ONE client behind ONE global `exec_lock`,
//! so every device execution in the process — `WorkerPool` decode
//! batches, tenant rollout waves, bench ladders, trainer grad steps —
//! serialised on a single mutex and only host-side work overlapped.
//! `ExecContext` is the unit that breaks that: contexts share nothing
//! (backend, cache, lock, counters are all per-context), so two contexts
//! execute truly concurrently. `super::Runtime` owns a pool of D of them
//! and routes work; see DESIGN.md §9 for the lock hierarchy and the
//! determinism argument, §10 for the backend abstraction.
//!
//! Counters are lock-free (`AtomicU64`; millisecond totals stored as
//! f64 bit patterns, accumulated via CAS) so the hot path never takes a
//! stats mutex — the old `Mutex<RuntimeStats>` was taken twice per
//! `run`, once per `load`.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::manifest::{DType, ExeInfo, Manifest};
use crate::runtime::backend::{Backend, CompiledExe, HostTensor};
use crate::tensor::{Arg, TensorF32, TensorI32};

/// Cumulative perf counters of one context (or, via `Runtime::stats`,
/// summed over all contexts). The supervision counters (retries,
/// requeues, quarantines, deaths — DESIGN.md §14) are runtime-wide:
/// `Runtime::stats` overlays them from the supervisor, per-context
/// snapshots leave them 0.
#[derive(Clone, Copy, Debug, Default)]
pub struct RuntimeStats {
    pub compile_ms: f64,
    pub run_ms: f64,
    pub runs: u64,
    pub compiles: u64,
    /// In-place retries of transient execute errors.
    pub retries: u64,
    /// Dispatches re-pinned from a quarantined context to a survivor.
    pub requeues: u64,
    /// Contexts quarantined (lost or struck out on deadlines).
    pub quarantines: u64,
    /// Contexts lost outright.
    pub deaths: u64,
}

impl RuntimeStats {
    /// Accumulate another context's counters (for pool-wide aggregation).
    pub fn add(&mut self, other: &RuntimeStats) {
        self.compile_ms += other.compile_ms;
        self.run_ms += other.run_ms;
        self.runs += other.runs;
        self.compiles += other.compiles;
        self.retries += other.retries;
        self.requeues += other.requeues;
        self.quarantines += other.quarantines;
        self.deaths += other.deaths;
    }
}

/// Add `ms` to a millisecond total stored as f64 bits in an `AtomicU64`
/// (CAS loop; no mutex on the hot path). Shared with `engine`'s counters.
pub fn add_ms(cell: &AtomicU64, ms: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + ms).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

/// Read a millisecond total stored as f64 bits.
pub fn ms_of(cell: &AtomicU64) -> f64 {
    f64::from_bits(cell.load(Ordering::Relaxed))
}

type Slot<V> = Arc<OnceLock<std::result::Result<Arc<V>, String>>>;

/// Keyed single-flight initialisation: however many threads ask for the
/// same key concurrently, the initialiser runs exactly once and everyone
/// gets the same `Arc`. Failures are NOT cached — the slot is cleared so
/// a later call can retry (a transient compile error must not poison the
/// cache for the life of the process; the sim backend's injected compile
/// failures drive this path end-to-end in `tests/e2e_sim.rs`).
///
/// This replaces the seed cache's check-then-insert pattern, where two
/// threads racing to compile the same executable both compiled and the
/// second insert won (the `Runtime::load` double-compile race).
pub struct SingleFlight<V> {
    slots: RwLock<HashMap<String, Slot<V>>>,
}

impl<V> Default for SingleFlight<V> {
    fn default() -> Self {
        Self { slots: RwLock::new(HashMap::new()) }
    }
}

impl<V> SingleFlight<V> {
    pub fn new() -> Self {
        Self::default()
    }

    /// The cached value for `key`, if an initialisation already succeeded.
    pub fn get(&self, key: &str) -> Option<Arc<V>> {
        let slots = self.slots.read().unwrap();
        slots.get(key).and_then(|s| s.get()).and_then(|r| r.as_ref().ok().cloned())
    }

    /// Get `key`'s value, running `init` at most once across all
    /// concurrent callers; latecomers block until the winner finishes.
    pub fn get_or_try_init<F>(&self, key: &str, init: F) -> Result<Arc<V>>
    where
        F: FnOnce() -> Result<V>,
    {
        let slot = {
            let slots = self.slots.read().unwrap();
            slots.get(key).cloned()
        };
        let slot = match slot {
            Some(s) => s,
            None => self.slots.write().unwrap().entry(key.to_string()).or_default().clone(),
        };
        // exactly-once: OnceLock runs the closure on one thread and parks
        // the rest until the result is published
        let res = slot.get_or_init(|| init().map(Arc::new).map_err(|e| format!("{e:#}")));
        match res {
            Ok(v) => Ok(v.clone()),
            Err(msg) => {
                let err = msg.clone();
                // clear the slot (if it is still ours) so a retry is possible
                let mut slots = self.slots.write().unwrap();
                if let Some(cur) = slots.get(key) {
                    if Arc::ptr_eq(cur, &slot) {
                        slots.remove(key);
                    }
                }
                bail!("{err}")
            }
        }
    }

    /// Number of slots (successful or in-flight) currently held.
    pub fn len(&self) -> usize {
        self.slots.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[derive(Default)]
struct PerfCounters {
    compiles: AtomicU64,
    runs: AtomicU64,
    /// f64 total ms as bits (see `add_ms`)
    compile_ms_bits: AtomicU64,
    run_ms_bits: AtomicU64,
    /// calls currently inside this context's backend (compile or execute)
    /// — the load signal behind `Runtime::checkout`'s least-loaded pick
    active: AtomicU64,
}

/// Decrements `active` on drop so error paths can't leak load.
struct ActiveGuard<'a>(&'a AtomicU64);

impl Drop for ActiveGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Process-unique context identities: a pool index alone cannot tell two
/// runtimes' contexts apart, and running one runtime's executable on
/// another's backend would touch native objects outside their owning lock.
static NEXT_CTX_UID: AtomicU64 = AtomicU64::new(1);

/// A compiled executable, pinned to the context that compiled it (PJRT
/// loaded executables are client-owned and cannot run elsewhere; the sim
/// keeps the same routing discipline so both backends exercise one path).
pub struct Executable {
    exe: Box<dyn CompiledExe>,
    pub info: ExeInfo,
    /// owning context's pool index — `Runtime::run` routes on this
    pub ctx: usize,
    /// owning context's process-unique identity — `ExecContext::run`
    /// rejects executables from any other context, even one with the
    /// same pool index in a different `Runtime`
    ctx_uid: u64,
}

/// Outputs of one execution, keyed by position (manifest order). Backends
/// hand results back as host tensors, so this type is backend-blind.
///
/// Known cost: the accessors clone the requested tensor (one memcpy per
/// accessed output on top of the backend's device→host transfer). At the
/// current tiers the largest output set is the pretrain grads (~0.5 MB);
/// if tiers grow, move to consuming/borrowing accessors rather than
/// widening this one.
pub struct Outputs {
    vals: Vec<HostTensor>,
    info: ExeInfo,
}

impl Outputs {
    pub fn f32(&self, idx: usize) -> Result<TensorF32> {
        let spec = &self.info.outputs[idx];
        if spec.dtype != DType::F32 {
            bail!("output {idx} ({}) is not f32", spec.name);
        }
        match &self.vals[idx] {
            HostTensor::F32(t) => Ok(t.clone()),
            HostTensor::I32(_) => bail!("output {idx} ({}) is not f32", spec.name),
        }
    }

    pub fn i32(&self, idx: usize) -> Result<TensorI32> {
        let spec = &self.info.outputs[idx];
        if spec.dtype != DType::S32 {
            bail!("output {idx} ({}) is not s32", spec.name);
        }
        match &self.vals[idx] {
            HostTensor::I32(t) => Ok(t.clone()),
            HostTensor::F32(_) => bail!("output {idx} ({}) is not s32", spec.name),
        }
    }

    pub fn len(&self) -> usize {
        self.vals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }

    /// Find an output index by manifest name.
    pub fn index_of(&self, name: &str) -> Result<usize> {
        self.info
            .outputs
            .iter()
            .position(|o| o.name == name)
            .with_context(|| format!("no output named {name:?}"))
    }
}

/// One device-parallel execution context.
pub struct ExecContext {
    /// stable index of this context within the runtime's pool
    pub id: usize,
    /// process-unique identity (see `NEXT_CTX_UID`)
    uid: u64,
    /// this context's device layer (PJRT client or sim); owned 1:1
    backend: Box<dyn Backend>,
    /// Serialises every native section that touches THIS context's
    /// backend state (PJRT: compile, execute, device→host transfer).
    /// Contexts hold independent locks, so D contexts execute
    /// concurrently; host-side work (arg conversion, decode/verify)
    /// stays outside the lock. The lock is threaded into the backend,
    /// which guards exactly its native sections (the sim guards nothing —
    /// it is pure rust).
    ffi: Mutex<()>,
    /// per-context executable cache with single-flight compile coalescing
    cache: SingleFlight<Executable>,
    perf: PerfCounters,
}

impl ExecContext {
    pub fn new(id: usize, backend: Box<dyn Backend>) -> Self {
        Self {
            id,
            uid: NEXT_CTX_UID.fetch_add(1, Ordering::Relaxed),
            backend,
            ffi: Mutex::new(()),
            cache: SingleFlight::new(),
            perf: PerfCounters::default(),
        }
    }

    /// Backend name ("pjrt" | "sim") for diagnostics.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Load (compile) an executable by manifest name, with single-flight
    /// caching: concurrent loads of one name compile exactly once.
    pub fn load(&self, manifest: &Manifest, art_dir: &Path, name: &str) -> Result<Arc<Executable>> {
        self.cache.get_or_try_init(name, || {
            let info = manifest.exe(name)?.clone();
            let t0 = Instant::now();
            let exe = {
                // compiles can hold the FFI lock for seconds — count them
                // in `in_flight` so least-loaded checkout steers around a
                // context stuck compiling, not just one mid-execute
                self.perf.active.fetch_add(1, Ordering::Relaxed);
                let _busy = ActiveGuard(&self.perf.active);
                self.backend.compile(art_dir, &info, &self.ffi)?
            };
            self.perf.compiles.fetch_add(1, Ordering::Relaxed);
            add_ms(&self.perf.compile_ms_bits, t0.elapsed().as_secs_f64() * 1e3);
            Ok(Executable { exe, info, ctx: self.id, ctx_uid: self.uid })
        })
    }

    /// Execute with shape-checked args; returns per-output host tensors.
    pub fn run(&self, exe: &Executable, args: &[Arg]) -> Result<Outputs> {
        if exe.ctx_uid != self.uid {
            // catches both a wrong context of this runtime AND a context
            // of a different runtime that happens to share pool index
            bail!(
                "{}: executable belongs to another execution context (ctx {}), not this one (ctx {})",
                exe.info.name,
                exe.ctx,
                self.id
            );
        }
        if args.len() != exe.info.inputs.len() {
            bail!(
                "{}: got {} args, want {}",
                exe.info.name,
                args.len(),
                exe.info.inputs.len()
            );
        }
        for (a, spec) in args.iter().zip(&exe.info.inputs) {
            a.check(spec).with_context(|| exe.info.name.clone())?;
        }
        let t0 = Instant::now();
        let vals = {
            self.perf.active.fetch_add(1, Ordering::Relaxed);
            let _busy = ActiveGuard(&self.perf.active);
            exe.exe.execute(&exe.info, args, &self.ffi)?
        };
        self.perf.runs.fetch_add(1, Ordering::Relaxed);
        add_ms(&self.perf.run_ms_bits, t0.elapsed().as_secs_f64() * 1e3);
        if vals.len() != exe.info.outputs.len() {
            bail!(
                "{}: got {} outputs, want {}",
                exe.info.name,
                vals.len(),
                exe.info.outputs.len()
            );
        }
        Ok(Outputs { vals, info: exe.info.clone() })
    }

    /// Calls currently inside this context's backend (executes AND
    /// compiles — a context stuck compiling reads as loaded).
    pub fn in_flight(&self) -> u64 {
        self.perf.active.load(Ordering::Relaxed)
    }

    /// Snapshot of this context's cumulative counters.
    pub fn stats(&self) -> RuntimeStats {
        RuntimeStats {
            compile_ms: ms_of(&self.perf.compile_ms_bits),
            run_ms: ms_of(&self.perf.run_ms_bits),
            runs: self.perf.runs.load(Ordering::Relaxed),
            compiles: self.perf.compiles.load(Ordering::Relaxed),
            ..Default::default()
        }
    }

    pub fn platform(&self) -> String {
        self.backend.platform(&self.ffi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// ISSUE 4 satellite: concurrent initialisation of one key runs the
    /// initialiser exactly once — everyone gets the winner's Arc.
    #[test]
    fn single_flight_concurrent_init_runs_once() {
        let sf: SingleFlight<u64> = SingleFlight::new();
        let ticks = AtomicU64::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    let v = sf
                        .get_or_try_init("exe", || {
                            ticks.fetch_add(1, Ordering::SeqCst);
                            // widen the race window: losers must park, not re-init
                            std::thread::sleep(std::time::Duration::from_millis(5));
                            Ok(42)
                        })
                        .unwrap();
                    assert_eq!(*v, 42);
                });
            }
        });
        assert_eq!(ticks.load(Ordering::SeqCst), 1, "initialiser ran more than once");
        assert_eq!(*sf.get("exe").unwrap(), 42);
        assert_eq!(sf.len(), 1);
    }

    #[test]
    fn single_flight_does_not_cache_failures() {
        let sf: SingleFlight<u64> = SingleFlight::new();
        let err = sf.get_or_try_init("k", || bail!("transient compile error"));
        assert!(err.is_err());
        assert!(sf.get("k").is_none(), "failure must not be cached");
        // the retry runs a fresh initialiser and succeeds
        let v = sf.get_or_try_init("k", || Ok(7)).unwrap();
        assert_eq!(*v, 7);
        assert_eq!(*sf.get("k").unwrap(), 7);
    }

    #[test]
    fn single_flight_returns_cached_arc_without_reinit() {
        let sf: SingleFlight<String> = SingleFlight::new();
        let a = sf.get_or_try_init("x", || Ok("hello".to_string())).unwrap();
        let b = sf.get_or_try_init("x", || panic!("must not re-init")).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
    }

    /// ISSUE 4 satellite: the CAS-loop f64 accumulator loses no updates
    /// under contention (0.25 is exact in binary, so the total is exact).
    #[test]
    fn atomic_ms_accumulation_is_lossless() {
        let cell = AtomicU64::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        add_ms(&cell, 0.25);
                    }
                });
            }
        });
        assert_eq!(ms_of(&cell), 1000.0);
    }

    #[test]
    fn runtime_stats_aggregation() {
        let mut agg = RuntimeStats::default();
        agg.add(&RuntimeStats {
            compile_ms: 1.5,
            run_ms: 2.0,
            runs: 3,
            compiles: 1,
            ..Default::default()
        });
        agg.add(&RuntimeStats {
            compile_ms: 0.5,
            run_ms: 1.0,
            runs: 2,
            compiles: 1,
            retries: 2,
            requeues: 1,
            quarantines: 1,
            deaths: 1,
        });
        assert_eq!(agg.compile_ms, 2.0);
        assert_eq!(agg.run_ms, 3.0);
        assert_eq!(agg.runs, 5);
        assert_eq!(agg.compiles, 2);
        assert_eq!(
            (agg.retries, agg.requeues, agg.quarantines, agg.deaths),
            (2, 1, 1, 1),
            "supervision counters aggregate too"
        );
    }
}
