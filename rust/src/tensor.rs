//! Host-side tensors: a flat buffer + shape, with conversions to/from the
//! `xla` crate's `Literal`. All device I/O goes through these.

use anyhow::{bail, Result};

use crate::manifest::{ArgSpec, DType};

/// Literal construction for an f32 buffer at a given shape. Rank-1
/// tensors skip the `reshape` round-trip entirely — `vec1` already
/// carries the right shape, and `reshape` materialises a second
/// full-size literal. That copy used to be paid on EVERY batch for every
/// rank-1 argument (prompt lengths, advantages, adapter theta vectors).
fn literal_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// i32 twin of [`literal_f32`] (the xla element-type trait is not
/// nameable from here, so the helper is monomorphic per dtype).
fn literal_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

#[derive(Clone, Debug, PartialEq)]
pub struct TensorF32 {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl TensorF32 {
    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        literal_f32(&self.shape, &self.data)
    }

    pub fn from_literal(lit: &xla::Literal, shape: &[usize]) -> Result<Self> {
        let data = lit.to_vec::<f32>()?;
        if data.len() != shape.iter().product::<usize>() {
            bail!("literal numel {} != shape {:?}", data.len(), shape);
        }
        Ok(Self { shape: shape.to_vec(), data })
    }

    /// L2 norm (used for grad-norm metrics and optimizer tests).
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl TensorI32 {
    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape: shape.to_vec(), data }
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        literal_i32(&self.shape, &self.data)
    }

    pub fn from_literal(lit: &xla::Literal, shape: &[usize]) -> Result<Self> {
        let data = lit.to_vec::<i32>()?;
        if data.len() != shape.iter().product::<usize>() {
            bail!("literal numel {} != shape {:?}", data.len(), shape);
        }
        Ok(Self { shape: shape.to_vec(), data })
    }
}

/// A runtime argument: either dtype, shape-checked against an `ArgSpec`.
#[derive(Clone, Debug)]
pub enum Arg {
    F32(TensorF32),
    I32(TensorI32),
    /// f32 scalar (shape [])
    Scalar(f32),
}

impl Arg {
    pub fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            Arg::F32(t) => t.to_literal(),
            Arg::I32(t) => t.to_literal(),
            Arg::Scalar(x) => Ok(xla::Literal::from(*x)),
        }
    }

    pub fn check(&self, spec: &ArgSpec) -> Result<()> {
        match self {
            Arg::F32(t) => {
                if spec.dtype != DType::F32 || t.shape != spec.shape {
                    bail!("arg {}: want f32{:?}, got f32{:?}", spec.name, spec.shape, t.shape);
                }
            }
            Arg::I32(t) => {
                if spec.dtype != DType::S32 || t.shape != spec.shape {
                    bail!("arg {}: want s32{:?}, got s32{:?}", spec.name, spec.shape, t.shape);
                }
            }
            Arg::Scalar(_) => {
                if spec.dtype != DType::F32 || !spec.shape.is_empty() {
                    bail!("arg {}: want {:?}{:?}, got f32 scalar", spec.name, spec.dtype, spec.shape);
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        let t = TensorF32::zeros(&[2, 3]);
        assert_eq!(t.numel(), 6);
        let spec = ArgSpec { name: "x".into(), dtype: DType::F32, shape: vec![2, 3] };
        Arg::F32(t).check(&spec).unwrap();
        let bad = Arg::F32(TensorF32::zeros(&[3, 2]));
        assert!(bad.check(&spec).is_err());
    }

    #[test]
    fn norm() {
        let t = TensorF32::from_vec(&[2], vec![3.0, 4.0]);
        assert!((t.norm() - 5.0).abs() < 1e-6);
    }

    /// ISSUE 4 satellite: the direct-shape literal construction (rank-1
    /// fast path included) round-trips exactly on random shapes, both
    /// dtypes. Literals are standalone host buffers — no client needed.
    #[test]
    fn prop_literal_roundtrip_random_shapes() {
        crate::testing::check("literal roundtrip", 50, |rng| {
            let rank = 1 + rng.below(3) as usize;
            let shape: Vec<usize> = (0..rank).map(|_| 1 + rng.below(5) as usize).collect();
            let n: usize = shape.iter().product();

            let data: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let t = TensorF32::from_vec(&shape, data);
            let lit = t.to_literal().map_err(|e| format!("{e:#}"))?;
            let back = TensorF32::from_literal(&lit, &shape).map_err(|e| format!("{e:#}"))?;
            if back != t {
                return Err(format!("f32 roundtrip mismatch at shape {shape:?}"));
            }

            let ti = TensorI32::from_vec(&shape, (0..n as i32).collect());
            let lit = ti.to_literal().map_err(|e| format!("{e:#}"))?;
            let back = TensorI32::from_literal(&lit, &shape).map_err(|e| format!("{e:#}"))?;
            if back != ti {
                return Err(format!("i32 roundtrip mismatch at shape {shape:?}"));
            }
            Ok(())
        });
    }
}
