//! The shared training-step driver.
//!
//! `TrainSession` owns everything the three hand-rolled loops used to
//! duplicate: the Adam optimizer, the warmup LR schedule, grad clipping,
//! the session RNG stream, step counting, run-log records and periodic
//! `TrainState` checkpoints. A loop only computes gradients
//! ([`TrainLoop::compute`]) and interprets metrics ([`TrainLoop::record`]).

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::coordinator::optimizer::{lr_at, Adam, AdamConfig};
use crate::metrics::RunLog;
use crate::runtime::Runtime;
use crate::trainer::state::{TrainState, TRAIN_STATE_VERSION};
use crate::trainer::{GradOutput, TrainLoop};
use crate::util::Pcg64;

/// Session-owned hyperparameters — the step-skeleton knobs every loop
/// shares. Loss-specific knobs (suite, group, clip_c, …) stay on the loop.
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// Total steps for the run (a resumed session continues up to this).
    pub steps: usize,
    pub lr: f32,
    pub warmup: u64,
    pub grad_clip: f32,
    pub seed: u64,
    /// RNG stream tag (one per algo, so the historical per-trainer streams
    /// are preserved and eval streams stay disjoint by construction).
    pub stream: u64,
    /// Save a `TrainState` every N completed steps (0 = off).
    pub ckpt_every: usize,
    pub ckpt_path: Option<PathBuf>,
}

pub struct TrainSession<L: TrainLoop> {
    pub cfg: SessionConfig,
    pub lp: L,
    pub(crate) opt: Adam,
    pub(crate) rng: Pcg64,
    pub(crate) step: usize,
}

impl<L: TrainLoop> TrainSession<L> {
    pub fn new(lp: L, cfg: SessionConfig) -> Self {
        let opt = Adam::new(
            lp.n_params(),
            AdamConfig { lr: cfg.lr, grad_clip: cfg.grad_clip, ..Default::default() },
        );
        let rng = Pcg64::with_stream(cfg.seed, cfg.stream);
        Self { cfg, lp, opt, rng, step: 0 }
    }

    /// Rebuild a session from a saved [`TrainState`]. The continuation is
    /// bit-identical to the uninterrupted run: parameters, Adam moments,
    /// the RNG stream and the step counter all resume exactly.
    pub fn resume(rt: &Runtime, mut lp: L, cfg: SessionConfig, st: &TrainState) -> Result<Self> {
        if st.algo != lp.algo() {
            bail!("train state is for algo {:?}, loop is {:?}", st.algo, lp.algo());
        }
        if st.tier != lp.tier() {
            bail!("train state is for tier {:?}, loop is {:?}", st.tier, lp.tier());
        }
        // param counts collide across schemes (many 13-param placements),
        // so the scheme tag must match exactly, not just the length
        if st.scheme_tag != lp.scheme_tag() {
            bail!(
                "train state is for scheme {:?}, loop is {:?}",
                st.scheme_tag,
                lp.scheme_tag()
            );
        }
        // a hyperparameter mismatch (suite, lr, schedule, seed, …) would
        // silently break bit-identical resume — require the exact flags
        if st.config != lp.config_tag() {
            bail!(
                "train state was saved with config [{}], loop has [{}] — \
                 repeat the original flags to resume",
                st.config,
                lp.config_tag()
            );
        }
        if st.params.len() != lp.n_params() {
            bail!(
                "train state has {} params, loop expects {} (scheme {:?} vs {:?})",
                st.params.len(),
                lp.n_params(),
                st.scheme_tag,
                lp.scheme_tag()
            );
        }
        lp.set_params(rt, &st.params)?;
        let mut opt = Adam::new(
            lp.n_params(),
            AdamConfig { lr: cfg.lr, grad_clip: cfg.grad_clip, ..Default::default() },
        );
        opt.restore(&st.adam);
        Ok(Self { cfg, lp, opt, rng: Pcg64::from_state(st.rng), step: st.step as usize })
    }

    /// Steps completed so far.
    pub fn completed_steps(&self) -> usize {
        self.step
    }

    /// Snapshot the resumable state (see [`TrainState`]).
    pub fn state(&self) -> TrainState {
        TrainState {
            version: TRAIN_STATE_VERSION,
            algo: self.lp.algo().to_string(),
            tier: self.lp.tier().to_string(),
            scheme_tag: self.lp.scheme_tag().to_string(),
            config: self.lp.config_tag(),
            step: self.step as u64,
            rng: self.rng.state(),
            adam: self.opt.state(),
            params: self.lp.params(),
        }
    }

    /// One full step: loop-owned gradient, then the shared skeleton.
    pub fn step_once(&mut self, rt: &Runtime, log: &mut RunLog) -> Result<L::Record> {
        let out = self.lp.compute(rt, self.step, &mut self.rng)?;
        self.apply(rt, out, log)
    }

    /// The optimizer/schedule/record/checkpoint half of a step — shared
    /// with `TenantTrainer`, whose rollouts happen outside the loop (pooled
    /// across tenants) before the gradient is applied here.
    pub fn apply(&mut self, rt: &Runtime, out: GradOutput, log: &mut RunLog) -> Result<L::Record> {
        self.opt.set_lr(lr_at(self.cfg.lr, self.cfg.warmup, self.step as u64));
        let mut params = self.lp.params();
        let grad_norm = self.opt.step(&mut params, &out.grad);
        self.lp.set_params(rt, &params)?;
        let rec = self.lp.record(self.step, self.opt.cfg.lr, &out, grad_norm, log);
        self.step += 1;
        if self.cfg.ckpt_every > 0 && self.step % self.cfg.ckpt_every == 0 {
            if let Some(path) = &self.cfg.ckpt_path {
                self.state().save(path)?;
            }
        }
        Ok(rec)
    }

    /// Run (or continue) to the configured step count, logging as we go.
    pub fn run(&mut self, rt: &Runtime, log: &mut RunLog) -> Result<Vec<L::Record>> {
        let mut records = Vec::with_capacity(self.cfg.steps.saturating_sub(self.step));
        while self.step < self.cfg.steps {
            records.push(self.step_once(rt, log)?);
        }
        Ok(records)
    }

    /// Consume the session, handing back the loop (and with it the trained
    /// policy/weights).
    pub fn into_loop(self) -> L {
        self.lp
    }
}
