//! The trainer subsystem — the ONE canonical training-step skeleton
//! (DESIGN.md §7), mirroring what `engine/` did for the decode paths.
//!
//! Before this subsystem existed, `pretrain.rs`, `grpo.rs` and `sft.rs`
//! each hand-rolled optimizer wiring, LR scheduling, grad clipping,
//! logging and ad-hoc checkpointing. Now:
//!
//!   * [`TrainLoop`] — what a *loss* must provide: assemble a batch and
//!     compute a gradient, plus how to interpret the step's metrics. The
//!     three loops (`PretrainLoop`, `GrpoLoop`, `SftLoop`) are thin impls.
//!   * [`TrainSession`] — the shared step driver: LR schedule → Adam step
//!     (with grad clip) → parameter install/re-merge → `RunLog` record →
//!     periodic [`TrainState`] checkpoint. Owns the RNG stream, so a saved
//!     state resumes bit-identically.
//!   * [`TrainState`] — versioned binary checkpoint (params + Adam moments
//!     + RNG stream + step counter) extending the `weights.rs` format.
//!   * [`TenantTrainer`] — the multi-tenant training plane: G GRPO
//!     sessions over independent TinyLoRA adapters sharing one backbone,
//!     rollout waves batched through `engine::WorkerPool`, finished
//!     adapters registered straight into the serving `AdapterStore`.
//!
//! Ownership rule: the trainer owns *how* a step runs; loops own *what*
//! the loss means.

pub mod pipeline;
pub mod session;
pub mod state;
pub mod tenant;

use anyhow::Result;

use crate::coordinator::policy::GradStats;
use crate::metrics::RunLog;
use crate::runtime::Runtime;
use crate::util::Pcg64;

pub use pipeline::{PipelineConfig, PipelineOutcome, PipelineStats, ReplayQueue};
pub use session::{SessionConfig, TrainSession};
pub use state::{TrainState, TRAIN_STATE_VERSION};
pub use tenant::{TenantOutcome, TenantSpec, TenantTrainer};

/// Loop-specific scalar metrics for one step. GRPO fills all four; SFT and
/// pretraining report through `GradStats` (loss / token accuracy) and leave
/// these at their defaults.
#[derive(Clone, Copy, Debug, Default)]
pub struct AuxMetrics {
    pub reward: f32,
    pub response_len: f32,
    pub format_rate: f32,
    pub eos_rate: f32,
}

/// Everything one loop iteration hands back to the session: the flat
/// gradient over the loop's parameter vector plus the step's diagnostics.
pub struct GradOutput {
    pub grad: Vec<f32>,
    pub stats: GradStats,
    pub aux: AuxMetrics,
    pub rollout_ms: f64,
    pub grad_ms: f64,
}

/// One trainable loss. Implementations own their parameter vessel (a
/// `Policy` for the adapter trainers, a raw `WeightSet` for pretraining)
/// and MUST NOT touch optimizers, LR schedules, logging plumbing or
/// checkpoint files — that is [`TrainSession`]'s job.
pub trait TrainLoop {
    /// Per-step record type (kept distinct per loop so figure drivers see
    /// the fields they always did).
    type Record: Clone;

    /// Algo tag recorded in checkpoints and logs ("pretrain"|"grpo"|"sft").
    fn algo(&self) -> &'static str;

    /// Backbone tier this loop trains against.
    fn tier(&self) -> &str;

    /// Adapter scheme tag ("-" when the loop trains raw weights).
    fn scheme_tag(&self) -> &str {
        "-"
    }

    /// Canonical fingerprint of every hyperparameter that shapes the
    /// training trajectory (suite, lr, schedule, loss knobs, seed — NOT
    /// the step count, so a finished run may be extended). Stored in the
    /// `TrainState` and compared on resume: a mismatch would silently
    /// break bit-identical resume, so it is a hard error instead.
    fn config_tag(&self) -> String;

    /// Length of the flat trainable vector.
    fn n_params(&self) -> usize;

    /// Current flat trainable vector (what the session's Adam steps over).
    fn params(&self) -> Vec<f32>;

    /// Install updated parameters; adapter loops re-merge here so the
    /// inference plane always sees folded weights.
    fn set_params(&mut self, rt: &Runtime, params: &[f32]) -> Result<()>;

    /// Loss-specific work for one step: draw a batch from `rng` (the
    /// session-owned stream — part of the resume state) and run the grad
    /// executable against the current parameters.
    fn compute(&mut self, rt: &Runtime, step: usize, rng: &mut Pcg64) -> Result<GradOutput>;

    /// Interpret a completed step: build the loop's record and write it to
    /// the run log (what the metrics *mean* is loop-owned; when a record is
    /// taken is session-owned).
    fn record(
        &self,
        step: usize,
        lr: f32,
        out: &GradOutput,
        grad_norm: f32,
        log: &mut RunLog,
    ) -> Self::Record;
}
