//! Versioned training-state checkpoints.
//!
//! A [`TrainState`] captures everything a [`super::TrainSession`] needs to
//! continue bit-identically after a kill: the flat trainable vector, the
//! Adam moments + step counter, the session RNG stream and the step index.
//! The binary layout extends the `weights.rs` checkpoint format (same
//! little-endian primitives, 8-byte magic, length-prefixed strings) with a
//! version field so later sessions can evolve it without breaking resume.

use std::io::{BufReader, BufWriter};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::optimizer::AdamState;
use crate::weights::{
    read_f32_vec, read_str, read_u32, read_u64, write_f32_slice, write_str, write_u32, write_u64,
};

const MAGIC: &[u8; 8] = b"TLRLTRN1";
pub const TRAIN_STATE_VERSION: u32 = 1;

/// Resumable snapshot of one training session.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainState {
    pub version: u32,
    pub algo: String,
    pub tier: String,
    pub scheme_tag: String,
    /// The loop's `config_tag` — every trajectory-shaping hyperparameter;
    /// resume refuses a mismatch (the flags must be repeated exactly).
    pub config: String,
    /// Steps already completed; the resumed session starts here.
    pub step: u64,
    /// Session RNG snapshot (`Pcg64::state` layout).
    pub rng: [u64; 4],
    pub adam: AdamState,
    /// Flat trainable vector (adapter theta, or full weights for
    /// pretraining / full-FT).
    pub params: Vec<f32>,
}

impl TrainState {
    /// Atomic save: write to `<path>.tmp`, flush, then rename over `path`,
    /// so a kill mid-save (exactly the scenario resume exists for) never
    /// destroys the previous good state.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let tmp = path.with_extension("tmp");
        {
            let mut f = BufWriter::new(std::fs::File::create(&tmp)?);
            use std::io::Write;
            f.write_all(MAGIC)?;
            write_u32(&mut f, self.version)?;
            write_str(&mut f, &self.algo)?;
            write_str(&mut f, &self.tier)?;
            write_str(&mut f, &self.scheme_tag)?;
            write_str(&mut f, &self.config)?;
            write_u64(&mut f, self.step)?;
            for &w in &self.rng {
                write_u64(&mut f, w)?;
            }
            write_u64(&mut f, self.adam.t)?;
            write_u32(&mut f, self.params.len() as u32)?;
            write_f32_slice(&mut f, &self.adam.m)?;
            write_f32_slice(&mut f, &self.adam.v)?;
            write_f32_slice(&mut f, &self.params)?;
            // surface full-disk errors here instead of silently in Drop
            f.flush()?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut f = BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening train state {path:?}"))?,
        );
        use std::io::Read;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("bad train-state magic in {path:?}");
        }
        let version = read_u32(&mut f)?;
        if version != TRAIN_STATE_VERSION {
            bail!("train state {path:?} has version {version}, expected {TRAIN_STATE_VERSION}");
        }
        let algo = read_str(&mut f)?;
        let tier = read_str(&mut f)?;
        let scheme_tag = read_str(&mut f)?;
        let config = read_str(&mut f)?;
        let step = read_u64(&mut f)?;
        let mut rng = [0u64; 4];
        for w in &mut rng {
            *w = read_u64(&mut f)?;
        }
        let adam_t = read_u64(&mut f)?;
        let n = read_u32(&mut f)? as usize;
        if n > (1 << 28) {
            bail!("implausible param count {n} in {path:?}");
        }
        let m = read_f32_vec(&mut f, n)?;
        let v = read_f32_vec(&mut f, n)?;
        let params = read_f32_vec(&mut f, n)?;
        Ok(Self {
            version,
            algo,
            tier,
            scheme_tag,
            config,
            step,
            rng,
            adam: AdamState { t: adam_t, m, v },
            params,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_state(n: usize) -> TrainState {
        TrainState {
            version: TRAIN_STATE_VERSION,
            algo: "grpo".into(),
            tier: "nano".into(),
            scheme_tag: "tinylora_r2_u13_all".into(),
            config: "suite=gsm8k-syn lr=0.002 seed=9".into(),
            step: 17,
            rng: [1, 2, 3, 4],
            adam: AdamState {
                t: 17,
                m: (0..n).map(|i| i as f32 * 0.25).collect(),
                v: (0..n).map(|i| i as f32 * 0.5 + 1.0).collect(),
            },
            params: (0..n).map(|i| (i as f32).sin()).collect(),
        }
    }

    #[test]
    fn save_load_roundtrip_is_exact() {
        let st = sample_state(13);
        let dir = std::env::temp_dir().join("tlrl_trainstate_test");
        let path = dir.join("grpo.trainstate");
        st.save(&path).unwrap();
        let back = TrainState::load(&path).unwrap();
        assert_eq!(st, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_wrong_magic_and_version() {
        let dir = std::env::temp_dir().join("tlrl_trainstate_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.trainstate");
        std::fs::write(&path, b"TLRLCKP1rest").unwrap();
        assert!(TrainState::load(&path).is_err());
        let mut st = sample_state(3);
        st.version = 999;
        // version is validated on load, not save
        let vpath = dir.join("vers.trainstate");
        st.save(&vpath).unwrap();
        assert!(TrainState::load(&vpath).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
