//! Async off-policy GRPO pipeline (DESIGN.md §15): rollout production and
//! optimizer consumption split around bounded per-tenant replay queues.
//!
//! The synchronous `TenantTrainer::step_wave` alternates rollout and
//! optimize per wave. Here the two halves are decoupled: a produce phase
//! plans rollouts for every tenant with queue room (up to its *window*,
//! see below) and decodes them as ONE pooled wave, tagging each job with
//! the tenant's policy version at plan time; a consume phase drains every
//! queue through the tenants' sessions on `optimizer_threads` threads,
//! enforcing the staleness bound and applying the gradient through the
//! same `TrainSession::apply` skeleton as the synchronous path.
//!
//! Staleness rule: a trajectory produced at policy version `v` may be
//! consumed at version `<= v + max_staleness`; anything older is dropped
//! and counted (`PipelineStats::dropped_stale`), never trained on.
//!
//! Importance correction: the GRPO loss is already truncated importance
//! sampling — the gradient executable weights each token by
//! `min(exp(logp_now − logp_rollout), clip_c)`, with the behavior
//! log-probs carried inside the rollout rows. The pipeline therefore
//! needs no extra math at consume time, only the version bookkeeping that
//! decides *whether* the correction is within the trust window. On the
//! sim backend rollout log-probs equal trainer log-probs at equal
//! weights, so at `max_staleness = 0` every computed ratio is exactly
//! 1.0 — asserted bit-for-bit in `tests/e2e_sim.rs`.
//!
//! Determinism contract (the point of the design): with
//! `max_staleness = 0` the window is 1, so each round degenerates to
//! exactly one plan → decode → apply per tenant, in tenant order — the
//! same call sequence as `step_wave`. Plans are always drawn on the
//! coordinating thread in tenant order (session RNGs are sequential
//! state), decode bytes are independent of job id and worker/device count
//! (engine invariant, e2e-asserted), and consume-phase records are
//! re-logged in tenant order regardless of how optimizer threads were
//! scheduled. Hence the async pipeline at staleness 0 is byte-identical
//! to the synchronous trainer — theta bits and RunLog rows (modulo wall
//! times) — at ANY `optimizer_threads`/worker/device count.
//!
//! With `queue_cap > max_staleness + 1` the producer runs ahead of the
//! consumer on purpose: each fill of the window yields `max_staleness + 1`
//! consumable groups and deterministically drops the rest — the mode the
//! staleness-accounting tests and the drop-rate column of
//! `BENCH_pipeline.json` exercise.

use std::collections::VecDeque;

use anyhow::{bail, Context, Result};

use crate::coordinator::grpo::{RolloutPlan, StepRecord};
use crate::engine::pool::GenJob;
use crate::engine::Generation;
use crate::metrics::RunLog;
use crate::runtime::Runtime;
use crate::trainer::{TenantOutcome, TenantTrainer};
use crate::util::json::Value;
use crate::util::Timer;

/// Pipeline knobs (`tenants --pipeline` flags).
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    /// Max allowed version gap S: consume at version `<= produced + S`.
    pub max_staleness: u64,
    /// Threads draining the per-tenant queues (grad + optimizer step).
    pub optimizer_threads: usize,
    /// Per-tenant replay-queue capacity; 0 = `max_staleness + 1`, the
    /// largest window that can never produce a stale drop fault-free.
    /// Larger values deliberately overproduce (see module docs).
    pub queue_cap: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self { max_staleness: 0, optimizer_threads: 1, queue_cap: 0 }
    }
}

impl PipelineConfig {
    /// Effective per-tenant producer window.
    pub fn window(&self) -> usize {
        if self.queue_cap == 0 {
            (self.max_staleness as usize).saturating_add(1)
        } else {
            self.queue_cap
        }
    }
}

/// Bounded FIFO of version-tagged items — the per-tenant replay queue.
/// Backpressure by rejection: a full queue returns the item to the
/// producer instead of overwriting unconsumed work.
pub struct ReplayQueue<T> {
    cap: usize,
    items: VecDeque<(u64, T)>,
}

impl<T> ReplayQueue<T> {
    pub fn new(cap: usize) -> Self {
        Self { cap: cap.max(1), items: VecDeque::new() }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Push a group produced at `version`. Full queue ⇒ `Err(item)` — the
    /// producer keeps it; nothing queued is ever overwritten.
    pub fn push(&mut self, version: u64, item: T) -> std::result::Result<(), T> {
        if self.items.len() >= self.cap {
            return Err(item);
        }
        self.items.push_back((version, item));
        Ok(())
    }

    /// Pop the next group fresh enough to train on at `version`: leading
    /// entries with `version - produced > max_staleness` are dropped (and
    /// counted in the returned tally); the first fresh entry comes back
    /// with its production version. FIFO among survivors.
    pub fn pop_fresh(
        &mut self,
        version: u64,
        max_staleness: u64,
    ) -> (Option<(u64, T)>, u64) {
        let mut dropped = 0u64;
        while let Some(&(v, _)) = self.items.front() {
            if version.saturating_sub(v) > max_staleness {
                self.items.pop_front();
                dropped += 1;
            } else {
                return (self.items.pop_front(), dropped);
            }
        }
        (None, dropped)
    }
}

/// One queued trajectory group: the plan it came from, the decoded
/// rollout (version-tagged), and its share of the decode wave's wall time.
pub struct ReplayItem {
    pub plan: RolloutPlan,
    pub gen: Generation,
    pub rollout_ms: f64,
}

/// Pipeline-level counters for one `run_async` call.
#[derive(Clone, Copy, Debug, Default)]
pub struct PipelineStats {
    /// trajectory groups decoded and queued
    pub produced: u64,
    /// groups trained on (== total optimizer steps applied)
    pub consumed: u64,
    /// groups dropped by the staleness rule (never trained on)
    pub dropped_stale: u64,
    /// largest consume-time version gap among CONSUMED groups
    pub max_version_gap: u64,
    /// pooled decode waves dispatched
    pub waves: u64,
    /// mean of the per-step mean importance ratios (exactly 1.0 on sim at
    /// staleness 0 — asserted in e2e)
    pub mean_ratio: f64,
    /// mean of the per-step clipped-token fractions
    pub frac_clipped: f64,
    /// consumed steps per wall second
    pub steps_per_s: f64,
}

/// What one pipeline run produced: per-tenant step records (tenant order)
/// plus the pipeline counters.
pub struct PipelineOutcome {
    pub records: Vec<Vec<StepRecord>>,
    pub stats: PipelineStats,
}

/// Per-tenant result of one consume phase (scratch-logged rows are
/// re-logged by the coordinator in tenant order).
#[derive(Default)]
struct TenantConsume {
    records: Vec<StepRecord>,
    rows: Vec<Value>,
    consumed: u64,
    dropped: u64,
    max_gap: u64,
}

/// Drain one chunk of tenants: pop fresh groups FIFO, compute the grad
/// (`GrpoLoop::finish`), and apply it through the session skeleton. Runs
/// on an optimizer thread; rows land in a scratch log so the coordinator
/// can serialize them deterministically.
fn consume_chunk(
    rt: &Runtime,
    sessions: &mut [crate::trainer::TrainSession<crate::coordinator::grpo::GrpoLoop>],
    queues: &mut [ReplayQueue<ReplayItem>],
    cfg: &PipelineConfig,
) -> Result<Vec<TenantConsume>> {
    let mut out = Vec::with_capacity(sessions.len());
    for (sess, q) in sessions.iter_mut().zip(queues.iter_mut()) {
        let mut tc = TenantConsume::default();
        let mut scratch = RunLog::null();
        loop {
            let version = sess.completed_steps() as u64;
            let (item, dropped) = q.pop_fresh(version, cfg.max_staleness);
            tc.dropped += dropped;
            let Some((produced_at, item)) = item else { break };
            debug_assert_eq!(produced_at, item.gen.policy_version);
            tc.max_gap = tc.max_gap.max(version - produced_at);
            let grad = sess.lp.finish(rt, &item.plan, &item.gen, item.rollout_ms)?;
            tc.records.push(sess.apply(rt, grad, &mut scratch)?);
            tc.consumed += 1;
        }
        tc.rows = std::mem::take(&mut scratch.rows);
        out.push(tc);
    }
    Ok(out)
}

/// Run the async pipeline until every tenant has applied `targets[i]`
/// optimizer steps (a tenant already at or past its target produces
/// nothing — that's how successive halving freezes losers). Returns the
/// per-tenant records and the pipeline counters, and logs one `pipeline`
/// JSONL row.
pub fn run_async(
    rt: &Runtime,
    tt: &mut TenantTrainer,
    cfg: &PipelineConfig,
    targets: &[usize],
    log: &mut RunLog,
    parallel: bool,
) -> Result<PipelineOutcome> {
    let g = tt.sessions.len();
    if targets.len() != g {
        bail!("pipeline targets: {} entries for {} tenants", targets.len(), g);
    }
    let window = cfg.window();
    let t0 = Timer::start();
    let mut queues: Vec<ReplayQueue<ReplayItem>> =
        (0..g).map(|_| ReplayQueue::new(window)).collect();
    let mut records: Vec<Vec<StepRecord>> = vec![Vec::new(); g];
    let mut stats = PipelineStats::default();

    loop {
        let done = tt
            .sessions
            .iter()
            .zip(targets)
            .all(|(sess, &t)| sess.completed_steps() >= t);
        if done {
            break;
        }

        // ---- produce: plans are drawn HERE, on the coordinating thread,
        // in tenant order (session RNGs are sequential state) — each
        // tenant fills its window, gated so in-flight + applied never
        // exceeds its target
        let mut jobs: Vec<GenJob> = Vec::new();
        let mut meta: Vec<(usize, RolloutPlan, u64)> = Vec::new();
        for (i, sess) in tt.sessions.iter_mut().enumerate() {
            let version = sess.completed_steps() as u64;
            while queues[i].len() + (jobs_for(&meta, i)) < window
                && sess.completed_steps() + queues[i].len() + jobs_for(&meta, i) < targets[i]
            {
                let plan = sess.lp.plan(&mut sess.rng);
                jobs.push(GenJob {
                    id: jobs.len() as u64,
                    weights: sess.lp.policy.merged.clone(),
                    problems: Vec::new(),
                    group: sess.lp.cfg.group,
                    pb: Some(plan.pb.clone()),
                    temperature: sess.lp.cfg.temperature,
                    seed: plan.seed,
                    policy_version: version,
                });
                meta.push((i, plan, version));
            }
        }
        if jobs.is_empty() {
            // every unfinished tenant has a full queue; consume below
            if queues.iter().all(|q| q.is_empty()) {
                bail!("pipeline stalled: no jobs to produce and nothing queued");
            }
        } else {
            let n_jobs = jobs.len();
            let tw = Timer::start();
            let results = tt.pool.serve_maybe(rt, &tt.engine, jobs, parallel)?;
            let per_job_ms = tw.millis() / n_jobs as f64;
            stats.waves += 1;
            // results come back sorted by id == production order == meta order
            for (res, (i, plan, version)) in results.into_iter().zip(meta) {
                let gen = Generation {
                    rows: res.rows,
                    group: tt.sessions[i].lp.cfg.group,
                    policy_version: version,
                };
                let item = ReplayItem { plan, gen, rollout_ms: per_job_ms };
                if queues[i].push(version, item).is_err() {
                    // can't happen: production was gated on queue room
                    bail!("pipeline invariant: queue {i} overflowed its window");
                }
                stats.produced += 1;
            }
        }

        // ---- consume: optimizer threads drain static tenant chunks; the
        // partition (and therefore every session's step sequence) is a
        // pure function of (g, optimizer_threads), never of scheduling
        let threads = cfg.optimizer_threads.max(1).min(g);
        let chunk = g.div_ceil(threads);
        let consumed: Vec<Result<Vec<TenantConsume>>> = std::thread::scope(|s| {
            let handles: Vec<_> = tt
                .sessions
                .chunks_mut(chunk)
                .zip(queues.chunks_mut(chunk))
                .map(|(sc, qc)| s.spawn(move || consume_chunk(rt, sc, qc, cfg)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("optimizer thread panicked"))
                .collect()
        });
        let mut i = 0usize;
        for chunk_res in consumed {
            for tc in chunk_res.with_context(|| "pipeline consume phase")? {
                stats.consumed += tc.consumed;
                stats.dropped_stale += tc.dropped;
                stats.max_version_gap = stats.max_version_gap.max(tc.max_gap);
                for row in tc.rows {
                    log.log(row);
                }
                records[i].extend(tc.records);
                i += 1;
            }
        }
    }

    let wall = t0.secs();
    let all: Vec<&StepRecord> = records.iter().flatten().collect();
    let n = all.len().max(1) as f64;
    stats.mean_ratio = all.iter().map(|r| r.stats.mean_ratio as f64).sum::<f64>() / n;
    stats.frac_clipped = all.iter().map(|r| r.stats.frac_clipped as f64).sum::<f64>() / n;
    stats.steps_per_s = if wall > 0.0 { stats.consumed as f64 / wall } else { 0.0 };
    log.log_pipeline(
        &tt.tier,
        g,
        cfg.max_staleness,
        window,
        cfg.optimizer_threads.max(1),
        &stats,
        wall * 1e3,
    );
    Ok(PipelineOutcome { records, stats })
}

/// Jobs already planned for tenant `i` in the current produce phase.
fn jobs_for(meta: &[(usize, RolloutPlan, u64)], i: usize) -> usize {
    meta.iter().filter(|(t, _, _)| *t == i).count()
}

/// [`TenantTrainer::train`], pipelined: every tenant runs to its
/// configured step count through the async pipeline, with the same
/// tail-5 outcome aggregation as the synchronous path.
pub fn train_async(
    rt: &Runtime,
    tt: &mut TenantTrainer,
    cfg: &PipelineConfig,
    log: &mut RunLog,
    parallel: bool,
) -> Result<(Vec<TenantOutcome>, PipelineStats)> {
    let targets: Vec<usize> = tt.sessions.iter().map(|s| s.cfg.steps).collect();
    let out = run_async(rt, tt, cfg, &targets, log, parallel)?;
    let outcomes = tt
        .specs
        .iter()
        .zip(&tt.sessions)
        .zip(out.records)
        .map(|((spec, sess), steps)| {
            let tail: Vec<&StepRecord> = steps.iter().rev().take(5.min(steps.len())).collect();
            let n = tail.len().max(1) as f32;
            TenantOutcome {
                name: spec.name.clone(),
                scheme_tag: spec.scheme_tag.clone(),
                lr: spec.cfg.lr,
                seed: spec.cfg.seed,
                trainable_params: sess.lp.policy.trainable_params(),
                final_reward: tail.iter().map(|r| r.reward).sum::<f32>() / n,
                final_format_rate: tail.iter().map(|r| r.format_rate).sum::<f32>() / n,
                steps,
            }
        })
        .collect();
    Ok((outcomes, out.stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::scheduler::{AdapterBatch, QueuedRequest, SchedPolicy, Scheduler};
    use crate::testing::check;

    #[test]
    fn window_is_staleness_plus_one_by_default() {
        let cfg = PipelineConfig::default();
        assert_eq!(cfg.window(), 1);
        let cfg = PipelineConfig { max_staleness: 3, ..Default::default() };
        assert_eq!(cfg.window(), 4);
        let cfg = PipelineConfig { max_staleness: 0, queue_cap: 5, ..Default::default() };
        assert_eq!(cfg.window(), 5);
    }

    /// Property (ISSUE 10 satellite): bounded-queue backpressure — a full
    /// queue rejects the push and hands the item back; nothing already
    /// queued is ever overwritten or reordered.
    #[test]
    fn replay_queue_backpressure_never_overwrites() {
        check("replay queue backpressure", 200, |rng| {
            let cap = rng.range_i64(1, 6) as usize;
            let mut q: ReplayQueue<u64> = ReplayQueue::new(cap);
            let mut expect: Vec<u64> = Vec::new();
            for k in 0..(rng.range_i64(1, 20) as u64) {
                match q.push(0, k) {
                    Ok(()) => expect.push(k),
                    Err(item) => {
                        if item != k {
                            return Err(format!("rejected item mangled: {item} != {k}"));
                        }
                        if expect.len() != cap {
                            return Err(format!(
                                "rejected below cap: len {} cap {cap}",
                                expect.len()
                            ));
                        }
                    }
                }
                if q.len() > cap {
                    return Err(format!("queue over cap: {} > {cap}", q.len()));
                }
            }
            // drain: FIFO, exactly the accepted items
            let mut got = Vec::new();
            while let (Some((_, item)), 0) = q.pop_fresh(0, u64::MAX) {
                got.push(item);
            }
            if got != expect {
                return Err(format!("drain {got:?} != accepted {expect:?}"));
            }
            Ok(())
        });
    }

    /// Property (ISSUE 10 satellite): staleness-drop exactness — an item
    /// produced at version v is dropped iff `consume_version - v > S`,
    /// and survivors come out in FIFO order.
    #[test]
    fn staleness_drop_is_exact() {
        check("staleness drop exactness", 300, |rng| {
            let s = rng.range_i64(0, 4) as u64;
            let n = rng.range_i64(1, 12) as usize;
            // non-decreasing production versions, like a real queue
            let mut versions = Vec::with_capacity(n);
            let mut v = 0u64;
            for _ in 0..n {
                v += rng.range_i64(0, 3) as u64;
                versions.push(v);
            }
            let consume_v = v + rng.range_i64(0, 6) as u64;
            let mut q: ReplayQueue<usize> = ReplayQueue::new(n);
            for (k, &ver) in versions.iter().enumerate() {
                q.push(ver, k).map_err(|_| "push rejected below cap".to_string())?;
            }
            let mut survivors = Vec::new();
            let mut dropped = 0u64;
            loop {
                let (item, d) = q.pop_fresh(consume_v, s);
                dropped += d;
                match item {
                    Some((ver, k)) => survivors.push((ver, k)),
                    None => break,
                }
            }
            let want: Vec<(u64, usize)> = versions
                .iter()
                .enumerate()
                .filter(|(_, &ver)| consume_v - ver <= s)
                .map(|(k, &ver)| (ver, k))
                .collect();
            let want_dropped = (n - want.len()) as u64;
            if survivors != want {
                return Err(format!("survivors {survivors:?} != {want:?} (S={s})"));
            }
            if dropped != want_dropped {
                return Err(format!("dropped {dropped} != {want_dropped}"));
            }
            Ok(())
        });
    }

    /// Property (ISSUE 10 satellite): FIFO-per-tenant consume order
    /// composes with PR 9's `Scheduler::requeue` — a batch bounced back by
    /// a lost context re-enters at the queue FRONT, so groups flow into
    /// the replay queue (and out of it) in original per-tenant submit
    /// order even across a requeue.
    #[test]
    fn replay_fifo_composes_with_scheduler_requeue() {
        check("replay FIFO x requeue", 100, |rng| {
            let tenants = rng.range_i64(1, 4) as usize;
            let per = rng.range_i64(2, 6) as usize;
            let mut sched = Scheduler::new(2, 0.0, SchedPolicy::RoundRobin);
            for k in 0..per {
                for t in 0..tenants {
                    sched.push(QueuedRequest {
                        id: (k * tenants + t) as u64,
                        adapter: format!("tenant-{t}"),
                        prompt: String::new(),
                        arrival: k as f64,
                    });
                }
            }
            // drain through next_batch, bouncing a random batch once via
            // requeue (a simulated context loss mid-wave)
            let bounce_at = rng.range_i64(0, 3) as usize;
            let mut bounced = false;
            let mut queues: Vec<ReplayQueue<u64>> =
                (0..tenants).map(|_| ReplayQueue::new(per)).collect();
            let mut waves = 0usize;
            while let Some(batch) = sched.next_batch(1e9) {
                if !bounced && waves == bounce_at {
                    bounced = true;
                    waves += 1;
                    sched.requeue(AdapterBatch {
                        adapter: batch.adapter.clone(),
                        requests: batch.requests.clone(),
                    });
                    continue;
                }
                waves += 1;
                let t: usize =
                    batch.adapter.trim_start_matches("tenant-").parse().unwrap();
                for req in batch.requests {
                    queues[t]
                        .push(0, req.id)
                        .map_err(|_| "replay queue overflow".to_string())?;
                }
            }
            // per tenant, consumed ids must be the original submit order
            for (t, q) in queues.iter_mut().enumerate() {
                let mut got = Vec::new();
                while let (Some((_, id)), 0) = q.pop_fresh(0, u64::MAX) {
                    got.push(id);
                }
                let want: Vec<u64> =
                    (0..per).map(|k| (k * tenants + t) as u64).collect();
                if got != want {
                    return Err(format!("tenant {t}: {got:?} != {want:?}"));
                }
            }
            Ok(())
        });
    }
}
