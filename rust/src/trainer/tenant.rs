//! The multi-tenant adapter-training plane — the paper's headline as a
//! *systems* claim: a 26-byte TinyLoRA update per tenant means G tenants
//! can train concurrently against ONE shared backbone, their rollout waves
//! interleaved on the same fused-generate executables (the same Punica-style
//! multi-tenant economics that motivate the serving plane, §1).
//!
//! Each tenant is an independent `TrainSession<GrpoLoop>` (own adapter
//! theta, own Adam moments, own RNG stream). Per global step the trainer
//! plans every tenant's rollout on the coordinating thread (session RNGs
//! are sequential state), fans the decode wave across `engine::WorkerPool`,
//! then applies each tenant's gradient through its session. Plans carry
//! their rollout seed, and the pool derives decode RNGs on the same stream
//! as the in-loop path — so parallel results are bit-identical to serial
//! ones, and a TenantTrainer run of G tenants equals G separate runs
//! (asserted in `tests/integration.rs`). With a device-parallel runtime
//! (`Runtime::with_devices`), wave jobs additionally pin to execution
//! contexts by tenant index (`job.id % devices`), so up to D tenants'
//! decodes run concurrently on the device instead of serialising on one
//! global FFI lock — the job→context map is a pure function of the
//! tenant, keeping pooled == serial byte-identical at any D.
//!
//! Finished tenants register straight into the serving `AdapterStore`'s
//! *cold tier* — one packed ~26-byte record appended to a contiguous
//! arena, no merge, no per-tenant heap allocation — closing the
//! train→serve loop at a cost that scales to millions of tenants
//! (serving promotes cold → warm → hot lazily on first request; see
//! `serving/store/` and DESIGN.md §12).
//!
//! Backend-blind: the plane resolves everything through the manifest
//! (grad/merge/generate entry points), so the same trainer runs on PJRT
//! artifacts and on the hermetic sim backend — `tests/e2e_sim.rs` asserts
//! tenant-wave == independent-runs bit-identity on sim in every CI run,
//! artifacts or not.
//!
//! Known memory bound: each tenant's `Policy` currently clones the frozen
//! base `WeightSet` (and waves clone merged weights into their `GenJob`s),
//! so residency is O(G · n_params) — fine at the current tiers (~0.5 MB
//! per copy), but the backbone should move behind `Arc` before tenant
//! counts scale to the thousands the 26-byte storage argument invites.

use std::path::Path;

use anyhow::{bail, Result};

use crate::adapters::packing::Precision;
use crate::coordinator::grpo::{grpo_session_cfg, GrpoConfig, GrpoLoop, StepRecord};
use crate::coordinator::policy::Policy;
use crate::engine::pool::{GenJob, WorkerPool};
use crate::engine::InferenceEngine;
use crate::metrics::RunLog;
use crate::runtime::Runtime;
use crate::serving::AdapterStore;
use crate::trainer::TrainSession;
use crate::util::Timer;
use crate::weights::WeightSet;

/// One tenant's full training configuration.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    /// Adapter name registered into the serving store.
    pub name: String,
    pub scheme_tag: String,
    pub cfg: GrpoConfig,
    /// Storage precision of the registered update (bf16 = the 26-byte
    /// headline for the 13-param scheme).
    pub precision: Precision,
}

/// What one tenant's run produced.
#[derive(Clone, Debug)]
pub struct TenantOutcome {
    pub name: String,
    pub scheme_tag: String,
    pub lr: f32,
    pub seed: u64,
    pub trainable_params: usize,
    /// mean reward / format rate over the last ≤5 steps
    pub final_reward: f32,
    pub final_format_rate: f32,
    pub steps: Vec<StepRecord>,
}

pub struct TenantTrainer {
    pub tier: String,
    /// Shared decode engine for the pooled rollout waves (same executable
    /// geometry as every tenant's in-loop engine).
    pub(crate) engine: InferenceEngine,
    pub(crate) pool: WorkerPool,
    pub sessions: Vec<TrainSession<GrpoLoop>>,
    pub(crate) specs: Vec<TenantSpec>,
}

impl TenantTrainer {
    /// Training-plane geometry (`manifest.batch.roll`).
    pub fn new(
        rt: &Runtime,
        base: &WeightSet,
        specs: Vec<TenantSpec>,
        workers: usize,
        ckpt_dir: &Path,
    ) -> Result<Self> {
        let batch = rt.manifest.batch.roll;
        Self::with_batch(rt, base, specs, workers, ckpt_dir, batch)
    }

    /// Explicit decode geometry (tests and tiny tiers use `batch.test`).
    pub fn with_batch(
        rt: &Runtime,
        base: &WeightSet,
        specs: Vec<TenantSpec>,
        workers: usize,
        ckpt_dir: &Path,
        batch: usize,
    ) -> Result<Self> {
        if specs.is_empty() {
            bail!("tenant trainer needs at least one tenant");
        }
        let steps0 = specs[0].cfg.steps;
        if specs.iter().any(|s| s.cfg.steps != steps0) {
            bail!("tenant step counts must match (waves are synchronized)");
        }
        let tier = base.tier.clone();
        let engine = InferenceEngine::new(rt, &tier, batch)?;
        // rollout waves must fill the baked geometry exactly (group *
        // prompts == batch); reject a bad group now instead of failing
        // G sessions deep into the first wave
        for spec in &specs {
            if spec.cfg.group == 0 || engine.batch % spec.cfg.group != 0 {
                bail!(
                    "tenant {}: group {} does not divide the decode batch {}",
                    spec.name,
                    spec.cfg.group,
                    engine.batch
                );
            }
        }
        let mut sessions = Vec::with_capacity(specs.len());
        for spec in &specs {
            let mut policy = Policy::new(
                rt,
                &tier,
                &spec.scheme_tag,
                "grpo",
                base.clone(),
                spec.cfg.seed,
                ckpt_dir,
            )?;
            policy.precision = spec.precision;
            let lp = GrpoLoop::with_batch(rt, policy, spec.cfg.clone(), batch)?;
            let scfg = grpo_session_cfg(&spec.cfg);
            sessions.push(TrainSession::new(lp, scfg));
        }
        Ok(Self { tier, engine, pool: WorkerPool::new(workers), sessions, specs })
    }

    /// Shared engine (pool occupancy / decode stats across all tenants).
    pub fn engine(&self) -> &InferenceEngine {
        &self.engine
    }

    /// One synchronized wave: plan every tenant's rollout on this thread,
    /// decode the wave through the pool (or its serial reference path when
    /// `parallel` is false — results are bit-identical), then run each
    /// tenant's grad + optimizer step through its own session.
    pub fn step_wave(
        &mut self,
        rt: &Runtime,
        log: &mut RunLog,
        parallel: bool,
    ) -> Result<Vec<StepRecord>> {
        let g = self.sessions.len();
        let mut plans = Vec::with_capacity(g);
        let mut jobs = Vec::with_capacity(g);
        for (i, sess) in self.sessions.iter_mut().enumerate() {
            let plan = sess.lp.plan(&mut sess.rng);
            jobs.push(GenJob {
                id: i as u64,
                weights: sess.lp.policy.merged.clone(),
                problems: Vec::new(),
                group: sess.lp.cfg.group,
                // ship the planner's already-tokenized batch; the worker
                // decodes it directly instead of re-assembling
                pb: Some(plan.pb.clone()),
                temperature: sess.lp.cfg.temperature,
                seed: plan.seed,
                policy_version: sess.completed_steps() as u64,
            });
            plans.push(plan);
        }
        let t0 = Timer::start();
        let results = self.pool.serve_maybe(rt, &self.engine, jobs, parallel)?;
        // results come back sorted by job id == tenant index
        let wave_ms = t0.millis();
        let per_tenant_ms = wave_ms / g as f64;
        let mut records = Vec::with_capacity(g);
        for ((sess, plan), res) in self.sessions.iter_mut().zip(&plans).zip(results) {
            // synchronous consume: the rollout is always exactly on-policy
            let roll = crate::engine::Generation {
                rows: res.rows,
                group: sess.lp.cfg.group,
                policy_version: sess.completed_steps() as u64,
            };
            let out = sess.lp.finish(rt, plan, &roll, per_tenant_ms)?;
            records.push(sess.apply(rt, out, log)?);
        }
        Ok(records)
    }

    /// Run every tenant to its configured step count in synchronized waves.
    pub fn train(
        &mut self,
        rt: &Runtime,
        log: &mut RunLog,
        parallel: bool,
    ) -> Result<Vec<TenantOutcome>> {
        let steps = self.specs[0].cfg.steps;
        let mut all: Vec<Vec<StepRecord>> = vec![Vec::with_capacity(steps); self.sessions.len()];
        for _ in 0..steps {
            for (i, rec) in self.step_wave(rt, log, parallel)?.into_iter().enumerate() {
                all[i].push(rec);
            }
        }
        Ok(self
            .specs
            .iter()
            .zip(&self.sessions)
            .zip(all)
            .map(|((spec, sess), steps)| {
                let tail: Vec<&StepRecord> =
                    steps.iter().rev().take(5.min(steps.len())).collect();
                let n = tail.len().max(1) as f32;
                TenantOutcome {
                    name: spec.name.clone(),
                    scheme_tag: spec.scheme_tag.clone(),
                    lr: spec.cfg.lr,
                    seed: spec.cfg.seed,
                    trainable_params: sess.lp.policy.trainable_params(),
                    final_reward: tail.iter().map(|r| r.reward).sum::<f32>() / n,
                    final_format_rate: tail.iter().map(|r| r.format_rate).sum::<f32>() / n,
                    steps,
                }
            })
            .collect())
    }

    /// Close the train→serve loop: pack every tenant's adapter at its
    /// storage precision into the serving store.
    pub fn register_into(&self, store: &mut AdapterStore) -> Result<()> {
        for (spec, sess) in self.specs.iter().zip(&self.sessions) {
            store.register(
                &spec.name,
                &sess.lp.policy.scheme_tag,
                &sess.lp.policy.theta,
                spec.precision,
            )?;
        }
        Ok(())
    }

    /// Consume the trainer, handing back the per-tenant sessions (figure
    /// drivers evaluate each tenant's merged weights from here).
    pub fn into_sessions(self) -> Vec<TrainSession<GrpoLoop>> {
        self.sessions
    }
}
