//! Synthetic math benchmark suite — the stand-in for GSM8K / MATH500 /
//! Minerva / OlympiadBench / AIME / AMC (see DESIGN.md §2 for the
//! substitution argument).  Deterministic templated word problems with
//! verifiable integer answers, over a difficulty ladder that mirrors the
//! paper's evaluation suites; the reward is exact-match on the canonical
//! `#### <answer>` format, exactly as in the paper's RLVR setup.
//!
//! Submodules: [`generator`] (the suites and their problem templates),
//! [`verifier`] (answer extraction + binary reward), [`corpus`] (batch
//! builders and the pretraining format mixture).  The benchmark subsystem
//! (`eval::bench`) layers per-suite decode budgets and pass@k/maj@k
//! scoring on top of these generators.

pub mod corpus;
pub mod generator;
pub mod verifier;

pub use generator::{Problem, Suite, SUITES};
pub use verifier::{extract_answer, reward};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;
    use crate::tokenizer::Tokenizer;

    #[test]
    fn all_suites_generate_verifiable_problems() {
        let tok = Tokenizer::new();
        for suite in SUITES {
            check(&format!("suite {}", suite.name), 60, |rng| {
                let p = suite.generate(rng);
                // gold reasoning must end with the canonical answer format
                match extract_answer(&p.gold) {
                    Some(a) if a == p.answer => {}
                    other => return Err(format!("gold {:?} -> {:?}", p.gold, other)),
                }
                if reward(&p.gold, p.answer) != 1.0 {
                    return Err("gold does not earn reward".into());
                }
                // prompts and golds must fit the model's sequence budget
                let np = tok.encode(&p.prompt).len();
                let ng = tok.encode(&p.gold).len();
                if np > 62 {
                    return Err(format!("prompt too long ({np}): {:?}", p.prompt));
                }
                if ng > 60 {
                    return Err(format!("gold too long ({ng}): {:?}", p.gold));
                }
                Ok(())
            });
        }
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let s = &SUITES[0];
        let p1 = {
            let mut rng = crate::util::Pcg64::new(7);
            s.generate(&mut rng)
        };
        let p2 = {
            let mut rng = crate::util::Pcg64::new(7);
            s.generate(&mut rng)
        };
        assert_eq!(p1.prompt, p2.prompt);
        assert_eq!(p1.answer, p2.answer);
    }

    #[test]
    fn difficulty_ladder_increases_steps() {
        // later suites must have >= expected reasoning steps than gsm8k-syn
        let mut rng = crate::util::Pcg64::new(3);
        let easy: f32 = (0..200)
            .map(|_| SUITES[0].generate(&mut rng).gold.matches('\n').count() as f32)
            .sum::<f32>()
            / 200.0;
        let hard: f32 = (0..200)
            .map(|_| SUITES[4].generate(&mut rng).gold.matches('\n').count() as f32)
            .sum::<f32>()
            / 200.0;
        assert!(hard > easy, "aime-syn ({hard}) should out-step gsm8k-syn ({easy})");
    }
}
