//! Templated problem generators over a difficulty ladder.
//!
//! Each suite mirrors one of the paper's evaluation datasets in *relative
//! difficulty* (steps, operand size, operation mix).  Prompts are compact
//! word problems; gold solutions are scratchpad lines (`a+b=c`) ending with
//! the canonical `#### answer` line the verifier rewards.

use anyhow::{bail, Context, Result};

use crate::util::Pcg64;

#[derive(Clone, Debug, PartialEq)]
pub struct Problem {
    pub prompt: String,
    /// Gold scratchpad + `#### answer` (canonical format A).
    pub gold: String,
    pub answer: i64,
    pub suite: &'static str,
}

#[derive(Clone, Copy)]
pub struct Suite {
    pub name: &'static str,
    /// which paper benchmark this tier stands in for
    pub stands_in_for: &'static str,
    pub min_steps: usize,
    pub max_steps: usize,
    pub max_operand: i64,
    pub allow_mul: bool,
    pub allow_expr: bool,
}

pub const SUITES: &[Suite] = &[
    Suite { name: "gsm8k-syn", stands_in_for: "GSM8K", min_steps: 1, max_steps: 2, max_operand: 99, allow_mul: false, allow_expr: false },
    Suite { name: "math500-syn", stands_in_for: "MATH500", min_steps: 2, max_steps: 2, max_operand: 99, allow_mul: true, allow_expr: false },
    Suite { name: "minerva-syn", stands_in_for: "Minerva Math", min_steps: 2, max_steps: 3, max_operand: 99, allow_mul: true, allow_expr: false },
    Suite { name: "olympiad-syn", stands_in_for: "OlympiadBench", min_steps: 3, max_steps: 3, max_operand: 99, allow_mul: true, allow_expr: true },
    Suite { name: "aime-syn", stands_in_for: "AIME24", min_steps: 3, max_steps: 4, max_operand: 99, allow_mul: true, allow_expr: false },
    Suite { name: "amc-syn", stands_in_for: "AMC23", min_steps: 2, max_steps: 3, max_operand: 50, allow_mul: true, allow_expr: true },
];

pub fn suite(name: &str) -> Option<&'static Suite> {
    SUITES.iter().find(|s| s.name == name)
}

/// Parse and arithmetically check one gold scratchpad line `a⊕b=c`
/// (⊕ ∈ {+,-,*}), returning `c`. Corpus lines are data, so a malformed
/// or arithmetically wrong line is an error naming the line — never a
/// panic (the seed's test helper panicked with "bad line ...").
pub fn check_gold_line(line: &str) -> Result<i64> {
    let (lhs, rhs) = line
        .split_once('=')
        .with_context(|| format!("bad corpus line {line:?}: no '='"))?;
    let want: i64 =
        rhs.trim().parse().with_context(|| format!("bad corpus line {line:?}: rhs not a number"))?;
    // operator search skips index 0 so a leading '-' reads as a sign
    let op_at = lhs
        .char_indices()
        .skip(1)
        .find(|&(_, c)| c == '+' || c == '-' || c == '*')
        .map(|(i, _)| i)
        .with_context(|| format!("bad corpus line {line:?}: no operator in {lhs:?}"))?;
    let a: i64 = lhs[..op_at]
        .trim()
        .parse()
        .with_context(|| format!("bad corpus line {line:?}: first operand"))?;
    let b: i64 = lhs[op_at + 1..]
        .trim()
        .parse()
        .with_context(|| format!("bad corpus line {line:?}: second operand"))?;
    let got = match lhs.as_bytes()[op_at] {
        b'+' => a + b,
        b'-' => a - b,
        b'*' => a * b,
        _ => unreachable!("operator search only matches + - *"),
    };
    if got != want {
        bail!("bad corpus line {line:?}: {a} {} {b} = {got}, not {want}", lhs.as_bytes()[op_at] as char);
    }
    Ok(want)
}

/// Validate a problem's whole gold scratchpad: every `a⊕b=c` line checks
/// out and the final `#### answer` line matches `p.answer`.
pub fn validate_gold(p: &Problem) -> Result<()> {
    let mut saw_answer = false;
    for line in p.gold.lines() {
        if let Some(ans) = line.strip_prefix("#### ") {
            let ans: i64 =
                ans.trim().parse().with_context(|| format!("bad answer line {line:?}"))?;
            if ans != p.answer {
                bail!("answer line says {ans}, problem says {}", p.answer);
            }
            saw_answer = true;
        } else if line.contains('=') {
            check_gold_line(line)?;
        }
    }
    if !saw_answer {
        bail!("gold scratchpad has no '#### answer' line");
    }
    Ok(())
}

const NAMES: &[&str] = &["ann", "ben", "tom", "sam", "kim", "leo", "mia", "dan"];
const ITEMS: &[&str] = &["pens", "cups", "nuts", "coins", "books", "cards", "kites", "stars"];

#[derive(Clone, Copy, Debug, PartialEq)]
enum Op {
    Add,
    Sub,
    Mul,
}

impl Suite {
    pub fn generate(&self, rng: &mut Pcg64) -> Problem {
        if self.allow_expr && rng.uniform() < 0.35 {
            return self.gen_expression(rng);
        }
        if self.max_steps <= 2 && rng.uniform() < 0.6 {
            self.gen_word_problem(rng)
        } else {
            self.gen_chain(rng)
        }
    }

    fn pick_op(&self, rng: &mut Pcg64) -> Op {
        if self.allow_mul && rng.uniform() < 0.3 {
            Op::Mul
        } else if rng.uniform() < 0.5 {
            Op::Add
        } else {
            Op::Sub
        }
    }

    /// Apply `op` to acc with a fresh operand, keeping 0 <= result <= 999.
    /// Falls back to a safe operation when `op` would leave the range.
    fn step(&self, rng: &mut Pcg64, acc: i64, op: Op) -> (i64, i64, char) {
        let op = match op {
            Op::Mul if acc < 2 || acc * 2 > 999 => Op::Sub,
            Op::Add if acc + 2 > 999 => Op::Sub,
            o => o,
        };
        let op = if acc < 1 && op == Op::Sub { Op::Add } else { op };
        match op {
            Op::Add => {
                let b = rng.range_i64(2, self.max_operand.min(999 - acc));
                (acc + b, b, '+')
            }
            Op::Sub => {
                let b = rng.range_i64(1, acc);
                (acc - b, b, '-')
            }
            Op::Mul => {
                let cap = (999 / acc).min(9);
                let b = rng.range_i64(2, cap);
                (acc * b, b, '*')
            }
        }
    }

    /// One/two-step natural-language word problems (gsm8k style).
    fn gen_word_problem(&self, rng: &mut Pcg64) -> Problem {
        let who = *rng.choice(NAMES);
        let who2 = *rng.choice(NAMES);
        let item = *rng.choice(ITEMS);
        let a = rng.range_i64(2, self.max_operand);
        let mut lines = Vec::new();
        let (prompt, answer) = match rng.below(4) {
            0 => {
                let b = rng.range_i64(2, self.max_operand);
                lines.push(format!("{a}+{b}={}", a + b));
                (format!("{who} has {a} {item}. {who2} gives her {b} more. how many now?"), a + b)
            }
            1 => {
                let b = rng.range_i64(1, a);
                lines.push(format!("{a}-{b}={}", a - b));
                (format!("{who} had {a} {item} and lost {b}. how many left?"), a - b)
            }
            2 if self.allow_mul => {
                let b = rng.range_i64(2, 9);
                lines.push(format!("{a}*{b}={}", a * b));
                (format!("a box holds {a} {item}. how many in {b} boxes?"), a * b)
            }
            _ => {
                let b = rng.range_i64(2, self.max_operand);
                let c = rng.range_i64(1, a + b);
                lines.push(format!("{a}+{b}={}", a + b));
                lines.push(format!("{}-{c}={}", a + b, a + b - c));
                (
                    format!("{who} got {a} {item}, then {b} more, then lost {c}. total?"),
                    a + b - c,
                )
            }
        };
        lines.push(format!("#### {answer}"));
        Problem { prompt, gold: lines.join("\n"), answer, suite: self.name }
    }

    /// Multi-step imperative chains ("start with a. add b. ...").
    fn gen_chain(&self, rng: &mut Pcg64) -> Problem {
        let n_steps = rng.range_i64(self.min_steps as i64, self.max_steps as i64) as usize;
        let mut acc = rng.range_i64(2, self.max_operand);
        let mut prompt = format!("start with {acc}.");
        let mut lines = Vec::new();
        for _ in 0..n_steps {
            let op = self.pick_op(rng);
            let prev = acc;
            let (next, b, sym) = self.step(rng, acc, op);
            acc = next;
            let verb = match sym {
                '+' => format!(" add {b}."),
                '-' => format!(" sub {b}."),
                _ => format!(" times {b}."),
            };
            prompt.push_str(&verb);
            lines.push(format!("{prev}{sym}{b}={acc}"));
        }
        prompt.push_str(" result?");
        lines.push(format!("#### {acc}"));
        Problem { prompt, gold: lines.join("\n"), answer: acc, suite: self.name }
    }

    /// Parenthesised expression evaluation (amc/olympiad style).
    fn gen_expression(&self, rng: &mut Pcg64) -> Problem {
        let a = rng.range_i64(2, self.max_operand.min(50));
        let b = rng.range_i64(2, self.max_operand.min(50));
        let c = rng.range_i64(2, 9);
        let d = rng.range_i64(1, 99);
        let (prompt, lines, answer) = if rng.uniform() < 0.5 {
            let s1 = a + b;
            let s2 = s1 * c;
            let ans = s2 - d.min(s2);
            let d = d.min(s2);
            (
                format!("what is ({a}+{b})*{c}-{d}?"),
                vec![
                    format!("{a}+{b}={s1}"),
                    format!("{s1}*{c}={s2}"),
                    format!("{s2}-{d}={ans}"),
                ],
                ans,
            )
        } else {
            let s1 = a * c;
            let ans = s1 + b;
            (
                format!("what is {a}*{c}+{b}?"),
                vec![format!("{a}*{c}={s1}"), format!("{s1}+{b}={ans}")],
                ans,
            )
        };
        let mut lines = lines;
        lines.push(format!("#### {answer}"));
        Problem { prompt, gold: lines.join("\n"), answer, suite: self.name }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_lookup() {
        assert_eq!(suite("gsm8k-syn").unwrap().name, "gsm8k-syn");
        assert!(suite("nope").is_none());
    }

    #[test]
    fn chains_respect_value_bounds() {
        let mut rng = Pcg64::new(11);
        for s in SUITES {
            for _ in 0..200 {
                let p = s.generate(&mut rng);
                assert!(p.answer >= 0 && p.answer <= 999, "{:?}", p);
                // every intermediate on each line must be in bounds
                for line in p.gold.lines() {
                    if let Some((_, rhs)) = line.split_once('=') {
                        let v: i64 = rhs.parse().unwrap();
                        assert!((0..=999).contains(&v), "line {line} in {:?}", p);
                    }
                }
            }
        }
    }

    #[test]
    fn gold_scratchpad_is_arithmetically_correct() {
        let mut rng = Pcg64::new(13);
        for s in SUITES {
            for _ in 0..100 {
                let p = s.generate(&mut rng);
                validate_gold(&p).unwrap_or_else(|e| panic!("{e:#} in {:?}", p.gold));
            }
        }
    }

    /// ISSUE 5 satellite: malformed corpus lines are structured errors
    /// naming the offending line, never panics.
    #[test]
    fn malformed_corpus_lines_are_errors() {
        // well-formed lines parse (leading '-' reads as a sign)
        assert_eq!(check_gold_line("2+3=5").unwrap(), 5);
        assert_eq!(check_gold_line("10-12=-2").unwrap(), -2);
        assert_eq!(check_gold_line("-2*3=-6").unwrap(), -6);
        for bad in [
            "garbage",        // no '='
            "2+3=",           // empty rhs
            "2+3=x",          // non-numeric rhs
            "23=23",          // no operator
            "2/4=0",          // unsupported operator
            "2+3=6",          // arithmetic lie
            "+=5",            // missing operands
        ] {
            let err = check_gold_line(bad).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains(bad), "error must name the line: {msg}");
        }
        // a corrupted gold scratchpad fails validation as a whole
        let mut rng = Pcg64::new(1);
        let mut p = SUITES[0].generate(&mut rng);
        assert!(validate_gold(&p).is_ok());
        p.gold = p.gold.replacen("####", "?###", 1);
        assert!(validate_gold(&p).is_err(), "missing answer line must be an error");
        let mut q = SUITES[0].generate(&mut rng);
        q.answer += 1; // answer line no longer matches the problem
        assert!(validate_gold(&q).is_err());
    }
}
