//! Exact-match answer verification (the paper's RLVR reward).
//!
//! The model earns reward 1.0 iff its response contains the canonical
//! `#### <integer>` line whose value equals the gold answer — format *and*
//! arithmetic both matter, exactly as in the paper's GSM8K protocol.

/// Extract the answer from the LAST `####` marker (models sometimes emit
/// several; graders take the final one).
///
/// ```
/// use tinylora_rl::tasks::extract_answer;
/// assert_eq!(extract_answer("12+3=15\n#### 15"), Some(15));
/// assert_eq!(extract_answer("#### 1\nwait\n#### 2"), Some(2));
/// assert_eq!(extract_answer("the answer is 5"), None);
/// ```
pub fn extract_answer(text: &str) -> Option<i64> {
    let idx = text.rfind("####")?;
    let rest = text[idx + 4..].trim_start();
    let mut end = 0;
    let bytes = rest.as_bytes();
    if end < bytes.len() && (bytes[end] == b'-' || bytes[end] == b'+') {
        end += 1;
    }
    while end < bytes.len() && bytes[end].is_ascii_digit() {
        end += 1;
    }
    if end == 0 || (end == 1 && !bytes[0].is_ascii_digit()) {
        return None;
    }
    rest[..end].parse().ok()
}

/// Binary exact-match reward.
pub fn reward(response: &str, gold_answer: i64) -> f32 {
    match extract_answer(response) {
        Some(a) if a == gold_answer => 1.0,
        _ => 0.0,
    }
}

/// Diagnostic: does the response use the rewarded format at all?
/// (Used by the elicitation analysis — RL mostly shifts *format*.)
pub fn has_canonical_format(response: &str) -> bool {
    extract_answer(response).is_some()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extracts_canonical() {
        assert_eq!(extract_answer("12+3=15\n#### 15"), Some(15));
        assert_eq!(extract_answer("#### -7"), Some(-7));
        assert_eq!(extract_answer("####42"), Some(42));
    }

    #[test]
    fn takes_last_marker() {
        assert_eq!(extract_answer("#### 1\nwait\n#### 2"), Some(2));
    }

    #[test]
    fn rejects_missing_or_malformed() {
        assert_eq!(extract_answer("the answer is 5"), None);
        assert_eq!(extract_answer("#### abc"), None);
        assert_eq!(extract_answer(""), None);
        assert_eq!(extract_answer("####"), None);
    }

    #[test]
    fn reward_requires_format_and_value() {
        assert_eq!(reward("5+5=10\n#### 10", 10), 1.0);
        assert_eq!(reward("5+5=10\n= 10", 10), 0.0); // right value, wrong format
        assert_eq!(reward("#### 11", 10), 0.0); // wrong value
    }

    #[test]
    fn format_diagnostic() {
        assert!(has_canonical_format("#### 3"));
        assert!(!has_canonical_format("3"));
    }
}
