//! Batch builders: pretraining corpus, SFT demonstrations and rollout
//! prompt batches.  This module owns the sequence-layout conventions shared
//! by every trainer:
//!
//!   prompt tokens   = [BOS] + encode(prompt + "\n")
//!   response tokens = encode(solution) + [EOS]
//!   training doc    = prompt ++ response, right-padded with PAD
//!   target_mask[t]  = 1 iff tokens[t+1] is a token the loss should score
//!
//! The pretraining corpus deliberately mixes *answer formats* (only one of
//! which the verifier rewards) so that the base model has the capability
//! but not the style — the situation the paper's RL-elicitation story
//! requires (DESIGN.md §2).

use crate::tasks::generator::{Problem, Suite};
use crate::tensor::{TensorF32, TensorI32};
use crate::tokenizer::{Tokenizer, BOS, EOS, PAD};
use crate::util::Pcg64;

/// Share of pretraining docs that are bare arithmetic drills.
const DRILL_FRAC: f32 = 0.3;
/// Answer-format mixture for pretraining docs: (canonical ####, "= n", bare).
pub const FORMAT_MIX: [f32; 3] = [0.35, 0.40, 0.25];

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AnswerFormat {
    Canonical, // "#### 42"  — the only format the verifier rewards
    Equals,    // ">> = 42"
    Bare,      // "42"
}

/// Render a problem's solution in a given format (scratchpad + answer line).
pub fn render_solution(p: &Problem, fmt: AnswerFormat) -> String {
    let scratch: Vec<&str> = p.gold.lines().filter(|l| !l.starts_with("####")).collect();
    let mut s = scratch.join("\n");
    if !s.is_empty() {
        s.push('\n');
    }
    match fmt {
        AnswerFormat::Canonical => s.push_str(&format!("#### {}", p.answer)),
        AnswerFormat::Equals => s.push_str(&format!("= {}", p.answer)),
        AnswerFormat::Bare => s.push_str(&format!("{}", p.answer)),
    }
    s
}

pub fn prompt_tokens(tok: &Tokenizer, prompt: &str) -> Vec<i32> {
    let mut ids = vec![BOS];
    ids.extend(tok.encode(prompt));
    ids.extend(tok.encode("\n"));
    ids
}

pub fn response_tokens(tok: &Tokenizer, solution: &str) -> Vec<i32> {
    let mut ids = tok.encode(solution);
    ids.push(EOS);
    ids
}

/// One pretraining document (token ids, unpadded).
fn pretrain_doc(suite: &Suite, tok: &Tokenizer, rng: &mut Pcg64, budget: usize) -> Vec<i32> {
    if rng.uniform() < DRILL_FRAC {
        // arithmetic drill: lines of "a+b=c" / "a*b=c" until budget
        let mut ids = vec![BOS];
        while ids.len() + 10 < budget {
            let a = rng.range_i64(2, 99);
            let line = if rng.uniform() < 0.3 {
                let b = rng.range_i64(2, 9);
                format!("{a}*{b}={}\n", a * b)
            } else if rng.uniform() < 0.5 {
                let b = rng.range_i64(2, 99);
                format!("{a}+{b}={}\n", a + b)
            } else {
                let b = rng.range_i64(1, a);
                format!("{a}-{b}={}\n", a - b)
            };
            ids.extend(tok.encode(&line));
        }
        ids.push(EOS);
        ids.truncate(budget);
        return ids;
    }
    let p = suite.generate(rng);
    let u = rng.uniform();
    let fmt = if u < FORMAT_MIX[0] {
        AnswerFormat::Canonical
    } else if u < FORMAT_MIX[0] + FORMAT_MIX[1] {
        AnswerFormat::Equals
    } else {
        AnswerFormat::Bare
    };
    let mut ids = prompt_tokens(tok, &p.prompt);
    ids.extend(response_tokens(tok, &render_solution(&p, fmt)));
    ids.truncate(budget);
    ids
}

/// Pad a doc to length `t` and derive the all-token target mask.
fn pad_and_mask(mut ids: Vec<i32>, t: usize) -> (Vec<i32>, Vec<f32>) {
    ids.truncate(t);
    let real = ids.len();
    ids.resize(t, PAD);
    // mask[j] scores the prediction of tokens[j+1]
    let mut mask = vec![0.0f32; t - 1];
    for j in 0..real.saturating_sub(1).min(t - 1) {
        mask[j] = 1.0;
    }
    (ids, mask)
}

/// Pretraining batch: [b, t] tokens + [b, t-1] mask (LM loss on all tokens).
pub fn pretrain_batch(
    suite: &Suite,
    tok: &Tokenizer,
    rng: &mut Pcg64,
    b: usize,
    t: usize,
) -> (TensorI32, TensorF32) {
    let mut tokens = Vec::with_capacity(b * t);
    let mut mask = Vec::with_capacity(b * (t - 1));
    for _ in 0..b {
        let (ids, m) = pad_and_mask(pretrain_doc(suite, tok, rng, t), t);
        tokens.extend(ids);
        mask.extend(m);
    }
    (
        TensorI32::from_vec(&[b, t], tokens),
        TensorF32::from_vec(&[b, t - 1], mask),
    )
}

/// SFT batch: gold canonical demonstrations, loss masked to response tokens
/// only (the paper's SFT baseline).
pub fn sft_batch(
    suite: &Suite,
    tok: &Tokenizer,
    rng: &mut Pcg64,
    b: usize,
    t: usize,
) -> (TensorI32, TensorF32) {
    let mut tokens = Vec::with_capacity(b * t);
    let mut mask = Vec::with_capacity(b * (t - 1));
    for _ in 0..b {
        let p = suite.generate(rng);
        let pt = prompt_tokens(tok, &p.prompt);
        let rt = response_tokens(tok, &render_solution(&p, AnswerFormat::Canonical));
        let plen = pt.len();
        let mut ids = pt;
        ids.extend(rt);
        ids.truncate(t);
        let real = ids.len();
        ids.resize(t, PAD);
        let mut m = vec![0.0f32; t - 1];
        // score only predictions of response tokens: positions plen..real
        for j in plen.saturating_sub(1)..real.saturating_sub(1).min(t - 1) {
            m[j] = 1.0;
        }
        tokens.extend(ids);
        mask.extend(m);
    }
    (
        TensorI32::from_vec(&[b, t], tokens),
        TensorF32::from_vec(&[b, t - 1], mask),
    )
}

/// A rollout prompt batch: `n_prompts` problems, each repeated `group`
/// times (GRPO's per-prompt groups), right-padded to t_prefill.
#[derive(Clone)]
pub struct PromptBatch {
    pub problems: Vec<Problem>,
    /// [b, t_prefill] right-padded prompt tokens
    pub tokens: TensorI32,
    /// [b] true prompt lengths
    pub prompt_len: TensorI32,
    pub group: usize,
}

pub fn prompt_batch(
    problems: &[Problem],
    tok: &Tokenizer,
    group: usize,
    t_prefill: usize,
) -> PromptBatch {
    let b = problems.len() * group;
    let mut tokens = Vec::with_capacity(b * t_prefill);
    let mut plen = Vec::with_capacity(b);
    let mut flat = Vec::with_capacity(b);
    for p in problems {
        let mut ids = prompt_tokens(tok, &p.prompt);
        ids.truncate(t_prefill);
        let real = ids.len();
        ids.resize(t_prefill, PAD);
        for _ in 0..group {
            tokens.extend_from_slice(&ids);
            plen.push(real as i32);
            flat.push(p.clone());
        }
    }
    PromptBatch {
        problems: flat,
        tokens: TensorI32::from_vec(&[b, t_prefill], tokens),
        prompt_len: TensorI32::from_vec(&[b], plen),
        group,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::generator::SUITES;
    use crate::tasks::verifier::extract_answer;

    #[test]
    fn format_rendering() {
        let mut rng = Pcg64::new(1);
        let p = SUITES[0].generate(&mut rng);
        assert!(render_solution(&p, AnswerFormat::Canonical).contains("####"));
        assert!(render_solution(&p, AnswerFormat::Equals).ends_with(&format!("= {}", p.answer)));
        assert!(!render_solution(&p, AnswerFormat::Equals).contains("####"));
        assert_eq!(
            extract_answer(&render_solution(&p, AnswerFormat::Canonical)),
            Some(p.answer)
        );
    }

    #[test]
    fn pretrain_batch_shapes_and_mask() {
        let tok = Tokenizer::new();
        let mut rng = Pcg64::new(2);
        let (tokens, mask) = pretrain_batch(&SUITES[0], &tok, &mut rng, 4, 64);
        assert_eq!(tokens.shape, vec![4, 64]);
        assert_eq!(mask.shape, vec![4, 63]);
        for b in 0..4 {
            assert_eq!(tokens.data[b * 64], BOS);
            // mask is 1 exactly while the *next* token is real
            for j in 0..63 {
                let next_real = tokens.data[b * 64 + j + 1] != PAD;
                assert_eq!(mask.data[b * 63 + j] == 1.0, next_real, "b={b} j={j}");
            }
        }
    }

    #[test]
    fn sft_mask_covers_response_only() {
        let tok = Tokenizer::new();
        let mut rng = Pcg64::new(3);
        let (tokens, mask) = sft_batch(&SUITES[0], &tok, &mut rng, 2, 96);
        for b in 0..2 {
            // find the newline ending the prompt (first \n token after BOS)
            let nl = tok.encode("\n")[0];
            let row = &tokens.data[b * 96..(b + 1) * 96];
            let prompt_end = row.iter().position(|&x| x == nl).unwrap();
            // no scored position before the prompt's final token
            for j in 0..prompt_end.saturating_sub(1) {
                assert_eq!(mask.data[b * 95 + j], 0.0, "b={b} j={j}");
            }
            // at least one scored position afterwards
            assert!(mask.data[b * 95..].iter().any(|&m| m == 1.0));
        }
    }

    #[test]
    fn prompt_batch_repeats_groups() {
        let tok = Tokenizer::new();
        let mut rng = Pcg64::new(4);
        let probs: Vec<_> = (0..3).map(|_| SUITES[0].generate(&mut rng)).collect();
        let pb = prompt_batch(&probs, &tok, 4, 64);
        assert_eq!(pb.tokens.shape, vec![12, 64]);
        assert_eq!(pb.problems.len(), 12);
        // rows within a group are identical
        for g in 0..3 {
            let base = &pb.tokens.data[g * 4 * 64..(g * 4 + 1) * 64];
            for k in 1..4 {
                let row = &pb.tokens.data[(g * 4 + k) * 64..(g * 4 + k + 1) * 64];
                assert_eq!(base, row);
                assert_eq!(pb.problems[g * 4 + k].prompt, pb.problems[g * 4].prompt);
            }
        }
    }
}
