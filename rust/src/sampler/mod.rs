//! Host-side sampling policies.
//!
//! The rollout hot path samples *inside* the fused `generate` executable
//! (rust supplies uniforms; see runtime docs), so this module serves the
//! per-step decode path (serving plane) and is the reference the in-HLO
//! sampler is validated against (integration test `generate_matches_host`).
//! The benchmark subsystem's k-way sampled decoding (`eval::bench`) rides
//! the same convention: temperature flows into the executable, uniforms
//! come from per-job RNG streams.

use crate::util::Pcg64;

/// log-softmax of a logit row (numerically stable).
pub fn log_softmax(logits: &[f32]) -> Vec<f32> {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = logits.iter().map(|&x| ((x - max) as f64).exp()).sum::<f64>().ln() as f32 + max;
    logits.iter().map(|&x| x - lse).collect()
}

pub fn softmax(logits: &[f32]) -> Vec<f32> {
    log_softmax(logits).iter().map(|&x| x.exp()).collect()
}

pub fn argmax(logits: &[f32]) -> usize {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

#[derive(Clone, Copy, Debug)]
pub struct SamplingParams {
    /// <= 0.0 means greedy
    pub temperature: f32,
    /// 0 means no top-k filtering
    pub top_k: usize,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self { temperature: 1.0, top_k: 0 }
    }
}

/// Sample one token; returns (token, logp under the sampling distribution).
/// Matches the in-HLO sampler: inverse-CDF over softmax(logits/temp) driven
/// by a single uniform.
///
/// ```
/// use tinylora_rl::sampler::{sample, SamplingParams};
/// // temperature <= 0 is greedy: picks the argmax, logp convention 0.0
/// let (tok, lp) = sample(&[0.1, 3.0, -1.0], SamplingParams { temperature: 0.0, top_k: 0 }, 0.5);
/// assert_eq!((tok, lp), (1, 0.0));
/// // u=0 always lands in the first bucket of the inverse CDF
/// let (tok, _) = sample(&[10.0, -10.0], SamplingParams::default(), 0.0);
/// assert_eq!(tok, 0);
/// ```
pub fn sample(logits: &[f32], params: SamplingParams, u: f32) -> (usize, f32) {
    if params.temperature <= 0.0 {
        let t = argmax(logits);
        return (t, 0.0);
    }
    let mut z: Vec<f32> = logits.iter().map(|&x| x / params.temperature).collect();
    if params.top_k > 0 && params.top_k < z.len() {
        let mut sorted: Vec<f32> = z.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let thresh = sorted[params.top_k - 1];
        for x in &mut z {
            if *x < thresh {
                *x = f32::NEG_INFINITY;
            }
        }
    }
    let lp = log_softmax(&z);
    let mut acc = 0.0f32;
    let mut tok = lp.len() - 1;
    for (i, &l) in lp.iter().enumerate() {
        acc += l.exp();
        if u < acc {
            tok = i;
            break;
        }
    }
    (tok, lp[tok])
}

/// Convenience: sample with an RNG instead of an explicit uniform.
pub fn sample_with_rng(logits: &[f32], params: SamplingParams, rng: &mut Pcg64) -> (usize, f32) {
    sample(logits, params, rng.uniform())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;

    #[test]
    fn softmax_sums_to_one() {
        check("softmax normalized", 100, |rng| {
            let n = rng.below(60) as usize + 2;
            let logits: Vec<f32> = (0..n).map(|_| rng.normal() * 5.0).collect();
            let s: f32 = softmax(&logits).iter().sum();
            if (s - 1.0).abs() < 1e-4 {
                Ok(())
            } else {
                Err(format!("sum {s}"))
            }
        });
    }

    #[test]
    fn greedy_picks_max() {
        let logits = vec![0.1, 3.0, -1.0, 2.9];
        let (t, lp) = sample(&logits, SamplingParams { temperature: 0.0, top_k: 0 }, 0.5);
        assert_eq!(t, 1);
        assert_eq!(lp, 0.0);
    }

    #[test]
    fn sampling_matches_distribution() {
        // frequency of each token under repeated sampling ~ softmax probs
        let logits = vec![1.0, 0.0, -1.0, 2.0];
        let probs = softmax(&logits);
        let mut rng = Pcg64::new(5);
        let mut counts = [0usize; 4];
        let n = 40_000;
        for _ in 0..n {
            let (t, _) = sample_with_rng(&logits, SamplingParams::default(), &mut rng);
            counts[t] += 1;
        }
        for i in 0..4 {
            let f = counts[i] as f32 / n as f32;
            assert!((f - probs[i]).abs() < 0.01, "tok {i}: {f} vs {}", probs[i]);
        }
    }

    #[test]
    fn temperature_sharpens() {
        let logits = vec![1.0, 0.0];
        let mut rng = Pcg64::new(6);
        let cold = SamplingParams { temperature: 0.2, top_k: 0 };
        let hot = SamplingParams { temperature: 5.0, top_k: 0 };
        let count = |p: SamplingParams, rng: &mut Pcg64| {
            (0..5000).filter(|_| sample_with_rng(&logits, p, rng).0 == 0).count()
        };
        let c_cold = count(cold, &mut rng);
        let c_hot = count(hot, &mut rng);
        assert!(c_cold > c_hot, "cold {c_cold} vs hot {c_hot}");
    }

    #[test]
    fn top_k_masks_tail() {
        let logits = vec![3.0, 2.0, -5.0, -6.0];
        let mut rng = Pcg64::new(7);
        for _ in 0..500 {
            let (t, _) =
                sample_with_rng(&logits, SamplingParams { temperature: 1.0, top_k: 2 }, &mut rng);
            assert!(t < 2, "sampled masked token {t}");
        }
    }

    #[test]
    fn reported_logp_is_correct() {
        let logits = vec![0.5, 1.5, -0.5];
        let lp_ref = log_softmax(&logits);
        let (t, lp) = sample(&logits, SamplingParams::default(), 0.3);
        assert!((lp - lp_ref[t]).abs() < 1e-5);
    }
}
