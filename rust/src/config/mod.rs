//! Experiment configuration + a tiny CLI argument parser (clap is not
//! available in the offline image).  Flags are `--key value` or `--flag`;
//! positional args are collected in order.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Self> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let next_is_value = argv
                    .get(i + 1)
                    .map(|n| !n.starts_with("--") || n.parse::<f64>().is_ok())
                    .unwrap_or(false);
                if next_is_value {
                    out.flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    out.flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                out.positional.push(a.clone());
                i += 1;
            }
        }
        Ok(out)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn req(&self, key: &str) -> Result<String> {
        self.flags.get(key).cloned().with_context(|| format!("missing required --{key}"))
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?} is not an integer")),
        }
    }

    pub fn u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?} is not an integer")),
        }
    }

    pub fn f32(&self, key: &str, default: f32) -> Result<f32> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?} is not a number")),
        }
    }

    pub fn f32_list(&self, key: &str, default: &[f32]) -> Result<Vec<f32>> {
        match self.flags.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|x| x.trim().parse::<f32>().map_err(|e| anyhow::anyhow!("{e}")))
                .collect(),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }

    pub fn str_list(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.flags.get(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|x| x.trim().to_string()).collect(),
        }
    }
}

/// Standard directories used by all drivers, overridable via env/flags.
#[derive(Clone, Debug)]
pub struct Dirs {
    pub artifacts: std::path::PathBuf,
    pub ckpts: std::path::PathBuf,
    pub results: std::path::PathBuf,
}

impl Dirs {
    pub fn from_args(args: &Args) -> Self {
        let art = args.str(
            "artifacts",
            &std::env::var("TINYLORA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
        );
        Self {
            artifacts: art.into(),
            ckpts: args.str("ckpts", "ckpts").into(),
            results: args.str("results", "results").into(),
        }
    }
}

/// Validate a scheme tag exists for a tier before spending time training.
pub fn validate_scheme(manifest: &crate::manifest::Manifest, tier: &str, tag: &str, algo: &str) -> Result<()> {
    if manifest.grad_exe(tier, algo, tag).is_err() {
        let available: Vec<_> = manifest
            .executables
            .values()
            .filter(|e| e.fn_kind == algo && e.tier == tier)
            .filter_map(|e| e.scheme_tag.clone())
            .collect();
        bail!("no {algo} artifact for {tier}/{tag}; available: {available:?}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn flags_and_positional() {
        let a = parse(&["train", "--tier", "micro", "--echo", "--lr", "1e-3"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.str("tier", "x"), "micro");
        assert!(a.bool("echo"));
        assert_eq!(a.f32("lr", 0.0).unwrap(), 1e-3);
        assert_eq!(a.usize("steps", 42).unwrap(), 42);
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = parse(&["--x", "-3"]);
        assert_eq!(a.f32("x", 0.0).unwrap(), -3.0);
    }

    #[test]
    fn lists() {
        let a = parse(&["--lrs", "1e-4,5e-4, 1e-3"]);
        assert_eq!(a.f32_list("lrs", &[]).unwrap(), vec![1e-4, 5e-4, 1e-3]);
        let b = parse(&["--tiers", "nano,micro"]);
        assert_eq!(b.str_list("tiers", &["base"]), vec!["nano", "micro"]);
    }

    #[test]
    fn bad_number_errors() {
        let a = parse(&["--lr", "abc"]);
        assert!(a.f32("lr", 0.0).is_err());
    }
}
