//! Benchmark-subsystem benchmarks (`cargo bench --bench bench_eval`).
//!
//! Pure-rust parts always run: the unbiased pass@k estimator over the full
//! (n, c, k) grid, majority voting, and grouped-row scoring over synthetic
//! decode rows. With artifacts built, the headline comparison runs: serial
//! vs pooled full-ladder runs at k ∈ {1, 4, 16} on the nano tier —
//! recorded alongside `bench_trainer` / `bench_main` output.

use std::path::Path;

use tinylora_rl::engine::{GenRow, InferenceEngine};
use tinylora_rl::eval::bench::{
    majority_answer, pass_at_k, run_ladder_with, score_rows, BenchConfig,
};
use tinylora_rl::tasks::generator::{Problem, SUITES};
use tinylora_rl::util::{timer::time_iters, Pcg64, Timer};
use tinylora_rl::weights::WeightSet;
use tinylora_rl::Runtime;

struct Bench {
    rows: Vec<(String, f64)>,
}

impl Bench {
    fn run<F: FnMut()>(&mut self, name: &str, iters: usize, note: &str, mut f: F) {
        f(); // warmup
        let (mean, min, max) = time_iters(iters, &mut f);
        println!("{name:<48} mean {mean:>9.3} ms  (min {min:>9.3}, max {max:>9.3})  {note}");
        self.rows.push((name.to_string(), mean));
    }
}

/// n_problems x k synthetic decode rows in the engine's grouped layout
/// (every third sample correct, all in canonical format).
fn synthetic_rows(n_problems: usize, k: usize) -> (Vec<Problem>, Vec<GenRow>) {
    let mut rng = Pcg64::new(3);
    let problems: Vec<Problem> = (0..n_problems).map(|_| SUITES[0].generate(&mut rng)).collect();
    let mut rows = Vec::with_capacity(n_problems * k);
    for p in &problems {
        for j in 0..k {
            let correct = j % 3 == 0;
            let ans = if correct { p.answer } else { p.answer + 1 };
            rows.push(GenRow {
                prompt_len: 8,
                response: vec![1; 12],
                behavior: vec![],
                text: format!("#### {ans}"),
                reward: if correct { 1.0 } else { 0.0 },
                hit_eos: true,
                has_format: true,
            });
        }
    }
    (problems, rows)
}

fn main() {
    let mut b = Bench { rows: Vec::new() };
    println!("== benchmark subsystem benchmarks ==\n");

    // ---------------- pure-rust estimators ----------------
    b.run("pass@k estimator, full 16x16x16 grid", 200, "unbiased formula", || {
        let mut acc = 0.0f64;
        for n in 1..=16usize {
            for c in 0..=n {
                for k in 1..=n {
                    acc += pass_at_k(n, c, k);
                }
            }
        }
        std::hint::black_box(acc);
    });

    let votes: Vec<Vec<Option<i64>>> = (0..1000)
        .map(|i| {
            (0..16).map(|j| if j % 5 == 4 { None } else { Some(((i + j) % 7) as i64) }).collect()
        })
        .collect();
    b.run("maj@16 vote, 1k problems", 200, "first-seen tie-break", || {
        let mut hits = 0usize;
        for v in &votes {
            if majority_answer(v).is_some() {
                hits += 1;
            }
        }
        std::hint::black_box(hits);
    });

    let (problems, rows) = synthetic_rows(1024, 4);
    b.run("score_rows 1024 problems x k=4", 100, "grouped-row scoring", || {
        std::hint::black_box(score_rows("gsm8k-syn", &problems, &rows, 4).unwrap());
    });

    // ---------------- ladder decode (needs artifacts) ----------------
    if !Path::new("artifacts/manifest.json").exists() {
        println!("\nartifacts not built — skipping ladder decode benches");
        return;
    }
    let rt = Runtime::new(Path::new("artifacts")).expect("runtime");
    let tier = rt.manifest.tier("nano").expect("nano tier").clone();
    let ckpt = Path::new("ckpts").join("nano.ckpt");
    let base =
        if ckpt.exists() { WeightSet::load(&ckpt).unwrap() } else { WeightSet::init(&tier, 0).unwrap() };

    println!();
    for k in [1usize, 4, 16] {
        // prefer the rollout geometry, fall back to the test geometry;
        // k must divide the baked batch
        let batch = [rt.manifest.batch.roll, rt.manifest.batch.test]
            .into_iter()
            .find(|&bsz| bsz >= k && bsz % k == 0);
        let Some(batch) = batch else {
            println!("ladder/k={k:<2} no decode geometry divisible by k — skipped");
            continue;
        };
        let engine = match InferenceEngine::new(&rt, "nano", batch) {
            Ok(e) => e,
            Err(e) => {
                println!("ladder/k={k:<2} no nano executable at batch {batch} — skipped ({e})");
                continue;
            }
        };
        for (label, workers) in [("serial", 1usize), ("4 workers", 4)] {
            let mut cfg = BenchConfig::new("nano");
            cfg.k = k;
            cfg.n = 8;
            cfg.temperature = 1.0;
            cfg.seed = 5;
            cfg.workers = workers;
            cfg.batch = batch;
            let t0 = Timer::start();
            let run = run_ladder_with(&rt, &engine, &base, "base", 0, &cfg).expect("ladder");
            let ms = t0.millis();
            let samples: usize = run.scores.iter().map(|sc| sc.n * sc.k).sum();
            println!(
                "ladder/k={k:<2} {label:<10} {ms:>9.0} ms  ({} suites, {samples} samples, {:.1} samples/s)",
                run.scores.len(),
                samples as f64 / (ms / 1e3)
            );
            b.rows.push((format!("ladder/{k}/{label}"), ms));
        }
        let serial = b.rows.iter().find(|r| r.0 == format!("ladder/{k}/serial")).unwrap().1;
        let par = b.rows.iter().find(|r| r.0 == format!("ladder/{k}/4 workers")).unwrap().1;
        println!(
            "pooled ladder speedup @k={k}: {:.2}x (serial {serial:.0} ms -> pooled {par:.0} ms)",
            serial / par
        );
    }
}
