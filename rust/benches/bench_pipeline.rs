//! Async-pipeline benchmark + the committed steps/s snapshot
//! (`cargo bench --bench bench_pipeline`).
//!
//! Emits `../BENCH_pipeline.json` (repo root): optimizer steps/s of the
//! synchronous wave trainer (`TenantTrainer::train`) vs the async
//! off-policy pipeline (`trainer::pipeline::train_async`) at 10 / 100 /
//! 1000 tenants on the hermetic sim backend — the population-scale claim
//! of the training plane, measurable on every machine with zero
//! artifacts.
//!
//! Snapshot schema, like `BENCH_SIM.json`:
//!   * `config` — deterministic echo of the run shape (tier, steps,
//!     group, staleness, threads, scales); `--check` recomputes it and
//!     fails on drift;
//!   * `measured` — per-scale steps/s plus the pipeline's own exact
//!     accounting, cross-checked by `--check`: `speedup` must equal
//!     async/sync, `consumed` must equal tenants × steps, and the
//!     window = staleness + 1 configuration must report ZERO stale
//!     drops (the replay queue can only overproduce past the window);
//!   * `provenance` — "measured" when this binary wrote the numbers on
//!     a live run, "estimate" when they were projected without one;
//!     `--check` accepts either and prints which.
//!
//! Modes:
//!   cargo bench --bench bench_pipeline              # run + rewrite snapshot
//!   cargo bench --bench bench_pipeline -- --check   # validate committed
//!                                                   # snapshot (ci.sh gate)

use tinylora_rl::adapters::packing::Precision;
use tinylora_rl::coordinator::grpo::GrpoConfig;
use tinylora_rl::metrics::RunLog;
use tinylora_rl::runtime::{SIM_SCHEME, SIM_TIER};
use tinylora_rl::trainer::pipeline::train_async;
use tinylora_rl::trainer::{PipelineConfig, TenantSpec, TenantTrainer};
use tinylora_rl::util::json::{num, obj, s, Value};
use tinylora_rl::util::Timer;
use tinylora_rl::weights::WeightSet;
use tinylora_rl::Runtime;

/// Committed snapshot path (repo root; cargo bench runs from `rust/`).
/// Override with TINYLORA_BENCH_PIPELINE for scratch runs.
fn snapshot_path() -> String {
    std::env::var("TINYLORA_BENCH_PIPELINE").unwrap_or_else(|_| "../BENCH_pipeline.json".into())
}

const SCHEMA_VERSION: usize = 1;
/// Tenant-population scales swept (the 10^1..10^3 trajectory).
const SCALES: [usize; 3] = [10, 100, 1000];
/// Optimizer steps per tenant at every scale.
const STEPS: usize = 4;
const GROUP: usize = 2;
/// Async shape: window = STALENESS + 1, so the pipeline can never drop —
/// `--check` asserts `dropped_stale == 0` on exactly that ground.
const STALENESS: u64 = 1;
const OPT_THREADS: usize = 4;
const WORKERS: usize = 4;
const DEVICES: usize = 2;

fn config_section() -> Value {
    obj(vec![
        ("tier", s(SIM_TIER)),
        ("scheme", s(SIM_SCHEME)),
        ("devices", num(DEVICES as f64)),
        ("workers", num(WORKERS as f64)),
        ("steps", num(STEPS as f64)),
        ("group", num(GROUP as f64)),
        ("staleness", num(STALENESS as f64)),
        ("optimizer_threads", num(OPT_THREADS as f64)),
        ("scales", Value::Arr(SCALES.iter().map(|&x| num(x as f64)).collect())),
    ])
}

fn build_trainer(rt: &Runtime, base: &WeightSet, tenants: usize) -> TenantTrainer {
    let specs: Vec<TenantSpec> = (0..tenants)
        .map(|i| TenantSpec {
            name: format!("bench-{i}"),
            scheme_tag: SIM_SCHEME.into(),
            cfg: GrpoConfig { group: GROUP, steps: STEPS, seed: i as u64, ..Default::default() },
            precision: Precision::Bf16,
        })
        .collect();
    let ckpt = std::env::temp_dir().join("tlrl_bench_pipeline");
    std::fs::create_dir_all(&ckpt).ok();
    let batch = rt.manifest.batch.test;
    TenantTrainer::with_batch(rt, base, specs, WORKERS, &ckpt, batch).expect("tenant trainer")
}

struct ScalePoint {
    tenants: usize,
    sync_sps: f64,
    async_sps: f64,
    produced: u64,
    consumed: u64,
    dropped: u64,
}

fn measure_scale(tenants: usize) -> ScalePoint {
    let rt = Runtime::sim(DEVICES).expect("sim runtime");
    let tier = rt.manifest.tier(SIM_TIER).expect("sim tier").clone();
    let base = WeightSet::init(&tier, 0).unwrap();
    let total = (tenants * STEPS) as f64;

    let mut tt = build_trainer(&rt, &base, tenants);
    let mut log = RunLog::null();
    let t = Timer::start();
    tt.train(&rt, &mut log, true).expect("sync train");
    let sync_sps = total / t.secs();

    let mut tt = build_trainer(&rt, &base, tenants);
    let pcfg = PipelineConfig {
        max_staleness: STALENESS,
        optimizer_threads: OPT_THREADS,
        queue_cap: 0,
    };
    let t = Timer::start();
    let (_, st) = train_async(&rt, &mut tt, &pcfg, &mut log, true).expect("async train");
    let async_sps = total / t.secs();
    println!(
        "tenants {tenants:>5}: sync {sync_sps:>8.1} steps/s | async {async_sps:>8.1} steps/s \
         ({:.2}x) | produced {} consumed {} dropped {}",
        async_sps / sync_sps,
        st.produced,
        st.consumed,
        st.dropped_stale,
    );
    ScalePoint {
        tenants,
        sync_sps,
        async_sps,
        produced: st.produced,
        consumed: st.consumed,
        dropped: st.dropped_stale,
    }
}

fn measured_section(points: &[ScalePoint]) -> Value {
    Value::Arr(
        points
            .iter()
            .map(|p| {
                obj(vec![
                    ("tenants", num(p.tenants as f64)),
                    ("sync_steps_per_s", num(p.sync_sps)),
                    ("async_steps_per_s", num(p.async_sps)),
                    ("speedup", num(p.async_sps / p.sync_sps)),
                    ("produced", num(p.produced as f64)),
                    ("consumed", num(p.consumed as f64)),
                    ("dropped_stale", num(p.dropped as f64)),
                ])
            })
            .collect(),
    )
}

fn validate_schema(v: &Value) -> Result<(), String> {
    let get = |key: &str| v.get(key).map_err(|e| format!("{e:#}"));
    if get("kind")?.str().map_err(|e| format!("kind: {e:#}"))? != "bench_pipeline" {
        return Err("kind != bench_pipeline".into());
    }
    let version = get("schema_version")?.usize().map_err(|e| format!("schema_version: {e:#}"))?;
    if version != SCHEMA_VERSION {
        return Err(format!("schema_version {version} != {SCHEMA_VERSION}"));
    }
    let provenance = get("provenance")?.str().map_err(|e| format!("provenance: {e:#}"))?;
    if provenance != "estimate" && provenance != "measured" {
        return Err(format!("provenance {provenance:?} not in {{estimate, measured}}"));
    }
    let config = get("config")?;
    let want = config_section();
    if *config != want {
        return Err(format!(
            "config drift: committed {} != recomputed {} — the bench shape \
             changed; rerun `cargo bench --bench bench_pipeline` and commit \
             the refreshed snapshot",
            config.to_string(),
            want.to_string()
        ));
    }
    let measured = get("measured")?
        .arr()
        .map(|a| a.to_vec())
        .map_err(|e| format!("measured: {e:#}"))?;
    if measured.len() != SCALES.len() {
        return Err(format!("measured has {} entries, expected {}", measured.len(), SCALES.len()));
    }
    for (entry, &scale) in measured.iter().zip(&SCALES) {
        let f = |key: &str| -> Result<f64, String> {
            entry.get(key).and_then(|x| x.f64()).map_err(|e| format!("{key} @ {scale}: {e:#}"))
        };
        if f("tenants")? as usize != scale {
            return Err(format!("scale order drift: expected tenants {scale}"));
        }
        let sync = f("sync_steps_per_s")?;
        let a = f("async_steps_per_s")?;
        let speedup = f("speedup")?;
        for (name, x) in [("sync_steps_per_s", sync), ("async_steps_per_s", a)] {
            if !x.is_finite() || x <= 0.0 {
                return Err(format!("{name} @ {scale} not positive: {x}"));
            }
        }
        let ratio = a / sync;
        if (speedup - ratio).abs() > 0.01 * ratio {
            return Err(format!(
                "speedup {speedup:.4} @ {scale} inconsistent with async/sync = {ratio:.4}"
            ));
        }
        let consumed = f("consumed")? as u64;
        let produced = f("produced")? as u64;
        let dropped = f("dropped_stale")? as u64;
        let want_steps = (scale * STEPS) as u64;
        if consumed != want_steps {
            return Err(format!(
                "consumed {consumed} @ {scale} != tenants x steps = {want_steps}"
            ));
        }
        if dropped != 0 || produced != consumed {
            return Err(format!(
                "window = staleness + 1 must never drop: produced {produced} \
                 consumed {consumed} dropped {dropped} @ {scale}"
            ));
        }
    }
    Ok(())
}

/// `--check`: committed snapshot must be schema-valid, shape-current and
/// internally consistent; prints the committed steps/s tally (and the
/// snapshot's provenance) that ci.sh surfaces in its full-mode report.
fn check_snapshot(path: &str) -> Result<(), String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    let v = Value::parse(text.trim()).map_err(|e| format!("parsing {path}: {e:#}"))?;
    validate_schema(&v)?;
    let provenance = v.get("provenance").and_then(|x| x.str().map(String::from)).unwrap();
    println!("pipeline snapshot provenance: {provenance}");
    let measured = v.get("measured").and_then(|x| x.arr().map(|a| a.to_vec())).unwrap();
    for entry in &measured {
        let f = |key: &str| entry.get(key).and_then(|x| x.f64()).unwrap();
        println!(
            "pipeline steps/s (committed): {:>5.0} tenants  sync {:>8.1}  async {:>8.1}  \
             ({:.2}x)  dropped {}",
            f("tenants"),
            f("sync_steps_per_s"),
            f("async_steps_per_s"),
            f("speedup"),
            f("dropped_stale"),
        );
    }
    Ok(())
}

fn main() {
    let check = std::env::args().any(|a| a == "--check");
    let path = snapshot_path();
    if check {
        match check_snapshot(&path) {
            Ok(()) => println!("BENCH_pipeline.json: schema + config + accounting OK ({path})"),
            Err(e) => {
                eprintln!("BENCH_pipeline.json check failed: {e}");
                std::process::exit(1);
            }
        }
        return;
    }

    println!("== async-pipeline benchmarks (sync vs async steps/s) ==\n");
    let points: Vec<ScalePoint> = SCALES.iter().map(|&t| measure_scale(t)).collect();
    let snapshot = obj(vec![
        ("kind", s("bench_pipeline")),
        ("schema_version", num(SCHEMA_VERSION as f64)),
        ("provenance", s("measured")),
        ("config", config_section()),
        ("measured", measured_section(&points)),
    ]);
    if let Err(e) = validate_schema(&snapshot) {
        eprintln!("generated snapshot failed its own schema: {e}");
        std::process::exit(1);
    }
    std::fs::write(&path, snapshot.to_string() + "\n").expect("writing snapshot");
    println!("perf snapshot -> {path}");
}
