//! Runtime-subsystem benchmarks + the repo's machine-readable perf
//! trajectory (`cargo bench --bench bench_runtime`).
//!
//! Emits `../BENCH_runtime.json` (repo root), the committed perf snapshot
//! the repo regresses against. The snapshot's schema is deterministic and
//! split in two:
//!
//!   * deterministic sections (`geometry`, `padding`) — pure functions of
//!     the code (occupancy-aware `flush_plan` vs the fixed-geometry
//!     baseline over a queue-depth grid), byte-identical on every
//!     machine; `--check` recomputes them and fails on any drift;
//!   * the measured section (`device_parallel`) — rows/s serial vs pooled
//!     at D ∈ {1, 2, 4} execution contexts on the hermetic sim backend.
//!     Running on `--backend sim` (instead of gating on PJRT artifacts)
//!     means the measurement runs on every machine, so the committed
//!     snapshot is REQUIRED to carry it — `--check` fails on `null`.
//!
//! Modes:
//!   cargo bench --bench bench_runtime              # run + rewrite snapshot
//!   cargo bench --bench bench_runtime -- --check   # validate committed
//!                                                  # snapshot (ci.sh gate)

use tinylora_rl::engine::pool::{GenJob, WorkerPool};
use tinylora_rl::engine::{flush_plan, InferenceEngine};
use tinylora_rl::eval::eval_problems;
use tinylora_rl::runtime::SIM_TIER;
use tinylora_rl::tensor::{TensorF32, TensorI32};
use tinylora_rl::util::json::{num, obj, s, Value};
use tinylora_rl::util::timer::time_iters;
use tinylora_rl::util::Timer;
use tinylora_rl::weights::WeightSet;
use tinylora_rl::Runtime;

/// Committed snapshot path (repo root; cargo bench runs from `rust/`).
/// Override with TINYLORA_BENCH_RUNTIME for scratch runs.
fn snapshot_path() -> String {
    std::env::var("TINYLORA_BENCH_RUNTIME").unwrap_or_else(|_| "../BENCH_runtime.json".into())
}

const SCHEMA_VERSION: usize = 2;
/// Fixed-geometry baseline: one baked batch, tails pad all the way up.
const FIXED: &[usize] = &[32];
/// Occupancy-aware geometry set: tails flush on the smallest fit.
const OCCUPANCY: &[usize] = &[4, 8, 16, 32];
/// Queue depths swept by the padding comparison: 1..=DEPTH_MAX.
const DEPTH_MAX: usize = 96;

fn padded_rows(plan: &[(usize, usize)]) -> usize {
    plan.iter().map(|(g, real)| g - real).sum()
}

fn geometry_section() -> Value {
    let ints = |xs: &[usize]| Value::Arr(xs.iter().map(|&x| num(x as f64)).collect());
    obj(vec![("fixed", ints(FIXED)), ("occupancy", ints(OCCUPANCY))])
}

/// Deterministic padding-waste comparison: integer totals only (integers
/// serialize identically everywhere; ratios are derived at read time).
fn padding_section() -> Value {
    let canonical = *OCCUPANCY.last().unwrap();
    let (mut rows, mut fixed_padded, mut occupancy_padded) = (0usize, 0usize, 0usize);
    for depth in 1..=DEPTH_MAX {
        rows += depth;
        fixed_padded += padded_rows(&flush_plan(FIXED, canonical, depth));
        occupancy_padded += padded_rows(&flush_plan(OCCUPANCY, canonical, depth));
    }
    obj(vec![
        ("depth_min", num(1.0)),
        ("depth_max", num(DEPTH_MAX as f64)),
        ("rows", num(rows as f64)),
        ("fixed_padded", num(fixed_padded as f64)),
        ("occupancy_padded", num(occupancy_padded as f64)),
    ])
}

/// Measured section: decode throughput serial vs pooled at D execution
/// contexts, measured on the hermetic sim backend — zero artifacts, so
/// it runs (and the snapshot stays populated) on every machine.
fn device_section() -> Value {
    let n_jobs = 8usize;
    let workers = 4usize;
    let mut serial_rps = 0.0f64;
    let mut pooled = Vec::new();
    for d in [1usize, 2, 4] {
        let rt = Runtime::sim(d).expect("sim runtime");
        let tier = rt.manifest.tier(SIM_TIER).expect("sim tier").clone();
        let batch = rt.manifest.batch.test;
        let engine = InferenceEngine::new(&rt, SIM_TIER, batch).expect("engine");
        let base = WeightSet::init(&tier, 0).unwrap();
        let make_jobs = || -> Vec<GenJob> {
            (0..n_jobs as u64)
                .map(|id| GenJob {
                    id,
                    weights: base.clone(),
                    problems: eval_problems("gsm8k-syn", batch, 100 + id).unwrap(),
                    group: 1,
                    pb: None,
                    temperature: 1.0,
                    seed: id,
                    policy_version: 0,
                })
                .collect()
        };
        let total_rows = (n_jobs * batch) as f64;
        let pool = WorkerPool::new(workers);
        // warmup: compile every (context, geometry) the jobs will touch
        pool.serve(&rt, &engine, make_jobs()).expect("warmup");
        if d == 1 {
            let t = Timer::start();
            WorkerPool::serve_serial(&rt, &engine, &make_jobs()).expect("serial");
            serial_rps = total_rows / t.secs();
        }
        let t = Timer::start();
        pool.serve(&rt, &engine, make_jobs()).expect("pooled");
        let rps = total_rows / t.secs();
        println!("device_parallel: D={d} pooled {rps:>9.1} rows/s ({workers} workers)");
        pooled.push((d, rps));
    }
    println!("device_parallel: serial {serial_rps:>9.1} rows/s");
    obj(vec![
        ("backend", s("sim")),
        ("tier", s(SIM_TIER)),
        ("jobs", num(n_jobs as f64)),
        ("workers", num(workers as f64)),
        ("serial_rows_per_s", num(serial_rps)),
        (
            "pooled_rows_per_s",
            Value::Arr(
                pooled
                    .iter()
                    .map(|&(d, rps)| {
                        obj(vec![("devices", num(d as f64)), ("rows_per_s", num(rps))])
                    })
                    .collect(),
            ),
        ),
    ])
}

fn build_snapshot(device: Value) -> Value {
    obj(vec![
        ("kind", s("bench_runtime")),
        ("schema_version", num(SCHEMA_VERSION as f64)),
        ("provenance", s("measured")),
        ("geometry", geometry_section()),
        ("padding", padding_section()),
        ("device_parallel", device),
    ])
}

// ---------------------------------------------------------------------------
// schema validation (the ci.sh gate)
// ---------------------------------------------------------------------------

fn ascending_usizes(v: &Value, what: &str) -> Result<(), String> {
    let xs = v.usize_vec().map_err(|e| format!("{what}: {e:#}"))?;
    if xs.is_empty() {
        return Err(format!("{what}: empty geometry set"));
    }
    if xs.windows(2).any(|w| w[0] >= w[1]) {
        return Err(format!("{what}: not strictly ascending: {xs:?}"));
    }
    Ok(())
}

/// Structural validation of a snapshot (measured values are NOT compared
/// — only their schema; the deterministic sections are compared exactly
/// by `check_snapshot`).
fn validate_schema(v: &Value) -> Result<(), String> {
    let get = |key: &str| v.get(key).map_err(|e| format!("{e:#}"));
    if get("kind")?.str().map_err(|e| format!("kind: {e:#}"))? != "bench_runtime" {
        return Err("kind != bench_runtime".into());
    }
    let version = get("schema_version")?.usize().map_err(|e| format!("schema_version: {e:#}"))?;
    if version != SCHEMA_VERSION {
        return Err(format!("schema_version {version} != {SCHEMA_VERSION}"));
    }
    let provenance = get("provenance")?.str().map_err(|e| format!("provenance: {e:#}"))?;
    if provenance != "estimate" && provenance != "measured" {
        return Err(format!("provenance {provenance:?} not in {{estimate, measured}}"));
    }
    let geo = get("geometry")?;
    ascending_usizes(geo.get("fixed").map_err(|e| format!("{e:#}"))?, "geometry.fixed")?;
    ascending_usizes(geo.get("occupancy").map_err(|e| format!("{e:#}"))?, "geometry.occupancy")?;
    let pad = get("padding")?;
    let field = |key: &str| -> Result<usize, String> {
        pad.get(key)
            .and_then(|x| x.usize())
            .map_err(|e| format!("padding.{key}: {e:#}"))
    };
    let (rows, fixed, occ) =
        (field("rows")?, field("fixed_padded")?, field("occupancy_padded")?);
    field("depth_min")?;
    field("depth_max")?;
    if occ > fixed {
        return Err(format!(
            "padding regression: occupancy_padded {occ} > fixed_padded {fixed} (rows {rows})"
        ));
    }
    let dev = get("device_parallel")?;
    if matches!(dev, Value::Null) {
        return Err(
            "device_parallel is null — the measurement runs on the hermetic sim \
             backend (no artifacts needed); rerun `cargo bench --bench \
             bench_runtime` and commit the refreshed snapshot"
                .into(),
        );
    }
    for key in ["backend", "tier"] {
        dev.get(key)
            .and_then(|x| x.str().map(str::to_string))
            .map_err(|e| format!("device_parallel.{key}: {e:#}"))?;
    }
    for key in ["jobs", "workers", "serial_rows_per_s"] {
        dev.get(key)
            .and_then(|x| x.f64())
            .map_err(|e| format!("device_parallel.{key}: {e:#}"))?;
    }
    let pooled = dev
        .get("pooled_rows_per_s")
        .and_then(|x| x.arr().map(|a| a.to_vec()))
        .map_err(|e| format!("device_parallel.pooled_rows_per_s: {e:#}"))?;
    if pooled.is_empty() {
        return Err("device_parallel.pooled_rows_per_s: empty".into());
    }
    for p in &pooled {
        p.get("devices")
            .and_then(|x| x.usize())
            .map_err(|e| format!("pooled devices: {e:#}"))?;
        let rps = p
            .get("rows_per_s")
            .and_then(|x| x.f64())
            .map_err(|e| format!("pooled rows_per_s: {e:#}"))?;
        if !rps.is_finite() || rps <= 0.0 {
            return Err(format!("pooled rows_per_s not positive: {rps}"));
        }
    }
    Ok(())
}

/// `--check`: the committed snapshot must be schema-valid AND its
/// deterministic sections must equal a fresh recomputation byte-for-byte.
fn check_snapshot(path: &str) -> Result<(), String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    let v = Value::parse(text.trim()).map_err(|e| format!("parsing {path}: {e:#}"))?;
    validate_schema(&v)?;
    let provenance = v.get("provenance").and_then(|x| x.str().map(String::from)).unwrap();
    println!("runtime snapshot provenance: {provenance}");
    let want = geometry_section();
    let got = v.get("geometry").map_err(|e| format!("{e:#}"))?;
    if *got != want {
        return Err(format!(
            "geometry drift: committed {} != recomputed {}",
            got.to_string(),
            want.to_string()
        ));
    }
    let want = padding_section();
    let got = v.get("padding").map_err(|e| format!("{e:#}"))?;
    if *got != want {
        return Err(format!(
            "padding drift: committed {} != recomputed {} — occupancy-aware \
             geometry selection changed; rerun `cargo bench --bench \
             bench_runtime` and commit the refreshed snapshot",
            got.to_string(),
            want.to_string()
        ));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// micro-benches (printed only; never serialized — timings are not
// deterministic and the snapshot stays byte-stable without them)
// ---------------------------------------------------------------------------

fn bench_literal_conversion() {
    let mut run = |name: &str, iters: usize, f: &mut dyn FnMut()| {
        f();
        let (mean, min, max) = time_iters(iters, f);
        println!("{name:<48} mean {mean:>9.3} ms  (min {min:>9.3}, max {max:>9.3})");
    };
    let rank1 = TensorF32::from_vec(&[1 << 16], vec![0.5; 1 << 16]);
    run("tensor/to_literal rank-1 64k (no reshape copy)", 200, &mut || {
        std::hint::black_box(rank1.to_literal().unwrap());
    });
    let rank2 = TensorF32::from_vec(&[256, 256], vec![0.5; 1 << 16]);
    run("tensor/to_literal rank-2 64k (reshape path)", 200, &mut || {
        std::hint::black_box(rank2.to_literal().unwrap());
    });
    let ints = TensorI32::from_vec(&[1 << 16], vec![7; 1 << 16]);
    run("tensor/to_literal rank-1 64k i32", 200, &mut || {
        std::hint::black_box(ints.to_literal().unwrap());
    });
}

fn main() {
    let check = std::env::args().any(|a| a == "--check");
    let path = snapshot_path();
    if check {
        match check_snapshot(&path) {
            Ok(()) => println!("BENCH_runtime.json: schema + deterministic sections OK ({path})"),
            Err(e) => {
                eprintln!("BENCH_runtime.json check failed: {e}");
                std::process::exit(1);
            }
        }
        return;
    }

    println!("== runtime subsystem benchmarks ==\n");
    bench_literal_conversion();

    let pad = padding_section();
    let fixed = pad.get("fixed_padded").and_then(|x| x.usize()).unwrap();
    let occ = pad.get("occupancy_padded").and_then(|x| x.usize()).unwrap();
    let rows = pad.get("rows").and_then(|x| x.usize()).unwrap();
    println!(
        "\npadding over depths 1..={DEPTH_MAX}: fixed {fixed} padded rows \
         ({:.1}% waste) -> occupancy-aware {occ} ({:.1}% waste)",
        100.0 * fixed as f64 / (rows + fixed) as f64,
        100.0 * occ as f64 / (rows + occ) as f64,
    );

    println!();
    let snapshot = build_snapshot(device_section());
    if let Err(e) = validate_schema(&snapshot) {
        eprintln!("generated snapshot failed its own schema: {e}");
        std::process::exit(1);
    }
    std::fs::write(&path, snapshot.to_string() + "\n").expect("writing snapshot");
    println!("perf snapshot -> {path}");
}
